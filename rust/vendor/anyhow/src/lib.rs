//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no network registry access (DESIGN.md §2), so
//! this vendored shim provides exactly the surface the `apt` crate uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait. Error values are rendered eagerly into a
//! message string with the `source()` chain appended (`: `-joined), which
//! matches how the callers format errors (`{e}` / `{e:#}`).

use std::fmt;

/// A string-backed error type. Deliberately does **not** implement
/// `std::error::Error` so the blanket `From<E: Error>` below does not
/// overlap with `core`'s identity `From` impl — the same coherence trick
/// the real `anyhow` relies on.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (used by [`anyhow!`]).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prefix the message with additional context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e:#}` (alternate) and `{e}` both print the full chain: the
        // chain was flattened into `msg` at construction time.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut msg = err.to_string();
        let mut source = err.source();
        while let Some(cause) = source {
            msg.push_str(": ");
            msg.push_str(&cause.to_string());
            source = cause.source();
        }
        Error { msg }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad dim {}: {}", 3, "x");
        assert_eq!(format!("{e}"), "bad dim 3: x");
        assert_eq!(format!("{e:#}"), "bad dim 3: x");
        assert_eq!(format!("{e:?}"), "bad dim 3: x");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "boom 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let _n: usize = "nope".parse()?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "m.txt")).unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("reading m.txt: "), "{s}");
        let r2: std::result::Result<(), std::io::Error> = Err(io_err());
        let e2 = r2.context("ctx").unwrap_err();
        assert!(format!("{e2}").starts_with("ctx: "));
    }
}
