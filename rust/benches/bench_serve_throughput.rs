//! cargo bench — serving throughput/latency (EXPERIMENTS.md §Serve):
//! QPS and client-side p50/p99 over batch size × worker count ×
//! {f32, int8, int16} frozen mlp models × {fused plan, unfused
//! interpreter}, measured with closed-loop concurrent clients against the
//! micro-batching `InferenceServer`. The fused/unfused pair at equal
//! config is the inference-compiler speedup (EXPERIMENTS.md
//! §Serve-Compiler) — the two paths are bit-identical (test_compiler.rs),
//! so any gap is pure execution efficiency.
//! Writes `results/serve_throughput.csv`.
//!
//! `BENCH_QUICK=1` shortens the workload; `APT_SERVE_REQUESTS=N`
//! overrides the per-cell request count.

use std::sync::Arc;
use std::time::Instant;

use apt::compiler::CompileOptions;
use apt::data::SynthImages;
use apt::kernels::Engine;
use apt::nn::{models, QuantMode};
use apt::serve::{FrozenModel, InferenceServer, ServeConfig};
use apt::train::SessionBuilder;
use apt::util::out::{results_dir, Csv};
use apt::util::stats::percentile;

const TRAIN_ITERS: u64 = 30;

fn frozen_for(mode: QuantMode, fuse: bool) -> FrozenModel {
    let mut s = SessionBuilder::classifier("mlp").mode(mode).lr(0.01).build();
    s.run(TRAIN_ITERS).expect("train");
    let opts = CompileOptions { fuse, ..CompileOptions::default() };
    FrozenModel::freeze_with(format!("mlp-{}", mode.label()), s.net(), &opts).expect("freeze")
}

struct Cell {
    qps: f64,
    p50_us: f64,
    p99_us: f64,
    mean_batch: f64,
}

/// Closed-loop load: `clients` threads each submit/wait over their share of
/// `requests` samples.
fn run_cell(frozen: &Arc<FrozenModel>, cfg: ServeConfig, requests: usize) -> Cell {
    // Serial per-worker engines: scaling comes from the worker dimension,
    // not intra-op threading, so the table isolates the batching effect.
    let server = InferenceServer::start(Arc::clone(frozen), Arc::new(Engine::serial()), cfg)
        .expect("serve config is valid");
    let clients = (2 * cfg.max_batch).clamp(8, 64);
    let d = frozen.input_len();
    let mut data = SynthImages::new(
        42,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let (xs, _) = data.batch(requests);

    let wall = Instant::now();
    let latencies = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = &server;
            let xs = &xs;
            handles.push(scope.spawn(move || {
                let mut lat = Vec::new();
                let mut i = c;
                while i < requests {
                    let input = xs.data[i * d..(i + 1) * d].to_vec();
                    let t = Instant::now();
                    server.submit(input).expect("submit").wait().expect("response");
                    lat.push(t.elapsed().as_secs_f64());
                    i += clients;
                }
                lat
            }));
        }
        let mut lat = Vec::new();
        for h in handles {
            lat.extend(h.join().expect("client"));
        }
        lat
    });
    let secs = wall.elapsed().as_secs_f64();
    let stats = server.shutdown();
    Cell {
        qps: requests as f64 / secs,
        p50_us: percentile(&latencies, 50.0) * 1e6,
        p99_us: percentile(&latencies, 99.0) * 1e6,
        mean_batch: stats.mean_batch(),
    }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let requests = std::env::var("APT_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(if quick { 96 } else { 384 });

    let modes = [
        ("f32", QuantMode::Float32),
        ("int8", QuantMode::Static(8)),
        ("int16", QuantMode::Static(16)),
    ];
    let batch_sweep = [1usize, 8, 32];
    // Quick mode keeps the fused-vs-unfused comparison but drops the
    // worker sweep (the compiler gap is per-forward, not per-worker).
    let worker_sweep: &[usize] = if quick { &[2] } else { &[1, 2, 4] };

    println!(
        "bench_serve_throughput — mlp, {requests} requests/cell, closed-loop clients = 2×batch"
    );
    println!(
        "{:<7} {:>5} {:>8} {:>7} {:>9} {:>10} {:>10} {:>11}",
        "model", "fused", "workers", "batch", "QPS", "p50 µs", "p99 µs", "mean batch"
    );

    let mut csv = Csv::new(
        results_dir().join("serve_throughput.csv"),
        &[
            "precision",
            "fused",
            "workers",
            "max_batch",
            "requests",
            "qps",
            "p50_us",
            "p99_us",
            "mean_batch",
        ],
    );
    for (label, mode) in modes {
        for fused in [true, false] {
            let frozen = Arc::new(frozen_for(mode, fused));
            for &workers in worker_sweep {
                for &max_batch in &batch_sweep {
                    let cfg = ServeConfig {
                        max_batch,
                        max_wait_us: 200,
                        queue_cap: 256,
                        workers,
                        ..ServeConfig::default()
                    };
                    let cell = run_cell(&frozen, cfg, requests);
                    println!(
                        "{:<7} {:>5} {:>8} {:>7} {:>9.0} {:>10.1} {:>10.1} {:>11.2}",
                        label,
                        if fused { "yes" } else { "no" },
                        workers,
                        max_batch,
                        cell.qps,
                        cell.p50_us,
                        cell.p99_us,
                        cell.mean_batch
                    );
                    csv.row(&[
                        label.to_string(),
                        (fused as u8).to_string(),
                        workers.to_string(),
                        max_batch.to_string(),
                        requests.to_string(),
                        format!("{:.1}", cell.qps),
                        format!("{:.2}", cell.p50_us),
                        format!("{:.2}", cell.p99_us),
                        format!("{:.3}", cell.mean_batch),
                    ]);
                }
            }
        }
        println!();
    }
    csv.write().unwrap();
    println!("wrote {}", results_dir().join("serve_throughput.csv").display());
    println!("fill the EXPERIMENTS.md §Serve and §Serve-Compiler tables from the CSV");
}
