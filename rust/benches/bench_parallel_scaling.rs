//! cargo bench — kernel-engine thread scaling (EXPERIMENTS.md §Perf):
//! 512³ GEMM in f32/i8/i16, an AlexNet-shape conv GEMM, and the bulk
//! quantize pass, each at 1/2/4/8 threads. Writes
//! `results/parallel_scaling.csv` with speedups relative to 1 thread.
//!
//! `BENCH_QUICK=1` shortens sampling; `APT_BENCH_THREADS=1,2,4` overrides
//! the thread sweep.

use apt::bench::{Bencher, Sample};
use apt::fixedpoint::quantize::max_abs;
use apt::fixedpoint::Scheme;
use apt::kernels::Engine;
use apt::util::out::{results_dir, Csv};
use apt::util::Pcg32;

const DIM: usize = 512;

struct Case {
    name: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

const CASES: [Case; 2] = [
    // The acceptance shape: 512³ (134M MACs per kernel call).
    Case { name: "gemm-512", m: DIM, k: DIM, n: DIM },
    // AlexNet conv1 im2col shape — m = out_c, so row panels are
    // output-channel blocks.
    Case { name: "conv1-shape", m: 256, k: 48 * 5 * 5, n: 27 * 27 },
];

fn thread_sweep() -> Vec<usize> {
    if let Ok(v) = std::env::var("APT_BENCH_THREADS") {
        return v
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .filter(|&t| t >= 1)
            .collect();
    }
    vec![1, 2, 4, 8]
}

fn run_case(bencher: &Bencher, eng: &Engine, case: &Case) -> (Sample, Sample, Sample) {
    let (m, k, n) = (case.m, case.k, case.n);
    let mut rng = Pcg32::seeded(42);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 0.2);
    let sa8 = Scheme::for_range(max_abs(&a), 8);
    let sb8 = Scheme::for_range(max_abs(&b), 8);
    let mut a8 = vec![0i8; m * k];
    let mut b8 = vec![0i8; k * n];
    eng.codes_i8(&a, &mut a8, sa8);
    eng.codes_i8(&b, &mut b8, sb8);
    let sa16 = Scheme::for_range(max_abs(&a), 16);
    let sb16 = Scheme::for_range(max_abs(&b), 16);
    let mut a16 = vec![0i16; m * k];
    let mut b16 = vec![0i16; k * n];
    eng.codes_i16(&a, &mut a16, sa16);
    eng.codes_i16(&b, &mut b16, sb16);

    let sf32 = {
        let (a, b) = (a.clone(), b.clone());
        let mut c = vec![0.0f32; m * n];
        bencher.run(&format!("{}-f32", case.name), move || {
            eng.gemm_f32(m, k, n, &a, &b, &mut c);
            std::hint::black_box(&c);
        })
    };
    let si8 = {
        let (a8, b8) = (a8.clone(), b8.clone());
        let mut acc = vec![0i32; m * n];
        bencher.run(&format!("{}-i8", case.name), move || {
            eng.gemm_i8(m, k, n, &a8, &b8, &mut acc);
            std::hint::black_box(&acc);
        })
    };
    let si16 = {
        let (a16, b16) = (a16.clone(), b16.clone());
        let mut acc = vec![0i32; m * n];
        bencher.run(&format!("{}-i16", case.name), move || {
            eng.gemm_i16(m, k, n, &a16, &b16, &mut acc);
            std::hint::black_box(&acc);
        })
    };
    (sf32, si8, si16)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let threads = thread_sweep();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("bench_parallel_scaling — engine thread sweep {threads:?} on {cores} core(s)");
    println!(
        "{:<14} {:>8} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9}",
        "case", "threads", "f32 ms", "i8 ms", "i16 ms", "f32 x", "i8 x", "i16 x"
    );

    let mut csv = Csv::new(
        results_dir().join("parallel_scaling.csv"),
        &[
            "case", "threads", "f32_ms", "i8_ms", "i16_ms",
            "f32_speedup", "i8_speedup", "i16_speedup",
        ],
    );
    for case in &CASES {
        let mut base: Option<(f64, f64, f64)> = None;
        for &t in &threads {
            let eng = Engine::new(t);
            let (sf, s8, s16) = run_case(&bencher, &eng, case);
            let (mf, m8, m16) = (sf.median(), s8.median(), s16.median());
            let (bf, b8, b16) = *base.get_or_insert((mf, m8, m16));
            println!(
                "{:<14} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>8.2}x {:>8.2}x {:>8.2}x",
                case.name,
                t,
                mf * 1e3,
                m8 * 1e3,
                m16 * 1e3,
                bf / mf.max(1e-12),
                b8 / m8.max(1e-12),
                b16 / m16.max(1e-12),
            );
            csv.row(&[
                case.name.to_string(),
                t.to_string(),
                format!("{:.4}", mf * 1e3),
                format!("{:.4}", m8 * 1e3),
                format!("{:.4}", m16 * 1e3),
                format!("{:.3}", bf / mf.max(1e-12)),
                format!("{:.3}", b8 / m8.max(1e-12)),
                format!("{:.3}", b16 / m16.max(1e-12)),
            ]);
        }
    }

    // Quantize-pass scaling (contiguous-slice sharding).
    let mut rng = Pcg32::seeded(7);
    let mut xs = vec![0.0f32; 16 << 20];
    rng.fill_normal(&mut xs, 1.0);
    let sch = Scheme::for_range(max_abs(&xs), 8);
    println!();
    let mut qbase: Option<f64> = None;
    for &t in &threads {
        let eng = Engine::new(t);
        let s = {
            let xs = xs.clone();
            let mut out = vec![0i8; xs.len()];
            bencher.run("codes_i8-16M", move || {
                eng.codes_i8(&xs, &mut out, sch);
                std::hint::black_box(&out);
            })
        };
        let m = s.median();
        let b = *qbase.get_or_insert(m);
        println!(
            "{:<14} {:>8} {:>10.3} ms {:>8.2}x",
            "quantize-16M", t, m * 1e3, b / m.max(1e-12)
        );
        csv.row(&[
            "quantize-16M".to_string(),
            t.to_string(),
            format!("{:.4}", m * 1e3),
            String::new(),
            String::new(),
            format!("{:.3}", b / m.max(1e-12)),
            String::new(),
            String::new(),
        ]);
    }
    csv.write().unwrap();
    println!("\nwrote {}", results_dir().join("parallel_scaling.csv").display());
    println!("target (EXPERIMENTS.md §Perf): >1.5x at 4 threads on gemm-512");
}
