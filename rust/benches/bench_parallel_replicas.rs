//! cargo bench — data-parallel replica scaling (EXPERIMENTS.md
//! §Parallel-Replicas): trains the mlp classifier at 1/2/4 replicas under
//! each communication policy (f32, int8, int16, adaptive) and writes
//! `results/parallel_replicas.csv` with wall time, steps/s, tail loss and
//! eval accuracy per cell.
//!
//! `BENCH_QUICK=1` shortens the run (CI smoke); `APT_BENCH_REPLICAS=1,2`
//! overrides the replica sweep.

use std::time::Instant;

use apt::train::{CommPrecision, SessionBuilder};
use apt::util::out::{results_dir, Csv};

fn replica_sweep() -> Vec<usize> {
    if let Ok(v) = std::env::var("APT_BENCH_REPLICAS") {
        return v
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .filter(|&r| r >= 1)
            .collect();
    }
    vec![1, 2, 4]
}

fn comm_policies(iters: u64) -> Vec<(&'static str, CommPrecision)> {
    // The same parser the CLI uses — one definition of each policy.
    ["f32", "int8", "int16", "adaptive"]
        .into_iter()
        .map(|name| (name, CommPrecision::parse(name, iters).unwrap()))
        .collect()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let iters: u64 = if quick { 10 } else { 120 };
    let replicas = replica_sweep();
    println!(
        "bench_parallel_replicas — mlp, {iters} iters, batch 16, replica sweep {replicas:?}"
    );
    println!(
        "{:<10} {:>9} {:>10} {:>10} {:>11} {:>9}",
        "comm", "replicas", "total s", "steps/s", "tail loss", "acc"
    );

    let mut csv = Csv::new(
        results_dir().join("parallel_replicas.csv"),
        &["comm", "replicas", "iters", "total_s", "steps_per_s", "tail_loss", "eval_acc"],
    );
    for (name, comm) in comm_policies(iters) {
        for &r in &replicas {
            let builder = SessionBuilder::classifier("mlp").lr(0.02);
            let mut s = match builder.build_parallel(r, comm) {
                Ok(s) => s,
                Err(e) => {
                    println!("{name:<10} {r:>9}   skipped: {e}");
                    continue;
                }
            };
            let t = Instant::now();
            s.run(iters).expect("parallel training cannot fail");
            let secs = t.elapsed().as_secs_f64();
            let rec = s.record().expect("eval cannot fail");
            let tail = rec.tail_loss(10);
            println!(
                "{:<10} {:>9} {:>10.3} {:>10.1} {:>11.4} {:>9.3}",
                name,
                r,
                secs,
                iters as f64 / secs.max(1e-9),
                tail,
                rec.eval_acc
            );
            csv.row(&[
                name.to_string(),
                r.to_string(),
                iters.to_string(),
                format!("{secs:.4}"),
                format!("{:.2}", iters as f64 / secs.max(1e-9)),
                format!("{tail:.6}"),
                format!("{:.4}", rec.eval_acc),
            ]);
        }
    }
    csv.write().unwrap();
    println!("\nwrote {}", results_dir().join("parallel_replicas.csv").display());
    println!(
        "expectations (EXPERIMENTS.md §Parallel-Replicas): int8 comm tracks the f32 \
         tail loss at every replica count; per-step cost grows with N on one machine \
         (replicas share the kernel-engine pool — the bench isolates comm-precision \
         effects, not wall-clock scaling across hosts)"
    );
}
