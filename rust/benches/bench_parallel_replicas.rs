//! cargo bench — data-parallel replica scaling × gradient compression
//! (EXPERIMENTS.md §Parallel-Replicas and §Compression): trains the mlp
//! classifier across the replica sweep under each (comm precision,
//! compression policy) pair and writes `results/parallel_replicas.csv`
//! with wall time, steps/s, tail loss, eval accuracy and bytes-on-wire
//! (per-replica compressed, inter-node hierarchical, reduction vs raw f32)
//! per cell. A headline pass pins the ISSUE-8 acceptance bar: ≥5×
//! bytes-on-wire reduction at topk:0.1+int8 with N=16 replicas.
//!
//! `BENCH_QUICK=1` shortens the run (CI smoke); `APT_BENCH_REPLICAS=1,2`
//! overrides the replica sweep.

use std::time::Instant;

use apt::train::{CommPrecision, CompressPolicy, SessionBuilder};
use apt::util::out::{results_dir, Csv};

fn replica_sweep() -> Vec<usize> {
    if let Ok(v) = std::env::var("APT_BENCH_REPLICAS") {
        return v
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .filter(|&r| r >= 1)
            .collect();
    }
    vec![1, 2, 4, 8, 16]
}

/// The (comm precision, compression policy) grid — parsed through the same
/// parsers the CLI uses, so there is one definition of each policy.
fn configs(iters: u64, quick: bool) -> Vec<(String, CommPrecision, CompressPolicy)> {
    let names: &[(&str, &str)] = if quick {
        &[("f32", "none"), ("int8", "quantize"), ("int8", "topk:0.1+quantize")]
    } else {
        &[
            ("f32", "none"),
            ("int8", "quantize"),
            ("int16", "quantize"),
            ("adaptive", "quantize"),
            ("f32", "topk:0.1"),
            ("int8", "topk:0.1+quantize"),
            ("int8", "topk:0.05+quantize"),
        ]
    };
    names
        .iter()
        .map(|(c, p)| {
            (
                format!("{c}/{p}"),
                CommPrecision::parse(c, iters).unwrap(),
                CompressPolicy::parse(p).unwrap(),
            )
        })
        .collect()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let iters: u64 = if quick { 10 } else { 120 };
    let replicas = replica_sweep();
    println!(
        "bench_parallel_replicas — mlp, {iters} iters, batch 16, replica sweep {replicas:?}"
    );
    println!(
        "{:<22} {:>4} {:>5} {:>8} {:>8} {:>10} {:>7} {:>9} {:>9} {:>7}",
        "comm/compress", "N", "node", "total s", "steps/s", "tail loss", "acc", "wire KB",
        "node KB", "redux"
    );

    let mut csv = Csv::new(
        results_dir().join("parallel_replicas.csv"),
        &[
            "comm",
            "compress",
            "replicas",
            "node",
            "iters",
            "total_s",
            "steps_per_s",
            "tail_loss",
            "eval_acc",
            "wire_kb",
            "internode_kb",
            "reduction_x",
        ],
    );
    for (name, comm, policy) in configs(iters, quick) {
        for &r in &replicas {
            // Two-level reduce once there is more than one "node" worth of
            // replicas; flat below that (node size must divide nothing —
            // any power of two works — but 4 is the interesting cell).
            let node = if r >= 4 { 4 } else { 1 };
            let builder =
                SessionBuilder::classifier("mlp").lr(0.02).compress(policy).node_size(node);
            let mut s = match builder.build_parallel(r, comm) {
                Ok(s) => s,
                Err(e) => {
                    println!("{name:<22} {r:>4}   skipped: {e}");
                    continue;
                }
            };
            let t = Instant::now();
            s.run(iters).expect("parallel training cannot fail");
            let secs = t.elapsed().as_secs_f64();
            let wire = s.wire_stats();
            let rec = s.record().expect("eval cannot fail");
            let tail = rec.tail_loss(10);
            let (wire_kb, node_kb) = (
                wire.replica_bytes as f64 / 1024.0,
                wire.internode_bytes as f64 / 1024.0,
            );
            println!(
                "{:<22} {:>4} {:>5} {:>8.3} {:>8.1} {:>10.4} {:>7.3} {:>9.1} {:>9.1} {:>6.1}x",
                name,
                r,
                node,
                secs,
                iters as f64 / secs.max(1e-9),
                tail,
                rec.eval_acc,
                wire_kb,
                node_kb,
                wire.reduction()
            );
            let (comm_name, policy_name) =
                name.split_once('/').expect("config names are comm/policy");
            csv.row(&[
                comm_name.to_string(),
                policy_name.to_string(),
                r.to_string(),
                node.to_string(),
                iters.to_string(),
                format!("{secs:.4}"),
                format!("{:.2}", iters as f64 / secs.max(1e-9)),
                format!("{tail:.6}"),
                format!("{:.4}", rec.eval_acc),
                format!("{wire_kb:.1}"),
                format!("{node_kb:.1}"),
                format!("{:.2}", wire.reduction()),
            ]);
        }
    }
    csv.write().unwrap();
    println!("\nwrote {}", results_dir().join("parallel_replicas.csv").display());

    // Headline acceptance cell (always runs, short in quick mode): N=16
    // replicas, topk:0.1 + int8 codes, hierarchical node size 4 — the wire
    // payload must shrink ≥5× vs raw f32 while the loss still falls.
    let head_iters: u64 = if quick { 4 } else { 30 };
    let mut s = SessionBuilder::classifier("mlp")
        .lr(0.02)
        .compress(CompressPolicy::parse("topk:0.1+quantize").unwrap())
        .node_size(4)
        .build_parallel(16, CommPrecision::Static(8))
        .expect("headline config must build");
    s.run(head_iters).expect("parallel training cannot fail");
    let wire = s.wire_stats();
    let rec = s.record().expect("eval cannot fail");
    println!(
        "headline: N=16 topk:0.1+int8 node=4 → wire {:.1} KB vs dense {:.1} KB = {:.1}x \
         reduction (inter-node {:.1}x), first loss {:.3} → tail {:.3}",
        wire.replica_bytes as f64 / 1024.0,
        wire.dense_bytes as f64 / 1024.0,
        wire.reduction(),
        wire.internode_reduction(),
        rec.losses.first().copied().unwrap_or(f32::NAN),
        rec.tail_loss(5)
    );
    assert!(
        wire.reduction() >= 5.0,
        "ISSUE-8 acceptance: expected ≥5x bytes-on-wire reduction at topk:0.1+int8, got {:.2}x",
        wire.reduction()
    );
    println!(
        "expectations (EXPERIMENTS.md §Compression): quantize tracks the f32 tail loss at \
         every replica count; topk error feedback recovers the withheld mass across steps; \
         per-step cost grows with N on one machine (replicas share the kernel-engine pool — \
         the bench isolates comm effects, not wall-clock scaling across hosts)"
    );
}
