//! cargo bench — SLO behaviour of the serving tier (EXPERIMENTS.md
//! §Serve-SLO): latency vs offered QPS for flush-and-wait vs continuous
//! batching under seeded open-loop Poisson arrivals, with per-request
//! deadlines and explicit shedding. Two row families land in
//! `results/serve_slo.csv` (same layout, `mode` column distinguishes):
//!
//! - `sim` — deterministic virtual-time replay of the production
//!   scheduler code under a fixed cost model. Bit-reproducible (the
//!   `loadgen_sim_row_is_deterministic_on_one_worker` test pins it), so
//!   policy comparisons carry no timing noise. The continuous-beats-flush
//!   p99 claim is asserted on these rows.
//! - `real` — the same arrival process against a live
//!   [`InferenceServer`] running a frozen int8 mlp, measured wall-clock.
//!
//! **Panics on any shed-accounting mismatch** (`submitted != served +
//! shed + refused`) in either family — a lost or double-counted request
//! is a correctness bug, not a performance artifact.
//!
//! Flags after `--`: `--scheduler flush|continuous|both` (default both),
//! `--deadline-us N` (0 = no deadlines, default 5000). `BENCH_QUICK=1`
//! shrinks the QPS grids and request counts.

use std::sync::Arc;

use apt::bench::loadgen::{self, LoadReport, SimCost, Trace, SLO_CSV_HEADER};
use apt::kernels::Engine;
use apt::nn::QuantMode;
use apt::serve::{FrozenModel, InferenceServer, SchedConfig, SchedPolicy, ServeConfig};
use apt::train::SessionBuilder;
use apt::util::cli::Args;
use apt::util::out::{results_dir, Csv};

const SEED: u64 = 42;
const WORKERS: usize = 2;
const MAX_BATCH: usize = 16;
const LANES: usize = 3;
const MAX_WAIT_US: u64 = 2_000;

fn check_accounting(tag: &str, r: &LoadReport) {
    assert!(
        r.accounted(),
        "{tag}: shed-accounting mismatch — {} submitted != {} served + {} shed + {} refused",
        r.submitted,
        r.served,
        r.shed,
        r.shed_admission
    );
}

fn print_row(mode: &str, policy: SchedPolicy, qps: u64, r: &LoadReport) {
    println!(
        "{:<5} {:<10} {:>9} {:>8} {:>6} {:>7} {:>10.1} {:>10.1} {:>10.1}",
        mode,
        policy.label(),
        qps,
        r.served,
        r.shed,
        r.shed_admission,
        r.p50_us,
        r.p99_us,
        r.p999_us
    );
}

fn main() {
    let args = Args::from_env();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let policies: Vec<SchedPolicy> = match args.str_or("scheduler", "both").as_str() {
        "both" => vec![SchedPolicy::Flush, SchedPolicy::Continuous],
        s => vec![SchedPolicy::parse(s).expect("--scheduler")],
    };
    let deadline_us = match args.u64_or("deadline-us", 5_000) {
        0 => None,
        d => Some(d),
    };

    // Sim sweep spans light load through past saturation (the cost model
    // caps capacity at ~2 workers / ~59 µs·req ≈ 34k QPS).
    let cost = SimCost { batch_overhead_us: 150, per_row_us: 40 };
    let (sim_grid, sim_n): (&[u64], usize) = if quick {
        (&[1_000, 8_000, 64_000], 400)
    } else {
        (&[500, 1_000, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000], 3_000)
    };
    // Real-server sweep stays modest: wall time per point is n/QPS.
    let (real_grid, real_n): (&[u64], usize) = if quick {
        (&[200, 1_000], 100)
    } else {
        (&[200, 1_000, 5_000], 600)
    };

    println!(
        "bench_serve_slo — open-loop Poisson, seed {SEED}, {WORKERS} workers, max_batch {MAX_BATCH}, deadline {:?} µs",
        deadline_us
    );
    println!(
        "{:<5} {:<10} {:>9} {:>8} {:>6} {:>7} {:>10} {:>10} {:>10}",
        "mode", "scheduler", "QPS", "served", "shed", "refused", "p50 µs", "p99 µs", "p99.9 µs"
    );

    let mut csv = Csv::new(results_dir().join("serve_slo.csv"), &SLO_CSV_HEADER);
    let scfg = SchedConfig { max_batch: MAX_BATCH, queue_cap: 256, lanes: LANES, max_wait_us: MAX_WAIT_US };

    // ---- sim rows (deterministic) ----
    let mut sim: Vec<(u64, SchedPolicy, LoadReport)> = Vec::new();
    for &qps in sim_grid {
        let trace = Trace::poisson(SEED, qps, sim_n, LANES);
        for &policy in &policies {
            let r = loadgen::simulate(policy, scfg, WORKERS, deadline_us, &trace, cost);
            check_accounting(&format!("sim/{}/{qps}qps", policy.label()), &r);
            print_row("sim", policy, qps, &r);
            csv.row(&loadgen::slo_csv_row("sim", policy, &trace, WORKERS, MAX_BATCH, deadline_us, &r));
            sim.push((qps, policy, r));
        }
    }

    // ---- real rows (frozen int8 mlp behind a live server) ----
    let mut session = SessionBuilder::classifier("mlp")
        .mode(QuantMode::Static(8))
        .lr(0.01)
        .build();
    session.run(if quick { 15 } else { 30 }).expect("train");
    let frozen = Arc::new(FrozenModel::freeze("mlp-int8", session.net()).expect("freeze"));
    let d = frozen.input_len();
    let input = |i: usize| {
        // Cheap deterministic per-request payload; serving cost does not
        // depend on values, only on the forward itself.
        let mut x = vec![0.1f32; d];
        x[i % d] = 0.9;
        x
    };
    for &qps in real_grid {
        let trace = Trace::poisson(SEED, qps, real_n, LANES);
        for &policy in &policies {
            let cfg = ServeConfig {
                max_batch: MAX_BATCH,
                max_wait_us: MAX_WAIT_US,
                queue_cap: 256,
                workers: WORKERS,
                policy,
                lanes: LANES,
            };
            let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg)
                .expect("serve config is valid");
            let r = loadgen::drive(&server, &trace, deadline_us, input);
            let stats = server.shutdown();
            let tag = format!("real/{}/{qps}qps", policy.label());
            check_accounting(&tag, &r);
            assert!(
                stats.accounted(),
                "{tag}: server counters disagree — accepted {} != served {} + shed {}",
                stats.accepted,
                stats.served,
                stats.shed
            );
            print_row("real", policy, qps, &r);
            csv.row(&loadgen::slo_csv_row("real", policy, &trace, WORKERS, MAX_BATCH, deadline_us, &r));
        }
    }
    csv.write().unwrap();
    println!("wrote {}", results_dir().join("serve_slo.csv").display());

    // ---- flush vs continuous on the deterministic rows ----
    if policies.len() == 2 {
        println!("\nsim p99 comparison (flush vs continuous):");
        let mut wins = 0usize;
        for &qps in sim_grid {
            let p99 = |want: SchedPolicy| {
                sim.iter()
                    .find(|(q, p, _)| *q == qps && *p == want)
                    .map(|(_, _, r)| r.p99_us)
                    .expect("both policies ran")
            };
            let (f, c) = (p99(SchedPolicy::Flush), p99(SchedPolicy::Continuous));
            let mark = if c < f { wins += 1; "continuous" } else { "flush" };
            println!("  {qps:>6} QPS: flush {f:>10.1} µs  continuous {c:>10.1} µs  → {mark}");
        }
        assert!(
            wins >= 1,
            "continuous batching should beat flush-and-wait p99 at ≥1 offered-QPS point"
        );
        println!("continuous wins p99 at {wins}/{} points", sim_grid.len());
    }
    println!("fill the EXPERIMENTS.md §Serve-SLO table from the CSV");
}
