//! cargo bench — Appendix E: the adaptive int8-fwd/int16-bwd mix vs
//! int16-everywhere (paper: 1.7× fwd, 1.3× overall), extended with the
//! format-family sweep of EXPERIMENTS.md §Formats: training accuracy
//! across int8/e4m3/e5m2 compute formats plus the int4 weight-only
//! serving footprint off the int8 run. Writes `results/formats.csv`.

use apt::apt::AptConfig;
use apt::compiler::CompileOptions;
use apt::exp;
use apt::fixedpoint::FormatFamily;
use apt::nn::QuantMode;
use apt::serve::FrozenModel;
use apt::train::SessionBuilder;
use apt::util::cli::Args;
use apt::util::out::{results_dir, Csv};

/// `--mode`-equivalent for one sweep column (`int8` static, else the
/// adaptive controller pinned to the format family).
fn mode_for(label: &str, iters: u64) -> QuantMode {
    match label {
        "int8" => QuantMode::Static(8),
        fam => {
            let mut cfg = AptConfig::for_family(FormatFamily::parse(fam).expect("sweep family"));
            cfg.init_phase_iters = iters / 10;
            QuantMode::Adaptive(cfg)
        }
    }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let args = Args::parse(
        [format!("--quick={}", if quick { "true" } else { "false" })]
            .into_iter(),
    );
    exp::run("appxE", &args);

    // ---- format-family sweep (EXPERIMENTS.md §Formats) ----
    let iters: u64 = if quick { 40 } else { 200 };
    let models: &[&str] = if quick { &["mlp"] } else { &["mlp", "alexnet"] };
    let mut csv = Csv::new(
        results_dir().join("formats.csv"),
        &["model", "format", "iters", "tail_loss", "eval_acc", "weight_bytes_int8", "weight_bytes_int4w"],
    );
    println!("\nformat sweep ({iters} iters):");
    for &model in models {
        for fmt in ["int8", "e4m3", "e5m2"] {
            let mut s = SessionBuilder::classifier(model)
                .mode(mode_for(fmt, iters))
                .lr(0.01)
                .build();
            s.run(iters).unwrap();
            // serving footprint: freeze the int8 run both ways before the
            // session is consumed by record()
            let (w8, w4) = if fmt == "int8" {
                let i8m = FrozenModel::freeze(format!("{model}-int8"), s.net()).unwrap();
                let opts = CompileOptions {
                    weight_format: Some(FormatFamily::Int4),
                    ..CompileOptions::default()
                };
                let i4m = FrozenModel::freeze_with(format!("{model}-int4w"), s.net(), &opts).unwrap();
                (i8m.compile_report().weight_bytes, i4m.compile_report().weight_bytes)
            } else {
                (0, 0)
            };
            let rec = s.record().unwrap();
            let footprint = if w8 > 0 {
                format!("  (weights: int8 {w8} B -> int4w {w4} B)")
            } else {
                String::new()
            };
            println!(
                "  {model:<9} {fmt:<5} tail loss {:.4}  eval acc {:.3}{footprint}",
                rec.tail_loss(10),
                rec.eval_acc
            );
            csv.row(&[
                model.to_string(),
                fmt.to_string(),
                iters.to_string(),
                format!("{:.5}", rec.tail_loss(10)),
                format!("{:.4}", rec.eval_acc),
                w8.to_string(),
                w4.to_string(),
            ]);
        }
    }
    csv.write().unwrap();
    println!("wrote {}", results_dir().join("formats.csv").display());
}
