//! cargo bench — Appendix E: the adaptive int8-fwd/int16-bwd mix vs
//! int16-everywhere (paper: 1.7× fwd, 1.3× overall).

use apt::exp;
use apt::util::cli::Args;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let args = Args::parse(
        [format!("--quick={}", if quick { "true" } else { "false" })]
            .into_iter(),
    );
    exp::run("appxE", &args);
}
