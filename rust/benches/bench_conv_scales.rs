//! cargo bench — Fig 10: computation time for growing conv scales,
//! fixed-point vs float, plus the QEM/QPA overhead series.

use apt::exp;
use apt::util::cli::Args;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let args = Args::parse(
        [format!("--quick={}", if quick { "true" } else { "false" })]
            .into_iter(),
    );
    exp::run("fig10", &args);
}
