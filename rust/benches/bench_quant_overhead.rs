//! cargo bench — measured analogue of Fig 7: the cost of the quantization
//! passes (fake-quant, codes, fused stats) relative to the GEMM they feed,
//! plus the QEM amortization effect of the update interval.

use apt::bench::Bencher;
use apt::fixedpoint::gemm;
use apt::fixedpoint::quantize::{codes_i8, fake_quant_stats_inplace, max_abs, stats_only};
use apt::fixedpoint::Scheme;
use apt::util::Pcg32;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let (m, k, n) = (256usize, 256, 256);
    let mut rng = Pcg32::seeded(0);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 1.0);
    let sch = Scheme::for_range(max_abs(&a), 8);

    let s_gemm = {
        let (a, b) = (a.clone(), b.clone());
        let mut c = vec![0.0f32; m * n];
        bencher.run("gemm_f32", move || {
            gemm::gemm_f32(m, k, n, &a, &b, &mut c);
            std::hint::black_box(&c);
        })
    };
    let s_fq = {
        let a0 = a.clone();
        bencher.run("fake_quant+stats", move || {
            let mut x = a0.clone();
            std::hint::black_box(fake_quant_stats_inplace(&mut x, sch));
        })
    };
    let s_codes = {
        let a0 = a.clone();
        let mut out = vec![0i8; a0.len()];
        bencher.run("codes_i8", move || {
            codes_i8(&a0, &mut out, sch);
            std::hint::black_box(&out);
        })
    };
    let s_stats = {
        let a0 = a.clone();
        bencher.run("stats_only (QEM probe)", move || {
            std::hint::black_box(stats_only(&a0, sch));
        })
    };

    println!("bench_quant_overhead ({m}x{k}x{n} GEMM vs {}-elem passes)", m * k);
    for s in [&s_gemm, &s_fq, &s_codes, &s_stats] {
        println!(
            "{:<24} {:>10.4} ms  ({:.2}% of GEMM)",
            s.name,
            s.median() * 1e3,
            100.0 * s.median() / s_gemm.median()
        );
    }
    // amortization: QEM runs every Itv iterations (paper: 0.01–2%)
    for itv in [1u64, 10, 100, 1000] {
        let amortized = s_stats.median() / itv as f64;
        println!(
            "QEM amortized at Itv={itv:<5} {:>10.5} ms ({:.3}% of GEMM)",
            amortized * 1e3,
            100.0 * amortized / s_gemm.median()
        );
    }
}
