//! cargo bench — quantized activation memory (EXPERIMENTS.md §Act-Memory):
//! trains the mlp and alexnet classifiers under every activation-stash
//! storage policy (f32, int8, int16, adaptive), with and without recompute
//! checkpointing, and writes `results/act_memory.csv` with the peak stashed
//! bytes per step, wall time, tail loss and eval accuracy per cell.
//!
//! Headline expectation (ISSUE 5 acceptance): int8 storage cuts alexnet's
//! peak stashed bytes ≥3× vs f32 storage while tier-1 convergence holds.
//!
//! `BENCH_QUICK=1` shortens the run (CI smoke); `APT_BENCH_MODELS=mlp`
//! overrides the model sweep.

use std::time::Instant;

use apt::mem::StashPolicy;
use apt::train::SessionBuilder;
use apt::util::out::{results_dir, Csv};

fn model_sweep() -> Vec<String> {
    if let Ok(v) = std::env::var("APT_BENCH_MODELS") {
        return v.split(',').map(|t| t.trim().to_string()).filter(|t| !t.is_empty()).collect();
    }
    vec!["mlp".into(), "alexnet".into()]
}

fn policies(iters: u64) -> Vec<(&'static str, StashPolicy)> {
    // The same parser the CLI uses — one definition of each policy.
    ["f32", "int8", "int16", "adaptive"]
        .into_iter()
        .map(|name| (name, StashPolicy::parse(name, iters).unwrap()))
        .collect()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let iters: u64 = if quick { 10 } else { 120 };
    let models = model_sweep();
    println!("bench_act_memory — {models:?}, {iters} iters, batch 16, f32 compute");
    println!(
        "{:<9} {:<9} {:>9} {:>12} {:>10} {:>11} {:>9}",
        "model", "act-bits", "recompute", "peak KB", "total s", "tail loss", "acc"
    );

    let mut csv = Csv::new(
        results_dir().join("act_memory.csv"),
        &[
            "model",
            "act_bits",
            "recompute",
            "iters",
            "peak_stash_bytes",
            "total_s",
            "steps_per_s",
            "tail_loss",
            "eval_acc",
        ],
    );
    // per (model) → f32/int8 peaks for the headline ratio line
    let mut f32_peak = std::collections::BTreeMap::new();
    let mut int8_peak = std::collections::BTreeMap::new();
    for model in &models {
        for (name, policy) in policies(iters) {
            for recompute in [false, true] {
                let mut s = SessionBuilder::classifier(model.clone())
                    .lr(0.02)
                    .stash_policy(policy)
                    .recompute(recompute)
                    .build();
                let t = Instant::now();
                s.run(iters).expect("host training cannot fail");
                let secs = t.elapsed().as_secs_f64();
                let peak = s.mem().peak_bytes();
                let rec = s.record().expect("eval cannot fail");
                let tail = rec.tail_loss(10);
                if !recompute && name == "f32" {
                    f32_peak.insert(model.clone(), peak);
                }
                if !recompute && name == "int8" {
                    int8_peak.insert(model.clone(), peak);
                }
                println!(
                    "{:<9} {:<9} {:>9} {:>12.1} {:>10.3} {:>11.4} {:>9.3}",
                    model,
                    name,
                    if recompute { "on" } else { "off" },
                    peak as f64 / 1024.0,
                    secs,
                    tail,
                    rec.eval_acc
                );
                csv.row(&[
                    model.clone(),
                    name.to_string(),
                    recompute.to_string(),
                    iters.to_string(),
                    peak.to_string(),
                    format!("{secs:.4}"),
                    format!("{:.2}", iters as f64 / secs.max(1e-9)),
                    format!("{tail:.6}"),
                    format!("{:.4}", rec.eval_acc),
                ]);
            }
        }
    }
    csv.write().unwrap();
    println!("\nwrote {}", results_dir().join("act_memory.csv").display());
    for model in &models {
        if let (Some(&f), Some(&q)) = (f32_peak.get(model), int8_peak.get(model)) {
            println!(
                "{model}: peak stashed bytes f32 {:.1} KB vs int8 {:.1} KB — {:.2}× smaller",
                f as f64 / 1024.0,
                q as f64 / 1024.0,
                f as f64 / (q as f64).max(1.0)
            );
        }
    }
    println!(
        "expectations (EXPERIMENTS.md §Act-Memory): int8 storage ≥3× below f32 on \
         alexnet (the conv patch matrices dominate and shrink 4×; bitset masks and \
         u32 argmax are policy-invariant); recompute drops the patches entirely; \
         tail loss under every policy tracks the f32 baseline"
    );
}
