//! cargo bench — end-to-end step latency:
//!   (a) one pure-Rust adaptive train step (alexnet-mini), vs f32;
//!   (b) one PJRT artifact train step (mlp / transformer) if artifacts exist.

use apt::bench::Bencher;
use apt::coordinator::{mlp_slot_names, tokens_value, ArtifactTrainer};
use apt::data::{lm_batch, SynthImages};
use apt::nn::loss::softmax_xent;
use apt::nn::{models, QuantMode, Sgd, TrainCtx};
use apt::runtime::{HostValue, Runtime};
use apt::util::Pcg32;

fn rust_step_bench(bencher: &Bencher, mode: QuantMode, label: &str) {
    let mut rng = Pcg32::seeded(0);
    let mut net = models::alexnet_mini(mode, &mut rng);
    let mut data = SynthImages::new(1, models::CLASSES, 3, 12, 12, 0.5);
    let mut opt = Sgd::new(0.01, 0.9);
    let mut ctx = TrainCtx::new();
    let mut it = 0u64;
    let s = bencher.run(label, || {
        ctx.iter = it;
        let (x, y) = data.batch(16);
        let logits = net.forward(&x, &mut ctx);
        let (_, g) = softmax_xent(&logits, &y);
        net.backward(&g, &mut ctx);
        opt.step(&mut net);
        it += 1;
    });
    println!("{:<28} {:>9.2} ms/step", s.name, s.median() * 1e3);
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    // All nn-layer GEMMs and quantize passes below route through the global
    // kernel engine; set APT_THREADS to change its width.
    println!("bench_e2e_step (kernel engine: {} thread(s))", apt::kernels::global().threads());
    rust_step_bench(&bencher, QuantMode::Float32, "rust alexnet-mini f32");
    let mut cfg = apt::apt::AptConfig::default();
    cfg.init_phase_iters = 3;
    rust_step_bench(&bencher, QuantMode::Adaptive(cfg), "rust alexnet-mini adaptive");

    // PJRT path
    match Runtime::new("artifacts") {
        Err(e) => println!("pjrt benches skipped: {e:#}"),
        Ok(mut rt) => {
            if rt.manifest.get("mlp_train_step").is_some() {
                let mut t =
                    ArtifactTrainer::new(&rt, "mlp_train_step", mlp_slot_names(3), QuantMode::Adaptive(cfg), 0)
                        .unwrap();
                let mut rng = Pcg32::seeded(1);
                let mut x = vec![0.0f32; 32 * 64];
                let s = bencher.run("pjrt mlp_train_step", || {
                    rng.fill_normal(&mut x, 1.0);
                    let y: Vec<i32> = (0..32).map(|_| rng.below(10) as i32).collect();
                    t.step(&mut rt, vec![HostValue::F32(x.clone()), HostValue::I32(y)], 0.05)
                        .unwrap();
                });
                println!("{:<28} {:>9.2} ms/step", s.name, s.median() * 1e3);
            }
            if rt.manifest.get("tfm_train_step").is_some() {
                let spec = rt.manifest.get("tfm_train_step").unwrap().clone();
                let n_q = spec.inputs[spec.input_index("qparams").unwrap()].dims[0];
                let layers = (n_q - 1) / 6;
                let toks = &spec.inputs[spec.input_index("tokens").unwrap()];
                let (b, s_len) = (toks.dims[0], toks.dims[1]);
                let vocab = spec.inputs[spec.input_index("p_embed").unwrap()].dims[0];
                let mut t = ArtifactTrainer::new(
                    &rt,
                    "tfm_train_step",
                    apt::coordinator::tfm_slot_names(layers),
                    QuantMode::Adaptive(cfg),
                    0,
                )
                .unwrap();
                let mut rng = Pcg32::seeded(2);
                let s = bencher.run("pjrt tfm_train_step", || {
                    let (tk, tg) = lm_batch(&mut rng, b, s_len, vocab);
                    t.step(&mut rt, vec![tokens_value(&tk), tokens_value(&tg)], 3e-3)
                        .unwrap();
                });
                println!("{:<28} {:>9.2} ms/step", s.name, s.median() * 1e3);
            }
        }
    }
}
