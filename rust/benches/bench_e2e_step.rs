//! cargo bench — end-to-end step latency:
//!   (a) one pure-Rust adaptive train step (alexnet-mini), vs f32;
//!   (b) one PJRT artifact train step (mlp / transformer) if artifacts exist.
//!
//! Both paths step through `train::Session` (DESIGN.md §Session-API), so
//! what's timed is exactly what the drivers run.

use apt::bench::Bencher;
use apt::coordinator::{mlp_slot_names, tfm_slot_names, tokens_value};
use apt::data::{lm_batch, SynthImages};
use apt::nn::QuantMode;
use apt::runtime::{HostValue, Runtime};
use apt::train::{PjrtBackend, Session, SessionBuilder};
use apt::util::Pcg32;

fn rust_step_bench(bencher: &Bencher, mode: QuantMode, label: &str) {
    let mut s = SessionBuilder::classifier("alexnet")
        .mode(mode)
        .lr(0.01)
        .seed(0)
        .data(Box::new(SynthImages::new(1, apt::nn::models::CLASSES, 3, 12, 12, 0.5)))
        .build();
    let sample = bencher.run(label, || {
        s.step().expect("host step cannot fail");
    });
    println!("{:<28} {:>9.2} ms/step", sample.name, sample.median() * 1e3);
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    // All nn-layer GEMMs and quantize passes below route through the global
    // kernel engine; set APT_THREADS to change its width.
    println!("bench_e2e_step (kernel engine: {} thread(s))", apt::kernels::global().threads());
    rust_step_bench(&bencher, QuantMode::Float32, "rust alexnet-mini f32");
    let mut cfg = apt::apt::AptConfig::default();
    cfg.init_phase_iters = 3;
    rust_step_bench(&bencher, QuantMode::Adaptive(cfg), "rust alexnet-mini adaptive");

    // PJRT path
    match Runtime::new("artifacts") {
        Err(e) => println!("pjrt benches skipped: {e:#}"),
        Ok(mut rt) => {
            if rt.manifest.get("mlp_train_step").is_some() {
                let mut rng = Pcg32::seeded(1);
                let data = Box::new(move |_iter: u64| {
                    let mut x = vec![0.0f32; 32 * 64];
                    rng.fill_normal(&mut x, 1.0);
                    let y: Vec<i32> = (0..32).map(|_| rng.below(10) as i32).collect();
                    vec![HostValue::F32(x), HostValue::I32(y)]
                });
                let backend = PjrtBackend::new(
                    &mut rt,
                    "mlp_train_step",
                    mlp_slot_names(3),
                    QuantMode::Adaptive(cfg),
                    0,
                    0.05,
                    "pjrt mlp_train_step",
                    data,
                )
                .unwrap();
                let mut sess = Session::with_backend(backend);
                let s = bencher.run("pjrt mlp_train_step", || {
                    sess.step().unwrap();
                });
                println!("{:<28} {:>9.2} ms/step", s.name, s.median() * 1e3);
            }
            if rt.manifest.get("tfm_train_step").is_some() {
                let spec = rt.manifest.get("tfm_train_step").unwrap().clone();
                let n_q = spec.inputs[spec.input_index("qparams").unwrap()].dims[0];
                let layers = (n_q - 1) / 6;
                let toks = &spec.inputs[spec.input_index("tokens").unwrap()];
                let (b, s_len) = (toks.dims[0], toks.dims[1]);
                let vocab = spec.inputs[spec.input_index("p_embed").unwrap()].dims[0];
                let mut rng = Pcg32::seeded(2);
                let data = Box::new(move |_iter: u64| {
                    let (tk, tg) = lm_batch(&mut rng, b, s_len, vocab);
                    vec![tokens_value(&tk), tokens_value(&tg)]
                });
                let backend = PjrtBackend::new(
                    &mut rt,
                    "tfm_train_step",
                    tfm_slot_names(layers),
                    QuantMode::Adaptive(cfg),
                    0,
                    3e-3,
                    "pjrt tfm_train_step",
                    data,
                )
                .unwrap();
                let mut sess = Session::with_backend(backend);
                let s = bencher.run("pjrt tfm_train_step", || {
                    sess.step().unwrap();
                });
                println!("{:<28} {:>9.2} ms/step", s.name, s.median() * 1e3);
            }
        }
    }
}
