//! cargo bench — PTQ calibration sweep (EXPERIMENTS.md §PTQ): train each
//! model purely in f32, calibrate activation formats post hoc with every
//! observer, freeze through `FrozenModel::freeze_ptq_net`, and measure
//! eval accuracy plus top-1 agreement with the float eval path. QAT
//! reference rows (the paper's quantize-during-training loop at the same
//! width) and the float ceiling land in the same table, so the CSV answers
//! both EXPERIMENTS.md questions: accuracy vs bits, and PTQ vs QAT.
//! Writes `results/ptq.csv`.
//!
//! `BENCH_QUICK=1` shrinks the model set, iteration counts, and sweeps.

use apt::calib::{Calibrator, ObserverKind};
use apt::compiler::CompileOptions;
use apt::data::SynthImages;
use apt::fixedpoint::FormatFamily;
use apt::nn::loss::accuracy;
use apt::nn::{models, QuantMode};
use apt::serve::FrozenModel;
use apt::tensor::Tensor;
use apt::train::SessionBuilder;
use apt::util::out::{results_dir, Csv};

const EVAL_N: usize = 256;

fn synth(seed: u64) -> SynthImages {
    SynthImages::new(seed, models::CLASSES, models::IN_C, models::IN_H, models::IN_W, 0.5)
}

fn top1_agreement(a: &Tensor, b: &Tensor) -> f64 {
    let (pa, pb) = (a.argmax_rows(), b.argmax_rows());
    let agree = pa.iter().zip(&pb).filter(|(x, y)| x == y).count();
    agree as f64 / pa.len() as f64
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let model_names: &[&str] = if quick { &["mlp"] } else { &["mlp", "alexnet"] };
    let iters: u64 = if quick { 40 } else { 120 };
    let calib_samples: usize = if quick { 96 } else { 256 };
    let observers: &[&str] = if quick {
        &["minmax", "percentile:99.99"]
    } else {
        &["minmax", "ema:0.01", "percentile:99.9", "percentile:99.99", "kl"]
    };
    let bits_sweep: &[u8] = if quick { &[8] } else { &[4, 6, 8, 16] };

    println!("bench_ptq — float train {iters} iters, {calib_samples} calibration samples, eval {EVAL_N}");
    println!(
        "{:<8} {:<6} {:<18} {:>4} {:>10} {:>9}",
        "model", "method", "observer", "bits", "agreement", "accuracy"
    );

    let mut csv = Csv::new(
        results_dir().join("ptq.csv"),
        &["model", "method", "observer", "bits", "samples", "agreement", "accuracy"],
    );
    let mut emit = |model: &str, method: &str, observer: &str, bits: u8, samples: usize, agreement: f64, acc: f64| {
        println!(
            "{:<8} {:<6} {:<18} {:>4} {:>10.4} {:>9.4}",
            model, method, observer, bits, agreement, acc
        );
        csv.row(&[
            model.to_string(),
            method.to_string(),
            observer.to_string(),
            bits.to_string(),
            samples.to_string(),
            format!("{agreement:.4}"),
            format!("{acc:.4}"),
        ]);
    };

    for &model in model_names {
        // Float baseline: the network every PTQ variant is frozen from.
        let mut float = SessionBuilder::classifier(model).mode(QuantMode::Float32).lr(0.01).build();
        float.run(iters).expect("float training");
        let (ex, ey) = synth(42).eval_set(999, EVAL_N);
        let float_logits = float.eval_logits(&ex);
        let float_acc = accuracy(&float_logits, &ey);
        emit(model, "float", "-", 32, 0, 1.0, float_acc);

        // One calibration stream per observer, shared across the bit sweep
        // (the observer sees f32 activations; bits only shapes `finish`).
        for &obs in observers {
            let kind = ObserverKind::parse(obs).expect("observer spec");
            let mut cal =
                Calibrator::from_net(model, float.net(), kind).expect("observation program");
            let mut stream = synth(4242);
            while cal.samples() < calib_samples {
                let (x, _) = stream.batch(32);
                cal.observe(&x);
            }
            for &bits in bits_sweep {
                let table = cal.finish(FormatFamily::FixedPoint, bits, false);
                let frozen = FrozenModel::freeze_ptq_net(
                    format!("{model}-ptq-int{bits}"),
                    float.net(),
                    &table,
                    &CompileOptions::default(),
                )
                .expect("calibrated freeze");
                let logits = frozen.forward(&ex, apt::kernels::global());
                emit(
                    model,
                    "ptq",
                    obs,
                    bits,
                    cal.samples(),
                    top1_agreement(&float_logits, &logits),
                    accuracy(&logits, &ey),
                );
            }
        }

        // QAT reference: the paper's loop — quantization live for the whole
        // run at the same static width.
        for &bits in bits_sweep {
            let mut qat =
                SessionBuilder::classifier(model).mode(QuantMode::Static(bits)).lr(0.01).build();
            qat.run(iters).expect("QAT training");
            let logits = qat.eval_logits(&ex);
            emit(model, "qat", "-", bits, 0, top1_agreement(&float_logits, &logits), accuracy(&logits, &ey));
        }
        println!();
    }

    csv.write().unwrap();
    println!("wrote {}", results_dir().join("ptq.csv").display());
    println!("fill the EXPERIMENTS.md §PTQ tables from the CSV");
}
