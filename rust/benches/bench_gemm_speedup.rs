//! cargo bench — Table 3: layer-wise AlexNet GEMM speedups (i8 fwd, i16 bwd
//! vs f32) on this CPU. `BENCH_QUICK=1` shortens sampling; `APT_THREADS=N`
//! measures the engine-sharded kernels instead of the serial backends.

use apt::bench::Bencher;
use apt::exp::speed::measure_layers;
use apt::kernels::Engine;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let threads = std::env::var("APT_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let eng = Engine::new(threads);
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    println!("bench_gemm_speedup (Table 3 substrate, {} thread(s))", eng.threads());
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "layer", "f32 ms", "i8 ms", "i16 ms", "fwd x", "bwd x"
    );
    let rows = measure_layers(64, &bencher, &eng);
    let (mut f, mut i8t, mut i16t) = (0.0, 0.0, 0.0);
    for (name, fwd, bwd, sf, s8, s16) in &rows {
        println!(
            "{:<8} {:>10.3} {:>10.3} {:>10.3} {:>8.2}x {:>8.2}x",
            name,
            sf.median() * 1e3,
            s8.median() * 1e3,
            s16.median() * 1e3,
            fwd,
            bwd
        );
        f += sf.median();
        i8t += s8.median();
        i16t += s16.median();
    }
    println!(
        "{:<8} {:>10.3} {:>10.3} {:>10.3} {:>8.2}x {:>8.2}x   (paper overall: fwd 3.98x bwd 2.07x)",
        "overall",
        f * 1e3,
        i8t * 1e3,
        i16t * 1e3,
        f / i8t,
        f / i16t
    );
}
