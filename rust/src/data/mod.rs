//! Synthetic data substrate (system S8 in DESIGN.md §2).
//!
//! Every generator is deterministic by seed. These replace the paper's
//! gated datasets (ImageNet/COCO/VOC/WMT) with distributions that exercise
//! the same code paths: class-template images with clutter for
//! classification, single-object scenes for detection, region masks for
//! segmentation, and a token-reversal corpus for translation.

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Class-conditional image generator: each class has a fixed random template
/// (drawn once from the dataset seed); samples are `template + σ·noise` with
/// per-sample global clutter. NCHW flattened to [n, c*h*w].
pub struct SynthImages {
    pub classes: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub noise: f32,
    templates: Vec<f32>,
    rng: Pcg32,
}

impl SynthImages {
    pub fn new(seed: u64, classes: usize, c: usize, h: usize, w: usize, noise: f32) -> Self {
        let mut trng = Pcg32::seeded(seed);
        let n = classes * c * h * w;
        let mut templates = vec![0.0f32; n];
        trng.fill_normal(&mut templates, 1.0);
        SynthImages { classes, c, h, w, noise, templates, rng: Pcg32::seeded(seed ^ 0xbeef) }
    }

    pub fn input_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Next batch: (images [n, chw], labels).
    pub fn batch(&mut self, n: usize) -> (Tensor, Vec<usize>) {
        let chw = self.input_len();
        let mut x = Tensor::zeros(&[n, chw]);
        let mut y = vec![0usize; n];
        for b in 0..n {
            let cls = self.rng.below(self.classes);
            y[b] = cls;
            let tpl = &self.templates[cls * chw..(cls + 1) * chw];
            let row = &mut x.data[b * chw..(b + 1) * chw];
            for (v, &t) in row.iter_mut().zip(tpl) {
                *v = t + self.rng.normal() * self.noise;
            }
        }
        (x, y)
    }

    /// Sample-stream RNG state (checkpointing; templates re-derive from the
    /// construction seed).
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state()
    }

    /// Restore a [`rng_state`](Self::rng_state) snapshot so subsequent
    /// batches continue the interrupted stream bit-identically.
    pub fn set_rng_state(&mut self, st: (u64, u64)) {
        self.rng = Pcg32::from_state(st);
    }

    /// Class of the template nearest (squared L2) to `row` — the Bayes
    /// classifier of this synthetic family. NaN-safe: distances compare via
    /// `f32::total_cmp`, so a corrupted row (NaN pixels) picks a defined
    /// class instead of panicking (the `util::stats::percentile` panic
    /// class; NaN totally orders above every real distance).
    pub fn nearest_template(&self, row: &[f32]) -> usize {
        let chw = self.input_len();
        assert_eq!(row.len(), chw, "row length vs template geometry");
        // one distance pass per class, then a NaN-total argmin over the
        // precomputed values (min_by's comparator would otherwise redo the
        // running minimum's sum on every comparison)
        let dists: Vec<f32> = (0..self.classes)
            .map(|cls| {
                row.iter()
                    .zip(&self.templates[cls * chw..(cls + 1) * chw])
                    .map(|(x, t)| (x - t) * (x - t))
                    .sum()
            })
            .collect();
        dists
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("at least one class")
    }

    /// A fixed evaluation set drawn from a separate stream.
    pub fn eval_set(&self, seed: u64, n: usize) -> (Tensor, Vec<usize>) {
        let mut clone = SynthImages {
            classes: self.classes,
            c: self.c,
            h: self.h,
            w: self.w,
            noise: self.noise,
            templates: self.templates.clone(),
            rng: Pcg32::seeded(seed),
        };
        clone.batch(n)
    }
}

/// Detection scene: clutter background + one axis-aligned box whose interior
/// carries a class-specific channel signature. Targets are
/// (cx, cy, w, h) in [0,1] plus the class id.
pub struct SynthDetection {
    pub classes: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    rng: Pcg32,
    signatures: Vec<f32>, // class × c
}

impl SynthDetection {
    pub fn new(seed: u64, classes: usize, c: usize, h: usize, w: usize) -> Self {
        let mut trng = Pcg32::seeded(seed);
        let mut signatures = vec![0.0f32; classes * c];
        trng.fill_normal(&mut signatures, 2.0);
        SynthDetection { classes, c, h, w, rng: Pcg32::seeded(seed ^ 0xd07), signatures }
    }

    pub fn input_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// (images, boxes [n][4], classes [n])
    pub fn batch(&mut self, n: usize) -> (Tensor, Vec<[f32; 4]>, Vec<usize>) {
        let (c, h, w) = (self.c, self.h, self.w);
        let chw = c * h * w;
        let mut x = Tensor::zeros(&[n, chw]);
        let mut boxes = Vec::with_capacity(n);
        let mut classes = Vec::with_capacity(n);
        for b in 0..n {
            let row = &mut x.data[b * chw..(b + 1) * chw];
            for v in row.iter_mut() {
                *v = self.rng.normal() * 0.3;
            }
            let cls = self.rng.below(self.classes);
            let bw = self.rng.range(0.25, 0.6);
            let bh = self.rng.range(0.25, 0.6);
            let cx = self.rng.range(bw / 2.0, 1.0 - bw / 2.0);
            let cy = self.rng.range(bh / 2.0, 1.0 - bh / 2.0);
            let (x0, x1) = (((cx - bw / 2.0) * w as f32) as usize, ((cx + bw / 2.0) * w as f32) as usize);
            let (y0, y1) = (((cy - bh / 2.0) * h as f32) as usize, ((cy + bh / 2.0) * h as f32) as usize);
            for ch in 0..c {
                let sig = self.signatures[cls * c + ch];
                for yy in y0..y1.min(h) {
                    for xx in x0..x1.min(w) {
                        row[ch * h * w + yy * w + xx] += sig;
                    }
                }
            }
            boxes.push([cx, cy, bw, bh]);
            classes.push(cls);
        }
        (x, boxes, classes)
    }
}

/// Segmentation scene: one rectangular region of a foreground class over
/// background class 0. Labels are per-pixel class ids.
pub struct SynthSegmentation {
    pub classes: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    rng: Pcg32,
    signatures: Vec<f32>,
}

impl SynthSegmentation {
    pub fn new(seed: u64, classes: usize, c: usize, h: usize, w: usize) -> Self {
        assert!(classes >= 2);
        let mut trng = Pcg32::seeded(seed);
        let mut signatures = vec![0.0f32; classes * c];
        trng.fill_normal(&mut signatures, 2.0);
        SynthSegmentation { classes, c, h, w, rng: Pcg32::seeded(seed ^ 0x5e6), signatures }
    }

    pub fn input_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// (images, per-pixel labels [n][h*w])
    pub fn batch(&mut self, n: usize) -> (Tensor, Vec<Vec<usize>>) {
        let (c, h, w) = (self.c, self.h, self.w);
        let chw = c * h * w;
        let mut x = Tensor::zeros(&[n, chw]);
        let mut labels = Vec::with_capacity(n);
        for b in 0..n {
            let row = &mut x.data[b * chw..(b + 1) * chw];
            for v in row.iter_mut() {
                *v = self.rng.normal() * 0.3;
            }
            let mut mask = vec![0usize; h * w];
            let cls = 1 + self.rng.below(self.classes - 1);
            let x0 = self.rng.below(w / 2);
            let y0 = self.rng.below(h / 2);
            let x1 = x0 + 2 + self.rng.below(w / 2 - 1);
            let y1 = y0 + 2 + self.rng.below(h / 2 - 1);
            for yy in y0..y1.min(h) {
                for xx in x0..x1.min(w) {
                    mask[yy * w + xx] = cls;
                    for ch in 0..c {
                        row[ch * h * w + yy * w + xx] += self.signatures[cls * c + ch];
                    }
                }
            }
            labels.push(mask);
        }
        (x, labels)
    }
}

/// Token-reversal translation batch: target is the reversed source — a
/// long-range dependency every position of the decoder must resolve, like
/// (a miniature of) real translation reordering. Token 0 is reserved as BOS.
pub fn translation_batch(
    rng: &mut Pcg32,
    batch: usize,
    len: usize,
    vocab: usize,
) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
    let mut src = Vec::with_capacity(batch);
    let mut tgt = Vec::with_capacity(batch);
    for _ in 0..batch {
        let s: Vec<usize> = (0..len).map(|_| 1 + rng.below(vocab - 1)).collect();
        let mut t = s.clone();
        t.reverse();
        src.push(s);
        tgt.push(t);
    }
    (src, tgt)
}

/// Integer-sequence LM batch for the transformer driver: arithmetic
/// progressions mod vocab (`x_{t+1} = x_t + step`), predictable but
/// position-dependent. Returns (tokens, targets) each [batch][seq].
pub fn lm_batch(
    rng: &mut Pcg32,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
    let mut toks = Vec::with_capacity(batch);
    let mut tgts = Vec::with_capacity(batch);
    for _ in 0..batch {
        let start = rng.below(vocab);
        let step = 1 + rng.below(3);
        let seq_full: Vec<i32> = (0..=seq)
            .map(|t| ((start + t * step) % vocab) as i32)
            .collect();
        toks.push(seq_full[..seq].to_vec());
        tgts.push(seq_full[1..].to_vec());
    }
    (toks, tgts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_deterministic_and_separable() {
        let mut d1 = SynthImages::new(7, 4, 3, 8, 8, 0.1);
        let mut d2 = SynthImages::new(7, 4, 3, 8, 8, 0.1);
        let (x1, y1) = d1.batch(8);
        let (x2, y2) = d2.batch(8);
        assert_eq!(x1.data, x2.data);
        assert_eq!(y1, y2);
        // low noise → nearest-template classification is near perfect
        let chw = d1.input_len();
        for b in 0..8 {
            let row = &x1.data[b * chw..(b + 1) * chw];
            assert_eq!(d1.nearest_template(row), y1[b]);
        }
    }

    #[test]
    fn nearest_template_survives_nan_rows() {
        // Regression: the old inline partial_cmp(..).unwrap() panicked the
        // moment a distance came out NaN (same class of bug as the
        // util::stats::percentile fix in PR 4). total_cmp stays total: a
        // poisoned row classifies to *some* class instead of aborting.
        let d = SynthImages::new(7, 4, 3, 8, 8, 0.1);
        let chw = d.input_len();
        // every distance NaN
        let all_nan = vec![f32::NAN; chw];
        assert!(d.nearest_template(&all_nan) < 4);
        // a single NaN pixel poisons all distances equally — still no panic
        let mut one_nan = d.templates[..chw].to_vec();
        one_nan[0] = f32::NAN;
        assert!(d.nearest_template(&one_nan) < 4);
        // and clean rows are unaffected by the comparator change
        let clean = d.templates[chw..2 * chw].to_vec();
        assert_eq!(d.nearest_template(&clean), 1);
    }

    #[test]
    fn detection_boxes_in_bounds() {
        let mut d = SynthDetection::new(3, 3, 3, 16, 16);
        let (_, boxes, classes) = d.batch(16);
        for (bx, cls) in boxes.iter().zip(&classes) {
            assert!(*cls < 3);
            assert!(bx[0] - bx[2] / 2.0 >= -1e-5 && bx[0] + bx[2] / 2.0 <= 1.0 + 1e-5);
            assert!(bx[1] - bx[3] / 2.0 >= -1e-5 && bx[1] + bx[3] / 2.0 <= 1.0 + 1e-5);
        }
    }

    #[test]
    fn segmentation_mask_matches_signal() {
        let mut d = SynthSegmentation::new(5, 3, 2, 12, 12);
        let (x, labels) = d.batch(4);
        // foreground pixels have larger magnitude on average
        let chw = d.input_len();
        let hw = 12 * 12;
        let mut fg = 0.0f32;
        let mut bg = 0.0f32;
        let (mut nfg, mut nbg) = (0, 0);
        for b in 0..4 {
            for p in 0..hw {
                let mag: f32 = (0..2).map(|ch| x.data[b * chw + ch * hw + p].abs()).sum();
                if labels[b][p] > 0 {
                    fg += mag;
                    nfg += 1;
                } else {
                    bg += mag;
                    nbg += 1;
                }
            }
        }
        assert!(fg / nfg as f32 > bg / nbg as f32);
    }

    #[test]
    fn translation_is_reversal() {
        let mut rng = Pcg32::seeded(0);
        let (src, tgt) = translation_batch(&mut rng, 4, 6, 20);
        for (s, t) in src.iter().zip(&tgt) {
            let mut r = s.clone();
            r.reverse();
            assert_eq!(&r, t);
            assert!(s.iter().all(|&tok| tok >= 1 && tok < 20));
        }
    }

    #[test]
    fn lm_batch_is_shifted() {
        let mut rng = Pcg32::seeded(1);
        let (toks, tgts) = lm_batch(&mut rng, 3, 10, 32);
        for (x, y) in toks.iter().zip(&tgts) {
            assert_eq!(x.len(), 10);
            assert_eq!(&x[1..], &y[..9]);
        }
    }
}
