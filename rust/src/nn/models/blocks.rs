//! Composite blocks: residual (ResNet) and parallel-branch (Inception).

use crate::fixedpoint::conv::Conv2dGeom;
use crate::mem::StashHandle;
use crate::nn::activ::ReLU;
use crate::nn::conv::Conv2d;
use crate::nn::norm::BatchNorm2d;
use crate::nn::{Layer, QuantMode, TrainCtx};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Identity residual block: x + F(x) with F = conv-bn-relu-conv-bn.
/// Channel count and spatial dims preserved. The block's own saved state
/// (the post-sum ReLU mask) stashes under `<name>/relu_mask`; the path
/// layers stash through their own handles.
pub struct ResidualBlock {
    name: String,
    path: Vec<Box<dyn Layer>>,
    h_mask: StashHandle,
}

impl ResidualBlock {
    pub fn new(name: &str, c: usize, h: usize, w: usize, mode: QuantMode, rng: &mut Pcg32) -> Self {
        let g = Conv2dGeom { in_c: c, out_c: c, kh: 3, kw: 3, stride: 1, pad: 1 };
        ResidualBlock {
            path: vec![
                Box::new(Conv2d::new(&format!("{name}c1"), g, h, w, mode, rng)),
                Box::new(BatchNorm2d::new(&format!("{name}bn1"), c, h * w)),
                Box::new(ReLU::new(&format!("{name}r1"))),
                Box::new(Conv2d::new(&format!("{name}c2"), g, h, w, mode, rng)),
                Box::new(BatchNorm2d::new(&format!("{name}bn2"), c, h * w)),
            ],
            h_mask: StashHandle::new(name, "relu_mask"),
            name: name.to_string(),
        }
    }
}

impl Layer for ResidualBlock {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let mut h = x.clone();
        for l in self.path.iter_mut() {
            h = l.forward(&h, ctx);
        }
        h.add_inplace(x);
        // final ReLU on the sum
        if ctx.training {
            let mask: Vec<bool> = h.data.iter().map(|&v| v > 0.0).collect();
            ctx.stash.put_mask(&self.h_mask, &mask);
        }
        h.map_inplace(|v| v.max(0.0));
        h
    }

    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let mask = ctx.stash.take_mask(&self.h_mask);
        let mut d = g.clone();
        for (v, &m) in d.data.iter_mut().zip(&mask) {
            if !m {
                *v = 0.0;
            }
        }
        let skip = d.clone();
        for l in self.path.iter_mut().rev() {
            d = l.backward(&d, ctx);
        }
        d.add_inplace(&skip);
        d
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        for l in self.path.iter_mut() {
            l.visit_params(f);
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn last_grad(&self) -> Option<&Tensor> {
        // expose the inner first conv's gradient for observation probes
        self.path.first().and_then(|l| l.last_grad())
    }

    fn set_grad_override(&mut self, layer: &str, bits: Option<u8>) -> bool {
        self.path.iter_mut().any(|l| l.set_grad_override(layer, bits))
    }

    fn quantizes_grads(&self) -> bool {
        self.path.iter().any(|l| l.quantizes_grads())
    }

    fn visit_controllers(&mut self, f: &mut dyn FnMut(&str, &mut crate::apt::LayerControllers)) {
        for l in self.path.iter_mut() {
            l.visit_controllers(f);
        }
    }

    fn visit_state(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        for l in self.path.iter_mut() {
            l.visit_state(f);
        }
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        // relu(F(x) + x): save the input, lower the path, add-back + ReLU.
        out.push(crate::serve::InferOp::Push);
        for l in &self.path {
            if !l.export_infer(out) {
                return false;
            }
        }
        out.push(crate::serve::InferOp::AddPopRelu);
        true
    }
}

/// Two-branch inception block: [1×1 conv ∥ 3×3 conv], channel-concatenated.
pub struct InceptionBlock {
    name: String,
    b1: Conv2d, // 1×1
    b3: Conv2d, // 3×3 pad 1
    c1: usize,
    c3: usize,
    hw: usize,
}

impl InceptionBlock {
    pub fn new(
        name: &str,
        in_c: usize,
        c1: usize,
        c3: usize,
        h: usize,
        w: usize,
        mode: QuantMode,
        rng: &mut Pcg32,
    ) -> Self {
        InceptionBlock {
            name: name.to_string(),
            b1: Conv2d::new(
                &format!("{name}_1x1"),
                Conv2dGeom { in_c, out_c: c1, kh: 1, kw: 1, stride: 1, pad: 0 },
                h,
                w,
                mode,
                rng,
            ),
            b3: Conv2d::new(
                &format!("{name}_3x3"),
                Conv2dGeom { in_c, out_c: c3, kh: 3, kw: 3, stride: 1, pad: 1 },
                h,
                w,
                mode,
                rng,
            ),
            c1,
            c3,
            hw: h * w,
        }
    }
}

impl Layer for InceptionBlock {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = x.dim(0);
        let y1 = self.b1.forward(x, ctx);
        let y3 = self.b3.forward(x, ctx);
        let hw = self.hw;
        let mut out = Tensor::zeros(&[n, (self.c1 + self.c3) * hw]);
        for img in 0..n {
            out.data[img * (self.c1 + self.c3) * hw..][..self.c1 * hw]
                .copy_from_slice(&y1.data[img * self.c1 * hw..][..self.c1 * hw]);
            out.data[img * (self.c1 + self.c3) * hw + self.c1 * hw..][..self.c3 * hw]
                .copy_from_slice(&y3.data[img * self.c3 * hw..][..self.c3 * hw]);
        }
        out
    }

    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = g.dim(0);
        let hw = self.hw;
        let mut g1 = Tensor::zeros(&[n, self.c1 * hw]);
        let mut g3 = Tensor::zeros(&[n, self.c3 * hw]);
        for img in 0..n {
            g1.data[img * self.c1 * hw..][..self.c1 * hw]
                .copy_from_slice(&g.data[img * (self.c1 + self.c3) * hw..][..self.c1 * hw]);
            g3.data[img * self.c3 * hw..][..self.c3 * hw].copy_from_slice(
                &g.data[img * (self.c1 + self.c3) * hw + self.c1 * hw..][..self.c3 * hw],
            );
        }
        let mut dx = self.b1.backward(&g1, ctx);
        dx.add_inplace(&self.b3.backward(&g3, ctx));
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        self.b1.visit_params(f);
        self.b3.visit_params(f);
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn last_grad(&self) -> Option<&Tensor> {
        self.b3.last_grad()
    }

    fn set_grad_override(&mut self, layer: &str, bits: Option<u8>) -> bool {
        self.b1.set_grad_override(layer, bits) || self.b3.set_grad_override(layer, bits)
    }

    fn quantizes_grads(&self) -> bool {
        true // both branches are convs
    }

    fn visit_controllers(&mut self, f: &mut dyn FnMut(&str, &mut crate::apt::LayerControllers)) {
        self.b1.visit_controllers(f);
        self.b3.visit_controllers(f);
    }

    fn visit_state(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        self.b1.visit_state(f);
        self.b3.visit_state(f);
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        // concat(b1(x), b3(x)): save x, run b1, swap x back, run b3, merge.
        out.push(crate::serve::InferOp::Push);
        if !self.b1.export_infer(out) {
            return false;
        }
        out.push(crate::serve::InferOp::Swap);
        if !self.b3.export_infer(out) {
            return false;
        }
        out.push(crate::serve::InferOp::ConcatPop { c_pop: self.c1, c_cur: self.c3, hw: self.hw });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::QuantMode;

    #[test]
    fn residual_identity_gradient_flows() {
        let mut rng = Pcg32::seeded(0);
        let mut blk = ResidualBlock::new("rb", 4, 6, 6, QuantMode::Float32, &mut rng);
        let mut x = Tensor::zeros(&[1, 4 * 36]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = blk.forward(&x, &mut ctx);
        assert_eq!(y.shape, x.shape);
        let g = Tensor::filled(&y.shape.clone(), 1.0);
        let dx = blk.backward(&g, &mut ctx);
        // skip path guarantees gradient magnitude comparable to upstream
        let norm: f32 = dx.data.iter().map(|v| v.abs()).sum();
        assert!(norm > 0.1, "gradient vanished through residual block");
    }

    #[test]
    fn residual_backward_matches_finite_difference() {
        let mut rng = Pcg32::seeded(1);
        let mut blk = ResidualBlock::new("rb", 2, 4, 4, QuantMode::Float32, &mut rng);
        let mut x = Tensor::zeros(&[1, 32]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = blk.forward(&x, &mut ctx);
        let g = Tensor::filled(&y.shape.clone(), 1.0);
        let dx = blk.backward(&g, &mut ctx);
        let eps = 1e-3f32;
        // BatchNorm couples all inputs of a channel; finite difference is
        // noisy — check a loose agreement on a few coords.
        for idx in [0usize, 9, 20] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let yp = blk.forward(&xp, &mut ctx).sum();
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let ym = blk.forward(&xm, &mut ctx).sum();
            let fd = ((yp - ym) / (2.0 * eps as f64)) as f32;
            assert!((dx.data[idx] - fd).abs() < 0.15, "idx={idx}: {} vs {fd}", dx.data[idx]);
        }
    }

    #[test]
    fn inception_concat_shapes() {
        let mut rng = Pcg32::seeded(2);
        let mut blk = InceptionBlock::new("inc", 4, 3, 5, 6, 6, QuantMode::Float32, &mut rng);
        let mut x = Tensor::zeros(&[2, 4 * 36]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = blk.forward(&x, &mut ctx);
        assert_eq!(y.shape, vec![2, 8 * 36]);
        let g = Tensor::filled(&y.shape.clone(), 1.0);
        let dx = blk.backward(&g, &mut ctx);
        assert_eq!(dx.shape, x.shape);
    }
}
