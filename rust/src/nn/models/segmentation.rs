//! deeplab-lite: fully-convolutional per-pixel classifier over the synthetic
//! mask task (Table 1's segmentation row).

use crate::fixedpoint::conv::Conv2dGeom;
use crate::nn::activ::ReLU;
use crate::nn::conv::Conv2d;
use crate::nn::loss::{mean_iou, pixel_xent};
use crate::nn::{QuantMode, Sequential, TrainCtx};
use crate::train::{Optimizer, Sgd};
use crate::tensor::Tensor;
use crate::util::Pcg32;

pub struct SegNet {
    pub net: Sequential,
    pub classes: usize,
    pub h: usize,
    pub w: usize,
    opt: Sgd,
}

impl SegNet {
    /// 3×12×12 input, `classes` per-pixel classes, resolution preserved.
    pub fn new(classes: usize, mode: QuantMode, rng: &mut Pcg32) -> Self {
        let g = |ic, oc, k, pad| Conv2dGeom { in_c: ic, out_c: oc, kh: k, kw: k, stride: 1, pad };
        SegNet {
            net: Sequential::new(vec![
                Box::new(Conv2d::new("seg_conv0", g(3, 8, 3, 1), 12, 12, mode, rng)),
                Box::new(ReLU::new("sr0")),
                Box::new(Conv2d::new("seg_conv1", g(8, 8, 3, 1), 12, 12, mode, rng)),
                Box::new(ReLU::new("sr1")),
                Box::new(Conv2d::new("seg_head", g(8, classes, 1, 0), 12, 12, mode, rng)),
            ]),
            classes,
            h: 12,
            w: 12,
            opt: Sgd::new(0.05, 0.9),
        }
    }

    /// One step; returns mean pixel loss.
    pub fn train_step(&mut self, x: &Tensor, labels: &[Vec<usize>], ctx: &mut TrainCtx) -> f32 {
        let logits = self.net.forward(x, ctx);
        let (l, g) = pixel_xent(&logits, labels, self.classes);
        self.net.backward(&g, ctx);
        self.opt.step(&mut self.net);
        self.net.zero_grads();
        l
    }

    /// Per-pixel argmax predictions.
    pub fn predict(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Vec<Vec<usize>> {
        let was = ctx.training;
        ctx.training = false;
        let logits = self.net.forward(x, ctx);
        ctx.training = was;
        let n = x.dim(0);
        let hw = self.h * self.w;
        let mut out = Vec::with_capacity(n);
        for img in 0..n {
            let mut mask = vec![0usize; hw];
            for p in 0..hw {
                let mut best = f32::NEG_INFINITY;
                for c in 0..self.classes {
                    let v = logits.data[img * self.classes * hw + c * hw + p];
                    if v > best {
                        best = v;
                        mask[p] = c;
                    }
                }
            }
            out.push(mask);
        }
        out
    }

    pub fn eval_miou(&mut self, x: &Tensor, labels: &[Vec<usize>], ctx: &mut TrainCtx) -> f64 {
        let preds = self.predict(x, ctx);
        mean_iou(&preds, labels, self.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSegmentation;

    #[test]
    fn segmentation_learns_f32() {
        let mut rng = Pcg32::seeded(0);
        let mut net = SegNet::new(3, QuantMode::Float32, &mut rng);
        let mut data = SynthSegmentation::new(1, 3, 3, 12, 12);
        let mut ctx = TrainCtx::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..25 {
            ctx.iter = it;
            let (x, labels) = data.batch(8);
            let l = net.train_step(&x, &labels, &mut ctx);
            if it == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first * 0.9, "first={first} last={last}");
        let (x, labels) = data.batch(8);
        let iou = net.eval_miou(&x, &labels, &mut ctx);
        assert!((0.0..=1.0).contains(&iou));
    }
}
