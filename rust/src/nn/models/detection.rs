//! SSD-lite: conv trunk + parallel box-regression and classification heads
//! over the synthetic single-object detection task (Table 1's detection rows).

use crate::fixedpoint::conv::Conv2dGeom;
use crate::nn::activ::{MaxPool2, ReLU};
use crate::nn::conv::Conv2d;
use crate::nn::linear::Linear;
use crate::nn::loss::{box_iou, smooth_l1, softmax_xent};
use crate::nn::{Layer, QuantMode, Sequential, TrainCtx};
use crate::tensor::Tensor;
use crate::util::Pcg32;

pub struct DetectionNet {
    pub trunk: Sequential,
    pub head_box: Linear,
    pub head_cls: Linear,
    pub classes: usize,
    feat: Tensor,
}

impl DetectionNet {
    /// 3×16×16 input, `classes` object classes.
    pub fn new(classes: usize, mode: QuantMode, rng: &mut Pcg32) -> Self {
        let g = |ic, oc| Conv2dGeom { in_c: ic, out_c: oc, kh: 3, kw: 3, stride: 1, pad: 1 };
        let trunk = Sequential::new(vec![
            Box::new(Conv2d::new("det_conv0", g(3, 8), 16, 16, mode, rng)),
            Box::new(ReLU::new("dr0")),
            Box::new(MaxPool2::new("dp0", 8, 16, 16)),
            Box::new(Conv2d::new("det_conv1", g(8, 16), 8, 8, mode, rng)),
            Box::new(ReLU::new("dr1")),
            Box::new(MaxPool2::new("dp1", 16, 8, 8)),
        ]);
        DetectionNet {
            trunk,
            head_box: Linear::new("det_box", 16 * 4 * 4, 4, mode, rng),
            head_cls: Linear::new("det_cls", 16 * 4 * 4, classes, mode, rng),
            classes,
            feat: Tensor::zeros(&[0]),
        }
    }

    /// Forward: (boxes [n,4] via sigmoid, class logits [n, classes]).
    pub fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> (Tensor, Tensor) {
        let f = self.trunk.forward(x, ctx);
        let mut boxes = self.head_box.forward(&f, ctx);
        // sigmoid → boxes in (0,1)
        boxes.map_inplace(|v| 1.0 / (1.0 + (-v).exp()));
        let logits = self.head_cls.forward(&f, ctx);
        self.feat = f;
        (boxes, logits)
    }

    /// One SGD step; returns (box loss, class loss).
    pub fn train_step(
        &mut self,
        x: &Tensor,
        gt_boxes: &[[f32; 4]],
        gt_classes: &[usize],
        lr: f32,
        ctx: &mut TrainCtx,
    ) -> (f32, f32) {
        let (boxes, logits) = self.forward(x, ctx);
        let n = x.dim(0);
        let mut target = Tensor::zeros(&[n, 4]);
        for (b, bx) in gt_boxes.iter().enumerate() {
            target.data[b * 4..(b + 1) * 4].copy_from_slice(bx);
        }
        let (lb, mut gb) = smooth_l1(&boxes, &target);
        // through the sigmoid
        for (g, &s) in gb.data.iter_mut().zip(&boxes.data) {
            *g *= s * (1.0 - s);
        }
        let (lc, gc) = softmax_xent(&logits, gt_classes);
        let dfb = self.head_box.backward(&gb, ctx);
        let dfc = self.head_cls.backward(&gc, ctx);
        let mut df = dfb;
        df.add_inplace(&dfc);
        self.trunk.backward(&df, ctx);
        // SGD (no momentum on the tiny detector)
        let mut apply = |p: &mut Tensor, g: &mut Tensor| {
            for (pv, gv) in p.data.iter_mut().zip(g.data.iter_mut()) {
                *pv -= lr * *gv;
                *gv = 0.0;
            }
        };
        self.trunk.visit_params(&mut apply);
        self.head_box.visit_params(&mut apply);
        self.head_cls.visit_params(&mut apply);
        (lb, lc)
    }

    /// mAP-lite: AP@IoU≥0.5 for the single-object task = fraction of images
    /// whose predicted class matches AND predicted box IoU ≥ 0.5.
    pub fn map_lite(
        &mut self,
        x: &Tensor,
        gt_boxes: &[[f32; 4]],
        gt_classes: &[usize],
        ctx: &mut TrainCtx,
    ) -> f64 {
        let was_training = ctx.training;
        ctx.training = false;
        let (boxes, logits) = self.forward(x, ctx);
        ctx.training = was_training;
        let preds = logits.argmax_rows();
        let n = x.dim(0);
        let mut hits = 0usize;
        for b in 0..n {
            let pb = [
                boxes.data[b * 4],
                boxes.data[b * 4 + 1],
                boxes.data[b * 4 + 2],
                boxes.data[b * 4 + 3],
            ];
            if preds[b] == gt_classes[b] && box_iou(&pb, &gt_boxes[b]) >= 0.5 {
                hits += 1;
            }
        }
        hits as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDetection;

    #[test]
    fn detection_learns_f32() {
        let mut rng = Pcg32::seeded(0);
        let mut net = DetectionNet::new(3, QuantMode::Float32, &mut rng);
        let mut data = SynthDetection::new(1, 3, 3, 16, 16);
        let mut ctx = TrainCtx::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..30 {
            ctx.iter = it;
            let (x, boxes, classes) = data.batch(8);
            let (lb, lc) = net.train_step(&x, &boxes, &classes, 0.05, &mut ctx);
            if it == 0 {
                first = lb + lc;
            }
            last = lb + lc;
        }
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn map_lite_bounds() {
        let mut rng = Pcg32::seeded(1);
        let mut net = DetectionNet::new(3, QuantMode::Float32, &mut rng);
        let mut data = SynthDetection::new(2, 3, 3, 16, 16);
        let mut ctx = TrainCtx::new();
        let (x, boxes, classes) = data.batch(8);
        let m = net.map_lite(&x, &boxes, &classes, &mut ctx);
        assert!((0.0..=1.0).contains(&m));
    }
}
