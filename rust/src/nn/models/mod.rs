//! Model zoo (system S7): miniature versions of every architecture family
//! the paper evaluates, plus the detection/segmentation task heads.
//!
//! All image models take 3×12×12 inputs (flattened NCHW) and emit 10-class
//! logits. "Mini" keeps each family's signature structure — AlexNet's
//! conv→pool→fc stack, VGG's 3×3 chains, ResNet's identity skips + BN,
//! MobileNet's depthwise-separable blocks, Inception's parallel branches —
//! because the paper's claim is about *gradient distributions per layer
//! type*, which these structures reproduce (DESIGN.md §2).

mod blocks;
mod detection;
mod segmentation;

pub use blocks::{InceptionBlock, ResidualBlock};
pub use detection::DetectionNet;
pub use segmentation::SegNet;

use super::activ::{GlobalAvgPool, MaxPool2, ReLU};
use super::conv::{Conv2d, DepthwiseConv2d};
use super::linear::Linear;
use super::norm::BatchNorm2d;
use super::{QuantMode, Sequential};
use crate::fixedpoint::conv::Conv2dGeom;
use crate::util::Pcg32;

/// Input geometry shared by the zoo.
pub const IN_C: usize = 3;
pub const IN_H: usize = 12;
pub const IN_W: usize = 12;
pub const CLASSES: usize = 10;

pub fn input_len() -> usize {
    IN_C * IN_H * IN_W
}

fn g(in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize) -> Conv2dGeom {
    Conv2dGeom { in_c, out_c, kh: k, kw: k, stride, pad }
}

/// AlexNet-mini: 3 convs (+pools) and 2 fully-connected layers — the
/// paper's Fig 1/2 subject. Layer names mirror the paper (conv0.., fc0..).
pub fn alexnet_mini(mode: QuantMode, rng: &mut Pcg32) -> Sequential {
    Sequential::new(vec![
        Box::new(Conv2d::new("conv0", g(IN_C, 8, 3, 1, 1), 12, 12, mode, rng)),
        Box::new(ReLU::new("relu0")),
        Box::new(MaxPool2::new("pool0", 8, 12, 12)),
        Box::new(Conv2d::new("conv1", g(8, 16, 3, 1, 1), 6, 6, mode, rng)),
        Box::new(ReLU::new("relu1")),
        Box::new(MaxPool2::new("pool1", 16, 6, 6)),
        Box::new(Conv2d::new("conv2", g(16, 16, 3, 1, 1), 3, 3, mode, rng)),
        Box::new(ReLU::new("relu2")),
        Box::new(Linear::new("fc0", 16 * 3 * 3, 64, mode, rng)),
        Box::new(ReLU::new("relu3")),
        Box::new(Linear::new("fc1", 64, CLASSES, mode, rng)),
    ])
}

/// VGG-mini: chained 3×3 convs in two stages.
pub fn vgg_mini(mode: QuantMode, rng: &mut Pcg32) -> Sequential {
    Sequential::new(vec![
        Box::new(Conv2d::new("conv0_0", g(IN_C, 8, 3, 1, 1), 12, 12, mode, rng)),
        Box::new(ReLU::new("r00")),
        Box::new(Conv2d::new("conv0_1", g(8, 8, 3, 1, 1), 12, 12, mode, rng)),
        Box::new(ReLU::new("r01")),
        Box::new(MaxPool2::new("p0", 8, 12, 12)),
        Box::new(Conv2d::new("conv1_0", g(8, 16, 3, 1, 1), 6, 6, mode, rng)),
        Box::new(ReLU::new("r10")),
        Box::new(Conv2d::new("conv1_1", g(16, 16, 3, 1, 1), 6, 6, mode, rng)),
        Box::new(ReLU::new("r11")),
        Box::new(MaxPool2::new("p1", 16, 6, 6)),
        Box::new(Linear::new("fc0", 16 * 3 * 3, 64, mode, rng)),
        Box::new(ReLU::new("rf")),
        Box::new(Linear::new("fc1", 64, CLASSES, mode, rng)),
    ])
}

/// ResNet-mini: stem conv + two identity residual blocks with BN.
pub fn resnet_mini(mode: QuantMode, rng: &mut Pcg32) -> Sequential {
    Sequential::new(vec![
        Box::new(Conv2d::new("conv0", g(IN_C, 16, 3, 1, 1), 12, 12, mode, rng)),
        Box::new(BatchNorm2d::new("bn0", 16, 12 * 12)),
        Box::new(ReLU::new("r0")),
        Box::new(ResidualBlock::new("g1b1", 16, 12, 12, mode, rng)),
        Box::new(ResidualBlock::new("g1b2", 16, 12, 12, mode, rng)),
        Box::new(MaxPool2::new("p", 16, 12, 12)),
        Box::new(GlobalAvgPool::new("gap", 16, 6, 6)),
        Box::new(Linear::new("fc", 16, CLASSES, mode, rng)),
    ])
}

/// MobileNet-mini: depthwise-separable blocks (dw 3×3 + pw 1×1 + BN).
pub fn mobilenet_mini(mode: QuantMode, rng: &mut Pcg32) -> Sequential {
    Sequential::new(vec![
        Box::new(Conv2d::new("conv0", g(IN_C, 8, 3, 2, 1), 12, 12, mode, rng)),
        Box::new(BatchNorm2d::new("bn0", 8, 6 * 6)),
        Box::new(ReLU::new("r0")),
        Box::new(DepthwiseConv2d::new("dw1", 8, 6, 6, 1, mode, rng)),
        Box::new(Conv2d::new("pw1", g(8, 16, 1, 1, 0), 6, 6, mode, rng)),
        Box::new(BatchNorm2d::new("bn1", 16, 6 * 6)),
        Box::new(ReLU::new("r1")),
        Box::new(DepthwiseConv2d::new("dw2", 16, 6, 6, 1, mode, rng)),
        Box::new(Conv2d::new("pw2", g(16, 16, 1, 1, 0), 6, 6, mode, rng)),
        Box::new(BatchNorm2d::new("bn2", 16, 6 * 6)),
        Box::new(ReLU::new("r2")),
        Box::new(GlobalAvgPool::new("gap", 16, 6, 6)),
        Box::new(Linear::new("fc", 16, CLASSES, mode, rng)),
    ])
}

/// Inception-mini: stem + one two-branch inception block (1×1 ∥ 3×3) + head.
pub fn inception_mini(mode: QuantMode, rng: &mut Pcg32) -> Sequential {
    Sequential::new(vec![
        Box::new(Conv2d::new("conv0", g(IN_C, 8, 3, 1, 1), 12, 12, mode, rng)),
        Box::new(BatchNorm2d::new("bn0", 8, 12 * 12)),
        Box::new(ReLU::new("r0")),
        Box::new(MaxPool2::new("p0", 8, 12, 12)),
        Box::new(InceptionBlock::new("inc1", 8, 8, 8, 6, 6, mode, rng)),
        Box::new(ReLU::new("r1")),
        Box::new(GlobalAvgPool::new("gap", 16, 6, 6)),
        Box::new(Linear::new("fc", 16, CLASSES, mode, rng)),
    ])
}

/// Plain MLP (the quickstart model; matches the L2 MLP artifact shape).
pub fn mlp(mode: QuantMode, rng: &mut Pcg32, din: usize, classes: usize) -> Sequential {
    Sequential::new(vec![
        Box::new(Linear::new("fc0", din, 128, mode, rng)),
        Box::new(ReLU::new("r0")),
        Box::new(Linear::new("fc1", 128, 64, mode, rng)),
        Box::new(ReLU::new("r1")),
        Box::new(Linear::new("fc2", 64, classes, mode, rng)),
    ])
}

/// Look up a classification model by family name.
pub fn by_name(name: &str, mode: QuantMode, rng: &mut Pcg32) -> Option<Sequential> {
    Some(match name {
        "alexnet" => alexnet_mini(mode, rng),
        "vgg" => vgg_mini(mode, rng),
        "resnet" => resnet_mini(mode, rng),
        "mobilenet" => mobilenet_mini(mode, rng),
        "inception" => inception_mini(mode, rng),
        "mlp" => mlp(mode, rng, input_len(), CLASSES),
        _ => return None,
    })
}

pub const ZOO: [&str; 5] = ["alexnet", "vgg", "inception", "resnet", "mobilenet"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::softmax_xent;
    use crate::nn::TrainCtx;
    use crate::tensor::Tensor;
    use crate::train::{Optimizer, Sgd};

    fn smoke(name: &str, mode: QuantMode) {
        let mut rng = Pcg32::seeded(0);
        let mut net = by_name(name, mode, &mut rng).unwrap();
        let mut ctx = TrainCtx::new();
        let mut x = Tensor::zeros(&[2, input_len()]);
        rng.fill_normal(&mut x.data, 1.0);
        let logits = net.forward(&x, &mut ctx);
        assert_eq!(logits.shape, vec![2, CLASSES], "{name}");
        let (l, g) = softmax_xent(&logits, &[0, 1]);
        assert!(l.is_finite(), "{name}");
        let dx = net.backward(&g, &mut ctx);
        assert_eq!(dx.len(), 2 * input_len(), "{name}");
        let mut opt = Sgd::new(0.01, 0.9);
        opt.step(&mut net);
        net.zero_grads();
    }

    #[test]
    fn all_models_forward_backward_f32() {
        for name in ZOO.iter().chain(["mlp"].iter()) {
            smoke(name, QuantMode::Float32);
        }
    }

    #[test]
    fn all_models_forward_backward_adaptive() {
        let mut cfg = crate::apt::AptConfig::default();
        cfg.init_phase_iters = 1;
        for name in ZOO.iter().chain(["mlp"].iter()) {
            smoke(name, QuantMode::Adaptive(cfg));
        }
    }

    #[test]
    fn alexnet_learns_synthetic_classes() {
        let mut rng = Pcg32::seeded(1);
        let mut net = alexnet_mini(QuantMode::Float32, &mut rng);
        let mut data = crate::data::SynthImages::new(11, CLASSES, IN_C, IN_H, IN_W, 0.4);
        let mut opt = Sgd::new(0.02, 0.9);
        let mut ctx = TrainCtx::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..40 {
            ctx.iter = it;
            let (x, y) = data.batch(16);
            let logits = net.forward(&x, &mut ctx);
            let (l, g) = softmax_xent(&logits, &y);
            net.backward(&g, &mut ctx);
            opt.step(&mut net);
            net.zero_grads();
            if it == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first * 0.6, "first={first} last={last}");
    }
}
