//! Fully-connected layer with Algorithm-1 quantization.
//!
//! Forward:  y = X̂ · Ŵ + b        (X̂, Ŵ fake-quantized per controller)
//! Backward: dX = dŶ · Ŵᵀ          (BPROP — quantized gradient)
//!           dW = X̂ᵀ · dŶ          (WTGRAD — same quantized gradient)
//!
//! Bias add and bias grad stay f32 (the paper quantizes the GEMM operands).
//!
//! Saved tensors route through the `TrainCtx` activation stash
//! (DESIGN.md §Activation-Memory): X̂ under the `<name>/x` handle and — for
//! quantized runs — Ŵ under `<name>/w`; f32 runs read the live weight at
//! backward (unchanged since forward). With recompute on, only the raw
//! input is stashed (`<name>/x`) and X̂/Ŵ are re-derived during backward
//! from the schemes frozen at forward time.

use super::{Layer, QuantMode, TrainCtx};
use crate::apt::LayerControllers;
use crate::fixedpoint::quantize::fake_quant_stats_inplace_fmt;
use crate::fixedpoint::Format;
use crate::mem::StashHandle;
use crate::tensor::Tensor;
use crate::util::Pcg32;

pub struct Linear {
    name: String,
    pub w: Tensor, // in × out
    pub b: Tensor,
    pub gw: Tensor,
    pub gb: Tensor,
    ctl: Option<LayerControllers>,
    // stash sites for the saved backward operands
    h_x: StashHandle,
    h_w: StashHandle,
    last_g: Option<Tensor>,
    /// When set, the gradient controller is forced to this static width for
    /// this layer only (the per-layer ablations of Fig 1/2/11).
    pub grad_bits_override: Option<u8>,
}

impl Linear {
    pub fn new(name: &str, din: usize, dout: usize, mode: QuantMode, rng: &mut Pcg32) -> Self {
        let mut w = Tensor::zeros(&[din, dout]);
        // He init, matching the paper's initialization assumption (§3).
        let std = (2.0 / din as f32).sqrt();
        rng.fill_normal(&mut w.data, std);
        Linear {
            name: name.to_string(),
            b: Tensor::zeros(&[dout]),
            gw: Tensor::zeros(&[din, dout]),
            gb: Tensor::zeros(&[dout]),
            ctl: mode.config().map(|c| LayerControllers::new(c, name)),
            w,
            h_x: StashHandle::new(name, "x"),
            h_w: StashHandle::new(name, "w"),
            last_g: None,
            grad_bits_override: None,
        }
    }

    pub fn grad_controller_bits(&self) -> Option<u8> {
        self.ctl.as_ref().map(|c| c.g.bits())
    }
}

impl Layer for Linear {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        assert_eq!(x.rank(), 2, "{}: expected 2-D input", self.name);
        let eng = crate::kernels::global();
        let recompute = ctx.stash.recompute();
        match &mut self.ctl {
            Some(ctl) if ctx.quant_on() => {
                // QEM/QPA at update iterations, then fake-quantize.
                let (din, dout) = (self.w.dim(0), self.w.dim(1));
                if ctl.w.needs_update(ctx.iter) {
                    ctl.w.maybe_update_from_data(ctx.iter, &self.w.data, &mut ctx.ledger);
                    // per-channel scales freeze with the per-tensor decision
                    ctl.w.refresh_pc_scales(&self.w.data, din, dout, false);
                }
                if ctl.x.needs_update(ctx.iter) {
                    ctl.x.maybe_update_from_data(ctx.iter, &x.data, &mut ctx.ledger);
                }
                let mut xq = x.clone();
                eng.fake_quant_fmt(&mut xq.data, ctl.x.format());
                let mut wq = self.w.clone();
                ctl.w.fake_quant_weights(&mut wq.data, din, dout, false);
                let mut y = xq.matmul_with(&wq, eng);
                y.add_row_bias(&self.b.data);
                if ctx.training {
                    if recompute {
                        // checkpointing: keep only the raw input; X̂/Ŵ are
                        // re-derived at backward from the frozen schemes
                        ctx.stash.put(&self.h_x, x.clone(), ctx.iter, &mut ctx.ledger);
                    } else {
                        ctx.stash.put(&self.h_x, xq, ctx.iter, &mut ctx.ledger);
                        ctx.stash.put(&self.h_w, wq, ctx.iter, &mut ctx.ledger);
                    }
                }
                y
            }
            // Float path: no controllers, or quantization not yet live
            // (`--quant-delay`). X̂ = X; the backward weight is the live `w`.
            _ => {
                if ctx.training {
                    ctx.stash.put(&self.h_x, x.clone(), ctx.iter, &mut ctx.ledger);
                }
                let mut y = x.matmul_with(&self.w, eng);
                y.add_row_bias(&self.b.data);
                y
            }
        }
    }

    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let gq = match &mut self.ctl {
            Some(ctl) if ctx.quant_on() => {
                let fg = match self.grad_bits_override {
                    Some(bits) => {
                        // static per-layer override (observation ablations)
                        Format::FixedPoint(crate::fixedpoint::Scheme::for_range(g.max_abs(), bits))
                    }
                    None => {
                        if ctl.g.needs_update(ctx.iter) {
                            ctl.g.maybe_update_from_data(ctx.iter, &g.data, &mut ctx.ledger);
                        }
                        ctl.g.format()
                    }
                };
                ctx.ledger.trace_bits(
                    &self.name,
                    crate::fixedpoint::TensorKind::Gradient,
                    ctx.iter,
                    fg.storage_bits(),
                );
                let mut gq = g.clone();
                fake_quant_stats_inplace_fmt(&mut gq.data, fg);
                gq
            }
            _ => g.clone(),
        };
        self.last_g = Some(g.clone());
        let eng = crate::kernels::global();
        // Reconstruct the saved operands: stashed X̂ (and Ŵ for quantized
        // runs), or — with recompute — re-derive both from the raw stashed
        // input and the formats frozen at forward time (bit-identical under
        // F32 storage; parameters have not changed since forward).
        let (x_used, wq_owned): (Tensor, Option<Tensor>) = if ctx.stash.recompute() {
            let x = ctx.stash.take(&self.h_x);
            match &self.ctl {
                Some(ctl) if ctx.quant_on() => {
                    let mut xq = x;
                    eng.fake_quant_fmt(&mut xq.data, ctl.x.format());
                    let mut wq = self.w.clone();
                    ctl.w.fake_quant_weights(&mut wq.data, self.w.dim(0), self.w.dim(1), false);
                    (xq, Some(wq))
                }
                _ => (x, None),
            }
        } else {
            let x = ctx.stash.take(&self.h_x);
            let wq = match &self.ctl {
                Some(_) if ctx.quant_on() => Some(ctx.stash.take(&self.h_w)),
                _ => None,
            };
            (x, wq)
        };
        let w_used: &Tensor = wq_owned.as_ref().unwrap_or(&self.w);
        // WTGRAD: dW += X̂ᵀ · dŶ
        let dw = x_used.t().matmul_with(&gq, eng);
        self.gw.add_inplace(&dw);
        // bias grad: column sums
        let n = gq.dim(1);
        for row in gq.data.chunks(n) {
            for (gb, &v) in self.gb.data.iter_mut().zip(row) {
                *gb += v;
            }
        }
        // BPROP: dX = dŶ · Ŵᵀ
        gq.matmul_with(&w_used.t(), eng)
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        f(&mut self.w, &mut self.gw);
        f(&mut self.b, &mut self.gb);
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn last_grad(&self) -> Option<&Tensor> {
        self.last_g.as_ref()
    }

    fn set_grad_override(&mut self, layer: &str, bits: Option<u8>) -> bool {
        if layer == self.name {
            self.grad_bits_override = bits;
            true
        } else {
            false
        }
    }

    fn quantizes_grads(&self) -> bool {
        true
    }

    fn visit_controllers(&mut self, f: &mut dyn FnMut(&str, &mut LayerControllers)) {
        if let Some(ctl) = self.ctl.as_mut() {
            f(&self.name, ctl);
        }
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        let (sw, sx) = match &self.ctl {
            None => (None, None),
            Some(ctl) => (Some(ctl.w.format()), Some(ctl.x.format())),
        };
        out.push(crate::serve::InferOp::Linear {
            name: self.name.clone(),
            w: self.w.clone(),
            b: self.b.data.clone(),
            sw,
            sx,
        });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apt::AptConfig;
    use crate::fixedpoint::quantize::fake_quant_stats_inplace;
    use crate::fixedpoint::Scheme;
    use crate::util::Pcg32;

    fn randt(rng: &mut Pcg32, shape: &[usize], std: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    #[test]
    fn f32_backward_matches_finite_difference() {
        let mut rng = Pcg32::seeded(0);
        let mut l = Linear::new("fc", 5, 3, QuantMode::Float32, &mut rng);
        let x = randt(&mut rng, &[2, 5], 1.0);
        let mut ctx = TrainCtx::new();
        // loss = sum(y)
        let y = l.forward(&x, &mut ctx);
        let g = Tensor::filled(&[2, 3], 1.0);
        let dx = l.backward(&g, &mut ctx);
        let eps = 1e-3f32;
        for idx in [0usize, 3, 9] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let yp = l.forward(&xp, &mut ctx).sum();
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let ym = l.forward(&xm, &mut ctx).sum();
            let fd = ((yp - ym) / (2.0 * eps as f64)) as f32;
            assert!((dx.data[idx] - fd).abs() < 1e-2, "idx={idx}: {} vs {fd}", dx.data[idx]);
        }
        let _ = y;
    }

    #[test]
    fn quantized_backward_uses_quantized_operands() {
        let mut rng = Pcg32::seeded(1);
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        let mut l = Linear::new("fc", 4, 4, QuantMode::Adaptive(cfg), &mut rng);
        let x = randt(&mut rng, &[3, 4], 1.0);
        let mut ctx = TrainCtx::new();
        let _y = l.forward(&x, &mut ctx);
        let g = randt(&mut rng, &[3, 4], 1.0);
        let dx = l.backward(&g, &mut ctx);

        // manual: ĝ @ ŵᵀ with the schemes the controllers landed on (Ŵ
        // re-derived from the frozen weight scheme — what the stash held)
        let sg = Scheme::for_range(g.max_abs(), l.ctl.as_ref().unwrap().g.bits());
        let mut gq = g.clone();
        fake_quant_stats_inplace(&mut gq.data, sg);
        let mut wq = l.w.clone();
        fake_quant_stats_inplace(&mut wq.data, l.ctl.as_ref().unwrap().w.scheme());
        let want = gq.matmul(&wq.t());
        for (a, b) in dx.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn static_mode_pins_every_width() {
        let mut rng = Pcg32::seeded(2);
        let mut l = Linear::new("fc", 8, 8, QuantMode::Static(16), &mut rng);
        let x = randt(&mut rng, &[2, 8], 1.0);
        let mut ctx = TrainCtx::new();
        let _ = l.forward(&x, &mut ctx);
        let g = randt(&mut rng, &[2, 8], 1.0);
        let _ = l.backward(&g, &mut ctx);
        let ctl = l.ctl.as_ref().unwrap();
        assert_eq!(ctl.w.bits(), 16);
        assert_eq!(ctl.x.bits(), 16);
        assert_eq!(ctl.g.bits(), 16);
    }

    #[test]
    fn grad_override_bypasses_controller() {
        let mut rng = Pcg32::seeded(3);
        let mut l = Linear::new("fc", 4, 4, QuantMode::Adaptive(AptConfig::default()), &mut rng);
        l.grad_bits_override = Some(12);
        let x = randt(&mut rng, &[2, 4], 1.0);
        let mut ctx = TrainCtx::new();
        let _ = l.forward(&x, &mut ctx);
        let g = randt(&mut rng, &[2, 4], 100.0);
        let _ = l.backward(&g, &mut ctx);
        // controller untouched by the override path
        assert_eq!(l.ctl.as_ref().unwrap().g.updates(), 0);
    }
}
