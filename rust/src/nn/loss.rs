//! Loss functions: softmax cross-entropy (classification / segmentation /
//! LM), smooth-L1 (detection box regression), plus accuracy/IoU metrics.

use crate::tensor::{softmax_rows, Tensor};

/// Softmax cross-entropy over rows. Returns (mean loss, dL/dlogits).
pub fn softmax_xent(logits: &Tensor, labels: &[usize]) -> (f32, Tensor) {
    assert_eq!(logits.rank(), 2);
    let n = logits.dim(0);
    let c = logits.dim(1);
    assert_eq!(labels.len(), n);
    let mut probs = logits.clone();
    softmax_rows(&mut probs);
    let mut loss = 0.0f64;
    let mut grad = probs.clone();
    for (b, &y) in labels.iter().enumerate() {
        debug_assert!(y < c);
        loss -= (probs.data[b * c + y].max(1e-12) as f64).ln();
        grad.data[b * c + y] -= 1.0;
    }
    grad.scale_inplace(1.0 / n as f32);
    ((loss / n as f64) as f32, grad)
}

/// Top-1 accuracy.
pub fn accuracy(logits: &Tensor, labels: &[usize]) -> f64 {
    let preds = logits.argmax_rows();
    let hit = preds.iter().zip(labels).filter(|(p, y)| p == y).count();
    hit as f64 / labels.len().max(1) as f64
}

/// Smooth-L1 (Huber, δ=1) over all elements. Returns (mean loss, grad).
pub fn smooth_l1(pred: &Tensor, target: &Tensor) -> (f32, Tensor) {
    assert_eq!(pred.shape, target.shape);
    let n = pred.len().max(1);
    let mut loss = 0.0f64;
    let mut grad = Tensor::zeros(&pred.shape);
    for i in 0..pred.len() {
        let d = pred.data[i] - target.data[i];
        if d.abs() < 1.0 {
            loss += (0.5 * d * d) as f64;
            grad.data[i] = d;
        } else {
            loss += (d.abs() - 0.5) as f64;
            grad.data[i] = d.signum();
        }
    }
    grad.scale_inplace(1.0 / n as f32);
    ((loss / n as f64) as f32, grad)
}

/// Per-pixel softmax-xent for segmentation: logits [n, classes*h*w] in CHW
/// order, labels [n, h*w]. Returns (loss, grad in the same layout).
pub fn pixel_xent(logits: &Tensor, labels: &[Vec<usize>], classes: usize) -> (f32, Tensor) {
    let n = logits.dim(0);
    let chw = logits.dim(1);
    let hw = chw / classes;
    let mut grad = Tensor::zeros(&logits.shape);
    let mut loss = 0.0f64;
    for img in 0..n {
        for p in 0..hw {
            // gather per-pixel logits (stride hw in CHW)
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..classes {
                maxv = maxv.max(logits.data[img * chw + c * hw + p]);
            }
            let mut z = 0.0f32;
            for c in 0..classes {
                z += (logits.data[img * chw + c * hw + p] - maxv).exp();
            }
            let y = labels[img][p];
            for c in 0..classes {
                let pr = (logits.data[img * chw + c * hw + p] - maxv).exp() / z;
                grad.data[img * chw + c * hw + p] = pr - (c == y) as i32 as f32;
                if c == y {
                    loss -= (pr.max(1e-12) as f64).ln();
                }
            }
        }
    }
    let denom = (n * hw) as f32;
    grad.scale_inplace(1.0 / denom);
    ((loss / denom as f64) as f32, grad)
}

/// Mean IoU over classes for segmentation predictions.
pub fn mean_iou(pred: &[Vec<usize>], gold: &[Vec<usize>], classes: usize) -> f64 {
    let mut inter = vec![0u64; classes];
    let mut union = vec![0u64; classes];
    for (p_img, g_img) in pred.iter().zip(gold) {
        for (&p, &g) in p_img.iter().zip(g_img) {
            if p == g {
                inter[p] += 1;
                union[p] += 1;
            } else {
                union[p] += 1;
                union[g] += 1;
            }
        }
    }
    let mut sum = 0.0;
    let mut cnt = 0;
    for c in 0..classes {
        if union[c] > 0 {
            sum += inter[c] as f64 / union[c] as f64;
            cnt += 1;
        }
    }
    if cnt == 0 {
        0.0
    } else {
        sum / cnt as f64
    }
}

/// IoU of two axis-aligned boxes (cx, cy, w, h) in [0,1] coords.
pub fn box_iou(a: &[f32; 4], b: &[f32; 4]) -> f64 {
    let (ax0, ay0, ax1, ay1) = (a[0] - a[2] / 2.0, a[1] - a[3] / 2.0, a[0] + a[2] / 2.0, a[1] + a[3] / 2.0);
    let (bx0, by0, bx1, by1) = (b[0] - b[2] / 2.0, b[1] - b[3] / 2.0, b[0] + b[2] / 2.0, b[1] + b[3] / 2.0);
    let iw = (ax1.min(bx1) - ax0.max(bx0)).max(0.0) as f64;
    let ih = (ay1.min(by1) - ay0.max(by0)).max(0.0) as f64;
    let inter = iw * ih;
    let ua = (ax1 - ax0) as f64 * (ay1 - ay0) as f64 + (bx1 - bx0) as f64 * (by1 - by0) as f64 - inter;
    if ua <= 0.0 {
        0.0
    } else {
        inter / ua
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xent_gradient_matches_probs_minus_onehot() {
        let logits = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let (l, g) = softmax_xent(&logits, &[2]);
        assert!(l > 0.0);
        // grad sums to 0 per row
        let s: f32 = g.data.iter().sum();
        assert!(s.abs() < 1e-6);
        assert!(g.data[2] < 0.0 && g.data[0] > 0.0);
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        let logits = Tensor::from_vec(&[1, 2], vec![20.0, -20.0]);
        let (l, _) = softmax_xent(&logits, &[0]);
        assert!(l < 1e-3);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Tensor::from_vec(&[2, 2], vec![2.0, 1.0, 0.0, 3.0]);
        assert_eq!(accuracy(&logits, &[0, 1]), 1.0);
        assert_eq!(accuracy(&logits, &[1, 1]), 0.5);
    }

    #[test]
    fn smooth_l1_quadratic_then_linear() {
        let p = Tensor::from_vec(&[1, 2], vec![0.5, 3.0]);
        let t = Tensor::zeros(&[1, 2]);
        let (l, g) = smooth_l1(&p, &t);
        assert!((l - (0.5 * 0.25 + 2.5) as f32 / 2.0).abs() < 1e-6);
        assert!((g.data[0] - 0.25).abs() < 1e-6); // 0.5/2
        assert!((g.data[1] - 0.5).abs() < 1e-6); // sign/2
    }

    #[test]
    fn box_iou_cases() {
        let a = [0.5, 0.5, 0.2, 0.2];
        assert!((box_iou(&a, &a) - 1.0).abs() < 1e-9);
        let b = [0.9, 0.9, 0.1, 0.1];
        assert_eq!(box_iou(&a, &b), 0.0);
    }

    #[test]
    fn mean_iou_perfect_and_disjoint() {
        let p = vec![vec![0, 1, 1, 0]];
        assert!((mean_iou(&p, &p, 2) - 1.0).abs() < 1e-9);
        let g = vec![vec![1, 0, 0, 1]];
        assert_eq!(mean_iou(&p, &g, 2), 0.0);
    }

    #[test]
    fn pixel_xent_grad_rowsums_zero() {
        let logits = Tensor::from_vec(&[1, 2 * 2], vec![1.0, -1.0, 0.5, 0.5]); // 2 classes, 2 px
        let labels = vec![vec![0usize, 1]];
        let (l, g) = pixel_xent(&logits, &labels, 2);
        assert!(l > 0.0);
        // per pixel, grads over classes sum to 0
        for p in 0..2 {
            let s = g.data[p] + g.data[2 + p];
            assert!(s.abs() < 1e-6);
        }
    }
}
