//! Elman-RNN encoder–decoder for the machine-translation experiment
//! (Fig 9a's Sockeye substitute — see DESIGN.md §2).
//!
//! Encoder:  h_t = tanh(Wx·e(x_t) + Wh·h_{t−1} + b)
//! Decoder:  s_t = tanh(Vx·e(y_{t−1}) + Vh·s_{t−1} + c),  logits_t = Why·s_t
//!
//! All six projection matmuls run on fake-quantized operands per
//! Algorithm 1, with activation-gradient quantization inside BPTT — the code
//! path where unified int16 visibly degrades and adaptive precision recovers
//! accuracy by escalating some tensors to int24 (the paper's key RNN claim).

use super::{QuantMode, TrainCtx};
use crate::apt::LayerControllers;
use crate::fixedpoint::quantize::fake_quant_stats_inplace_fmt;
use crate::fixedpoint::TensorKind;
use crate::mem::StashHandle;
use crate::tensor::Tensor;
use crate::util::Pcg32;

pub struct Seq2Seq {
    pub vocab: usize,
    pub dim: usize,
    // parameters
    pub emb_src: Tensor,
    pub emb_tgt: Tensor,
    pub enc_wx: Tensor,
    pub enc_wh: Tensor,
    pub enc_b: Tensor,
    pub dec_wx: Tensor,
    pub dec_wh: Tensor,
    pub dec_b: Tensor,
    pub why: Tensor,
    pub by: Tensor,
    // grads (same shapes)
    pub grads: Vec<Tensor>,
    // velocity for SGD-momentum
    vel: Vec<Tensor>,
    // quant controllers per projection
    ctl: Option<Vec<LayerControllers>>, // [enc_wx, enc_wh, dec_wx, dec_wh, why]
    // per-timestep stash handles (rnn/<role><t>), created once and grown
    // lazily to the longest sequence seen — the create-once handle
    // convention of DESIGN.md §Activation-Memory, adapted to BPTT
    enc_handles: Vec<(StashHandle, StashHandle)>,
    dec_handles: Vec<(StashHandle, StashHandle, StashHandle)>,
}

const PROJ_NAMES: [&str; 5] = ["enc_wx", "enc_wh", "dec_wx", "dec_wh", "why"];

fn tanh_vec(v: &mut [f32]) {
    for x in v.iter_mut() {
        *x = x.tanh();
    }
}

impl Seq2Seq {
    pub fn new(vocab: usize, dim: usize, mode: QuantMode, rng: &mut Pcg32) -> Self {
        let mut t = |shape: &[usize], std: f32| {
            let mut x = Tensor::zeros(shape);
            rng.fill_normal(&mut x.data, std);
            x
        };
        let d = dim;
        let std = (1.0 / d as f32).sqrt();
        let shapes: Vec<Vec<usize>> = vec![
            vec![vocab, d],
            vec![vocab, d],
            vec![d, d],
            vec![d, d],
            vec![d],
            vec![d, d],
            vec![d, d],
            vec![d],
            vec![d, vocab],
            vec![vocab],
        ];
        let grads = shapes.iter().map(|s| Tensor::zeros(s)).collect::<Vec<_>>();
        let vel = shapes.iter().map(|s| Tensor::zeros(s)).collect::<Vec<_>>();
        Seq2Seq {
            vocab,
            dim,
            emb_src: t(&[vocab, d], 0.1),
            emb_tgt: t(&[vocab, d], 0.1),
            enc_wx: t(&[d, d], std),
            enc_wh: t(&[d, d], std),
            enc_b: Tensor::zeros(&[d]),
            dec_wx: t(&[d, d], std),
            dec_wh: t(&[d, d], std),
            dec_b: Tensor::zeros(&[d]),
            why: t(&[d, vocab], std),
            by: Tensor::zeros(&[vocab]),
            grads,
            vel,
            ctl: mode
                .config()
                .map(|c| PROJ_NAMES.iter().map(|n| LayerControllers::new(c, n)).collect()),
            enc_handles: Vec::new(),
            dec_handles: Vec::new(),
        }
    }

    /// Grow the per-timestep stash-handle caches to cover `s_len`/`t_len`
    /// (no-op once the longest sequence has been seen).
    fn ensure_handles(&mut self, s_len: usize, t_len: usize) {
        while self.enc_handles.len() < s_len {
            let t = self.enc_handles.len();
            self.enc_handles.push((
                StashHandle::new("rnn", &format!("enc_x{t}")),
                StashHandle::new("rnn", &format!("enc_h{t}")),
            ));
        }
        while self.dec_handles.len() < t_len {
            let t = self.dec_handles.len();
            self.dec_handles.push((
                StashHandle::new("rnn", &format!("dec_x{t}")),
                StashHandle::new("rnn", &format!("dec_h{t}")),
                StashHandle::new("rnn", &format!("dec_s{t}")),
            ));
        }
    }

    /// Mirror of [`crate::nn::Layer::quantizes_grads`] for the non-`Layer`
    /// recurrent stack: every projection GEMM quantizes its incoming
    /// gradient per Algorithm 1 (structural, mode-independent).
    pub fn quantizes_grads(&self) -> bool {
        true
    }

    /// Names of the gradient-quantizing projections, in forward order — the
    /// rnn analogue of `Sequential::quantized_layer_names`.
    pub fn quantized_proj_names() -> [&'static str; 5] {
        PROJ_NAMES
    }

    /// Gradient bit-widths currently applied per projection (for reporting).
    pub fn grad_bits(&self) -> Vec<(String, u8)> {
        match &self.ctl {
            None => vec![],
            Some(cs) => cs
                .iter()
                .zip(Self::quantized_proj_names())
                .map(|(c, n)| (n.to_string(), c.g.bits()))
                .collect(),
        }
    }

    fn embed(table: &Tensor, tokens: &[usize], d: usize) -> Tensor {
        let mut out = Tensor::zeros(&[tokens.len(), d]);
        for (i, &t) in tokens.iter().enumerate() {
            out.data[i * d..(i + 1) * d].copy_from_slice(&table.data[t * d..(t + 1) * d]);
        }
        out
    }

    /// Quantize a weight in place per its controller's format (per-channel
    /// scales over output columns when configured). `quant` is false while
    /// a `--quant-delay` holds the run in f32.
    fn qw(
        ctl: &mut Option<Vec<LayerControllers>>,
        idx: usize,
        w: &Tensor,
        iter: u64,
        quant: bool,
        ledger: &mut crate::apt::Ledger,
    ) -> Tensor {
        let mut wq = w.clone();
        if !quant {
            return wq;
        }
        if let Some(cs) = ctl {
            let c = &mut cs[idx];
            if c.w.needs_update(iter) {
                c.w.maybe_update_from_data(iter, &w.data, ledger);
                c.w.refresh_pc_scales(&w.data, w.dim(0), w.dim(1), false);
            }
            c.w.fake_quant_weights(&mut wq.data, w.dim(0), w.dim(1), false);
        }
        wq
    }

    fn qx(
        ctl: &mut Option<Vec<LayerControllers>>,
        idx: usize,
        x: &Tensor,
        iter: u64,
        quant: bool,
        ledger: &mut crate::apt::Ledger,
    ) -> Tensor {
        let mut xq = x.clone();
        if !quant {
            return xq;
        }
        if let Some(cs) = ctl {
            let c = &mut cs[idx];
            if c.x.needs_update(iter) {
                c.x.maybe_update_from_data(iter, &x.data, ledger);
            }
            fake_quant_stats_inplace_fmt(&mut xq.data, c.x.format());
        }
        xq
    }

    fn qg(
        ctl: &mut Option<Vec<LayerControllers>>,
        idx: usize,
        g: &Tensor,
        iter: u64,
        quant: bool,
        ledger: &mut crate::apt::Ledger,
    ) -> Tensor {
        let mut gq = g.clone();
        if !quant {
            return gq;
        }
        if let Some(cs) = ctl {
            let c = &mut cs[idx];
            if c.g.needs_update(iter) {
                c.g.maybe_update_from_data(iter, &g.data, ledger);
            }
            let fg = c.g.format();
            ledger.trace_bits(PROJ_NAMES[idx], TensorKind::Gradient, iter, fg.storage_bits());
            fake_quant_stats_inplace_fmt(&mut gq.data, fg);
        }
        gq
    }

    /// Run forward+backward without applying the update (fills `grads`).
    pub fn train_step_no_update(
        &mut self,
        src: &[Vec<usize>],
        tgt: &[Vec<usize>],
        ctx: &mut TrainCtx,
    ) -> (f32, f64) {
        for g in self.grads.iter_mut() {
            g.data.fill(0.0);
        }
        self.run(src, tgt, true, ctx)
    }

    /// One training step on a batch of (src, tgt) token sequences with
    /// teacher forcing. Returns (mean loss, word accuracy).
    pub fn train_step(
        &mut self,
        src: &[Vec<usize>],
        tgt: &[Vec<usize>],
        lr: f32,
        ctx: &mut TrainCtx,
    ) -> (f32, f64) {
        let (loss, acc) = self.run(src, tgt, true, ctx);
        // SGD momentum update
        let lr = lr;
        let params: Vec<&mut Tensor> = vec![
            &mut self.emb_src,
            &mut self.emb_tgt,
            &mut self.enc_wx,
            &mut self.enc_wh,
            &mut self.enc_b,
            &mut self.dec_wx,
            &mut self.dec_wh,
            &mut self.dec_b,
            &mut self.why,
            &mut self.by,
        ];
        for ((p, g), v) in params.into_iter().zip(self.grads.iter_mut()).zip(self.vel.iter_mut()) {
            for ((pv, gv), vv) in p.data.iter_mut().zip(g.data.iter_mut()).zip(v.data.iter_mut()) {
                *vv = 0.9 * *vv + *gv;
                *pv -= lr * *vv;
                *gv = 0.0;
            }
        }
        (loss, acc)
    }

    /// Evaluate (teacher-forced word accuracy + loss) without updating.
    pub fn eval(&mut self, src: &[Vec<usize>], tgt: &[Vec<usize>], ctx: &mut TrainCtx) -> (f32, f64) {
        self.run(src, tgt, false, ctx)
    }

    fn run(
        &mut self,
        src: &[Vec<usize>],
        tgt: &[Vec<usize>],
        train: bool,
        ctx: &mut TrainCtx,
    ) -> (f32, f64) {
        let eng = crate::kernels::global();
        let b = src.len();
        let d = self.dim;
        let v = self.vocab;
        let s_len = src[0].len();
        let t_len = tgt[0].len();
        let iter = ctx.iter;
        let quant = ctx.quant_on();

        // quantized weights for this step
        let enc_wx_q = Self::qw(&mut self.ctl, 0, &self.enc_wx, iter, quant, &mut ctx.ledger);
        let enc_wh_q = Self::qw(&mut self.ctl, 1, &self.enc_wh, iter, quant, &mut ctx.ledger);
        let dec_wx_q = Self::qw(&mut self.ctl, 2, &self.dec_wx, iter, quant, &mut ctx.ledger);
        let dec_wh_q = Self::qw(&mut self.ctl, 3, &self.dec_wh, iter, quant, &mut ctx.ledger);
        let why_q = Self::qw(&mut self.ctl, 4, &self.why, iter, quant, &mut ctx.ledger);

        // ---------------- forward ----------------
        // BPTT operands (quantized embeddings / hidden inputs / softmax
        // inputs) stash per timestep under the cached rnn/<role><t>
        // handles (DESIGN.md §Activation-Memory); the tanh outputs stay
        // local — they drive the forward recurrence itself. Forward and
        // backward share the same handle cache, so key agreement is
        // structural. (A `TrainCtx` serves one model — the repo-wide
        // convention — so the fixed `rnn` namespace is safe.)
        if train {
            self.ensure_handles(s_len, t_len);
        }
        let mut enc_h: Vec<Tensor> = Vec::with_capacity(s_len + 1);
        enc_h.push(Tensor::zeros(&[b, d]));
        for t in 0..s_len {
            let toks: Vec<usize> = src.iter().map(|s| s[t]).collect();
            let e = Self::embed(&self.emb_src, &toks, d);
            let eq = Self::qx(&mut self.ctl, 0, &e, iter, quant, &mut ctx.ledger);
            let hq = Self::qx(&mut self.ctl, 1, enc_h.last().unwrap(), iter, quant, &mut ctx.ledger);
            let mut h = eq.matmul_with(&enc_wx_q, eng);
            h.add_inplace(&hq.matmul_with(&enc_wh_q, eng));
            h.add_row_bias(&self.enc_b.data);
            tanh_vec(&mut h.data);
            if train {
                ctx.stash.put(&self.enc_handles[t].0, eq, iter, &mut ctx.ledger);
                ctx.stash.put(&self.enc_handles[t].1, hq, iter, &mut ctx.ledger);
            }
            enc_h.push(h);
        }

        let mut dec_h: Vec<Tensor> = Vec::with_capacity(t_len + 1);
        dec_h.push(enc_h.last().unwrap().clone());
        let mut logits_all: Vec<Tensor> = Vec::with_capacity(t_len);
        let bos = 0usize;
        for t in 0..t_len {
            let toks: Vec<usize> = tgt
                .iter()
                .map(|s| if t == 0 { bos } else { s[t - 1] })
                .collect();
            let e = Self::embed(&self.emb_tgt, &toks, d);
            let eq = Self::qx(&mut self.ctl, 2, &e, iter, quant, &mut ctx.ledger);
            let hq = Self::qx(&mut self.ctl, 3, dec_h.last().unwrap(), iter, quant, &mut ctx.ledger);
            let mut h = eq.matmul_with(&dec_wx_q, eng);
            h.add_inplace(&hq.matmul_with(&dec_wh_q, eng));
            h.add_row_bias(&self.dec_b.data);
            tanh_vec(&mut h.data);
            let sq = Self::qx(&mut self.ctl, 4, &h, iter, quant, &mut ctx.ledger);
            let mut logits = sq.matmul_with(&why_q, eng);
            logits.add_row_bias(&self.by.data);
            if train {
                ctx.stash.put(&self.dec_handles[t].0, eq, iter, &mut ctx.ledger);
                ctx.stash.put(&self.dec_handles[t].1, hq, iter, &mut ctx.ledger);
                ctx.stash.put(&self.dec_handles[t].2, sq, iter, &mut ctx.ledger);
            }
            dec_h.push(h);
            logits_all.push(logits);
        }

        // loss + metrics
        let mut loss = 0.0f32;
        let mut hits = 0usize;
        let mut dlogits: Vec<Tensor> = Vec::with_capacity(t_len);
        for t in 0..t_len {
            let labels: Vec<usize> = tgt.iter().map(|s| s[t]).collect();
            let (l, g) = super::loss::softmax_xent(&logits_all[t], &labels);
            loss += l;
            let preds = logits_all[t].argmax_rows();
            hits += preds.iter().zip(&labels).filter(|(p, y)| p == y).count();
            dlogits.push(g);
        }
        loss /= t_len as f32;
        let acc = hits as f64 / (b * t_len) as f64;
        if !train {
            return (loss, acc);
        }

        // ---------------- backward (BPTT) ----------------
        // grads index map: 0 emb_src, 1 emb_tgt, 2 enc_wx, 3 enc_wh, 4 enc_b,
        //                  5 dec_wx, 6 dec_wh, 7 dec_b, 8 why, 9 by
        let scale = 1.0 / t_len as f32;
        let mut dh_next = Tensor::zeros(&[b, d]);
        for t in (0..t_len).rev() {
            let mut dl = dlogits[t].clone();
            dl.scale_inplace(scale);
            // quantize dlogits (ΔX̂ for the Why projection)
            let dlq = Self::qg(&mut self.ctl, 4, &dl, iter, quant, &mut ctx.ledger);
            // why grads: sᵀ·ĝ ; by: col sums
            let sq = ctx.stash.take(&self.dec_handles[t].2);
            self.grads[8].add_inplace(&sq.t().matmul_with(&dlq, eng));
            for row in dlq.data.chunks(v) {
                for (gb, &x) in self.grads[9].data.iter_mut().zip(row) {
                    *gb += x;
                }
            }
            // ds = ĝ·Whyᵀ + dh_next
            let mut ds = dlq.matmul_with(&why_q.t(), eng);
            ds.add_inplace(&dh_next);
            // through tanh
            for (dv, &hv) in ds.data.iter_mut().zip(&dec_h[t + 1].data) {
                *dv *= 1.0 - hv * hv;
            }
            // quantize recurrent gradient (ΔX̂ for dec projections)
            let dsq = Self::qg(&mut self.ctl, 3, &ds, iter, quant, &mut ctx.ledger);
            let xq = ctx.stash.take(&self.dec_handles[t].0);
            let hq = ctx.stash.take(&self.dec_handles[t].1);
            self.grads[5].add_inplace(&xq.t().matmul_with(&dsq, eng));
            self.grads[6].add_inplace(&hq.t().matmul_with(&dsq, eng));
            for row in dsq.data.chunks(d) {
                for (gb, &x) in self.grads[7].data.iter_mut().zip(row) {
                    *gb += x;
                }
            }
            // embedding grad (f32, scatter)
            let de = dsq.matmul_with(&dec_wx_q.t(), eng);
            for (bidx, s) in tgt.iter().enumerate() {
                let tok = if t == 0 { bos } else { s[t - 1] };
                for j in 0..d {
                    self.grads[1].data[tok * d + j] += de.data[bidx * d + j];
                }
            }
            dh_next = dsq.matmul_with(&dec_wh_q.t(), eng);
        }

        // into encoder: gradient w.r.t. enc final h
        let mut dhe = dh_next;
        for t in (0..s_len).rev() {
            for (dv, &hv) in dhe.data.iter_mut().zip(&enc_h[t + 1].data) {
                *dv *= 1.0 - hv * hv;
            }
            let dhq = Self::qg(&mut self.ctl, 1, &dhe, iter, quant, &mut ctx.ledger);
            let xq = ctx.stash.take(&self.enc_handles[t].0);
            let hq = ctx.stash.take(&self.enc_handles[t].1);
            self.grads[2].add_inplace(&xq.t().matmul_with(&dhq, eng));
            self.grads[3].add_inplace(&hq.t().matmul_with(&dhq, eng));
            for row in dhq.data.chunks(d) {
                for (gb, &x) in self.grads[4].data.iter_mut().zip(row) {
                    *gb += x;
                }
            }
            let de = dhq.matmul_with(&enc_wx_q.t(), eng);
            for (bidx, s) in src.iter().enumerate() {
                let tok = s[t];
                for j in 0..d {
                    self.grads[0].data[tok * d + j] += de.data[bidx * d + j];
                }
            }
            dhe = dhq.matmul_with(&enc_wh_q.t(), eng);
        }

        (loss, acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::translation_batch;
    use crate::nn::QuantMode;

    #[test]
    fn bptt_gradient_matches_finite_difference() {
        let mut rng = Pcg32::seeded(42);
        let mut m = Seq2Seq::new(8, 6, QuantMode::Float32, &mut rng);
        let mut ctx = TrainCtx::new();
        let (src, tgt) = translation_batch(&mut rng, 2, 3, 8);
        // one backward to fill grads (lr=0 → params unchanged)
        let _ = m.train_step_no_update(&src, &tgt, &mut ctx);
        let eps = 1e-3f32;
        // check a few coordinates of enc_wx (idx 2) and why (idx 8)
        for (which, idx) in [(0usize, 1usize), (0, 7), (1, 3)] {
            let grad = if which == 0 { m.grads[2].data[idx] } else { m.grads[8].data[idx] };
            let bump = |m: &mut Seq2Seq, d: f32| {
                if which == 0 { m.enc_wx.data[idx] += d } else { m.why.data[idx] += d }
            };
            bump(&mut m, eps);
            let (lp, _) = m.eval(&src, &tgt, &mut ctx);
            bump(&mut m, -2.0 * eps);
            let (lm, _) = m.eval(&src, &tgt, &mut ctx);
            bump(&mut m, eps);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((grad - fd).abs() < 2e-2, "which={which} idx={idx}: {grad} vs {fd}");
        }
    }

    #[test]
    fn f32_seq2seq_learns_reversal() {
        let mut rng = Pcg32::seeded(0);
        let mut m = Seq2Seq::new(12, 32, QuantMode::Float32, &mut rng);
        let mut ctx = TrainCtx::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..250 {
            ctx.iter = it;
            let (src, tgt) = translation_batch(&mut rng, 16, 4, 12);
            let (l, _) = m.train_step(&src, &tgt, 0.05, &mut ctx);
            if it == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first * 0.6, "first={first} last={last}");
    }

    #[test]
    fn projection_quantization_surface() {
        let mut rng = Pcg32::seeded(4);
        let m = Seq2Seq::new(8, 6, QuantMode::Float32, &mut rng);
        // structural, mode-independent — mirrors Layer::quantizes_grads
        assert!(m.quantizes_grads());
        assert_eq!(Seq2Seq::quantized_proj_names(), PROJ_NAMES);
    }

    #[test]
    fn adaptive_seq2seq_trains_and_reports_bits() {
        let mut rng = Pcg32::seeded(1);
        let mut cfg = crate::apt::AptConfig::default();
        cfg.init_phase_iters = 5;
        let mut m = Seq2Seq::new(12, 32, QuantMode::Adaptive(cfg), &mut rng);
        let mut ctx = TrainCtx::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..250 {
            ctx.iter = it;
            let (src, tgt) = translation_batch(&mut rng, 16, 4, 12);
            let (l, _) = m.train_step(&src, &tgt, 0.05, &mut ctx);
            if it == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first * 0.7, "first={first} last={last}");
        let bits = m.grad_bits();
        assert_eq!(bits.len(), 5);
        assert!(bits.iter().all(|(_, b)| [8u8, 16, 24, 32].contains(b)));
    }
}
