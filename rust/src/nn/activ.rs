//! Activation / shape layers: ReLU, max-pool 2×2, global average pool.
//! None of these are quantized (the paper quantizes GEMM operands only) —
//! but their backward bookkeeping routes through the `TrainCtx` stash as
//! exact packed payloads (1-bit ReLU masks, u32 pool argmax), so the
//! reported stash peaks cover every byte held between forward and backward.

use super::{Layer, TrainCtx};
use crate::mem::StashHandle;
use crate::tensor::Tensor;

/// Elementwise ReLU.
pub struct ReLU {
    name: String,
    h_mask: StashHandle,
}

impl ReLU {
    pub fn new(name: &str) -> Self {
        ReLU { h_mask: StashHandle::new(name, "mask"), name: name.to_string() }
    }
}

impl Layer for ReLU {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let mut y = x.clone();
        if ctx.training {
            let mask: Vec<bool> = x.data.iter().map(|&v| v > 0.0).collect();
            ctx.stash.put_mask(&self.h_mask, &mask);
        }
        y.map_inplace(|v| v.max(0.0));
        y
    }

    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let mask = ctx.stash.take_mask(&self.h_mask);
        assert_eq!(g.len(), mask.len());
        let mut d = g.clone();
        for (v, &m) in d.data.iter_mut().zip(&mask) {
            if !m {
                *v = 0.0;
            }
        }
        d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        out.push(crate::serve::InferOp::Relu);
        true
    }
}

/// 2×2 max pool, stride 2, over NCHW carried as [n, c*h*w].
pub struct MaxPool2 {
    name: String,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    h_argmax: StashHandle,
}

impl MaxPool2 {
    pub fn new(name: &str, c: usize, h: usize, w: usize) -> Self {
        assert!(h % 2 == 0 && w % 2 == 0, "pool needs even dims, got {h}x{w}");
        MaxPool2 { h_argmax: StashHandle::new(name, "argmax"), name: name.to_string(), c, h, w }
    }

    pub fn out_hw(&self) -> (usize, usize) {
        (self.h / 2, self.w / 2)
    }
}

impl Layer for MaxPool2 {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = x.dim(0);
        let (c, h, w) = (self.c, self.h, self.w);
        assert_eq!(x.dim(1), c * h * w);
        let (oh, ow) = self.out_hw();
        let mut y = Tensor::zeros(&[n, c * oh * ow]);
        let mut argmax = vec![0usize; if ctx.training { n * c * oh * ow } else { 0 }];
        for img in 0..n {
            for ch in 0..c {
                let xi = &x.data[img * c * h * w + ch * h * w..][..h * w];
                let base_o = img * c * oh * ow + ch * oh * ow;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut best = f32::NEG_INFINITY;
                        let mut bi = 0usize;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                let idx = (2 * oy + dy) * w + 2 * ox + dx;
                                if xi[idx] > best {
                                    best = xi[idx];
                                    bi = idx;
                                }
                            }
                        }
                        y.data[base_o + oy * ow + ox] = best;
                        if ctx.training {
                            argmax[base_o + oy * ow + ox] = img * c * h * w + ch * h * w + bi;
                        }
                    }
                }
            }
        }
        if ctx.training {
            ctx.stash.put_indices(&self.h_argmax, &argmax);
        }
        y
    }

    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = g.dim(0);
        let argmax = ctx.stash.take_indices(&self.h_argmax);
        let mut dx = Tensor::zeros(&[n, self.c * self.h * self.w]);
        for (i, &gi) in g.data.iter().enumerate() {
            dx.data[argmax[i]] += gi;
        }
        dx
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        out.push(crate::serve::InferOp::MaxPool { c: self.c, h: self.h, w: self.w });
        true
    }
}

/// Global average pool: [n, c*h*w] → [n, c].
pub struct GlobalAvgPool {
    name: String,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl GlobalAvgPool {
    pub fn new(name: &str, c: usize, h: usize, w: usize) -> Self {
        GlobalAvgPool { name: name.to_string(), c, h, w }
    }
}

impl Layer for GlobalAvgPool {
    fn forward(&mut self, x: &Tensor, _ctx: &mut TrainCtx) -> Tensor {
        let n = x.dim(0);
        let hw = self.h * self.w;
        assert_eq!(x.dim(1), self.c * hw);
        let mut y = Tensor::zeros(&[n, self.c]);
        for img in 0..n {
            for ch in 0..self.c {
                let s: f32 = x.data[img * self.c * hw + ch * hw..][..hw].iter().sum();
                y.data[img * self.c + ch] = s / hw as f32;
            }
        }
        y
    }

    fn backward(&mut self, g: &Tensor, _ctx: &mut TrainCtx) -> Tensor {
        let n = g.dim(0);
        let hw = self.h * self.w;
        let mut dx = Tensor::zeros(&[n, self.c * hw]);
        let inv = 1.0 / hw as f32;
        for img in 0..n {
            for ch in 0..self.c {
                let gv = g.data[img * self.c + ch] * inv;
                for v in dx.data[img * self.c * hw + ch * hw..][..hw].iter_mut() {
                    *v = gv;
                }
            }
        }
        dx
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        out.push(crate::serve::InferOp::GlobalAvgPool { c: self.c, h: self.h, w: self.w });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn relu_masks_gradient() {
        let mut r = ReLU::new("r");
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 2.0, -3.0, 4.0]);
        let mut ctx = TrainCtx::new();
        let y = r.forward(&x, &mut ctx);
        assert_eq!(y.data, vec![0.0, 2.0, 0.0, 4.0]);
        let g = Tensor::filled(&[1, 4], 1.0);
        let d = r.backward(&g, &mut ctx);
        assert_eq!(d.data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn maxpool_forward_backward() {
        let mut p = MaxPool2::new("p", 1, 2, 2);
        let x = Tensor::from_vec(&[1, 4], vec![1.0, 5.0, 3.0, 2.0]);
        let mut ctx = TrainCtx::new();
        let y = p.forward(&x, &mut ctx);
        assert_eq!(y.data, vec![5.0]);
        let d = p.backward(&Tensor::filled(&[1, 1], 2.0), &mut ctx);
        assert_eq!(d.data, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn gap_mean_and_backward() {
        let mut p = GlobalAvgPool::new("g", 2, 2, 2);
        let mut x = Tensor::zeros(&[1, 8]);
        let mut rng = Pcg32::seeded(0);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = p.forward(&x, &mut ctx);
        let m0: f32 = x.data[..4].iter().sum::<f32>() / 4.0;
        assert!((y.data[0] - m0).abs() < 1e-6);
        let d = p.backward(&Tensor::filled(&[1, 2], 4.0), &mut ctx);
        assert!(d.data.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }
}
