//! Pure-Rust training substrate (system S5/S6 in DESIGN.md).
//!
//! A small define-by-layer autograd: each [`Layer`] caches what its backward
//! needs, `Sequential` chains them, and quantization per Algorithm 1 happens
//! *inside* the linear/conv layers (quantized W/X on forward, quantized
//! dY driving both BPROP and WTGRAD on backward), steered by the per-layer
//! [`crate::apt::PrecisionController`]s.

pub mod activ;
pub mod conv;
pub mod linear;
pub mod loss;
pub mod models;
pub mod norm;
pub mod rnn;

use crate::apt::{AptConfig, LayerControllers, Ledger};
use crate::mem::{ActivationStash, StashPolicy};
use crate::tensor::Tensor;

/// Quantization mode of a training run.
#[derive(Clone, Copy, Debug)]
pub enum QuantMode {
    /// Plain float32 training.
    Float32,
    /// Adaptive precision training (the paper's method).
    Adaptive(AptConfig),
    /// Unified static bit-width for every quantized tensor (the int8 / int16
    /// baselines of Fig 9 and Table 2).
    Static(u8),
}

impl QuantMode {
    /// The controller config, if quantization is on.
    pub fn config(&self) -> Option<AptConfig> {
        match self {
            QuantMode::Float32 => None,
            QuantMode::Adaptive(c) => Some(*c),
            QuantMode::Static(bits) => Some(AptConfig::static_bits(*bits)),
        }
    }

    pub fn label(&self) -> String {
        match self {
            QuantMode::Float32 => "float32".into(),
            QuantMode::Adaptive(_) => "adaptive".into(),
            QuantMode::Static(b) => format!("int{b}"),
        }
    }
}

/// Mutable training context threaded through forward/backward.
pub struct TrainCtx {
    pub iter: u64,
    pub training: bool,
    pub ledger: Ledger,
    /// First iteration at which quantization is live (`apt train
    /// --quant-delay N`). Iterations below this train in plain f32 — the
    /// layers skip controller updates and fake-quant entirely — then the
    /// controllers warm-start from the float weights at `quant_from`.
    /// 0 (the default) is bit-identical to quantizing from the start.
    pub quant_from: u64,
    /// Every tensor saved for backward lives here, behind the run's
    /// [`StashPolicy`] (DESIGN.md §Activation-Memory). `new()` uses F32
    /// storage without recompute — bit-identical to the historical
    /// layer-private caches.
    pub stash: ActivationStash,
}

impl TrainCtx {
    pub fn new() -> Self {
        TrainCtx {
            iter: 0,
            training: true,
            ledger: Ledger::new(),
            quant_from: 0,
            stash: ActivationStash::f32_default(),
        }
    }

    /// A context whose stash stores under `policy`, optionally recomputing
    /// the GEMM layers' saved operands during backward.
    pub fn with_stash(policy: StashPolicy, recompute: bool) -> Self {
        TrainCtx {
            iter: 0,
            training: true,
            ledger: Ledger::new(),
            quant_from: 0,
            stash: ActivationStash::new(policy, recompute),
        }
    }

    /// Is quantization live at the current iteration? Layers consult this
    /// in both forward and backward (the same `iter`, so the two passes of
    /// one step always agree).
    pub fn quant_on(&self) -> bool {
        self.iter >= self.quant_from
    }
}

impl Default for TrainCtx {
    fn default() -> Self {
        Self::new()
    }
}

/// A differentiable layer.
pub trait Layer {
    /// Forward pass; caches whatever backward needs when `ctx.training`.
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor;
    /// Backward pass: consumes dL/dy, accumulates parameter grads internally,
    /// returns dL/dx.
    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor;
    /// Visit (param, grad) pairs for the optimizer.
    fn visit_params(&mut self, _f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {}
    /// Layer name (used as the ledger key).
    fn name(&self) -> &str;
    /// Gradient-tensor probe for the observation experiments: layers that
    /// quantize gradients report the last dY seen (before quantization).
    fn last_grad(&self) -> Option<&Tensor> {
        None
    }
    /// Force a static gradient bit-width on the named (sub)layer — the
    /// per-layer ablation switch of Fig 1/2/11. Returns true if applied.
    fn set_grad_override(&mut self, _layer: &str, _bits: Option<u8>) -> bool {
        false
    }
    /// Whether this layer quantizes its incoming activation gradient per
    /// Algorithm 1 (linear/conv do; activations, pools and norms do not).
    /// Structural — true regardless of the run's [`QuantMode`].
    fn quantizes_grads(&self) -> bool {
        false
    }
    /// Visit the per-tensor precision controllers (layer name, controllers)
    /// of this layer and any sublayers, in forward order. Layers training in
    /// Float32 have no controllers and visit nothing. Used by
    /// `train::checkpoint` for save/restore.
    fn visit_controllers(&mut self, _f: &mut dyn FnMut(&str, &mut LayerControllers)) {}
    /// Visit non-parameter state that must survive a checkpoint (e.g.
    /// batch-norm running statistics), in a deterministic order.
    fn visit_state(&mut self, _f: &mut dyn FnMut(&mut [f32])) {}
    /// Append this layer's forward-only serving ops (frozen schemes, folded
    /// eval-mode state — see `serve::FrozenModel`, DESIGN.md §Serving) to
    /// `out`. Returns `false` when the layer has no serving export (the
    /// default), which makes the whole freeze fail with the layer's name.
    fn export_infer(&self, _out: &mut Vec<crate::serve::InferOp>) -> bool {
        false
    }
}

/// A chain of layers.
pub struct Sequential {
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        Sequential { layers }
    }

    pub fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let mut h = x.clone();
        for l in self.layers.iter_mut() {
            h = l.forward(&h, ctx);
        }
        h
    }

    pub fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let mut d = g.clone();
        for l in self.layers.iter_mut().rev() {
            d = l.backward(&d, ctx);
        }
        d
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        for l in self.layers.iter_mut() {
            l.visit_params(f);
        }
    }

    /// Visit (layer name, param, grad) triples. Parameters of composite
    /// blocks report the block's name; the (name, slot-within-name) pair is
    /// the stable address behind `train::ParamId`.
    pub fn visit_params_named(&mut self, f: &mut dyn FnMut(&str, &mut Tensor, &mut Tensor)) {
        for l in self.layers.iter_mut() {
            let name = l.name().to_string();
            l.visit_params(&mut |p, g| f(&name, p, g));
        }
    }

    /// [`visit_params_named`](Self::visit_params_named) plus the per-layer
    /// slot index — the single definition of `train::ParamId` addressing
    /// (param/checkpoint walks must all agree on it).
    pub fn visit_params_slotted(
        &mut self,
        f: &mut dyn FnMut(&str, usize, &mut Tensor, &mut Tensor),
    ) {
        for l in self.layers.iter_mut() {
            let name = l.name().to_string();
            let mut slot = 0usize;
            l.visit_params(&mut |p, g| {
                f(&name, slot, p, g);
                slot += 1;
            });
        }
    }

    /// Visit every layer's precision controllers, in forward order.
    pub fn visit_controllers(&mut self, f: &mut dyn FnMut(&str, &mut LayerControllers)) {
        for l in self.layers.iter_mut() {
            l.visit_controllers(f);
        }
    }

    /// Visit every layer's non-parameter checkpoint state, in forward order.
    pub fn visit_state(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        for l in self.layers.iter_mut() {
            l.visit_state(f);
        }
    }

    /// Reset all accumulated parameter gradients to zero. An explicit step:
    /// optimizers only *read* gradients, so probes between `backward` and
    /// the next `zero_grads` observe the step's true gradients
    /// (DESIGN.md §Session-API).
    pub fn zero_grads(&mut self) {
        self.visit_params(&mut |_, g| g.data.fill(0.0));
    }

    pub fn param_count(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(&mut |p, _| n += p.len());
        n
    }

    /// Apply a per-layer gradient bit-width override (Fig 1/2/11 ablations).
    pub fn set_grad_override(&mut self, layer: &str, bits: Option<u8>) -> bool {
        self.layers.iter_mut().any(|l| l.set_grad_override(layer, bits))
    }

    /// The last pre-quantization activation gradient seen by a named layer.
    pub fn last_grad_of(&self, layer: &str) -> Option<&Tensor> {
        self.layers.iter().find(|l| l.name() == layer).and_then(|l| l.last_grad())
    }

    /// Export the whole chain as forward-only serving ops, in forward
    /// order (the input of `serve::FrozenModel::freeze`). Errors with the
    /// offending layer's name if any layer has no serving export.
    pub fn export_infer(&self) -> anyhow::Result<Vec<crate::serve::InferOp>> {
        let mut ops = Vec::new();
        for l in &self.layers {
            if !l.export_infer(&mut ops) {
                anyhow::bail!(
                    "layer {:?} has no forward-only serving export (serve::FrozenModel)",
                    l.name()
                );
            }
        }
        Ok(ops)
    }

    /// Names of gradient-quantizing layers, in forward order — layers whose
    /// [`Layer::quantizes_grads`] is true (linear/conv families and the
    /// composite blocks that contain them).
    pub fn quantized_layer_names(&self) -> Vec<String> {
        self.layers
            .iter()
            .filter(|l| l.quantizes_grads())
            .map(|l| l.name().to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::linear::Linear;
    use crate::nn::loss::softmax_xent;
    use crate::train::{Optimizer, Sgd};
    use crate::util::Pcg32;

    /// A 2-layer MLP must fit a linearly-separable toy problem in f32.
    #[test]
    fn sequential_learns_f32() {
        let mut rng = Pcg32::seeded(0);
        let mut net = Sequential::new(vec![
            Box::new(Linear::new("fc0", 4, 16, QuantMode::Float32, &mut rng)),
            Box::new(crate::nn::activ::ReLU::new("relu0")),
            Box::new(Linear::new("fc1", 16, 2, QuantMode::Float32, &mut rng)),
        ]);
        let mut opt = Sgd::new(0.1, 0.9);
        let mut ctx = TrainCtx::new();
        let mut first = None;
        let mut last = 0.0;
        for it in 0..60 {
            ctx.iter = it;
            // class = sign of x0+x1
            let mut x = Tensor::zeros(&[16, 4]);
            let mut y = vec![0usize; 16];
            for b in 0..16 {
                for j in 0..4 {
                    x.data[b * 4 + j] = rng.normal();
                }
                y[b] = (x.data[b * 4] + x.data[b * 4 + 1] > 0.0) as usize;
            }
            let logits = net.forward(&x, &mut ctx);
            let (l, g) = softmax_xent(&logits, &y);
            net.backward(&g, &mut ctx);
            opt.step(&mut net);
            net.zero_grads();
            if first.is_none() {
                first = Some(l);
            }
            last = l;
        }
        assert!(last < first.unwrap() * 0.5, "first={:?} last={last}", first);
    }

    #[test]
    fn quantized_layer_names_are_explicit() {
        let mut rng = Pcg32::seeded(0);
        let net = crate::nn::models::alexnet_mini(QuantMode::Float32, &mut rng);
        // structural, mode-independent: convs + fcs, never relus/pools
        assert_eq!(
            net.quantized_layer_names(),
            vec!["conv0", "conv1", "conv2", "fc0", "fc1"]
        );
        let net = crate::nn::models::mobilenet_mini(QuantMode::Float32, &mut rng);
        let names = net.quantized_layer_names();
        assert!(names.iter().any(|n| n == "dw1"), "depthwise missing: {names:?}");
        assert!(names.iter().any(|n| n == "pw2"), "pointwise missing: {names:?}");
        assert!(names.iter().all(|n| !n.starts_with("bn") && !n.starts_with('r')));
    }

    #[test]
    fn quant_mode_labels() {
        assert_eq!(QuantMode::Float32.label(), "float32");
        assert_eq!(QuantMode::Static(16).label(), "int16");
        assert!(QuantMode::Adaptive(AptConfig::default()).label().contains("adaptive"));
        assert!(QuantMode::Float32.config().is_none());
        assert_eq!(QuantMode::Static(16).config().unwrap().min_bits, 16);
    }
}
