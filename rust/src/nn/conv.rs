//! Convolution layers (standard + depthwise) with Algorithm-1 quantization.
//!
//! Internally a conv is the GEMM `W[out_c × CKK] · patches[CKK × OHW]` over
//! the im2col lowering, so quantization hits exactly the operands the paper
//! quantizes. NCHW activations flattened as `[n, c*h*w]` 2-D tensors with
//! the geometry carried by the layer.
//!
//! The im2col patch matrices — k·k× the input size, the dominant stash
//! entry of every conv net — route through the `TrainCtx` activation stash
//! (`<name>/patches`, one `[n, rows·cols]` tensor per step) together with
//! Ŵ for quantized runs (`<name>/w`). With recompute on, only the raw
//! input images are stashed (`<name>/x`) and the patches are re-lowered
//! (and re-fake-quantized with the frozen scheme) during backward —
//! classic gradient checkpointing with a ~k² stash reduction.

use super::{Layer, QuantMode, TrainCtx};
use crate::apt::LayerControllers;
use crate::fixedpoint::conv::{col2im, im2col, Conv2dGeom};
use crate::fixedpoint::gemm;
use crate::fixedpoint::quantize::fake_quant_stats_inplace_fmt;
use crate::fixedpoint::{Format, TensorKind};
use crate::mem::StashHandle;
use crate::tensor::Tensor;
use crate::util::Pcg32;

pub struct Conv2d {
    name: String,
    pub geom: Conv2dGeom,
    pub in_h: usize,
    pub in_w: usize,
    pub w: Tensor, // out_c × (in_c·kh·kw)
    pub b: Tensor,
    pub gw: Tensor,
    pub gb: Tensor,
    ctl: Option<LayerControllers>,
    // stash sites: quantized patches + Ŵ, or the raw input under recompute
    h_patches: StashHandle,
    h_w: StashHandle,
    h_x: StashHandle,
    last_g: Option<Tensor>,
    pub grad_bits_override: Option<u8>,
}

impl Conv2d {
    pub fn new(
        name: &str,
        geom: Conv2dGeom,
        in_h: usize,
        in_w: usize,
        mode: QuantMode,
        rng: &mut Pcg32,
    ) -> Self {
        let fan_in = geom.in_c * geom.kh * geom.kw;
        let mut w = Tensor::zeros(&[geom.out_c, fan_in]);
        rng.fill_normal(&mut w.data, (2.0 / fan_in as f32).sqrt());
        Conv2d {
            name: name.to_string(),
            geom,
            in_h,
            in_w,
            b: Tensor::zeros(&[geom.out_c]),
            gw: Tensor::zeros(&[geom.out_c, fan_in]),
            gb: Tensor::zeros(&[geom.out_c]),
            ctl: mode.config().map(|c| LayerControllers::new(c, name)),
            w,
            h_patches: StashHandle::new(name, "patches"),
            h_w: StashHandle::new(name, "w"),
            h_x: StashHandle::new(name, "x"),
            last_g: None,
            grad_bits_override: None,
        }
    }

    pub fn out_hw(&self) -> (usize, usize) {
        self.geom.out_hw(self.in_h, self.in_w)
    }

    pub fn out_len(&self) -> usize {
        let (oh, ow) = self.out_hw();
        self.geom.out_c * oh * ow
    }
}

impl Layer for Conv2d {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = x.dim(0);
        let (h, w) = (self.in_h, self.in_w);
        let g = self.geom;
        assert_eq!(x.dim(1), g.in_c * h * w, "{}: input size", self.name);
        let (rows, cols) = g.im2col_dims(h, w);

        // quantization parameter update + weight fake-quant; `fx_opt` is
        // Some exactly when quantization is live this step (controllers
        // present and past any `--quant-delay`)
        let fx_opt = match &mut self.ctl {
            Some(ctl) if ctx.quant_on() => {
                if ctl.w.needs_update(ctx.iter) {
                    ctl.w.maybe_update_from_data(ctx.iter, &self.w.data, &mut ctx.ledger);
                    // per-channel scales freeze with the per-tensor decision
                    ctl.w.refresh_pc_scales(&self.w.data, g.out_c, rows, true);
                }
                if ctl.x.needs_update(ctx.iter) {
                    ctl.x.maybe_update_from_data(ctx.iter, &x.data, &mut ctx.ledger);
                }
                Some(ctl.x.format())
            }
            _ => None,
        };
        let mut wq = self.w.clone();
        if fx_opt.is_some() {
            let ctl = self.ctl.as_ref().unwrap();
            ctl.w.fake_quant_weights(&mut wq.data, g.out_c, rows, true);
        }

        // Engine dispatch: the im2col GEMM has m = out_c, so its row panels
        // shard by output-channel blocks (DESIGN.md §Kernel-Engine).
        let eng = crate::kernels::global();
        let (oh, ow) = g.out_hw(h, w);
        let mut out = Tensor::zeros(&[n, g.out_c * oh * ow]);
        let recompute = ctx.stash.recompute();
        let save_patches = ctx.training && !recompute;
        let mut patches_save = if save_patches {
            Vec::with_capacity(n * rows * cols)
        } else {
            Vec::new()
        };
        let mut patch = vec![0.0f32; rows * cols];
        for img in 0..n {
            let xi = &x.data[img * g.in_c * h * w..(img + 1) * g.in_c * h * w];
            im2col(g, h, w, xi, &mut patch);
            if let Some(fx) = fx_opt {
                eng.fake_quant_fmt(&mut patch, fx);
            }
            let co = &mut out.data[img * g.out_c * cols..(img + 1) * g.out_c * cols];
            eng.gemm_f32(g.out_c, rows, cols, &wq.data, &patch, co);
            // bias per output channel
            for oc in 0..g.out_c {
                let bv = self.b.data[oc];
                for v in co[oc * cols..(oc + 1) * cols].iter_mut() {
                    *v += bv;
                }
            }
            if save_patches {
                patches_save.extend_from_slice(&patch);
            }
        }
        if ctx.training {
            if recompute {
                // checkpointing: the raw input alone (~1/k² of the patch
                // bytes); backward re-lowers with the frozen schemes
                ctx.stash.put(&self.h_x, x.clone(), ctx.iter, &mut ctx.ledger);
            } else {
                let patches = Tensor::from_vec(&[n, rows * cols], patches_save);
                ctx.stash.put(&self.h_patches, patches, ctx.iter, &mut ctx.ledger);
                if fx_opt.is_some() {
                    // float-path runs read the live weight at backward instead
                    ctx.stash.put(&self.h_w, wq, ctx.iter, &mut ctx.ledger);
                }
            }
        }
        out
    }

    fn backward(&mut self, gout: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = gout.dim(0);
        let g = self.geom;
        let (h, w) = (self.in_h, self.in_w);
        let (rows, cols) = g.im2col_dims(h, w);

        // quantize the incoming activation gradient (Algorithm 1's ΔX̂)
        let mut gq = gout.clone();
        if let Some(ctl) = &mut self.ctl {
            if ctx.quant_on() {
                let fg = match self.grad_bits_override {
                    Some(bits) => Format::FixedPoint(crate::fixedpoint::Scheme::for_range(
                        gout.max_abs(),
                        bits,
                    )),
                    None => {
                        if ctl.g.needs_update(ctx.iter) {
                            ctl.g.maybe_update_from_data(ctx.iter, &gout.data, &mut ctx.ledger);
                        }
                        ctl.g.format()
                    }
                };
                ctx.ledger.trace_bits(&self.name, TensorKind::Gradient, ctx.iter, fg.storage_bits());
                fake_quant_stats_inplace_fmt(&mut gq.data, fg);
            }
        }
        self.last_g = Some(gout.clone());

        let eng = crate::kernels::global();
        // Reconstruct the saved operands: the stashed `[n, rows·cols]`
        // patch tensor + Ŵ, or — with recompute — re-lower im2col from the
        // raw stashed input and re-apply the schemes frozen at forward time
        // (bit-identical under F32 storage; weights have not changed).
        let (patches, wq_owned): (Tensor, Option<Tensor>) = if ctx.stash.recompute() {
            let x = ctx.stash.take(&self.h_x);
            let (wq_opt, fx_opt) = match &self.ctl {
                Some(ctl) if ctx.quant_on() => {
                    let mut wq = self.w.clone();
                    ctl.w.fake_quant_weights(&mut wq.data, g.out_c, rows, true);
                    (Some(wq), Some(ctl.x.format()))
                }
                _ => (None, None),
            };
            let mut pd = vec![0.0f32; n * rows * cols];
            let mut patch = vec![0.0f32; rows * cols];
            for img in 0..n {
                let xi = &x.data[img * g.in_c * h * w..(img + 1) * g.in_c * h * w];
                im2col(g, h, w, xi, &mut patch);
                if let Some(fx) = fx_opt {
                    eng.fake_quant_fmt(&mut patch, fx);
                }
                pd[img * rows * cols..(img + 1) * rows * cols].copy_from_slice(&patch);
            }
            (Tensor::from_vec(&[n, rows * cols], pd), wq_opt)
        } else {
            let p = ctx.stash.take(&self.h_patches);
            let wq = match &self.ctl {
                Some(_) if ctx.quant_on() => Some(ctx.stash.take(&self.h_w)),
                _ => None,
            };
            (p, wq)
        };
        let wsrc: &Tensor = wq_owned.as_ref().unwrap_or(&self.w);
        let mut dx = Tensor::zeros(&[n, g.in_c * h * w]);
        let mut dpatch = vec![0.0f32; rows * cols];
        let mut wt = vec![0.0f32; self.w.len()];
        gemm::transpose(g.out_c, rows, &wsrc.data, &mut wt);
        let mut dw_local = vec![0.0f32; self.w.len()];
        let mut patch_t = vec![0.0f32; rows * cols];
        for img in 0..n {
            let gi = &gq.data[img * g.out_c * cols..(img + 1) * g.out_c * cols];
            // WTGRAD: dW += ĝ[out_c×cols] · patchᵀ[cols×rows]
            let pq = &patches.data[img * rows * cols..(img + 1) * rows * cols];
            gemm::transpose(rows, cols, pq, &mut patch_t);
            eng.gemm_f32(g.out_c, cols, rows, gi, &patch_t, &mut dw_local);
            for (a, &b) in self.gw.data.iter_mut().zip(dw_local.iter()) {
                *a += b;
            }
            // bias grad
            for oc in 0..g.out_c {
                self.gb.data[oc] += gi[oc * cols..(oc + 1) * cols].iter().sum::<f32>();
            }
            // BPROP: dpatch = Ŵᵀ[rows×out_c] · ĝ[out_c×cols]; col2im → dx
            eng.gemm_f32(rows, g.out_c, cols, &wt, gi, &mut dpatch);
            let dxi = &mut dx.data[img * g.in_c * h * w..(img + 1) * g.in_c * h * w];
            col2im(g, h, w, &dpatch, dxi);
        }
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        f(&mut self.w, &mut self.gw);
        f(&mut self.b, &mut self.gb);
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn last_grad(&self) -> Option<&Tensor> {
        self.last_g.as_ref()
    }

    fn set_grad_override(&mut self, layer: &str, bits: Option<u8>) -> bool {
        if layer == self.name {
            self.grad_bits_override = bits;
            true
        } else {
            false
        }
    }

    fn quantizes_grads(&self) -> bool {
        true
    }

    fn visit_controllers(&mut self, f: &mut dyn FnMut(&str, &mut LayerControllers)) {
        if let Some(ctl) = self.ctl.as_mut() {
            f(&self.name, ctl);
        }
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        let (sw, sx) = match &self.ctl {
            None => (None, None),
            Some(ctl) => (Some(ctl.w.format()), Some(ctl.x.format())),
        };
        out.push(crate::serve::InferOp::Conv {
            name: self.name.clone(),
            geom: self.geom,
            in_h: self.in_h,
            in_w: self.in_w,
            w: self.w.clone(),
            b: self.b.data.clone(),
            sw,
            sx,
        });
        true
    }
}

/// Depthwise 3×3 convolution (MobileNet's separable building block).
/// Quantization applies to the per-channel kernels and activations the same
/// way; implemented directly (no im2col) since each channel is independent.
/// X̂ stashes under `<name>/x` (Ŵ under `<name>/w` for quantized runs);
/// recompute does not apply (the input *is* the saved operand here).
pub struct DepthwiseConv2d {
    name: String,
    pub c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub stride: usize,
    pub w: Tensor, // c × 9
    pub gw: Tensor,
    ctl: Option<LayerControllers>,
    h_x: StashHandle,
    h_w: StashHandle,
    last_g: Option<Tensor>,
}

impl DepthwiseConv2d {
    pub fn new(name: &str, c: usize, in_h: usize, in_w: usize, stride: usize, mode: QuantMode, rng: &mut Pcg32) -> Self {
        let mut w = Tensor::zeros(&[c, 9]);
        rng.fill_normal(&mut w.data, (2.0 / 9.0f32).sqrt());
        DepthwiseConv2d {
            name: name.to_string(),
            c,
            in_h,
            in_w,
            stride,
            gw: Tensor::zeros(&[c, 9]),
            ctl: mode.config().map(|cg| LayerControllers::new(cg, name)),
            w,
            h_x: StashHandle::new(name, "x"),
            h_w: StashHandle::new(name, "w"),
            last_g: None,
        }
    }

    pub fn out_hw(&self) -> (usize, usize) {
        ((self.in_h + 2 - 3) / self.stride + 1, (self.in_w + 2 - 3) / self.stride + 1)
    }
}

impl Layer for DepthwiseConv2d {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = x.dim(0);
        let (h, w) = (self.in_h, self.in_w);
        let (oh, ow) = self.out_hw();
        assert_eq!(x.dim(1), self.c * h * w);

        let (mut xq, mut wq) = (x.clone(), self.w.clone());
        let quant = ctx.quant_on();
        if let Some(ctl) = &mut self.ctl {
            if quant {
                if ctl.w.needs_update(ctx.iter) {
                    ctl.w.maybe_update_from_data(ctx.iter, &self.w.data, &mut ctx.ledger);
                    ctl.w.refresh_pc_scales(&self.w.data, self.c, 9, true);
                }
                if ctl.x.needs_update(ctx.iter) {
                    ctl.x.maybe_update_from_data(ctx.iter, &x.data, &mut ctx.ledger);
                }
                fake_quant_stats_inplace_fmt(&mut xq.data, ctl.x.format());
                ctl.w.fake_quant_weights(&mut wq.data, self.c, 9, true);
            }
        }

        let mut out = Tensor::zeros(&[n, self.c * oh * ow]);
        for img in 0..n {
            for c in 0..self.c {
                let xi = &xq.data[img * self.c * h * w + c * h * w..][..h * w];
                let k = &wq.data[c * 9..(c + 1) * 9];
                let oi = &mut out.data[img * self.c * oh * ow + c * oh * ow..][..oh * ow];
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0f32;
                        for ky in 0..3 {
                            let iy = (oy * self.stride + ky) as isize - 1;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..3 {
                                let ix = (ox * self.stride + kx) as isize - 1;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                acc += k[ky * 3 + kx] * xi[iy as usize * w + ix as usize];
                            }
                        }
                        oi[oy * ow + ox] = acc;
                    }
                }
            }
        }
        if ctx.training {
            ctx.stash.put(&self.h_x, xq, ctx.iter, &mut ctx.ledger);
            if self.ctl.is_some() && quant {
                ctx.stash.put(&self.h_w, wq, ctx.iter, &mut ctx.ledger);
            }
        }
        out
    }

    fn backward(&mut self, gout: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = gout.dim(0);
        let (h, w) = (self.in_h, self.in_w);
        let (oh, ow) = self.out_hw();
        let quant = ctx.quant_on();
        let mut gq = gout.clone();
        if let Some(ctl) = &mut self.ctl {
            if quant {
                if ctl.g.needs_update(ctx.iter) {
                    ctl.g.maybe_update_from_data(ctx.iter, &gout.data, &mut ctx.ledger);
                }
                let fg = ctl.g.format();
                ctx.ledger.trace_bits(&self.name, TensorKind::Gradient, ctx.iter, fg.storage_bits());
                fake_quant_stats_inplace_fmt(&mut gq.data, fg);
            }
        }
        self.last_g = Some(gout.clone());

        let xq = ctx.stash.take(&self.h_x);
        let wq_owned = if self.ctl.is_some() && quant {
            Some(ctx.stash.take(&self.h_w))
        } else {
            None
        };
        let wq: &Tensor = wq_owned.as_ref().unwrap_or(&self.w);
        let mut dx = Tensor::zeros(&[n, self.c * h * w]);
        for img in 0..n {
            for c in 0..self.c {
                let xi = &xq.data[img * self.c * h * w + c * h * w..][..h * w];
                let k = &wq.data[c * 9..(c + 1) * 9];
                let gi = &gq.data[img * self.c * oh * ow + c * oh * ow..][..oh * ow];
                let dxi = &mut dx.data[img * self.c * h * w + c * h * w..][..h * w];
                let gk = &mut self.gw.data[c * 9..(c + 1) * 9];
                for oy in 0..oh {
                    for ox in 0..ow {
                        let gv = gi[oy * ow + ox];
                        if gv == 0.0 {
                            continue;
                        }
                        for ky in 0..3 {
                            let iy = (oy * self.stride + ky) as isize - 1;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..3 {
                                let ix = (ox * self.stride + kx) as isize - 1;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let xi_v = xi[iy as usize * w + ix as usize];
                                gk[ky * 3 + kx] += gv * xi_v;
                                dxi[iy as usize * w + ix as usize] += gv * k[ky * 3 + kx];
                            }
                        }
                    }
                }
            }
        }
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        f(&mut self.w, &mut self.gw);
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn last_grad(&self) -> Option<&Tensor> {
        self.last_g.as_ref()
    }

    fn quantizes_grads(&self) -> bool {
        true
    }

    fn visit_controllers(&mut self, f: &mut dyn FnMut(&str, &mut LayerControllers)) {
        if let Some(ctl) = self.ctl.as_mut() {
            f(&self.name, ctl);
        }
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        let (sw, sx) = match &self.ctl {
            None => (None, None),
            Some(ctl) => (Some(ctl.w.format()), Some(ctl.x.format())),
        };
        out.push(crate::serve::InferOp::Depthwise {
            name: self.name.clone(),
            c: self.c,
            in_h: self.in_h,
            in_w: self.in_w,
            stride: self.stride,
            w: self.w.clone(),
            sw,
            sx,
        });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::QuantMode;
    use crate::util::Pcg32;

    #[test]
    fn conv_backward_matches_finite_difference() {
        let mut rng = Pcg32::seeded(0);
        let g = Conv2dGeom { in_c: 2, out_c: 3, kh: 3, kw: 3, stride: 1, pad: 1 };
        let mut l = Conv2d::new("c", g, 5, 5, QuantMode::Float32, &mut rng);
        let mut x = Tensor::zeros(&[1, 2 * 5 * 5]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = l.forward(&x, &mut ctx);
        let gup = Tensor::filled(&y.shape.clone(), 1.0);
        let dx = l.backward(&gup, &mut ctx);
        let eps = 1e-3f32;
        for idx in [0usize, 7, 30, 49] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let yp = l.forward(&xp, &mut ctx).sum();
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let ym = l.forward(&xm, &mut ctx).sum();
            let fd = ((yp - ym) / (2.0 * eps as f64)) as f32;
            assert!((dx.data[idx] - fd).abs() < 2e-2, "idx={idx}: {} vs {fd}", dx.data[idx]);
        }
    }

    #[test]
    fn conv_weight_grad_matches_finite_difference() {
        let mut rng = Pcg32::seeded(1);
        let g = Conv2dGeom { in_c: 1, out_c: 2, kh: 3, kw: 3, stride: 1, pad: 0 };
        let mut l = Conv2d::new("c", g, 4, 4, QuantMode::Float32, &mut rng);
        let mut x = Tensor::zeros(&[2, 16]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = l.forward(&x, &mut ctx);
        let gup = Tensor::filled(&y.shape.clone(), 1.0);
        let _ = l.backward(&gup, &mut ctx);
        let gw = l.gw.clone();
        let eps = 1e-3f32;
        for idx in [0usize, 5, 17] {
            l.gw.data.fill(0.0);
            l.w.data[idx] += eps;
            let yp = l.forward(&x, &mut ctx).sum();
            l.w.data[idx] -= 2.0 * eps;
            let ym = l.forward(&x, &mut ctx).sum();
            l.w.data[idx] += eps;
            let fd = ((yp - ym) / (2.0 * eps as f64)) as f32;
            assert!((gw.data[idx] - fd).abs() < 2e-2, "idx={idx}: {} vs {fd}", gw.data[idx]);
        }
    }

    #[test]
    fn depthwise_backward_matches_finite_difference() {
        let mut rng = Pcg32::seeded(2);
        let mut l = DepthwiseConv2d::new("dw", 2, 5, 5, 1, QuantMode::Float32, &mut rng);
        let mut x = Tensor::zeros(&[1, 2 * 25]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let y = l.forward(&x, &mut ctx);
        let gup = Tensor::filled(&y.shape.clone(), 1.0);
        let dx = l.backward(&gup, &mut ctx);
        let eps = 1e-3f32;
        for idx in [0usize, 12, 26, 49] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let yp = l.forward(&xp, &mut ctx).sum();
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let ym = l.forward(&xm, &mut ctx).sum();
            let fd = ((yp - ym) / (2.0 * eps as f64)) as f32;
            assert!((dx.data[idx] - fd).abs() < 2e-2, "idx={idx}: {} vs {fd}", dx.data[idx]);
        }
    }

    #[test]
    fn quantized_conv_close_to_f32_conv() {
        let mut rng = Pcg32::seeded(3);
        let g = Conv2dGeom { in_c: 2, out_c: 4, kh: 3, kw: 3, stride: 1, pad: 1 };
        let mut lf = Conv2d::new("cf", g, 6, 6, QuantMode::Float32, &mut rng);
        let mut lq = Conv2d::new("cq", g, 6, 6, QuantMode::Static(16), &mut rng);
        lq.w = lf.w.clone();
        let mut x = Tensor::zeros(&[1, 2 * 36]);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        let yf = lf.forward(&x, &mut ctx);
        let yq = lq.forward(&x, &mut ctx);
        let rel: f32 = yf
            .data
            .iter()
            .zip(&yq.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / yf.data.iter().map(|v| v.abs()).sum::<f32>();
        assert!(rel < 0.01, "int16 conv deviates {rel}");
    }
}
