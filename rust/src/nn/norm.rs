//! Batch normalization over channels of NCHW activations carried as
//! [n, c*h*w]. Kept in f32 (the paper quantizes only GEMM operands); needed
//! for the ResNet/Inception/MobileNet mini architectures to train.
//!
//! The saved normalized activation x̂ — the layer's one per-sample backward
//! tensor — routes through the `TrainCtx` stash (`<name>/xhat`); the
//! per-channel 1/σ vector is c floats of derived statistics and stays
//! in-layer.

use super::{Layer, TrainCtx};
use crate::mem::StashHandle;
use crate::tensor::Tensor;

pub struct BatchNorm2d {
    name: String,
    pub c: usize,
    pub hw: usize,
    pub gamma: Tensor,
    pub beta: Tensor,
    pub ggamma: Tensor,
    pub gbeta: Tensor,
    pub running_mean: Vec<f32>,
    pub running_var: Vec<f32>,
    pub momentum: f32,
    eps: f32,
    // stash site for x̂; the tiny per-channel 1/σ stays a field
    h_xhat: StashHandle,
    inv_std: Vec<f32>,
}

impl BatchNorm2d {
    pub fn new(name: &str, c: usize, hw: usize) -> Self {
        BatchNorm2d {
            name: name.to_string(),
            c,
            hw,
            gamma: Tensor::filled(&[c], 1.0),
            beta: Tensor::zeros(&[c]),
            ggamma: Tensor::zeros(&[c]),
            gbeta: Tensor::zeros(&[c]),
            running_mean: vec![0.0; c],
            running_var: vec![1.0; c],
            momentum: 0.1,
            eps: 1e-5,
            h_xhat: StashHandle::new(name, "xhat"),
            inv_std: vec![],
        }
    }
}

impl Layer for BatchNorm2d {
    fn forward(&mut self, x: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = x.dim(0);
        let (c, hw) = (self.c, self.hw);
        assert_eq!(x.dim(1), c * hw);
        let cnt = (n * hw) as f32;
        let mut y = x.clone();
        if ctx.training {
            self.inv_std = vec![0.0; c];
            let mut xhat = x.clone();
            for ch in 0..c {
                let mut mean = 0.0f32;
                for img in 0..n {
                    mean += x.data[img * c * hw + ch * hw..][..hw].iter().sum::<f32>();
                }
                mean /= cnt;
                let mut var = 0.0f32;
                for img in 0..n {
                    for &v in &x.data[img * c * hw + ch * hw..][..hw] {
                        var += (v - mean) * (v - mean);
                    }
                }
                var /= cnt;
                let istd = 1.0 / (var + self.eps).sqrt();
                self.inv_std[ch] = istd;
                self.running_mean[ch] =
                    (1.0 - self.momentum) * self.running_mean[ch] + self.momentum * mean;
                self.running_var[ch] =
                    (1.0 - self.momentum) * self.running_var[ch] + self.momentum * var;
                let (g, b) = (self.gamma.data[ch], self.beta.data[ch]);
                for img in 0..n {
                    for i in 0..hw {
                        let idx = img * c * hw + ch * hw + i;
                        let xh = (x.data[idx] - mean) * istd;
                        xhat.data[idx] = xh;
                        y.data[idx] = g * xh + b;
                    }
                }
            }
            ctx.stash.put(&self.h_xhat, xhat, ctx.iter, &mut ctx.ledger);
        } else {
            for ch in 0..c {
                let istd = 1.0 / (self.running_var[ch] + self.eps).sqrt();
                let mean = self.running_mean[ch];
                let (g, b) = (self.gamma.data[ch], self.beta.data[ch]);
                for img in 0..n {
                    for i in 0..hw {
                        let idx = img * c * hw + ch * hw + i;
                        y.data[idx] = g * (x.data[idx] - mean) * istd + b;
                    }
                }
            }
        }
        y
    }

    fn backward(&mut self, g: &Tensor, ctx: &mut TrainCtx) -> Tensor {
        let n = g.dim(0);
        let (c, hw) = (self.c, self.hw);
        let cnt = (n * hw) as f32;
        let xhat = ctx.stash.take(&self.h_xhat);
        let mut dx = Tensor::zeros(&[n, c * hw]);
        for ch in 0..c {
            let mut sum_g = 0.0f32;
            let mut sum_gx = 0.0f32;
            for img in 0..n {
                for i in 0..hw {
                    let idx = img * c * hw + ch * hw + i;
                    sum_g += g.data[idx];
                    sum_gx += g.data[idx] * xhat.data[idx];
                }
            }
            self.gbeta.data[ch] += sum_g;
            self.ggamma.data[ch] += sum_gx;
            let gamma = self.gamma.data[ch];
            let istd = self.inv_std[ch];
            for img in 0..n {
                for i in 0..hw {
                    let idx = img * c * hw + ch * hw + i;
                    dx.data[idx] = gamma * istd / cnt
                        * (cnt * g.data[idx] - sum_g - xhat.data[idx] * sum_gx);
                }
            }
        }
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &mut Tensor)) {
        f(&mut self.gamma, &mut self.ggamma);
        f(&mut self.beta, &mut self.gbeta);
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn visit_state(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.running_mean);
        f(&mut self.running_var);
    }

    fn export_infer(&self, out: &mut Vec<crate::serve::InferOp>) -> bool {
        // Fold the running stats: istd carries the per-channel sqrt so the
        // serving pass is a pure affine, computed with the exact expression
        // of the eval branch above (bit-identical).
        let istd: Vec<f32> = self
            .running_var
            .iter()
            .map(|&v| 1.0 / (v + self.eps).sqrt())
            .collect();
        out.push(crate::serve::InferOp::BnEval {
            c: self.c,
            hw: self.hw,
            gamma: self.gamma.data.clone(),
            beta: self.beta.data.clone(),
            mean: self.running_mean.clone(),
            istd,
        });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn normalizes_per_channel() {
        let mut bn = BatchNorm2d::new("bn", 2, 4);
        let mut x = Tensor::zeros(&[3, 8]);
        let mut rng = Pcg32::seeded(0);
        rng.fill_normal(&mut x.data, 5.0);
        for v in x.data.iter_mut() {
            *v += 10.0;
        }
        let mut ctx = TrainCtx::new();
        let y = bn.forward(&x, &mut ctx);
        // per channel over batch: mean ≈ 0, var ≈ 1
        for ch in 0..2 {
            let mut vals = vec![];
            for img in 0..3 {
                vals.extend_from_slice(&y.data[img * 8 + ch * 4..][..4]);
            }
            let m: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let v: f32 = vals.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / vals.len() as f32;
            assert!(m.abs() < 1e-4, "mean {m}");
            assert!((v - 1.0).abs() < 1e-2, "var {v}");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut bn = BatchNorm2d::new("bn", 1, 3);
        let mut x = Tensor::zeros(&[2, 3]);
        let mut rng = Pcg32::seeded(1);
        rng.fill_normal(&mut x.data, 1.0);
        let mut ctx = TrainCtx::new();
        // loss = Σ y² /2 → g = y
        let y = bn.forward(&x, &mut ctx);
        let dx = bn.backward(&y, &mut ctx);
        let eps = 1e-3f32;
        let loss = |bn: &mut BatchNorm2d, x: &Tensor, ctx: &mut TrainCtx| -> f64 {
            let y = bn.forward(x, ctx);
            y.data.iter().map(|&v| (v * v / 2.0) as f64).sum()
        };
        for idx in 0..6 {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let lp = loss(&mut bn, &xp, &mut ctx);
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let lm = loss(&mut bn, &xm, &mut ctx);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!((dx.data[idx] - fd).abs() < 1e-2, "idx={idx}: {} vs {fd}", dx.data[idx]);
        }
    }

    #[test]
    fn eval_mode_uses_running_stats() {
        let mut bn = BatchNorm2d::new("bn", 1, 2);
        let mut ctx = TrainCtx::new();
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        for _ in 0..50 {
            let _ = bn.forward(&x, &mut ctx);
        }
        ctx.training = false;
        let y_eval = bn.forward(&x, &mut ctx);
        // running stats converge to batch stats → eval ≈ train output
        ctx.training = true;
        let y_train = bn.forward(&x, &mut ctx);
        for (a, b) in y_eval.data.iter().zip(&y_train.data) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }
}
