//! Frozen inference models (DESIGN.md §Serving).
//!
//! A [`FrozenModel`] is the deployment form of a trained network: the layer
//! stack is exported once into a flat list of forward-only ops
//! ([`InferOp`], produced by `nn::Layer::export_infer`), batch-norm running
//! statistics are folded into per-channel affine coefficients, and the
//! weights of every quantized GEMM are converted **once** into int8/int16
//! codes (int8 weights pre-packed into the transposed BT layout the VNNI
//! kernels consume). Serving then runs integer GEMMs + one rescale per
//! layer through the [`crate::kernels::Engine`] — no gradient buffers, no
//! QEM/QPA controller probes, no training caches.
//!
//! **Parity contract.** With 8-bit schemes the integer serving path is
//! *bit-identical* to `train::Session::eval` whenever every GEMM's depth
//! satisfies `k · 2¹⁴ < 2²⁴` (k ≤ 1024): all products and partial sums are
//! then exact in both the fake-quant f32 reference and the i32 accumulator,
//! so the two paths compute the same reals. Every model in the zoo is far
//! under the bound; `rust/tests/test_serve.rs` pins the property. 16-bit
//! schemes exceed f32's 24-bit mantissa in the reference path, so int16
//! serving agrees only to float rounding (the integer path is the *more*
//! exact of the two).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::fixedpoint::conv::{im2col, Conv2dGeom};
use crate::fixedpoint::gemm_simd;
use crate::fixedpoint::quantize;
use crate::fixedpoint::Scheme;
use crate::kernels::Engine;
use crate::nn::{models, QuantMode, Sequential};
use crate::tensor::Tensor;
use crate::train::checkpoint::Checkpoint;
use crate::util::Pcg32;

/// One forward-only primitive exported by an `nn` layer for serving
/// (DESIGN.md §Serving). Composite blocks lower to several ops around the
/// small value-stack ops ([`InferOp::Push`] / [`InferOp::Swap`] /
/// [`InferOp::AddPopRelu`] / [`InferOp::ConcatPop`]).
pub enum InferOp {
    /// Fully-connected `y = x̂·Ŵ + b`; schemes are present iff the layer
    /// trained quantized.
    Linear {
        /// Layer name (diagnostics only).
        name: String,
        /// Weight matrix, `din × dout` row-major.
        w: Tensor,
        /// Bias, length `dout`.
        b: Vec<f32>,
        /// Frozen weight scheme (from the layer's W controller).
        sw: Option<Scheme>,
        /// Frozen activation scheme (from the layer's X controller).
        sx: Option<Scheme>,
    },
    /// im2col convolution with the training-time geometry.
    Conv {
        /// Layer name (diagnostics only).
        name: String,
        /// Convolution geometry (channels, kernel, stride, padding).
        geom: Conv2dGeom,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Weights, `out_c × (in_c·kh·kw)` row-major.
        w: Tensor,
        /// Per-output-channel bias.
        b: Vec<f32>,
        /// Frozen weight scheme.
        sw: Option<Scheme>,
        /// Frozen activation (patch) scheme.
        sx: Option<Scheme>,
    },
    /// Depthwise 3×3 convolution (scalar kernel; quantization applies as
    /// fake-quant, matching training).
    Depthwise {
        /// Layer name (diagnostics only).
        name: String,
        /// Channel count.
        c: usize,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Stride.
        stride: usize,
        /// Per-channel 3×3 kernels, `c × 9`.
        w: Tensor,
        /// Frozen weight scheme.
        sw: Option<Scheme>,
        /// Frozen activation scheme.
        sx: Option<Scheme>,
    },
    /// Elementwise `max(0, x)`.
    Relu,
    /// 2×2 stride-2 max pool over `[n, c·h·w]`.
    MaxPool {
        /// Channels.
        c: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// Global average pool `[n, c·h·w] → [n, c]`.
    GlobalAvgPool {
        /// Channels.
        c: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// Batch-norm running statistics folded for evaluation:
    /// `y = γ·(x−μ)·istd + β` with `istd = 1/√(σ²+ε)` precomputed per
    /// channel (the expensive part of the eval pass — no sqrt at serve
    /// time, and bit-identical to `BatchNorm2d`'s eval branch).
    BnEval {
        /// Channels.
        c: usize,
        /// Spatial size per channel (`h·w`).
        hw: usize,
        /// Scale γ per channel.
        gamma: Vec<f32>,
        /// Shift β per channel.
        beta: Vec<f32>,
        /// Running mean μ per channel.
        mean: Vec<f32>,
        /// Folded inverse stddev `1/√(σ²+ε)` per channel.
        istd: Vec<f32>,
    },
    /// Save (duplicate) the current activation on the value stack —
    /// residual/branch entry.
    Push,
    /// Swap the current activation with the stack top — second-branch
    /// entry (the saved input becomes current again).
    Swap,
    /// Pop the saved tensor, add it to the current activation, then ReLU —
    /// residual exit (`relu(F(x) + x)`).
    AddPopRelu,
    /// Pop the saved tensor and channel-concatenate `[popped ; current]` —
    /// branch merge (Inception).
    ConcatPop {
        /// Channels of the popped (first) tensor.
        c_pop: usize,
        /// Channels of the current (second) tensor.
        c_cur: usize,
        /// Spatial size per channel.
        hw: usize,
    },
}

/// Pre-quantized weight form of one frozen linear layer.
enum LinKind {
    /// Unquantized f32 weights (`din × dout`).
    F32 { w: Tensor },
    /// int8 codes, pre-packed transposed (BT) with per-column sums for the
    /// VNNI bias trick.
    I8 { bt: Vec<i8>, colsum: Vec<i32>, sw: Scheme, sx: Scheme },
    /// int16 codes, pre-packed transposed.
    I16 { bt: Vec<i16>, sw: Scheme, sx: Scheme },
    /// Wider-than-16-bit scheme: pre-fake-quantized f32 weights, f32 GEMM.
    Fq { wq: Tensor, sx: Scheme },
}

struct ExecLinear {
    din: usize,
    dout: usize,
    b: Vec<f32>,
    kind: LinKind,
}

/// Pre-quantized weight form of one frozen convolution.
enum ConvKind {
    F32 { w: Vec<f32> },
    I8 { cw: Vec<i8>, sw: Scheme, sx: Scheme },
    I16 { cw: Vec<i16>, sw: Scheme, sx: Scheme },
    Fq { wq: Vec<f32>, sx: Scheme },
}

struct ExecConv {
    geom: Conv2dGeom,
    in_h: usize,
    in_w: usize,
    b: Vec<f32>,
    kind: ConvKind,
}

struct ExecDw {
    c: usize,
    in_h: usize,
    in_w: usize,
    stride: usize,
    /// Pre-fake-quantized (or plain f32) kernels, `c × 9`.
    wq: Vec<f32>,
    sx: Option<Scheme>,
}

enum ExecOp {
    Linear(ExecLinear),
    Conv(ExecConv),
    Depthwise(ExecDw),
    Relu,
    MaxPool { c: usize, h: usize, w: usize },
    Gap { c: usize, h: usize, w: usize },
    Bn { c: usize, hw: usize, gamma: Vec<f32>, beta: Vec<f32>, mean: Vec<f32>, istd: Vec<f32> },
    Push,
    Swap,
    AddPopRelu,
    ConcatPop { c_pop: usize, c_cur: usize, hw: usize },
}

/// A trained network frozen for serving: forward-only op list with
/// pre-quantized weights and folded batch-norm statistics. Immutable after
/// construction — [`forward`](FrozenModel::forward) takes `&self`, so one
/// model is shared by every [`crate::serve::InferenceServer`] worker behind
/// an `Arc` with no locking.
pub struct FrozenModel {
    label: String,
    precision: String,
    din: usize,
    ops: Vec<ExecOp>,
}

impl FrozenModel {
    /// Freeze a live network (e.g. `session.net()` right after training).
    /// Errors if any layer has no forward-only serving export.
    pub fn freeze(label: impl Into<String>, net: &Sequential) -> Result<FrozenModel> {
        let ops = net.export_infer()?;
        Self::compile(label.into(), ops)
    }

    /// Load a `train::checkpoint` file and freeze it: rebuilds the named
    /// model-zoo architecture under `mode`, restores parameters, controller
    /// schemes and batch-norm running stats from the checkpoint, and
    /// pre-quantizes the weights. This is the train→deploy hand-off: the
    /// checkpoint must come from a session built with the same
    /// `(model, mode)` pair (shapes are verified during restore).
    pub fn from_checkpoint(
        path: impl AsRef<Path>,
        model: &str,
        mode: QuantMode,
    ) -> Result<FrozenModel> {
        // `read` already contextualizes I/O errors with the path.
        let ck = Checkpoint::read(path.as_ref())?;
        // Parameters are overwritten by the restore; the init seed is moot.
        let mut rng = Pcg32::seeded(0);
        let mut net = models::by_name(model, mode, &mut rng)
            .ok_or_else(|| anyhow!("unknown model {model:?}"))?;
        ck.restore_net(&mut net)?;
        Self::freeze(format!("{model}-{}", mode.label()), &net)
    }

    fn compile(label: String, ops: Vec<InferOp>) -> Result<FrozenModel> {
        let din = match ops.first() {
            Some(InferOp::Linear { w, .. }) => w.dim(0),
            Some(InferOp::Conv { geom, in_h, in_w, .. }) => geom.in_c * in_h * in_w,
            Some(InferOp::Depthwise { c, in_h, in_w, .. }) => c * in_h * in_w,
            _ => return Err(anyhow!("cannot infer input width: model must start with a linear/conv layer")),
        };
        // Validate value-stack discipline at freeze time, so a malformed
        // export (hand-built op list, future layer bug) fails here with a
        // useful error instead of panicking inside a serve worker mid-batch.
        {
            let mut depth = 0usize;
            for (i, op) in ops.iter().enumerate() {
                let (need, delta): (usize, isize) = match op {
                    InferOp::Push => (0, 1),
                    InferOp::Swap => (1, 0),
                    InferOp::AddPopRelu | InferOp::ConcatPop { .. } => (1, -1),
                    _ => (0, 0),
                };
                if depth < need {
                    return Err(anyhow!(
                        "op {i} of {label} underflows the serve value stack (depth {depth})"
                    ));
                }
                depth = (depth as isize + delta) as usize;
            }
            if depth != 0 {
                return Err(anyhow!(
                    "{label} leaves {depth} unconsumed tensor(s) on the serve value stack"
                ));
            }
        }
        let mut max_bits: Option<u8> = None;
        let mut note = |sw: &Option<Scheme>, sx: &Option<Scheme>| {
            for s in [sw, sx].into_iter().flatten() {
                max_bits = Some(max_bits.map_or(s.bits, |m| m.max(s.bits)));
            }
        };
        let mut exec = Vec::with_capacity(ops.len());
        for op in ops {
            exec.push(match op {
                InferOp::Linear { w, b, sw, sx, .. } => {
                    note(&sw, &sx);
                    let (din_l, dout) = (w.dim(0), w.dim(1));
                    let kind = match (sw, sx) {
                        (Some(sw), Some(sx)) if sw.bits <= 8 && sx.bits <= 8 => {
                            let mut bt = vec![0i8; w.len()];
                            let mut colsum = vec![0i32; dout];
                            gemm_simd::codes_i8_bt(din_l, dout, &w.data, sw, &mut bt, &mut colsum);
                            LinKind::I8 { bt, colsum, sw, sx }
                        }
                        (Some(sw), Some(sx)) if sw.bits <= 16 && sx.bits <= 16 => {
                            let mut cb = vec![0i16; w.len()];
                            quantize::codes_i16(&w.data, &mut cb, sw);
                            let mut bt = vec![0i16; w.len()];
                            gemm_simd::pack_bt_i16(din_l, dout, &cb, &mut bt);
                            LinKind::I16 { bt, sw, sx }
                        }
                        (Some(sw), Some(sx)) => {
                            let mut wq = w.clone();
                            quantize::fake_quant_stats_inplace(&mut wq.data, sw);
                            LinKind::Fq { wq, sx }
                        }
                        _ => LinKind::F32 { w },
                    };
                    ExecOp::Linear(ExecLinear { din: din_l, dout, b, kind })
                }
                InferOp::Conv { geom, in_h, in_w, w, b, sw, sx, .. } => {
                    note(&sw, &sx);
                    let kind = match (sw, sx) {
                        (Some(sw), Some(sx)) if sw.bits <= 8 && sx.bits <= 8 => {
                            let mut cw = vec![0i8; w.len()];
                            quantize::codes_i8(&w.data, &mut cw, sw);
                            ConvKind::I8 { cw, sw, sx }
                        }
                        (Some(sw), Some(sx)) if sw.bits <= 16 && sx.bits <= 16 => {
                            let mut cw = vec![0i16; w.len()];
                            quantize::codes_i16(&w.data, &mut cw, sw);
                            ConvKind::I16 { cw, sw, sx }
                        }
                        (Some(sw), Some(sx)) => {
                            let mut wq = w.data.clone();
                            quantize::fake_quant_stats_inplace(&mut wq, sw);
                            ConvKind::Fq { wq, sx }
                        }
                        _ => ConvKind::F32 { w: w.data },
                    };
                    ExecOp::Conv(ExecConv { geom, in_h, in_w, b, kind })
                }
                InferOp::Depthwise { c, in_h, in_w, stride, w, sw, sx, .. } => {
                    note(&sw, &sx);
                    let mut wq = w.data;
                    if let Some(sw) = sw {
                        quantize::fake_quant_stats_inplace(&mut wq, sw);
                    }
                    ExecOp::Depthwise(ExecDw { c, in_h, in_w, stride, wq, sx })
                }
                InferOp::Relu => ExecOp::Relu,
                InferOp::MaxPool { c, h, w } => ExecOp::MaxPool { c, h, w },
                InferOp::GlobalAvgPool { c, h, w } => ExecOp::Gap { c, h, w },
                InferOp::BnEval { c, hw, gamma, beta, mean, istd } => {
                    ExecOp::Bn { c, hw, gamma, beta, mean, istd }
                }
                InferOp::Push => ExecOp::Push,
                InferOp::Swap => ExecOp::Swap,
                InferOp::AddPopRelu => ExecOp::AddPopRelu,
                InferOp::ConcatPop { c_pop, c_cur, hw } => ExecOp::ConcatPop { c_pop, c_cur, hw },
            });
        }
        let precision = match max_bits {
            None => "f32".to_string(),
            Some(b) if b <= 8 => "int8".to_string(),
            Some(b) if b <= 16 => "int16".to_string(),
            Some(b) => format!("int{b}"),
        };
        Ok(FrozenModel { label, precision, din, ops })
    }

    /// Display label (`"<model>-<mode>"` when built from a checkpoint).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Serving precision derived from the frozen forward schemes:
    /// `"f32"`, `"int8"` or `"int16"` (the widest scheme wins).
    pub fn precision(&self) -> &str {
        &self.precision
    }

    /// Flattened per-sample input width the model expects.
    pub fn input_len(&self) -> usize {
        self.din
    }

    /// Forward a batch `[n, input_len]` → logits `[n, classes]`. Pure:
    /// takes `&self`, so concurrent callers need no synchronization. Rows
    /// are computed independently, so a sample's logits do not depend on
    /// what it was batched with (the micro-batching invariant).
    pub fn forward(&self, x: &Tensor, eng: &Engine) -> Tensor {
        assert_eq!(x.rank(), 2, "frozen forward expects [n, d] input");
        assert_eq!(x.dim(1), self.din, "input width {} ≠ model width {}", x.dim(1), self.din);
        let mut cur = x.clone();
        let mut stack: Vec<Tensor> = Vec::new();
        for op in &self.ops {
            cur = apply(op, cur, &mut stack, eng);
        }
        cur
    }

    /// Forward one flattened sample; returns its logits.
    pub fn forward_one(&self, x: &[f32], eng: &Engine) -> Vec<f32> {
        let t = Tensor::from_vec(&[1, x.len()], x.to_vec());
        self.forward(&t, eng).data
    }
}

fn apply(op: &ExecOp, cur: Tensor, stack: &mut Vec<Tensor>, eng: &Engine) -> Tensor {
    match op {
        ExecOp::Linear(l) => exec_linear(l, &cur, eng),
        ExecOp::Conv(cv) => exec_conv(cv, &cur, eng),
        ExecOp::Depthwise(dw) => exec_depthwise(dw, &cur),
        ExecOp::Relu => {
            let mut y = cur;
            y.map_inplace(|v| v.max(0.0));
            y
        }
        ExecOp::MaxPool { c, h, w } => exec_maxpool(*c, *h, *w, &cur),
        ExecOp::Gap { c, h, w } => exec_gap(*c, *h, *w, &cur),
        ExecOp::Bn { c, hw, gamma, beta, mean, istd } => {
            let mut y = cur;
            let n = y.dim(0);
            for ch in 0..*c {
                let (g, b) = (gamma[ch], beta[ch]);
                let (m, is) = (mean[ch], istd[ch]);
                for img in 0..n {
                    for i in 0..*hw {
                        let idx = img * c * hw + ch * hw + i;
                        let v = y.data[idx];
                        y.data[idx] = g * (v - m) * is + b;
                    }
                }
            }
            y
        }
        // Stack discipline is verified by `compile` at freeze time, so the
        // pops/peeks below cannot underflow on any constructible model.
        ExecOp::Push => {
            stack.push(cur.clone());
            cur
        }
        ExecOp::Swap => {
            let mut cur = cur;
            let top = stack.last_mut().expect("serve stack underflow (Swap)");
            std::mem::swap(top, &mut cur);
            cur
        }
        ExecOp::AddPopRelu => {
            let saved = stack.pop().expect("serve stack underflow (AddPopRelu)");
            let mut h = cur;
            h.add_inplace(&saved);
            h.map_inplace(|v| v.max(0.0));
            h
        }
        ExecOp::ConcatPop { c_pop, c_cur, hw } => {
            let first = stack.pop().expect("serve stack underflow (ConcatPop)");
            let n = cur.dim(0);
            let (c1, c3, hw) = (*c_pop, *c_cur, *hw);
            let mut out = Tensor::zeros(&[n, (c1 + c3) * hw]);
            for img in 0..n {
                out.data[img * (c1 + c3) * hw..][..c1 * hw]
                    .copy_from_slice(&first.data[img * c1 * hw..][..c1 * hw]);
                out.data[img * (c1 + c3) * hw + c1 * hw..][..c3 * hw]
                    .copy_from_slice(&cur.data[img * c3 * hw..][..c3 * hw]);
            }
            out
        }
    }
}

fn exec_linear(l: &ExecLinear, x: &Tensor, eng: &Engine) -> Tensor {
    let m = x.dim(0);
    assert_eq!(x.dim(1), l.din, "linear input width");
    match &l.kind {
        LinKind::F32 { w } => {
            let mut y = x.matmul_with(w, eng);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::Fq { wq, sx } => {
            let mut xq = x.clone();
            eng.fake_quant_stats(&mut xq.data, *sx);
            let mut y = xq.matmul_with(wq, eng);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::I8 { bt, colsum, sw, sx } => {
            let mut ca = vec![0i8; x.len()];
            eng.codes_i8(&x.data, &mut ca, *sx);
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i8_prepacked(m, l.din, l.dout, &ca, bt, colsum, &mut acc);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::I16 { bt, sw, sx } => {
            let mut ca = vec![0i16; x.len()];
            eng.codes_i16(&x.data, &mut ca, *sx);
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i16_prepacked(m, l.din, l.dout, &ca, bt, &mut acc);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y.add_row_bias(&l.b);
            y
        }
    }
}

fn exec_conv(cv: &ExecConv, x: &Tensor, eng: &Engine) -> Tensor {
    let n = x.dim(0);
    let g = cv.geom;
    let (h, w) = (cv.in_h, cv.in_w);
    assert_eq!(x.dim(1), g.in_c * h * w, "conv input size");
    let (rows, cols) = g.im2col_dims(h, w);
    let (oh, ow) = g.out_hw(h, w);
    let mut out = Tensor::zeros(&[n, g.out_c * oh * ow]);
    // Per-image scratch, hoisted out of the hot loop (sizes are
    // loop-invariant; every pass fully overwrites its buffer).
    let mut patch = vec![0.0f32; rows * cols];
    let (mut cp8, mut cp16, mut acc) = (Vec::new(), Vec::new(), Vec::new());
    match &cv.kind {
        ConvKind::I8 { .. } => {
            cp8 = vec![0i8; rows * cols];
            acc = vec![0i32; g.out_c * cols];
        }
        ConvKind::I16 { .. } => {
            cp16 = vec![0i16; rows * cols];
            acc = vec![0i32; g.out_c * cols];
        }
        _ => {}
    }
    for img in 0..n {
        let xi = &x.data[img * g.in_c * h * w..(img + 1) * g.in_c * h * w];
        im2col(g, h, w, xi, &mut patch);
        let co = &mut out.data[img * g.out_c * cols..(img + 1) * g.out_c * cols];
        match &cv.kind {
            ConvKind::F32 { w } => eng.gemm_f32(g.out_c, rows, cols, w, &patch, co),
            ConvKind::Fq { wq, sx } => {
                eng.fake_quant_stats(&mut patch, *sx);
                eng.gemm_f32(g.out_c, rows, cols, wq, &patch, co);
            }
            ConvKind::I8 { cw, sw, sx } => {
                eng.codes_i8(&patch, &mut cp8, *sx);
                eng.gemm_i8(g.out_c, rows, cols, cw, &cp8, &mut acc);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), co);
            }
            ConvKind::I16 { cw, sw, sx } => {
                eng.codes_i16(&patch, &mut cp16, *sx);
                eng.gemm_i16(g.out_c, rows, cols, cw, &cp16, &mut acc);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), co);
            }
        }
        for oc in 0..g.out_c {
            let bv = cv.b[oc];
            for v in co[oc * cols..(oc + 1) * cols].iter_mut() {
                *v += bv;
            }
        }
    }
    out
}

fn exec_depthwise(dw: &ExecDw, x: &Tensor) -> Tensor {
    let n = x.dim(0);
    let (c, h, w, stride) = (dw.c, dw.in_h, dw.in_w, dw.stride);
    assert_eq!(x.dim(1), c * h * w, "depthwise input size");
    let (oh, ow) = ((h + 2 - 3) / stride + 1, (w + 2 - 3) / stride + 1);
    let xq = match dw.sx {
        None => x.clone(),
        Some(sx) => {
            let mut xq = x.clone();
            quantize::fake_quant_stats_inplace(&mut xq.data, sx);
            xq
        }
    };
    let mut out = Tensor::zeros(&[n, c * oh * ow]);
    for img in 0..n {
        for ch in 0..c {
            let xi = &xq.data[img * c * h * w + ch * h * w..][..h * w];
            let k = &dw.wq[ch * 9..(ch + 1) * 9];
            let oi = &mut out.data[img * c * oh * ow + ch * oh * ow..][..oh * ow];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ky in 0..3 {
                        let iy = (oy * stride + ky) as isize - 1;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..3 {
                            let ix = (ox * stride + kx) as isize - 1;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc += k[ky * 3 + kx] * xi[iy as usize * w + ix as usize];
                        }
                    }
                    oi[oy * ow + ox] = acc;
                }
            }
        }
    }
    out
}

fn exec_maxpool(c: usize, h: usize, w: usize, x: &Tensor) -> Tensor {
    let n = x.dim(0);
    assert_eq!(x.dim(1), c * h * w, "maxpool input size");
    let (oh, ow) = (h / 2, w / 2);
    let mut y = Tensor::zeros(&[n, c * oh * ow]);
    for img in 0..n {
        for ch in 0..c {
            let xi = &x.data[img * c * h * w + ch * h * w..][..h * w];
            let base_o = img * c * oh * ow + ch * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx = (2 * oy + dy) * w + 2 * ox + dx;
                            if xi[idx] > best {
                                best = xi[idx];
                            }
                        }
                    }
                    y.data[base_o + oy * ow + ox] = best;
                }
            }
        }
    }
    y
}

fn exec_gap(c: usize, h: usize, w: usize, x: &Tensor) -> Tensor {
    let n = x.dim(0);
    let hw = h * w;
    assert_eq!(x.dim(1), c * hw, "global-pool input size");
    let mut y = Tensor::zeros(&[n, c]);
    for img in 0..n {
        for ch in 0..c {
            let s: f32 = x.data[img * c * hw + ch * hw..][..hw].iter().sum();
            y.data[img * c + ch] = s / hw as f32;
        }
    }
    y
}
