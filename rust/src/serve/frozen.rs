//! Frozen inference models (DESIGN.md §Serving, §Inference-Compiler).
//!
//! A [`FrozenModel`] is the deployment form of a trained network: the layer
//! stack is exported once into a flat list of forward-only ops
//! ([`InferOp`], produced by `nn::Layer::export_infer`) and handed to the
//! inference compiler ([`crate::compiler`]), which validates the op list,
//! pre-quantizes every weight **once** (int8 weights pre-packed into the
//! transposed BT layout the VNNI kernels consume), fuses GEMM → BN →
//! ReLU → requantize chains into single steps that pass integer codes
//! between ops, and resolves per-shape GEMM tiles from the artifact's plan
//! cache (or a load-time search). Serving then runs the compiled plan
//! through the [`crate::kernels::Engine`] — no gradient buffers, no QEM/QPA
//! controller probes, no training caches. The unfused interpreter stays
//! available as the correctness oracle and behind `apt serve --no-fuse`.
//!
//! **Parity contract.** With 8-bit schemes the integer serving path is
//! *bit-identical* to `train::Session::eval` whenever every GEMM's depth
//! satisfies `k · 2¹⁴ < 2²⁴` (k ≤ 1024): all products and partial sums are
//! then exact in both the fake-quant f32 reference and the i32 accumulator,
//! so the two paths compute the same reals. Every model in the zoo is far
//! under the bound; `rust/tests/test_serve.rs` pins the property. 16-bit
//! schemes exceed f32's 24-bit mantissa in the reference path, so int16
//! serving agrees only to float rounding (the integer path is the *more*
//! exact of the two). Fused execution is additionally bit-identical to the
//! unfused interpreter — every fusion rewrite has an exactness argument
//! (DESIGN.md §Inference-Compiler) and `rust/tests/test_compiler.rs` pins
//! it per zoo model.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::compiler::{self, CompileOptions, CompileReport, StepTimer, TuneEntry};
use crate::kernels::Engine;
use crate::nn::{models, QuantMode, Sequential};
use crate::tensor::Tensor;
use crate::train::checkpoint::Checkpoint;
use crate::util::Pcg32;

pub use crate::compiler::InferOp;

/// A trained network frozen for serving: a compiled forward-only plan with
/// pre-quantized weights and folded batch-norm statistics. Immutable after
/// construction — [`forward`](FrozenModel::forward) takes `&self`, so one
/// model is shared by every [`crate::serve::InferenceServer`] worker behind
/// an `Arc` with no locking (the per-step timers are atomics).
pub struct FrozenModel {
    label: String,
    compiled: compiler::Compiled,
    timers: Vec<StepTimer>,
}

impl FrozenModel {
    /// Freeze a live network (e.g. `session.net()` right after training)
    /// with default compile options (fusion on, no load-time tile search).
    /// Errors if any layer has no forward-only serving export.
    pub fn freeze(label: impl Into<String>, net: &Sequential) -> Result<FrozenModel> {
        Self::freeze_with(label, net, &CompileOptions::default())
    }

    /// [`freeze`](FrozenModel::freeze) with explicit compile options —
    /// `fuse: false` keeps the unfused interpreter as the primary path.
    pub fn freeze_with(
        label: impl Into<String>,
        net: &Sequential,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        let ops = net.export_infer()?;
        Self::compile_ops(label.into(), ops, opts, &[])
    }

    /// Compile a hand-built op list. Exposed so tests (and future
    /// exporters) can exercise freeze-time validation directly: malformed
    /// value-stack programs fail here with the op index named, never at
    /// execution time inside a serve worker.
    pub fn from_infer_ops(
        label: impl Into<String>,
        ops: Vec<InferOp>,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        Self::compile_ops(label.into(), ops, opts, &[])
    }

    /// Load a `train::checkpoint` file and freeze it: rebuilds the named
    /// model-zoo architecture under `mode`, restores parameters, controller
    /// schemes and batch-norm running stats from the checkpoint, and
    /// pre-quantizes the weights. This is the train→deploy hand-off: the
    /// checkpoint must come from a session built with the same
    /// `(model, mode)` pair (shapes are verified during restore). Default
    /// compile options; any tile plan cached in the artifact is applied.
    pub fn from_checkpoint(
        path: impl AsRef<Path>,
        model: &str,
        mode: QuantMode,
    ) -> Result<FrozenModel> {
        Self::from_checkpoint_with(path, model, mode, &CompileOptions::default())
    }

    /// [`from_checkpoint`](FrozenModel::from_checkpoint) with explicit
    /// compile options. With `tune: true`, shapes missing from the
    /// artifact's plan cache are tile-searched at load time; persist
    /// [`tuned_tiles`](FrozenModel::tuned_tiles) back with
    /// `Checkpoint::write_tune_cache` so subsequent loads skip the search.
    pub fn from_checkpoint_with(
        path: impl AsRef<Path>,
        model: &str,
        mode: QuantMode,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        // `read` already contextualizes I/O errors with the path.
        let ck = Checkpoint::read(path.as_ref())?;
        // Parameters are overwritten by the restore; the init seed is moot.
        let mut rng = Pcg32::seeded(0);
        let mut net = models::by_name(model, mode, &mut rng)
            .ok_or_else(|| anyhow!("unknown model {model:?}"))?;
        ck.restore_net(&mut net)?;
        let ops = net.export_infer()?;
        Self::compile_ops(format!("{model}-{}", mode.label()), ops, opts, ck.tune_cache())
    }

    fn compile_ops(
        label: String,
        ops: Vec<InferOp>,
        opts: &CompileOptions,
        cache: &[TuneEntry],
    ) -> Result<FrozenModel> {
        let compiled = compiler::compile(&label, ops, opts, cache, crate::kernels::global())?;
        let timers = (0..compiled.n_steps()).map(|_| StepTimer::new()).collect();
        Ok(FrozenModel { label, compiled, timers })
    }

    /// Display label (`"<model>-<mode>"` when built from a checkpoint).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Serving precision derived from the frozen forward formats:
    /// `"f32"`, `"int8"` or `"int16"` for fixed-point (the widest scheme
    /// wins), a family label (`"e4m3"`, `"e5m2"`, `"int4"`) when the model
    /// trained in that family, or `"int4w"` under the weight-only int4
    /// compile override.
    pub fn precision(&self) -> &str {
        &self.compiled.precision
    }

    /// Flattened per-sample input width the model expects.
    pub fn input_len(&self) -> usize {
        self.compiled.din
    }

    /// Whether the primary execution path is the fused plan.
    pub fn fused(&self) -> bool {
        self.compiled.plan.is_some()
    }

    /// What the compile pass did: op/step counts, code edges, tile
    /// provenance, per-step labels.
    pub fn compile_report(&self) -> &CompileReport {
        &self.compiled.report
    }

    /// Tile decisions this model runs with (plan-cache hits + load-time
    /// search results) — persist with `Checkpoint::write_tune_cache`.
    pub fn tuned_tiles(&self) -> &[TuneEntry] {
        self.compiled.tuned()
    }

    /// Forward a batch `[n, input_len]` → logits `[n, classes]`. Pure:
    /// takes `&self`, so concurrent callers need no synchronization. Rows
    /// are computed independently, so a sample's logits do not depend on
    /// what it was batched with (the micro-batching invariant). Runs the
    /// fused plan when one was compiled, the unfused interpreter otherwise,
    /// and accumulates per-step wall-time into
    /// [`timing_report`](FrozenModel::timing_report).
    pub fn forward(&self, x: &Tensor, eng: &Engine) -> Tensor {
        self.check_input(x);
        self.compiled.run(x, eng, &self.timers)
    }

    /// Forward through the unfused reference interpreter regardless of the
    /// compiled plan — the oracle fused execution is pinned against (and
    /// the loser side of the fused-vs-unfused benchmarks). Does not touch
    /// the step timers.
    pub fn forward_unfused(&self, x: &Tensor, eng: &Engine) -> Tensor {
        self.check_input(x);
        self.compiled.run_unfused(x, eng)
    }

    fn check_input(&self, x: &Tensor) {
        assert_eq!(x.rank(), 2, "frozen forward expects [n, d] input");
        let din = self.compiled.din;
        assert_eq!(x.dim(1), din, "input width {} ≠ model width {}", x.dim(1), din);
    }

    /// Forward one flattened sample; returns its logits.
    pub fn forward_one(&self, x: &[f32], eng: &Engine) -> Vec<f32> {
        let t = Tensor::from_vec(&[1, x.len()], x.to_vec());
        self.forward(&t, eng).data
    }

    /// Per-step timing table over every [`forward`](FrozenModel::forward)
    /// since construction, or `None` before the first forward. Lines align
    /// with the compile report's steps.
    pub fn timing_report(&self) -> Option<String> {
        let snaps: Vec<(u64, u64)> = self.timers.iter().map(|t| t.snapshot()).collect();
        let total_ns: u64 = snaps.iter().map(|s| s.0).sum();
        let calls = snaps.iter().map(|s| s.1).max().unwrap_or(0);
        if calls == 0 {
            return None;
        }
        let mut out = format!(
            "per-step timings for {} ({} calls, {:.1} ms total):\n",
            self.label,
            calls,
            total_ns as f64 / 1e6
        );
        for (i, ((ns, n), line)) in
            snaps.iter().zip(&self.compiled.report.lines).enumerate()
        {
            let us = *ns as f64 / (*n).max(1) as f64 / 1e3;
            let pct = if total_ns > 0 { *ns as f64 * 100.0 / total_ns as f64 } else { 0.0 };
            out.push_str(&format!("  [{i:2}] {line:<44} {us:>9.1} us/call {pct:5.1}%\n"));
        }
        Some(out)
    }
}
