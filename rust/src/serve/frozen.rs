//! Frozen inference models (DESIGN.md §Serving, §Inference-Compiler).
//!
//! A [`FrozenModel`] is the deployment form of a trained network: the layer
//! stack is exported once into a flat list of forward-only ops
//! ([`InferOp`], produced by `nn::Layer::export_infer`) and handed to the
//! inference compiler ([`crate::compiler`]), which validates the op list,
//! pre-quantizes every weight **once** (int8 weights pre-packed into the
//! transposed BT layout the VNNI kernels consume), fuses GEMM → BN →
//! ReLU → requantize chains into single steps that pass integer codes
//! between ops, and resolves per-shape GEMM tiles from the artifact's plan
//! cache (or a load-time search). Serving then runs the compiled plan
//! through the [`crate::kernels::Engine`] — no gradient buffers, no QEM/QPA
//! controller probes, no training caches. The unfused interpreter stays
//! available as the correctness oracle and behind `apt serve --no-fuse`.
//!
//! **Parity contract.** With 8-bit schemes the integer serving path is
//! *bit-identical* to `train::Session::eval` whenever every GEMM's depth
//! satisfies `k · 2¹⁴ < 2²⁴` (k ≤ 1024): all products and partial sums are
//! then exact in both the fake-quant f32 reference and the i32 accumulator,
//! so the two paths compute the same reals. Every model in the zoo is far
//! under the bound; `rust/tests/test_serve.rs` pins the property. 16-bit
//! schemes exceed f32's 24-bit mantissa in the reference path, so int16
//! serving agrees only to float rounding (the integer path is the *more*
//! exact of the two). Fused execution is additionally bit-identical to the
//! unfused interpreter — every fusion rewrite has an exactness argument
//! (DESIGN.md §Inference-Compiler) and `rust/tests/test_compiler.rs` pins
//! it per zoo model.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::compiler::{self, CompileOptions, CompileReport, StepTimer, TuneEntry};
use crate::kernels::Engine;
use crate::nn::{models, QuantMode, Sequential};
use crate::tensor::Tensor;
use crate::train::checkpoint::Checkpoint;
use crate::util::Pcg32;

pub use crate::compiler::InferOp;

/// A trained network frozen for serving: a compiled forward-only plan with
/// pre-quantized weights and folded batch-norm statistics. Immutable after
/// construction — [`forward`](FrozenModel::forward) takes `&self`, so one
/// model is shared by every [`crate::serve::InferenceServer`] worker behind
/// an `Arc` with no locking (the per-step timers are atomics).
pub struct FrozenModel {
    label: String,
    compiled: compiler::Compiled,
    timers: Vec<StepTimer>,
}

impl FrozenModel {
    /// Freeze a live network (e.g. `session.net()` right after training)
    /// with default compile options (fusion on, no load-time tile search).
    /// Errors if any layer has no forward-only serving export.
    pub fn freeze(label: impl Into<String>, net: &Sequential) -> Result<FrozenModel> {
        Self::freeze_with(label, net, &CompileOptions::default())
    }

    /// [`freeze`](FrozenModel::freeze) with explicit compile options —
    /// `fuse: false` keeps the unfused interpreter as the primary path.
    pub fn freeze_with(
        label: impl Into<String>,
        net: &Sequential,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        let ops = net.export_infer()?;
        Self::compile_ops(label.into(), ops, opts, &[])
    }

    /// Compile a hand-built op list. Exposed so tests (and future
    /// exporters) can exercise freeze-time validation directly: malformed
    /// value-stack programs fail here with the op index named, never at
    /// execution time inside a serve worker.
    pub fn from_infer_ops(
        label: impl Into<String>,
        ops: Vec<InferOp>,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        Self::compile_ops(label.into(), ops, opts, &[])
    }

    /// Load a `train::checkpoint` file and freeze it: rebuilds the named
    /// model-zoo architecture under `mode`, restores parameters, controller
    /// schemes and batch-norm running stats from the checkpoint, and
    /// pre-quantizes the weights. This is the train→deploy hand-off: the
    /// checkpoint must come from a session built with the same
    /// `(model, mode)` pair (shapes are verified during restore). Default
    /// compile options; any tile plan cached in the artifact is applied.
    pub fn from_checkpoint(
        path: impl AsRef<Path>,
        model: &str,
        mode: QuantMode,
    ) -> Result<FrozenModel> {
        Self::from_checkpoint_with(path, model, mode, &CompileOptions::default())
    }

    /// [`from_checkpoint`](FrozenModel::from_checkpoint) with explicit
    /// compile options. With `tune: true`, shapes missing from the
    /// artifact's plan cache are tile-searched at load time; persist
    /// [`tuned_tiles`](FrozenModel::tuned_tiles) back with
    /// `Checkpoint::write_tune_cache` so subsequent loads skip the search.
    pub fn from_checkpoint_with(
        path: impl AsRef<Path>,
        model: &str,
        mode: QuantMode,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        // `read` already contextualizes I/O errors with the path.
        let ck = Checkpoint::read(path.as_ref())?;
        // Parameters are overwritten by the restore; the init seed is moot.
        let mut rng = Pcg32::seeded(0);
        let mut net = models::by_name(model, mode, &mut rng)
            .ok_or_else(|| anyhow!("unknown model {model:?}"))?;
        ck.restore_net(&mut net)?;
        let ops = net.export_infer()?;
        Self::compile_ops(format!("{model}-{}", mode.label()), ops, opts, ck.tune_cache())
    }

    /// Post-training quantization: freeze a **float** checkpoint into a
    /// statically quantized model using a calibration table instead of
    /// train-time controller schemes (DESIGN.md §Calibration). The
    /// checkpoint must come from a `QuantMode::Float32` session for `model`
    /// (no QAT run anywhere); `apt calibrate` produces the table. Per
    /// quantizable site the table supplies the calibrated activation
    /// format; weight formats are re-derived from the frozen weights' own
    /// range — per tensor (feeding the ordinary integer/minifloat kinds)
    /// or, when the table says `per_channel`, per output channel
    /// (weights fake-quantized channel-wise at freeze time, activations on
    /// the calibrated per-tensor format).
    pub fn freeze_ptq(
        path: impl AsRef<Path>,
        model: &str,
        table: &crate::calib::CalibTable,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        let ck = Checkpoint::read(path.as_ref())?;
        let mut rng = Pcg32::seeded(0);
        let mut net = models::by_name(model, QuantMode::Float32, &mut rng)
            .ok_or_else(|| anyhow!("unknown model {model:?}"))?;
        ck.restore_net(&mut net)?;
        let mut ops = net.export_infer()?;
        apply_calib(&mut ops, table)?;
        Self::compile_ops(
            format!("{model}-ptq-{}", table.observer),
            ops,
            opts,
            ck.tune_cache(),
        )
    }

    fn compile_ops(
        label: String,
        ops: Vec<InferOp>,
        opts: &CompileOptions,
        cache: &[TuneEntry],
    ) -> Result<FrozenModel> {
        let compiled = compiler::compile(&label, ops, opts, cache, crate::kernels::global())?;
        let timers = (0..compiled.n_steps()).map(|_| StepTimer::new()).collect();
        Ok(FrozenModel { label, compiled, timers })
    }

    /// Display label (`"<model>-<mode>"` when built from a checkpoint).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Serving precision derived from the frozen forward formats:
    /// `"f32"`, `"int8"` or `"int16"` for fixed-point (the widest scheme
    /// wins), a family label (`"e4m3"`, `"e5m2"`, `"int4"`) when the model
    /// trained in that family, or `"int4w"` under the weight-only int4
    /// compile override.
    pub fn precision(&self) -> &str {
        &self.compiled.precision
    }

    /// Flattened per-sample input width the model expects.
    pub fn input_len(&self) -> usize {
        self.compiled.din
    }

    /// Whether the primary execution path is the fused plan.
    pub fn fused(&self) -> bool {
        self.compiled.plan.is_some()
    }

    /// What the compile pass did: op/step counts, code edges, tile
    /// provenance, per-step labels.
    pub fn compile_report(&self) -> &CompileReport {
        &self.compiled.report
    }

    /// Tile decisions this model runs with (plan-cache hits + load-time
    /// search results) — persist with `Checkpoint::write_tune_cache`.
    pub fn tuned_tiles(&self) -> &[TuneEntry] {
        self.compiled.tuned()
    }

    /// Forward a batch `[n, input_len]` → logits `[n, classes]`. Pure:
    /// takes `&self`, so concurrent callers need no synchronization. Rows
    /// are computed independently, so a sample's logits do not depend on
    /// what it was batched with (the micro-batching invariant). Runs the
    /// fused plan when one was compiled, the unfused interpreter otherwise,
    /// and accumulates per-step wall-time into
    /// [`timing_report`](FrozenModel::timing_report).
    pub fn forward(&self, x: &Tensor, eng: &Engine) -> Tensor {
        self.check_input(x);
        self.compiled.run(x, eng, &self.timers)
    }

    /// Forward through the unfused reference interpreter regardless of the
    /// compiled plan — the oracle fused execution is pinned against (and
    /// the loser side of the fused-vs-unfused benchmarks). Does not touch
    /// the step timers.
    pub fn forward_unfused(&self, x: &Tensor, eng: &Engine) -> Tensor {
        self.check_input(x);
        self.compiled.run_unfused(x, eng)
    }

    fn check_input(&self, x: &Tensor) {
        assert_eq!(x.rank(), 2, "frozen forward expects [n, d] input");
        let din = self.compiled.din;
        assert_eq!(x.dim(1), din, "input width {} ≠ model width {}", x.dim(1), din);
    }

    /// Forward one flattened sample; returns its logits.
    pub fn forward_one(&self, x: &[f32], eng: &Engine) -> Vec<f32> {
        let t = Tensor::from_vec(&[1, x.len()], x.to_vec());
        self.forward(&t, eng).data
    }

    /// Apply a calibration table to a float export: set every quantizable
    /// site's activation format from its calibrated range and derive the
    /// weight format from the frozen weights themselves. Split out of
    /// [`freeze_ptq`](FrozenModel::freeze_ptq) so live nets (no checkpoint
    /// on disk) can take the same path.
    pub fn freeze_ptq_net(
        label: impl Into<String>,
        net: &Sequential,
        table: &crate::calib::CalibTable,
        opts: &CompileOptions,
    ) -> Result<FrozenModel> {
        let mut ops = net.export_infer()?;
        apply_calib(&mut ops, table)?;
        Self::compile_ops(label.into(), ops, opts, &[])
    }

    /// Per-step timing table over every [`forward`](FrozenModel::forward)
    /// since construction, or `None` before the first forward. Lines align
    /// with the compile report's steps.
    pub fn timing_report(&self) -> Option<String> {
        let snaps: Vec<(u64, u64)> = self.timers.iter().map(|t| t.snapshot()).collect();
        let total_ns: u64 = snaps.iter().map(|s| s.0).sum();
        let calls = snaps.iter().map(|s| s.1).max().unwrap_or(0);
        if calls == 0 {
            return None;
        }
        let mut out = format!(
            "per-step timings for {} ({} calls, {:.1} ms total):\n",
            self.label,
            calls,
            total_ns as f64 / 1e6
        );
        for (i, ((ns, n), line)) in
            snaps.iter().zip(&self.compiled.report.lines).enumerate()
        {
            let us = *ns as f64 / (*n).max(1) as f64 / 1e3;
            let pct = if total_ns > 0 { *ns as f64 * 100.0 / total_ns as f64 } else { 0.0 };
            out.push_str(&format!("  [{i:2}] {line:<44} {us:>9.1} us/call {pct:5.1}%\n"));
        }
        Some(out)
    }
}

/// Stamp a calibration table onto a float export. Per-tensor: the site gets
/// a weight format derived from the frozen weights' range plus the
/// calibrated activation format — the ordinary integer/minifloat kinds.
/// Per-channel: weights are fake-quantized per output channel right here
/// (no single per-tensor format could describe them, so `sw` stays `None`
/// and lowering takes the activation-only `Fq` kind).
fn apply_calib(ops: &mut [InferOp], table: &crate::calib::CalibTable) -> Result<()> {
    use crate::fixedpoint::{quantize, Format};

    let max_abs = |w: &[f32]| w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let site_of = |name: &str| {
        table.get(name).ok_or_else(|| {
            anyhow!("calibration table has no site {name:?} (calibrated for a different model?)")
        })
    };
    for op in ops.iter_mut() {
        match op {
            InferOp::Linear { name, w, sw, sx, .. } => {
                if sw.is_some() || sx.is_some() {
                    return Err(anyhow!(
                        "{name}: checkpoint already carries trained formats — freeze_ptq expects a float export"
                    ));
                }
                let site = site_of(name)?;
                if table.per_channel {
                    // Linear weights are din × dout: output channels are
                    // the columns.
                    let (rows, cols) = (w.dim(0), w.dim(1));
                    let scales = quantize::channel_scales_cols(
                        &w.data, rows, cols, table.family, table.bits,
                    );
                    quantize::fake_quant_per_channel_cols(
                        &mut w.data, rows, cols, table.family, table.bits, &scales,
                    );
                } else {
                    *sw = Some(Format::for_range(table.family, max_abs(&w.data), table.bits));
                }
                *sx = Some(site.fmt);
            }
            InferOp::Conv { name, w, geom, sw, sx, .. } => {
                if sw.is_some() || sx.is_some() {
                    return Err(anyhow!(
                        "{name}: checkpoint already carries trained formats — freeze_ptq expects a float export"
                    ));
                }
                let site = site_of(name)?;
                if table.per_channel {
                    // Conv weights are out_c × (in_c·kh·kw): output
                    // channels are the rows.
                    let rows = geom.out_c;
                    let cols = w.len() / rows;
                    let scales = quantize::channel_scales_rows(
                        &w.data, rows, cols, table.family, table.bits,
                    );
                    quantize::fake_quant_per_channel_rows(
                        &mut w.data, rows, cols, table.family, table.bits, &scales,
                    );
                } else {
                    *sw = Some(Format::for_range(table.family, max_abs(&w.data), table.bits));
                }
                *sx = Some(site.fmt);
            }
            InferOp::Depthwise { name, w, c, sw, sx, .. } => {
                if sw.is_some() || sx.is_some() {
                    return Err(anyhow!(
                        "{name}: checkpoint already carries trained formats — freeze_ptq expects a float export"
                    ));
                }
                let site = site_of(name)?;
                if table.per_channel {
                    // Depthwise kernels are c × 9: one channel per row.
                    let scales = quantize::channel_scales_rows(
                        &w.data, *c, 9, table.family, table.bits,
                    );
                    quantize::fake_quant_per_channel_rows(
                        &mut w.data, *c, 9, table.family, table.bits, &scales,
                    );
                } else {
                    *sw = Some(Format::for_range(table.family, max_abs(&w.data), table.bits));
                }
                *sx = Some(site.fmt);
            }
            _ => {}
        }
    }
    Ok(())
}
