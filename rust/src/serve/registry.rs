//! Multi-model registry with versioned warm swap (DESIGN.md
//! §Serving-Tier).
//!
//! A [`ModelRegistry`] maps `name → {version → model}` plus one *active*
//! version per name. Publishing a new version is a **warm swap**: the
//! active pointer flips atomically under the registry lock, so requests
//! admitted after the publish resolve to the new version while every
//! request admitted before it keeps the `Arc` it was pinned to at
//! admission and drains on the old version — no queue flush, no
//! mixed-version batch (the server never stacks two model handles into
//! one tensor). Evicting a non-active version only drops the registry's
//! `Arc`; in-flight batches still holding clones finish normally and the
//! model is freed when the last clone drops.
//!
//! Models are registered behind the [`ServeModel`] trait —
//! [`crate::serve::FrozenModel`] is the production implementation; tests
//! register purpose-built fakes (e.g. a forward that panics) to exercise
//! the server's failure paths without a real checkpoint.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{anyhow, bail, Result};

use crate::kernels::Engine;
use crate::tensor::Tensor;

use super::frozen::FrozenModel;

/// What the serving tier needs from a model: a pure batched forward.
/// `forward` takes `&self`, so one instance is shared by every worker
/// behind an `Arc` with no locking.
pub trait ServeModel: Send + Sync {
    /// Flattened per-sample input width.
    fn input_len(&self) -> usize;
    /// Forward a batch `[n, input_len] → [n, classes]`.
    fn forward(&self, x: &Tensor, eng: &Engine) -> Tensor;
    /// Display label (diagnostics only).
    fn label(&self) -> &str;
    /// Per-step timing table accumulated across forwards, if the model
    /// tracks one (compiled [`FrozenModel`]s do; fakes may not bother).
    fn timing_report(&self) -> Option<String> {
        None
    }
}

impl ServeModel for FrozenModel {
    fn input_len(&self) -> usize {
        FrozenModel::input_len(self)
    }

    fn forward(&self, x: &Tensor, eng: &Engine) -> Tensor {
        FrozenModel::forward(self, x, eng)
    }

    fn label(&self) -> &str {
        FrozenModel::label(self)
    }

    fn timing_report(&self) -> Option<String> {
        FrozenModel::timing_report(self)
    }
}

struct NameEntry {
    versions: BTreeMap<u64, Arc<dyn ServeModel>>,
    active: u64,
}

/// Registry state: one lock around a small name→versions map. Lookups
/// clone an `Arc` and leave; the lock is never held across a forward.
pub struct ModelRegistry {
    inner: Mutex<BTreeMap<String, NameEntry>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// One row of [`ModelRegistry::list`].
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Registered name.
    pub name: String,
    /// Version new requests currently resolve to.
    pub active: u64,
    /// Every loaded version, ascending.
    pub versions: Vec<u64>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry { inner: Mutex::new(BTreeMap::new()) }
    }

    fn lock(&self) -> MutexGuard<'_, BTreeMap<String, NameEntry>> {
        // Registry mutations are single map inserts/removes; state stays
        // coherent across a poisoning panic, so keep serving (same
        // rationale as the serve queue lock).
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Load `model` as `name@version` and make it the active version
    /// (warm swap when the name already serves traffic). Re-publishing an
    /// existing `(name, version)` is an error — versions are immutable.
    pub fn publish(
        &self,
        name: impl Into<String>,
        version: u64,
        model: Arc<dyn ServeModel>,
    ) -> Result<()> {
        let name = name.into();
        let mut map = self.lock();
        let entry = map
            .entry(name.clone())
            .or_insert_with(|| NameEntry { versions: BTreeMap::new(), active: version });
        if entry.versions.contains_key(&version) {
            bail!("model {name}@{version} is already published (versions are immutable)");
        }
        entry.versions.insert(version, model);
        entry.active = version;
        Ok(())
    }

    /// Resolve the active version of `name`: `(version, model)`.
    pub fn resolve(&self, name: &str) -> Option<(u64, Arc<dyn ServeModel>)> {
        let map = self.lock();
        let e = map.get(name)?;
        e.versions.get(&e.active).map(|m| (e.active, Arc::clone(m)))
    }

    /// Resolve one specific version of `name`.
    pub fn resolve_version(&self, name: &str, version: u64) -> Option<Arc<dyn ServeModel>> {
        self.lock().get(name)?.versions.get(&version).cloned()
    }

    /// Re-point the active version (rollback / canary promote).
    pub fn activate(&self, name: &str, version: u64) -> Result<()> {
        let mut map = self.lock();
        let e = map.get_mut(name).ok_or_else(|| anyhow!("unknown model {name:?}"))?;
        if !e.versions.contains_key(&version) {
            bail!("model {name} has no version {version}");
        }
        e.active = version;
        Ok(())
    }

    /// Unload `name@version`. The active version cannot be evicted
    /// (activate or publish another first); in-flight batches holding
    /// the `Arc` finish normally either way.
    pub fn evict(&self, name: &str, version: u64) -> Result<()> {
        let mut map = self.lock();
        let e = map.get_mut(name).ok_or_else(|| anyhow!("unknown model {name:?}"))?;
        if e.active == version {
            bail!("cannot evict the active version {name}@{version}");
        }
        if e.versions.remove(&version).is_none() {
            bail!("model {name} has no version {version}");
        }
        Ok(())
    }

    /// Unload every version of `name` (the name stops resolving at once;
    /// in-flight batches drain).
    pub fn evict_model(&self, name: &str) -> Result<()> {
        self.lock()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| anyhow!("unknown model {name:?}"))
    }

    /// Names currently registered, with their active + loaded versions.
    pub fn list(&self) -> Vec<ModelInfo> {
        self.lock()
            .iter()
            .map(|(name, e)| ModelInfo {
                name: name.clone(),
                active: e.active,
                versions: e.versions.keys().copied().collect(),
            })
            .collect()
    }

    /// Total loaded `(name, version)` pairs.
    pub fn loaded(&self) -> usize {
        self.lock().values().map(|e| e.versions.len()).sum()
    }
}
