//! The serving tier's data plane (DESIGN.md §Serving-Tier).
//!
//! [`InferenceServer`] owns the threads, locks, payloads and response
//! channels; the batching *policy* lives behind the
//! [`Scheduler`](super::scheduler::Scheduler) trait and the model
//! lookup behind [`ModelRegistry`](super::registry::ModelRegistry).
//! Request flow:
//!
//! 1. **Admission** (`submit` / `try_submit` / `submit_opts`): resolve
//!    the target model's *active* version in the registry and pin its
//!    `Arc` into the job (warm-swap pinning: a publish after this point
//!    does not retarget the request), validate the input width, then ask
//!    the scheduler to admit `(id, lane, deadline)`. The scheduler may
//!    queue it, shed it (bounded queue / infeasible deadline — the
//!    caller gets an immediate error), or admit it by evicting a
//!    lower-priority queued request (the victim's [`Pending`] resolves
//!    to an explicit rejection).
//! 2. **Dispatch**: an idle worker asks the scheduler to `plan`; the
//!    policy either hands it a batch of ids (flush-and-wait holds
//!    partial batches open, continuous batching never does) or a
//!    deadline to sleep until. Dispatched ids whose deadline already
//!    passed are answered `Rejected(DeadlineExpired)` without running.
//! 3. **Forward**: the batch is grouped by pinned model handle (a warm
//!    swap may split one batch into per-version sub-batches — versions
//!    are never mixed in one tensor), each group is stacked and run
//!    under `catch_unwind`: a panicking forward turns into explicit
//!    `Rejected(WorkerPanic)` replies instead of hung clients and a
//!    poisoned queue, and the worker keeps serving.
//! 4. **Shutdown**: in-flight batches drain and answer normally; ids
//!    still queued are answered `Rejected(Shutdown)`.
//!
//! Accounting invariant (checked by tests and the SLO bench): every
//! admitted request is answered exactly once, so after shutdown
//! `accepted == served + shed` and `submitted == accepted +
//! shed_admission`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::frozen::FrozenModel;
use super::registry::{ModelRegistry, ServeModel};
use super::scheduler::{Admit, Plan, SchedConfig, SchedCtx, SchedEntry, SchedPolicy, Scheduler, ShedReason};
use crate::kernels::Engine;
use crate::tensor::Tensor;

/// Lock the queue, shrugging off poisoning: every mutation under this
/// lock is a single scheduler/map operation, so the state stays coherent
/// if a worker panics while holding it — the remaining workers and
/// submitters keep serving instead of cascading the panic through every
/// `lock().unwrap()` in the server.
fn lock_queue(m: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Flush a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush-and-wait hold time: flush a partial batch this many
    /// microseconds after its oldest request arrived (ignored by
    /// continuous batching, which never holds a batch open).
    pub max_wait_us: u64,
    /// Bounded queue capacity; `submit` blocks (and the non-blocking
    /// paths shed) when the queue holds this many un-dispatched
    /// requests. A `queue_cap` smaller than `max_batch` also caps the
    /// flush fill target at `min(max_batch, queue_cap)`.
    pub queue_cap: usize,
    /// Worker thread count (each forms and runs batches independently).
    pub workers: usize,
    /// Batching policy (see [`SchedPolicy`]).
    pub policy: SchedPolicy,
    /// Priority lane count; lane 0 is most urgent. [`SubmitOpts`]
    /// defaults to lane 1 ("normal" of the default three).
    pub lanes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 16,
            max_wait_us: 200,
            queue_cap: 256,
            workers: 2,
            policy: SchedPolicy::Flush,
            lanes: 3,
        }
    }
}

impl ServeConfig {
    fn sched_config(&self) -> SchedConfig {
        SchedConfig {
            max_batch: self.max_batch,
            queue_cap: self.queue_cap,
            lanes: self.lanes,
            max_wait_us: self.max_wait_us,
        }
    }
}

/// Per-request submission options (see [`InferenceServer::submit_opts`]).
#[derive(Clone, Debug)]
pub struct SubmitOpts {
    /// Priority lane, 0 = most urgent (clamped to `cfg.lanes - 1`).
    pub lane: usize,
    /// Relative completion deadline; enables reject-on-admission and
    /// dispatch-time expiry shedding.
    pub deadline_us: Option<u64>,
    /// Registry model name; `None` serves the server's default model.
    pub model: Option<String>,
}

impl Default for SubmitOpts {
    fn default() -> Self {
        SubmitOpts { lane: 1, deadline_us: None, model: None }
    }
}

/// Counters accumulated over the server's lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Requests answered with logits.
    pub served: u64,
    /// Batches flushed (per-model sub-batches count individually).
    pub batches: u64,
    /// Admitted requests later answered with an explicit rejection
    /// (evicted, deadline expired, shutdown, worker panic).
    pub shed: u64,
    /// Requests refused synchronously at admission (queue full with no
    /// victim, or deadline unmeetable) — these never entered the queue.
    pub shed_admission: u64,
}

impl ServerStats {
    /// Mean flushed batch size (0 when nothing was served).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    /// Total requests that reached admission (accepted or refused).
    pub fn submitted(&self) -> u64 {
        self.accepted + self.shed_admission
    }

    /// The answered-exactly-once invariant: after shutdown every
    /// accepted request was either served or explicitly shed.
    pub fn accounted(&self) -> bool {
        self.accepted == self.served + self.shed
    }
}

/// One reply on a request's private channel, stamped with the instant
/// the worker produced it (so open-loop load generators measure latency
/// at completion time, not at `wait()` time).
pub(crate) enum Reply {
    /// Logits for the request's own input row.
    Logits(Vec<f32>, Instant),
    /// Explicit rejection — the request was shed, never silently dropped.
    Shed(ShedReason, Instant),
}

/// How one admitted request ended (see [`Pending::outcome`]).
#[derive(Clone, Debug)]
pub enum ServeOutcome {
    /// The request's logits.
    Logits(Vec<f32>),
    /// The request was shed for this reason.
    Shed(ShedReason),
}

struct Job {
    input: Vec<f32>,
    tx: mpsc::Sender<Reply>,
    model: Arc<dyn ServeModel>,
}

impl Job {
    /// Send a reply, stamping it now. A receiver that gave up (dropped
    /// its `Pending`) is not an error.
    fn reply(&self, r: Result<Vec<f32>, ShedReason>) {
        let at = Instant::now();
        let _ = self.tx.send(match r {
            Ok(logits) => Reply::Logits(logits, at),
            Err(reason) => Reply::Shed(reason, at),
        });
    }
}

struct QueueState {
    sched: Box<dyn Scheduler>,
    jobs: HashMap<u64, Job>,
    next_id: u64,
    closed: bool,
}

struct Shared {
    registry: Arc<ModelRegistry>,
    default_model: String,
    cfg: ServeConfig,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    space: Condvar,
    accepted: AtomicU64,
    served: AtomicU64,
    batches: AtomicU64,
    shed: AtomicU64,
    shed_admission: AtomicU64,
    /// EWMA of seconds-per-request over finished batches (f64 bits);
    /// 0 until the first batch lands. Drives deadline feasibility.
    ewma_req_secs: AtomicU64,
}

impl Shared {
    fn ctx(&self, now: Instant) -> SchedCtx {
        SchedCtx {
            now,
            est_req_secs: f64::from_bits(self.ewma_req_secs.load(Ordering::Relaxed)),
            workers: self.cfg.workers,
        }
    }

    fn note_batch(&self, n: usize, secs: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.served.fetch_add(n as u64, Ordering::Relaxed);
        let x = secs / n.max(1) as f64;
        let old = f64::from_bits(self.ewma_req_secs.load(Ordering::Relaxed));
        let new = if old == 0.0 { x } else { 0.8 * old + 0.2 * x };
        self.ewma_req_secs.store(new.to_bits(), Ordering::Relaxed);
    }
}

/// Handle to one in-flight request; resolve it with
/// [`wait`](Pending::wait) or [`outcome`](Pending::outcome).
pub struct Pending {
    rx: mpsc::Receiver<Reply>,
}

impl Pending {
    pub(crate) fn recv(self) -> Result<Reply> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("inference server dropped the request without answering"))
    }

    /// Block until the logits for this request arrive. Errors if the
    /// request was shed (the message names the [`ShedReason`]) or the
    /// server dropped it without answering.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.recv()? {
            Reply::Logits(logits, _) => Ok(logits),
            Reply::Shed(reason, _) => Err(anyhow!("request shed ({})", reason.label())),
        }
    }

    /// Block until the request resolves, distinguishing logits from an
    /// explicit shed (useful when shedding is an expected outcome).
    pub fn outcome(self) -> Result<ServeOutcome> {
        Ok(match self.recv()? {
            Reply::Logits(logits, _) => ServeOutcome::Logits(logits),
            Reply::Shed(reason, _) => ServeOutcome::Shed(reason),
        })
    }
}

/// A running inference server: model registry, bounded multi-lane
/// queue behind a pluggable [`Scheduler`], `workers` forward threads.
/// See the module docs for the request lifecycle.
pub struct InferenceServer {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Single-model convenience: registers `model` as version 1 of a
    /// fresh registry under its own label and serves it. `engine` is the
    /// kernel-engine handle every worker uses for its GEMMs — pass
    /// [`crate::kernels::global_arc`] to share the process pool, or a
    /// dedicated `Engine` to isolate serving from training traffic.
    /// Errors on a bad [`ServeConfig`] or unspawnable workers — typed,
    /// like every other serving-tier failure, never a panic.
    pub fn start(
        model: Arc<FrozenModel>,
        engine: Arc<Engine>,
        cfg: ServeConfig,
    ) -> Result<InferenceServer> {
        let name = model.label().to_string();
        let registry = Arc::new(ModelRegistry::new());
        registry.publish(&name, 1, model as Arc<dyn ServeModel>)?;
        Self::start_registry(registry, name, engine, cfg)
    }

    /// Serve a [`ModelRegistry`]: requests name a model via
    /// [`SubmitOpts::model`] (default `default_model`) and are pinned to
    /// its active version at admission. Publishing to the registry while
    /// the server runs is the warm-swap path. Errors if `default_model`
    /// does not resolve.
    pub fn start_registry(
        registry: Arc<ModelRegistry>,
        default_model: impl Into<String>,
        engine: Arc<Engine>,
        cfg: ServeConfig,
    ) -> Result<InferenceServer> {
        // Config validation errors instead of asserting: these are
        // CLI-reachable (`--workers 0`), and the no-panic contract of the
        // serving tier covers its construction too.
        if cfg.workers < 1 {
            bail!("serve config: need at least one worker");
        }
        if cfg.max_batch < 1 {
            bail!("serve config: max_batch must be ≥ 1");
        }
        if cfg.queue_cap < 1 {
            bail!("serve config: queue_cap must be ≥ 1");
        }
        if cfg.lanes < 1 {
            bail!("serve config: need at least one priority lane");
        }
        let default_model = default_model.into();
        if registry.resolve(&default_model).is_none() {
            bail!("default model {default_model:?} is not in the registry");
        }
        let shared = Arc::new(Shared {
            registry,
            default_model,
            cfg,
            state: Mutex::new(QueueState {
                sched: cfg.policy.build(cfg.sched_config()),
                jobs: HashMap::new(),
                next_id: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            space: Condvar::new(),
            accepted: AtomicU64::new(0),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            shed_admission: AtomicU64::new(0),
            ewma_req_secs: AtomicU64::new(0),
        });
        let workers = (0..cfg.workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                let eng = Arc::clone(&engine);
                thread::Builder::new()
                    .name(format!("apt-serve-{i}"))
                    .spawn(move || worker_loop(sh, eng))
            })
            .collect::<std::io::Result<Vec<_>>>()
            .context("spawning serve worker threads")?;
        Ok(InferenceServer { shared, workers })
    }

    /// The registry this server routes through.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.shared.registry
    }

    /// Name requests resolve to when [`SubmitOpts::model`] is `None`.
    pub fn default_model(&self) -> &str {
        &self.shared.default_model
    }

    /// Input width of the default model's active version.
    pub fn input_len(&self) -> usize {
        self.shared
            .registry
            .resolve(&self.shared.default_model)
            .map(|(_, m)| m.input_len())
            .unwrap_or(0)
    }

    /// Enqueue one flattened sample for the default model at normal
    /// priority with no deadline, blocking while the queue is full
    /// (backpressure). Errors if the input width is wrong or the server
    /// is shut down.
    pub fn submit(&self, input: Vec<f32>) -> Result<Pending> {
        self.enqueue(input, SubmitOpts::default(), true)
    }

    /// Non-blocking [`submit`](Self::submit): errors immediately when
    /// the queue is full instead of waiting for space.
    pub fn try_submit(&self, input: Vec<f32>) -> Result<Pending> {
        self.enqueue(input, SubmitOpts::default(), false)
    }

    /// Full-control submission: priority lane, deadline, target model.
    /// Never blocks — admission control decides immediately: queued,
    /// queued-by-evicting-a-lower-priority-request, or refused with an
    /// error naming the [`ShedReason`].
    pub fn submit_opts(&self, input: Vec<f32>, opts: SubmitOpts) -> Result<Pending> {
        self.enqueue(input, opts, false)
    }

    fn enqueue(&self, input: Vec<f32>, opts: SubmitOpts, block: bool) -> Result<Pending> {
        let name = opts.model.as_deref().unwrap_or(&self.shared.default_model);
        let (_version, model) = self
            .shared
            .registry
            .resolve(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}"))?;
        let want = model.input_len();
        if input.len() != want {
            bail!("input has {} values, model {name:?} expects {want}", input.len());
        }
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let deadline = opts.deadline_us.map(|us| now + Duration::from_micros(us));
        let victim = {
            let mut st = lock_queue(&self.shared.state);
            if block {
                while st.sched.len() >= self.shared.cfg.queue_cap && !st.closed {
                    st = self
                        .shared
                        .space
                        .wait(st)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                }
            }
            if st.closed {
                bail!("inference server is shut down");
            }
            let id = st.next_id;
            st.next_id += 1;
            let entry = SchedEntry { id, lane: opts.lane, deadline, arrived: Instant::now() };
            let ctx = self.shared.ctx(entry.arrived);
            match st.sched.admit(entry, &ctx) {
                Admit::Queued => {
                    st.jobs.insert(id, Job { input, tx, model });
                    None
                }
                Admit::Evict { victim } => {
                    st.jobs.insert(id, Job { input, tx, model });
                    st.jobs.remove(&victim)
                }
                Admit::Shed(reason) => {
                    self.shared.shed_admission.fetch_add(1, Ordering::Relaxed);
                    match reason {
                        ShedReason::QueueFull => bail!(
                            "request shed ({}): queue is full ({} pending)",
                            reason.label(),
                            st.sched.len()
                        ),
                        _ => bail!("request shed ({})", reason.label()),
                    }
                }
            }
        };
        if let Some(v) = victim {
            v.reply(Err(ShedReason::Evicted));
            self.shared.shed.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.accepted.fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        Ok(Pending { rx })
    }

    /// Current lifetime counters.
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            accepted: self.shared.accepted.load(Ordering::Relaxed),
            served: self.shared.served.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            shed_admission: self.shared.shed_admission.load(Ordering::Relaxed),
        }
    }

    /// Per-step timing tables from every registered model version that
    /// has served at least one forward (see
    /// [`ServeModel::timing_report`]). One string per `(name, version)`
    /// pair, registry order; empty until the first batch lands.
    pub fn timing_reports(&self) -> Vec<String> {
        let mut out = Vec::new();
        for info in self.shared.registry.list() {
            for v in &info.versions {
                if let Some(m) = self.shared.registry.resolve_version(&info.name, *v) {
                    if let Some(r) = m.timing_report() {
                        out.push(format!("{}@{v}: {r}", info.name));
                    }
                }
            }
        }
        out
    }

    /// Stop accepting requests, let in-flight batches drain and answer,
    /// reject everything still queued (`Rejected(Shutdown)` — SLO
    /// semantics: at shutdown a queued request is better told "no" at
    /// once than served late), join the workers, and return the final
    /// counters. Every accepted request is answered exactly once.
    pub fn shutdown(mut self) -> ServerStats {
        self.close_and_join();
        self.stats()
    }

    fn close_and_join(&mut self) {
        {
            let mut st = lock_queue(&self.shared.state);
            st.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.space.notify_all();
        for h in std::mem::take(&mut self.workers) {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Pop the jobs for `ids` out of the payload map. Ids whose job vanished
/// (evicted concurrently — cannot happen today, but cheap to tolerate)
/// are skipped.
fn take_jobs(st: &mut QueueState, ids: Vec<u64>) -> Vec<Job> {
    ids.into_iter().filter_map(|id| st.jobs.remove(&id)).collect()
}

fn worker_loop(shared: Arc<Shared>, eng: Arc<Engine>) {
    loop {
        // Decide under the lock; compute outside it.
        let (batch, expired, closing) = {
            let mut st = lock_queue(&shared.state);
            loop {
                if st.closed {
                    // Shutdown: reject everything still queued (the first
                    // worker in drains it; later workers see empty).
                    let ids = st.sched.drain();
                    let jobs = take_jobs(&mut st, ids);
                    break (Vec::new(), jobs, true);
                }
                let ctx = shared.ctx(Instant::now());
                match st.sched.plan(&ctx) {
                    Plan::Dispatch { batch, expired } => {
                        let b = take_jobs(&mut st, batch);
                        let e = take_jobs(&mut st, expired);
                        break (b, e, false);
                    }
                    Plan::Wait(None) => {
                        st = shared
                            .not_empty
                            .wait(st)
                            .unwrap_or_else(|poisoned| poisoned.into_inner());
                    }
                    Plan::Wait(Some(hold_until)) => {
                        let now = Instant::now();
                        if hold_until <= now {
                            continue; // hold elapsed while planning; replan
                        }
                        let (g, _timeout) = shared
                            .not_empty
                            .wait_timeout(st, hold_until - now)
                            .unwrap_or_else(|poisoned| poisoned.into_inner());
                        st = g;
                    }
                }
            }
        };
        shared.space.notify_all();
        let reason = if closing { ShedReason::Shutdown } else { ShedReason::DeadlineExpired };
        for job in &expired {
            job.reply(Err(reason));
        }
        shared.shed.fetch_add(expired.len() as u64, Ordering::Relaxed);
        if closing {
            return;
        }
        if batch.is_empty() {
            continue;
        }
        // More work may be queued than this batch took; hand it to
        // another idle worker instead of letting it wait for the next
        // arrival notification.
        {
            let st = lock_queue(&shared.state);
            if st.sched.len() > 0 {
                shared.not_empty.notify_one();
            }
        }
        // A warm swap between admissions pins different versions into one
        // dispatch: group by model handle so versions never share a
        // tensor, then run each group.
        let mut groups: Vec<(Arc<dyn ServeModel>, Vec<Job>)> = Vec::new();
        for job in batch {
            match groups.iter_mut().find(|(m, _)| Arc::ptr_eq(m, &job.model)) {
                Some((_, v)) => v.push(job),
                None => {
                    let m = Arc::clone(&job.model);
                    groups.push((m, vec![job]));
                }
            }
        }
        for (model, jobs) in groups {
            run_group(&shared, &eng, model, jobs);
        }
    }
}

/// Stack one model's jobs into a `[n, d]` tensor, forward under
/// `catch_unwind`, and answer each job over its private channel — logits
/// on success, `Rejected(WorkerPanic)` if the forward panicked (an
/// admitted request is answered even when the model blows up mid-batch).
fn run_group(shared: &Shared, eng: &Engine, model: Arc<dyn ServeModel>, jobs: Vec<Job>) {
    let n = jobs.len();
    let d = model.input_len();
    let mut x = Tensor::zeros(&[n, d]);
    for (i, job) in jobs.iter().enumerate() {
        x.data[i * d..(i + 1) * d].copy_from_slice(&job.input);
    }
    let t0 = Instant::now();
    let forwarded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        model.forward(&x, eng)
    }));
    match forwarded {
        Ok(y) => {
            let out_d = y.dim(1);
            shared.note_batch(n, t0.elapsed().as_secs_f64());
            for (i, job) in jobs.into_iter().enumerate() {
                job.reply(Ok(y.data[i * out_d..(i + 1) * out_d].to_vec()));
            }
        }
        Err(_) => {
            shared.shed.fetch_add(n as u64, Ordering::Relaxed);
            for job in jobs.into_iter() {
                job.reply(Err(ShedReason::WorkerPanic));
            }
        }
    }
}
