//! Pluggable batching policies (DESIGN.md §Serving-Tier).
//!
//! The [`Scheduler`] trait is the pure *policy* half of the serving tier:
//! it orders queued request ids into batches; the server
//! (`serve::server`) owns the payloads, threads, locks and response
//! channels. Keeping the policy payload-free means every implementation
//! runs the same conformance battery in `rust/tests/test_scheduler.rs`
//! (no lost/duplicated ids, `batch ≤ max_batch`, lane FIFO, explicit
//! shed decisions) and the deterministic virtual-time simulator in
//! [`crate::bench::loadgen`] can replay a policy without any threads.
//!
//! Two policies ship:
//!
//! - [`SchedPolicy::Flush`] — the original flush-and-wait micro-batcher:
//!   hold a batch open until it reaches `min(max_batch, queue_cap)`
//!   requests or `max_wait_us` has passed since the oldest queued arrival,
//!   then flush.
//! - [`SchedPolicy::Continuous`] — continuous batching: never hold a
//!   batch open. A free worker dispatches whatever is queued *right now*
//!   (up to `max_batch`); requests that arrive while every worker is busy
//!   are admitted into the next batch the instant one frees. For one-shot
//!   CNN/MLP forwards this is exactly the iteration-level admission of
//!   LLM continuous batching collapsed to a single iteration — under load
//!   batches form from queue occupancy, under light load nothing ever
//!   waits out an artificial deadline.
//!
//! Both policies share the same admission control ([`LaneQueue::admit`]):
//! bounded occupancy (`queue_cap`), priority-lane eviction (an arriving
//! high-priority request may displace the youngest lowest-priority queued
//! one when full) and SLO-aware reject-on-admission (a request whose
//! deadline cannot be met under the current queue-delay estimate is shed
//! immediately instead of timing out in the queue).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Why a request was refused service. Every shed path produces an
/// *explicit* reply carrying one of these — a shed request never hangs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Queue at `queue_cap` and no lower-priority victim to displace.
    QueueFull,
    /// Reject-on-admission: predicted queue delay exceeds the deadline.
    DeadlineUnmeetable,
    /// Displaced from the queue by a higher-priority arrival.
    Evicted,
    /// Deadline passed while queued; dropped at dispatch time.
    DeadlineExpired,
    /// Server shut down before the request was dispatched.
    Shutdown,
    /// The worker running the batch panicked mid-forward.
    WorkerPanic,
}

impl ShedReason {
    /// Stable lowercase token (CSV columns, error messages).
    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::DeadlineUnmeetable => "deadline-unmeetable",
            ShedReason::Evicted => "evicted",
            ShedReason::DeadlineExpired => "deadline-expired",
            ShedReason::Shutdown => "shutdown",
            ShedReason::WorkerPanic => "worker-panic",
        }
    }
}

/// Which batching policy a server (or simulator) runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Flush-and-wait micro-batching (the PR-3 behaviour).
    Flush,
    /// Continuous batching — dispatch whatever is queued to a free worker.
    Continuous,
}

impl SchedPolicy {
    /// Parse a `--scheduler` flag value.
    pub fn parse(s: &str) -> Result<SchedPolicy> {
        Ok(match s {
            "flush" => SchedPolicy::Flush,
            "continuous" | "cont" => SchedPolicy::Continuous,
            other => bail!("unknown scheduler {other:?} (expected flush or continuous)"),
        })
    }

    /// Stable lowercase token.
    pub fn label(&self) -> &'static str {
        match self {
            SchedPolicy::Flush => "flush",
            SchedPolicy::Continuous => "continuous",
        }
    }

    /// Build the scheduler for this policy.
    pub fn build(&self, cfg: SchedConfig) -> Box<dyn Scheduler> {
        match self {
            SchedPolicy::Flush => Box::new(FlushScheduler::new(cfg)),
            SchedPolicy::Continuous => Box::new(ContinuousScheduler::new(cfg)),
        }
    }
}

/// Policy-level tuning shared by every scheduler.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Largest batch a single dispatch may return.
    pub max_batch: usize,
    /// Bounded queue occupancy; admissions beyond it shed (or evict).
    pub queue_cap: usize,
    /// Priority lane count; lane 0 is most urgent, `lanes-1` least.
    pub lanes: usize,
    /// Flush-and-wait hold time (ignored by continuous batching).
    pub max_wait_us: u64,
}

/// What the policy knows about one queued request. The `id` is the
/// server's key back to the payload; the scheduler never sees inputs.
#[derive(Clone, Copy, Debug)]
pub struct SchedEntry {
    /// Server-assigned unique id (monotone in admission order).
    pub id: u64,
    /// Priority lane, `0 = most urgent`; clamped to `lanes-1`.
    pub lane: usize,
    /// Absolute completion deadline, if the client set one.
    pub deadline: Option<Instant>,
    /// Admission timestamp (drives the flush hold timer and lane FIFO).
    pub arrived: Instant,
}

/// Live service-rate estimate handed to admission control: the server
/// maintains an EWMA of seconds-per-request over finished batches; the
/// simulator derives it from its deterministic cost model.
#[derive(Clone, Copy, Debug)]
pub struct SchedCtx {
    /// Decision timestamp.
    pub now: Instant,
    /// Estimated seconds to serve one request (0 ⇒ no estimate yet: the
    /// feasibility check admits everything until the first batch lands).
    pub est_req_secs: f64,
    /// Worker threads draining this queue.
    pub workers: usize,
}

impl SchedCtx {
    /// Predicted queueing delay for a request entering behind `ahead`
    /// queued requests: `ahead · est / workers` — the fluid-limit drain
    /// time of everything in front of it.
    pub fn queue_delay(&self, ahead: usize) -> Duration {
        Duration::from_secs_f64(self.est_req_secs * ahead as f64 / self.workers.max(1) as f64)
    }
}

/// Outcome of [`Scheduler::admit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Entry queued; it will appear in exactly one later dispatch /
    /// expiry / drain.
    Queued,
    /// Entry refused before queueing; the caller must reply `Rejected`.
    Shed(ShedReason),
    /// Entry queued after displacing `victim` (a queued lower-priority
    /// id); the caller must reply `Rejected(Evicted)` to the victim.
    Evict {
        /// The displaced id.
        victim: u64,
    },
}

/// Outcome of [`Scheduler::plan`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Plan {
    /// Run `batch` now (≤ `max_batch` ids, lane-ordered, FIFO within a
    /// lane). `expired` ids missed their deadline while queued and must
    /// be answered `Rejected(DeadlineExpired)` without running.
    Dispatch {
        /// Ids to forward as one batch.
        batch: Vec<u64>,
        /// Ids shed at dispatch time (deadline already passed).
        expired: Vec<u64>,
    },
    /// Nothing runnable. `Some(t)` ⇒ a partial batch is holding until
    /// `t` (flush policy); `None` ⇒ queue is empty, wait for an arrival.
    Wait(Option<Instant>),
}

/// A batching policy over queued request ids. Implementations must be
/// pure queue logic — no clocks (use `ctx.now`), no threads, no I/O —
/// so the conformance battery and the virtual-time simulator exercise
/// exactly the code the live server runs.
pub trait Scheduler: Send {
    /// Policy name (`"flush"` / `"continuous"`).
    fn name(&self) -> &'static str;

    /// Admission decision for one arriving entry.
    fn admit(&mut self, e: SchedEntry, ctx: &SchedCtx) -> Admit;

    /// Batch-formation decision for an idle worker.
    fn plan(&mut self, ctx: &SchedCtx) -> Plan;

    /// Queued entry count.
    fn len(&self) -> usize;

    /// True when nothing is queued.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove and return every queued id (shutdown path); the caller
    /// replies `Rejected(Shutdown)` to each.
    fn drain(&mut self) -> Vec<u64>;
}

/// Per-lane FIFO queues + the admission control shared by every policy.
struct LaneQueue {
    cfg: SchedConfig,
    lanes: Vec<VecDeque<SchedEntry>>,
    len: usize,
}

impl LaneQueue {
    fn new(cfg: SchedConfig) -> LaneQueue {
        assert!(cfg.lanes >= 1, "need at least one priority lane");
        LaneQueue { lanes: (0..cfg.lanes).map(|_| VecDeque::new()).collect(), len: 0, cfg }
    }

    /// Shared admission control: bounded occupancy, SLO feasibility,
    /// lowest-priority-first eviction.
    fn admit(&mut self, mut e: SchedEntry, ctx: &SchedCtx) -> Admit {
        e.lane = e.lane.min(self.cfg.lanes - 1);
        // Reject-on-admission: requests are served in lane order, so only
        // occupancy at the same or more urgent lanes delays this one.
        if let Some(deadline) = e.deadline {
            let ahead: usize = self.lanes[..=e.lane].iter().map(|q| q.len()).sum();
            if ctx.now + ctx.queue_delay(ahead) > deadline {
                return Admit::Shed(ShedReason::DeadlineUnmeetable);
            }
        }
        if self.len >= self.cfg.queue_cap {
            // Shed lowest priority first: displace the *youngest* entry of
            // the least urgent non-empty lane strictly below the arrival.
            // `pop_back` doubles as the emptiness check — no unwrap on a
            // lane that could race empty under a future locking change.
            let victim =
                (e.lane + 1..self.cfg.lanes).rev().find_map(|l| self.lanes[l].pop_back());
            match victim {
                Some(v) => {
                    self.lanes[e.lane].push_back(e);
                    Admit::Evict { victim: v.id }
                }
                None => Admit::Shed(ShedReason::QueueFull),
            }
        } else {
            self.len += 1;
            self.lanes[e.lane].push_back(e);
            Admit::Queued
        }
    }

    /// Pop up to `max_batch` runnable ids (lane order, FIFO within a
    /// lane), separating entries whose deadline already passed.
    fn take_batch(&mut self, now: Instant) -> (Vec<u64>, Vec<u64>) {
        let mut batch = Vec::new();
        let mut expired = Vec::new();
        for lane in &mut self.lanes {
            while batch.len() < self.cfg.max_batch {
                match lane.pop_front() {
                    None => break,
                    Some(e) => {
                        self.len -= 1;
                        match e.deadline {
                            Some(d) if d < now => expired.push(e.id),
                            _ => batch.push(e.id),
                        }
                    }
                }
            }
            if batch.len() >= self.cfg.max_batch {
                break;
            }
        }
        (batch, expired)
    }

    /// Arrival time of the oldest queued entry (drives the flush timer).
    fn oldest_arrival(&self) -> Option<Instant> {
        self.lanes.iter().filter_map(|q| q.front()).map(|e| e.arrived).min()
    }

    fn drain(&mut self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.len);
        for lane in &mut self.lanes {
            out.extend(lane.drain(..).map(|e| e.id));
        }
        self.len = 0;
        out
    }
}

/// Flush-and-wait: hold a batch open until `min(max_batch, queue_cap)`
/// requests are queued or `max_wait_us` has passed since the oldest
/// arrival, then flush (the PR-3 state machine, now behind the trait).
pub struct FlushScheduler {
    q: LaneQueue,
}

impl FlushScheduler {
    /// Build with the given tuning.
    pub fn new(cfg: SchedConfig) -> FlushScheduler {
        FlushScheduler { q: LaneQueue::new(cfg) }
    }
}

impl Scheduler for FlushScheduler {
    fn name(&self) -> &'static str {
        "flush"
    }

    fn admit(&mut self, e: SchedEntry, ctx: &SchedCtx) -> Admit {
        self.q.admit(e, ctx)
    }

    fn plan(&mut self, ctx: &SchedCtx) -> Plan {
        if self.q.len == 0 {
            return Plan::Wait(None);
        }
        // queue_cap clamps the fill target: a queue that can never reach
        // max_batch must flush when full, not wait out the deadline while
        // submitters sit blocked on backpressure.
        let fill_target = self.q.cfg.max_batch.min(self.q.cfg.queue_cap);
        let hold_until = match self.q.oldest_arrival() {
            Some(t) => t + Duration::from_micros(self.q.cfg.max_wait_us),
            // `len > 0` with every lane empty would be a bookkeeping bug;
            // flush whatever take_batch finds instead of panicking the
            // worker that noticed.
            None => ctx.now,
        };
        if self.q.len >= fill_target || ctx.now >= hold_until {
            let (batch, expired) = self.q.take_batch(ctx.now);
            Plan::Dispatch { batch, expired }
        } else {
            Plan::Wait(Some(hold_until))
        }
    }

    fn len(&self) -> usize {
        self.q.len
    }

    fn drain(&mut self) -> Vec<u64> {
        self.q.drain()
    }
}

/// Continuous batching: a free worker always dispatches immediately;
/// batch size is whatever queue occupancy provides (≤ `max_batch`).
pub struct ContinuousScheduler {
    q: LaneQueue,
}

impl ContinuousScheduler {
    /// Build with the given tuning (`max_wait_us` is ignored).
    pub fn new(cfg: SchedConfig) -> ContinuousScheduler {
        ContinuousScheduler { q: LaneQueue::new(cfg) }
    }
}

impl Scheduler for ContinuousScheduler {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn admit(&mut self, e: SchedEntry, ctx: &SchedCtx) -> Admit {
        self.q.admit(e, ctx)
    }

    fn plan(&mut self, ctx: &SchedCtx) -> Plan {
        if self.q.len == 0 {
            return Plan::Wait(None);
        }
        let (batch, expired) = self.q.take_batch(ctx.now);
        Plan::Dispatch { batch, expired }
    }

    fn len(&self) -> usize {
        self.q.len
    }

    fn drain(&mut self) -> Vec<u64> {
        self.q.drain()
    }
}
