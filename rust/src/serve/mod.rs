//! Quantized inference serving (DESIGN.md §Serving, §Serving-Tier) — the
//! deployment side of the paper's quantization payoff.
//!
//! Training (the `train::Session` API) pins weights and activations to int8
//! the whole run, so a finished checkpoint *is* an int8 model; this module
//! closes the train→deploy loop that motivates that design (paper §1,
//! "Efficiency"; cf. the per-tensor fixed-point deployment argument in
//! PAPERS.md). Four pieces:
//!
//! - [`FrozenModel`] — a checkpoint (or live net) frozen for serving
//!   through the inference compiler (`crate::compiler`, DESIGN.md
//!   §Inference-Compiler): forward-only op list validated at freeze time,
//!   batch-norm running stats folded to per-channel affines, weights
//!   pre-quantized and pre-packed **once** into the layouts the integer
//!   GEMM kernels consume, GEMM→requantize→ReLU chains fused into steps
//!   that pass integer codes (bit-identical to the unfused interpreter),
//!   and per-shape tiles autotuned/cached. No gradient buffers, no
//!   controller probes, no training caches.
//! - [`ModelRegistry`] — versioned multi-model registry behind the
//!   [`ServeModel`] trait: load/evict models by name+version, warm swap
//!   (publish flips the active version for new admissions while in-flight
//!   batches drain on the version they were pinned to — no queue flush).
//! - [`Scheduler`] — pluggable batching policy over queued request ids:
//!   [`SchedPolicy::Flush`] (flush-and-wait micro-batching) and
//!   [`SchedPolicy::Continuous`] (continuous batching: a free worker
//!   dispatches whatever is queued, nothing waits out a fill timer), both
//!   with priority lanes, per-request deadlines and SLO-aware shedding
//!   (reject-on-admission, lowest-priority-first eviction, dispatch-time
//!   expiry — every shed is an explicit reply, never a hang).
//! - [`InferenceServer`] — the data plane: bounded multi-lane queue, N
//!   worker threads each owning a [`crate::kernels::Engine`] handle,
//!   `catch_unwind` around every forward so a panicking model answers
//!   `Rejected(WorkerPanic)` instead of hanging its batch.
//!
//! ```no_run
//! use std::sync::Arc;
//! use apt::nn::QuantMode;
//! use apt::serve::{FrozenModel, InferenceServer, ServeConfig, SchedPolicy};
//!
//! let frozen = FrozenModel::from_checkpoint("ckpt.txt", "mlp", QuantMode::Static(8)).unwrap();
//! let server = InferenceServer::start(
//!     Arc::new(frozen),
//!     apt::kernels::global_arc(),
//!     ServeConfig { policy: SchedPolicy::Continuous, ..ServeConfig::default() },
//! ).unwrap();
//! let pending = server.submit(vec![0.0; server.input_len()]).unwrap();
//! let logits = pending.wait().unwrap();
//! println!("prediction: {:?}", logits);
//! ```
//!
//! Operational protocol and the tables live in EXPERIMENTS.md §Serve and
//! §Serve-SLO; `apt serve` (the CLI) and `examples/serve_quickstart.rs`
//! are runnable end-to-end demos; `bench_serve_slo` sweeps offered QPS
//! against both schedulers into `results/serve_slo.csv`.

#![warn(missing_docs)]

mod frozen;
mod registry;
mod scheduler;
mod server;

pub use frozen::{FrozenModel, InferOp};
pub use registry::{ModelInfo, ModelRegistry, ServeModel};
pub use scheduler::{
    Admit, ContinuousScheduler, FlushScheduler, Plan, SchedConfig, SchedCtx, SchedEntry,
    SchedPolicy, Scheduler, ShedReason,
};
pub use server::{InferenceServer, Pending, ServeConfig, ServeOutcome, ServerStats, SubmitOpts};

pub(crate) use server::Reply;
