//! Quantized inference serving (DESIGN.md §Serving) — the deployment side
//! of the paper's quantization payoff.
//!
//! Training (the `train::Session` API) pins weights and activations to int8
//! the whole run, so a finished checkpoint *is* an int8 model; this module
//! closes the train→deploy loop that motivates that design (paper §1,
//! "Efficiency"; cf. the per-tensor fixed-point deployment argument in
//! PAPERS.md). Two pieces:
//!
//! - [`FrozenModel`] — a checkpoint (or live net) frozen for serving:
//!   forward-only op list, batch-norm running stats folded to per-channel
//!   affines, weights pre-quantized **once** into int8/int16 codes that
//!   feed the integer GEMM kernels. No gradient buffers, no controller
//!   probes, no training caches.
//! - [`InferenceServer`] — a bounded request queue with dynamic
//!   micro-batching (flush on `max_batch` or `max_wait_us`) and N worker
//!   threads, each owning a [`crate::kernels::Engine`] handle.
//!
//! ```no_run
//! use std::sync::Arc;
//! use apt::nn::QuantMode;
//! use apt::serve::{FrozenModel, InferenceServer, ServeConfig};
//!
//! let frozen = FrozenModel::from_checkpoint("ckpt.txt", "mlp", QuantMode::Static(8)).unwrap();
//! let server = InferenceServer::start(
//!     Arc::new(frozen),
//!     apt::kernels::global_arc(),
//!     ServeConfig::default(),
//! );
//! let pending = server.submit(vec![0.0; server.model().input_len()]).unwrap();
//! let logits = pending.wait().unwrap();
//! println!("prediction: {:?}", logits);
//! ```
//!
//! Operational protocol and the throughput/latency table template live in
//! EXPERIMENTS.md §Serve; `apt serve` (the CLI) and
//! `examples/serve_quickstart.rs` are runnable end-to-end demos.

#![warn(missing_docs)]

mod batcher;
mod frozen;

pub use batcher::{InferenceServer, Pending, ServeConfig, ServerStats};
pub use frozen::{FrozenModel, InferOp};
