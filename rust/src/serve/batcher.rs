//! Dynamic micro-batching inference server (DESIGN.md §Serving).
//!
//! Request flow: [`InferenceServer::submit`] pushes a job onto one bounded
//! FIFO queue (backpressure: submit blocks while the queue is at
//! `queue_cap`); `workers` threads each run the batching state machine
//!
//! `Idle ── job arrives ──▶ Filling(deadline) ── fill target
//! (= min(max_batch, queue_cap)) reached or max_wait_us elapsed or
//! shutdown ──▶ Flush ──▶ Idle`
//!
//! A flushing worker drains up to `max_batch` jobs under the queue lock,
//! releases it, stacks the inputs into one `[n, d]` tensor, runs the shared
//! [`FrozenModel`] forward on its own [`Engine`] handle, and answers each
//! job over its private response channel — so responses can never be
//! mis-paired and per-submitter ordering is the caller's `wait()` order.
//! While one worker computes, the others keep forming batches from new
//! arrivals.
//!
//! Thread ownership: the model is immutable and shared (`Arc<FrozenModel>`,
//! `forward(&self)`); each worker owns an `Arc<Engine>` handle for its
//! GEMMs; the only shared mutable state is the queue behind one `Mutex` +
//! two `Condvar`s (`not_empty` wakes batchers, `space` wakes blocked
//! submitters). Shutdown ([`InferenceServer::shutdown`] or drop) closes the
//! queue, lets the workers drain every accepted job, and joins them — an
//! accepted request is always answered.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::frozen::FrozenModel;
use crate::kernels::Engine;
use crate::tensor::Tensor;

/// Lock the queue, shrugging off poisoning: if a worker panicked while
/// holding the lock, the queue state itself (a `VecDeque` + flag) is still
/// coherent — every mutation is a single push/drain — so the remaining
/// workers and submitters keep serving instead of cascading the panic
/// through every `lock().unwrap()` in the server.
fn lock_queue(m: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Flush a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush a partial batch this many microseconds after a worker starts
    /// filling one (the latency bound under light load).
    pub max_wait_us: u64,
    /// Bounded queue capacity; `submit` blocks (and `try_submit` errors)
    /// when the queue holds this many un-flushed requests. A `queue_cap`
    /// smaller than `max_batch` also caps the batch: workers flush at
    /// `min(max_batch, queue_cap)` rather than waiting out the deadline
    /// on a queue that can never fill further.
    pub queue_cap: usize,
    /// Worker thread count (each forms and runs batches independently).
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 16, max_wait_us: 200, queue_cap: 256, workers: 2 }
    }
}

/// Counters accumulated over the server's lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Requests accepted by `submit`/`try_submit`.
    pub accepted: u64,
    /// Requests answered by a worker.
    pub served: u64,
    /// Batches flushed.
    pub batches: u64,
}

impl ServerStats {
    /// Mean flushed batch size (0 when nothing was served).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }
}

struct Job {
    input: Vec<f32>,
    tx: mpsc::Sender<Vec<f32>>,
}

struct QueueState {
    q: VecDeque<Job>,
    closed: bool,
}

struct Shared {
    model: Arc<FrozenModel>,
    cfg: ServeConfig,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    space: Condvar,
    accepted: AtomicU64,
    served: AtomicU64,
    batches: AtomicU64,
}

/// Handle to one in-flight request; resolve it with
/// [`wait`](Pending::wait).
pub struct Pending {
    rx: mpsc::Receiver<Vec<f32>>,
}

impl Pending {
    /// Block until the logits for this request arrive. Errors only if the
    /// server dropped the request without answering (a worker panicked).
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("inference server dropped the request without answering"))
    }
}

/// A running inference server over one [`FrozenModel`]: bounded request
/// queue, dynamic micro-batching, `workers` forward threads. See the
/// module docs for the batching state machine and thread-ownership map.
pub struct InferenceServer {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Start the worker threads. `engine` is the kernel-engine handle every
    /// worker uses for its GEMMs — pass [`crate::kernels::global_arc`] to
    /// share the process pool, or a dedicated `Engine` to isolate serving
    /// from training traffic.
    pub fn start(model: Arc<FrozenModel>, engine: Arc<Engine>, cfg: ServeConfig) -> InferenceServer {
        assert!(cfg.workers >= 1, "need at least one worker");
        assert!(cfg.max_batch >= 1, "max_batch must be ≥ 1");
        assert!(cfg.queue_cap >= 1, "queue_cap must be ≥ 1");
        let shared = Arc::new(Shared {
            model,
            cfg,
            state: Mutex::new(QueueState { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            space: Condvar::new(),
            accepted: AtomicU64::new(0),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        });
        let workers = (0..cfg.workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                let eng = Arc::clone(&engine);
                thread::Builder::new()
                    .name(format!("apt-serve-{i}"))
                    .spawn(move || worker_loop(sh, eng))
                    .expect("spawn serve worker thread")
            })
            .collect();
        InferenceServer { shared, workers }
    }

    /// The model being served.
    pub fn model(&self) -> &FrozenModel {
        &self.shared.model
    }

    /// Enqueue one flattened sample, blocking while the queue is full
    /// (backpressure). Errors if the input width is wrong or the server is
    /// shut down.
    pub fn submit(&self, input: Vec<f32>) -> Result<Pending> {
        self.enqueue(input, true)
    }

    /// Non-blocking [`submit`](Self::submit): errors immediately when the
    /// queue is full instead of waiting for space.
    pub fn try_submit(&self, input: Vec<f32>) -> Result<Pending> {
        self.enqueue(input, false)
    }

    fn enqueue(&self, input: Vec<f32>, block: bool) -> Result<Pending> {
        let want = self.shared.model.input_len();
        if input.len() != want {
            bail!("input has {} values, model expects {}", input.len(), want);
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_queue(&self.shared.state);
            while st.q.len() >= self.shared.cfg.queue_cap && !st.closed {
                if !block {
                    bail!("request queue is full ({} pending)", st.q.len());
                }
                st = self
                    .shared
                    .space
                    .wait(st)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
            }
            if st.closed {
                bail!("inference server is shut down");
            }
            st.q.push_back(Job { input, tx });
        }
        self.shared.accepted.fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        Ok(Pending { rx })
    }

    /// Current lifetime counters.
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            accepted: self.shared.accepted.load(Ordering::Relaxed),
            served: self.shared.served.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting requests, answer everything already queued, join the
    /// workers, and return the final counters.
    pub fn shutdown(mut self) -> ServerStats {
        self.close_and_join();
        self.stats()
    }

    fn close_and_join(&mut self) {
        {
            let mut st = lock_queue(&self.shared.state);
            st.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.space.notify_all();
        for h in std::mem::take(&mut self.workers) {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn worker_loop(shared: Arc<Shared>, eng: Arc<Engine>) {
    loop {
        let jobs = {
            let mut st = lock_queue(&shared.state);
            // Idle: wait for the first request (or shutdown).
            while st.q.is_empty() && !st.closed {
                st = shared
                    .not_empty
                    .wait(st)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
            }
            if st.q.is_empty() && st.closed {
                return;
            }
            // Filling: hold the batch open until it is full, the deadline
            // passes, or the server is closing (then flush what we have).
            // The fill target is clamped by queue_cap: a queue that can
            // never reach max_batch must flush when full, not wait out the
            // deadline while submitters sit blocked on backpressure.
            let fill_target = shared.cfg.max_batch.min(shared.cfg.queue_cap);
            let deadline = Instant::now() + Duration::from_micros(shared.cfg.max_wait_us);
            while st.q.len() < fill_target && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, timeout) = shared
                    .not_empty
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
                st = g;
                if timeout.timed_out() {
                    break;
                }
                // Another worker may have drained the queue while we slept.
                if st.q.is_empty() {
                    break;
                }
            }
            // Flush.
            let take = st.q.len().min(shared.cfg.max_batch);
            st.q.drain(..take).collect::<Vec<Job>>()
        };
        shared.space.notify_all();
        if jobs.is_empty() {
            continue;
        }
        let n = jobs.len();
        let d = shared.model.input_len();
        let mut x = Tensor::zeros(&[n, d]);
        for (i, job) in jobs.iter().enumerate() {
            x.data[i * d..(i + 1) * d].copy_from_slice(&job.input);
        }
        let y = shared.model.forward(&x, &eng);
        let out_d = y.dim(1);
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.served.fetch_add(n as u64, Ordering::Relaxed);
        for (i, job) in jobs.into_iter().enumerate() {
            // A receiver that gave up (dropped its Pending) is not an error.
            let _ = job.tx.send(y.data[i * out_d..(i + 1) * out_d].to_vec());
        }
    }
}
