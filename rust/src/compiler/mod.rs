//! Inference compiler for the frozen serving IR (DESIGN.md
//! §Inference-Compiler).
//!
//! Freeze time hands this module the [`InferOp`] list a model exports; the
//! compiler turns it into an executable artifact in three stages:
//!
//! 1. **Lower** (`ir`) — validate the value-stack discipline and
//!    pre-quantize/pre-pack every weight exactly once (int8 codes in the
//!    transposed VNNI/BT layout with column sums, int16 BT codes, or
//!    pre-fake-quantized f32). One `InferOp → ExecOp` definition shared by
//!    every execution strategy.
//! 2. **Fuse** (`fuse`) — collapse `Linear`/`Conv`/`Depthwise` with their
//!    folded BN, residual add, and ReLU into single steps, and decide per
//!    step whether to emit f32 or the next consumer's integer codes
//!    (max-pools between integer layers run in code space). Every rewrite
//!    has an exactness argument, so fused execution is bit-identical to the
//!    unfused interpreter (`interp`) — which stays around as the oracle
//!    and as the `--no-fuse` escape hatch.
//! 3. **Tune** (`tune`) — per-GEMM-shape tile search at load time, with
//!    winners cached in the frozen artifact's `tune` section so subsequent
//!    loads skip the search.

mod exec;
mod fuse;
mod interp;
mod ir;
mod tune;

pub use ir::InferOp;
pub use tune::{GemmKind, ShapeKey, TuneEntry, TUNE_BATCH};

pub(crate) use exec::StepTimer;

use ir::ExecOp;

use anyhow::Result;

use crate::kernels::Engine;
use crate::tensor::Tensor;

/// Knobs for the compile pass. Defaults match `apt serve`: fusion on, load-time
/// tile search off (cached tiles are always applied when present).
#[derive(Clone, Copy, Debug)]
pub struct CompileOptions {
    /// Build a fused execution plan (`false` = interpret the ops unfused —
    /// the `--no-fuse` escape hatch).
    pub fuse: bool,
    /// Search tiles for shapes missing from the plan cache (costs a few
    /// milliseconds per novel shape at load time).
    pub tune: bool,
    /// Freeze-time weight-only re-quantization: re-derive every quantized
    /// layer's *weight* format in this family from the frozen weights'
    /// range (`int4` nibble-packs them, halving weight bytes vs int8;
    /// activations keep their trained formats). `None` / `FixedPoint`
    /// keeps the trained weight formats.
    pub weight_format: Option<crate::fixedpoint::FormatFamily>,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions { fuse: true, tune: false, weight_format: None }
    }
}

/// What the compile pass did — shown by `apt serve` at startup and
/// exposed programmatically via `FrozenModel::compile_report`.
#[derive(Clone, Debug, Default)]
pub struct CompileReport {
    /// Model label the plan was compiled for.
    pub label: String,
    /// Serving precision (`"f32"` / `"int8"` / `"int16"` / a format-family
    /// label such as `"e4m3"`, or `"int4w"` for weight-only int4).
    pub precision: String,
    /// Ops in the lowered program.
    pub ops: usize,
    /// Bytes of pre-packed weight payload (codes / f32 values) across the
    /// program — the number weight-only int4 halves vs int8.
    pub weight_bytes: usize,
    /// Steps in the executable plan (equals `ops` when fusion is off).
    pub steps: usize,
    /// Whether a fused plan was built.
    pub fused: bool,
    /// Steps whose output stays in integer codes (no f32 round-trip).
    pub code_edges: usize,
    /// GEMM shapes whose tile came from the artifact's plan cache.
    pub tiles_cached: usize,
    /// GEMM shapes tile-searched at this load.
    pub tiles_tuned: usize,
    /// One display line per plan step.
    pub lines: Vec<String>,
}

impl std::fmt::Display for CompileReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "compiled {} ({}): {} ops -> {} steps{}, {} code edge(s), {} weight bytes, tiles: {} cached / {} tuned",
            self.label,
            self.precision,
            self.ops,
            self.steps,
            if self.fused { "" } else { " (fusion off)" },
            self.code_edges,
            self.weight_bytes,
            self.tiles_cached,
            self.tiles_tuned,
        )?;
        for (i, line) in self.lines.iter().enumerate() {
            writeln!(f, "  [{i:2}] {line}")?;
        }
        Ok(())
    }
}

/// A compiled model: the executable op list plus (unless fusion was
/// disabled) the fused plan. Owned by `serve::FrozenModel`.
pub(crate) struct Compiled {
    pub(crate) din: usize,
    pub(crate) precision: String,
    pub(crate) ops: Vec<ExecOp>,
    pub(crate) plan: Option<fuse::ExecPlan>,
    pub(crate) report: CompileReport,
}

impl Compiled {
    /// Steps the primary execution path has (plan steps when fused, ops
    /// when not) — the timer vector is sized to this.
    pub(crate) fn n_steps(&self) -> usize {
        self.plan.as_ref().map_or(self.ops.len(), |p| p.steps.len())
    }

    /// Run the primary path: the fused plan when present, the unfused
    /// interpreter otherwise.
    pub(crate) fn run(&self, x: &Tensor, eng: &Engine, timers: &[StepTimer]) -> Tensor {
        match &self.plan {
            Some(plan) => exec::run_plan(plan, &self.ops, x, eng, timers),
            None => interp::run_unfused(&self.ops, x, eng, timers),
        }
    }

    /// Run the unfused interpreter regardless of the plan — the oracle the
    /// bit-identity tests compare against. Never touches the step timers
    /// (they belong to the primary path).
    pub(crate) fn run_unfused(&self, x: &Tensor, eng: &Engine) -> Tensor {
        interp::run_unfused(&self.ops, x, eng, &[])
    }

    /// Run unfused with each quantizable site's input activation handed to
    /// `tap(site_name, data)` before the op consumes it — the observation
    /// hook `calib::Calibrator` drives its forward-only passes through.
    pub(crate) fn run_observed(
        &self,
        x: &Tensor,
        eng: &Engine,
        tap: &mut dyn FnMut(&str, &[f32]),
    ) -> Tensor {
        interp::run_observed(&self.ops, x, eng, tap)
    }

    /// Quantizable site names (linear / conv / depthwise layers), in
    /// forward order — the keys `run_observed` taps and a `CalibTable`
    /// indexes by.
    pub(crate) fn site_names(&self) -> Vec<String> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                ExecOp::Linear(l) => Some(l.name.clone()),
                ExecOp::Conv(cv) => Some(cv.name.clone()),
                ExecOp::Depthwise(dw) => Some(dw.name.clone()),
                _ => None,
            })
            .collect()
    }

    /// Tile decisions to persist in the artifact's plan cache.
    pub(crate) fn tuned(&self) -> &[TuneEntry] {
        self.plan.as_ref().map_or(&[], |p| &p.tuned)
    }
}

/// Compile an exported op list into an executable artifact: lower +
/// validate, optionally fuse, and resolve tiles (plan `cache` first, then —
/// when `opts.tune` — a timed search on `eng` for the rest).
pub(crate) fn compile(
    label: &str,
    infer_ops: Vec<InferOp>,
    opts: &CompileOptions,
    cache: &[TuneEntry],
    eng: &Engine,
) -> Result<Compiled> {
    let lowered = ir::lower(label, infer_ops, opts.weight_format)?;
    let mut report = CompileReport {
        label: label.to_string(),
        precision: lowered.precision.clone(),
        ops: lowered.ops.len(),
        steps: lowered.ops.len(),
        weight_bytes: ir::weight_bytes(&lowered.ops),
        fused: opts.fuse,
        ..CompileReport::default()
    };
    let plan = if opts.fuse {
        let mut plan = fuse::build_plan(&lowered.ops);
        let shapes = fuse::shape_keys(&lowered.ops, &plan.steps);
        let outcome = tune::resolve_tiles(&shapes, cache, opts.tune, eng);
        fuse::apply_tiles(&lowered.ops, &mut plan.steps, &outcome.entries);
        plan.tuned = outcome.entries;
        report.steps = plan.steps.len();
        report.code_edges = plan.code_edges();
        report.tiles_cached = outcome.cached;
        report.tiles_tuned = outcome.searched;
        report.lines = plan.labels.clone();
        Some(plan)
    } else {
        report.lines = lowered.ops.iter().map(|op| op.describe()).collect();
        None
    };
    Ok(Compiled { din: lowered.din, precision: lowered.precision, ops: lowered.ops, plan, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::{Format, FormatFamily, Scheme};

    fn mlp_ops() -> Vec<InferOp> {
        let q = |s| {
            (
                Format::FixedPoint(Scheme { bits: 8, s }),
                Format::FixedPoint(Scheme { bits: 8, s: s + 1 }),
            )
        };
        let lin = |name: &str, din: usize, dout: usize, s: i32| InferOp::Linear {
            name: name.to_string(),
            w: Tensor::zeros(&[din, dout]),
            b: vec![0.0; dout],
            sw: Some(q(s).0),
            sx: Some(q(s).1),
        };
        vec![lin("fc0", 6, 8, -6), InferOp::Relu, lin("fc1", 8, 4, -5)]
    }

    #[test]
    fn compile_fused_and_unfused_report_shapes() {
        let eng = Engine::serial();
        let fused =
            compile("m", mlp_ops(), &CompileOptions::default(), &[], &eng).unwrap();
        assert_eq!(fused.precision, "int8");
        assert_eq!(fused.din, 6);
        assert_eq!(fused.report.steps, 2);
        assert_eq!(fused.report.code_edges, 1);
        assert!(fused.plan.is_some());

        let opts = CompileOptions { fuse: false, ..CompileOptions::default() };
        let unfused = compile("m", mlp_ops(), &opts, &[], &eng).unwrap();
        assert!(unfused.plan.is_none());
        assert_eq!(unfused.report.steps, 3);
        assert_eq!(unfused.report.lines.len(), 3);
        let txt = format!("{}", unfused.report);
        assert!(txt.contains("fusion off"));
    }

    #[test]
    fn int4_weight_only_halves_weight_bytes() {
        let eng = Engine::serial();
        let i8c = compile("m", mlp_ops(), &CompileOptions::default(), &[], &eng).unwrap();
        let opts =
            CompileOptions { weight_format: Some(FormatFamily::Int4), ..CompileOptions::default() };
        let i4c = compile("m", mlp_ops(), &opts, &[], &eng).unwrap();
        assert_eq!(i4c.precision, "int4w");
        assert_eq!(i4c.report.weight_bytes * 2, i8c.report.weight_bytes);
        // Codes still flow between the two linears: the i4 kind consumes
        // i8 activation codes exactly like the i8 kind.
        assert_eq!(i4c.report.code_edges, 1);
    }

    #[test]
    fn compile_rejects_malformed_stack_programs() {
        let eng = Engine::serial();
        let mut ops = mlp_ops();
        ops.push(InferOp::AddPopRelu); // nothing pushed — must underflow
        let err = compile("bad", ops, &CompileOptions::default(), &[], &eng)
            .unwrap_err()
            .to_string();
        assert!(err.contains("op 3"), "error must name the op index: {err}");
        assert!(err.contains("underflows"));
    }

    #[test]
    fn tune_search_records_entries_for_every_gemm_shape() {
        let eng = Engine::serial();
        let opts = CompileOptions { tune: true, ..CompileOptions::default() };
        let c = compile("m", mlp_ops(), &opts, &[], &eng).unwrap();
        assert_eq!(c.tuned().len(), 2);
        assert_eq!(c.report.tiles_tuned, 2);
        // Second compile with the cache: no search.
        let cache: Vec<TuneEntry> = c.tuned().to_vec();
        let c2 = compile("m", mlp_ops(), &opts, &cache, &eng).unwrap();
        assert_eq!(c2.report.tiles_tuned, 0);
        assert_eq!(c2.report.tiles_cached, 2);
        assert_eq!(c2.tuned(), cache.as_slice());
    }
}
