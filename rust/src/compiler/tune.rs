//! Per-shape tile autotuning (DESIGN.md §Inference-Compiler).
//!
//! Every fused GEMM step in a plan carries a [`Tile`] — blocking `mc`/`kc`
//! plus the engine's row-shard chunk. All tiles are bit-identical by
//! construction (pinned in `fixedpoint::gemm` and `kernels` tests), so the
//! search is a pure speed question: run each candidate on synthetic
//! operands of the exact shape, keep the fastest. Results are cached as
//! [`TuneEntry`] rows in the frozen artifact's `tune` section
//! (`train::checkpoint`), so subsequent loads of the same checkpoint skip
//! the search entirely.
//!
//! Honesty note: on the AVX-512 VNNI/BW paths the SIMD kernels stream
//! full-`k` dot products and ignore `mc`/`kc`; there the only tunable axis
//! is the parallel shard chunk, and on a serial engine the candidate set
//! degenerates to the default tile (no search, nothing to win). The f32
//! and portable-integer paths expose the full blocking space.

use std::time::{Duration, Instant};

use crate::fixedpoint::gemm::Tile;
use crate::fixedpoint::gemm_simd;
use crate::kernels::Engine;

/// Which GEMM kernel family a tuned shape belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    /// f32 blocked kernel (`gemm_f32_tiled`).
    F32,
    /// int8 prepacked kernel (VNNI or portable fallback).
    I8,
    /// int16 prepacked kernel (vpmaddwd or portable fallback).
    I16,
}

impl GemmKind {
    /// Stable one-token name used by the checkpoint `tune` section.
    pub fn token(&self) -> &'static str {
        match self {
            GemmKind::F32 => "f32",
            GemmKind::I8 => "i8",
            GemmKind::I16 => "i16",
        }
    }

    /// Inverse of [`GemmKind::token`].
    pub fn from_token(s: &str) -> Option<GemmKind> {
        match s {
            "f32" => Some(GemmKind::F32),
            "i8" => Some(GemmKind::I8),
            "i16" => Some(GemmKind::I16),
            _ => None,
        }
    }
}

/// One GEMM shape as the autotuner keys it: kernel family × (m, k, n).
/// Linear steps are tuned at the nominal serving batch [`TUNE_BATCH`]
/// (their real `m` varies per request batch); conv steps use their exact
/// per-image shape (`m = out_c`, `k = rows`, `n = cols`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShapeKey {
    /// Kernel family.
    pub kind: GemmKind,
    /// Output rows.
    pub m: usize,
    /// Reduction depth.
    pub k: usize,
    /// Output columns.
    pub n: usize,
}

/// A tuned (or cached) tile decision for one shape — the unit the frozen
/// artifact's `tune` section stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneEntry {
    /// The shape this tile was chosen for.
    pub key: ShapeKey,
    /// The winning tile.
    pub tile: Tile,
}

/// Nominal batch size linear-layer shapes are tuned at (their `m` is
/// request-dependent; conv shapes are exact).
pub const TUNE_BATCH: usize = 32;

/// What tile resolution produced, with provenance counts for the compile
/// report.
pub(crate) struct TuneOutcome {
    /// Every decided entry (cache hits + fresh searches) — this is what
    /// gets written back to the artifact.
    pub(crate) entries: Vec<TuneEntry>,
    /// Shapes freshly measured this load.
    pub(crate) searched: usize,
    /// Shapes answered from the artifact's plan cache.
    pub(crate) cached: usize,
}

pub(crate) fn lookup(entries: &[TuneEntry], key: ShapeKey) -> Option<Tile> {
    entries.iter().find(|e| e.key == key).map(|e| e.tile)
}

/// Resolve a tile for every shape: plan cache first, then (when `search`
/// is on) a timed sweep of the candidate set, else the default tile.
/// Shapes that were neither cached nor searched are *not* recorded, so a
/// later tuning load still measures them.
pub(crate) fn resolve_tiles(
    shapes: &[ShapeKey],
    cache: &[TuneEntry],
    search: bool,
    eng: &Engine,
) -> TuneOutcome {
    let mut out = TuneOutcome { entries: Vec::new(), searched: 0, cached: 0 };
    for &key in shapes {
        if lookup(&out.entries, key).is_some() {
            continue; // duplicate shape in this plan — already decided
        }
        if let Some(tile) = lookup(cache, key) {
            out.entries.push(TuneEntry { key, tile });
            out.cached += 1;
        } else if search {
            let tile = tune_shape(key, eng);
            out.entries.push(TuneEntry { key, tile });
            out.searched += 1;
        }
    }
    out
}

/// Candidate tiles for one shape on this engine. Single-element when the
/// kernel has no tunable axis here (SIMD path on a serial engine).
pub(crate) fn candidates(kind: GemmKind, threads: usize) -> Vec<Tile> {
    let simd = match kind {
        GemmKind::F32 => false,
        GemmKind::I8 => gemm_simd::has_vnni(),
        GemmKind::I16 => gemm_simd::has_avx512bw(),
    };
    let blocks: &[(usize, usize)] = if simd {
        // mc/kc are moot for the SIMD kernels; only the shard axis counts.
        &[(64, 256)]
    } else {
        &[(32, 128), (32, 512), (64, 256), (128, 256), (128, 1024)]
    };
    let shards: &[usize] = if threads > 1 { &[0, 8, 32, 64] } else { &[0] };
    let mut out = Vec::with_capacity(blocks.len() * shards.len());
    for &(mc, kc) in blocks {
        for &shard in shards {
            out.push(Tile { mc, kc, shard });
        }
    }
    out
}

/// Deterministic synthetic operands of one shape (seedless integer
/// pattern — the values only need to be representative, incl. some zeros
/// for the f32 kernel's zero-skip).
enum Operands {
    F32 { a: Vec<f32>, b: Vec<f32> },
    I8 { a: Vec<i8>, bt: Vec<i8>, colsum: Vec<i32> },
    I16 { a: Vec<i16>, bt: Vec<i16> },
}

fn synth(key: ShapeKey) -> Operands {
    let (m, k, n) = (key.m, key.k, key.n);
    let pat = |i: usize| (i * 7 + 3) % 13;
    match key.kind {
        GemmKind::F32 => {
            let a: Vec<f32> = (0..m * k).map(|i| pat(i) as f32 - 6.0).collect();
            let b: Vec<f32> = (0..k * n).map(|i| pat(i + 5) as f32 - 6.0).collect();
            Operands::F32 { a, b }
        }
        GemmKind::I8 => {
            let a: Vec<i8> = (0..m * k).map(|i| (pat(i) as i8) - 6).collect();
            let bt: Vec<i8> = (0..k * n).map(|i| (pat(i + 5) as i8) - 6).collect();
            let mut colsum = vec![0i32; n];
            for (j, cs) in colsum.iter_mut().enumerate() {
                *cs = bt[j * k..(j + 1) * k].iter().map(|&v| v as i32).sum();
            }
            Operands::I8 { a, bt, colsum }
        }
        GemmKind::I16 => {
            let a: Vec<i16> = (0..m * k).map(|i| (pat(i) as i16) - 6).collect();
            let bt: Vec<i16> = (0..k * n).map(|i| (pat(i + 5) as i16) - 6).collect();
            Operands::I16 { a, bt }
        }
    }
}

fn run_once(key: ShapeKey, ops: &Operands, tile: Tile, eng: &Engine) -> Duration {
    let (m, k, n) = (key.m, key.k, key.n);
    match ops {
        Operands::F32 { a, b } => {
            let mut c = vec![0.0f32; m * n];
            let t0 = Instant::now();
            eng.gemm_f32_tiled(m, k, n, a, b, &mut c, tile);
            t0.elapsed()
        }
        Operands::I8 { a, bt, colsum } => {
            let mut c = vec![0i32; m * n];
            let t0 = Instant::now();
            eng.gemm_i8_prepacked_tiled(m, k, n, a, bt, colsum, &mut c, tile);
            t0.elapsed()
        }
        Operands::I16 { a, bt } => {
            let mut c = vec![0i32; m * n];
            let t0 = Instant::now();
            eng.gemm_i16_prepacked_tiled(m, k, n, a, bt, &mut c, tile);
            t0.elapsed()
        }
    }
}

/// Time every candidate on this engine and return the fastest (min over
/// `REPS` timed runs after one warmup — serving shapes are small, so the
/// whole search stays in the milliseconds).
pub(crate) fn tune_shape(key: ShapeKey, eng: &Engine) -> Tile {
    const REPS: usize = 3;
    let cands = candidates(key.kind, eng.threads());
    if cands.len() == 1 {
        return cands[0];
    }
    let ops = synth(key);
    let mut best = cands[0];
    let mut best_t = Duration::MAX;
    for &tile in &cands {
        run_once(key, &ops, tile, eng); // warmup: page in buffers, spin pool
        let mut t = Duration::MAX;
        for _ in 0..REPS {
            t = t.min(run_once(key, &ops, tile, eng));
        }
        if t < best_t {
            best_t = t;
            best = tile;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tokens_roundtrip() {
        for k in [GemmKind::F32, GemmKind::I8, GemmKind::I16] {
            assert_eq!(GemmKind::from_token(k.token()), Some(k));
        }
        assert_eq!(GemmKind::from_token("i4"), None);
    }

    #[test]
    fn resolve_prefers_cache_and_dedupes() {
        let key = ShapeKey { kind: GemmKind::F32, m: 8, k: 16, n: 8 };
        let cached_tile = Tile { mc: 32, kc: 128, shard: 0 };
        let cache = [TuneEntry { key, tile: cached_tile }];
        let eng = Engine::serial();
        let out = resolve_tiles(&[key, key], &cache, true, &eng);
        assert_eq!(out.entries.len(), 1);
        assert_eq!(out.entries[0].tile, cached_tile);
        assert_eq!((out.cached, out.searched), (1, 0));
    }

    #[test]
    fn search_returns_a_candidate() {
        let eng = Engine::serial();
        for kind in [GemmKind::F32, GemmKind::I8, GemmKind::I16] {
            let key = ShapeKey { kind, m: 8, k: 32, n: 8 };
            let tile = tune_shape(key, &eng);
            assert!(candidates(kind, 1).contains(&tile));
        }
    }

    #[test]
    fn no_search_records_nothing() {
        let key = ShapeKey { kind: GemmKind::I8, m: 4, k: 8, n: 4 };
        let eng = Engine::serial();
        let out = resolve_tiles(&[key], &[], false, &eng);
        assert!(out.entries.is_empty());
        assert_eq!((out.cached, out.searched), (0, 0));
    }
}
