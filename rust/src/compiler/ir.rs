//! The serving IR (DESIGN.md §Inference-Compiler).
//!
//! Two levels, one lowering:
//!
//! - [`InferOp`] — the *export* IR: what `nn::Layer::export_infer` emits.
//!   Weights are still f32 tensors; schemes are attached but not applied.
//! - [`ExecOp`] — the *executable* IR: weights pre-quantized once (int8
//!   codes in the transposed BT/VNNI layout with column sums, int16 BT
//!   codes, or pre-fake-quantized f32), batch-norm already folded by the
//!   exporter. Both the unfused interpreter ([`super::interp`]) and the
//!   fusing plan compiler ([`super::fuse`]) consume this one definition —
//!   there is exactly one `InferOp → ExecOp` lowering, [`lower`], shared
//!   by every execution strategy.
//!
//! Lowering also validates the value-stack discipline (`Push` / `Swap` /
//! `AddPopRelu` / `ConcatPop`): a malformed op list — hand-built, or from a
//! future exporter bug — fails here with the op index named instead of
//! panicking inside a serve worker mid-batch.

use anyhow::{anyhow, Result};

use crate::fixedpoint::conv::Conv2dGeom;
use crate::fixedpoint::{gemm_simd, quantize, Scheme};
use crate::tensor::Tensor;

/// One forward-only primitive exported by an `nn` layer for serving
/// (DESIGN.md §Serving). Composite blocks lower to several ops around the
/// small value-stack ops ([`InferOp::Push`] / [`InferOp::Swap`] /
/// [`InferOp::AddPopRelu`] / [`InferOp::ConcatPop`]).
pub enum InferOp {
    /// Fully-connected `y = x̂·Ŵ + b`; schemes are present iff the layer
    /// trained quantized.
    Linear {
        /// Layer name (diagnostics only).
        name: String,
        /// Weight matrix, `din × dout` row-major.
        w: Tensor,
        /// Bias, length `dout`.
        b: Vec<f32>,
        /// Frozen weight scheme (from the layer's W controller).
        sw: Option<Scheme>,
        /// Frozen activation scheme (from the layer's X controller).
        sx: Option<Scheme>,
    },
    /// im2col convolution with the training-time geometry.
    Conv {
        /// Layer name (diagnostics only).
        name: String,
        /// Convolution geometry (channels, kernel, stride, padding).
        geom: Conv2dGeom,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Weights, `out_c × (in_c·kh·kw)` row-major.
        w: Tensor,
        /// Per-output-channel bias.
        b: Vec<f32>,
        /// Frozen weight scheme.
        sw: Option<Scheme>,
        /// Frozen activation (patch) scheme.
        sx: Option<Scheme>,
    },
    /// Depthwise 3×3 convolution (scalar kernel; quantization applies as
    /// fake-quant, matching training).
    Depthwise {
        /// Layer name (diagnostics only).
        name: String,
        /// Channel count.
        c: usize,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Stride.
        stride: usize,
        /// Per-channel 3×3 kernels, `c × 9`.
        w: Tensor,
        /// Frozen weight scheme.
        sw: Option<Scheme>,
        /// Frozen activation scheme.
        sx: Option<Scheme>,
    },
    /// Elementwise `max(0, x)`.
    Relu,
    /// 2×2 stride-2 max pool over `[n, c·h·w]`.
    MaxPool {
        /// Channels.
        c: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// Global average pool `[n, c·h·w] → [n, c]`.
    GlobalAvgPool {
        /// Channels.
        c: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// Batch-norm running statistics folded for evaluation:
    /// `y = γ·(x−μ)·istd + β` with `istd = 1/√(σ²+ε)` precomputed per
    /// channel (the expensive part of the eval pass — no sqrt at serve
    /// time, and bit-identical to `BatchNorm2d`'s eval branch).
    BnEval {
        /// Channels.
        c: usize,
        /// Spatial size per channel (`h·w`).
        hw: usize,
        /// Scale γ per channel.
        gamma: Vec<f32>,
        /// Shift β per channel.
        beta: Vec<f32>,
        /// Running mean μ per channel.
        mean: Vec<f32>,
        /// Folded inverse stddev `1/√(σ²+ε)` per channel.
        istd: Vec<f32>,
    },
    /// Save (duplicate) the current activation on the value stack —
    /// residual/branch entry.
    Push,
    /// Swap the current activation with the stack top — second-branch
    /// entry (the saved input becomes current again).
    Swap,
    /// Pop the saved tensor, add it to the current activation, then ReLU —
    /// residual exit (`relu(F(x) + x)`).
    AddPopRelu,
    /// Pop the saved tensor and channel-concatenate `[popped ; current]` —
    /// branch merge (Inception).
    ConcatPop {
        /// Channels of the popped (first) tensor.
        c_pop: usize,
        /// Channels of the current (second) tensor.
        c_cur: usize,
        /// Spatial size per channel.
        hw: usize,
    },
}

/// Pre-quantized weight form of one frozen linear layer.
pub(crate) enum LinKind {
    /// Unquantized f32 weights (`din × dout`).
    F32 { w: Tensor },
    /// int8 codes, pre-packed transposed (BT) with per-column sums for the
    /// VNNI bias trick.
    I8 { bt: Vec<i8>, colsum: Vec<i32>, sw: Scheme, sx: Scheme },
    /// int16 codes, pre-packed transposed.
    I16 { bt: Vec<i16>, sw: Scheme, sx: Scheme },
    /// Wider-than-16-bit scheme: pre-fake-quantized f32 weights, f32 GEMM.
    Fq { wq: Tensor, sx: Scheme },
}

pub(crate) struct ExecLinear {
    pub(crate) name: String,
    pub(crate) din: usize,
    pub(crate) dout: usize,
    pub(crate) b: Vec<f32>,
    pub(crate) kind: LinKind,
}

/// Pre-quantized weight form of one frozen convolution. The int weights
/// stay row-major (`out_c × rows`): they are the GEMM's *A* operand — it is
/// the per-image patch matrix that gets the BT treatment, at execution
/// time, via the fused `im2col_bt_*` kernels.
pub(crate) enum ConvKind {
    F32 { w: Vec<f32> },
    I8 { cw: Vec<i8>, sw: Scheme, sx: Scheme },
    I16 { cw: Vec<i16>, sw: Scheme, sx: Scheme },
    Fq { wq: Vec<f32>, sx: Scheme },
}

pub(crate) struct ExecConv {
    pub(crate) name: String,
    pub(crate) geom: Conv2dGeom,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) b: Vec<f32>,
    pub(crate) kind: ConvKind,
}

pub(crate) struct ExecDw {
    pub(crate) name: String,
    pub(crate) c: usize,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) stride: usize,
    /// Pre-fake-quantized (or plain f32) kernels, `c × 9`.
    pub(crate) wq: Vec<f32>,
    pub(crate) sx: Option<Scheme>,
}

/// Executable op: [`InferOp`] with weights pre-quantized/pre-packed once.
pub(crate) enum ExecOp {
    Linear(ExecLinear),
    Conv(ExecConv),
    Depthwise(ExecDw),
    Relu,
    MaxPool { c: usize, h: usize, w: usize },
    Gap { c: usize, h: usize, w: usize },
    Bn { c: usize, hw: usize, gamma: Vec<f32>, beta: Vec<f32>, mean: Vec<f32>, istd: Vec<f32> },
    Push,
    Swap,
    AddPopRelu,
    ConcatPop { c_pop: usize, c_cur: usize, hw: usize },
}

impl ExecOp {
    /// Short human-readable tag for compile reports and timing tables.
    pub(crate) fn describe(&self) -> String {
        match self {
            ExecOp::Linear(l) => {
                let k = match &l.kind {
                    LinKind::F32 { .. } => "f32",
                    LinKind::I8 { .. } => "i8",
                    LinKind::I16 { .. } => "i16",
                    LinKind::Fq { .. } => "fq",
                };
                format!("linear {} {k} [{}x{}]", l.name, l.din, l.dout)
            }
            ExecOp::Conv(cv) => {
                let k = match &cv.kind {
                    ConvKind::F32 { .. } => "f32",
                    ConvKind::I8 { .. } => "i8",
                    ConvKind::I16 { .. } => "i16",
                    ConvKind::Fq { .. } => "fq",
                };
                let g = cv.geom;
                format!("conv {} {k} [{}x{}x{}x{}]", cv.name, g.out_c, g.in_c, g.kh, g.kw)
            }
            ExecOp::Depthwise(dw) => format!("dw {} [c={}]", dw.name, dw.c),
            ExecOp::Relu => "relu".to_string(),
            ExecOp::MaxPool { .. } => "maxpool".to_string(),
            ExecOp::Gap { .. } => "gap".to_string(),
            ExecOp::Bn { .. } => "bn".to_string(),
            ExecOp::Push => "push".to_string(),
            ExecOp::Swap => "swap".to_string(),
            ExecOp::AddPopRelu => "add-pop-relu".to_string(),
            ExecOp::ConcatPop { .. } => "concat-pop".to_string(),
        }
    }
}

/// Result of [`lower`]: the executable op list plus the model facts every
/// execution strategy needs.
pub(crate) struct Lowered {
    /// Flattened per-sample input width (from the first GEMM-ish op).
    pub(crate) din: usize,
    /// `"f32"` / `"int8"` / `"int16"` — widest frozen scheme wins.
    pub(crate) precision: String,
    pub(crate) ops: Vec<ExecOp>,
}

/// Lower the export IR into executable ops: validate the value-stack
/// discipline, infer the input width, pre-quantize/pre-pack every weight
/// exactly once, and derive the serving precision label. The single
/// `InferOp → ExecOp` definition shared by the unfused interpreter and the
/// fusing compiler.
pub(crate) fn lower(label: &str, ops: Vec<InferOp>) -> Result<Lowered> {
    let din = match ops.first() {
        Some(InferOp::Linear { w, .. }) => w.dim(0),
        Some(InferOp::Conv { geom, in_h, in_w, .. }) => geom.in_c * in_h * in_w,
        Some(InferOp::Depthwise { c, in_h, in_w, .. }) => c * in_h * in_w,
        _ => {
            return Err(anyhow!(
                "cannot infer input width: model must start with a linear/conv layer"
            ))
        }
    };
    // Validate value-stack discipline at freeze time, so a malformed
    // export (hand-built op list, future layer bug) fails here with a
    // useful error instead of panicking inside a serve worker mid-batch.
    {
        let mut depth = 0usize;
        for (i, op) in ops.iter().enumerate() {
            let (need, delta): (usize, isize) = match op {
                InferOp::Push => (0, 1),
                InferOp::Swap => (1, 0),
                InferOp::AddPopRelu | InferOp::ConcatPop { .. } => (1, -1),
                _ => (0, 0),
            };
            if depth < need {
                return Err(anyhow!(
                    "op {i} of {label} underflows the serve value stack (depth {depth})"
                ));
            }
            depth = (depth as isize + delta) as usize;
        }
        if depth != 0 {
            return Err(anyhow!(
                "{label} leaves {depth} unconsumed tensor(s) on the serve value stack"
            ));
        }
    }
    let mut max_bits: Option<u8> = None;
    let mut note = |sw: &Option<Scheme>, sx: &Option<Scheme>| {
        for s in [sw, sx].into_iter().flatten() {
            max_bits = Some(max_bits.map_or(s.bits, |m| m.max(s.bits)));
        }
    };
    let mut exec = Vec::with_capacity(ops.len());
    for op in ops {
        exec.push(match op {
            InferOp::Linear { name, w, b, sw, sx } => {
                note(&sw, &sx);
                let (din_l, dout) = (w.dim(0), w.dim(1));
                let kind = match (sw, sx) {
                    (Some(sw), Some(sx)) if sw.bits <= 8 && sx.bits <= 8 => {
                        let mut bt = vec![0i8; w.len()];
                        let mut colsum = vec![0i32; dout];
                        gemm_simd::codes_i8_bt(din_l, dout, &w.data, sw, &mut bt, &mut colsum);
                        LinKind::I8 { bt, colsum, sw, sx }
                    }
                    (Some(sw), Some(sx)) if sw.bits <= 16 && sx.bits <= 16 => {
                        let mut cb = vec![0i16; w.len()];
                        quantize::codes_i16(&w.data, &mut cb, sw);
                        let mut bt = vec![0i16; w.len()];
                        gemm_simd::pack_bt_i16(din_l, dout, &cb, &mut bt);
                        LinKind::I16 { bt, sw, sx }
                    }
                    (Some(sw), Some(sx)) => {
                        let mut wq = w.clone();
                        quantize::fake_quant_stats_inplace(&mut wq.data, sw);
                        LinKind::Fq { wq, sx }
                    }
                    _ => LinKind::F32 { w },
                };
                ExecOp::Linear(ExecLinear { name, din: din_l, dout, b, kind })
            }
            InferOp::Conv { name, geom, in_h, in_w, w, b, sw, sx } => {
                note(&sw, &sx);
                let kind = match (sw, sx) {
                    (Some(sw), Some(sx)) if sw.bits <= 8 && sx.bits <= 8 => {
                        let mut cw = vec![0i8; w.len()];
                        quantize::codes_i8(&w.data, &mut cw, sw);
                        ConvKind::I8 { cw, sw, sx }
                    }
                    (Some(sw), Some(sx)) if sw.bits <= 16 && sx.bits <= 16 => {
                        let mut cw = vec![0i16; w.len()];
                        quantize::codes_i16(&w.data, &mut cw, sw);
                        ConvKind::I16 { cw, sw, sx }
                    }
                    (Some(sw), Some(sx)) => {
                        let mut wq = w.data.clone();
                        quantize::fake_quant_stats_inplace(&mut wq, sw);
                        ConvKind::Fq { wq, sx }
                    }
                    _ => ConvKind::F32 { w: w.data },
                };
                ExecOp::Conv(ExecConv { name, geom, in_h, in_w, b, kind })
            }
            InferOp::Depthwise { name, c, in_h, in_w, stride, w, sw, sx } => {
                note(&sw, &sx);
                let mut wq = w.data;
                if let Some(sw) = sw {
                    quantize::fake_quant_stats_inplace(&mut wq, sw);
                }
                ExecOp::Depthwise(ExecDw { name, c, in_h, in_w, stride, wq, sx })
            }
            InferOp::Relu => ExecOp::Relu,
            InferOp::MaxPool { c, h, w } => ExecOp::MaxPool { c, h, w },
            InferOp::GlobalAvgPool { c, h, w } => ExecOp::Gap { c, h, w },
            InferOp::BnEval { c, hw, gamma, beta, mean, istd } => {
                ExecOp::Bn { c, hw, gamma, beta, mean, istd }
            }
            InferOp::Push => ExecOp::Push,
            InferOp::Swap => ExecOp::Swap,
            InferOp::AddPopRelu => ExecOp::AddPopRelu,
            InferOp::ConcatPop { c_pop, c_cur, hw } => ExecOp::ConcatPop { c_pop, c_cur, hw },
        });
    }
    let precision = match max_bits {
        None => "f32".to_string(),
        Some(b) if b <= 8 => "int8".to_string(),
        Some(b) if b <= 16 => "int16".to_string(),
        Some(b) => format!("int{b}"),
    };
    Ok(Lowered { din, precision, ops: exec })
}
