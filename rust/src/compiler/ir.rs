//! The serving IR (DESIGN.md §Inference-Compiler).
//!
//! Two levels, one lowering:
//!
//! - [`InferOp`] — the *export* IR: what `nn::Layer::export_infer` emits.
//!   Weights are still f32 tensors; formats are attached but not applied.
//! - [`ExecOp`] — the *executable* IR: weights pre-quantized once (int8
//!   codes in the transposed BT/VNNI layout with column sums, int16 BT
//!   codes, nibble-packed int4 codes, or pre-fake-quantized f32),
//!   batch-norm already folded by the exporter. Both the unfused
//!   interpreter ([`super::interp`]) and the fusing plan compiler
//!   ([`super::fuse`]) consume this one definition — there is exactly one
//!   `InferOp → ExecOp` lowering, [`lower`], shared by every execution
//!   strategy.
//!
//! Lowering also validates the value-stack discipline (`Push` / `Swap` /
//! `AddPopRelu` / `ConcatPop`): a malformed op list — hand-built, or from a
//! future exporter bug — fails here with the op index named instead of
//! panicking inside a serve worker mid-batch.
//!
//! **Format dispatch.** The frozen formats are [`Format`]s, not bare
//! schemes. Any format with a fixed-point view (`as_scheme`) takes the
//! integer GEMM paths exactly as before — an 8-bit fixed format lowers to
//! the same `I8` kind byte-for-byte it always did. Minifloat formats have
//! no integer codes, so they lower to the fake-quant (`Fq`) kinds: weights
//! pre-fake-quantized through the codec once, activations fake-quantized
//! per forward, f32 GEMM. A freeze-time `weight_format` override
//! re-quantizes *weights only* into another family — `int4` nibble-packs
//! them two codes per byte (halving weight bytes vs int8) while
//! activations stay on their trained 8-bit scheme.

use anyhow::{anyhow, Result};

use crate::fixedpoint::conv::Conv2dGeom;
use crate::fixedpoint::{gemm_simd, pack_nibbles, quantize, Format, FormatFamily, Scheme};
use crate::tensor::Tensor;

/// One forward-only primitive exported by an `nn` layer for serving
/// (DESIGN.md §Serving). Composite blocks lower to several ops around the
/// small value-stack ops ([`InferOp::Push`] / [`InferOp::Swap`] /
/// [`InferOp::AddPopRelu`] / [`InferOp::ConcatPop`]).
pub enum InferOp {
    /// Fully-connected `y = x̂·Ŵ + b`; formats are present iff the layer
    /// trained quantized.
    Linear {
        /// Layer name (diagnostics only).
        name: String,
        /// Weight matrix, `din × dout` row-major.
        w: Tensor,
        /// Bias, length `dout`.
        b: Vec<f32>,
        /// Frozen weight format (from the layer's W controller).
        sw: Option<Format>,
        /// Frozen activation format (from the layer's X controller).
        sx: Option<Format>,
    },
    /// im2col convolution with the training-time geometry.
    Conv {
        /// Layer name (diagnostics only).
        name: String,
        /// Convolution geometry (channels, kernel, stride, padding).
        geom: Conv2dGeom,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Weights, `out_c × (in_c·kh·kw)` row-major.
        w: Tensor,
        /// Per-output-channel bias.
        b: Vec<f32>,
        /// Frozen weight format.
        sw: Option<Format>,
        /// Frozen activation (patch) format.
        sx: Option<Format>,
    },
    /// Depthwise 3×3 convolution (scalar kernel; quantization applies as
    /// fake-quant, matching training).
    Depthwise {
        /// Layer name (diagnostics only).
        name: String,
        /// Channel count.
        c: usize,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Stride.
        stride: usize,
        /// Per-channel 3×3 kernels, `c × 9`.
        w: Tensor,
        /// Frozen weight format.
        sw: Option<Format>,
        /// Frozen activation format.
        sx: Option<Format>,
    },
    /// Elementwise `max(0, x)`.
    Relu,
    /// 2×2 stride-2 max pool over `[n, c·h·w]`.
    MaxPool {
        /// Channels.
        c: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// Global average pool `[n, c·h·w] → [n, c]`.
    GlobalAvgPool {
        /// Channels.
        c: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// Batch-norm running statistics folded for evaluation:
    /// `y = γ·(x−μ)·istd + β` with `istd = 1/√(σ²+ε)` precomputed per
    /// channel (the expensive part of the eval pass — no sqrt at serve
    /// time, and bit-identical to `BatchNorm2d`'s eval branch).
    BnEval {
        /// Channels.
        c: usize,
        /// Spatial size per channel (`h·w`).
        hw: usize,
        /// Scale γ per channel.
        gamma: Vec<f32>,
        /// Shift β per channel.
        beta: Vec<f32>,
        /// Running mean μ per channel.
        mean: Vec<f32>,
        /// Folded inverse stddev `1/√(σ²+ε)` per channel.
        istd: Vec<f32>,
    },
    /// Save (duplicate) the current activation on the value stack —
    /// residual/branch entry.
    Push,
    /// Swap the current activation with the stack top — second-branch
    /// entry (the saved input becomes current again).
    Swap,
    /// Pop the saved tensor, add it to the current activation, then ReLU —
    /// residual exit (`relu(F(x) + x)`).
    AddPopRelu,
    /// Pop the saved tensor and channel-concatenate `[popped ; current]` —
    /// branch merge (Inception).
    ConcatPop {
        /// Channels of the popped (first) tensor.
        c_pop: usize,
        /// Channels of the current (second) tensor.
        c_cur: usize,
        /// Spatial size per channel.
        hw: usize,
    },
}

/// Pre-quantized weight form of one frozen linear layer.
pub(crate) enum LinKind {
    /// Unquantized f32 weights (`din × dout`).
    F32 { w: Tensor },
    /// int8 codes, pre-packed transposed (BT) with per-column sums for the
    /// VNNI bias trick.
    I8 { bt: Vec<i8>, colsum: Vec<i32>, sw: Scheme, sx: Scheme },
    /// int16 codes, pre-packed transposed.
    I16 { bt: Vec<i16>, sw: Scheme, sx: Scheme },
    /// Weight-only int4: BT-layout 4-bit codes nibble-packed two per byte
    /// (half the bytes of `I8`), unpacked to an i8 scratch at execution
    /// and fed to the same prepacked int8 GEMM. Activations stay int8.
    I4 { packed: Vec<u8>, colsum: Vec<i32>, sw: Scheme, sx: Scheme },
    /// No integer kernel for the format pair (minifloat, or wider than 16
    /// bits): pre-fake-quantized f32 weights, fake-quant activations, f32
    /// GEMM.
    Fq { wq: Tensor, sx: Format },
}

pub(crate) struct ExecLinear {
    pub(crate) name: String,
    pub(crate) din: usize,
    pub(crate) dout: usize,
    pub(crate) b: Vec<f32>,
    pub(crate) kind: LinKind,
}

/// Pre-quantized weight form of one frozen convolution. The int weights
/// stay row-major (`out_c × rows`): they are the GEMM's *A* operand — it is
/// the per-image patch matrix that gets the BT treatment, at execution
/// time, via the fused `im2col_bt_*` kernels.
pub(crate) enum ConvKind {
    F32 { w: Vec<f32> },
    I8 { cw: Vec<i8>, sw: Scheme, sx: Scheme },
    I16 { cw: Vec<i16>, sw: Scheme, sx: Scheme },
    /// Weight-only int4: row-major 4-bit codes nibble-packed; unpacked
    /// once per forward into an i8 scratch for the int8 conv GEMM.
    I4 { packed: Vec<u8>, sw: Scheme, sx: Scheme },
    Fq { wq: Vec<f32>, sx: Format },
}

pub(crate) struct ExecConv {
    pub(crate) name: String,
    pub(crate) geom: Conv2dGeom,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) b: Vec<f32>,
    pub(crate) kind: ConvKind,
}

pub(crate) struct ExecDw {
    pub(crate) name: String,
    pub(crate) c: usize,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) stride: usize,
    /// Pre-fake-quantized (or plain f32) kernels, `c × 9`.
    pub(crate) wq: Vec<f32>,
    pub(crate) sx: Option<Format>,
}

/// Executable op: [`InferOp`] with weights pre-quantized/pre-packed once.
pub(crate) enum ExecOp {
    Linear(ExecLinear),
    Conv(ExecConv),
    Depthwise(ExecDw),
    Relu,
    MaxPool { c: usize, h: usize, w: usize },
    Gap { c: usize, h: usize, w: usize },
    Bn { c: usize, hw: usize, gamma: Vec<f32>, beta: Vec<f32>, mean: Vec<f32>, istd: Vec<f32> },
    Push,
    Swap,
    AddPopRelu,
    ConcatPop { c_pop: usize, c_cur: usize, hw: usize },
}

impl ExecOp {
    /// Short human-readable tag for compile reports and timing tables.
    pub(crate) fn describe(&self) -> String {
        match self {
            ExecOp::Linear(l) => {
                let k = match &l.kind {
                    LinKind::F32 { .. } => "f32",
                    LinKind::I8 { .. } => "i8",
                    LinKind::I16 { .. } => "i16",
                    LinKind::I4 { .. } => "i4w",
                    LinKind::Fq { sx, .. } => match sx.family() {
                        FormatFamily::E4M3 => "e4m3",
                        FormatFamily::E5M2 => "e5m2",
                        _ => "fq",
                    },
                };
                format!("linear {} {k} [{}x{}]", l.name, l.din, l.dout)
            }
            ExecOp::Conv(cv) => {
                let k = match &cv.kind {
                    ConvKind::F32 { .. } => "f32",
                    ConvKind::I8 { .. } => "i8",
                    ConvKind::I16 { .. } => "i16",
                    ConvKind::I4 { .. } => "i4w",
                    ConvKind::Fq { sx, .. } => match sx.family() {
                        FormatFamily::E4M3 => "e4m3",
                        FormatFamily::E5M2 => "e5m2",
                        _ => "fq",
                    },
                };
                let g = cv.geom;
                format!("conv {} {k} [{}x{}x{}x{}]", cv.name, g.out_c, g.in_c, g.kh, g.kw)
            }
            ExecOp::Depthwise(dw) => format!("dw {} [c={}]", dw.name, dw.c),
            ExecOp::Relu => "relu".to_string(),
            ExecOp::MaxPool { .. } => "maxpool".to_string(),
            ExecOp::Gap { .. } => "gap".to_string(),
            ExecOp::Bn { .. } => "bn".to_string(),
            ExecOp::Push => "push".to_string(),
            ExecOp::Swap => "swap".to_string(),
            ExecOp::AddPopRelu => "add-pop-relu".to_string(),
            ExecOp::ConcatPop { .. } => "concat-pop".to_string(),
        }
    }
}

/// Result of [`lower`]: the executable op list plus the model facts every
/// execution strategy needs.
pub(crate) struct Lowered {
    /// Flattened per-sample input width (from the first GEMM-ish op).
    pub(crate) din: usize,
    /// `"f32"` / `"int8"` / `"int16"` / a format-family label (`"e4m3"`,
    /// `"int4w"` for the weight-only override) — widest frozen format wins.
    pub(crate) precision: String,
    pub(crate) ops: Vec<ExecOp>,
}

/// Bytes of pre-packed weight payload across the executable program (codes
/// or f32 values; per-column sums and biases excluded). This is the number
/// the int4 weight-only path halves vs int8 — surfaced in the compile
/// report.
pub(crate) fn weight_bytes(ops: &[ExecOp]) -> usize {
    ops.iter()
        .map(|op| match op {
            ExecOp::Linear(l) => match &l.kind {
                LinKind::F32 { w } => 4 * w.len(),
                LinKind::Fq { wq, .. } => 4 * wq.len(),
                LinKind::I8 { bt, .. } => bt.len(),
                LinKind::I16 { bt, .. } => 2 * bt.len(),
                LinKind::I4 { packed, .. } => packed.len(),
            },
            ExecOp::Conv(cv) => match &cv.kind {
                ConvKind::F32 { w } => 4 * w.len(),
                ConvKind::Fq { wq, .. } => 4 * wq.len(),
                ConvKind::I8 { cw, .. } => cw.len(),
                ConvKind::I16 { cw, .. } => 2 * cw.len(),
                ConvKind::I4 { packed, .. } => packed.len(),
            },
            ExecOp::Depthwise(dw) => 4 * dw.wq.len(),
            _ => 0,
        })
        .sum()
}

/// Apply the freeze-time weight-format override: re-derive the weight
/// format in the requested family from the frozen weights' own range.
/// `FixedPoint` (or no override) keeps the trained format — the layer's
/// controller already chose it.
fn effective_weight_format(fw: Format, w: &[f32], over: Option<FormatFamily>) -> Format {
    match over {
        None | Some(FormatFamily::FixedPoint) => fw,
        Some(fam) => {
            let z = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            Format::for_range(fam, z, fw.storage_bits().max(4))
        }
    }
}

/// Lower the export IR into executable ops: validate the value-stack
/// discipline, infer the input width, pre-quantize/pre-pack every weight
/// exactly once, and derive the serving precision label. The single
/// `InferOp → ExecOp` definition shared by the unfused interpreter and the
/// fusing compiler. `weight_format` is the freeze-time weight-only
/// re-quantization override (`CompileOptions::weight_format`); it only
/// applies to layers that trained quantized.
pub(crate) fn lower(
    label: &str,
    ops: Vec<InferOp>,
    weight_format: Option<FormatFamily>,
) -> Result<Lowered> {
    let din = match ops.first() {
        Some(InferOp::Linear { w, .. }) => w.dim(0),
        Some(InferOp::Conv { geom, in_h, in_w, .. }) => geom.in_c * in_h * in_w,
        Some(InferOp::Depthwise { c, in_h, in_w, .. }) => c * in_h * in_w,
        _ => {
            return Err(anyhow!(
                "cannot infer input width: model must start with a linear/conv layer"
            ))
        }
    };
    // Validate value-stack discipline at freeze time, so a malformed
    // export (hand-built op list, future layer bug) fails here with a
    // useful error instead of panicking inside a serve worker mid-batch.
    {
        let mut depth = 0usize;
        for (i, op) in ops.iter().enumerate() {
            let (need, delta): (usize, isize) = match op {
                InferOp::Push => (0, 1),
                InferOp::Swap => (1, 0),
                InferOp::AddPopRelu | InferOp::ConcatPop { .. } => (1, -1),
                _ => (0, 0),
            };
            if depth < need {
                return Err(anyhow!(
                    "op {i} of {label} underflows the serve value stack (depth {depth})"
                ));
            }
            depth = (depth as isize + delta) as usize;
        }
        if depth != 0 {
            return Err(anyhow!(
                "{label} leaves {depth} unconsumed tensor(s) on the serve value stack"
            ));
        }
    }
    let mut max_bits: Option<u8> = None;
    let mut fams: Vec<FormatFamily> = Vec::new();
    let mut note = |sw: &Option<Format>, sx: &Option<Format>| {
        for f in [sw, sx].into_iter().flatten() {
            match f {
                Format::FixedPoint(s) => {
                    max_bits = Some(max_bits.map_or(s.bits, |m| m.max(s.bits)))
                }
                _ => {
                    let fam = f.family();
                    if !fams.contains(&fam) {
                        fams.push(fam);
                    }
                }
            }
        }
    };
    let mut exec = Vec::with_capacity(ops.len());
    for op in ops {
        exec.push(match op {
            InferOp::Linear { name, w, b, sw, sx } => {
                note(&sw, &sx);
                let (din_l, dout) = (w.dim(0), w.dim(1));
                let kind = match (sw, sx) {
                    (Some(fw), Some(fx)) => {
                        let fw = effective_weight_format(fw, &w.data, weight_format);
                        match (fw.as_scheme(), fx.as_scheme()) {
                            (Some(ws), Some(xs))
                                if fw.family() == FormatFamily::Int4 && xs.bits <= 8 =>
                            {
                                let mut bt = vec![0i8; w.len()];
                                let mut colsum = vec![0i32; dout];
                                gemm_simd::codes_i8_bt(din_l, dout, &w.data, ws, &mut bt, &mut colsum);
                                LinKind::I4 { packed: pack_nibbles(&bt), colsum, sw: ws, sx: xs }
                            }
                            (Some(ws), Some(xs)) if ws.bits <= 8 && xs.bits <= 8 => {
                                let mut bt = vec![0i8; w.len()];
                                let mut colsum = vec![0i32; dout];
                                gemm_simd::codes_i8_bt(din_l, dout, &w.data, ws, &mut bt, &mut colsum);
                                LinKind::I8 { bt, colsum, sw: ws, sx: xs }
                            }
                            (Some(ws), Some(xs)) if ws.bits <= 16 && xs.bits <= 16 => {
                                let mut cb = vec![0i16; w.len()];
                                quantize::codes_i16(&w.data, &mut cb, ws);
                                let mut bt = vec![0i16; w.len()];
                                gemm_simd::pack_bt_i16(din_l, dout, &cb, &mut bt);
                                LinKind::I16 { bt, sw: ws, sx: xs }
                            }
                            _ => {
                                let mut wq = w.clone();
                                quantize::fake_quant_stats_inplace_fmt(&mut wq.data, fw);
                                LinKind::Fq { wq, sx: fx }
                            }
                        }
                    }
                    // Activation-only quantization (PTQ per-channel freeze):
                    // the weights arrive already quantized — per-channel, so
                    // no single per-tensor format could re-derive them — and
                    // only the calibrated activation format remains to apply.
                    (None, Some(fx)) => LinKind::Fq { wq: w, sx: fx },
                    _ => LinKind::F32 { w },
                };
                ExecOp::Linear(ExecLinear { name, din: din_l, dout, b, kind })
            }
            InferOp::Conv { name, geom, in_h, in_w, w, b, sw, sx } => {
                note(&sw, &sx);
                let kind = match (sw, sx) {
                    (Some(fw), Some(fx)) => {
                        let fw = effective_weight_format(fw, &w.data, weight_format);
                        match (fw.as_scheme(), fx.as_scheme()) {
                            (Some(ws), Some(xs))
                                if fw.family() == FormatFamily::Int4 && xs.bits <= 8 =>
                            {
                                let mut cw = vec![0i8; w.len()];
                                quantize::codes_i8(&w.data, &mut cw, ws);
                                ConvKind::I4 { packed: pack_nibbles(&cw), sw: ws, sx: xs }
                            }
                            (Some(ws), Some(xs)) if ws.bits <= 8 && xs.bits <= 8 => {
                                let mut cw = vec![0i8; w.len()];
                                quantize::codes_i8(&w.data, &mut cw, ws);
                                ConvKind::I8 { cw, sw: ws, sx: xs }
                            }
                            (Some(ws), Some(xs)) if ws.bits <= 16 && xs.bits <= 16 => {
                                let mut cw = vec![0i16; w.len()];
                                quantize::codes_i16(&w.data, &mut cw, ws);
                                ConvKind::I16 { cw, sw: ws, sx: xs }
                            }
                            _ => {
                                let mut wq = w.data.clone();
                                quantize::fake_quant_stats_inplace_fmt(&mut wq, fw);
                                ConvKind::Fq { wq, sx: fx }
                            }
                        }
                    }
                    // Activation-only quantization — see the linear arm.
                    (None, Some(fx)) => ConvKind::Fq { wq: w.data, sx: fx },
                    _ => ConvKind::F32 { w: w.data },
                };
                ExecOp::Conv(ExecConv { name, geom, in_h, in_w, b, kind })
            }
            InferOp::Depthwise { name, c, in_h, in_w, stride, w, sw, sx } => {
                note(&sw, &sx);
                let mut wq = w.data;
                if let Some(fw) = sw {
                    let fw = effective_weight_format(fw, &wq, weight_format);
                    quantize::fake_quant_stats_inplace_fmt(&mut wq, fw);
                }
                ExecOp::Depthwise(ExecDw { name, c, in_h, in_w, stride, wq, sx })
            }
            InferOp::Relu => ExecOp::Relu,
            InferOp::MaxPool { c, h, w } => ExecOp::MaxPool { c, h, w },
            InferOp::GlobalAvgPool { c, h, w } => ExecOp::Gap { c, h, w },
            InferOp::BnEval { c, hw, gamma, beta, mean, istd } => {
                ExecOp::Bn { c, hw, gamma, beta, mean, istd }
            }
            InferOp::Push => ExecOp::Push,
            InferOp::Swap => ExecOp::Swap,
            InferOp::AddPopRelu => ExecOp::AddPopRelu,
            InferOp::ConcatPop { c_pop, c_cur, hw } => ExecOp::ConcatPop { c_pop, c_cur, hw },
        });
    }
    let precision = if let Some(fam) = weight_format.filter(|f| *f != FormatFamily::FixedPoint) {
        // Weight-only override: label it distinctly (`int4w` = int4
        // weights over the trained activation formats).
        format!("{}w", fam.label())
    } else if fams.len() == 1 {
        fams[0].label().to_string()
    } else if fams.len() > 1 {
        "mixed".to_string()
    } else {
        match max_bits {
            None => "f32".to_string(),
            Some(b) if b <= 8 => "int8".to_string(),
            Some(b) if b <= 16 => "int16".to_string(),
            Some(b) => format!("int{b}"),
        }
    };
    Ok(Lowered { din, precision, ops: exec })
}
