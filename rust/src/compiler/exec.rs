//! Fused plan executor (DESIGN.md §Inference-Compiler).
//!
//! Runs an [`ExecPlan`] produced by [`super::fuse`]: each GEMM step applies
//! its whole epilogue (bias → folded BN → residual add → ReLU) in one pass
//! over the accumulator block and, when the next consumer is an integer
//! layer, emits quantized codes directly — the activation flowing between
//! fused steps is an [`Act`] that can be int8/int16 codes, with max-pools
//! executed on the codes themselves. Bit-identity with the unfused
//! interpreter holds because every scalar f32 operation happens in exactly
//! the same order with exactly the same formula (see DESIGN.md for the
//! per-rewrite legality arguments); the `test_compiler` integration tests
//! pin it per model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::fixedpoint::conv::{
    im2col, im2col_bt_codes_i16, im2col_bt_codes_i8, im2col_bt_quant_i16, im2col_bt_quant_i8,
};
use crate::fixedpoint::{quantize, unpack_nibbles, Scheme};
use crate::kernels::Engine;
use crate::tensor::Tensor;

use super::fuse::{Emit, Epilogue, ExecPlan, Step};
use super::interp::{self, dw_channel};
use super::ir::{ConvKind, ExecConv, ExecDw, ExecLinear, ExecOp, LinKind};

/// Cumulative wall-time for one plan step (or one interpreter op), shared
/// across serve workers — hence atomics, not a `Cell`.
pub(crate) struct StepTimer {
    ns: AtomicU64,
    calls: AtomicU64,
}

impl StepTimer {
    pub(crate) fn new() -> Self {
        StepTimer { ns: AtomicU64::new(0), calls: AtomicU64::new(0) }
    }

    pub(crate) fn add(&self, d: Duration) {
        self.ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
    }

    /// (total nanoseconds, call count).
    pub(crate) fn snapshot(&self) -> (u64, u64) {
        (self.ns.load(Ordering::Relaxed), self.calls.load(Ordering::Relaxed))
    }
}

/// The activation flowing between plan steps: plain f32, or quantized
/// codes tagged with their scheme (what the next integer GEMM would have
/// produced by quantizing the f32 tensor — kept in code space instead).
pub(crate) enum Act {
    F32(Tensor),
    I8 { codes: Vec<i8>, n: usize, d: usize, s: Scheme },
    I16 { codes: Vec<i16>, n: usize, d: usize, s: Scheme },
}

impl Act {
    fn rows(&self) -> usize {
        match self {
            Act::F32(t) => t.dim(0),
            Act::I8 { n, .. } | Act::I16 { n, .. } => *n,
        }
    }
}

fn expect_f32(act: Act) -> Tensor {
    match act {
        Act::F32(t) => t,
        _ => panic!("fused plan invariant violated: codes reached a step expecting f32"),
    }
}

/// Execute a compiled plan. `timers` may be empty (no timing) or hold one
/// slot per step.
pub(crate) fn run_plan(
    plan: &ExecPlan,
    ops: &[ExecOp],
    x: &Tensor,
    eng: &Engine,
    timers: &[StepTimer],
) -> Tensor {
    let mut act = Act::F32(x.clone());
    let mut stack: Vec<Tensor> = Vec::new();
    for (si, step) in plan.steps.iter().enumerate() {
        let t0 = std::time::Instant::now();
        act = run_step(step, ops, act, &mut stack, eng);
        if let Some(t) = timers.get(si) {
            t.add(t0.elapsed());
        }
    }
    // The fuse pass always emits f32 at the terminal op (no consumer).
    expect_f32(act)
}

fn run_step(step: &Step, ops: &[ExecOp], act: Act, stack: &mut Vec<Tensor>, eng: &Engine) -> Act {
    match step {
        Step::Linear { op, epi, tile } => {
            let l = match &ops[*op] {
                ExecOp::Linear(l) => l,
                _ => unreachable!("plan step/op mismatch"),
            };
            run_linear(l, epi, *tile, act, stack, eng)
        }
        Step::Conv { op, epi, tile } => {
            let cv = match &ops[*op] {
                ExecOp::Conv(cv) => cv,
                _ => unreachable!("plan step/op mismatch"),
            };
            run_conv(cv, epi.bn.map(|bi| &ops[bi]), epi, *tile, act, stack, eng)
        }
        Step::Dw { op, relu, emit } => {
            let dw = match &ops[*op] {
                ExecOp::Depthwise(dw) => dw,
                _ => unreachable!("plan step/op mismatch"),
            };
            run_dw(dw, *relu, emit, act)
        }
        Step::PoolI8 { op } | Step::PoolI16 { op } => {
            let (c, h, w) = match &ops[*op] {
                ExecOp::MaxPool { c, h, w } => (*c, *h, *w),
                _ => unreachable!("plan step/op mismatch"),
            };
            pool_codes(c, h, w, act)
        }
        Step::Op(i) => {
            let cur = expect_f32(act);
            Act::F32(interp::apply_op(&ops[*i], cur, stack, eng))
        }
    }
}

/// Quantize a finished f32 activation into the form the next step wants.
/// Uses the exact consumer-side formulas (`Engine::codes_*`), so a codes
/// emit is bit-identical to handing the consumer the f32 tensor.
fn emit_tensor(y: Tensor, emit: &Emit, eng: &Engine) -> Act {
    match emit {
        Emit::F32 => Act::F32(y),
        Emit::I8(s) => {
            let (n, d) = (y.dim(0), y.dim(1));
            let mut codes = vec![0i8; y.len()];
            eng.codes_i8(&y.data, &mut codes, *s);
            Act::I8 { codes, n, d, s: *s }
        }
        Emit::I16(s) => {
            let (n, d) = (y.dim(0), y.dim(1));
            let mut codes = vec![0i16; y.len()];
            eng.codes_i16(&y.data, &mut codes, *s);
            Act::I16 { codes, n, d, s: *s }
        }
    }
}

/// Fused linear: GEMM (codes in when the producer already emitted them) +
/// bias + optional residual add + ReLU + emit, with the caller's tile.
fn run_linear(
    l: &ExecLinear,
    epi: &Epilogue,
    tile: crate::fixedpoint::gemm::Tile,
    act: Act,
    stack: &mut Vec<Tensor>,
    eng: &Engine,
) -> Act {
    debug_assert!(epi.bn.is_none(), "BN never fuses into linear");
    let m = act.rows();
    let saved = if epi.add_pop {
        Some(stack.pop().expect("fused plan stack underflow (validated at lower time)"))
    } else {
        None
    };
    let mut y = match &l.kind {
        LinKind::F32 { w } => {
            let x = expect_f32(act);
            assert_eq!(x.dim(1), l.din, "linear input width");
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.gemm_f32_tiled(m, l.din, l.dout, &x.data, &w.data, &mut y.data, tile);
            y
        }
        LinKind::Fq { wq, sx } => {
            let mut xq = expect_f32(act);
            assert_eq!(xq.dim(1), l.din, "linear input width");
            eng.fake_quant_fmt(&mut xq.data, *sx);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.gemm_f32_tiled(m, l.din, l.dout, &xq.data, &wq.data, &mut y.data, tile);
            y
        }
        LinKind::I8 { bt, colsum, sw, sx } => {
            let mut cab: Vec<i8> = Vec::new();
            let ca: &[i8] = match &act {
                Act::I8 { codes, d, s, .. } => {
                    assert_eq!(*d, l.din, "linear input width");
                    debug_assert_eq!(*s, *sx, "producer emitted codes at the wrong scheme");
                    codes
                }
                Act::F32(x) => {
                    assert_eq!(x.dim(1), l.din, "linear input width");
                    cab = vec![0i8; x.len()];
                    eng.codes_i8(&x.data, &mut cab, *sx);
                    &cab
                }
                Act::I16 { .. } => panic!("fused plan invariant violated: i16 codes at i8 linear"),
            };
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i8_prepacked_tiled(m, l.din, l.dout, ca, bt, colsum, &mut acc, tile);
            drop(cab);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y
        }
        LinKind::I4 { packed, colsum, sw, sx } => {
            // Weight-only int4: unpack the nibble-packed BT codes into an
            // i8 scratch, then the path is identical to the i8 kind.
            let mut bt = vec![0i8; l.din * l.dout];
            unpack_nibbles(packed, &mut bt);
            let mut cab: Vec<i8> = Vec::new();
            let ca: &[i8] = match &act {
                Act::I8 { codes, d, s, .. } => {
                    assert_eq!(*d, l.din, "linear input width");
                    debug_assert_eq!(*s, *sx, "producer emitted codes at the wrong scheme");
                    codes
                }
                Act::F32(x) => {
                    assert_eq!(x.dim(1), l.din, "linear input width");
                    cab = vec![0i8; x.len()];
                    eng.codes_i8(&x.data, &mut cab, *sx);
                    &cab
                }
                Act::I16 { .. } => panic!("fused plan invariant violated: i16 codes at i4 linear"),
            };
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i8_prepacked_tiled(m, l.din, l.dout, ca, &bt, colsum, &mut acc, tile);
            drop(cab);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y
        }
        LinKind::I16 { bt, sw, sx } => {
            let mut cab: Vec<i16> = Vec::new();
            let ca: &[i16] = match &act {
                Act::I16 { codes, d, s, .. } => {
                    assert_eq!(*d, l.din, "linear input width");
                    debug_assert_eq!(*s, *sx, "producer emitted codes at the wrong scheme");
                    codes
                }
                Act::F32(x) => {
                    assert_eq!(x.dim(1), l.din, "linear input width");
                    cab = vec![0i16; x.len()];
                    eng.codes_i16(&x.data, &mut cab, *sx);
                    &cab
                }
                Act::I8 { .. } => panic!("fused plan invariant violated: i8 codes at i16 linear"),
            };
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i16_prepacked_tiled(m, l.din, l.dout, ca, bt, &mut acc, tile);
            drop(cab);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y
        }
    };
    // Same scalar chain, same order as the unfused interpreter:
    // bias → residual add → ReLU.
    y.add_row_bias(&l.b);
    if let Some(sv) = &saved {
        y.add_inplace(sv);
    }
    if epi.relu {
        y.map_inplace(|v| v.max(0.0));
    }
    emit_tensor(y, &epi.emit, eng)
}

enum ConvOut {
    F(Tensor),
    C8(Vec<i8>, Scheme),
    C16(Vec<i16>, Scheme),
}

/// Fused conv: per image, im2col straight into the BT layout (gathering
/// producer codes when available), prepacked GEMM with the caller's tile,
/// then one epilogue pass (bias → BN → residual add → ReLU) over the
/// accumulator block, emitted per the plan.
#[allow(clippy::too_many_arguments)]
fn run_conv(
    cv: &ExecConv,
    bn_op: Option<&ExecOp>,
    epi: &Epilogue,
    tile: crate::fixedpoint::gemm::Tile,
    act: Act,
    stack: &mut Vec<Tensor>,
    eng: &Engine,
) -> Act {
    let g = cv.geom;
    let (h, w) = (cv.in_h, cv.in_w);
    let (rows, cols) = g.im2col_dims(h, w);
    let d_in = g.in_c * h * w;
    let d_out = g.out_c * cols;
    let n = act.rows();
    match &act {
        Act::F32(x) => assert_eq!(x.dim(1), d_in, "conv input size"),
        Act::I8 { d, .. } | Act::I16 { d, .. } => assert_eq!(*d, d_in, "conv input size"),
    }
    let saved = if epi.add_pop {
        Some(stack.pop().expect("fused plan stack underflow (validated at lower time)"))
    } else {
        None
    };
    let bnp = bn_op.map(|op| match op {
        ExecOp::Bn { gamma, beta, mean, istd, .. } => (gamma, beta, mean, istd),
        _ => unreachable!("plan epilogue bn index must point at a BN op"),
    });
    // Per-image scratch (loop-invariant sizes, fully overwritten each pass).
    let (mut btp8, mut btp16) = (Vec::new(), Vec::new());
    let (mut colsum, mut acc, mut patch) = (Vec::new(), Vec::new(), Vec::new());
    let mut cw8 = Vec::new();
    match &cv.kind {
        ConvKind::I8 { .. } => {
            btp8 = vec![0i8; rows * cols];
            colsum = vec![0i32; cols];
            acc = vec![0i32; g.out_c * cols];
        }
        ConvKind::I4 { packed, .. } => {
            btp8 = vec![0i8; rows * cols];
            colsum = vec![0i32; cols];
            acc = vec![0i32; g.out_c * cols];
            // Unpack the weight nibbles once per forward (loop-invariant).
            cw8 = vec![0i8; g.out_c * rows];
            unpack_nibbles(packed, &mut cw8);
        }
        ConvKind::I16 { .. } => {
            btp16 = vec![0i16; rows * cols];
            acc = vec![0i32; g.out_c * cols];
        }
        _ => patch = vec![0.0f32; rows * cols],
    }
    let mut vb = vec![0.0f32; d_out];
    let mut out = match &epi.emit {
        Emit::F32 => ConvOut::F(Tensor::zeros(&[n, d_out])),
        Emit::I8(s) => ConvOut::C8(vec![0i8; n * d_out], *s),
        Emit::I16(s) => ConvOut::C16(vec![0i16; n * d_out], *s),
    };
    for img in 0..n {
        // 1. GEMM block for this image, rescaled into `vb` (f32).
        match &cv.kind {
            ConvKind::I8 { cw, sw, sx } => {
                match &act {
                    Act::F32(x) => {
                        let xi = &x.data[img * d_in..(img + 1) * d_in];
                        im2col_bt_quant_i8(g, h, w, xi, *sx, &mut btp8, &mut colsum);
                    }
                    Act::I8 { codes, s, .. } => {
                        debug_assert_eq!(*s, *sx, "producer emitted codes at the wrong scheme");
                        let ci = &codes[img * d_in..(img + 1) * d_in];
                        im2col_bt_codes_i8(g, h, w, ci, &mut btp8, &mut colsum);
                    }
                    Act::I16 { .. } => {
                        panic!("fused plan invariant violated: i16 codes at i8 conv")
                    }
                }
                eng.gemm_i8_prepacked_tiled(g.out_c, rows, cols, cw, &btp8, &colsum, &mut acc, tile);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut vb);
            }
            ConvKind::I4 { sw, sx, .. } => {
                match &act {
                    Act::F32(x) => {
                        let xi = &x.data[img * d_in..(img + 1) * d_in];
                        im2col_bt_quant_i8(g, h, w, xi, *sx, &mut btp8, &mut colsum);
                    }
                    Act::I8 { codes, s, .. } => {
                        debug_assert_eq!(*s, *sx, "producer emitted codes at the wrong scheme");
                        let ci = &codes[img * d_in..(img + 1) * d_in];
                        im2col_bt_codes_i8(g, h, w, ci, &mut btp8, &mut colsum);
                    }
                    Act::I16 { .. } => {
                        panic!("fused plan invariant violated: i16 codes at i4 conv")
                    }
                }
                eng.gemm_i8_prepacked_tiled(g.out_c, rows, cols, &cw8, &btp8, &colsum, &mut acc, tile);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut vb);
            }
            ConvKind::I16 { cw, sw, sx } => {
                match &act {
                    Act::F32(x) => {
                        let xi = &x.data[img * d_in..(img + 1) * d_in];
                        im2col_bt_quant_i16(g, h, w, xi, *sx, &mut btp16);
                    }
                    Act::I16 { codes, s, .. } => {
                        debug_assert_eq!(*s, *sx, "producer emitted codes at the wrong scheme");
                        let ci = &codes[img * d_in..(img + 1) * d_in];
                        im2col_bt_codes_i16(g, h, w, ci, &mut btp16);
                    }
                    Act::I8 { .. } => {
                        panic!("fused plan invariant violated: i8 codes at i16 conv")
                    }
                }
                eng.gemm_i16_prepacked_tiled(g.out_c, rows, cols, cw, &btp16, &mut acc, tile);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut vb);
            }
            ConvKind::F32 { w: wt } => {
                let x = match &act {
                    Act::F32(x) => x,
                    _ => panic!("fused plan invariant violated: codes at f32 conv"),
                };
                let xi = &x.data[img * d_in..(img + 1) * d_in];
                im2col(g, h, w, xi, &mut patch);
                eng.gemm_f32_tiled(g.out_c, rows, cols, wt, &patch, &mut vb, tile);
            }
            ConvKind::Fq { wq, sx } => {
                let x = match &act {
                    Act::F32(x) => x,
                    _ => panic!("fused plan invariant violated: codes at fq conv"),
                };
                let xi = &x.data[img * d_in..(img + 1) * d_in];
                im2col(g, h, w, xi, &mut patch);
                eng.fake_quant_fmt(&mut patch, *sx);
                eng.gemm_f32_tiled(g.out_c, rows, cols, wq, &patch, &mut vb, tile);
            }
        }
        // 2. Single epilogue pass, identical scalar chain/order to the
        // unfused ops: +bias, then BN, then residual add, then ReLU.
        for oc in 0..g.out_c {
            let bv = cv.b[oc];
            for j in 0..cols {
                let idx = oc * cols + j;
                let mut v = vb[idx] + bv;
                if let Some((ga, be, mu, is)) = &bnp {
                    v = ga[oc] * (v - mu[oc]) * is[oc] + be[oc];
                }
                if let Some(sv) = &saved {
                    v += sv.data[img * d_out + idx];
                }
                if epi.relu {
                    v = v.max(0.0);
                }
                vb[idx] = v;
            }
        }
        // 3. Emit this image's block.
        match &mut out {
            ConvOut::F(t) => t.data[img * d_out..(img + 1) * d_out].copy_from_slice(&vb),
            ConvOut::C8(codes, s) => {
                quantize::codes_i8(&vb, &mut codes[img * d_out..(img + 1) * d_out], *s)
            }
            ConvOut::C16(codes, s) => {
                quantize::codes_i16(&vb, &mut codes[img * d_out..(img + 1) * d_out], *s)
            }
        }
    }
    match out {
        ConvOut::F(t) => Act::F32(t),
        ConvOut::C8(codes, s) => Act::I8 { codes, n, d: d_out, s },
        ConvOut::C16(codes, s) => Act::I16 { codes, n, d: d_out, s },
    }
}

/// Fused depthwise conv. Producer codes dequantize exactly to the
/// fake-quantized input the unfused path computes (`code · 2^s` is exact in
/// f32 for every representable code), so accepting codes loses nothing.
fn run_dw(dw: &ExecDw, relu: bool, emit: &Emit, act: Act) -> Act {
    {
        let (c, h, w, stride) = (dw.c, dw.in_h, dw.in_w, dw.stride);
        let d_in = c * h * w;
        let (oh, ow) = ((h + 2 - 3) / stride + 1, (w + 2 - 3) / stride + 1);
        let xq: Tensor = match act {
            Act::F32(x) => {
                assert_eq!(x.dim(1), d_in, "depthwise input size");
                match dw.sx {
                    None => x,
                    Some(fx) => {
                        let mut xq = x;
                        quantize::fake_quant_stats_inplace_fmt(&mut xq.data, fx);
                        xq
                    }
                }
            }
            Act::I8 { codes, n, d, s } => {
                assert_eq!(d, d_in, "depthwise input size");
                debug_assert_eq!(
                    Some(s),
                    dw.sx.and_then(|f| f.as_scheme()),
                    "producer emitted codes at the wrong scheme"
                );
                let r = s.resolution();
                let mut xq = Tensor::zeros(&[n, d]);
                for (o, &cd) in xq.data.iter_mut().zip(&codes) {
                    *o = cd as f32 * r;
                }
                xq
            }
            Act::I16 { codes, n, d, s } => {
                assert_eq!(d, d_in, "depthwise input size");
                debug_assert_eq!(
                    Some(s),
                    dw.sx.and_then(|f| f.as_scheme()),
                    "producer emitted codes at the wrong scheme"
                );
                let r = s.resolution();
                let mut xq = Tensor::zeros(&[n, d]);
                for (o, &cd) in xq.data.iter_mut().zip(&codes) {
                    *o = cd as f32 * r;
                }
                xq
            }
        };
        let n = xq.dim(0);
        let mut y = Tensor::zeros(&[n, c * oh * ow]);
        for img in 0..n {
            for ch in 0..c {
                let xi = &xq.data[img * c * h * w + ch * h * w..][..h * w];
                let k = &dw.wq[ch * 9..(ch + 1) * 9];
                let oi = &mut y.data[img * c * oh * ow + ch * oh * ow..][..oh * ow];
                dw_channel(k, xi, oi, h, w, oh, ow, stride);
            }
        }
        if relu {
            y.map_inplace(|v| v.max(0.0));
        }
        match emit {
            Emit::F32 => Act::F32(y),
            Emit::I8(s) => {
                let (n, d) = (y.dim(0), y.dim(1));
                let mut codes = vec![0i8; y.len()];
                quantize::codes_i8(&y.data, &mut codes, *s);
                Act::I8 { codes, n, d, s: *s }
            }
            Emit::I16(s) => {
                let (n, d) = (y.dim(0), y.dim(1));
                let mut codes = vec![0i16; y.len()];
                quantize::codes_i16(&y.data, &mut codes, *s);
                Act::I16 { codes, n, d, s: *s }
            }
        }
    }
}

/// 2×2 stride-2 max pool directly on integer codes. Legal because
/// quantization is monotone and the pooled maximum is one of the pooled
/// values: `quant(max(vs)) == max(quant(vs))` exactly.
fn pool_codes(c: usize, h: usize, w: usize, act: Act) -> Act {
    match act {
        Act::I8 { codes, n, d, s } => {
            assert_eq!(d, c * h * w, "maxpool input size");
            let (oh, ow) = (h / 2, w / 2);
            let mut out = vec![0i8; n * c * oh * ow];
            pool_block(&codes, &mut out, n, c, h, w, oh, ow, i8::MIN);
            Act::I8 { codes: out, n, d: c * oh * ow, s }
        }
        Act::I16 { codes, n, d, s } => {
            assert_eq!(d, c * h * w, "maxpool input size");
            let (oh, ow) = (h / 2, w / 2);
            let mut out = vec![0i16; n * c * oh * ow];
            pool_block(&codes, &mut out, n, c, h, w, oh, ow, i16::MIN);
            Act::I16 { codes: out, n, d: c * oh * ow, s }
        }
        Act::F32(_) => panic!("fused plan invariant violated: f32 at a codes max-pool"),
    }
}

#[allow(clippy::too_many_arguments)]
fn pool_block<T: Copy + PartialOrd>(
    src: &[T],
    dst: &mut [T],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    floor: T,
) {
    for img in 0..n {
        for ch in 0..c {
            let xi = &src[img * c * h * w + ch * h * w..][..h * w];
            let base_o = img * c * oh * ow + ch * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = floor;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = xi[(2 * oy + dy) * w + 2 * ox + dx];
                            if v > best {
                                best = v;
                            }
                        }
                    }
                    dst[base_o + oy * ow + ox] = best;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize;

    #[test]
    fn step_timer_accumulates() {
        let t = StepTimer::new();
        t.add(Duration::from_nanos(40));
        t.add(Duration::from_nanos(2));
        assert_eq!(t.snapshot(), (42, 2));
    }

    #[test]
    fn pool_on_codes_commutes_with_quantize() {
        // quant(maxpool(x)) == maxpool(quant(x)) — the legality condition
        // for running max-pool in code space.
        let (c, h, w) = (2, 4, 6);
        let s = Scheme { bits: 8, s: -4 };
        let xs: Vec<f32> = (0..2 * c * h * w)
            .map(|i| ((i * 37 + 11) % 97) as f32 * 0.11 - 5.0)
            .collect();
        let mut x = Tensor::zeros(&[2, c * h * w]);
        x.data.copy_from_slice(&xs);
        // f32 pool then quantize.
        let pooled = interp::exec_maxpool(c, h, w, &x);
        let mut want = vec![0i8; pooled.len()];
        quantize::codes_i8(&pooled.data, &mut want, s);
        // quantize then code-space pool.
        let mut codes = vec![0i8; xs.len()];
        quantize::codes_i8(&xs, &mut codes, s);
        let got = pool_codes(c, h, w, Act::I8 { codes, n: 2, d: c * h * w, s });
        match got {
            Act::I8 { codes, d, .. } => {
                assert_eq!(d, c * (h / 2) * (w / 2));
                assert_eq!(codes, want);
            }
            _ => panic!("pool must stay in codes"),
        }
    }
}
