//! The fusion pass: [`ExecOp`] list → [`ExecPlan`] (DESIGN.md
//! §Inference-Compiler).
//!
//! A plan step is either one fused group — a GEMM-ish op with its whole
//! epilogue (folded BN, residual add, ReLU) and an *emission* decision —
//! or a single pass-through op executed by the shared interpreter.
//!
//! Emission is decided by lookahead: if the next real consumer (skipping
//! only max-pools) is an integer GEMM, the group emits that consumer's
//! activation codes directly and the intervening max-pools run in code
//! space. Every other op — `Push`, `Swap`, `AddPopRelu` not absorbed into
//! an epilogue, `ConcatPop`, standalone BN/ReLU, global average pool, and
//! the end of the program — is a barrier that forces an f32 emit. These are
//! exactly the rewrites with an exactness argument (quantization is
//! monotone, so pooling commutes with it; the epilogue chain is the same
//! scalar f32 program in the same order), which is what keeps the fused
//! executor bit-identical to the unfused interpreter.

use crate::fixedpoint::gemm::Tile;
use crate::fixedpoint::Scheme;

use super::ir::{ConvKind, ExecOp, LinKind};
use super::tune::{lookup, GemmKind, ShapeKey, TuneEntry, TUNE_BATCH};

/// What a fused group hands to the next step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum Emit {
    /// Plain f32 tensor (barrier follows, or the consumer is f32/fq).
    F32,
    /// int8 codes at the consumer's activation scheme.
    I8(Scheme),
    /// int16 codes at the consumer's activation scheme.
    I16(Scheme),
}

/// The fused tail of a GEMM step, applied in one pass over the accumulator:
/// bias (always) → BN → residual add → ReLU → emit.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Epilogue {
    /// Index of the folded `ExecOp::Bn` (conv groups only).
    pub(crate) bn: Option<usize>,
    /// Absorbed `AddPopRelu`: pop the saved tensor and add it (implies
    /// `relu`).
    pub(crate) add_pop: bool,
    /// Absorbed trailing ReLU.
    pub(crate) relu: bool,
    /// Output form.
    pub(crate) emit: Emit,
}

/// One executable plan step. GEMM steps reference their op by index (the
/// pre-packed weights live in the op list — no duplication) and carry the
/// autotuned tile.
pub(crate) enum Step {
    Linear { op: usize, epi: Epilogue, tile: Tile },
    Conv { op: usize, epi: Epilogue, tile: Tile },
    Dw { op: usize, relu: bool, emit: Emit },
    /// Max-pool executed on int8 codes.
    PoolI8 { op: usize },
    /// Max-pool executed on int16 codes.
    PoolI16 { op: usize },
    /// Pass-through: run `ops[i]` in the shared interpreter (f32 in/out).
    Op(usize),
}

/// A compiled execution plan: fused steps, display labels (aligned with
/// `steps`), and the tile decisions that should be written back to the
/// artifact's plan cache.
pub(crate) struct ExecPlan {
    pub(crate) steps: Vec<Step>,
    pub(crate) labels: Vec<String>,
    pub(crate) tuned: Vec<TuneEntry>,
}

impl ExecPlan {
    /// How many steps emit integer codes instead of f32 (the "stayed in
    /// code space" edges the compile report counts).
    pub(crate) fn code_edges(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| {
                let e = match s {
                    Step::Linear { epi, .. } | Step::Conv { epi, .. } => &epi.emit,
                    Step::Dw { emit, .. } => emit,
                    Step::PoolI8 { .. } | Step::PoolI16 { .. } => return true,
                    _ => return false,
                };
                !matches!(e, Emit::F32)
            })
            .count()
    }
}

/// What the next real consumer (skipping max-pools only) wants as input.
fn decide_emit(ops: &[ExecOp], j: usize) -> Emit {
    let mut k = j;
    while matches!(ops.get(k), Some(ExecOp::MaxPool { .. })) {
        k += 1;
    }
    match ops.get(k) {
        // The int4 weight-only kind consumes i8 activation codes at `sx`
        // exactly like the i8 kind — same emit decision.
        Some(ExecOp::Linear(l)) => match &l.kind {
            LinKind::I8 { sx, .. } | LinKind::I4 { sx, .. } => Emit::I8(*sx),
            LinKind::I16 { sx, .. } => Emit::I16(*sx),
            _ => Emit::F32,
        },
        Some(ExecOp::Conv(cv)) => match &cv.kind {
            ConvKind::I8 { sx, .. } | ConvKind::I4 { sx, .. } => Emit::I8(*sx),
            ConvKind::I16 { sx, .. } => Emit::I16(*sx),
            _ => Emit::F32,
        },
        // Depthwise only accepts codes for formats with a fixed-point
        // view (codes dequantize exactly); minifloat stays f32.
        Some(ExecOp::Depthwise(dw)) => match dw.sx.and_then(|f| f.as_scheme()) {
            Some(s) if s.bits <= 8 => Emit::I8(s),
            Some(s) if s.bits <= 16 => Emit::I16(s),
            _ => Emit::F32,
        },
        _ => Emit::F32,
    }
}

/// After a codes emit, absorb the max-pools sitting between the producer
/// and its consumer as code-space pool steps.
fn consume_pools(ops: &[ExecOp], mut i: usize, emit: &Emit, steps: &mut Vec<Step>) -> usize {
    loop {
        match (emit, ops.get(i)) {
            (Emit::I8(_), Some(ExecOp::MaxPool { .. })) => {
                steps.push(Step::PoolI8 { op: i });
                i += 1;
            }
            (Emit::I16(_), Some(ExecOp::MaxPool { .. })) => {
                steps.push(Step::PoolI16 { op: i });
                i += 1;
            }
            _ => return i,
        }
    }
}

/// Absorb a trailing `Relu` / `AddPopRelu` at `j` into an epilogue.
/// Returns (relu, add_pop, next index).
fn take_activation(ops: &[ExecOp], j: usize) -> (bool, bool, usize) {
    match ops.get(j) {
        Some(ExecOp::Relu) => (true, false, j + 1),
        Some(ExecOp::AddPopRelu) => (true, true, j + 1),
        _ => (false, false, j),
    }
}

/// Build the fused plan (default tiles; [`apply_tiles`] patches in tuned
/// ones afterwards).
pub(crate) fn build_plan(ops: &[ExecOp]) -> ExecPlan {
    let mut steps = Vec::new();
    let mut i = 0;
    while i < ops.len() {
        match &ops[i] {
            ExecOp::Linear(_) => {
                let (relu, add_pop, j) = take_activation(ops, i + 1);
                let emit = decide_emit(ops, j);
                steps.push(Step::Linear {
                    op: i,
                    epi: Epilogue { bn: None, add_pop, relu, emit },
                    tile: Tile::default(),
                });
                i = consume_pools(ops, j, &emit, &mut steps);
            }
            ExecOp::Conv(cv) => {
                let mut j = i + 1;
                let mut bn = None;
                if let Some(ExecOp::Bn { c, hw, .. }) = ops.get(j) {
                    let (_, cols) = cv.geom.im2col_dims(cv.in_h, cv.in_w);
                    if *c == cv.geom.out_c && *hw == cols {
                        bn = Some(j);
                        j += 1;
                    }
                }
                let (relu, add_pop, j) = take_activation(ops, j);
                let emit = decide_emit(ops, j);
                steps.push(Step::Conv {
                    op: i,
                    epi: Epilogue { bn, add_pop, relu, emit },
                    tile: Tile::default(),
                });
                i = consume_pools(ops, j, &emit, &mut steps);
            }
            ExecOp::Depthwise(_) => {
                let (relu, _, j) = match ops.get(i + 1) {
                    Some(ExecOp::Relu) => (true, false, i + 2),
                    _ => (false, false, i + 1),
                };
                let emit = decide_emit(ops, j);
                steps.push(Step::Dw { op: i, relu, emit });
                i = consume_pools(ops, j, &emit, &mut steps);
            }
            _ => {
                steps.push(Step::Op(i));
                i += 1;
            }
        }
    }
    let labels = steps.iter().map(|s| step_label(ops, s)).collect();
    ExecPlan { steps, labels, tuned: Vec::new() }
}

/// The autotuner shape of one step, if it is a tiled GEMM.
pub(crate) fn step_shape(ops: &[ExecOp], step: &Step) -> Option<ShapeKey> {
    match step {
        Step::Linear { op, .. } => {
            let l = match &ops[*op] {
                ExecOp::Linear(l) => l,
                _ => unreachable!("plan step/op mismatch"),
            };
            let kind = match &l.kind {
                LinKind::I8 { .. } | LinKind::I4 { .. } => GemmKind::I8,
                LinKind::I16 { .. } => GemmKind::I16,
                _ => GemmKind::F32,
            };
            Some(ShapeKey { kind, m: TUNE_BATCH, k: l.din, n: l.dout })
        }
        Step::Conv { op, .. } => {
            let cv = match &ops[*op] {
                ExecOp::Conv(cv) => cv,
                _ => unreachable!("plan step/op mismatch"),
            };
            let (rows, cols) = cv.geom.im2col_dims(cv.in_h, cv.in_w);
            let kind = match &cv.kind {
                ConvKind::I8 { .. } | ConvKind::I4 { .. } => GemmKind::I8,
                ConvKind::I16 { .. } => GemmKind::I16,
                _ => GemmKind::F32,
            };
            Some(ShapeKey { kind, m: cv.geom.out_c, k: rows, n: cols })
        }
        _ => None,
    }
}

/// Every tunable shape in plan order (with duplicates; the tuner dedupes).
pub(crate) fn shape_keys(ops: &[ExecOp], steps: &[Step]) -> Vec<ShapeKey> {
    steps.iter().filter_map(|s| step_shape(ops, s)).collect()
}

/// Patch resolved tiles into the plan's GEMM steps; shapes without an
/// entry keep the default tile.
pub(crate) fn apply_tiles(ops: &[ExecOp], steps: &mut [Step], entries: &[TuneEntry]) {
    for s in steps.iter_mut() {
        let Some(key) = step_shape(ops, s) else { continue };
        let Some(tile) = lookup(entries, key) else { continue };
        match s {
            Step::Linear { tile: t, .. } | Step::Conv { tile: t, .. } => *t = tile,
            _ => {}
        }
    }
}

fn step_label(ops: &[ExecOp], step: &Step) -> String {
    let decorate = |op: usize, bn: bool, add_pop: bool, relu: bool, emit: &Emit| {
        let mut l = ops[op].describe();
        if bn {
            l.push_str("+bn");
        }
        if add_pop {
            l.push_str("+add+relu");
        } else if relu {
            l.push_str("+relu");
        }
        match emit {
            Emit::I8(_) => l.push_str("->i8"),
            Emit::I16(_) => l.push_str("->i16"),
            Emit::F32 => {}
        }
        l
    };
    match step {
        Step::Linear { op, epi, .. } | Step::Conv { op, epi, .. } => {
            decorate(*op, epi.bn.is_some(), epi.add_pop, epi.relu, &epi.emit)
        }
        Step::Dw { op, relu, emit } => decorate(*op, false, false, *relu, emit),
        Step::PoolI8 { .. } => "maxpool@i8".to_string(),
        Step::PoolI16 { .. } => "maxpool@i16".to_string(),
        Step::Op(i) => ops[*i].describe(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::ir::{lower, InferOp};
    use super::*;
    use crate::tensor::Tensor;

    fn sch(bits: u8, s: i32) -> Scheme {
        Scheme { bits, s }
    }

    fn lin(name: &str, din: usize, dout: usize, q: Option<(Scheme, Scheme)>) -> InferOp {
        InferOp::Linear {
            name: name.to_string(),
            w: Tensor::zeros(&[din, dout]),
            b: vec![0.0; dout],
            sw: q.map(|(sw, _)| crate::fixedpoint::Format::FixedPoint(sw)),
            sx: q.map(|(_, sx)| crate::fixedpoint::Format::FixedPoint(sx)),
        }
    }

    #[test]
    fn mlp_chain_stays_in_codes() {
        let q = Some((sch(8, -6), sch(8, -4)));
        let ops = vec![lin("fc0", 4, 8, q), InferOp::Relu, lin("fc1", 8, 3, q)];
        let low = lower("t", ops, None).unwrap();
        let plan = build_plan(&low.ops);
        assert_eq!(plan.steps.len(), 2);
        match &plan.steps[0] {
            Step::Linear { epi, .. } => {
                assert!(epi.relu && !epi.add_pop);
                assert_eq!(epi.emit, Emit::I8(sch(8, -4)));
            }
            _ => panic!("expected fused linear"),
        }
        match &plan.steps[1] {
            Step::Linear { epi, .. } => assert_eq!(epi.emit, Emit::F32),
            _ => panic!("expected fused linear"),
        }
        assert_eq!(plan.code_edges(), 1);
        assert!(plan.labels[0].contains("+relu") && plan.labels[0].contains("->i8"));
    }

    #[test]
    fn push_is_a_barrier_and_add_pop_fuses() {
        let q = Some((sch(8, -6), sch(8, -4)));
        let ops = vec![
            lin("fcin", 4, 4, q),
            InferOp::Push,
            lin("fc0", 4, 4, q),
            InferOp::AddPopRelu,
            lin("fc1", 4, 3, q),
        ];
        let low = lower("t", ops, None).unwrap();
        let plan = build_plan(&low.ops);
        // fcin | push | fc0+add+relu | fc1
        assert_eq!(plan.steps.len(), 4);
        match &plan.steps[0] {
            // Push right after fcin is a barrier: must emit f32.
            Step::Linear { epi, .. } => assert_eq!(epi.emit, Emit::F32),
            _ => panic!("expected fused linear"),
        }
        assert!(matches!(plan.steps[1], Step::Op(1)));
        match &plan.steps[2] {
            Step::Linear { epi, .. } => {
                assert!(epi.add_pop && epi.relu);
                // next consumer is fc1 (i8) — codes emit is still legal
                // after a fused residual add.
                assert_eq!(epi.emit, Emit::I8(sch(8, -4)));
            }
            _ => panic!("expected fused linear"),
        }
    }

    #[test]
    fn pools_run_in_code_space_between_int_convs() {
        use crate::fixedpoint::conv::Conv2dGeom;
        let g = Conv2dGeom { in_c: 1, out_c: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let g2 = Conv2dGeom { in_c: 2, out_c: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let q = Some((sch(8, -6), sch(8, -4)));
        let conv = |name: &str, g: Conv2dGeom, h: usize, w: usize| InferOp::Conv {
            name: name.to_string(),
            geom: g,
            in_h: h,
            in_w: w,
            w: Tensor::zeros(&[g.out_c, g.in_c * g.kh * g.kw]),
            b: vec![0.0; g.out_c],
            sw: q.map(|(sw, _)| crate::fixedpoint::Format::FixedPoint(sw)),
            sx: q.map(|(_, sx)| crate::fixedpoint::Format::FixedPoint(sx)),
        };
        let ops = vec![
            conv("c0", g, 8, 8),
            InferOp::Relu,
            InferOp::MaxPool { c: 2, h: 8, w: 8 },
            conv("c1", g2, 4, 4),
        ];
        let low = lower("t", ops, None).unwrap();
        let plan = build_plan(&low.ops);
        assert_eq!(plan.steps.len(), 3);
        assert!(matches!(
            plan.steps[0],
            Step::Conv { epi: Epilogue { emit: Emit::I8(_), relu: true, .. }, .. }
        ));
        assert!(matches!(plan.steps[1], Step::PoolI8 { op: 2 }));
        assert!(matches!(plan.steps[2], Step::Conv { .. }));
        assert_eq!(plan.code_edges(), 2);
    }

    #[test]
    fn tiles_patch_into_matching_steps() {
        let q = Some((sch(8, -6), sch(8, -4)));
        let ops = vec![lin("fc0", 4, 8, q)];
        let low = lower("t", ops, None).unwrap();
        let mut plan = build_plan(&low.ops);
        let key = step_shape(&low.ops, &plan.steps[0]).unwrap();
        assert_eq!(key, ShapeKey { kind: GemmKind::I8, m: TUNE_BATCH, k: 4, n: 8 });
        let tile = Tile { mc: 7, kc: 9, shard: 0 };
        apply_tiles(&low.ops, &mut plan.steps, &[TuneEntry { key, tile }]);
        match &plan.steps[0] {
            Step::Linear { tile: t, .. } => assert_eq!(*t, tile),
            _ => panic!("expected linear step"),
        }
    }
}
