//! The unfused reference interpreter: one [`ExecOp`] at a time, f32
//! activations between every op (DESIGN.md §Inference-Compiler).
//!
//! This is the oracle the fused plan executor ([`super::exec`]) is pinned
//! against, and the serving path behind `apt serve --no-fuse`. It is *not*
//! naive: weights are pre-quantized/pre-packed at lower time, and the conv
//! path quantizes + gathers each image's im2col patch straight into the BT
//! layout (`fixedpoint::conv::im2col_bt_quant_*`), so even the interpreter
//! allocates no pack buffers per GEMM call — the per-call `pack_bt_*` of
//! the original serving tier is gone from both execution strategies.

use crate::fixedpoint::conv::{im2col, im2col_bt_quant_i16, im2col_bt_quant_i8};
use crate::fixedpoint::{quantize, unpack_nibbles};
use crate::kernels::Engine;
use crate::tensor::Tensor;

use super::exec::StepTimer;
use super::ir::{ConvKind, ExecConv, ExecDw, ExecLinear, ExecOp, LinKind};

/// Run the full op list unfused. `timers` may be empty (no timing) or hold
/// one slot per op.
pub(crate) fn run_unfused(ops: &[ExecOp], x: &Tensor, eng: &Engine, timers: &[StepTimer]) -> Tensor {
    let mut cur = x.clone();
    let mut stack: Vec<Tensor> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        let t0 = std::time::Instant::now();
        cur = apply_op(op, cur, &mut stack, eng);
        if let Some(t) = timers.get(i) {
            t.add(t0.elapsed());
        }
    }
    cur
}

/// Run the full op list unfused, handing each quantizable site's *input*
/// activation to `tap(site_name, data)` just before the op consumes it —
/// the observation hook behind `calib::Calibrator`. Conv sites observe the
/// pre-im2col input: padding only adds zeros, so the patch range the
/// frozen `Fq`/int kinds will clip to is the same.
pub(crate) fn run_observed(
    ops: &[ExecOp],
    x: &Tensor,
    eng: &Engine,
    tap: &mut dyn FnMut(&str, &[f32]),
) -> Tensor {
    let mut cur = x.clone();
    let mut stack: Vec<Tensor> = Vec::new();
    for op in ops {
        match op {
            ExecOp::Linear(l) => tap(&l.name, &cur.data),
            ExecOp::Conv(cv) => tap(&cv.name, &cur.data),
            ExecOp::Depthwise(dw) => tap(&dw.name, &cur.data),
            _ => {}
        }
        cur = apply_op(op, cur, &mut stack, eng);
    }
    cur
}

/// Execute one op against the current activation + value stack. Shared
/// verbatim by the fused plan executor for ops outside any fusion group,
/// so pass-through semantics cannot drift between the two strategies.
pub(crate) fn apply_op(op: &ExecOp, cur: Tensor, stack: &mut Vec<Tensor>, eng: &Engine) -> Tensor {
    match op {
        ExecOp::Linear(l) => exec_linear(l, &cur, eng),
        ExecOp::Conv(cv) => exec_conv(cv, &cur, eng),
        ExecOp::Depthwise(dw) => exec_depthwise(dw, &cur),
        ExecOp::Relu => {
            let mut y = cur;
            y.map_inplace(|v| v.max(0.0));
            y
        }
        ExecOp::MaxPool { c, h, w } => exec_maxpool(*c, *h, *w, &cur),
        ExecOp::Gap { c, h, w } => exec_gap(*c, *h, *w, &cur),
        ExecOp::Bn { c, hw, gamma, beta, mean, istd } => {
            let mut y = cur;
            let n = y.dim(0);
            for ch in 0..*c {
                let (g, b) = (gamma[ch], beta[ch]);
                let (m, is) = (mean[ch], istd[ch]);
                for img in 0..n {
                    for i in 0..*hw {
                        let idx = img * c * hw + ch * hw + i;
                        let v = y.data[idx];
                        y.data[idx] = g * (v - m) * is + b;
                    }
                }
            }
            y
        }
        // Stack discipline is verified by `ir::lower` at freeze time, so
        // the pops/peeks below cannot underflow on any constructible model.
        ExecOp::Push => {
            stack.push(cur.clone());
            cur
        }
        ExecOp::Swap => {
            let mut cur = cur;
            let top = stack.last_mut().expect("serve stack underflow (Swap)");
            std::mem::swap(top, &mut cur);
            cur
        }
        ExecOp::AddPopRelu => {
            let saved = stack.pop().expect("serve stack underflow (AddPopRelu)");
            let mut h = cur;
            h.add_inplace(&saved);
            h.map_inplace(|v| v.max(0.0));
            h
        }
        ExecOp::ConcatPop { c_pop, c_cur, hw } => {
            let first = stack.pop().expect("serve stack underflow (ConcatPop)");
            let n = cur.dim(0);
            let (c1, c3, hw) = (*c_pop, *c_cur, *hw);
            let mut out = Tensor::zeros(&[n, (c1 + c3) * hw]);
            for img in 0..n {
                out.data[img * (c1 + c3) * hw..][..c1 * hw]
                    .copy_from_slice(&first.data[img * c1 * hw..][..c1 * hw]);
                out.data[img * (c1 + c3) * hw + c1 * hw..][..c3 * hw]
                    .copy_from_slice(&cur.data[img * c3 * hw..][..c3 * hw]);
            }
            out
        }
    }
}

pub(crate) fn exec_linear(l: &ExecLinear, x: &Tensor, eng: &Engine) -> Tensor {
    let m = x.dim(0);
    assert_eq!(x.dim(1), l.din, "linear input width");
    match &l.kind {
        LinKind::F32 { w } => {
            let mut y = x.matmul_with(w, eng);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::Fq { wq, sx } => {
            let mut xq = x.clone();
            eng.fake_quant_fmt(&mut xq.data, *sx);
            let mut y = xq.matmul_with(wq, eng);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::I8 { bt, colsum, sw, sx } => {
            let mut ca = vec![0i8; x.len()];
            eng.codes_i8(&x.data, &mut ca, *sx);
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i8_prepacked(m, l.din, l.dout, &ca, bt, colsum, &mut acc);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::I4 { packed, colsum, sw, sx } => {
            // Weight-only int4: unpack the nibble-packed BT codes into an
            // i8 scratch and run the ordinary prepacked int8 GEMM — the
            // codes are identical to what an i8 BT pack at `sw` would hold.
            let mut bt = vec![0i8; l.din * l.dout];
            unpack_nibbles(packed, &mut bt);
            let mut ca = vec![0i8; x.len()];
            eng.codes_i8(&x.data, &mut ca, *sx);
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i8_prepacked(m, l.din, l.dout, &ca, &bt, colsum, &mut acc);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y.add_row_bias(&l.b);
            y
        }
        LinKind::I16 { bt, sw, sx } => {
            let mut ca = vec![0i16; x.len()];
            eng.codes_i16(&x.data, &mut ca, *sx);
            let mut acc = vec![0i32; m * l.dout];
            eng.gemm_i16_prepacked(m, l.din, l.dout, &ca, bt, &mut acc);
            let mut y = Tensor::zeros(&[m, l.dout]);
            eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), &mut y.data);
            y.add_row_bias(&l.b);
            y
        }
    }
}

pub(crate) fn exec_conv(cv: &ExecConv, x: &Tensor, eng: &Engine) -> Tensor {
    let n = x.dim(0);
    let g = cv.geom;
    let (h, w) = (cv.in_h, cv.in_w);
    assert_eq!(x.dim(1), g.in_c * h * w, "conv input size");
    let (rows, cols) = g.im2col_dims(h, w);
    let (oh, ow) = g.out_hw(h, w);
    let mut out = Tensor::zeros(&[n, g.out_c * oh * ow]);
    // Per-image scratch, hoisted out of the hot loop (sizes are
    // loop-invariant; every pass fully overwrites its buffer). The int
    // paths quantize + gather the patch straight into the BT layout and
    // feed the prepacked GEMM entry points — no per-call `pack_bt_*`.
    let mut patch = Vec::new();
    let (mut btp8, mut btp16, mut colsum, mut acc) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut cw8 = Vec::new();
    match &cv.kind {
        ConvKind::I8 { .. } => {
            btp8 = vec![0i8; rows * cols];
            colsum = vec![0i32; cols];
            acc = vec![0i32; g.out_c * cols];
        }
        ConvKind::I4 { packed, .. } => {
            btp8 = vec![0i8; rows * cols];
            colsum = vec![0i32; cols];
            acc = vec![0i32; g.out_c * cols];
            // Unpack the nibble-packed weight codes once per forward —
            // loop-invariant across images.
            cw8 = vec![0i8; g.out_c * rows];
            unpack_nibbles(packed, &mut cw8);
        }
        ConvKind::I16 { .. } => {
            btp16 = vec![0i16; rows * cols];
            acc = vec![0i32; g.out_c * cols];
        }
        _ => patch = vec![0.0f32; rows * cols],
    }
    for img in 0..n {
        let xi = &x.data[img * g.in_c * h * w..(img + 1) * g.in_c * h * w];
        let co = &mut out.data[img * g.out_c * cols..(img + 1) * g.out_c * cols];
        match &cv.kind {
            ConvKind::F32 { w: wt } => {
                im2col(g, h, w, xi, &mut patch);
                eng.gemm_f32(g.out_c, rows, cols, wt, &patch, co);
            }
            ConvKind::Fq { wq, sx } => {
                im2col(g, h, w, xi, &mut patch);
                eng.fake_quant_fmt(&mut patch, *sx);
                eng.gemm_f32(g.out_c, rows, cols, wq, &patch, co);
            }
            ConvKind::I8 { cw, sw, sx } => {
                im2col_bt_quant_i8(g, h, w, xi, *sx, &mut btp8, &mut colsum);
                eng.gemm_i8_prepacked(g.out_c, rows, cols, cw, &btp8, &colsum, &mut acc);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), co);
            }
            ConvKind::I4 { sw, sx, .. } => {
                im2col_bt_quant_i8(g, h, w, xi, *sx, &mut btp8, &mut colsum);
                eng.gemm_i8_prepacked(g.out_c, rows, cols, &cw8, &btp8, &colsum, &mut acc);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), co);
            }
            ConvKind::I16 { cw, sw, sx } => {
                im2col_bt_quant_i16(g, h, w, xi, *sx, &mut btp16);
                eng.gemm_i16_prepacked(g.out_c, rows, cols, cw, &btp16, &mut acc);
                eng.rescale_i32(&acc, sw.resolution() * sx.resolution(), co);
            }
        }
        for oc in 0..g.out_c {
            let bv = cv.b[oc];
            for v in co[oc * cols..(oc + 1) * cols].iter_mut() {
                *v += bv;
            }
        }
    }
    out
}

pub(crate) fn exec_depthwise(dw: &ExecDw, x: &Tensor) -> Tensor {
    let n = x.dim(0);
    let (c, h, w, stride) = (dw.c, dw.in_h, dw.in_w, dw.stride);
    assert_eq!(x.dim(1), c * h * w, "depthwise input size");
    let (oh, ow) = ((h + 2 - 3) / stride + 1, (w + 2 - 3) / stride + 1);
    let xq = match dw.sx {
        None => x.clone(),
        Some(fx) => {
            let mut xq = x.clone();
            quantize::fake_quant_stats_inplace_fmt(&mut xq.data, fx);
            xq
        }
    };
    let mut out = Tensor::zeros(&[n, c * oh * ow]);
    for img in 0..n {
        for ch in 0..c {
            let xi = &xq.data[img * c * h * w + ch * h * w..][..h * w];
            let k = &dw.wq[ch * 9..(ch + 1) * 9];
            let oi = &mut out.data[img * c * oh * ow + ch * oh * ow..][..oh * ow];
            dw_channel(k, xi, oi, h, w, oh, ow, stride);
        }
    }
    out
}

/// One depthwise 3×3 channel: `oi = k ⊛ xi` (pad 1). Shared with the fused
/// executor so the inner arithmetic cannot drift.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dw_channel(
    k: &[f32],
    xi: &[f32],
    oi: &mut [f32],
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    stride: usize,
) {
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0.0f32;
            for ky in 0..3 {
                let iy = (oy * stride + ky) as isize - 1;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..3 {
                    let ix = (ox * stride + kx) as isize - 1;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    acc += k[ky * 3 + kx] * xi[iy as usize * w + ix as usize];
                }
            }
            oi[oy * ow + ox] = acc;
        }
    }
}

pub(crate) fn exec_maxpool(c: usize, h: usize, w: usize, x: &Tensor) -> Tensor {
    let n = x.dim(0);
    assert_eq!(x.dim(1), c * h * w, "maxpool input size");
    let (oh, ow) = (h / 2, w / 2);
    let mut y = Tensor::zeros(&[n, c * oh * ow]);
    for img in 0..n {
        for ch in 0..c {
            let xi = &x.data[img * c * h * w + ch * h * w..][..h * w];
            let base_o = img * c * oh * ow + ch * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx = (2 * oy + dy) * w + 2 * ox + dx;
                            if xi[idx] > best {
                                best = xi[idx];
                            }
                        }
                    }
                    y.data[base_o + oy * ow + ox] = best;
                }
            }
        }
    }
    y
}

pub(crate) fn exec_gap(c: usize, h: usize, w: usize, x: &Tensor) -> Tensor {
    let n = x.dim(0);
    let hw = h * w;
    assert_eq!(x.dim(1), c * hw, "global-pool input size");
    let mut y = Tensor::zeros(&[n, c]);
    for img in 0..n {
        for ch in 0..c {
            let s: f32 = x.data[img * c * hw + ch * hw..][..hw].iter().sum();
            y.data[img * c + ch] = s / hw as f32;
        }
    }
    y
}
