//! Checkpoint save/restore for host-path sessions (DESIGN.md
//! §Session-API).
//!
//! Captures everything a mid-run stop needs to continue **bit-identically**:
//! iteration count + loss curve, every parameter tensor, optimizer state
//! buffers, per-tensor `PrecisionController` decision state, the QEM/QPA
//! ledger, batch-norm running statistics, and the data stream's RNG state.
//! Accumulated gradients are deliberately *not* saved: the session zeroes
//! the previous step's gradients at the start of the next step, so a
//! restored run (fresh zero gradients, `needs_zero = false`) accumulates
//! into exactly the state the uninterrupted run would have.
//!
//! Format: a whitespace-tokenized text file, all f32/f64 payloads written
//! as raw bit patterns in hex — reads back to the identical float, no
//! decimal round-tripping. Architecture/config are not stored; the caller
//! rebuilds the session from the same `SessionBuilder` configuration and
//! `load` verifies names, slots and shapes as it walks.
//!
//! The public surface is [`Checkpoint`]: `read` parses a file without
//! needing a session, and `restore_net` applies the network-owned portion
//! (parameters, controller schemes, batch-norm state) to any compatible
//! [`Sequential`] — the hand-off `serve::FrozenModel::from_checkpoint`
//! uses to deploy a trained model without optimizer or data-stream
//! baggage. Session save/restore (`Session::{save,load}_checkpoint`) rides
//! on the same type and additionally round-trips optimizer buffers, the
//! ledger, the loss curve and the data RNG.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::optim::OptimizerState;
use super::{HostBackend, Session};
use crate::apt::{ControllerState, Ledger};
use crate::apt::ledger::Event;
use crate::fixedpoint::TensorKind;
use crate::nn::Sequential;

const MAGIC: &str = "aptckpt";
const VERSION: &str = "v1";

fn kind_label(k: TensorKind) -> &'static str {
    k.label() // "W" | "X" | "dX"
}

fn parse_kind(s: &str) -> Result<TensorKind> {
    Ok(match s {
        "W" => TensorKind::Weight,
        "X" => TensorKind::Activation,
        "dX" => TensorKind::Gradient,
        other => bail!("unknown tensor kind {other:?}"),
    })
}

fn push_f32s(out: &mut String, data: &[f32]) {
    for v in data {
        let _ = write!(out, " {:08x}", v.to_bits());
    }
}

/// Serialize the session. Takes `&mut` only because parameter visitation
/// is `&mut`-based; nothing is modified.
pub(super) fn save(session: &mut Session<HostBackend>, path: &Path) -> Result<()> {
    let mut out = String::new();
    let _ = writeln!(out, "{MAGIC} {VERSION}");
    let _ = writeln!(out, "iter {}", session.iter);

    out.push_str(&format!("losses {}", session.losses.len()));
    push_f32s(&mut out, &session.losses);
    out.push('\n');

    let host = &mut session.backend;
    let opt_state = host.opt.state();
    let _ = writeln!(
        out,
        "opt {} {} {}",
        host.opt.name(),
        opt_state.step,
        opt_state.buffers.len()
    );
    for buf in &opt_state.buffers {
        out.push_str(&format!("buf {}", buf.len()));
        push_f32s(&mut out, buf);
        out.push('\n');
    }

    let mut params = String::new();
    let mut n_params = 0usize;
    host.net.visit_params_slotted(&mut |layer, slot, p, _| {
        params.push_str(&format!("p {layer} {slot} {}", p.shape.len()));
        for d in &p.shape {
            let _ = write!(params, " {d}");
        }
        let _ = write!(params, " {}", p.data.len());
        push_f32s(&mut params, &p.data);
        params.push('\n');
        n_params += 1;
    });
    let _ = writeln!(out, "params {n_params}");
    out.push_str(&params);

    let mut ctls = String::new();
    let mut n_ctls = 0usize;
    host.net.visit_controllers(&mut |layer, lc| {
        for (kind, c) in [("w", &lc.w), ("x", &lc.x), ("g", &lc.g)] {
            let st = c.snapshot();
            let _ = writeln!(
                ctls,
                "c {layer} {kind} {} {} {:08x} {} {:08x} {} {}",
                st.bits,
                st.s,
                st.ema_value.to_bits(),
                st.ema_initialized as u8,
                st.prev_range.to_bits(),
                st.next_update,
                st.updates
            );
        }
        n_ctls += 1;
    });
    let _ = writeln!(out, "ctls {n_ctls}");
    out.push_str(&ctls);

    let mut state = String::new();
    let mut n_state = 0usize;
    host.net.visit_state(&mut |buf| {
        state.push_str(&format!("s {}", buf.len()));
        push_f32s(&mut state, buf);
        state.push('\n');
        n_state += 1;
    });
    let _ = writeln!(out, "state {n_state}");
    out.push_str(&state);

    let ledger = &host.ctx.ledger;
    let _ = writeln!(out, "ledger {} {}", ledger.total_iters, ledger.tensors.len());
    for ((layer, kind), hist) in &ledger.tensors {
        let _ = writeln!(
            out,
            "t {layer} {} {} {}",
            kind_label(*kind),
            hist.events.len(),
            hist.bits_trace.len()
        );
        for ev in &hist.events {
            let _ = writeln!(
                out,
                "e {} {} {} {:016x}",
                ev.iter,
                ev.bits,
                ev.interval,
                ev.error.to_bits()
            );
        }
        for (it, bits) in &hist.bits_trace {
            let _ = writeln!(out, "b {it} {bits}");
        }
    }

    let (st, inc) = host.data.rng_state();
    let _ = writeln!(out, "datarng {st} {inc}");
    let _ = writeln!(out, "end");

    std::fs::write(path, out).with_context(|| format!("writing checkpoint {path:?}"))?;
    Ok(())
}

/// Whitespace-token reader with typed accessors.
struct Lexer<'a> {
    toks: std::str::SplitAsciiWhitespace<'a>,
}

impl<'a> Lexer<'a> {
    fn next(&mut self) -> Result<&'a str> {
        self.toks.next().ok_or_else(|| anyhow!("truncated checkpoint"))
    }

    fn expect(&mut self, tag: &str) -> Result<()> {
        let t = self.next()?;
        if t != tag {
            bail!("expected {tag:?}, found {t:?}");
        }
        Ok(())
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(self.next()?.parse::<u64>()?)
    }

    fn usize(&mut self) -> Result<usize> {
        Ok(self.next()?.parse::<usize>()?)
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(self.next()?.parse::<i32>()?)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.next()?.parse::<u8>()?)
    }

    fn f32_hex(&mut self) -> Result<f32> {
        Ok(f32::from_bits(u32::from_str_radix(self.next()?, 16)?))
    }

    fn f64_hex(&mut self) -> Result<f64> {
        Ok(f64::from_bits(u64::from_str_radix(self.next()?, 16)?))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32_hex()?);
        }
        Ok(v)
    }
}

struct ParamRec {
    layer: String,
    slot: usize,
    shape: Vec<usize>,
    data: Vec<f32>,
}

struct CtlRec {
    layer: String,
    st: [ControllerState; 3], // w, x, g
}

/// Everything a checkpoint file contains, fully parsed before any of it is
/// applied — restores validate the whole file against the target and only
/// then mutate, so a failed restore leaves the target untouched.
pub struct Checkpoint {
    iter: u64,
    losses: Vec<f32>,
    opt_name: String,
    opt_state: OptimizerState,
    params: Vec<ParamRec>,
    ctls: Vec<CtlRec>,
    state_bufs: Vec<Vec<f32>>,
    ledger: Ledger,
    data_rng: (u64, u64),
}

impl Checkpoint {
    /// Parse a checkpoint file. No session is needed: the result can feed
    /// either a full [`Session::load_checkpoint`] restore or a
    /// forward-only [`restore_net`](Checkpoint::restore_net).
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        parse(&text)
    }

    /// Iteration count the checkpoint was taken at.
    pub fn iters_done(&self) -> u64 {
        self.iter
    }

    /// Optimizer identifier recorded at save time (`"sgd"` / `"adam"`).
    pub fn optimizer(&self) -> &str {
        &self.opt_name
    }

    /// Restore the network-owned portion — parameter tensors, per-tensor
    /// controller decision state (frozen schemes included), and
    /// non-parameter layer state such as batch-norm running statistics —
    /// into a net built with the same architecture and [`crate::nn::QuantMode`].
    /// Validates every name, slot and shape against the net before
    /// mutating anything; on error the net is untouched. Optimizer
    /// buffers, ledger, loss curve and data RNG are not applied (they are
    /// session state, not model state).
    pub fn restore_net(&self, net: &mut Sequential) -> Result<()> {
        // ---- validate (read-only) ----
        {
            let mut i = 0usize;
            let mut err: Option<String> = None;
            net.visit_params_slotted(&mut |layer, slot, p, _| {
                if err.is_none() {
                    match self.params.get(i) {
                        None => err = Some(format!("checkpoint has only {i} parameters")),
                        Some(r) if r.layer != layer || r.slot != slot || r.shape != p.shape => {
                            err = Some(format!(
                                "parameter mismatch at {i}: checkpoint {}#{} {:?} vs net {layer}#{slot} {:?}",
                                r.layer, r.slot, r.shape, p.shape
                            ));
                        }
                        Some(_) => {}
                    }
                }
                i += 1;
            });
            if let Some(e) = err {
                bail!("{e}");
            }
            if i != self.params.len() {
                bail!("net has {i} parameters, checkpoint has {}", self.params.len());
            }
        }
        {
            let mut i = 0usize;
            let mut err: Option<String> = None;
            net.visit_controllers(&mut |layer, _| {
                if err.is_none() {
                    match self.ctls.get(i) {
                        None => err = Some(format!("checkpoint has only {i} controller sets")),
                        Some(r) if r.layer != layer => {
                            err = Some(format!("controller mismatch: {} vs {layer}", r.layer))
                        }
                        Some(_) => {}
                    }
                }
                i += 1;
            });
            if let Some(e) = err {
                bail!("{e}");
            }
            if i != self.ctls.len() {
                bail!("net has {i} controller sets, checkpoint has {}", self.ctls.len());
            }
        }
        {
            let mut i = 0usize;
            let mut err: Option<String> = None;
            net.visit_state(&mut |buf| {
                if err.is_none() {
                    match self.state_bufs.get(i) {
                        None => err = Some(format!("checkpoint has only {i} state buffers")),
                        Some(b) if b.len() != buf.len() => {
                            err = Some(format!(
                                "state buffer {i} length {} vs {}",
                                b.len(),
                                buf.len()
                            ))
                        }
                        Some(_) => {}
                    }
                }
                i += 1;
            });
            if let Some(e) = err {
                bail!("{e}");
            }
            if i != self.state_bufs.len() {
                bail!("net has {i} state buffers, checkpoint has {}", self.state_bufs.len());
            }
        }

        // ---- apply (cannot fail past this point) ----
        {
            let mut i = 0usize;
            net.visit_params_slotted(&mut |_, _, p, _| {
                p.data.copy_from_slice(&self.params[i].data);
                i += 1;
            });
        }
        {
            let mut i = 0usize;
            net.visit_controllers(&mut |_, lc| {
                let r = &self.ctls[i];
                lc.w.restore(&r.st[0]);
                lc.x.restore(&r.st[1]);
                lc.g.restore(&r.st[2]);
                i += 1;
            });
        }
        {
            let mut i = 0usize;
            net.visit_state(&mut |buf| {
                buf.copy_from_slice(&self.state_bufs[i]);
                i += 1;
            });
        }
        Ok(())
    }
}

fn parse(text: &str) -> Result<Checkpoint> {
    let mut lx = Lexer { toks: text.split_ascii_whitespace() };
    lx.expect(MAGIC)?;
    lx.expect(VERSION)?;

    lx.expect("iter")?;
    let iter = lx.u64()?;
    lx.expect("losses")?;
    let n_losses = lx.usize()?;
    let losses = lx.f32_vec(n_losses)?;

    lx.expect("opt")?;
    let opt_name = lx.next()?.to_string();
    let opt_step = lx.u64()?;
    let n_buf = lx.usize()?;
    let mut buffers = Vec::with_capacity(n_buf);
    for _ in 0..n_buf {
        lx.expect("buf")?;
        let len = lx.usize()?;
        buffers.push(lx.f32_vec(len)?);
    }

    lx.expect("params")?;
    let n_params = lx.usize()?;
    let mut params = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        lx.expect("p")?;
        let layer = lx.next()?.to_string();
        let slot = lx.usize()?;
        let ndim = lx.usize()?;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(lx.usize()?);
        }
        let len = lx.usize()?;
        params.push(ParamRec { layer, slot, shape, data: lx.f32_vec(len)? });
    }

    lx.expect("ctls")?;
    let n_ctls = lx.usize()?;
    let mut ctls: Vec<CtlRec> = Vec::with_capacity(n_ctls);
    for _ in 0..n_ctls {
        let mut states = [ControllerState {
            bits: 0,
            s: 0,
            ema_value: 0.0,
            ema_initialized: false,
            prev_range: 0.0,
            next_update: 0,
            updates: 0,
        }; 3];
        let mut layer = String::new();
        for (j, want) in ["w", "x", "g"].iter().enumerate() {
            lx.expect("c")?;
            let l = lx.next()?.to_string();
            if j == 0 {
                layer = l;
            } else if l != layer {
                bail!("controller record order broken: {l} vs {layer}");
            }
            lx.expect(want)?;
            states[j] = ControllerState {
                bits: lx.u8()?,
                s: lx.i32()?,
                ema_value: lx.f32_hex()?,
                ema_initialized: lx.u8()? != 0,
                prev_range: lx.f32_hex()?,
                next_update: lx.u64()?,
                updates: lx.u64()?,
            };
        }
        ctls.push(CtlRec { layer, st: states });
    }

    lx.expect("state")?;
    let n_state = lx.usize()?;
    let mut state_bufs = Vec::with_capacity(n_state);
    for _ in 0..n_state {
        lx.expect("s")?;
        let len = lx.usize()?;
        state_bufs.push(lx.f32_vec(len)?);
    }

    lx.expect("ledger")?;
    let total_iters = lx.u64()?;
    let n_tensors = lx.usize()?;
    let mut ledger = Ledger::new();
    ledger.set_total_iters(total_iters);
    for _ in 0..n_tensors {
        lx.expect("t")?;
        let layer = lx.next()?.to_string();
        let kind = parse_kind(lx.next()?)?;
        let n_events = lx.usize()?;
        let n_trace = lx.usize()?;
        for _ in 0..n_events {
            lx.expect("e")?;
            let ev = Event {
                iter: lx.u64()?,
                bits: lx.u8()?,
                interval: lx.u64()?,
                error: lx.f64_hex()?,
            };
            ledger.record_event(&layer, kind, ev);
        }
        for _ in 0..n_trace {
            lx.expect("b")?;
            let it = lx.u64()?;
            let bits = lx.u8()?;
            ledger.trace_bits(&layer, kind, it, bits);
        }
    }

    lx.expect("datarng")?;
    let data_rng = (lx.u64()?, lx.u64()?);
    lx.expect("end")?;

    Ok(Checkpoint {
        iter,
        losses,
        opt_name,
        opt_state: OptimizerState { step: opt_step, buffers },
        params,
        ctls,
        state_bufs,
        ledger,
        data_rng,
    })
}

/// Restore `path` into a session built with the checkpoint's configuration.
/// Parse → validate → apply: nothing in the session is mutated until the
/// whole file has been checked against the net's parameter/controller/state
/// layout (the network portion rides on [`Checkpoint::restore_net`], which
/// upholds the same contract).
pub(super) fn load(session: &mut Session<HostBackend>, path: &Path) -> Result<()> {
    let ck = Checkpoint::read(path)?;
    let host = &mut session.backend;

    if ck.opt_name != host.opt.name() {
        bail!(
            "checkpoint optimizer {:?} ≠ session optimizer {:?}",
            ck.opt_name,
            host.opt.name()
        );
    }
    ck.restore_net(&mut host.net)?;

    // ---- session-only state (cannot fail past this point) ----
    host.opt.load_state(ck.opt_state);
    host.ctx.ledger = ck.ledger;
    host.data.set_rng_state(ck.data_rng);

    // Accumulated gradients are not part of a checkpoint (see module doc):
    // clear any the session accumulated before the restore (no-op on a
    // fresh net) so the first continued backward starts from zeros.
    host.net.zero_grads();
    host.needs_zero = false;
    host.ctx.training = true;
    session.iter = ck.iter;
    session.losses = ck.losses;
    Ok(())
}
