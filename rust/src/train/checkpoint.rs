//! Checkpoint save/restore for host-path sessions (DESIGN.md
//! §Session-API).
//!
//! Captures everything a mid-run stop needs to continue **bit-identically**:
//! iteration count + loss curve, every parameter tensor, optimizer state
//! buffers, per-tensor `PrecisionController` decision state, the QEM/QPA
//! ledger, batch-norm running statistics, and the data stream's RNG state.
//! Accumulated gradients are deliberately *not* saved: the session zeroes
//! the previous step's gradients at the start of the next step, so a
//! restored run (fresh zero gradients, `needs_zero = false`) accumulates
//! into exactly the state the uninterrupted run would have.
//!
//! Format: a whitespace-tokenized text file, all f32/f64 payloads written
//! as raw bit patterns in hex — reads back to the identical float, no
//! decimal round-tripping. Architecture/config are not stored; the caller
//! rebuilds the session from the same `SessionBuilder` configuration and
//! `load` verifies names, slots and shapes as it walks.
//!
//! The public surface is [`Checkpoint`]: `read` parses a file without
//! needing a session, and `restore_net` applies the network-owned portion
//! (parameters, controller schemes, batch-norm state) to any compatible
//! [`Sequential`] — the hand-off `serve::FrozenModel::from_checkpoint`
//! uses to deploy a trained model without optimizer or data-stream
//! baggage. Session save/restore (`Session::{save,load}_checkpoint`) rides
//! on the same type and additionally round-trips optimizer buffers, the
//! ledger, the loss curve and the data RNG.
//!
//! Deployment additionally rides a serving-only *plan cache*: an optional
//! trailing `tune` section holding the inference compiler's per-shape GEMM
//! tile decisions (DESIGN.md §Inference-Compiler). Training never writes
//! it; `Checkpoint::write_tune_cache` appends/replaces it in an existing
//! file after a load-time tile search, and `from_checkpoint` loads apply
//! it via [`Checkpoint::tune_cache`]. Files without the section parse
//! exactly as before.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::optim::OptimizerState;
use super::parallel::{CompressSnapshot, ParallelBackend};
use super::{HostBackend, Session};
use crate::apt::{ControllerState, Ledger};
use crate::calib::{CalibSite, CalibTable};
use crate::apt::ledger::Event;
use crate::compiler::{GemmKind, ShapeKey, TuneEntry};
use crate::fixedpoint::{FormatFamily, TensorKind};
use crate::kernels::Tile;
use crate::nn::Sequential;

const MAGIC: &str = "aptckpt";
// v2: per-tensor ledger histories carry interval-clamp iterations, and a
// trailing `comm` section snapshots the data-parallel gradient-
// communication controllers (empty for single-replica sessions).
// v3: a trailing `stash` section snapshots the adaptive activation-storage
// controllers (DESIGN.md §Activation-Memory; empty for non-adaptive
// `--act-bits` policies). v1 and v2 files keep loading — pinned by the
// fixture checkpoints under rust/tests/fixtures/.
//
// Still v3: an *optional* `tune` section may sit between `stash` and the
// final `end` — the serving plan cache appended by
// `Checkpoint::write_tune_cache`. Readers that predate it would reject the
// file, but it is only ever added to artifacts by the serving tier, never
// by training saves; absence parses exactly as before, so no version bump.
//
// Still v3 (gradient compression v2): an *optional* `compress` section may
// sit between `stash` and `tune`/`end` — the data-parallel compression
// policy label plus every error-feedback residual (`cr <tensor> <replica>
// <len> <hex…>` records). Written by every data-parallel save; absent from
// host saves and all older artifacts, which keep loading (a missing
// section restores fine into stateless policies and is rejected read-only
// by error-feedback ones — see `QuantAllReduce::check_compress`).
//
// Still v3 (calibration subsystem, DESIGN.md §Calibration): an *optional*
// `calib` section may sit between `compress` and `tune`/`end` — a PTQ
// calibration table (`calib <observer> <family> <bits> <per_channel>
// <samples> <n>` + one `cs <site> <maxabs-hex> <ftag> <bits> <s>` record
// per site) embedded by `Checkpoint::write_calib` or `apt calibrate
// --embed`. Training never writes it; absence parses exactly as before.
//
// v4 (format-family axis, DESIGN.md §Formats): every controller record
// (`c`/`cc`/`sc`) carries a format-family tag (`fixed`/`e4m3`/`e5m2`/
// `int4`) between the record head and the `bits` field, and a `pcs`
// section after `stash` holds per-channel weight scale exponents
// (`pc <layer> <n> <s…>`, empty for per-tensor runs). v1–v3 files keep
// loading read-only with family = fixed and no per-channel scales —
// pinned by the fixture checkpoints under rust/tests/fixtures/.
const VERSION: &str = "v4";

fn kind_label(k: TensorKind) -> &'static str {
    k.label() // "W" | "X" | "dX"
}

fn parse_kind(s: &str) -> Result<TensorKind> {
    Ok(match s {
        "W" => TensorKind::Weight,
        "X" => TensorKind::Activation,
        "dX" => TensorKind::Gradient,
        other => bail!("unknown tensor kind {other:?}"),
    })
}

fn push_f32s(out: &mut String, data: &[f32]) {
    for v in data {
        let _ = write!(out, " {:08x}", v.to_bits());
    }
}

/// Render everything through the `datarng` record — the host-path portion
/// shared by single-replica and data-parallel checkpoints. Takes `&mut`
/// only because parameter visitation is `&mut`-based; nothing is modified.
fn render_host(iter: u64, losses: &[f32], host: &mut HostBackend) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{MAGIC} {VERSION}");
    let _ = writeln!(out, "iter {iter}");

    out.push_str(&format!("losses {}", losses.len()));
    push_f32s(&mut out, losses);
    out.push('\n');
    let opt_state = host.opt.state();
    let _ = writeln!(
        out,
        "opt {} {} {}",
        host.opt.name(),
        opt_state.step,
        opt_state.buffers.len()
    );
    for buf in &opt_state.buffers {
        out.push_str(&format!("buf {}", buf.len()));
        push_f32s(&mut out, buf);
        out.push('\n');
    }

    let mut params = String::new();
    let mut n_params = 0usize;
    host.net.visit_params_slotted(&mut |layer, slot, p, _| {
        params.push_str(&format!("p {layer} {slot} {}", p.shape.len()));
        for d in &p.shape {
            let _ = write!(params, " {d}");
        }
        let _ = write!(params, " {}", p.data.len());
        push_f32s(&mut params, &p.data);
        params.push('\n');
        n_params += 1;
    });
    let _ = writeln!(out, "params {n_params}");
    out.push_str(&params);

    let mut ctls = String::new();
    let mut n_ctls = 0usize;
    host.net.visit_controllers(&mut |layer, lc| {
        for (kind, c) in [("w", &lc.w), ("x", &lc.x), ("g", &lc.g)] {
            let st = c.snapshot();
            let _ = writeln!(
                ctls,
                "c {layer} {kind} {} {} {} {:08x} {} {:08x} {} {}",
                st.family.tag(),
                st.bits,
                st.s,
                st.ema_value.to_bits(),
                st.ema_initialized as u8,
                st.prev_range.to_bits(),
                st.next_update,
                st.updates
            );
        }
        n_ctls += 1;
    });
    let _ = writeln!(out, "ctls {n_ctls}");
    out.push_str(&ctls);

    let mut state = String::new();
    let mut n_state = 0usize;
    host.net.visit_state(&mut |buf| {
        state.push_str(&format!("s {}", buf.len()));
        push_f32s(&mut state, buf);
        state.push('\n');
        n_state += 1;
    });
    let _ = writeln!(out, "state {n_state}");
    out.push_str(&state);

    let ledger = &host.ctx.ledger;
    let _ = writeln!(out, "ledger {} {}", ledger.total_iters, ledger.tensors.len());
    for ((layer, kind), hist) in &ledger.tensors {
        let _ = writeln!(
            out,
            "t {layer} {} {} {} {}",
            kind_label(*kind),
            hist.events.len(),
            hist.bits_trace.len(),
            hist.clamps.len()
        );
        for ev in &hist.events {
            let _ = writeln!(
                out,
                "e {} {} {} {:016x}",
                ev.iter,
                ev.bits,
                ev.interval,
                ev.error.to_bits()
            );
        }
        for (it, bits) in &hist.bits_trace {
            let _ = writeln!(out, "b {it} {bits}");
        }
        for it in &hist.clamps {
            let _ = writeln!(out, "x {it}");
        }
    }

    let (st, inc) = host.data.rng_state();
    let _ = writeln!(out, "datarng {st} {inc}");
    out
}

/// Render one controller snapshot section: `<tag> <n>` + one `<rec>`
/// record per controller, in visit order. Shared by the `comm`/`cc`
/// (data-parallel gradient communication) and `stash`/`sc` (adaptive
/// activation storage) sections — the record layout is identical.
fn render_ctl_section(
    out: &mut String,
    tag: &str,
    rec: &str,
    ctls: &[(String, ControllerState)],
) {
    let _ = writeln!(out, "{tag} {}", ctls.len());
    for (name, st) in ctls {
        let _ = writeln!(
            out,
            "{rec} {name} {} {} {} {:08x} {} {:08x} {} {}",
            st.family.tag(),
            st.bits,
            st.s,
            st.ema_value.to_bits(),
            st.ema_initialized as u8,
            st.prev_range.to_bits(),
            st.next_update,
            st.updates
        );
    }
}

/// Render the v4 `pcs` section: per-channel weight scale exponents, one
/// `pc <layer> <n> <s…>` record per layer whose weight controller carries a
/// per-channel scale vector (none under per-tensor quantization).
fn render_pc_section(out: &mut String, host: &mut HostBackend) {
    let mut rows = String::new();
    let mut n = 0usize;
    host.net.visit_controllers(&mut |layer, lc| {
        let scales = lc.w.pc_scales();
        if !scales.is_empty() {
            let _ = write!(rows, "pc {layer} {}", scales.len());
            for s in scales {
                let _ = write!(rows, " {s}");
            }
            rows.push('\n');
            n += 1;
        }
    });
    let _ = writeln!(out, "pcs {n}");
    out.push_str(&rows);
}

/// Serialize a host session (no communication controllers).
pub(super) fn save(session: &mut Session<HostBackend>, path: &Path) -> Result<()> {
    let stash = session.backend.ctx.stash.snapshot_controllers();
    let mut out = render_host(session.iter, &session.losses, &mut session.backend);
    render_ctl_section(&mut out, "comm", "cc", &[]);
    render_ctl_section(&mut out, "stash", "sc", &stash);
    render_pc_section(&mut out, &mut session.backend);
    let _ = writeln!(out, "end");
    std::fs::write(path, out).with_context(|| format!("writing checkpoint {path:?}"))?;
    Ok(())
}

/// Render the `compress` section: policy label + one `cr` record per
/// (tensor, replica) error-feedback residual.
fn render_compress_section(out: &mut String, snap: &CompressSnapshot) {
    let _ = writeln!(out, "compress {} {}", snap.label, snap.residuals.len());
    for (t, r, v) in &snap.residuals {
        let _ = write!(out, "cr {t} {r} {}", v.len());
        push_f32s(out, v);
        out.push('\n');
    }
}

/// Render the optional `calib` section: the table head plus one `cs`
/// record per calibrated site — the checkpoint-embedded twin of
/// [`CalibTable::render`], re-tokenized to the checkpoint's conventions.
fn render_calib_section(out: &mut String, t: &CalibTable) {
    let _ = writeln!(
        out,
        "calib {} {} {} {} {} {}",
        t.observer,
        t.family.tag(),
        t.bits,
        t.per_channel as u8,
        t.samples,
        t.sites.len()
    );
    for s in &t.sites {
        let _ = writeln!(
            out,
            "cs {} {:08x} {} {} {}",
            s.name,
            s.max_abs.to_bits(),
            s.fmt.family().tag(),
            s.fmt.storage_bits(),
            s.fmt.scale_exp()
        );
    }
}

/// Serialize a data-parallel session: the root replica's host-path state
/// (parameters/optimizer/controllers are bit-identical across replicas
/// under the sync invariant) plus the per-gradient communication
/// controllers and the compression-policy state (label + error-feedback
/// residuals). Note: under quantized *compute* modes the peers' in-layer
/// controller state is replica-local and is restored from the root's
/// snapshot — exact resume is guaranteed for the communication controllers
/// and for f32-compute runs (see DESIGN.md §Data-Parallel).
pub(super) fn save_parallel(session: &mut Session<ParallelBackend>, path: &Path) -> Result<()> {
    let iter = session.iter;
    let losses = session.losses.clone();
    let group = &mut session.backend.group;
    let stash = group.host.ctx.stash.snapshot_controllers();
    let mut out = render_host(iter, &losses, &mut group.host);
    render_ctl_section(&mut out, "comm", "cc", &group.comm.snapshot());
    render_ctl_section(&mut out, "stash", "sc", &stash);
    render_pc_section(&mut out, &mut group.host);
    render_compress_section(&mut out, &group.comm.compress_snapshot());
    let _ = writeln!(out, "end");
    std::fs::write(path, out).with_context(|| format!("writing checkpoint {path:?}"))?;
    Ok(())
}

/// Whitespace-token reader with typed accessors.
struct Lexer<'a> {
    toks: std::str::SplitAsciiWhitespace<'a>,
}

impl<'a> Lexer<'a> {
    fn next(&mut self) -> Result<&'a str> {
        self.toks.next().ok_or_else(|| anyhow!("truncated checkpoint"))
    }

    fn expect(&mut self, tag: &str) -> Result<()> {
        let t = self.next()?;
        if t != tag {
            bail!("expected {tag:?}, found {t:?}");
        }
        Ok(())
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(self.next()?.parse::<u64>()?)
    }

    fn usize(&mut self) -> Result<usize> {
        Ok(self.next()?.parse::<usize>()?)
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(self.next()?.parse::<i32>()?)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.next()?.parse::<u8>()?)
    }

    fn f32_hex(&mut self) -> Result<f32> {
        Ok(f32::from_bits(u32::from_str_radix(self.next()?, 16)?))
    }

    fn f64_hex(&mut self) -> Result<f64> {
        Ok(f64::from_bits(u64::from_str_radix(self.next()?, 16)?))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32_hex()?);
        }
        Ok(v)
    }
}

struct ParamRec {
    layer: String,
    slot: usize,
    shape: Vec<usize>,
    data: Vec<f32>,
}

struct CtlRec {
    layer: String,
    st: [ControllerState; 3], // w, x, g
}

/// Everything a checkpoint file contains, fully parsed before any of it is
/// applied — restores validate the whole file against the target and only
/// then mutate, so a failed restore leaves the target untouched.
pub struct Checkpoint {
    iter: u64,
    losses: Vec<f32>,
    opt_name: String,
    opt_state: OptimizerState,
    params: Vec<ParamRec>,
    ctls: Vec<CtlRec>,
    state_bufs: Vec<Vec<f32>>,
    ledger: Ledger,
    data_rng: (u64, u64),
    /// Gradient-communication controller snapshots (data-parallel runs);
    /// empty for single-replica checkpoints.
    comm: Vec<(String, ControllerState)>,
    /// Adaptive activation-storage controller snapshots
    /// (`--act-bits adaptive` runs, DESIGN.md §Activation-Memory); empty
    /// for other policies and for v1/v2 files.
    stash: Vec<(String, ControllerState)>,
    /// Per-channel weight scale exponents (v4 `pcs` section, DESIGN.md
    /// §Formats); empty for per-tensor runs and for v1–v3 files.
    pc: Vec<(String, Vec<i32>)>,
    /// Gradient-compression state (policy label + error-feedback
    /// residuals) of data-parallel saves; `None` for host saves and for
    /// artifacts predating the optional `compress` section.
    compress: Option<CompressSnapshot>,
    /// PTQ calibration table embedded by [`Checkpoint::write_calib`] or
    /// `apt calibrate --embed`; `None` for files without the optional
    /// `calib` section (every training save).
    calib: Option<CalibTable>,
    /// Serving plan cache: per-shape GEMM tile decisions appended by
    /// [`Checkpoint::write_tune_cache`]. Empty for files without the
    /// optional `tune` section (every training save).
    tune: Vec<TuneEntry>,
}

impl Checkpoint {
    /// Parse a checkpoint file. No session is needed: the result can feed
    /// either a full [`Session::load_checkpoint`] restore or a
    /// forward-only [`restore_net`](Checkpoint::restore_net).
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        parse(&text)
    }

    /// Iteration count the checkpoint was taken at.
    pub fn iters_done(&self) -> u64 {
        self.iter
    }

    /// Optimizer identifier recorded at save time (`"sgd"` / `"adam"`).
    pub fn optimizer(&self) -> &str {
        &self.opt_name
    }

    /// Gradient-communication controller snapshots recorded at save time
    /// (`comm:<layer>.<slot>` keys, in parameter visit order). Empty for
    /// checkpoints from single-replica sessions.
    pub fn comm_controllers(&self) -> &[(String, ControllerState)] {
        &self.comm
    }

    /// Adaptive activation-storage controller snapshots recorded at save
    /// time (stash-site keys like `fc0/x`, in key order). Empty for
    /// non-adaptive `--act-bits` policies and for v1/v2 files.
    pub fn stash_controllers(&self) -> &[(String, ControllerState)] {
        &self.stash
    }

    /// The serving plan cache: GEMM tile decisions recorded by a previous
    /// tuning load via [`write_tune_cache`](Checkpoint::write_tune_cache).
    /// Empty when the file has no `tune` section.
    pub fn tune_cache(&self) -> &[TuneEntry] {
        &self.tune
    }

    /// Gradient-compression state recorded at save time (policy label +
    /// error-feedback residuals). `None` for host saves and for artifacts
    /// predating the optional `compress` section.
    pub fn compress_state(&self) -> Option<&CompressSnapshot> {
        self.compress.as_ref()
    }

    /// The embedded PTQ calibration table, if a calibration pass wrote one
    /// via [`write_calib`](Checkpoint::write_calib). `None` when the file
    /// has no `calib` section.
    pub fn calib_table(&self) -> Option<&CalibTable> {
        self.calib.as_ref()
    }

    /// Embed (or replace) the `calib` section of an existing checkpoint
    /// file with `table` — the single-artifact deployment path (`apt
    /// calibrate --embed`), so `serve --calib` can read ranges from the
    /// checkpoint itself. Only the optional tail is rewritten: everything
    /// the training session saved is byte-identical afterwards, and an
    /// existing `tune` plan cache is preserved (the `calib` section always
    /// precedes `tune`, which is why [`write_tune_cache`]
    /// (Checkpoint::write_tune_cache)'s tail cut keeps it intact). The
    /// file is parsed first, so a corrupt checkpoint is refused untouched.
    pub fn write_calib(path: impl AsRef<Path>, table: &CalibTable) -> Result<()> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        parse(&text).with_context(|| format!("refusing to rewrite {path:?}"))?;
        let body = text.trim_end();
        let body = body
            .strip_suffix("end")
            .ok_or_else(|| anyhow!("checkpoint {path:?} does not end with \"end\""))?;
        // Lift off a trailing tune section (kept, re-appended after the new
        // calib) and drop a previous calib section, if any. Like the tune
        // cut in `write_tune_cache`, these tags only ever introduce their
        // sections at the start of a line.
        let (body, tune_text) = match body.rfind("\ntune ") {
            Some(pos) => (&body[..pos], Some(body[pos + 1..].trim_end().to_string())),
            None => (body, None),
        };
        let body = match body.rfind("\ncalib ") {
            Some(pos) => &body[..pos],
            None => body,
        };
        let mut out = body.trim_end().to_string();
        out.push('\n');
        render_calib_section(&mut out, table);
        if let Some(t) = tune_text {
            out.push_str(&t);
            out.push('\n');
        }
        out.push_str("end\n");
        std::fs::write(path, out).with_context(|| format!("writing checkpoint {path:?}"))?;
        Ok(())
    }

    /// Append (or replace) the `tune` plan-cache section of an existing
    /// checkpoint file with `entries` — typically
    /// `FrozenModel::tuned_tiles` after a `tune: true` load, so subsequent
    /// loads of the artifact skip the tile search. Only the trailing
    /// section is rewritten; everything the training session saved is
    /// byte-identical afterwards. The file is parsed first, so a corrupt
    /// checkpoint is refused untouched.
    pub fn write_tune_cache(path: impl AsRef<Path>, entries: &[TuneEntry]) -> Result<()> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        parse(&text).with_context(|| format!("refusing to rewrite {path:?}"))?;
        let body = text.trim_end();
        let body = body
            .strip_suffix("end")
            .ok_or_else(|| anyhow!("checkpoint {path:?} does not end with \"end\""))?;
        // Drop a previous tune section, if any. `tune` at the start of a
        // line only ever introduces the section: every other record tag is
        // distinct and layer/model names never begin a line.
        let body = match body.rfind("\ntune ") {
            Some(pos) => &body[..pos],
            None => body,
        };
        let mut out = body.trim_end().to_string();
        out.push('\n');
        let _ = writeln!(out, "tune {}", entries.len());
        for e in entries {
            let _ = writeln!(
                out,
                "tl {} {} {} {} {} {} {}",
                e.key.kind.token(),
                e.key.m,
                e.key.k,
                e.key.n,
                e.tile.mc,
                e.tile.kc,
                e.tile.shard
            );
        }
        out.push_str("end\n");
        std::fs::write(path, out).with_context(|| format!("writing checkpoint {path:?}"))?;
        Ok(())
    }

    /// Restore the network-owned portion — parameter tensors, per-tensor
    /// controller decision state (frozen schemes included), and
    /// non-parameter layer state such as batch-norm running statistics —
    /// into a net built with the same architecture and [`crate::nn::QuantMode`].
    /// Validates every name, slot and shape against the net before
    /// mutating anything; on error the net is untouched. Optimizer
    /// buffers, ledger, loss curve and data RNG are not applied (they are
    /// session state, not model state).
    pub fn restore_net(&self, net: &mut Sequential) -> Result<()> {
        // ---- validate (read-only) ----
        {
            let mut i = 0usize;
            let mut err: Option<String> = None;
            net.visit_params_slotted(&mut |layer, slot, p, _| {
                if err.is_none() {
                    match self.params.get(i) {
                        None => err = Some(format!("checkpoint has only {i} parameters")),
                        Some(r) if r.layer != layer || r.slot != slot || r.shape != p.shape => {
                            err = Some(format!(
                                "parameter mismatch at {i}: checkpoint {}#{} {:?} vs net {layer}#{slot} {:?}",
                                r.layer, r.slot, r.shape, p.shape
                            ));
                        }
                        Some(_) => {}
                    }
                }
                i += 1;
            });
            if let Some(e) = err {
                bail!("{e}");
            }
            if i != self.params.len() {
                bail!("net has {i} parameters, checkpoint has {}", self.params.len());
            }
        }
        {
            let mut i = 0usize;
            let mut err: Option<String> = None;
            net.visit_controllers(&mut |layer, lc| {
                if err.is_none() {
                    match self.ctls.get(i) {
                        None => err = Some(format!("checkpoint has only {i} controller sets")),
                        Some(r) if r.layer != layer => {
                            err = Some(format!("controller mismatch: {} vs {layer}", r.layer))
                        }
                        Some(r) if r.st[0].family != lc.w.cfg.family => {
                            err = Some(format!(
                                "controller format-family mismatch at {layer}: checkpoint {} vs session {}",
                                r.st[0].family.label(),
                                lc.w.cfg.family.label()
                            ))
                        }
                        Some(_) => {}
                    }
                }
                i += 1;
            });
            if let Some(e) = err {
                bail!("{e}");
            }
            if i != self.ctls.len() {
                bail!("net has {i} controller sets, checkpoint has {}", self.ctls.len());
            }
            for (layer, _) in &self.pc {
                if !self.ctls.iter().any(|r| &r.layer == layer) {
                    bail!("per-channel scales for unknown layer {layer:?}");
                }
            }
        }
        {
            let mut i = 0usize;
            let mut err: Option<String> = None;
            net.visit_state(&mut |buf| {
                if err.is_none() {
                    match self.state_bufs.get(i) {
                        None => err = Some(format!("checkpoint has only {i} state buffers")),
                        Some(b) if b.len() != buf.len() => {
                            err = Some(format!(
                                "state buffer {i} length {} vs {}",
                                b.len(),
                                buf.len()
                            ))
                        }
                        Some(_) => {}
                    }
                }
                i += 1;
            });
            if let Some(e) = err {
                bail!("{e}");
            }
            if i != self.state_bufs.len() {
                bail!("net has {i} state buffers, checkpoint has {}", self.state_bufs.len());
            }
        }

        // ---- apply (cannot fail past this point) ----
        {
            let mut i = 0usize;
            net.visit_params_slotted(&mut |_, _, p, _| {
                p.data.copy_from_slice(&self.params[i].data);
                i += 1;
            });
        }
        {
            let mut i = 0usize;
            net.visit_controllers(&mut |layer, lc| {
                let r = &self.ctls[i];
                lc.w.restore(&r.st[0]);
                lc.x.restore(&r.st[1]);
                lc.g.restore(&r.st[2]);
                let scales = self
                    .pc
                    .iter()
                    .find(|(l, _)| l.as_str() == layer)
                    .map(|(_, s)| s.clone())
                    .unwrap_or_default();
                lc.w.set_pc_scales(scales);
                i += 1;
            });
        }
        {
            let mut i = 0usize;
            net.visit_state(&mut |buf| {
                buf.copy_from_slice(&self.state_bufs[i]);
                i += 1;
            });
        }
        Ok(())
    }
}

/// Parse the state payload of one `cc`/`sc` controller record — the shared
/// layout behind [`render_ctl_section`] (tag and name are consumed by the
/// caller). v4 records lead with a format-family tag; older files are all
/// fixed-point.
fn parse_ctl_state(lx: &mut Lexer<'_>, v4: bool) -> Result<ControllerState> {
    let family = if v4 {
        let tag = lx.next()?;
        FormatFamily::parse(tag)
            .ok_or_else(|| anyhow!("unknown format family {tag:?} in controller record"))?
    } else {
        FormatFamily::FixedPoint
    };
    Ok(ControllerState {
        bits: lx.u8()?,
        s: lx.i32()?,
        ema_value: lx.f32_hex()?,
        ema_initialized: lx.u8()? != 0,
        prev_range: lx.f32_hex()?,
        next_update: lx.u64()?,
        updates: lx.u64()?,
        family,
    })
}

fn parse(text: &str) -> Result<Checkpoint> {
    let mut lx = Lexer { toks: text.split_ascii_whitespace() };
    lx.expect(MAGIC)?;
    // Older files are forward-parseable: v1 lacks the per-tensor clamp
    // counts and the `comm` section, v2 lacks the `stash` section, v3
    // lacks the format-family tags and the `pcs` section — all keep
    // loading (with the missing state defaulted) instead of erroring.
    // Pinned by the committed fixtures under rust/tests/fixtures/.
    let version = lx.next()?;
    let (v1, has_stash, v4) = match version {
        "v1" => (true, false, false),
        "v2" => (false, false, false),
        "v3" => (false, true, false),
        v if v == VERSION => (false, true, true),
        other => {
            bail!("unsupported checkpoint version {other:?} (this build reads v1/v2/v3/{VERSION})")
        }
    };

    lx.expect("iter")?;
    let iter = lx.u64()?;
    lx.expect("losses")?;
    let n_losses = lx.usize()?;
    let losses = lx.f32_vec(n_losses)?;

    lx.expect("opt")?;
    let opt_name = lx.next()?.to_string();
    let opt_step = lx.u64()?;
    let n_buf = lx.usize()?;
    let mut buffers = Vec::with_capacity(n_buf);
    for _ in 0..n_buf {
        lx.expect("buf")?;
        let len = lx.usize()?;
        buffers.push(lx.f32_vec(len)?);
    }

    lx.expect("params")?;
    let n_params = lx.usize()?;
    let mut params = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        lx.expect("p")?;
        let layer = lx.next()?.to_string();
        let slot = lx.usize()?;
        let ndim = lx.usize()?;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(lx.usize()?);
        }
        let len = lx.usize()?;
        params.push(ParamRec { layer, slot, shape, data: lx.f32_vec(len)? });
    }

    lx.expect("ctls")?;
    let n_ctls = lx.usize()?;
    let mut ctls: Vec<CtlRec> = Vec::with_capacity(n_ctls);
    for _ in 0..n_ctls {
        let mut states = [ControllerState {
            bits: 0,
            s: 0,
            ema_value: 0.0,
            ema_initialized: false,
            prev_range: 0.0,
            next_update: 0,
            updates: 0,
            family: FormatFamily::FixedPoint,
        }; 3];
        let mut layer = String::new();
        for (j, want) in ["w", "x", "g"].iter().enumerate() {
            lx.expect("c")?;
            let l = lx.next()?.to_string();
            if j == 0 {
                layer = l;
            } else if l != layer {
                bail!("controller record order broken: {l} vs {layer}");
            }
            lx.expect(want)?;
            states[j] = parse_ctl_state(&mut lx, v4)?;
        }
        ctls.push(CtlRec { layer, st: states });
    }

    lx.expect("state")?;
    let n_state = lx.usize()?;
    let mut state_bufs = Vec::with_capacity(n_state);
    for _ in 0..n_state {
        lx.expect("s")?;
        let len = lx.usize()?;
        state_bufs.push(lx.f32_vec(len)?);
    }

    lx.expect("ledger")?;
    let total_iters = lx.u64()?;
    let n_tensors = lx.usize()?;
    let mut ledger = Ledger::new();
    ledger.set_total_iters(total_iters);
    for _ in 0..n_tensors {
        lx.expect("t")?;
        let layer = lx.next()?.to_string();
        let kind = parse_kind(lx.next()?)?;
        let n_events = lx.usize()?;
        let n_trace = lx.usize()?;
        let n_clamps = if v1 { 0 } else { lx.usize()? };
        for _ in 0..n_events {
            lx.expect("e")?;
            let ev = Event {
                iter: lx.u64()?,
                bits: lx.u8()?,
                interval: lx.u64()?,
                error: lx.f64_hex()?,
            };
            ledger.record_event(&layer, kind, ev);
        }
        for _ in 0..n_trace {
            lx.expect("b")?;
            let it = lx.u64()?;
            let bits = lx.u8()?;
            ledger.trace_bits(&layer, kind, it, bits);
        }
        for _ in 0..n_clamps {
            lx.expect("x")?;
            let it = lx.u64()?;
            ledger.record_clamp(&layer, kind, it);
        }
    }

    lx.expect("datarng")?;
    let data_rng = (lx.u64()?, lx.u64()?);

    let n_comm = if v1 {
        0
    } else {
        lx.expect("comm")?;
        lx.usize()?
    };
    let mut comm = Vec::with_capacity(n_comm);
    for _ in 0..n_comm {
        lx.expect("cc")?;
        let name = lx.next()?.to_string();
        comm.push((name, parse_ctl_state(&mut lx, v4)?));
    }

    let n_stash = if has_stash {
        lx.expect("stash")?;
        lx.usize()?
    } else {
        0
    };
    let mut stash = Vec::with_capacity(n_stash);
    for _ in 0..n_stash {
        lx.expect("sc")?;
        let name = lx.next()?.to_string();
        stash.push((name, parse_ctl_state(&mut lx, v4)?));
    }

    // v4: per-channel weight scale exponents (`pcs <n>` + `pc <layer>
    // <len> <s…>` records). Older files have none.
    let mut pc: Vec<(String, Vec<i32>)> = Vec::new();
    if v4 {
        lx.expect("pcs")?;
        let n_pc = lx.usize()?;
        for _ in 0..n_pc {
            lx.expect("pc")?;
            let layer = lx.next()?.to_string();
            let len = lx.usize()?;
            let mut scales = Vec::with_capacity(len);
            for _ in 0..len {
                scales.push(lx.i32()?);
            }
            pc.push((layer, scales));
        }
    }

    // Optional gradient-compression section (see the VERSION note):
    // `compress <label> <n>` with one `cr <tensor> <replica> <len> <hex…>`
    // error-feedback residual per record, between `stash` and `tune`/`end`.
    let mut compress = None;
    let mut tok = lx.next()?;
    if tok == "compress" {
        let label = lx.next()?.to_string();
        let n_res = lx.usize()?;
        let mut residuals = Vec::with_capacity(n_res);
        for _ in 0..n_res {
            lx.expect("cr")?;
            let t = lx.usize()?;
            let r = lx.usize()?;
            let len = lx.usize()?;
            residuals.push((t, r, lx.f32_vec(len)?));
        }
        compress = Some(CompressSnapshot { label, residuals });
        tok = lx.next()?;
    }

    // Optional PTQ calibration table (see the VERSION note): the table
    // head plus one `cs` record per site, between `compress` and
    // `tune`/`end`.
    let mut calib = None;
    if tok == "calib" {
        let observer = lx.next()?.to_string();
        let ftag = lx.next()?;
        let family = FormatFamily::parse(ftag)
            .ok_or_else(|| anyhow!("unknown format family {ftag:?} in calib section"))?;
        let bits = lx.u8()?;
        let per_channel = lx.u8()? != 0;
        let samples = lx.usize()?;
        let n_sites = lx.usize()?;
        let mut sites = Vec::with_capacity(n_sites);
        for _ in 0..n_sites {
            lx.expect("cs")?;
            let name = lx.next()?.to_string();
            let max_abs = lx.f32_hex()?;
            let fmt = crate::calib::parse_fmt(lx.next()?, lx.next()?, lx.next()?)?;
            sites.push(CalibSite { name, max_abs, fmt });
        }
        calib = Some(CalibTable { observer, family, bits, per_channel, samples, sites });
        tok = lx.next()?;
    }

    // Optional serving plan cache (see the VERSION note): `tune <n>` with
    // one `tl <kind> <m> <k> <n> <mc> <kc> <shard>` row per shape, sitting
    // just before the final `end`.
    let mut tune = Vec::new();
    match tok {
        "end" => {}
        "tune" => {
            let n_tune = lx.usize()?;
            for _ in 0..n_tune {
                lx.expect("tl")?;
                let tok = lx.next()?;
                let kind = GemmKind::from_token(tok)
                    .ok_or_else(|| anyhow!("unknown GEMM kind {tok:?} in tune section"))?;
                let key = ShapeKey { kind, m: lx.usize()?, k: lx.usize()?, n: lx.usize()? };
                let tile = Tile { mc: lx.usize()?, kc: lx.usize()?, shard: lx.usize()? };
                tune.push(TuneEntry { key, tile });
            }
            lx.expect("end")?;
        }
        other => bail!("expected \"compress\", \"calib\", \"tune\" or \"end\", found {other:?}"),
    }

    Ok(Checkpoint {
        iter,
        losses,
        opt_name,
        opt_state: OptimizerState { step: opt_step, buffers },
        params,
        ctls,
        state_bufs,
        ledger,
        data_rng,
        comm,
        stash,
        pc,
        compress,
        calib,
        tune,
    })
}

/// Apply the host-path portion of a parsed checkpoint to one
/// [`HostBackend`] — everything except the owned optimizer buffers and
/// ledger, which the callers move (single-replica) or clone (per peer) as
/// their ownership allows. Validation happens before any mutation.
fn apply_to_host(ck: &Checkpoint, host: &mut HostBackend) -> Result<()> {
    if ck.opt_name != host.opt.name() {
        bail!(
            "checkpoint optimizer {:?} ≠ session optimizer {:?}",
            ck.opt_name,
            host.opt.name()
        );
    }
    // Validate the stash-controller section read-only *first* (policy
    // compatibility), keeping the parse → validate → apply contract.
    host.ctx.stash.check_controllers(&ck.stash)?;
    ck.restore_net(&mut host.net)?;

    // ---- session-only state (cannot fail past this point) ----
    host.data.set_rng_state(ck.data_rng);
    host.ctx
        .stash
        .restore_controllers(&ck.stash)
        .expect("stash controllers validated above");
    // Checkpoints land between steps: no in-flight stashed activation
    // survives one.
    host.ctx.stash.clear_entries();

    // Accumulated gradients are not part of a checkpoint (see module doc):
    // clear any the session accumulated before the restore (no-op on a
    // fresh net) so the first continued backward starts from zeros.
    host.net.zero_grads();
    host.needs_zero = false;
    host.ctx.training = true;
    Ok(())
}

/// Restore `path` into a session built with the checkpoint's configuration.
/// Parse → validate → apply: nothing in the session is mutated until the
/// whole file has been checked against the net's parameter/controller/state
/// layout (the network portion rides on [`Checkpoint::restore_net`], which
/// upholds the same contract). A data-parallel checkpoint's communication
/// controllers are ignored here — deploying a parallel run into a
/// single-replica session is legitimate (there is nothing to communicate).
pub(super) fn load(session: &mut Session<HostBackend>, path: &Path) -> Result<()> {
    let ck = Checkpoint::read(path)?;
    apply_to_host(&ck, &mut session.backend)?;
    let host = &mut session.backend;
    host.opt.load_state(ck.opt_state);
    host.ctx.ledger = ck.ledger;
    // Mid-phase resume under a progressive schedule: the restored schemes
    // already reflect the phase's retune at save time, but the width
    // *floor* lives in session config (not checkpoint state) — re-pin it
    // without touching the restored schemes, so controllers that adapted
    // above the floor keep their widths.
    if let Some(bits) = host.schedule.bits_at(ck.iter) {
        apply_width_floor(&mut host.net, bits);
    }
    session.iter = ck.iter;
    session.losses = ck.losses;
    Ok(())
}

/// Re-pin every controller's width floor after a restore (see the
/// schedule note in [`load`]). Bounds only — restored schemes stay as
/// saved.
fn apply_width_floor(net: &mut Sequential, bits: u8) {
    net.visit_controllers(&mut |_, lc| {
        lc.w.set_width_floor(bits);
        lc.x.set_width_floor(bits);
        lc.g.set_width_floor(bits);
    });
}

/// Restore `path` into a data-parallel session: the root replica takes the
/// host-path state, every peer is re-broadcast the same network/optimizer
/// snapshot (re-establishing the sync invariant exactly as a step's
/// all-reduce would), and the gradient-communication controllers resume
/// their saved schemes and update schedules, as does any compression
/// (error-feedback) state. The group must match the checkpoint's comm and
/// compression policies (controller names and the policy label are
/// verified read-only before anything is mutated).
pub(super) fn load_parallel(session: &mut Session<ParallelBackend>, path: &Path) -> Result<()> {
    let ck = Checkpoint::read(path)?;
    let group = &mut session.backend.group;

    // Validate the comm-controller and compression sections read-only
    // *first*, so a policy mismatch fails before any replica state has
    // been overwritten (the parse → validate → apply contract of this
    // module).
    group.comm.check_snapshot(&ck.comm)?;
    group.comm.check_compress(ck.compress.as_ref())?;
    apply_to_host(&ck, &mut group.host)?;
    for peer in &mut group.peers {
        ck.restore_net(&mut peer.net)?;
        peer.opt.load_state(ck.opt_state.clone());
        // Peers mirror the root's stash-controller snapshot, exactly as
        // their in-layer controllers are restored from the root's records
        // (replica-local state; see DESIGN.md §Data-Parallel caveat).
        peer.ctx
            .stash
            .restore_controllers(&ck.stash)
            .expect("stash controllers validated against the root");
        peer.ctx.stash.clear_entries();
        peer.net.zero_grads();
        peer.needs_zero = false;
        peer.ctx.training = true;
    }
    group.comm.restore(&ck.comm)?;
    group.comm.restore_compress(ck.compress.as_ref())?;

    // Root takes the owned buffers last, after every peer cloned its copy.
    group.host.opt.load_state(ck.opt_state);
    group.host.ctx.ledger = ck.ledger;
    // Re-pin the schedule's width floor on every replica (see `load`).
    if let Some(bits) = group.host.schedule.bits_at(ck.iter) {
        apply_width_floor(&mut group.host.net, bits);
        for peer in &mut group.peers {
            apply_width_floor(&mut peer.net, bits);
        }
    }

    session.iter = ck.iter;
    session.losses = ck.losses;
    Ok(())
}
