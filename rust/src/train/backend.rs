//! The execution seam under [`super::Session`] (DESIGN.md §Session-API).
//!
//! A [`Backend`] owns everything one training path needs — model/artifact,
//! data stream, optimizer, `TrainCtx`/ledger — and exposes the uniform
//! step/eval/ledger surface the `Session` drives. Three implementations:
//!
//! - [`HostBackend`] — the pure-Rust classifier path (`Sequential` +
//!   [`DataSource`] + [`Optimizer`]), the successor of the hand-rolled
//!   `exp::common::train_classifier` loop;
//! - [`Seq2SeqBackend`] — the Elman encoder–decoder translation path
//!   (Fig 9a / Table 2);
//! - [`PjrtBackend`] — the `coordinator::ArtifactTrainer` device path
//!   (Fig 9b, `train_transformer`), previously a parallel universe with its
//!   own stepping convention.

use anyhow::{bail, Result};

use super::optim::Optimizer;
use super::{EvalOut, Phase, StepInfo};
use crate::apt::Ledger;
use crate::calib::Schedule;
use crate::coordinator::ArtifactTrainer;
use crate::data::{translation_batch, SynthImages};
use crate::mem::{ActivationStash, StashPolicy};
use crate::nn::loss::{accuracy, softmax_xent};
use crate::nn::rnn::Seq2Seq;
use crate::nn::{QuantMode, Sequential, TrainCtx};
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// A labeled-batch stream for the host classifier path. Implementations
/// must be deterministic by construction seed, and expose their sample
/// stream state so checkpoints can resume it bit-identically.
pub trait DataSource {
    /// Next training batch: (inputs `[n, d]`, labels).
    fn batch(&mut self, n: usize) -> (Tensor, Vec<usize>);
    /// A fixed held-out set drawn from a separate stream.
    fn eval_set(&self, seed: u64, n: usize) -> (Tensor, Vec<usize>);
    /// Sample-stream RNG state (checkpointing).
    fn rng_state(&self) -> (u64, u64);
    /// Restore a [`rng_state`](DataSource::rng_state) snapshot so batches
    /// continue the interrupted stream bit-identically.
    fn set_rng_state(&mut self, st: (u64, u64));
}

impl DataSource for SynthImages {
    fn batch(&mut self, n: usize) -> (Tensor, Vec<usize>) {
        SynthImages::batch(self, n)
    }

    fn eval_set(&self, seed: u64, n: usize) -> (Tensor, Vec<usize>) {
        SynthImages::eval_set(self, seed, n)
    }

    fn rng_state(&self) -> (u64, u64) {
        SynthImages::rng_state(self)
    }

    fn set_rng_state(&mut self, st: (u64, u64)) {
        SynthImages::set_rng_state(self, st)
    }
}

/// One training path behind the [`super::Session`] surface.
pub trait Backend {
    /// Display label for records/logs (e.g. `"alexnet-adaptive"`).
    fn label(&self) -> &str;
    /// One optimization step at iteration `iter`. `observe` fires the
    /// session's typed hooks: [`Phase::AfterBackward`] between backward and
    /// the parameter update (host paths only), [`Phase::AfterStep`] after
    /// it. Returns the step's training loss.
    fn step(&mut self, iter: u64, observe: &mut dyn FnMut(Phase, &StepInfo)) -> Result<f32>;
    /// Held-out evaluation after `iters_done` iterations.
    fn eval(&mut self, iters_done: u64) -> Result<EvalOut>;
    /// Take the run ledger (stamping `iters_done` as its span).
    fn take_ledger(&mut self, iters_done: u64) -> Ledger;
    /// Currently applied gradient bit-widths per quantized tensor, where
    /// the backend tracks them directly (rnn projections, PJRT slots).
    fn grad_bits(&self) -> Vec<(String, u8)> {
        Vec::new()
    }
}

/// Host classifier backend: quantized forward/backward on a [`Sequential`]
/// with QEM/QPA inside the layers, an explicit [`Optimizer`], and deferred
/// gradient zeroing (§Session-API ordering: gradients of step *i* stay
/// observable until step *i+1* begins).
pub struct HostBackend {
    /// The live network (reach it through `Session::{net, net_mut}`).
    pub net: Sequential,
    pub(super) data: Box<dyn DataSource>,
    pub(super) ctx: TrainCtx,
    pub(super) opt: Box<dyn Optimizer>,
    pub(super) batch: usize,
    pub(super) eval_seed: u64,
    pub(super) eval_n: usize,
    pub(super) needs_zero: bool,
    pub(super) schedule: Schedule,
    label: String,
}

/// Retune every compute controller of `net` to `bits` at iteration `iter` —
/// what a [`Schedule`] phase boundary does
/// (`PrecisionController::retune_bits`; no-op for controllers already at
/// the width, so degenerate schedules stay bit-identical).
pub(super) fn retune_net(net: &mut Sequential, bits: u8, iter: u64) {
    net.visit_controllers(&mut |_, lc| {
        lc.w.retune_bits(bits, iter);
        lc.x.retune_bits(bits, iter);
        lc.g.retune_bits(bits, iter);
    });
}

impl HostBackend {
    /// Assemble a host backend from its parts (the `SessionBuilder` is the
    /// usual constructor; this is the escape hatch for custom data/nets).
    pub fn new(
        net: Sequential,
        data: Box<dyn DataSource>,
        opt: Box<dyn Optimizer>,
        batch: usize,
        eval_seed: u64,
        eval_n: usize,
        label: String,
    ) -> Self {
        HostBackend {
            net,
            data,
            ctx: TrainCtx::new(),
            opt,
            batch,
            eval_seed,
            eval_n,
            needs_zero: false,
            schedule: Schedule::default(),
            label,
        }
    }

    /// Forward a batch in inference mode (training caches off, quantized
    /// forward — deployment-int8 semantics under quantized modes).
    pub fn eval_logits(&mut self, x: &Tensor) -> Tensor {
        let was = self.ctx.training;
        self.ctx.training = false;
        let logits = self.net.forward(x, &mut self.ctx);
        self.ctx.training = was;
        logits
    }

    /// Replace the activation stash with a fresh one under `policy` /
    /// `recompute` (DESIGN.md §Activation-Memory). Call before the first
    /// step — the stash carries no cross-step state, but swapping it while
    /// a forward's tensors are in flight would strand them.
    pub fn set_stash(&mut self, policy: StashPolicy, recompute: bool) {
        self.ctx.stash = ActivationStash::new(policy, recompute);
    }

    /// The activation stash (storage policy, byte accounting, adaptive
    /// storage controllers).
    pub fn stash(&self) -> &ActivationStash {
        &self.ctx.stash
    }

    /// Keep every compute controller dormant until step `n` — sugar for
    /// [`set_schedule`](Self::set_schedule) with `Schedule::delay(n)`.
    pub fn set_quant_delay(&mut self, n: u64) {
        self.set_schedule(Schedule::delay(n));
    }

    /// Install a precision schedule (DESIGN.md §Calibration): forward and
    /// backward run pure f32 for iterations below the schedule's
    /// quantization start, then the controllers activate warm-starting from
    /// the float weights; progressive phases retune every compute
    /// controller at their start iterations. The trivial `delay:0`
    /// schedule (the default) is bit-identical to an unscheduled run.
    /// `Schedule::install` is the single definition of the quantization
    /// start — the plumbing `set_quant_delay` used to duplicate per
    /// backend.
    pub fn set_schedule(&mut self, schedule: Schedule) {
        schedule.install(&mut self.ctx);
        self.schedule = schedule;
    }
}

impl Backend for HostBackend {
    fn label(&self) -> &str {
        &self.label
    }

    fn step(&mut self, iter: u64, observe: &mut dyn FnMut(Phase, &StepInfo)) -> Result<f32> {
        // Deferred zeroing: clear the *previous* step's gradients only now,
        // so AfterStep hooks and inter-step probes saw them un-cleared.
        if self.needs_zero {
            self.net.zero_grads();
            self.needs_zero = false;
        }
        self.ctx.stash.begin_step();
        self.ctx.iter = iter;
        if let Some(bits) = self.schedule.retune_at(iter) {
            retune_net(&mut self.net, bits, iter);
        }
        let (x, y) = self.data.batch(self.batch);
        let logits = self.net.forward(&x, &mut self.ctx);
        let (loss, g) = softmax_xent(&logits, &y);
        self.net.backward(&g, &mut self.ctx);
        observe(Phase::AfterBackward, &StepInfo { iter, loss, net: Some(&self.net) });
        self.opt.step(&mut self.net);
        self.needs_zero = true;
        observe(Phase::AfterStep, &StepInfo { iter, loss, net: Some(&self.net) });
        Ok(loss)
    }

    fn eval(&mut self, iters_done: u64) -> Result<EvalOut> {
        self.ctx.ledger.set_total_iters(iters_done);
        let (ex, ey) = self.data.eval_set(self.eval_seed, self.eval_n);
        let logits = self.eval_logits(&ex);
        Ok(EvalOut { accuracy: accuracy(&logits, &ey), loss: None })
    }

    fn take_ledger(&mut self, iters_done: u64) -> Ledger {
        self.ctx.ledger.set_total_iters(iters_done);
        std::mem::take(&mut self.ctx.ledger)
    }
}

/// RNN translation backend over [`Seq2Seq`] and the token-reversal corpus.
/// One seeded RNG drives model init *and* the batch stream, matching the
/// original Fig 9a driver exactly.
pub struct Seq2SeqBackend {
    /// The live encoder–decoder model.
    pub model: Seq2Seq,
    rng: Pcg32,
    ctx: TrainCtx,
    batch: usize,
    len: usize,
    vocab: usize,
    lr: f32,
    eval_batch: usize,
    label: String,
}

impl Seq2SeqBackend {
    /// Build the Fig 9a translation setup: a seeded RNG initializes the
    /// model and then drives the token-reversal batch stream.
    pub fn new(
        label: impl Into<String>,
        vocab: usize,
        dim: usize,
        mode: QuantMode,
        seed: u64,
        batch: usize,
        len: usize,
        lr: f32,
        eval_batch: usize,
    ) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let model = Seq2Seq::new(vocab, dim, mode, &mut rng);
        Seq2SeqBackend {
            model,
            rng,
            ctx: TrainCtx::new(),
            batch,
            len,
            vocab,
            lr,
            eval_batch,
            label: label.into(),
        }
    }

    /// Replace the activation stash (storage policy for the per-timestep
    /// BPTT operands; see [`HostBackend::set_stash`]). Call before the
    /// first step.
    pub fn set_stash(&mut self, policy: StashPolicy, recompute: bool) {
        self.ctx.stash = ActivationStash::new(policy, recompute);
    }

    /// The activation stash (byte accounting, adaptive storage controllers).
    pub fn stash(&self) -> &ActivationStash {
        &self.ctx.stash
    }

    /// Float warm-up: quantized BPTT stays dormant until step `n` — sugar
    /// for [`set_schedule`](Self::set_schedule) with `Schedule::delay(n)`.
    pub fn set_quant_delay(&mut self, n: u64) {
        self.set_schedule(Schedule::delay(n));
    }

    /// Install a precision schedule's quantization start (one
    /// `Schedule::install` definition shared with [`HostBackend`]). The RNN
    /// path's projection controllers are not externally visitable, so
    /// progressive phase retunes apply only on the classifier backends; the
    /// delay axis is fully honored here.
    pub fn set_schedule(&mut self, schedule: Schedule) {
        schedule.install(&mut self.ctx);
    }
}

impl Backend for Seq2SeqBackend {
    fn label(&self) -> &str {
        &self.label
    }

    fn step(&mut self, iter: u64, observe: &mut dyn FnMut(Phase, &StepInfo)) -> Result<f32> {
        self.ctx.stash.begin_step();
        self.ctx.iter = iter;
        let (src, tgt) = translation_batch(&mut self.rng, self.batch, self.len, self.vocab);
        let (loss, _) = self.model.train_step(&src, &tgt, self.lr, &mut self.ctx);
        observe(Phase::AfterStep, &StepInfo { iter, loss, net: None });
        Ok(loss)
    }

    fn eval(&mut self, iters_done: u64) -> Result<EvalOut> {
        self.ctx.ledger.set_total_iters(iters_done);
        // Fork the stream: the eval batch is the one the historical driver
        // drew at this point, but eval() stays idempotent and does not
        // perturb subsequent training batches.
        let mut eval_rng = Pcg32::from_state(self.rng.state());
        let (src, tgt) = translation_batch(&mut eval_rng, self.eval_batch, self.len, self.vocab);
        let (loss, acc) = self.model.eval(&src, &tgt, &mut self.ctx);
        Ok(EvalOut { accuracy: acc, loss: Some(loss) })
    }

    fn take_ledger(&mut self, iters_done: u64) -> Ledger {
        self.ctx.ledger.set_total_iters(iters_done);
        std::mem::take(&mut self.ctx.ledger)
    }

    fn grad_bits(&self) -> Vec<(String, u8)> {
        self.model.grad_bits()
    }
}

/// PJRT backend: drives a train-step artifact through
/// [`coordinator::ArtifactTrainer`](crate::coordinator::ArtifactTrainer)
/// while QEM/QPA run on the host. Borrows the `Runtime` so several
/// sessions (float32 / int16 / adaptive sweeps) can share one compiled
/// artifact. Data inputs come from a caller-supplied generator so the same
/// backend serves LM tokens, MLP batches, or anything the manifest expects.
pub struct PjrtBackend<'r> {
    rt: &'r mut Runtime,
    /// The artifact trainer (slot metadata, controllers, ledger).
    pub trainer: ArtifactTrainer,
    data: Box<dyn FnMut(u64) -> Vec<HostValue> + 'r>,
    lr: f32,
    last_grad_bits: Vec<u8>,
    label: String,
}

impl<'r> PjrtBackend<'r> {
    /// Compile-free construction over an already-loaded artifact: infers
    /// slots from the manifest and initializes parameters host-side.
    pub fn new(
        rt: &'r mut Runtime,
        artifact: &str,
        slot_names: Vec<String>,
        mode: QuantMode,
        seed: u64,
        lr: f32,
        label: impl Into<String>,
        data: Box<dyn FnMut(u64) -> Vec<HostValue> + 'r>,
    ) -> Result<Self> {
        let trainer = ArtifactTrainer::new(rt, artifact, slot_names, mode, seed)?;
        Ok(PjrtBackend { rt, trainer, data, lr, last_grad_bits: Vec::new(), label: label.into() })
    }
}

impl Backend for PjrtBackend<'_> {
    fn label(&self) -> &str {
        &self.label
    }

    fn step(&mut self, iter: u64, observe: &mut dyn FnMut(Phase, &StepInfo)) -> Result<f32> {
        let data = (self.data)(iter);
        let res = self.trainer.step(self.rt, data, self.lr)?;
        self.last_grad_bits = res.grad_bits;
        observe(Phase::AfterStep, &StepInfo { iter, loss: res.loss, net: None });
        Ok(res.loss)
    }

    fn eval(&mut self, _iters_done: u64) -> Result<EvalOut> {
        bail!("the PJRT train-step artifacts carry no eval graph; read the loss curve instead")
    }

    fn take_ledger(&mut self, iters_done: u64) -> Ledger {
        self.trainer.ledger.set_total_iters(iters_done);
        std::mem::take(&mut self.trainer.ledger)
    }

    fn grad_bits(&self) -> Vec<(String, u8)> {
        self.trainer
            .slots
            .iter()
            .map(|s| s.name.clone())
            .zip(self.last_grad_bits.iter().copied())
            .collect()
    }
}
