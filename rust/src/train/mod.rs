//! The unified training front-end (DESIGN.md §Session-API).
//!
//! One builder-driven API over every training path in the repository: a
//! [`SessionBuilder`] configures model, [`QuantMode`], optimizer, data and
//! seed, and produces a [`Session`] with `step()` / `run(n)` / `eval()`,
//! typed [`Phase`] hooks, stable [`ParamId`]-addressed parameter access,
//! checkpoint save/restore, and a [`TrainRecord`] as the uniform result.
//! The host `Sequential` path, the RNN translation path, the PJRT
//! `ArtifactTrainer` path and the data-parallel [`ReplicaGroup`] path
//! (`train::parallel`, DESIGN.md §Data-Parallel) all sit behind the same
//! surface via the [`Backend`] seam — per-tensor precision control
//! (QEM/QPA) stays consistent across them because each backend threads the
//! same controllers/ledger machinery.
//!
//! Ordering contract (the `zero_grads` fix): a step is
//! `zero_grads(previous) → forward → loss → backward → [AfterBackward
//! hooks] → optimizer.step → [AfterStep hooks]`. Gradient clearing is
//! deferred to the *start* of the next step, so probes after `step()`
//! observe the step's true gradients; optimizers never clear them.
//!
//! ```no_run
//! use apt::train::SessionBuilder;
//!
//! let record = SessionBuilder::classifier("alexnet").lr(0.01).train(300);
//! println!("{}: eval acc {:.3}", record.label, record.eval_acc);
//! ```

#![warn(missing_docs)]

mod backend;
pub mod checkpoint;
mod optim;
pub mod parallel;

pub use backend::{Backend, DataSource, HostBackend, PjrtBackend, Seq2SeqBackend};
pub use optim::{Adam, Optimizer, OptimizerState, Sgd};
pub use parallel::{
    CommPrecision, CompressPolicy, ParallelBackend, ReduceError, ReplicaGroup, WireStats,
};

use std::fmt;

use anyhow::{bail, Result};

use crate::apt::Ledger;
use crate::calib::Schedule;
use crate::data::SynthImages;
use crate::mem::{ActivationStash, MemLedger, StashPolicy};
use crate::nn::{models, QuantMode, Sequential};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Where a typed hook fires inside one step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Between `backward` and the optimizer update — parameter gradients
    /// are fully accumulated and untouched (host paths expose the net).
    AfterBackward,
    /// After the optimizer update (gradients are still un-cleared).
    AfterStep,
}

/// What a hook sees.
pub struct StepInfo<'a> {
    /// Iteration index of the step being observed (0-based).
    pub iter: u64,
    /// Training loss of this step.
    pub loss: f32,
    /// The live network on host paths; `None` on device backends.
    pub net: Option<&'a Sequential>,
}

/// Stable parameter address: layer name + slot within that layer's
/// `visit_params` order (e.g. `fc0.0` = weight, `fc0.1` = bias). Replaces
/// the fragile global visit-order indices of the old
/// `param_copy`/`with_param_replaced` idiom — an id stays valid under any
/// change that leaves its layer alone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamId {
    /// Owning layer's name (ledger key).
    pub layer: String,
    /// Index within that layer's `visit_params` order.
    pub slot: usize,
}

impl fmt::Display for ParamId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.layer, self.slot)
    }
}

/// One addressable parameter.
#[derive(Clone, Debug)]
pub struct ParamInfo {
    /// Stable address of the parameter.
    pub id: ParamId,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

/// Held-out evaluation result.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    /// Task metric: classification / word accuracy in [0, 1].
    pub accuracy: f64,
    /// Eval loss where the backend computes one.
    pub loss: Option<f32>,
}

/// Uniform result of a finished run — the successor of the ad-hoc
/// `TrainRun` structs each driver used to carry.
pub struct TrainRecord {
    /// Run label (e.g. `"alexnet-adaptive"`).
    pub label: String,
    /// Per-iteration training losses.
    pub losses: Vec<f32>,
    /// Held-out accuracy (NaN when the backend has no eval path).
    pub eval_acc: f64,
    /// Held-out loss, where the backend computes one.
    pub eval_loss: Option<f32>,
    /// QEM/QPA decision ledger for the whole run.
    pub ledger: Ledger,
    /// Final applied gradient bit-widths, where the backend tracks them.
    pub grad_bits: Vec<(String, u8)>,
}

impl TrainRecord {
    /// Mean of the last `k` losses (convergence summary).
    pub fn tail_loss(&self, k: usize) -> f64 {
        let k = k.min(self.losses.len()).max(1);
        self.losses[self.losses.len() - k..].iter().map(|&x| x as f64).sum::<f64>() / k as f64
    }
}

struct Hook<'h> {
    phase: Phase,
    every: u64,
    f: Box<dyn FnMut(&StepInfo) + 'h>,
}

/// A live training run over some [`Backend`]. `'h` bounds the hook
/// closures (they may borrow driver locals mutably; take the
/// [`record`](Session::record) to release them).
pub struct Session<'h, B: Backend> {
    backend: B,
    label: String,
    iter: u64,
    losses: Vec<f32>,
    hooks: Vec<Hook<'h>>,
}

impl<'h, B: Backend> Session<'h, B> {
    /// Wrap an explicitly constructed backend (the builder covers the host
    /// classifier path; RNN/PJRT backends are constructed directly).
    pub fn with_backend(backend: B) -> Self {
        let label = backend.label().to_string();
        Session { backend, label, iter: 0, losses: Vec::new(), hooks: Vec::new() }
    }

    /// Register a typed hook firing at `phase` on every `every`-th
    /// iteration (1 = every step). Replaces the old `probe_every` closure.
    pub fn on(&mut self, phase: Phase, every: u64, f: impl FnMut(&StepInfo) + 'h) {
        assert!(every >= 1, "hook interval must be ≥ 1");
        self.hooks.push(Hook { phase, every, f: Box::new(f) });
    }

    /// One optimization step; returns the training loss.
    pub fn step(&mut self) -> Result<f32> {
        let iter = self.iter;
        let hooks = &mut self.hooks;
        let backend = &mut self.backend;
        let loss = backend.step(iter, &mut |phase, info| {
            for h in hooks.iter_mut() {
                if h.phase == phase && info.iter % h.every == 0 {
                    (h.f)(info);
                }
            }
        })?;
        self.iter += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Run `iters` steps.
    pub fn run(&mut self, iters: u64) -> Result<&mut Self> {
        for _ in 0..iters {
            self.step()?;
        }
        Ok(self)
    }

    /// Held-out evaluation at the current iteration.
    pub fn eval(&mut self) -> Result<EvalOut> {
        self.backend.eval(self.iter)
    }

    /// Display label of the run (e.g. `"alexnet-adaptive"`).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Number of steps taken so far.
    pub fn iters_done(&self) -> u64 {
        self.iter
    }

    /// Training losses of every step so far.
    pub fn losses(&self) -> &[f32] {
        &self.losses
    }

    /// Currently applied gradient bit-widths, where the backend tracks them.
    pub fn grad_bits(&self) -> Vec<(String, u8)> {
        self.backend.grad_bits()
    }

    /// The underlying backend (e.g. to reach `PjrtBackend::trainer`).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Finish with a held-out evaluation (errors on backends without one).
    pub fn record(mut self) -> Result<TrainRecord> {
        let eval = self.backend.eval(self.iter)?;
        Ok(self.finish(Some(eval)))
    }

    /// Finish without evaluating (e.g. PJRT artifacts, which carry no eval
    /// graph).
    pub fn record_without_eval(mut self) -> TrainRecord {
        self.finish(None)
    }

    fn finish(&mut self, eval: Option<EvalOut>) -> TrainRecord {
        TrainRecord {
            label: self.label.clone(),
            losses: std::mem::take(&mut self.losses),
            eval_acc: eval.as_ref().map(|e| e.accuracy).unwrap_or(f64::NAN),
            eval_loss: eval.and_then(|e| e.loss),
            ledger: self.backend.take_ledger(self.iter),
            grad_bits: self.backend.grad_bits(),
        }
    }
}

/// Host-path extras: stable parameter access and checkpointing.
impl<'h> Session<'h, HostBackend> {
    /// The live network (e.g. for `serve::FrozenModel::freeze`).
    pub fn net(&self) -> &Sequential {
        &self.backend.net
    }

    /// Mutable access to the live network.
    pub fn net_mut(&mut self) -> &mut Sequential {
        &mut self.backend.net
    }

    /// All parameters, in visit order, as stable [`ParamInfo`]s.
    pub fn params(&mut self) -> Vec<ParamInfo> {
        let mut out = Vec::new();
        self.backend.net.visit_params_slotted(&mut |layer, slot, p, _| {
            out.push(ParamInfo {
                id: ParamId { layer: layer.to_string(), slot },
                shape: p.shape.clone(),
            });
        });
        out
    }

    /// The 2-D (weight-matrix) parameters — the tensors the Fig 5/6
    /// deployment-quantization sweep perturbs.
    pub fn weight_params(&mut self) -> Vec<ParamInfo> {
        self.params().into_iter().filter(|p| p.shape.len() == 2).collect()
    }

    fn with_param<R>(&mut self, id: &ParamId, f: &mut dyn FnMut(&mut Tensor) -> R) -> Option<R> {
        let mut out = None;
        self.backend.net.visit_params_slotted(&mut |layer, slot, p, _| {
            if out.is_none() && layer == id.layer && slot == id.slot {
                out = Some(f(p));
            }
        });
        out
    }

    /// Copy of one parameter. Panics on an unknown id.
    pub fn param_copy(&mut self, id: &ParamId) -> Tensor {
        self.with_param(id, &mut |p| p.clone())
            .unwrap_or_else(|| panic!("no parameter {id}"))
    }

    /// Run `f` with parameter `id` temporarily replaced by a transformed
    /// copy, restoring the original afterwards (Fig 5/6 protocol).
    pub fn with_param_replaced<R>(
        &mut self,
        id: &ParamId,
        transform: impl Fn(&mut Tensor),
        f: impl FnOnce(&mut Self) -> R,
    ) -> R {
        let snapshot = self
            .with_param(id, &mut |p| {
                let snap = p.clone();
                transform(p);
                snap
            })
            .unwrap_or_else(|| panic!("no parameter {id}"));
        let out = f(self);
        let mut snapshot = Some(snapshot);
        self.with_param(id, &mut |p| *p = snapshot.take().unwrap())
            .expect("parameter disappeared during with_param_replaced");
        out
    }

    /// Forward a batch in inference mode (deployment-int8 semantics under
    /// quantized modes).
    pub fn eval_logits(&mut self, x: &Tensor) -> Tensor {
        self.backend.eval_logits(x)
    }

    /// The activation stash (storage policy, adaptive storage controllers;
    /// DESIGN.md §Activation-Memory).
    pub fn stash(&self) -> &ActivationStash {
        self.backend.stash()
    }

    /// Activation-memory accounting: peak stashed bytes per step / per run,
    /// put traffic. The measurement behind `bench_act_memory`.
    pub fn mem(&self) -> &MemLedger {
        self.backend.stash().mem()
    }

    /// Save the full mid-run state — parameters, optimizer buffers,
    /// controller state, ledger, data stream, loss curve — such that
    /// [`load_checkpoint`](Session::load_checkpoint) continues the run
    /// bit-identically (see `train::checkpoint`).
    pub fn save_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        checkpoint::save(self, path.as_ref())
    }

    /// Restore a checkpoint into this session. The session must have been
    /// built with the same configuration (model, mode, optimizer, seeds)
    /// that produced the checkpoint; shapes are verified during restore.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        checkpoint::load(self, path.as_ref())
    }
}

/// Data-parallel extras: root-replica access, sync checking, and
/// checkpointing that includes the gradient-communication controllers.
impl<'h> Session<'h, ParallelBackend> {
    /// The root replica's live network (bit-identical to every peer under
    /// the sync invariant).
    pub fn net(&self) -> &Sequential {
        &self.backend.group.host.net
    }

    /// Mutable root-replica network access. Intended for probes; mutating
    /// parameters here without mirroring the peers breaks the sync
    /// invariant.
    pub fn net_mut(&mut self) -> &mut Sequential {
        &mut self.backend.group.host.net
    }

    /// Replica count N of the group.
    pub fn replicas(&self) -> usize {
        self.backend.group.replicas()
    }

    /// Verify that every peer's parameters are bit-identical to the
    /// root's (see [`ReplicaGroup::replicas_in_sync`]).
    pub fn replicas_in_sync(&mut self) -> bool {
        self.backend.group.replicas_in_sync()
    }

    /// The root replica's activation stash (every replica shares the
    /// policy; per-shard peaks are symmetric).
    pub fn stash(&self) -> &ActivationStash {
        self.backend.group.stash()
    }

    /// Root-replica activation-memory accounting (peak stashed bytes per
    /// step / per run). Multiply by [`replicas`](Self::replicas) for the
    /// whole-group figure.
    pub fn mem(&self) -> &MemLedger {
        self.backend.group.stash().mem()
    }

    /// Cumulative bytes-on-wire accounting of the gradient all-reduce
    /// (compressed payload vs raw-f32 baseline vs inter-node traffic) —
    /// the measurement behind `bench_parallel_replicas` (EXPERIMENTS.md
    /// §Compression).
    pub fn wire_stats(&self) -> WireStats {
        *self.backend.group.comm().wire()
    }

    /// Save the full mid-run state — the host-path surface plus the
    /// per-gradient communication controllers and any compression
    /// (error-feedback) state (`train::checkpoint`, DESIGN.md
    /// §Data-Parallel).
    pub fn save_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        checkpoint::save_parallel(self, path.as_ref())
    }

    /// Restore a checkpoint into this group: the root replica's state is
    /// applied and broadcast to every peer (re-establishing the sync
    /// invariant), and the communication controllers resume their saved
    /// schemes and update schedules. The session must have been built with
    /// the same configuration (model, mode, optimizer, seeds, replicas,
    /// comm policy) that produced the checkpoint.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        checkpoint::load_parallel(self, path.as_ref())
    }
}

/// Optimizer choice for the host path.
#[derive(Clone, Copy, Debug)]
pub enum OptChoice {
    /// SGD with momentum coefficient `momentum`.
    SgdMomentum {
        /// Momentum coefficient μ.
        momentum: f32,
    },
    /// Adam with the usual moment/epsilon hyper-parameters.
    Adam {
        /// First-moment decay β₁.
        beta1: f32,
        /// Second-moment decay β₂.
        beta2: f32,
        /// Denominator stabilizer ε.
        eps: f32,
    },
}

enum ModelSpec {
    Zoo(String),
    // `Fn` (not `FnOnce`) so data-parallel sessions can instantiate one
    // bit-identical network per replica from the same seeded RNG state.
    Custom(String, Box<dyn Fn(&mut Pcg32) -> Sequential>),
}

/// The one model-instantiation sequence (seeded RNG → model → gradient
/// overrides) shared by [`SessionBuilder::build`] and
/// [`SessionBuilder::build_parallel`] — the N=1 bit-identity contract
/// between them rests on there being exactly one copy of this code.
fn instantiate_net(
    model: &ModelSpec,
    mode: QuantMode,
    seed: u64,
    overrides: &[(String, u8)],
) -> Result<(String, Sequential)> {
    let mut rng = Pcg32::seeded(seed);
    let (name, mut net) = match model {
        ModelSpec::Zoo(name) => match models::by_name(name, mode, &mut rng) {
            Some(net) => (name.clone(), net),
            None => bail!("unknown model {name:?}"),
        },
        ModelSpec::Custom(name, build) => (name.clone(), build(&mut rng)),
    };
    for (layer, bits) in overrides {
        if !net.set_grad_override(layer, Some(*bits)) {
            bail!("no layer {layer:?} in {name}");
        }
    }
    Ok((name, net))
}

/// The one optimizer-construction path shared by both build flavors.
fn make_optimizer(choice: OptChoice, lr: f32) -> Box<dyn Optimizer> {
    match choice {
        OptChoice::SgdMomentum { momentum } => Box::new(Sgd::new(lr, momentum)),
        OptChoice::Adam { beta1, beta2, eps } => {
            Box::new(Adam::with_config(lr, beta1, beta2, eps))
        }
    }
}

/// The one default-data-source rule shared by both build flavors.
fn make_data(
    data: Option<Box<dyn DataSource>>,
    seed: u64,
    noise: f32,
) -> Box<dyn DataSource> {
    data.unwrap_or_else(|| {
        Box::new(SynthImages::new(
            seed + 1000,
            models::CLASSES,
            models::IN_C,
            models::IN_H,
            models::IN_W,
            noise,
        ))
    })
}

/// Builder for host-path [`Session`]s — the one way to configure a
/// classifier training run. Defaults mirror the historical
/// `exp::common::TrainOpts` defaults (alexnet, float32, lr 0.02, batch 16,
/// seed 0, noise 0.5, SGD momentum 0.9), so a bare
/// `SessionBuilder::classifier("alexnet").train(n)` reproduces the old
/// `train_classifier` run bit-for-bit.
pub struct SessionBuilder {
    model: ModelSpec,
    mode: QuantMode,
    lr: f32,
    batch: usize,
    seed: u64,
    noise: f32,
    grad_overrides: Vec<(String, u8)>,
    optimizer: OptChoice,
    data: Option<Box<dyn DataSource>>,
    eval_seed: u64,
    eval_n: usize,
    label: Option<String>,
    stash: StashPolicy,
    recompute: bool,
    compress: Option<CompressPolicy>,
    node_size: usize,
    schedule: Schedule,
}

/// Under a schedule with a quantization delay the Adaptive init phase
/// (probe every iteration) shifts to begin at activation, so the
/// controllers still get their dense warm-up on the first *quantized*
/// steps. Delay 0 returns the mode untouched — the bit-identity pin.
fn delayed_mode(mode: QuantMode, delay: u64) -> QuantMode {
    match mode {
        QuantMode::Adaptive(mut cfg) if delay > 0 => {
            cfg.init_phase_iters += delay;
            QuantMode::Adaptive(cfg)
        }
        m => m,
    }
}

impl SessionBuilder {
    /// A model-zoo classifier by family name
    /// (`alexnet|vgg|resnet|mobilenet|inception|mlp`).
    pub fn classifier(model: impl Into<String>) -> Self {
        SessionBuilder {
            model: ModelSpec::Zoo(model.into()),
            mode: QuantMode::Float32,
            lr: 0.02,
            batch: 16,
            seed: 0,
            noise: 0.5,
            grad_overrides: Vec::new(),
            optimizer: OptChoice::SgdMomentum { momentum: 0.9 },
            data: None,
            eval_seed: 999,
            eval_n: 256,
            label: None,
            stash: StashPolicy::F32,
            recompute: false,
            compress: None,
            node_size: 1,
            schedule: Schedule::default(),
        }
    }

    /// A custom [`Sequential`], built from the session's seeded RNG so runs
    /// stay deterministic. Pair with [`data`](Self::data) unless the net
    /// consumes the default synthetic-image geometry. The builder closure
    /// may run once per replica under
    /// [`build_parallel`](Self::build_parallel), so it must be `Fn`.
    pub fn custom(
        label: impl Into<String>,
        build: impl Fn(&mut Pcg32) -> Sequential + 'static,
    ) -> Self {
        let label = label.into();
        let mut b = Self::classifier("");
        b.model = ModelSpec::Custom(label.clone(), Box::new(build));
        b.label = Some(label);
        b
    }

    /// Quantization mode of the run (default float32).
    pub fn mode(mut self, mode: QuantMode) -> Self {
        self.mode = mode;
        self
    }

    /// Learning rate (default 0.02).
    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    /// Batch size (default 16).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Model/data seed (default 0).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Noise level of the default synthetic-image data source.
    pub fn noise(mut self, noise: f32) -> Self {
        self.noise = noise;
        self
    }

    /// Pin one layer's gradient bit-width (Fig 1/2/11 ablations).
    pub fn grad_override(mut self, layer: impl Into<String>, bits: u8) -> Self {
        self.grad_overrides.push((layer.into(), bits));
        self
    }

    /// Pin several layers' gradient bit-widths at once.
    pub fn grad_overrides(mut self, ovs: Vec<(String, u8)>) -> Self {
        self.grad_overrides.extend(ovs);
        self
    }

    /// Optimizer choice (default SGD, momentum 0.9).
    pub fn optimizer(mut self, opt: OptChoice) -> Self {
        self.optimizer = opt;
        self
    }

    /// Use Adam (β₁=0.9, β₂=0.999, ε=1e-8) instead of SGD-momentum.
    pub fn adam(self) -> Self {
        self.optimizer(OptChoice::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 })
    }

    /// Replace the default synthetic-image source.
    pub fn data(mut self, data: Box<dyn DataSource>) -> Self {
        self.data = Some(data);
        self
    }

    /// Held-out evaluation stream (seed, set size); default (999, 256).
    pub fn eval_set(mut self, seed: u64, n: usize) -> Self {
        self.eval_seed = seed;
        self.eval_n = n;
        self
    }

    /// Override the record/log label (default `"<model>-<mode>"`).
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Activation-stash storage policy (CLI `--act-bits`; default
    /// [`StashPolicy::F32`], bit-identical to the historical private-field
    /// caches — DESIGN.md §Activation-Memory).
    pub fn stash_policy(mut self, policy: StashPolicy) -> Self {
        self.stash = policy;
        self
    }

    /// Gradient-checkpointing option (CLI `--recompute`): the GEMM layers
    /// stash only their raw inputs and re-derive X̂/Ŵ/patches during
    /// backward from the schemes frozen at forward time. Orthogonal to
    /// [`stash_policy`](Self::stash_policy); bit-identical under F32
    /// storage.
    pub fn recompute(mut self, on: bool) -> Self {
        self.recompute = on;
        self
    }

    /// Gradient-compression policy of the data-parallel all-reduce (CLI
    /// `--compress`; DESIGN.md §Data-Parallel). Defaults per `--comm-bits`:
    /// dense codes ([`CompressPolicy::Quantize`]) for quantized precisions,
    /// [`CompressPolicy::None`] for f32. Only
    /// [`build_parallel`](Self::build_parallel) consults it; compatibility
    /// with the comm precision is validated there.
    pub fn compress(mut self, policy: CompressPolicy) -> Self {
        self.compress = Some(policy);
        self
    }

    /// Float warm-up before quantization (CLI `--quant-delay`): the first
    /// `n` steps run pure f32 forward/backward, then the controllers
    /// activate, warm-starting from the float weights. Under
    /// [`QuantMode::Adaptive`] the init probe phase shifts to begin at
    /// step `n`, so the probe-every-iteration warm-up covers the first
    /// quantized steps. `n = 0` (the default) is bit-identical to an
    /// undelayed run. Compute-side only — the data-parallel comm precision
    /// is unaffected (wire compression has its own adaptive warm-up).
    /// Sugar for [`schedule`](Self::schedule) with `Schedule::delay(n)`.
    pub fn quant_delay(self, n: u64) -> Self {
        self.schedule(Schedule::delay(n))
    }

    /// Precision schedule of the run (CLI `--schedule`; DESIGN.md
    /// §Calibration): when quantization turns on
    /// (generalizing [`quant_delay`](Self::quant_delay)) and, for
    /// progressive schedules, which bit-width every compute controller is
    /// retuned to at each phase boundary. The default `Schedule::delay(0)`
    /// and any degenerate schedule (single phase at the configured width)
    /// are bit-identical to an unscheduled run.
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Hierarchical node size of the all-reduce (CLI `--node-size`;
    /// default 1 = flat). Replicas are grouped into consecutive
    /// power-of-two "nodes": the intra-node hop aggregates exactly, only
    /// the inter-node hop pays compressed traffic. Bit-identical to the
    /// flat reduction at any node size (the `hier_reduce_f32` lemma).
    pub fn node_size(mut self, node: usize) -> Self {
        self.node_size = node;
        self
    }

    /// Construct the [`Session`]. Initialization order (RNG → model →
    /// overrides → data → optimizer) matches the historical loop exactly.
    /// Panics on an unknown model/layer (the historical contract);
    /// [`build_parallel`](Self::build_parallel) is the `Result` flavor.
    pub fn build<'h>(self) -> Session<'h, HostBackend> {
        let mode = delayed_mode(self.mode, self.schedule.quant_from());
        let (name, net) = instantiate_net(&self.model, mode, self.seed, &self.grad_overrides)
            .unwrap_or_else(|e| panic!("{e}"));
        let data = make_data(self.data, self.seed, self.noise);
        let opt = make_optimizer(self.optimizer, self.lr);
        let label = self
            .label
            .unwrap_or_else(|| format!("{}-{}", name, self.mode.label()));
        let mut backend = HostBackend::new(
            net,
            data,
            opt,
            self.batch,
            self.eval_seed,
            self.eval_n,
            label,
        );
        backend.set_stash(self.stash, self.recompute);
        backend.set_schedule(self.schedule);
        Session::with_backend(backend)
    }

    /// Build, run `iters` steps, evaluate, and return the record — the
    /// one-call replacement for `train_classifier`.
    pub fn train(self, iters: u64) -> TrainRecord {
        let mut s = self.build();
        s.run(iters).expect("host training cannot fail");
        s.record().expect("host eval cannot fail")
    }

    /// Construct a data-parallel [`Session`]: `replicas` bit-identical
    /// model copies sharding each batch, exchanging gradients under the
    /// `comm` precision and the configured [`compress`](Self::compress) /
    /// [`node_size`](Self::node_size) policy through the deterministic
    /// compressed all-reduce (DESIGN.md §Data-Parallel). Each replica
    /// replays the exact [`build`](Self::build) initialization sequence
    /// from the same seed, and with `replicas == 1` the session degenerates
    /// to the plain host loop bit-identically, regardless of `comm` or
    /// compression policy. Errors when the batch does not split evenly,
    /// the model name is unknown, or the (comm, compress, node) combination
    /// is invalid.
    pub fn build_parallel<'h>(
        self,
        replicas: usize,
        comm: CommPrecision,
    ) -> Result<Session<'h, ParallelBackend>> {
        if replicas == 0 {
            bail!("need at least one replica");
        }
        if self.batch % replicas != 0 {
            bail!(
                "batch {} does not split across {replicas} replicas (use a multiple)",
                self.batch
            );
        }
        let SessionBuilder {
            model,
            mode,
            lr,
            batch,
            seed,
            noise,
            grad_overrides,
            optimizer,
            data,
            eval_seed,
            eval_n,
            label,
            stash,
            recompute,
            compress,
            node_size,
            schedule,
        } = self;
        let mode = delayed_mode(mode, schedule.quant_from());
        let policy = compress.unwrap_or_else(|| comm.default_compress());
        // One bit-identical instantiation per replica: the same
        // `instantiate_net` sequence `build()` runs, once per replica.
        let mut nets = Vec::with_capacity(replicas);
        let mut name = String::new();
        for _ in 0..replicas {
            let (n, net) = instantiate_net(&model, mode, seed, &grad_overrides)?;
            name = n;
            nets.push(net);
        }
        let data = make_data(data, seed, noise);
        let base = label.unwrap_or_else(|| format!("{}-{}", name, mode.label()));
        let full = if replicas > 1 {
            if policy == comm.default_compress() {
                format!("{base}-x{replicas}-{}", comm.label())
            } else {
                format!("{base}-x{replicas}-{}-{}", comm.label(), policy.label())
            }
        } else {
            base
        };
        let host = HostBackend::new(
            nets.remove(0),
            data,
            make_optimizer(optimizer, lr),
            batch,
            eval_seed,
            eval_n,
            full.clone(),
        );
        let peer_parts = nets
            .into_iter()
            .map(|net| (net, make_optimizer(optimizer, lr)))
            .collect();
        let mut group = ReplicaGroup::new(host, peer_parts, comm, policy, node_size)?;
        group.set_stash(stash, recompute);
        group.set_schedule(schedule);
        Ok(Session::with_backend(ParallelBackend::new(group, full)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apt::AptConfig;

    #[test]
    fn classifier_trains_and_reports() {
        let run = SessionBuilder::classifier("mlp").train(30);
        assert_eq!(run.losses.len(), 30);
        assert!(run.eval_acc > 0.15, "acc={}", run.eval_acc); // better than chance
        assert_eq!(run.label, "mlp-float32");
    }

    #[test]
    fn hooks_fire_on_schedule() {
        let mut after_backward = 0usize;
        let mut after_step = 0usize;
        {
            let mut s = SessionBuilder::classifier("mlp").build();
            s.on(Phase::AfterBackward, 2, |info| {
                assert!(info.net.is_some());
                after_backward += 1;
            });
            s.on(Phase::AfterStep, 1, |_| after_step += 1);
            s.run(10).unwrap();
        }
        assert_eq!(after_backward, 5); // iters 0,2,4,6,8
        assert_eq!(after_step, 10);
    }

    #[test]
    fn grads_observable_after_step() {
        let mut s = SessionBuilder::classifier("mlp").build();
        s.step().unwrap();
        // the fused-Sgd footgun: these used to read back all-zero
        let mut nonzero = false;
        s.net_mut().visit_params(&mut |_, g| {
            nonzero |= g.data.iter().any(|&v| v != 0.0);
        });
        assert!(nonzero, "gradients were cleared before probes could see them");
    }

    #[test]
    fn param_ids_are_stable_addresses() {
        let mut s = SessionBuilder::classifier("mlp").build();
        let params = s.params();
        // mlp: 3 × (weight + bias)
        assert_eq!(params.len(), 6);
        assert_eq!(params[0].id, ParamId { layer: "fc0".into(), slot: 0 });
        assert_eq!(params[1].id, ParamId { layer: "fc0".into(), slot: 1 });
        let weights = s.weight_params();
        assert_eq!(weights.len(), 3);
        assert!(weights.iter().all(|p| p.shape.len() == 2));

        let id = weights[0].id.clone();
        let before = s.param_copy(&id);
        let seen = s.with_param_replaced(
            &id,
            |p| p.data.fill(0.0),
            |s2| s2.param_copy(&id),
        );
        assert!(seen.data.iter().all(|&v| v == 0.0));
        assert_eq!(s.param_copy(&id), before, "original must be restored");
    }

    #[test]
    fn adaptive_session_fills_ledger() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 2;
        let run = SessionBuilder::classifier("mlp")
            .mode(QuantMode::Adaptive(cfg))
            .train(20);
        assert!(run.ledger.total_updates() > 0);
        assert_eq!(run.ledger.total_iters, 20);
        assert_eq!(run.label, "mlp-adaptive");
    }

    #[test]
    fn quant_delay_zero_is_bit_identical_and_delay_floats_first() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 2;
        // delay 0 must be a no-op down to the bits.
        let base = SessionBuilder::classifier("mlp").mode(QuantMode::Adaptive(cfg)).train(12);
        let d0 = SessionBuilder::classifier("mlp")
            .mode(QuantMode::Adaptive(cfg))
            .quant_delay(0)
            .train(12);
        for (i, (a, b)) in base.losses.iter().zip(&d0.losses).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "delay-0 loss {i} diverged");
        }
        // delay n: the first n steps are the float trajectory, bit for bit
        // (controllers exist but stay dormant), and the run still finishes.
        let f32run = SessionBuilder::classifier("mlp").train(12);
        let d6 = SessionBuilder::classifier("mlp")
            .mode(QuantMode::Adaptive(cfg))
            .quant_delay(6)
            .train(12);
        for i in 0..6 {
            assert_eq!(
                f32run.losses[i].to_bits(),
                d6.losses[i].to_bits(),
                "pre-activation loss {i} diverged from float"
            );
        }
        assert_eq!(d6.losses.len(), 12);
        // After activation the controllers actually record decisions.
        assert!(d6.ledger.total_updates() > 0, "controllers never activated");
    }

    #[test]
    fn seq2seq_backend_same_surface() {
        let b = Seq2SeqBackend::new("rnn-f32", 12, 16, QuantMode::Float32, 0, 8, 4, 0.05, 32);
        let mut s = Session::with_backend(b);
        s.run(25).unwrap();
        let rec = s.record().unwrap();
        assert_eq!(rec.losses.len(), 25);
        assert!(rec.eval_loss.is_some());
        assert!(rec.eval_acc >= 0.0 && rec.eval_acc <= 1.0);
    }
}
