//! Composable gradient compressors for the data-parallel all-reduce
//! (DESIGN.md §Data-Parallel).
//!
//! The [`Compressor`] trait is the lossy-stage seam of
//! [`QuantAllReduce`](super::QuantAllReduce): each replica's parameter
//! gradient is **corrected** (error-feedback residual added back),
//! **compressed** into a [`WirePayload`], and the payloads are combined by
//! the engine — exact i64 code summation for quantized payloads, the
//! deterministic f32 tree for dense/sparse ones. Four policies compose the
//! two lossy stages the literature layers on top of each other:
//!
//! - [`IdentityCompressor`] (`--compress none`) — raw f32 payloads,
//!   bit-identical to the pre-seam f32 path.
//! - [`QuantizeCompressor`] (`--compress quantize`) — the QEM/QPA-adaptive
//!   fixed-point path: shared root-probed scheme, integer codes on the wire.
//! - [`TopKCompressor`] (`--compress topk:<ratio>`) — magnitude top-k
//!   sparsification with **error feedback**: the un-sent mass is carried
//!   into the next step's gradient, not dropped.
//! - [`TopKQuantizeCompressor`] (`--compress topk:<ratio>+quantize`) —
//!   the composition: top-k selection first, then fixed-point codes for the
//!   selected values under a root-probed shared scheme.
//!
//! Exactness contracts (pinned by `rust/tests/test_compress_props.rs`):
//! compress∘decompress of the identity policy is bit-identical to its
//! input; the quantize policy equals the scheme's `fake_quant` per element;
//! and top-k error feedback is an exact *partition* of the corrected
//! gradient — every element lands bit-identically either in the payload or
//! in the stored residual, never both, never changed.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

use crate::apt::{AptConfig, ControllerState, Ledger, PrecisionController};
use crate::fixedpoint::{quantize, Format, MinifloatKind, Scheme, TensorKind};

/// Fallback top-k ratio for the bare `topk` / `topk+quantize` spellings.
pub const DEFAULT_TOPK_RATIO: f32 = 0.1;

/// Which lossy stages sit on the gradient wire (CLI `--compress`). The
/// payload *bit-width* stays a [`super::CommPrecision`] concern; the policy
/// decides whether quantization and/or sparsification are applied at all.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressPolicy {
    /// Raw f32 payloads (requires f32 comm precision).
    None,
    /// Fixed-point codes under the root-probed per-tensor scheme — the
    /// historical quantized all-reduce (requires quantized comm precision).
    Quantize,
    /// Top-k sparsification with error feedback; selected values travel as
    /// raw f32 (requires f32 comm precision).
    TopK(f32),
    /// Top-k sparsification with error feedback, selected values quantized
    /// to fixed-point codes (requires quantized comm precision).
    TopKQuantize(f32),
}

impl CompressPolicy {
    /// Parse a `--compress` value: `none`, `quantize`, `topk[:<ratio>]`,
    /// `topk[:<ratio>]+quantize`.
    pub fn parse(s: &str) -> Result<CompressPolicy> {
        let s = s.trim();
        let parsed = match s {
            "none" => CompressPolicy::None,
            "quantize" => CompressPolicy::Quantize,
            "topk" => CompressPolicy::TopK(DEFAULT_TOPK_RATIO),
            "topk+quantize" => CompressPolicy::TopKQuantize(DEFAULT_TOPK_RATIO),
            _ => match s.strip_prefix("topk:") {
                Some(rest) => {
                    let (ratio_str, quantize) = match rest.strip_suffix("+quantize") {
                        Some(r) => (r, true),
                        None => (rest, false),
                    };
                    let ratio: f32 = ratio_str.parse().map_err(|_| {
                        anyhow::anyhow!("--compress topk ratio {ratio_str:?} is not a number")
                    })?;
                    if quantize {
                        CompressPolicy::TopKQuantize(ratio)
                    } else {
                        CompressPolicy::TopK(ratio)
                    }
                }
                None => bail!(
                    "unknown --compress {s:?} (expected none, quantize, topk:<ratio> or \
                     topk:<ratio>+quantize)"
                ),
            },
        };
        parsed.validate_ratio()?;
        Ok(parsed)
    }

    /// Display label; also the token stored in the checkpoint `compress`
    /// section, so it must stay whitespace-free and deterministic.
    pub fn label(&self) -> String {
        match self {
            CompressPolicy::None => "none".into(),
            CompressPolicy::Quantize => "quantize".into(),
            CompressPolicy::TopK(r) => format!("topk:{r}"),
            CompressPolicy::TopKQuantize(r) => format!("topk:{r}+quantize"),
        }
    }

    /// Whether the wire payload is integer codes (needs a quantized
    /// [`super::CommPrecision`]).
    pub fn wants_codes(&self) -> bool {
        matches!(self, CompressPolicy::Quantize | CompressPolicy::TopKQuantize(_))
    }

    /// Whether the policy carries per-(tensor, replica) error-feedback
    /// residuals that a checkpoint must round-trip.
    pub fn has_residual_state(&self) -> bool {
        matches!(self, CompressPolicy::TopK(_) | CompressPolicy::TopKQuantize(_))
    }

    pub(crate) fn validate_ratio(&self) -> Result<()> {
        if let CompressPolicy::TopK(r) | CompressPolicy::TopKQuantize(r) = self {
            if !(*r > 0.0 && *r <= 1.0) {
                bail!("top-k ratio must be in (0, 1], got {r}");
            }
        }
        Ok(())
    }
}

/// A typed all-reduce input rejection — malformed per-replica gradients
/// fail loudly instead of producing a silently wrong average (the
/// zip-truncation bug class this replaces).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ReduceError {
    /// `reduce` was called with an empty replica list.
    Empty,
    /// A replica contributed a different number of gradient tensors than
    /// replica 0.
    TensorCount {
        /// Offending replica index.
        replica: usize,
        /// Its tensor count.
        got: usize,
        /// Replica 0's tensor count.
        want: usize,
    },
    /// One replica's gradient tensor disagrees in length with replica 0's.
    Length {
        /// Tensor index (parameter visit order).
        tensor: usize,
        /// Offending replica index.
        replica: usize,
        /// Its tensor length.
        got: usize,
        /// Replica 0's tensor length.
        want: usize,
    },
}

impl fmt::Display for ReduceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReduceError::Empty => write!(f, "gradient all-reduce over zero replicas"),
            ReduceError::TensorCount { replica, got, want } => write!(
                f,
                "replica {replica} contributed {got} gradient tensors, replica 0 has {want}"
            ),
            ReduceError::Length { tensor, replica, got, want } => write!(
                f,
                "gradient tensor {tensor}: replica {replica} has length {got}, replica 0 has {want}"
            ),
        }
    }
}

impl std::error::Error for ReduceError {}

/// What one replica actually puts on the wire for one gradient tensor.
/// [`wire_bytes`](WirePayload::wire_bytes) is the accounting the replica
/// bench reports; [`encode`](WirePayload::encode) is the canonical byte
/// serialization those counts are pinned against (and the determinism
/// witness: same input ⇒ byte-identical payload).
#[derive(Clone, Debug, PartialEq)]
pub enum WirePayload {
    /// Raw f32 gradient (identity policy / f32 comm).
    Dense(Vec<f32>),
    /// Fixed-point codes of every element under a shared scheme.
    Codes {
        /// The shared (root-probed) quantization scheme.
        scheme: Scheme,
        /// One code per element.
        codes: Vec<i32>,
    },
    /// Scaled minifloat byte codes of every element (`--comm-bits
    /// e4m3|e5m2`). Minifloat sums are not exact, so these decode to f32
    /// and travel the deterministic tree like dense payloads — the saving
    /// is the 1 byte/element replica hop, not the reduction itself.
    F8 {
        /// The minifloat codec.
        kind: MinifloatKind,
        /// Per-payload scale exponent (each sender scales to its own range).
        s: i32,
        /// One byte code per element.
        codes: Vec<u8>,
    },
    /// Top-k values at their indices; un-sent elements are implicit zeros.
    Sparse {
        /// Dense length of the tensor.
        len: usize,
        /// Selected indices, ascending.
        idx: Vec<u32>,
        /// Selected values, parallel to `idx`.
        val: Vec<f32>,
    },
    /// Top-k *quantized* values at their indices.
    SparseCodes {
        /// Dense length of the tensor.
        len: usize,
        /// The shared (root-probed) quantization scheme.
        scheme: Scheme,
        /// Selected indices, ascending.
        idx: Vec<u32>,
        /// Codes of the selected values, parallel to `idx`.
        codes: Vec<i32>,
    },
}

/// Bytes one `bits`-wide two's-complement code occupies on the wire.
fn bytes_per_code(bits: u32) -> u64 {
    (bits as u64).div_ceil(8)
}

/// Extra carry bits an exact sum of `m` codes needs: ceil(log2(m)).
fn carry_bits(m: usize) -> u32 {
    if m <= 1 {
        0
    } else {
        usize::BITS - (m - 1).leading_zeros()
    }
}

impl WirePayload {
    /// Dense length of the tensor the payload describes.
    pub fn dense_len(&self) -> usize {
        match self {
            WirePayload::Dense(v) => v.len(),
            WirePayload::Codes { codes, .. } => codes.len(),
            WirePayload::F8 { codes, .. } => codes.len(),
            WirePayload::Sparse { len, .. } | WirePayload::SparseCodes { len, .. } => *len,
        }
    }

    /// The shared quantization scheme, for code-carrying payloads.
    pub fn scheme(&self) -> Option<Scheme> {
        match self {
            WirePayload::Codes { scheme, .. } | WirePayload::SparseCodes { scheme, .. } => {
                Some(*scheme)
            }
            _ => None,
        }
    }

    /// Bytes this payload occupies on the wire — exactly
    /// `self.encode().len()` (pinned by the property battery), computed
    /// without materializing the bytes.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            WirePayload::Dense(v) => 5 + 4 * v.len() as u64,
            WirePayload::Codes { scheme, codes } => {
                10 + bytes_per_code(scheme.bits as u32) * codes.len() as u64
            }
            WirePayload::F8 { codes, .. } => 10 + codes.len() as u64,
            WirePayload::Sparse { idx, .. } => 9 + 8 * idx.len() as u64,
            WirePayload::SparseCodes { scheme, idx, .. } => {
                14 + (4 + bytes_per_code(scheme.bits as u32)) * idx.len() as u64
            }
        }
    }

    /// Canonical little-endian serialization: a 1-byte tag, the layout
    /// header, then the packed elements (codes take `ceil(bits/8)` bytes
    /// each). Deterministic by construction — the byte-identity witness of
    /// the determinism property.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() as usize);
        match self {
            WirePayload::Dense(v) => {
                out.push(0u8);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            WirePayload::Codes { scheme, codes } => {
                out.push(1u8);
                out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
                out.push(scheme.bits);
                out.extend_from_slice(&scheme.s.to_le_bytes());
                let bp = bytes_per_code(scheme.bits as u32) as usize;
                for c in codes {
                    out.extend_from_slice(&c.to_le_bytes()[..bp.min(4)]);
                }
            }
            WirePayload::F8 { kind, s, codes } => {
                out.push(4u8);
                out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
                out.push(match kind {
                    MinifloatKind::E4M3 => 0,
                    MinifloatKind::E5M2 => 1,
                });
                out.extend_from_slice(&s.to_le_bytes());
                out.extend_from_slice(codes);
            }
            WirePayload::Sparse { len, idx, val } => {
                out.push(2u8);
                out.extend_from_slice(&(*len as u32).to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for i in idx {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for x in val {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            WirePayload::SparseCodes { len, scheme, idx, codes } => {
                out.push(3u8);
                out.extend_from_slice(&(*len as u32).to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                out.push(scheme.bits);
                out.extend_from_slice(&scheme.s.to_le_bytes());
                let bp = bytes_per_code(scheme.bits as u32) as usize;
                for (i, c) in idx.iter().zip(codes) {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&c.to_le_bytes()[..bp.min(4)]);
                }
            }
        }
        out
    }

    /// Decode back to a dense f32 tensor (un-sent sparse elements are 0.0).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            WirePayload::Dense(v) => v.clone(),
            WirePayload::Codes { scheme, codes } => {
                codes.iter().map(|&c| scheme.decode(c)).collect()
            }
            WirePayload::F8 { kind, s, codes } => {
                let mut out = vec![0.0f32; codes.len()];
                quantize::decode_f8(codes, &mut out, *kind, *s);
                out
            }
            WirePayload::Sparse { len, idx, val } => {
                let mut out = vec![0.0f32; *len];
                for (&i, &x) in idx.iter().zip(val) {
                    out[i as usize] = x;
                }
                out
            }
            WirePayload::SparseCodes { len, scheme, idx, codes } => {
                let mut out = vec![0.0f32; *len];
                for (&i, &c) in idx.iter().zip(codes) {
                    out[i as usize] = scheme.decode(c);
                }
                out
            }
        }
    }

    /// Whether the payload carries integer codes (summed exactly in i64)
    /// rather than f32 values (summed by the deterministic tree).
    pub fn is_coded(&self) -> bool {
        matches!(self, WirePayload::Codes { .. } | WirePayload::SparseCodes { .. })
    }

    /// Add this payload's codes into a dense i64 accumulator — the exact,
    /// order-independent summation of the quantized paths.
    pub(crate) fn accumulate_codes(&self, acc: &mut [i64]) {
        match self {
            WirePayload::Codes { codes, .. } => {
                for (a, &c) in acc.iter_mut().zip(codes) {
                    *a += c as i64;
                }
            }
            WirePayload::SparseCodes { idx, codes, .. } => {
                for (&i, &c) in idx.iter().zip(codes) {
                    acc[i as usize] += c as i64;
                }
            }
            _ => unreachable!("f32 payloads are tree-reduced, not code-summed"),
        }
    }
}

/// Bytes the exact intra-node aggregate of `group` payloads occupies on
/// the inter-node wire: code payloads widen by ceil(log2(members)) carry
/// bits (the i64 partial sum re-encoded at the minimal exact width),
/// sparse payloads merge to their support union. With one member this is
/// exactly the member's [`WirePayload::wire_bytes`].
pub fn aggregate_wire_bytes(group: &[WirePayload]) -> u64 {
    assert!(!group.is_empty(), "aggregate over an empty node");
    if group.len() == 1 {
        // A node of one forwards the payload as-is, whatever its type.
        return group[0].wire_bytes();
    }
    let carry = carry_bits(group.len());
    match &group[0] {
        WirePayload::Dense(v) => 5 + 4 * v.len() as u64,
        WirePayload::Codes { scheme, codes } => {
            10 + bytes_per_code(scheme.bits as u32 + carry) * codes.len() as u64
        }
        // Minifloat partial sums are not representable in f8 without new
        // rounding, so the inter-node hop carries the decoded f32 sums.
        WirePayload::F8 { codes, .. } => 5 + 4 * codes.len() as u64,
        WirePayload::Sparse { len, .. } => 9 + 8 * union_support(group, *len),
        WirePayload::SparseCodes { len, scheme, .. } => {
            14 + (4 + bytes_per_code(scheme.bits as u32 + carry)) * union_support(group, *len)
        }
    }
}

/// Size of the union of sparse supports across `group`.
fn union_support(group: &[WirePayload], len: usize) -> u64 {
    let mut seen = vec![false; len];
    for p in group {
        if let WirePayload::Sparse { idx, .. } | WirePayload::SparseCodes { idx, .. } = p {
            for &i in idx {
                seen[i as usize] = true;
            }
        }
    }
    seen.iter().filter(|&&s| s).count() as u64
}

/// Cumulative bytes-on-wire accounting of a reduction engine — the
/// measurement behind `bench_parallel_replicas` (EXPERIMENTS.md
/// §Compression). Purely observational: no reduction math depends on it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Total compressed payload bytes produced by all replicas (the flat,
    /// single-level communication cost).
    pub replica_bytes: u64,
    /// Bytes crossing the inter-node boundary under the two-level
    /// hierarchical reduce (equals `replica_bytes` at `node_size` 1).
    pub internode_bytes: u64,
    /// What the same gradient traffic costs as raw f32 (4 bytes/element ×
    /// replicas) — the baseline of the reduction ratio.
    pub dense_bytes: u64,
    /// Number of `reduce` calls accounted.
    pub reduces: u64,
}

impl WireStats {
    /// Bytes-on-wire reduction factor vs raw f32: `dense / replica` (1.0
    /// before any traffic).
    pub fn reduction(&self) -> f64 {
        if self.replica_bytes == 0 {
            1.0
        } else {
            self.dense_bytes as f64 / self.replica_bytes as f64
        }
    }

    /// Reduction factor of the inter-node hop (hierarchical aggregation on
    /// top of per-replica compression).
    pub fn internode_reduction(&self) -> f64 {
        if self.internode_bytes == 0 {
            1.0
        } else {
            self.dense_bytes as f64 / self.internode_bytes as f64
        }
    }
}

/// One checkpointed error-feedback residual: (tensor index, replica index,
/// residual vector) — the `cr` records of the checkpoint `compress`
/// section.
pub type ResidualRecord = (usize, usize, Vec<f32>);

/// The checkpointed state of a compression policy: its label (format
/// compatibility gate, mirroring the comm-controller name check) plus every
/// error-feedback residual. Serialized as the optional trailing `compress`
/// section of checkpoint format v3 (`train::checkpoint`).
#[derive(Clone, Debug, PartialEq)]
pub struct CompressSnapshot {
    /// [`CompressPolicy::label`] of the saving group.
    pub label: String,
    /// Per-(tensor, replica) residuals, in key order; empty for policies
    /// without error feedback.
    pub residuals: Vec<ResidualRecord>,
}

/// Validate a controller-snapshot section against a controller list —
/// shared by every compressor so the error wording (pinned by
/// `test_parallel.rs`) stays identical across policies.
fn check_controller_snapshot(
    ctls: &[PrecisionController],
    st: &[(String, ControllerState)],
) -> Result<()> {
    if st.len() != ctls.len() {
        bail!(
            "checkpoint has {} communication controllers, this group has {}",
            st.len(),
            ctls.len()
        );
    }
    for ((name, _), c) in st.iter().zip(ctls) {
        if *name != c.layer {
            bail!("communication controller mismatch: checkpoint {name:?} vs group {:?}", c.layer);
        }
    }
    Ok(())
}

/// One lossy (or identity) stage between a replica's local gradient and
/// the wire. The engine drives it per tensor `t` as: `corrected(t, 0)` →
/// [`begin_tensor`](Compressor::begin_tensor) (root probe) → one
/// [`compress`](Compressor::compress) per replica → payload combination.
/// State (controllers, residuals) is snapshot/restored through the same
/// methods checkpointing uses for the rest of the session.
pub trait Compressor {
    /// Policy label (matches [`CompressPolicy::label`]).
    fn label(&self) -> String;

    /// Root-probe hook: called once per tensor per step with replica 0's
    /// *corrected* gradient, before any `compress` call — where the
    /// quantizing policies run QEM/QPA and freeze the step's shared scheme.
    fn begin_tensor(&mut self, _iter: u64, _t: usize, _root: &[f32], _ledger: &mut Ledger) {}

    /// Error-feedback correction for (tensor `t`, replica `r`): the local
    /// gradient plus the residual withheld from the previous step
    /// (identity for policies without residuals).
    fn corrected(&self, _t: usize, _r: usize, grad: &[f32]) -> Vec<f32> {
        grad.to_vec()
    }

    /// Compress the corrected gradient into its wire payload, updating the
    /// (tensor, replica) residual state for policies that keep one.
    fn compress(&mut self, t: usize, r: usize, corrected: Vec<f32>) -> WirePayload;

    /// Decode a payload back to dense f32 — the receive half of the seam.
    fn decompress(&self, p: &WirePayload) -> Vec<f32> {
        p.to_dense()
    }

    /// Currently applied communication bit-width per tensor (empty for
    /// unquantized policies).
    fn controller_bits(&self) -> Vec<(String, u8)> {
        Vec::new()
    }

    /// Snapshot every communication controller, in tensor order.
    fn controller_snapshot(&self) -> Vec<(String, ControllerState)> {
        Vec::new()
    }

    /// Validate a controller snapshot read-only (multi-stage restores fail
    /// before anything has been mutated).
    fn check_controllers(&self, st: &[(String, ControllerState)]) -> Result<()> {
        check_controller_snapshot(&[], st)
    }

    /// Restore a controller snapshot ([`check_controllers`](Compressor::check_controllers)
    /// first; errors leave the compressor untouched).
    fn restore_controllers(&mut self, st: &[(String, ControllerState)]) -> Result<()> {
        check_controller_snapshot(&[], st)
    }

    /// Whether the policy carries error-feedback residual state.
    fn has_residual_state(&self) -> bool {
        false
    }

    /// Snapshot every (tensor, replica) residual, in key order.
    fn residual_snapshot(&self) -> Vec<ResidualRecord> {
        Vec::new()
    }

    /// Replace the residual state with checkpointed records.
    fn restore_residuals(&mut self, _res: &[ResidualRecord]) {}
}

// ------------------------------------------------------------------ identity

/// `--compress none`: the payload is the raw f32 gradient. Combined with
/// the deterministic f32 tree this is bit-identical to the pre-seam
/// unquantized all-reduce (pinned by the N ∈ {2, 4} oracle tests).
pub struct IdentityCompressor;

impl Compressor for IdentityCompressor {
    fn label(&self) -> String {
        "none".into()
    }

    fn compress(&mut self, _t: usize, _r: usize, corrected: Vec<f32>) -> WirePayload {
        WirePayload::Dense(corrected)
    }
}

// ------------------------------------------------------------------ quantize

/// `--compress quantize`: the historical QEM/QPA fixed-point path behind
/// the seam. One [`PrecisionController`] per tensor adapts the payload
/// bit-width from replica 0's gradient (root-probe protocol); every sender
/// encodes with the resulting shared scheme so integer codes sum exactly.
pub struct QuantizeCompressor {
    ctls: Vec<PrecisionController>,
    /// Scheme frozen per tensor by the last root probe.
    schemes: Vec<Scheme>,
}

impl QuantizeCompressor {
    /// One controller per tensor name, keyed `comm:<name>` in the ledger.
    pub fn new(cfg: AptConfig, names: &[String]) -> QuantizeCompressor {
        let ctls: Vec<PrecisionController> = names
            .iter()
            .map(|n| PrecisionController::new(cfg, format!("comm:{n}"), TensorKind::Gradient))
            .collect();
        let schemes = ctls.iter().map(|c| c.scheme()).collect();
        QuantizeCompressor { ctls, schemes }
    }
}

impl Compressor for QuantizeCompressor {
    fn label(&self) -> String {
        "quantize".into()
    }

    fn begin_tensor(&mut self, iter: u64, t: usize, root: &[f32], ledger: &mut Ledger) {
        self.schemes[t] = self.ctls[t].maybe_update_from_data(iter, root, ledger);
    }

    fn compress(&mut self, t: usize, _r: usize, corrected: Vec<f32>) -> WirePayload {
        let scheme = self.schemes[t];
        let codes = corrected.iter().map(|&x| scheme.code(x)).collect();
        WirePayload::Codes { scheme, codes }
    }

    fn controller_bits(&self) -> Vec<(String, u8)> {
        self.ctls.iter().map(|c| (c.layer.clone(), c.bits())).collect()
    }

    fn controller_snapshot(&self) -> Vec<(String, ControllerState)> {
        self.ctls.iter().map(|c| (c.layer.clone(), c.snapshot())).collect()
    }

    fn check_controllers(&self, st: &[(String, ControllerState)]) -> Result<()> {
        check_controller_snapshot(&self.ctls, st)
    }

    fn restore_controllers(&mut self, st: &[(String, ControllerState)]) -> Result<()> {
        check_controller_snapshot(&self.ctls, st)?;
        for ((_, s), c) in st.iter().zip(self.ctls.iter_mut()) {
            c.restore(s);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- minifloat

/// `--comm-bits e4m3|e5m2` with the (default) quantize policy: every
/// replica encodes its corrected gradient as scaled minifloat byte codes —
/// int8's wire footprint with relative error. Each sender scales to its own
/// range (no root probe: f8 payloads decode to f32 and travel the
/// deterministic tree, so a shared scale buys no exact-summation property
/// the way a shared fixed-point scheme does). No controllers: the format is
/// the static 8-bit codec, so there is no bit-width to adapt.
pub struct MinifloatCompressor {
    kind: MinifloatKind,
    names: Vec<String>,
}

impl MinifloatCompressor {
    /// Encode every tensor with `kind`; `names` label the fixed 8-bit
    /// reports of [`controller_bits`](Compressor::controller_bits).
    pub fn new(kind: MinifloatKind, names: &[String]) -> MinifloatCompressor {
        MinifloatCompressor { kind, names: names.to_vec() }
    }
}

impl Compressor for MinifloatCompressor {
    fn label(&self) -> String {
        "quantize".into()
    }

    fn compress(&mut self, _t: usize, _r: usize, corrected: Vec<f32>) -> WirePayload {
        let s = Format::for_range(self.kind.family(), quantize::max_abs(&corrected), 8)
            .scale_exp();
        let mut codes = vec![0u8; corrected.len()];
        quantize::codes_f8(&corrected, &mut codes, self.kind, s);
        WirePayload::F8 { kind: self.kind, s, codes }
    }

    fn controller_bits(&self) -> Vec<(String, u8)> {
        self.names.iter().map(|n| (format!("comm:{n}"), 8u8)).collect()
    }
}

// -------------------------------------------------------------------- top-k

/// Deterministic magnitude top-k selection: indices of the `k =
/// clamp(ceil(ratio·len), 1, len)` largest `|values|`, returned in
/// ascending index order. Ties break toward the lower index, so the
/// selection is a pure function of the input (the determinism property
/// rests on this).
pub fn top_k_indices(values: &[f32], ratio: f32) -> Vec<u32> {
    let len = values.len();
    if len == 0 {
        return Vec::new();
    }
    let k = ((ratio as f64 * len as f64).ceil() as usize).clamp(1, len);
    let mut order: Vec<u32> = (0..len as u32).collect();
    if k < len {
        // Partition so the first k entries are the top-k under
        // (magnitude descending, index ascending) — a total order even
        // with NaN gradients (total_cmp), hence fully deterministic.
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            values[b as usize]
                .abs()
                .total_cmp(&values[a as usize].abs())
                .then(a.cmp(&b))
        });
        order.truncate(k);
    }
    order.sort_unstable();
    order
}

/// Per-(tensor, replica) error-feedback store: the exactly-withheld mass of
/// each top-k step, added back into the next step's gradient.
#[derive(Default)]
struct ErrorFeedback {
    residuals: BTreeMap<(usize, usize), Vec<f32>>,
}

impl ErrorFeedback {
    fn corrected(&self, t: usize, r: usize, grad: &[f32]) -> Vec<f32> {
        match self.residuals.get(&(t, r)) {
            Some(res) if res.len() == grad.len() => {
                grad.iter().zip(res).map(|(g, e)| g + e).collect()
            }
            _ => grad.to_vec(),
        }
    }

    fn store(&mut self, t: usize, r: usize, residual: Vec<f32>) {
        self.residuals.insert((t, r), residual);
    }

    fn snapshot(&self) -> Vec<ResidualRecord> {
        self.residuals.iter().map(|(&(t, r), v)| (t, r, v.clone())).collect()
    }

    fn restore(&mut self, recs: &[ResidualRecord]) {
        self.residuals =
            recs.iter().map(|(t, r, v)| ((*t, *r), v.clone())).collect();
    }
}

/// Split `corrected` into its top-k payload half and its residual half —
/// an exact partition: selected elements move into `vals` bit-identically
/// and are zeroed in the residual; everything else stays in the residual
/// bit-identically. Returns (indices, selected values, residual).
fn split_top_k(corrected: Vec<f32>, ratio: f32) -> (Vec<u32>, Vec<f32>, Vec<f32>) {
    let idx = top_k_indices(&corrected, ratio);
    let mut residual = corrected;
    let mut vals = Vec::with_capacity(idx.len());
    for &i in &idx {
        vals.push(residual[i as usize]);
        residual[i as usize] = 0.0;
    }
    (idx, vals, residual)
}

/// `--compress topk:<ratio>`: magnitude top-k sparsification with error
/// feedback. Selected values travel as raw f32 (combined by the
/// deterministic tree); the withheld remainder is carried bit-exactly into
/// the next step's corrected gradient.
pub struct TopKCompressor {
    ratio: f32,
    fb: ErrorFeedback,
}

impl TopKCompressor {
    /// Keep `ratio` of each tensor's elements per step (0 < ratio ≤ 1).
    pub fn new(ratio: f32) -> TopKCompressor {
        TopKCompressor { ratio, fb: ErrorFeedback::default() }
    }
}

impl Compressor for TopKCompressor {
    fn label(&self) -> String {
        CompressPolicy::TopK(self.ratio).label()
    }

    fn corrected(&self, t: usize, r: usize, grad: &[f32]) -> Vec<f32> {
        self.fb.corrected(t, r, grad)
    }

    fn compress(&mut self, t: usize, r: usize, corrected: Vec<f32>) -> WirePayload {
        let len = corrected.len();
        let (idx, val, residual) = split_top_k(corrected, self.ratio);
        self.fb.store(t, r, residual);
        WirePayload::Sparse { len, idx, val }
    }

    fn has_residual_state(&self) -> bool {
        true
    }

    fn residual_snapshot(&self) -> Vec<ResidualRecord> {
        self.fb.snapshot()
    }

    fn restore_residuals(&mut self, res: &[ResidualRecord]) {
        self.fb.restore(res);
    }
}

// ----------------------------------------------------------- topk ∘ quantize

/// `--compress topk:<ratio>+quantize`: the composition. Top-k selection
/// (with error feedback) picks what travels; the selected values are then
/// encoded as fixed-point codes under a shared scheme root-probed from
/// replica 0's *selected* values — QEM measures the error of exactly the
/// payload that ships. Only the sparsification error is fed back: the
/// residual stays the exact un-sent mass, so the partition invariant (and
/// its checkpoint round-trip) is identical to plain top-k, while the
/// quantization error stays the same bounded, controller-managed error the
/// dense quantized path has.
pub struct TopKQuantizeCompressor {
    ratio: f32,
    ctls: Vec<PrecisionController>,
    schemes: Vec<Scheme>,
    fb: ErrorFeedback,
}

impl TopKQuantizeCompressor {
    /// One controller per tensor name (ledger keys `comm:<name>`), plus the
    /// top-k ratio (0 < ratio ≤ 1).
    pub fn new(cfg: AptConfig, ratio: f32, names: &[String]) -> TopKQuantizeCompressor {
        let ctls: Vec<PrecisionController> = names
            .iter()
            .map(|n| PrecisionController::new(cfg, format!("comm:{n}"), TensorKind::Gradient))
            .collect();
        let schemes = ctls.iter().map(|c| c.scheme()).collect();
        TopKQuantizeCompressor { ratio, ctls, schemes, fb: ErrorFeedback::default() }
    }
}

impl Compressor for TopKQuantizeCompressor {
    fn label(&self) -> String {
        CompressPolicy::TopKQuantize(self.ratio).label()
    }

    fn begin_tensor(&mut self, iter: u64, t: usize, root: &[f32], ledger: &mut Ledger) {
        // Probe on the values the root will actually send: its top-k
        // selection. Top-k keeps the largest magnitudes, so the range the
        // controller sees equals the full tensor's — but QEM's error ratio
        // reflects the shipped payload, not elements that never travel.
        let idx = top_k_indices(root, self.ratio);
        let sel: Vec<f32> = idx.iter().map(|&i| root[i as usize]).collect();
        self.schemes[t] = self.ctls[t].maybe_update_from_data(iter, &sel, ledger);
    }

    fn corrected(&self, t: usize, r: usize, grad: &[f32]) -> Vec<f32> {
        self.fb.corrected(t, r, grad)
    }

    fn compress(&mut self, t: usize, r: usize, corrected: Vec<f32>) -> WirePayload {
        let len = corrected.len();
        let scheme = self.schemes[t];
        let (idx, val, residual) = split_top_k(corrected, self.ratio);
        self.fb.store(t, r, residual);
        let codes = val.iter().map(|&x| scheme.code(x)).collect();
        WirePayload::SparseCodes { len, scheme, idx, codes }
    }

    fn controller_bits(&self) -> Vec<(String, u8)> {
        self.ctls.iter().map(|c| (c.layer.clone(), c.bits())).collect()
    }

    fn controller_snapshot(&self) -> Vec<(String, ControllerState)> {
        self.ctls.iter().map(|c| (c.layer.clone(), c.snapshot())).collect()
    }

    fn check_controllers(&self, st: &[(String, ControllerState)]) -> Result<()> {
        check_controller_snapshot(&self.ctls, st)
    }

    fn restore_controllers(&mut self, st: &[(String, ControllerState)]) -> Result<()> {
        check_controller_snapshot(&self.ctls, st)?;
        for ((_, s), c) in st.iter().zip(self.ctls.iter_mut()) {
            c.restore(s);
        }
        Ok(())
    }

    fn has_residual_state(&self) -> bool {
        true
    }

    fn residual_snapshot(&self) -> Vec<ResidualRecord> {
        self.fb.snapshot()
    }

    fn restore_residuals(&mut self, res: &[ResidualRecord]) {
        self.fb.restore(res);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_labels_roundtrip_through_parse() {
        for p in [
            CompressPolicy::None,
            CompressPolicy::Quantize,
            CompressPolicy::TopK(0.1),
            CompressPolicy::TopK(0.25),
            CompressPolicy::TopKQuantize(0.1),
            CompressPolicy::TopKQuantize(0.05),
        ] {
            assert_eq!(CompressPolicy::parse(&p.label()).unwrap(), p);
        }
        assert!(CompressPolicy::parse("topk:0").is_err());
        assert!(CompressPolicy::parse("topk:1.5").is_err());
        assert!(CompressPolicy::parse("topk:x").is_err());
        assert!(CompressPolicy::parse("gzip").is_err());
        assert_eq!(
            CompressPolicy::parse("topk+quantize").unwrap(),
            CompressPolicy::TopKQuantize(DEFAULT_TOPK_RATIO)
        );
    }

    #[test]
    fn top_k_selects_largest_magnitudes_in_index_order() {
        let v = [0.1f32, -5.0, 0.0, 3.0, -0.2, 3.0];
        assert_eq!(top_k_indices(&v, 0.34), vec![1, 3]); // k = ceil(0.34*6) = 3? no: 2.04 → 3
        // ceil(0.34 * 6) = ceil(2.04) = 3 → indices of |-5|, |3|, |3| with
        // the tie broken toward the lower index
        assert_eq!(top_k_indices(&v, 0.34).len(), 3);
        assert_eq!(top_k_indices(&v, 0.34), vec![1, 3, 5]);
        // k floors at 1 and caps at len
        assert_eq!(top_k_indices(&v, 0.0001), vec![1]);
        assert_eq!(top_k_indices(&v, 1.0), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(top_k_indices(&[], 0.5), Vec::<u32>::new());
    }

    #[test]
    fn payload_roundtrips_to_dense() {
        let p = WirePayload::Sparse { len: 5, idx: vec![1, 4], val: vec![2.5, -1.0] };
        assert_eq!(p.to_dense(), vec![0.0, 2.5, 0.0, 0.0, -1.0]);
        let sch = Scheme { bits: 8, s: -4 };
        let q = WirePayload::SparseCodes { len: 3, scheme: sch, idx: vec![2], codes: vec![16] };
        assert_eq!(q.to_dense(), vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn aggregate_bytes_degenerate_to_member_bytes_at_node_one() {
        let sch = Scheme { bits: 8, s: -4 };
        for p in [
            WirePayload::Dense(vec![1.0; 7]),
            WirePayload::Codes { scheme: sch, codes: vec![1; 7] },
            WirePayload::Sparse { len: 7, idx: vec![0, 3], val: vec![1.0, 2.0] },
            WirePayload::SparseCodes { len: 7, scheme: sch, idx: vec![0, 3], codes: vec![1, 2] },
        ] {
            assert_eq!(aggregate_wire_bytes(std::slice::from_ref(&p)), p.wire_bytes());
        }
    }

    #[test]
    fn aggregate_bytes_widen_codes_and_union_supports() {
        let sch = Scheme { bits: 8, s: -4 };
        // 4 members → 2 carry bits → 10-bit codes → 2 bytes each
        let codes: Vec<WirePayload> = (0..4)
            .map(|_| WirePayload::Codes { scheme: sch, codes: vec![1; 6] })
            .collect();
        assert_eq!(aggregate_wire_bytes(&codes), 10 + 2 * 6);
        // overlapping supports {0,3} and {3,5} union to 3 indices
        let sparse = vec![
            WirePayload::Sparse { len: 8, idx: vec![0, 3], val: vec![1.0, 2.0] },
            WirePayload::Sparse { len: 8, idx: vec![3, 5], val: vec![4.0, 8.0] },
        ];
        assert_eq!(aggregate_wire_bytes(&sparse), 9 + 8 * 3);
    }
}
