//! Compressed gradient all-reduce (DESIGN.md §Data-Parallel).
//!
//! The communication analogue of the paper's compute-side adaptation: each
//! data-parallel replica produces a full set of parameter gradients, and
//! before the (replica-local) optimizer step those gradients are exchanged
//! through a composable [`Compressor`] stage — identity f32, QEM/QPA
//! fixed-point codes, top-k sparsification with error feedback, or the
//! top-k ∘ quantize composition (`train::parallel::compress`). The
//! quantized policies keep one [`crate::apt::PrecisionController`] per
//! tensor (ledger keys `comm:<layer>.<slot>`), exactly as the in-layer
//! controllers do for compute tensors.
//!
//! Determinism contract (pinned by `rust/tests/test_parallel.rs` and
//! `rust/tests/test_compress_props.rs`):
//!
//! - **f32 payloads** (identity / top-k) — partial gradients are summed by
//!   [`tree_reduce_f32`], a fixed stride-doubling binary tree (round k:
//!   `part[i] += part[i + 2^k]` for every `i` divisible by `2^(k+1)`), then
//!   scaled by `1/n`. The order never depends on thread scheduling, so runs
//!   are bit-identical run-to-run and match the oracle reduction exactly.
//! - **coded payloads** (quantize / top-k+quantize) — every replica encodes
//!   with the *same* scheme (root-probe protocol: the controller updates
//!   from replica 0's corrected gradient and the scheme is broadcast), the
//!   integer codes are summed in an `i64` accumulator — exact, hence
//!   order-independent — and decoded once as `sum · r / n` in f64 before
//!   the final f32 cast.
//! - **hierarchical reduce** — [`hier_reduce_f32`] splits replicas into
//!   power-of-two "nodes", reduces each node exactly, then reduces the node
//!   sums. By the lemma on [`hier_reduce_f32`] this is bit-identical to the
//!   flat tree for f32 payloads; for coded payloads the i64 sum is exact at
//!   any grouping, so the node size never changes the result — it only
//!   changes the *bytes-on-wire accounting* of the inter-node hop.

use anyhow::{bail, Result};

use super::compress::{
    aggregate_wire_bytes, CompressPolicy, CompressSnapshot, Compressor, IdentityCompressor,
    MinifloatCompressor, QuantizeCompressor, ReduceError, TopKCompressor,
    TopKQuantizeCompressor, WireStats,
};
use crate::apt::{AptConfig, ControllerState, Ledger};
use crate::fixedpoint::MinifloatKind;

/// Bit-width policy for the gradient all-reduce payload (CLI
/// `--comm-bits {8,16,e4m3,e5m2,adaptive,f32}`).
#[derive(Clone, Copy, Debug)]
pub enum CommPrecision {
    /// Exchange raw f32 gradients (no communication quantization); the
    /// deterministic tree reduction still applies.
    F32,
    /// Fixed-point codes at a static bit-width (8 or 16) with per-tensor
    /// range tracking (the scheme's resolution still follows the data).
    Static(u8),
    /// Scaled OCP minifloat byte codes (e4m3 or e5m2): int8's wire
    /// footprint with relative error. Payloads decode to f32 and travel the
    /// deterministic tree (minifloat sums are not exact).
    Minifloat(MinifloatKind),
    /// Full QEM/QPA adaptation of the communication bit-width per gradient
    /// tensor, as the paper adapts compute bit-widths.
    Adaptive(AptConfig),
}

impl CommPrecision {
    /// Parse a `--comm-bits` value. `iters` sizes the adaptive init phase
    /// (one-tenth of the run, mirroring `--mode adaptive`).
    pub fn parse(s: &str, iters: u64) -> Result<CommPrecision> {
        Ok(match s {
            "f32" | "float32" => CommPrecision::F32,
            "8" | "int8" => CommPrecision::Static(8),
            "16" | "int16" => CommPrecision::Static(16),
            "e4m3" => CommPrecision::Minifloat(MinifloatKind::E4M3),
            "e5m2" => CommPrecision::Minifloat(MinifloatKind::E5M2),
            "adaptive" => {
                let mut cfg = AptConfig::default();
                cfg.init_phase_iters = iters / 10;
                CommPrecision::Adaptive(cfg)
            }
            other => bail!(
                "unknown --comm-bits {other:?} (expected 8, 16, e4m3, e5m2, adaptive or f32)"
            ),
        })
    }

    /// Display label (`"f32"`, `"int8"`, `"int16"`, `"e4m3"`, `"e5m2"`,
    /// `"adaptive"`).
    pub fn label(&self) -> String {
        match self {
            CommPrecision::F32 => "f32".into(),
            CommPrecision::Static(b) => format!("int{b}"),
            CommPrecision::Minifloat(kind) => kind.label().into(),
            CommPrecision::Adaptive(_) => "adaptive".into(),
        }
    }

    /// Controller config, if the payload carries *fixed-point* codes (the
    /// minifloat precisions quantize but have no bit-width to adapt).
    pub fn config(&self) -> Option<AptConfig> {
        match self {
            CommPrecision::F32 | CommPrecision::Minifloat(_) => None,
            CommPrecision::Static(b) => Some(AptConfig::static_bits(*b)),
            CommPrecision::Adaptive(cfg) => Some(*cfg),
        }
    }

    /// The minifloat codec, if that is the payload format.
    pub fn minifloat_kind(&self) -> Option<MinifloatKind> {
        match self {
            CommPrecision::Minifloat(kind) => Some(*kind),
            _ => None,
        }
    }

    /// The compression policy this precision implies when `--compress` is
    /// not given: quantized precisions (fixed-point *and* minifloat) keep
    /// the dense-code path, f32 stays uncompressed.
    pub fn default_compress(&self) -> CompressPolicy {
        match self {
            CommPrecision::F32 => CompressPolicy::None,
            _ => CompressPolicy::Quantize,
        }
    }
}

/// Deterministic fixed-order tree sum of equally-shaped slices: round k
/// folds `part[i + 2^k]` into `part[i]` for every `i` divisible by
/// `2^(k+1)` (non-power-of-two counts simply skip absent partners). The
/// schedule is a pure function of the replica count, so the floating-point
/// result is reproducible run-to-run and matches any re-implementation of
/// the same ladder bit-for-bit.
pub fn tree_reduce_f32(parts: &[&[f32]]) -> Vec<f32> {
    assert!(!parts.is_empty(), "tree reduction over zero replicas");
    let len = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), len, "gradient shards must agree in length");
    }
    let mut bufs: Vec<Vec<f32>> = parts.iter().map(|p| p.to_vec()).collect();
    let n = bufs.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (lo, hi) = bufs.split_at_mut(i + stride);
            let dst = &mut lo[i];
            let src = &hi[0];
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += s;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    bufs.swap_remove(0)
}

/// Two-level deterministic tree sum: replicas are grouped into consecutive
/// "nodes" of `node` members (the last node may be partial), each node is
/// summed by [`tree_reduce_f32`], then the node sums are summed by the same
/// tree — the schedule of a hierarchical all-reduce (exact intra-node hop,
/// compressed inter-node hop).
///
/// **Bit-exactness lemma** (pinned by the property battery): for any
/// replica count `n` and any power-of-two `node = p`, this two-level
/// schedule performs *exactly the additions of the flat ladder* — rounds
/// with stride `< p` pair indices only within aligned `p`-blocks (a partial
/// last block runs the same sub-ladder), and rounds with stride `≥ p` are
/// the flat ladder over block bases via `j = i / p`. Hence
/// `hier_reduce_f32(parts, p) == tree_reduce_f32(parts)` bit-for-bit.
/// Non-power-of-two node sizes would break the alignment argument, so they
/// are rejected.
pub fn hier_reduce_f32(parts: &[&[f32]], node: usize) -> Vec<f32> {
    assert!(
        node >= 1 && node.is_power_of_two(),
        "hierarchical node size {node} must be a power of two"
    );
    assert!(!parts.is_empty(), "tree reduction over zero replicas");
    let sums: Vec<Vec<f32>> = parts.chunks(node).map(tree_reduce_f32).collect();
    let refs: Vec<&[f32]> = sums.iter().map(|s| s.as_slice()).collect();
    tree_reduce_f32(&refs)
}

/// The gradient-communication engine of a
/// [`ReplicaGroup`](super::ReplicaGroup): a [`Compressor`] stage chosen by
/// ([`CommPrecision`], [`CompressPolicy`]), the communication ledger, the
/// hierarchical node size, bytes-on-wire accounting, and the reduction
/// itself. See the module docs for the determinism contract.
pub struct QuantAllReduce {
    precision: CommPrecision,
    policy: CompressPolicy,
    /// The lossy stage between local gradients and the wire.
    comp: Box<dyn Compressor>,
    /// Hierarchical node size (1 = flat single-level reduction).
    node: usize,
    /// Stable tensor names (`<layer>.<slot>` param ids), in visit order.
    names: Vec<String>,
    /// Cumulative bytes-on-wire accounting across `reduce` calls.
    wire: WireStats,
    /// QEM/QPA decisions (and interval-clamp events) of the communication
    /// controllers, keyed `comm:<name>`; merged into the run ledger by
    /// `ParallelBackend::take_ledger`.
    pub ledger: Ledger,
}

impl QuantAllReduce {
    /// Build the reduction engine with the precision's default compression
    /// policy (dense codes for quantized precisions, identity for f32) and
    /// a flat (node size 1) reduction.
    pub fn new(precision: CommPrecision, names: Vec<String>) -> QuantAllReduce {
        QuantAllReduce::with_policy(precision, precision.default_compress(), 1, names)
            .expect("the default compression policy is always compatible")
    }

    /// Build the reduction engine for tensors named `names` (the group's
    /// stable `<layer>.<slot>` parameter ids, in visit order) under an
    /// explicit compression policy and hierarchical node size. Errors on
    /// incompatible (precision, policy) pairs — coded policies need a
    /// quantized `--comm-bits`, f32 policies need `--comm-bits f32` — on
    /// out-of-range top-k ratios, and on non-power-of-two node sizes.
    pub fn with_policy(
        precision: CommPrecision,
        policy: CompressPolicy,
        node: usize,
        names: Vec<String>,
    ) -> Result<QuantAllReduce> {
        policy.validate_ratio()?;
        if node == 0 || !node.is_power_of_two() {
            bail!(
                "hierarchical node size {node} must be a power of two \
                 (bit-exactness of the two-level reduce)"
            );
        }
        let comp: Box<dyn Compressor> = if let Some(kind) = precision.minifloat_kind() {
            match policy {
                CompressPolicy::Quantize => Box::new(MinifloatCompressor::new(kind, &names)),
                CompressPolicy::TopKQuantize(_) => bail!(
                    "--compress {} re-encodes selected values as shared-scheme fixed-point \
                     codes, which minifloat --comm-bits {} does not provide; use \
                     --compress quantize, or a fixed-point --comm-bits for top-k+quantize",
                    policy.label(),
                    precision.label()
                ),
                p => bail!(
                    "--comm-bits {} quantizes the payload, but --compress {} sends raw f32; \
                     use --compress quantize",
                    precision.label(),
                    p.label()
                ),
            }
        } else {
            match (policy, precision.config()) {
                (CompressPolicy::None, None) => Box::new(IdentityCompressor),
                (CompressPolicy::TopK(r), None) => Box::new(TopKCompressor::new(r)),
                (CompressPolicy::Quantize, Some(cfg)) => {
                    Box::new(QuantizeCompressor::new(cfg, &names))
                }
                (CompressPolicy::TopKQuantize(r), Some(cfg)) => {
                    Box::new(TopKQuantizeCompressor::new(cfg, r, &names))
                }
                (p, None) => bail!(
                    "--compress {} quantizes the payload and needs a quantized --comm-bits \
                     (8, 16, e4m3, e5m2 or adaptive), not f32",
                    p.label()
                ),
                (p, Some(_)) => bail!(
                    "--comm-bits {} quantizes the payload, but --compress {} sends raw f32; \
                     use --compress quantize or topk:<ratio>+quantize",
                    precision.label(),
                    p.label()
                ),
            }
        };
        Ok(QuantAllReduce {
            precision,
            policy,
            comp,
            node,
            names,
            wire: WireStats::default(),
            ledger: Ledger::new(),
        })
    }

    /// The configured payload policy.
    pub fn precision(&self) -> &CommPrecision {
        &self.precision
    }

    /// The configured compression policy.
    pub fn policy(&self) -> CompressPolicy {
        self.policy
    }

    /// The hierarchical node size (1 = flat).
    pub fn node_size(&self) -> usize {
        self.node
    }

    /// Cumulative bytes-on-wire accounting across all `reduce` calls.
    pub fn wire(&self) -> &WireStats {
        &self.wire
    }

    /// Currently applied communication bit-width per tensor (empty for
    /// unquantized policies).
    pub fn bits(&self) -> Vec<(String, u8)> {
        self.comp.controller_bits()
    }

    /// Average `per_replica[r][t]` over replicas `r` for every tensor `t`,
    /// returning the reduced tensors in visit order. `iter` drives the
    /// controllers' update schedule. Malformed inputs (mismatched tensor
    /// counts or lengths across replicas) are rejected with a typed
    /// [`ReduceError`] instead of a silently wrong average.
    pub fn reduce(
        &mut self,
        iter: u64,
        per_replica: &[Vec<Vec<f32>>],
    ) -> std::result::Result<Vec<Vec<f32>>, ReduceError> {
        let n = per_replica.len();
        if n == 0 {
            return Err(ReduceError::Empty);
        }
        let tensors = per_replica[0].len();
        for (r, grads) in per_replica.iter().enumerate() {
            if grads.len() != tensors {
                return Err(ReduceError::TensorCount { replica: r, got: grads.len(), want: tensors });
            }
        }
        for t in 0..tensors {
            let want = per_replica[0][t].len();
            for (r, grads) in per_replica.iter().enumerate() {
                if grads[t].len() != want {
                    return Err(ReduceError::Length { tensor: t, replica: r, got: grads[t].len(), want });
                }
            }
        }

        self.wire.reduces += 1;
        let mut out = Vec::with_capacity(tensors);
        for t in 0..tensors {
            let len = per_replica[0][t].len();
            // Root-probe protocol: the compressor observes replica 0's
            // *corrected* gradient (error feedback applied) before any
            // payload is built — quantizing policies freeze the step's
            // shared scheme here (a shared scale is what lets integer codes
            // sum exactly; values outside the root's range saturate).
            let root = self.comp.corrected(t, 0, &per_replica[0][t]);
            self.comp.begin_tensor(iter, t, &root, &mut self.ledger);
            let mut payloads = Vec::with_capacity(n);
            payloads.push(self.comp.compress(t, 0, root));
            for (r, grads) in per_replica.iter().enumerate().skip(1) {
                let corrected = self.comp.corrected(t, r, &grads[t]);
                payloads.push(self.comp.compress(t, r, corrected));
            }

            // Bytes-on-wire accounting: what each replica sends, what the
            // same traffic costs as raw f32, and what crosses the
            // inter-node boundary after exact intra-node aggregation.
            for p in &payloads {
                self.wire.replica_bytes += p.wire_bytes();
            }
            self.wire.dense_bytes += 4 * len as u64 * n as u64;
            for chunk in payloads.chunks(self.node) {
                self.wire.internode_bytes += aggregate_wire_bytes(chunk);
            }

            if payloads[0].is_coded() {
                // Exact i64 code summation — order-independent, so the
                // hierarchical grouping cannot change the result.
                let scheme = payloads[0].scheme().expect("coded payload has a scheme");
                let mut acc = vec![0i64; len];
                for p in &payloads {
                    p.accumulate_codes(&mut acc);
                }
                let scale = scheme.resolution() as f64 / n as f64;
                out.push(acc.iter().map(|&c| (c as f64 * scale) as f32).collect());
            } else {
                // f32 payloads: deterministic hierarchical tree (bit-equal
                // to the flat ladder by the hier_reduce_f32 lemma).
                let dense: Vec<Vec<f32>> = payloads.iter().map(|p| p.to_dense()).collect();
                let refs: Vec<&[f32]> = dense.iter().map(|d| d.as_slice()).collect();
                let mut sum = hier_reduce_f32(&refs, self.node);
                let inv = 1.0 / n as f32;
                for v in &mut sum {
                    *v *= inv;
                }
                out.push(sum);
            }
        }
        Ok(out)
    }

    /// Snapshot every communication controller (checkpointing): stable
    /// ledger key + decision state, in visit order.
    pub fn snapshot(&self) -> Vec<(String, ControllerState)> {
        self.comp.controller_snapshot()
    }

    /// Validate a [`snapshot`](Self::snapshot) against this group without
    /// mutating anything — lets a multi-stage restore fail *before* any
    /// other state has been overwritten.
    pub fn check_snapshot(&self, st: &[(String, ControllerState)]) -> Result<()> {
        self.comp.check_controllers(st)
    }

    /// Restore a [`snapshot`](Self::snapshot). Errors (without mutating
    /// anything) if the checkpoint's controller list does not match this
    /// group's tensors — e.g. a checkpoint from a different `--comm-bits`
    /// policy or model.
    pub fn restore(&mut self, st: &[(String, ControllerState)]) -> Result<()> {
        self.comp.restore_controllers(st)
    }

    /// Snapshot the compression policy state (label + error-feedback
    /// residuals) for the checkpoint `compress` section.
    pub fn compress_snapshot(&self) -> CompressSnapshot {
        CompressSnapshot {
            label: self.policy.label(),
            residuals: self.comp.residual_snapshot(),
        }
    }

    /// Validate a checkpoint's optional `compress` section against this
    /// group without mutating anything. A missing section is accepted for
    /// stateless policies (none/quantize — every pre-v2-compression
    /// artifact loads), rejected for error-feedback policies; a present
    /// section must carry this group's exact policy label and in-range
    /// tensor indices.
    pub fn check_compress(&self, snap: Option<&CompressSnapshot>) -> Result<()> {
        match snap {
            None => {
                if self.comp.has_residual_state() {
                    bail!(
                        "checkpoint has no compress section, but this group's --compress {} \
                         carries error-feedback residual state",
                        self.policy.label()
                    );
                }
                Ok(())
            }
            Some(s) => {
                if s.label != self.policy.label() {
                    bail!(
                        "compression policy mismatch: checkpoint compress {:?} vs group {:?}",
                        s.label,
                        self.policy.label()
                    );
                }
                for (t, _, _) in &s.residuals {
                    if *t >= self.names.len() {
                        bail!(
                            "compress section references tensor {t}, this group has {} tensors",
                            self.names.len()
                        );
                    }
                }
                Ok(())
            }
        }
    }

    /// Restore a checkpoint's optional `compress` section
    /// ([`check_compress`](Self::check_compress) first; errors leave the
    /// engine untouched).
    pub fn restore_compress(&mut self, snap: Option<&CompressSnapshot>) -> Result<()> {
        self.check_compress(snap)?;
        if let Some(s) = snap {
            self.comp.restore_residuals(&s.residuals);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn vecs(seed: u64, n: usize, len: usize) -> Vec<Vec<f32>> {
        let mut r = Pcg32::seeded(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                r.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn tree_matches_ladder_spec() {
        // ((a+b)+(c+d)) for 4 parts, ((a+b)+c) for 3 — per the module spec.
        let a = vec![1.0f32, 10.0];
        let b = vec![2.0f32, 20.0];
        let c = vec![4.0f32, 40.0];
        let d = vec![8.0f32, 80.0];
        let r4 = tree_reduce_f32(&[&a, &b, &c, &d]);
        assert_eq!(r4, vec![(1.0 + 2.0) + (4.0 + 8.0), (10.0 + 20.0) + (40.0 + 80.0)]);
        let r3 = tree_reduce_f32(&[&a, &b, &c]);
        assert_eq!(r3, vec![(1.0 + 2.0) + 4.0, (10.0 + 20.0) + 40.0]);
        let r1 = tree_reduce_f32(&[&a]);
        assert_eq!(r1, a);
    }

    #[test]
    fn f32_reduce_is_deterministic() {
        let parts = vecs(3, 4, 257);
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let x = tree_reduce_f32(&refs);
        let y = tree_reduce_f32(&refs);
        assert_eq!(x, y);
    }

    #[test]
    fn hierarchical_reduce_is_bit_identical_to_flat() {
        // The lemma, exercised across non-power-of-two replica counts and
        // node sizes larger than the group.
        for n in [1usize, 2, 3, 5, 6, 8, 13, 16] {
            let parts = vecs(40 + n as u64, n, 129);
            let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
            let flat = tree_reduce_f32(&refs);
            for node in [1usize, 2, 4, 8, 32] {
                assert_eq!(
                    hier_reduce_f32(&refs, node),
                    flat,
                    "hier(node={node}) diverged from flat at n={n}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn hierarchical_reduce_rejects_non_power_of_two_nodes() {
        let parts = vecs(7, 4, 8);
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        hier_reduce_f32(&refs, 3);
    }

    #[test]
    fn quantized_reduce_tracks_f32_average() {
        // Replica 1's gradient sits inside replica 0's range (the root
        // probe sets the shared scale), so no saturation in this case.
        let base = vecs(10, 1, 512).remove(0);
        let half: Vec<f32> = base.iter().map(|&v| v * 0.5).collect();
        let per: Vec<Vec<Vec<f32>>> = vec![vec![base], vec![half]];
        let mut q = QuantAllReduce::new(CommPrecision::Static(16), vec!["t.0".into()]);
        let red = q.reduce(0, &per).unwrap();
        // int16 payload: the average should track the exact mean closely
        let exact: Vec<f32> =
            (0..512).map(|i| (per[0][0][i] + per[1][0][i]) / 2.0).collect();
        let err: f32 = red[0]
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "int16 comm error too large: {err}");
        assert_eq!(q.bits(), vec![("comm:t.0".to_string(), 16u8)]);
    }

    #[test]
    fn minifloat_reduce_tracks_f32_average() {
        let base = vecs(11, 1, 512).remove(0);
        let half: Vec<f32> = base.iter().map(|&v| v * 0.5).collect();
        let per: Vec<Vec<Vec<f32>>> = vec![vec![base], vec![half]];
        for kind in [MinifloatKind::E4M3, MinifloatKind::E5M2] {
            let mut q = QuantAllReduce::new(
                CommPrecision::Minifloat(kind),
                vec!["t.0".into()],
            );
            let red = q.reduce(0, &per).unwrap();
            let exact: Vec<f32> =
                (0..512).map(|i| (per[0][0][i] + per[1][0][i]) / 2.0).collect();
            // Relative error of the codec (e5m2: 2 mantissa bits → half-ulp
            // 1/8) plus a tiny absolute floor near zero.
            let err = red[0]
                .iter()
                .zip(&exact)
                .map(|(a, b)| (a - b).abs() / b.abs().max(0.05))
                .fold(0.0, f32::max);
            assert!(err < 0.15, "{} comm error too large: {err}", kind.label());
            // 1 byte/element on the replica hop, both replicas.
            assert_eq!(q.wire().replica_bytes, 2 * (10 + 512));
            // No bit-width controllers, but the fixed 8-bit report exists.
            assert_eq!(q.bits(), vec![("comm:t.0".to_string(), 8u8)]);
            assert!(q.snapshot().is_empty());
        }
    }

    #[test]
    fn minifloat_rejects_topk_quantize_and_raw_policies() {
        let names = vec!["t.0".to_string()];
        let prec = CommPrecision::Minifloat(MinifloatKind::E4M3);
        let err = QuantAllReduce::with_policy(
            prec,
            CompressPolicy::TopKQuantize(0.1),
            1,
            names.clone(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("fixed-point"), "{err}");
        assert!(QuantAllReduce::with_policy(prec, CompressPolicy::TopK(0.1), 1, names.clone())
            .is_err());
        assert!(QuantAllReduce::with_policy(prec, CompressPolicy::None, 1, names.clone())
            .is_err());
        // the default pairing (quantize) builds
        assert!(
            QuantAllReduce::with_policy(prec, prec.default_compress(), 1, names).is_ok()
        );
    }

    #[test]
    fn reduce_rejects_malformed_inputs_with_typed_errors() {
        let mut q = QuantAllReduce::new(CommPrecision::F32, vec!["t.0".into()]);
        assert_eq!(q.reduce(0, &[]).unwrap_err(), ReduceError::Empty);
        let per = vec![vec![vec![1.0f32; 4]], vec![]];
        assert_eq!(
            q.reduce(0, &per).unwrap_err(),
            ReduceError::TensorCount { replica: 1, got: 0, want: 1 }
        );
        let per = vec![vec![vec![1.0f32; 4]], vec![vec![1.0f32; 3]]];
        assert_eq!(
            q.reduce(0, &per).unwrap_err(),
            ReduceError::Length { tensor: 0, replica: 1, got: 3, want: 4 }
        );
    }

    #[test]
    fn with_policy_rejects_incompatible_combinations() {
        let names = vec!["t.0".to_string()];
        // coded policy over f32 wire
        assert!(QuantAllReduce::with_policy(
            CommPrecision::F32,
            CompressPolicy::Quantize,
            1,
            names.clone()
        )
        .is_err());
        // f32 policy over quantized wire
        assert!(QuantAllReduce::with_policy(
            CommPrecision::Static(8),
            CompressPolicy::TopK(0.1),
            1,
            names.clone()
        )
        .is_err());
        // out-of-range ratio
        assert!(QuantAllReduce::with_policy(
            CommPrecision::F32,
            CompressPolicy::TopK(0.0),
            1,
            names.clone()
        )
        .is_err());
        // non-power-of-two node size
        assert!(QuantAllReduce::with_policy(
            CommPrecision::F32,
            CompressPolicy::None,
            3,
            names.clone()
        )
        .is_err());
        // the valid corners build
        for (prec, pol) in [
            (CommPrecision::F32, CompressPolicy::None),
            (CommPrecision::F32, CompressPolicy::TopK(0.25)),
            (CommPrecision::Static(8), CompressPolicy::Quantize),
            (CommPrecision::Static(8), CompressPolicy::TopKQuantize(0.25)),
        ] {
            assert!(QuantAllReduce::with_policy(prec, pol, 4, names.clone()).is_ok());
        }
    }

    #[test]
    fn snapshot_roundtrip_restores_schemes() {
        let per = vec![vec![vecs(21, 1, 256).remove(0)], vec![vecs(22, 1, 256).remove(0)]];
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        let mut q = QuantAllReduce::new(CommPrecision::Adaptive(cfg), vec!["t.0".into()]);
        q.reduce(0, &per).unwrap();
        let snap = q.snapshot();
        let mut q2 = QuantAllReduce::new(CommPrecision::Adaptive(cfg), vec!["t.0".into()]);
        q2.restore(&snap).unwrap();
        assert_eq!(q2.snapshot(), snap);
        // mismatched policy errors instead of silently desyncing
        let mut qf = QuantAllReduce::new(CommPrecision::F32, vec!["t.0".into()]);
        assert!(qf.restore(&snap).is_err());
    }

    #[test]
    fn compress_snapshot_roundtrip_and_mismatch() {
        let names = vec!["t.0".to_string(), "t.1".to_string()];
        let mut q = QuantAllReduce::with_policy(
            CommPrecision::F32,
            CompressPolicy::TopK(0.5),
            1,
            names.clone(),
        )
        .unwrap();
        let per = vec![
            vec![vecs(31, 1, 8).remove(0), vecs(32, 1, 5).remove(0)],
            vec![vecs(33, 1, 8).remove(0), vecs(34, 1, 5).remove(0)],
        ];
        q.reduce(0, &per).unwrap();
        let snap = q.compress_snapshot();
        assert_eq!(snap.label, "topk:0.5");
        assert_eq!(snap.residuals.len(), 4); // 2 tensors × 2 replicas
        let mut q2 = QuantAllReduce::with_policy(
            CommPrecision::F32,
            CompressPolicy::TopK(0.5),
            1,
            names.clone(),
        )
        .unwrap();
        q2.restore_compress(Some(&snap)).unwrap();
        assert_eq!(q2.compress_snapshot(), snap);
        // missing section: fine without residual state, fatal with it
        let qn = QuantAllReduce::new(CommPrecision::F32, names.clone());
        assert!(qn.check_compress(None).is_ok());
        assert!(q2.check_compress(None).is_err());
        // label mismatch rejected
        let qr = QuantAllReduce::with_policy(
            CommPrecision::F32,
            CompressPolicy::TopK(0.25),
            1,
            names,
        )
        .unwrap();
        let err = qr.check_compress(Some(&snap)).unwrap_err().to_string();
        assert!(err.contains("compression policy mismatch"), "{err}");
    }
}
