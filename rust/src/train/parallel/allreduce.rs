//! Quantized gradient all-reduce (DESIGN.md §Data-Parallel).
//!
//! The communication analogue of the paper's compute-side adaptation: each
//! data-parallel replica produces a full set of parameter gradients, and
//! before the (replica-local) optimizer step those gradients are exchanged
//! as **fixed-point codes** whose bit-width is chosen per tensor by a
//! dedicated [`PrecisionController`] — QEM measures the quantization error
//! of the *communication* payload, QPA adapts its width and re-probe
//! interval, exactly as the in-layer controllers do for compute tensors
//! (controller keys are `comm:<layer>.<slot>` in the merged run ledger).
//!
//! Determinism contract (pinned by `rust/tests/test_parallel.rs`):
//!
//! - **f32 path** — partial gradients are summed by [`tree_reduce_f32`], a
//!   fixed stride-doubling binary tree (round k: `part[i] += part[i + 2^k]`
//!   for every `i` divisible by `2^(k+1)`), then scaled by `1/n`. The order
//!   never depends on thread scheduling, so runs are bit-identical
//!   run-to-run and match the oracle reduction exactly.
//! - **quantized path** — every replica encodes with the *same* scheme
//!   (root-probe protocol: the controller updates from replica 0's local
//!   gradient and the scheme is broadcast), the integer codes are summed in
//!   an `i64` accumulator — exact, hence order-independent — and decoded
//!   once as `sum · r / n` in f64 before the final f32 cast.

use anyhow::{bail, Result};

use crate::apt::{AptConfig, Ledger, PrecisionController};
use crate::apt::ControllerState;
use crate::fixedpoint::TensorKind;

/// Bit-width policy for the gradient all-reduce payload (CLI
/// `--comm-bits {8,16,adaptive,f32}`).
#[derive(Clone, Copy, Debug)]
pub enum CommPrecision {
    /// Exchange raw f32 gradients (no communication quantization); the
    /// deterministic tree reduction still applies.
    F32,
    /// Fixed-point codes at a static bit-width (8 or 16) with per-tensor
    /// range tracking (the scheme's resolution still follows the data).
    Static(u8),
    /// Full QEM/QPA adaptation of the communication bit-width per gradient
    /// tensor, as the paper adapts compute bit-widths.
    Adaptive(AptConfig),
}

impl CommPrecision {
    /// Parse a `--comm-bits` value. `iters` sizes the adaptive init phase
    /// (one-tenth of the run, mirroring `--mode adaptive`).
    pub fn parse(s: &str, iters: u64) -> Result<CommPrecision> {
        Ok(match s {
            "f32" | "float32" => CommPrecision::F32,
            "8" | "int8" => CommPrecision::Static(8),
            "16" | "int16" => CommPrecision::Static(16),
            "adaptive" => {
                let mut cfg = AptConfig::default();
                cfg.init_phase_iters = iters / 10;
                CommPrecision::Adaptive(cfg)
            }
            other => bail!("unknown --comm-bits {other:?} (expected 8, 16, adaptive or f32)"),
        })
    }

    /// Display label (`"f32"`, `"int8"`, `"int16"`, `"adaptive"`).
    pub fn label(&self) -> String {
        match self {
            CommPrecision::F32 => "f32".into(),
            CommPrecision::Static(b) => format!("int{b}"),
            CommPrecision::Adaptive(_) => "adaptive".into(),
        }
    }

    /// Controller config, if the payload is quantized.
    pub fn config(&self) -> Option<AptConfig> {
        match self {
            CommPrecision::F32 => None,
            CommPrecision::Static(b) => Some(AptConfig::static_bits(*b)),
            CommPrecision::Adaptive(cfg) => Some(*cfg),
        }
    }
}

/// Deterministic fixed-order tree sum of equally-shaped slices: round k
/// folds `part[i + 2^k]` into `part[i]` for every `i` divisible by
/// `2^(k+1)` (non-power-of-two counts simply skip absent partners). The
/// schedule is a pure function of the replica count, so the floating-point
/// result is reproducible run-to-run and matches any re-implementation of
/// the same ladder bit-for-bit.
pub fn tree_reduce_f32(parts: &[&[f32]]) -> Vec<f32> {
    assert!(!parts.is_empty(), "tree reduction over zero replicas");
    let len = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), len, "gradient shards must agree in length");
    }
    let mut bufs: Vec<Vec<f32>> = parts.iter().map(|p| p.to_vec()).collect();
    let n = bufs.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (lo, hi) = bufs.split_at_mut(i + stride);
            let dst = &mut lo[i];
            let src = &hi[0];
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += s;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    bufs.swap_remove(0)
}

/// The gradient-communication engine of a
/// [`ReplicaGroup`](super::ReplicaGroup): one [`PrecisionController`] per
/// parameter-gradient tensor (quantized policies), the communication
/// ledger, and the reduction itself. See the module docs for the
/// determinism contract.
pub struct QuantAllReduce {
    precision: CommPrecision,
    /// One controller per tensor, in parameter visit order; empty for f32.
    ctls: Vec<PrecisionController>,
    /// Stable tensor names (`<layer>.<slot>` param ids), in visit order.
    names: Vec<String>,
    /// QEM/QPA decisions (and interval-clamp events) of the communication
    /// controllers, keyed `comm:<name>`; merged into the run ledger by
    /// `ParallelBackend::take_ledger`.
    pub ledger: Ledger,
}

impl QuantAllReduce {
    /// Build the reduction engine for tensors named `names` (the group's
    /// stable `<layer>.<slot>` parameter ids, in visit order).
    pub fn new(precision: CommPrecision, names: Vec<String>) -> QuantAllReduce {
        let ctls = match precision.config() {
            None => Vec::new(),
            Some(cfg) => names
                .iter()
                .map(|n| PrecisionController::new(cfg, format!("comm:{n}"), TensorKind::Gradient))
                .collect(),
        };
        QuantAllReduce { precision, ctls, names, ledger: Ledger::new() }
    }

    /// The configured payload policy.
    pub fn precision(&self) -> &CommPrecision {
        &self.precision
    }

    /// Currently applied communication bit-width per tensor (empty for f32).
    pub fn bits(&self) -> Vec<(String, u8)> {
        self.names
            .iter()
            .zip(&self.ctls)
            .map(|(n, c)| (format!("comm:{n}"), c.bits()))
            .collect()
    }

    /// Average `per_replica[r][t]` over replicas `r` for every tensor `t`,
    /// returning the reduced tensors in visit order. `iter` drives the
    /// controllers' update schedule.
    pub fn reduce(&mut self, iter: u64, per_replica: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
        let n = per_replica.len();
        assert!(n >= 1, "reduce over zero replicas");
        let tensors = per_replica[0].len();
        let mut out = Vec::with_capacity(tensors);
        for t in 0..tensors {
            let parts: Vec<&[f32]> = per_replica.iter().map(|r| r[t].as_slice()).collect();
            if self.ctls.is_empty() {
                let mut sum = tree_reduce_f32(&parts);
                let inv = 1.0 / n as f32;
                for v in &mut sum {
                    *v *= inv;
                }
                out.push(sum);
            } else {
                // Root-probe protocol: QEM/QPA run on replica 0's local
                // gradient; the resulting scheme is shared by every sender
                // (a shared scale is what lets integer codes sum exactly).
                // Values outside the root's range saturate per the scheme.
                let sch = self.ctls[t].maybe_update_from_data(iter, parts[0], &mut self.ledger);
                let len = parts[0].len();
                let mut acc = vec![0i64; len];
                for part in &parts {
                    for (a, &x) in acc.iter_mut().zip(part.iter()) {
                        *a += sch.code(x) as i64;
                    }
                }
                let scale = sch.resolution() as f64 / n as f64;
                out.push(acc.iter().map(|&c| (c as f64 * scale) as f32).collect());
            }
        }
        out
    }

    /// Snapshot every communication controller (checkpointing): stable
    /// ledger key + decision state, in visit order.
    pub fn snapshot(&self) -> Vec<(String, ControllerState)> {
        self.ctls.iter().map(|c| (c.layer.clone(), c.snapshot())).collect()
    }

    /// Validate a [`snapshot`](Self::snapshot) against this group without
    /// mutating anything — lets a multi-stage restore fail *before* any
    /// other state has been overwritten.
    pub fn check_snapshot(&self, st: &[(String, ControllerState)]) -> Result<()> {
        if st.len() != self.ctls.len() {
            bail!(
                "checkpoint has {} communication controllers, this group has {}",
                st.len(),
                self.ctls.len()
            );
        }
        for ((name, _), c) in st.iter().zip(&self.ctls) {
            if *name != c.layer {
                bail!("communication controller mismatch: checkpoint {name:?} vs group {:?}", c.layer);
            }
        }
        Ok(())
    }

    /// Restore a [`snapshot`](Self::snapshot). Errors (without mutating
    /// anything) if the checkpoint's controller list does not match this
    /// group's tensors — e.g. a checkpoint from a different `--comm-bits`
    /// policy or model.
    pub fn restore(&mut self, st: &[(String, ControllerState)]) -> Result<()> {
        self.check_snapshot(st)?;
        for ((_, s), c) in st.iter().zip(self.ctls.iter_mut()) {
            c.restore(s);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn vecs(seed: u64, n: usize, len: usize) -> Vec<Vec<f32>> {
        let mut r = Pcg32::seeded(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                r.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn tree_matches_ladder_spec() {
        // ((a+b)+(c+d)) for 4 parts, ((a+b)+c) for 3 — per the module spec.
        let a = vec![1.0f32, 10.0];
        let b = vec![2.0f32, 20.0];
        let c = vec![4.0f32, 40.0];
        let d = vec![8.0f32, 80.0];
        let r4 = tree_reduce_f32(&[&a, &b, &c, &d]);
        assert_eq!(r4, vec![(1.0 + 2.0) + (4.0 + 8.0), (10.0 + 20.0) + (40.0 + 80.0)]);
        let r3 = tree_reduce_f32(&[&a, &b, &c]);
        assert_eq!(r3, vec![(1.0 + 2.0) + 4.0, (10.0 + 20.0) + 40.0]);
        let r1 = tree_reduce_f32(&[&a]);
        assert_eq!(r1, a);
    }

    #[test]
    fn f32_reduce_is_deterministic() {
        let parts = vecs(3, 4, 257);
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let x = tree_reduce_f32(&refs);
        let y = tree_reduce_f32(&refs);
        assert_eq!(x, y);
    }

    #[test]
    fn quantized_reduce_tracks_f32_average() {
        // Replica 1's gradient sits inside replica 0's range (the root
        // probe sets the shared scale), so no saturation in this case.
        let base = vecs(10, 1, 512).remove(0);
        let half: Vec<f32> = base.iter().map(|&v| v * 0.5).collect();
        let per: Vec<Vec<Vec<f32>>> = vec![vec![base], vec![half]];
        let mut q = QuantAllReduce::new(CommPrecision::Static(16), vec!["t.0".into()]);
        let red = q.reduce(0, &per);
        // int16 payload: the average should track the exact mean closely
        let exact: Vec<f32> =
            (0..512).map(|i| (per[0][0][i] + per[1][0][i]) / 2.0).collect();
        let err: f32 = red[0]
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "int16 comm error too large: {err}");
        assert_eq!(q.bits(), vec![("comm:t.0".to_string(), 16u8)]);
    }

    #[test]
    fn snapshot_roundtrip_restores_schemes() {
        let per = vec![vec![vecs(21, 1, 256).remove(0)], vec![vecs(22, 1, 256).remove(0)]];
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        let mut q = QuantAllReduce::new(CommPrecision::Adaptive(cfg), vec!["t.0".into()]);
        q.reduce(0, &per);
        let snap = q.snapshot();
        let mut q2 = QuantAllReduce::new(CommPrecision::Adaptive(cfg), vec!["t.0".into()]);
        q2.restore(&snap).unwrap();
        assert_eq!(q2.snapshot(), snap);
        // mismatched policy errors instead of silently desyncing
        let mut qf = QuantAllReduce::new(CommPrecision::F32, vec!["t.0".into()]);
        assert!(qf.restore(&snap).is_err());
    }
}
