//! Data-parallel quantized training (DESIGN.md §Data-Parallel).
//!
//! A [`ReplicaGroup`] holds N identically-initialized model replicas. Every
//! step it draws **one** global batch from the shared [`super::DataSource`],
//! splits it row-wise into N contiguous shards (replica r gets rows
//! `[r·B/N, (r+1)·B/N)`), runs forward/backward independently on each
//! replica (all kernel math multiplexes onto the process-wide
//! [`crate::kernels::Engine`] thread pool), then aggregates parameter
//! gradients through the compressed all-reduce of [`QuantAllReduce`] — a
//! composable [`Compressor`] stage (identity, per-tensor
//! int8/int16/adaptive codes, top-k sparsification with error feedback, or
//! top-k ∘ quantize) over a deterministic fixed-order tree reduction, with
//! an optional two-level hierarchical schedule for large N.
//! Every replica then applies the *same* averaged gradient with its own
//! optimizer instance, so parameters and optimizer state stay bit-identical
//! across replicas by construction (the sync invariant, checkable with
//! [`ReplicaGroup::replicas_in_sync`]).
//!
//! Exactness conditions (pinned by `rust/tests/test_parallel.rs`):
//!
//! - `--replicas 1` — there is nothing to communicate, so the group
//!   degenerates to the plain [`HostBackend`] step *regardless of the
//!   `--comm-bits` / `--compress` policy*: loss/parameter trajectories are
//!   bit-identical to the single-replica `Session` loop.
//! - `--replicas N`, f32 comm — gradients match the stride-doubling tree
//!   reduction oracle bit-exactly (the schedule is a pure function of N;
//!   see [`tree_reduce_f32`]), at any hierarchical node size (the
//!   [`hier_reduce_f32`] lemma).
//! - quantized comm — the integer-code sum is exact (i64 accumulator), so
//!   the only deviation from the f32 path is the per-replica encode — the
//!   same controlled error QEM/QPA bound on the compute side.
//! - top-k comm — the un-sent mass is withheld bit-exactly into the next
//!   step's error-feedback residual (an exact partition of the corrected
//!   gradient; `rust/tests/test_compress_props.rs`).

mod allreduce;
mod compress;

pub use allreduce::{hier_reduce_f32, tree_reduce_f32, CommPrecision, QuantAllReduce};
pub use compress::{
    aggregate_wire_bytes, top_k_indices, CompressPolicy, CompressSnapshot, Compressor,
    IdentityCompressor, MinifloatCompressor, QuantizeCompressor, ReduceError, ResidualRecord,
    TopKCompressor, TopKQuantizeCompressor, WirePayload, WireStats, DEFAULT_TOPK_RATIO,
};

use anyhow::{bail, Result};

use super::backend::Backend;
use super::optim::Optimizer;
use super::{EvalOut, HostBackend, Phase, StepInfo};
use crate::apt::Ledger;
use crate::nn::loss::softmax_xent;
use crate::nn::{Sequential, TrainCtx};
use crate::tensor::Tensor;

/// One non-root replica: its own network copy, training context and
/// optimizer instance. (The root replica is the wrapped [`HostBackend`],
/// which also owns the shared data stream and eval configuration.)
pub(super) struct Replica {
    pub(super) net: Sequential,
    pub(super) ctx: TrainCtx,
    pub(super) opt: Box<dyn Optimizer>,
    pub(super) needs_zero: bool,
}

/// N data-parallel model replicas around one [`HostBackend`] plus the
/// quantized gradient all-reduce between them. Construct through
/// [`super::SessionBuilder::build_parallel`].
pub struct ReplicaGroup {
    /// Replica 0 — also the data stream, eval set and checkpoint surface.
    pub(super) host: HostBackend,
    /// Replicas 1..N.
    pub(super) peers: Vec<Replica>,
    /// Gradient communication engine (controllers + comm ledger).
    pub(super) comm: QuantAllReduce,
}

/// Collect every parameter gradient of `net` (visit order) as owned
/// buffers — the send half of the all-reduce.
fn gather_grads(net: &mut Sequential) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    net.visit_params(&mut |_, g| out.push(g.data.clone()));
    out
}

/// Overwrite every parameter gradient of `net` with the reduced tensors —
/// the receive half of the all-reduce.
fn scatter_grads(net: &mut Sequential, reduced: &[Vec<f32>]) {
    let mut i = 0usize;
    net.visit_params(&mut |_, g| {
        g.data.copy_from_slice(&reduced[i]);
        i += 1;
    });
}

impl ReplicaGroup {
    /// Assemble a group. `host` carries the root replica plus the shared
    /// data stream; `peer_parts` are the (net, optimizer) pairs of replicas
    /// 1..N, which must be bit-identical copies of the root's initial
    /// state. Errors if the global batch does not split evenly or the
    /// (comm, policy, node) combination is invalid.
    pub(super) fn new(
        mut host: HostBackend,
        peer_parts: Vec<(Sequential, Box<dyn Optimizer>)>,
        comm: CommPrecision,
        policy: CompressPolicy,
        node: usize,
    ) -> Result<ReplicaGroup> {
        let replicas = peer_parts.len() + 1;
        if host.batch % replicas != 0 {
            bail!(
                "batch {} does not split across {} replicas (use a multiple)",
                host.batch,
                replicas
            );
        }
        let mut names = Vec::new();
        host.net.visit_params_slotted(&mut |layer, slot, _, _| {
            names.push(format!("{layer}.{slot}"));
        });
        let peers = peer_parts
            .into_iter()
            .map(|(net, opt)| Replica { net, ctx: TrainCtx::new(), opt, needs_zero: false })
            .collect();
        Ok(ReplicaGroup {
            host,
            peers,
            comm: QuantAllReduce::with_policy(comm, policy, node, names)?,
        })
    }

    /// Total replica count N (root + peers).
    pub fn replicas(&self) -> usize {
        self.peers.len() + 1
    }

    /// Give every replica its own fresh activation stash under `policy` /
    /// `recompute` (DESIGN.md §Activation-Memory). Stashes are
    /// replica-local — each replica's forward/backward runs on its own
    /// batch shard — and the N=1 degenerate case is exactly the
    /// [`HostBackend`] stash, preserving the bit-identity contract.
    pub(super) fn set_stash(&mut self, policy: crate::mem::StashPolicy, recompute: bool) {
        self.host.set_stash(policy, recompute);
        for peer in &mut self.peers {
            peer.ctx.stash = crate::mem::ActivationStash::new(policy, recompute);
        }
    }

    /// Install a precision schedule on every replica (DESIGN.md
    /// §Calibration): one `Schedule::install` per training context sets the
    /// quantization start (the plumbing `set_quant_delay` used to
    /// duplicate), and progressive phases retune each replica's compute
    /// controllers at their start iterations. Replicas must share the
    /// schedule or they would diverge at activation; the gradient
    /// all-reduce keeps its own comm precision throughout (wire compression
    /// is a bandwidth decision, not a compute one).
    pub(super) fn set_schedule(&mut self, schedule: crate::calib::Schedule) {
        for peer in &mut self.peers {
            schedule.install(&mut peer.ctx);
        }
        // The host backend stores the schedule too: the N=1 degenerate step
        // delegates to `HostBackend::step`, which applies the retunes.
        self.host.set_schedule(schedule);
    }

    /// The root replica's activation stash (peers mirror its policy; their
    /// per-shard byte peaks are the same by symmetry).
    pub fn stash(&self) -> &crate::mem::ActivationStash {
        self.host.stash()
    }

    /// The gradient-communication engine (e.g. for its applied bit-widths).
    pub fn comm(&self) -> &QuantAllReduce {
        &self.comm
    }

    /// Verify the sync invariant: every peer's parameters are bit-identical
    /// to the root's. A `false` here means the all-reduce or optimizer
    /// stepping broke determinism — it should never happen.
    pub fn replicas_in_sync(&mut self) -> bool {
        let mut root = Vec::new();
        self.host.net.visit_params(&mut |p, _| root.push(p.data.clone()));
        for peer in &mut self.peers {
            let mut i = 0usize;
            let mut ok = true;
            peer.net.visit_params(&mut |p, _| {
                ok &= i < root.len() && p.data == root[i];
                i += 1;
            });
            if !ok || i != root.len() {
                return false;
            }
        }
        true
    }

    /// One sharded data-parallel step. See the module docs for the exact
    /// sequence; with no peers this is precisely the [`HostBackend`] step.
    fn step(&mut self, iter: u64, observe: &mut dyn FnMut(Phase, &StepInfo)) -> Result<f32> {
        if self.peers.is_empty() {
            return self.host.step(iter, observe);
        }
        let n = self.replicas();

        // Deferred zeroing, on every replica (§Session-API ordering).
        if self.host.needs_zero {
            self.host.net.zero_grads();
            self.host.needs_zero = false;
        }
        for peer in &mut self.peers {
            if peer.needs_zero {
                peer.net.zero_grads();
                peer.needs_zero = false;
            }
        }
        self.host.ctx.stash.begin_step();
        for peer in &mut self.peers {
            peer.ctx.stash.begin_step();
        }
        self.host.ctx.iter = iter;
        for peer in &mut self.peers {
            peer.ctx.iter = iter;
        }
        // Schedule phase boundary: retune every replica's controllers in
        // lockstep (same `retune_bits` call on bit-identical state, so the
        // sync invariant is preserved by construction).
        if let Some(bits) = self.host.schedule.retune_at(iter) {
            super::backend::retune_net(&mut self.host.net, bits, iter);
            for peer in &mut self.peers {
                super::backend::retune_net(&mut peer.net, bits, iter);
            }
        }

        // One global batch, sharded row-wise into N contiguous slices.
        let (x, y) = self.host.data.batch(self.host.batch);
        let shard = self.host.batch / n;
        let d = x.dim(1);

        // Independent forward/backward per replica, then gather grads.
        let mut shard_losses = Vec::with_capacity(n);
        let mut per_replica: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
        for r in 0..n {
            let xs = Tensor::from_vec(
                &[shard, d],
                x.data[r * shard * d..(r + 1) * shard * d].to_vec(),
            );
            let ys = &y[r * shard..(r + 1) * shard];
            let (net, ctx) = if r == 0 {
                (&mut self.host.net, &mut self.host.ctx)
            } else {
                let p = &mut self.peers[r - 1];
                (&mut p.net, &mut p.ctx)
            };
            let logits = net.forward(&xs, ctx);
            let (loss, g) = softmax_xent(&logits, ys);
            net.backward(&g, ctx);
            shard_losses.push(loss);
            per_replica.push(gather_grads(net));
        }

        // Compressed all-reduce, then broadcast the average back.
        let reduced = self.comm.reduce(iter, &per_replica)?;
        scatter_grads(&mut self.host.net, &reduced);
        for peer in &mut self.peers {
            scatter_grads(&mut peer.net, &reduced);
        }

        // Group loss: fixed-order mean of the shard losses.
        let loss =
            (shard_losses.iter().map(|&l| l as f64).sum::<f64>() / n as f64) as f32;

        // Hooks observe the root replica with the *reduced* gradients in
        // place — the data-parallel analogue of "fully accumulated".
        observe(Phase::AfterBackward, &StepInfo { iter, loss, net: Some(&self.host.net) });

        // Identical update on every replica keeps them in lockstep.
        self.host.opt.step(&mut self.host.net);
        self.host.needs_zero = true;
        for peer in &mut self.peers {
            peer.opt.step(&mut peer.net);
            peer.needs_zero = true;
        }
        observe(Phase::AfterStep, &StepInfo { iter, loss, net: Some(&self.host.net) });
        Ok(loss)
    }
}

/// [`super::Backend`] over a [`ReplicaGroup`] — the data-parallel
/// counterpart of [`HostBackend`], sharing its eval path and checkpoint
/// surface through the root replica.
pub struct ParallelBackend {
    pub(super) group: ReplicaGroup,
    label: String,
}

impl ParallelBackend {
    /// Wrap a group under a display label.
    pub(super) fn new(group: ReplicaGroup, label: String) -> ParallelBackend {
        ParallelBackend { group, label }
    }

    /// The replica group (replica count, comm engine, sync check).
    pub fn group(&self) -> &ReplicaGroup {
        &self.group
    }

    /// Mutable group access (e.g. [`ReplicaGroup::replicas_in_sync`]).
    pub fn group_mut(&mut self) -> &mut ReplicaGroup {
        &mut self.group
    }
}

impl Backend for ParallelBackend {
    fn label(&self) -> &str {
        &self.label
    }

    fn step(&mut self, iter: u64, observe: &mut dyn FnMut(Phase, &StepInfo)) -> Result<f32> {
        self.group.step(iter, observe)
    }

    fn eval(&mut self, iters_done: u64) -> Result<EvalOut> {
        // Parameters are identical across replicas (sync invariant), so the
        // root replica evaluates for the group.
        self.group.host.eval(iters_done)
    }

    fn take_ledger(&mut self, iters_done: u64) -> Ledger {
        let mut ledger = self.group.host.take_ledger(iters_done);
        // Merge the communication controllers' history under their
        // `comm:<layer>.<slot>` keys (disjoint from layer names by prefix).
        let comm = std::mem::take(&mut self.group.comm.ledger);
        ledger.tensors.extend(comm.tensors);
        ledger
    }

    fn grad_bits(&self) -> Vec<(String, u8)> {
        self.group.comm.bits()
    }
}
