//! Optimizers behind one trait (DESIGN.md §Session-API).
//!
//! SGD-with-momentum moved here from `nn` (it used to be `nn::Sgd`), plus
//! Adam to match what the L2 train-step artifacts already support
//! on-device. Two contracts every implementation upholds:
//!
//! 1. **Optimizers read gradients, never clear them.** Gradient zeroing is
//!    the explicit [`crate::nn::Sequential::zero_grads`] step, scheduled by
//!    the `Session` at the *start* of the next iteration — so probes that
//!    run after `step()` observe the step's true gradients (the old fused
//!    `Sgd::step` silently cleared them mid-update).
//! 2. **State buffers are keyed by parameter visit order**, which is stable
//!    for a fixed architecture, and are exposed through
//!    [`Optimizer::state`] for bit-identical checkpoint round-trips.

use crate::nn::Sequential;

/// Serializable optimizer state: scalar counters + per-parameter buffers in
/// visit order (SGD: `[velocity…]`; Adam: `[m…, v…]`).
#[derive(Clone, Debug, Default)]
pub struct OptimizerState {
    /// Update counter (Adam's bias-correction `t`; 0 for SGD).
    pub step: u64,
    /// Per-parameter state buffers in visit order (see struct docs).
    pub buffers: Vec<Vec<f32>>,
}

/// One parameter update rule over a [`Sequential`].
pub trait Optimizer {
    /// Apply one update from the accumulated gradients. Does **not** zero
    /// them — see the module contract.
    fn step(&mut self, net: &mut Sequential);
    /// Identifier written into checkpoints (`"sgd"` / `"adam"`).
    fn name(&self) -> &'static str;
    /// Snapshot the mutable state for checkpointing.
    fn state(&self) -> OptimizerState;
    /// Restore a [`state`](Optimizer::state) snapshot.
    fn load_state(&mut self, st: OptimizerState);
}

/// SGD with momentum: `v ← μ·v + g`, `p ← p − lr·v` — the arithmetic of the
/// pre-trait `nn::Sgd`, minus its fused gradient clearing.
pub struct Sgd {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient μ.
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    /// SGD with fresh (zero) velocity buffers.
    pub fn new(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, velocity: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, net: &mut Sequential) {
        let mut idx = 0usize;
        let lr = self.lr;
        let mu = self.momentum;
        let vel = &mut self.velocity;
        net.visit_params(&mut |p, g| {
            if vel.len() <= idx {
                vel.push(vec![0.0; p.len()]);
            }
            let v = &mut vel[idx];
            assert_eq!(v.len(), p.len(), "parameter set changed shape");
            for ((pv, &gv), vv) in p.data.iter_mut().zip(g.data.iter()).zip(v.iter_mut()) {
                *vv = mu * *vv + gv;
                *pv -= lr * *vv;
            }
            idx += 1;
        });
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn state(&self) -> OptimizerState {
        OptimizerState { step: 0, buffers: self.velocity.clone() }
    }

    fn load_state(&mut self, st: OptimizerState) {
        self.velocity = st.buffers;
    }
}

/// Adam (Kingma & Ba): the host-side twin of the Adam update compiled into
/// the L2 artifacts (`python/compile/model.py`), so a workload can move
/// between the host and PJRT backends without changing its update rule.
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay β₁.
    pub beta1: f32,
    /// Second-moment decay β₂.
    pub beta2: f32,
    /// Denominator stabilizer ε.
    pub eps: f32,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Standard defaults: β₁=0.9, β₂=0.999, ε=1e-8.
    pub fn new(lr: f32) -> Self {
        Self::with_config(lr, 0.9, 0.999, 1e-8)
    }

    /// Fully explicit hyper-parameters.
    pub fn with_config(lr: f32, beta1: f32, beta2: f32, eps: f32) -> Self {
        Adam { lr, beta1, beta2, eps, t: 0, m: Vec::new(), v: Vec::new() }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, net: &mut Sequential) {
        self.t += 1;
        let (b1, b2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let mut idx = 0usize;
        let (ms, vs) = (&mut self.m, &mut self.v);
        net.visit_params(&mut |p, g| {
            if ms.len() <= idx {
                ms.push(vec![0.0; p.len()]);
                vs.push(vec![0.0; p.len()]);
            }
            let (m, v) = (&mut ms[idx], &mut vs[idx]);
            assert_eq!(m.len(), p.len(), "parameter set changed shape");
            for (((pv, &gv), mv), vv) in
                p.data.iter_mut().zip(g.data.iter()).zip(m.iter_mut()).zip(v.iter_mut())
            {
                *mv = b1 * *mv + (1.0 - b1) * gv;
                *vv = b2 * *vv + (1.0 - b2) * gv * gv;
                *pv -= lr * (*mv / bc1) / ((*vv / bc2).sqrt() + eps);
            }
            idx += 1;
        });
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn state(&self) -> OptimizerState {
        let mut buffers = self.m.clone();
        buffers.extend(self.v.iter().cloned());
        OptimizerState { step: self.t, buffers }
    }

    fn load_state(&mut self, st: OptimizerState) {
        self.t = st.step;
        let half = st.buffers.len() / 2;
        let mut buffers = st.buffers;
        self.v = buffers.split_off(half);
        self.m = buffers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::linear::Linear;
    use crate::nn::{QuantMode, TrainCtx};
    use crate::tensor::Tensor;
    use crate::util::Pcg32;

    fn toy_net(seed: u64) -> Sequential {
        let mut rng = Pcg32::seeded(seed);
        Sequential::new(vec![
            Box::new(Linear::new("fc0", 4, 8, QuantMode::Float32, &mut rng)),
            Box::new(crate::nn::activ::ReLU::new("r0")),
            Box::new(Linear::new("fc1", 8, 2, QuantMode::Float32, &mut rng)),
        ])
    }

    fn one_backward(net: &mut Sequential, rng: &mut Pcg32) {
        let mut ctx = TrainCtx::new();
        let mut x = Tensor::zeros(&[4, 4]);
        rng.fill_normal(&mut x.data, 1.0);
        let logits = net.forward(&x, &mut ctx);
        let (_, g) = crate::nn::loss::softmax_xent(&logits, &[0, 1, 0, 1]);
        net.backward(&g, &mut ctx);
    }

    #[test]
    fn sgd_step_preserves_grads() {
        let mut net = toy_net(0);
        let mut rng = Pcg32::seeded(1);
        one_backward(&mut net, &mut rng);
        let mut before = Vec::new();
        net.visit_params(&mut |_, g| before.push(g.clone()));
        let mut opt = Sgd::new(0.1, 0.9);
        opt.step(&mut net);
        let mut after = Vec::new();
        net.visit_params(&mut |_, g| after.push(g.clone()));
        assert_eq!(before.len(), after.len());
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(b.data, a.data, "optimizer must not clear gradients");
        }
        net.zero_grads();
        net.visit_params(&mut |_, g| assert!(g.data.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn sgd_matches_fused_reference_update() {
        // Reference: the pre-refactor fused update (v ← μv+g, p ← p−lr·v,
        // g ← 0) applied by hand. The trait Sgd + explicit zero_grads must
        // land on bit-identical parameters and velocity.
        let mut net_a = toy_net(3);
        let mut net_b = toy_net(3);
        let mut rng_a = Pcg32::seeded(4);
        let mut rng_b = Pcg32::seeded(4);
        let mut opt = Sgd::new(0.05, 0.9);
        let mut vel: Vec<Vec<f32>> = Vec::new();
        for _ in 0..5 {
            one_backward(&mut net_a, &mut rng_a);
            one_backward(&mut net_b, &mut rng_b);
            // reference fused loop on net_b
            let mut idx = 0usize;
            net_b.visit_params(&mut |p, g| {
                if vel.len() <= idx {
                    vel.push(vec![0.0; p.len()]);
                }
                let v = &mut vel[idx];
                for ((pv, gv), vv) in p.data.iter_mut().zip(g.data.iter_mut()).zip(v.iter_mut()) {
                    *vv = 0.9 * *vv + *gv;
                    *pv -= 0.05 * *vv;
                    *gv = 0.0;
                }
                idx += 1;
            });
            // trait path on net_a
            opt.step(&mut net_a);
            net_a.zero_grads();
        }
        let mut pa = Vec::new();
        let mut pb = Vec::new();
        net_a.visit_params(&mut |p, _| pa.push(p.clone()));
        net_b.visit_params(&mut |p, _| pb.push(p.clone()));
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.data, b.data, "trait SGD diverged from fused reference");
        }
        for (a, b) in opt.state().buffers.iter().zip(&vel) {
            assert_eq!(a, b, "velocity diverged");
        }
    }

    #[test]
    fn adam_decreases_loss_and_roundtrips_state() {
        let mut net = toy_net(5);
        let mut rng = Pcg32::seeded(6);
        let mut opt = Adam::new(0.01);
        let mut ctx = TrainCtx::new();
        let mut x = Tensor::zeros(&[8, 4]);
        rng.fill_normal(&mut x.data, 1.0);
        let y: Vec<usize> = (0..8).map(|i| i % 2).collect();
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..80 {
            ctx.iter = it;
            let logits = net.forward(&x, &mut ctx);
            let (l, g) = crate::nn::loss::softmax_xent(&logits, &y);
            net.backward(&g, &mut ctx);
            opt.step(&mut net);
            net.zero_grads();
            if it == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first * 0.5, "adam failed to fit: first={first} last={last}");

        let st = opt.state();
        assert_eq!(st.step, 80);
        let mut opt2 = Adam::new(0.01);
        opt2.load_state(st.clone());
        let st2 = opt2.state();
        assert_eq!(st2.step, st.step);
        assert_eq!(st2.buffers, st.buffers);
    }
}
