//! Analytic operation-count model of the paper's *full-size* networks
//! (system S9) — regenerates Fig 7 (quantification overhead percentages)
//! and Appendix D Table 5 (absolute operation counts) exactly, because both
//! are analytic properties of the architectures, not of any training run.
//!
//! Counting conventions (validated against the paper's own numbers):
//!   forward ops  = 2 · MACs · batch                  (mul+add)
//!   backward ops = 2 · forward ops                   (BPROP + WTGRAD)
//!   quantification ops = 3 per element               (scale, round, clamp)
//!     forward:  per-iteration over W (once) + X (per batch element)
//!     backward: over ΔX (per batch element)
//!
//! With batch=256 these reproduce the paper's forward columns to within a
//! few percent (AlexNet 3.78e11, VGG16 7.93e12, ResNet50 1.78e12,
//! MobileNet-v2 1.54e11). The paper's backward column is ~3× forward
//! (ours is 2×: BPROP+WTGRAD); the delta is bookkeeping the paper does not
//! itemize — noted in EXPERIMENTS.md.

/// One countable layer of a full-size architecture.
#[derive(Clone, Debug)]
pub enum LayerDesc {
    /// Conv: in_c, out_c, k, stride, pad, input h/w (square), groups.
    Conv { in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize, hw: usize, groups: usize },
    /// Fully connected in → out.
    Fc { din: usize, dout: usize },
}

impl LayerDesc {
    pub fn out_hw(&self) -> usize {
        match self {
            LayerDesc::Conv { k, stride, pad, hw, .. } => (hw + 2 * pad - k) / stride + 1,
            LayerDesc::Fc { .. } => 1,
        }
    }

    /// MACs per example.
    pub fn macs(&self) -> u64 {
        match self {
            LayerDesc::Conv { in_c, out_c, k, groups, .. } => {
                let ohw = self.out_hw();
                (*out_c as u64) * (ohw * ohw) as u64 * ((in_c / groups) * k * k) as u64
            }
            LayerDesc::Fc { din, dout } => (*din as u64) * (*dout as u64),
        }
    }

    /// Weight element count.
    pub fn weights(&self) -> u64 {
        match self {
            LayerDesc::Conv { in_c, out_c, k, groups, .. } => {
                (*out_c as u64) * ((in_c / groups) * k * k) as u64
            }
            LayerDesc::Fc { din, dout } => (*din as u64) * (*dout as u64),
        }
    }

    /// Input activation elements per example.
    pub fn activations(&self) -> u64 {
        match self {
            LayerDesc::Conv { in_c, hw, .. } => (*in_c as u64) * (hw * hw) as u64,
            LayerDesc::Fc { din, .. } => *din as u64,
        }
    }

    /// Output (= activation-gradient) elements per example.
    pub fn outputs(&self) -> u64 {
        match self {
            LayerDesc::Conv { out_c, .. } => {
                let ohw = self.out_hw();
                (*out_c as u64) * (ohw * ohw) as u64
            }
            LayerDesc::Fc { dout, .. } => *dout as u64,
        }
    }
}

/// Operation totals for one network at one batch size.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCounts {
    pub forward: f64,
    pub forward_quant: f64,
    pub backward: f64,
    pub backward_quant: f64,
}

impl OpCounts {
    pub fn forward_quant_pct(&self) -> f64 {
        100.0 * self.forward_quant / (self.forward + self.forward_quant)
    }

    pub fn backward_quant_pct(&self) -> f64 {
        100.0 * self.backward_quant / (self.backward + self.backward_quant)
    }

    /// Total quantification share of all training ops (Fig 7's stacked bar).
    pub fn quant_share(&self) -> f64 {
        let q = self.forward_quant + self.backward_quant;
        let t = self.forward + self.backward + q;
        q / t
    }
}

pub const QUANT_OPS_PER_ELEM: f64 = 3.0;

/// Count ops for a network at a batch size.
pub fn count(layers: &[LayerDesc], batch: usize) -> OpCounts {
    let b = batch as f64;
    let mut c = OpCounts::default();
    for l in layers {
        let macs = l.macs() as f64;
        c.forward += 2.0 * macs * b;
        c.backward += 2.0 * 2.0 * macs * b;
        c.forward_quant +=
            QUANT_OPS_PER_ELEM * (l.weights() as f64 + l.activations() as f64 * b);
        c.backward_quant += QUANT_OPS_PER_ELEM * l.outputs() as f64 * b;
    }
    c
}

// ---------------------------------------------------------------------------
// Architecture descriptors (full-size, as evaluated in the paper)
// ---------------------------------------------------------------------------

fn conv(in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize, hw: usize) -> LayerDesc {
    LayerDesc::Conv { in_c, out_c, k, stride, pad, hw, groups: 1 }
}

fn dwconv(c: usize, k: usize, stride: usize, pad: usize, hw: usize) -> LayerDesc {
    LayerDesc::Conv { in_c: c, out_c: c, k, stride, pad, hw, groups: c }
}

/// AlexNet (227×227 input, 1000 classes).
pub fn alexnet() -> Vec<LayerDesc> {
    // conv1/conv3/conv4 are the original 2-group convolutions.
    let g2 = |in_c, out_c, k, stride, pad, hw| LayerDesc::Conv {
        in_c, out_c, k, stride, pad, hw, groups: 2,
    };
    vec![
        conv(3, 96, 11, 4, 0, 227),   // conv0 → 55
        g2(96, 256, 5, 1, 2, 27),     // conv1 (after pool) → 27
        conv(256, 384, 3, 1, 1, 13),  // conv2
        g2(384, 384, 3, 1, 1, 13),    // conv3
        g2(384, 256, 3, 1, 1, 13),    // conv4
        LayerDesc::Fc { din: 256 * 6 * 6, dout: 4096 },
        LayerDesc::Fc { din: 4096, dout: 4096 },
        LayerDesc::Fc { din: 4096, dout: 1000 },
    ]
}

/// VGG16 (224×224).
pub fn vgg16() -> Vec<LayerDesc> {
    let mut l = Vec::new();
    let stages: [(usize, usize, usize, usize); 5] = [
        (3, 64, 2, 224),
        (64, 128, 2, 112),
        (128, 256, 3, 56),
        (256, 512, 3, 28),
        (512, 512, 3, 14),
    ];
    for (in_c, out_c, convs, hw) in stages {
        for i in 0..convs {
            l.push(conv(if i == 0 { in_c } else { out_c }, out_c, 3, 1, 1, hw));
        }
    }
    l.push(LayerDesc::Fc { din: 512 * 7 * 7, dout: 4096 });
    l.push(LayerDesc::Fc { din: 4096, dout: 4096 });
    l.push(LayerDesc::Fc { din: 4096, dout: 1000 });
    l
}

/// ResNet50 (224×224), bottleneck blocks.
pub fn resnet50() -> Vec<LayerDesc> {
    let mut l = vec![conv(3, 64, 7, 2, 3, 224)]; // stem → 112
    let stages: [(usize, usize, usize, usize, usize); 4] = [
        // (in_c, mid, out, blocks, hw_in)
        (64, 64, 256, 3, 56),
        (256, 128, 512, 4, 56),
        (512, 256, 1024, 6, 28),
        (1024, 512, 2048, 3, 14),
    ];
    for (in_c, mid, out, blocks, hw_in) in stages {
        let mut cin = in_c;
        let mut hw = hw_in;
        for b in 0..blocks {
            // resnet_v1 (TF-slim, the paper's code base): downsampling
            // stride sits on the block's first 1×1 conv.
            let stride = if b == 0 && in_c != 64 { 2 } else { 1 };
            l.push(conv(cin, mid, 1, stride, 0, hw));
            let hw_out = if stride == 2 { hw / 2 } else { hw };
            l.push(conv(mid, mid, 3, 1, 1, hw_out));
            l.push(conv(mid, out, 1, 1, 0, hw_out));
            if b == 0 {
                l.push(conv(cin, out, 1, stride, 0, hw)); // projection skip
            }
            cin = out;
            hw = hw_out;
        }
    }
    l.push(LayerDesc::Fc { din: 2048, dout: 1000 });
    l
}

/// MobileNet-v2 (224×224), inverted residuals.
pub fn mobilenet_v2() -> Vec<LayerDesc> {
    let mut l = vec![conv(3, 32, 3, 2, 1, 224)]; // stem → 112
    // (expansion t, out channels, repeats, first stride)
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    let mut hw = 112;
    for (t, out, reps, s0) in cfg {
        for r in 0..reps {
            let stride = if r == 0 { s0 } else { 1 };
            let hidden = cin * t;
            if t != 1 {
                l.push(conv(cin, hidden, 1, 1, 0, hw)); // expand
            }
            l.push(dwconv(hidden, 3, stride, 1, hw));
            let hw_out = if stride == 2 { hw / 2 } else { hw };
            l.push(conv(hidden, out, 1, 1, 0, hw_out)); // project
            cin = out;
            hw = hw_out;
        }
    }
    l.push(conv(cin, 1280, 1, 1, 0, hw));
    l.push(LayerDesc::Fc { din: 1280, dout: 1000 });
    l
}

/// The four networks of Fig 7 / Table 5.
pub fn paper_networks() -> Vec<(&'static str, Vec<LayerDesc>)> {
    vec![
        ("AlexNet", alexnet()),
        ("ResNet50", resnet50()),
        ("MobileNet-v2", mobilenet_v2()),
        ("VGG16", vgg16()),
    ]
}

/// Paper Table 5 values for comparison printing.
pub fn paper_table5() -> Vec<(&'static str, [f64; 4])> {
    // (forward, forward quant, backward, backward quant)
    vec![
        ("AlexNet", [3.78e11, 6.95e8, 1.78e12, 1.90e9]),
        ("ResNet50", [1.78e12, 1.01e10, 5.37e12, 3.39e10]),
        ("MobileNet-v2", [1.54e11, 8.68e9, 4.41e11, 2.57e10]),
        ("VGG16", [7.93e12, 1.24e10, 2.88e13, 4.70e10]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / b
    }

    #[test]
    fn alexnet_geometry() {
        let l = alexnet();
        assert_eq!(l[0].out_hw(), 55); // conv0: (227-11)/4+1
        // ~61M params total (AlexNet's well-known size)
        let w: u64 = l.iter().map(|d| d.weights()).sum();
        assert!(w > 55_000_000 && w < 65_000_000, "weights={w}");
    }

    #[test]
    fn forward_counts_match_paper_table5() {
        // the paper's forward column at batch 256, within 15%
        for ((name, layers), (pname, row)) in paper_networks().iter().zip(paper_table5()) {
            assert_eq!(*name, pname);
            let c = count(layers, 256);
            assert!(
                rel_err(c.forward, row[0]) < 0.15,
                "{name}: forward {:.3e} vs paper {:.3e}",
                c.forward,
                row[0]
            );
        }
    }

    #[test]
    fn quantification_overhead_small_except_mobilenet() {
        // Fig 7's qualitative content: quant ops ≲1% for heavy nets, several
        // percent for MobileNet.
        let shares: Vec<(String, f64)> = paper_networks()
            .iter()
            .map(|(n, l)| (n.to_string(), count(l, 256).quant_share()))
            .collect();
        let get = |n: &str| shares.iter().find(|(s, _)| s == n).unwrap().1;
        assert!(get("VGG16") < 0.01, "vgg {:?}", get("VGG16"));
        assert!(get("ResNet50") < 0.02);
        assert!(get("AlexNet") < 0.01);
        assert!(get("MobileNet-v2") > get("VGG16") * 4.0, "mobilenet must dominate");
    }

    #[test]
    fn resnet50_macs_sane() {
        // ~4.1 GMACs for ResNet50 at 224² (literature value ±15%)
        let macs: u64 = resnet50().iter().map(|l| l.macs()).sum();
        assert!(
            (3.2e9..4.5e9).contains(&(macs as f64)),
            "resnet50 macs={macs}"
        );
    }

    #[test]
    fn mobilenet_macs_sane() {
        // ~300 MMACs for MobileNet-v2 (literature value ±30%)
        let macs: u64 = mobilenet_v2().iter().map(|l| l.macs()).sum();
        assert!(
            (2.2e8..4.2e8).contains(&(macs as f64)),
            "mobilenet macs={macs}"
        );
    }

    #[test]
    fn backward_is_twice_forward() {
        let c = count(&alexnet(), 32);
        assert!((c.backward / c.forward - 2.0).abs() < 1e-9);
    }
}
