//! Post-training calibration and precision schedules
//! (DESIGN.md §Calibration).
//!
//! The paper quantizes *during* training; this subsystem covers the two
//! workflows around that loop:
//!
//! 1. **PTQ calibration** — the tf.contrib.quantize-style "train float,
//!    quantize later" path. An [`Observer`] ([`MinMax`], [`MovingAverage`],
//!    [`Percentile`], [`Kl`]) watches each quantizable site's activations
//!    while a [`Calibrator`] drives forward-only passes over a data stream
//!    through the serving compiler's observed interpreter; the result is a
//!    [`CalibTable`] (site → calibrated [`crate::fixedpoint::Format`]),
//!    which `serve::FrozenModel::freeze_ptq` combines with a *float*
//!    checkpoint into a statically quantized serving artifact — no QAT run
//!    needed (Sakr & Shanbhag, arXiv 1812.11732: precision from observed
//!    statistics alone). Tables persist as standalone files
//!    (`apt calibrate --out` / `apt serve --calib`) and as the optional
//!    `calib` checkpoint section.
//! 2. **Precision schedules** — [`Schedule`] generalizes the old
//!    `quant_delay` knob on `train::SessionBuilder` into a full axis:
//!    `delay:<n>`, `warmup`, and phased `progressive:16@0,8@k` schedules
//!    that retune every fixed-point controller at exact step boundaries
//!    (AdaPT, arXiv 2107.13490). `delay:0` and degenerate schedules are
//!    bit-identical to the pre-schedule controller path.
//!
//! ```
//! use apt::calib::{Calibrator, ObserverKind};
//! use apt::data::SynthImages;
//! use apt::fixedpoint::FormatFamily;
//! use apt::nn::{models, QuantMode};
//! use apt::train::SessionBuilder;
//!
//! // A float model: no train-time activation schemes anywhere.
//! let s = SessionBuilder::classifier("mlp").mode(QuantMode::Float32).build();
//! let mut cal = Calibrator::from_net("mlp", s.net(), ObserverKind::Percentile(99.9)).unwrap();
//! let mut data =
//!     SynthImages::new(1000, models::CLASSES, models::IN_C, models::IN_H, models::IN_W, 0.5);
//! for _ in 0..4 {
//!     let (x, _) = data.batch(16);
//!     cal.observe(&x);
//! }
//! let table = cal.finish(FormatFamily::FixedPoint, 8, false);
//! assert_eq!(table.sites.len(), 3); // mlp: fc0, fc1, fc2
//! assert!(table.sites.iter().all(|s| s.max_abs > 0.0));
//! ```

mod observer;
mod schedule;
mod table;

pub use observer::{Kl, MagnitudeHistogram, MinMax, MovingAverage, Observer, ObserverKind, Percentile};
pub use schedule::Schedule;
pub use table::{CalibSite, CalibTable};

pub(crate) use table::parse_fmt;

use anyhow::Result;

use crate::compiler::{self, CompileOptions};
use crate::fixedpoint::{Format, FormatFamily};
use crate::nn::Sequential;
use crate::serve::InferOp;
use crate::tensor::Tensor;

/// Drives calibration: a forward-only program compiled from a model's
/// serving export, with one [`Observer`] attached to every quantizable
/// site (linear / conv / depthwise input). Feed it batches with
/// [`observe`](Calibrator::observe), then [`finish`](Calibrator::finish)
/// into a [`CalibTable`].
pub struct Calibrator {
    program: compiler::Compiled,
    observers: Vec<(String, Box<dyn Observer>)>,
    kind: ObserverKind,
    samples: usize,
}

impl Calibrator {
    /// Build from an exported op list (what `Sequential::export_infer`
    /// yields). The ops run unfused and unquantized — exactly the f32
    /// forward the calibrated model will approximate.
    pub fn from_infer_ops(label: &str, ops: Vec<InferOp>, kind: ObserverKind) -> Result<Calibrator> {
        let opts = CompileOptions { fuse: false, tune: false, weight_format: None };
        let program = compiler::compile(label, ops, &opts, &[], crate::kernels::global())?;
        let observers =
            program.site_names().into_iter().map(|n| (n, kind.build())).collect();
        Ok(Calibrator { program, observers, kind, samples: 0 })
    }

    /// Build from a live net (convenience over
    /// [`from_infer_ops`](Self::from_infer_ops)).
    pub fn from_net(label: &str, net: &Sequential, kind: ObserverKind) -> Result<Calibrator> {
        Self::from_infer_ops(label, net.export_infer()?, kind)
    }

    /// Run one forward-only pass over a batch `[n, din]`, feeding every
    /// site's input activation to its observer.
    pub fn observe(&mut self, x: &Tensor) {
        let observers = &mut self.observers;
        self.program.run_observed(x, crate::kernels::global(), &mut |name, data| {
            if let Some((_, ob)) = observers.iter_mut().find(|(n, _)| n == name) {
                ob.observe(data);
            }
        });
        self.samples += x.dim(0);
    }

    /// Sites being observed, in forward (program) order.
    pub fn site_names(&self) -> Vec<String> {
        self.observers.iter().map(|(n, _)| n.clone()).collect()
    }

    /// Samples (input rows) observed so far.
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Derive the calibration table: each site's observed range becomes a
    /// `family`-family activation format at `bits` (fixed-width families
    /// keep their storage width; only the scale tracks the range).
    /// `per_channel` marks the table for per-output-channel weight
    /// quantization at freeze time.
    pub fn finish(&self, family: FormatFamily, bits: u8, per_channel: bool) -> CalibTable {
        let sites = self
            .observers
            .iter()
            .map(|(name, ob)| {
                let max_abs = ob.calibrated_max(bits);
                CalibSite {
                    name: name.clone(),
                    max_abs,
                    fmt: Format::for_range(family, max_abs, bits),
                }
            })
            .collect();
        CalibTable {
            observer: self.kind.label(),
            family,
            bits,
            per_channel,
            samples: self.samples,
            sites,
        }
    }
}
