//! The calibration table: per-site calibrated activation formats
//! (DESIGN.md §Calibration).
//!
//! A [`CalibTable`] is what a calibration pass produces and what
//! `serve::FrozenModel::freeze_ptq` consumes: one record per quantizable
//! site (linear / conv / depthwise layer, keyed by layer name) holding the
//! observed clipping range and the [`Format`] derived from it. Tables
//! round-trip through a small whitespace-tokenized text file (same
//! conventions as the checkpoint format: f32 payloads as hex bit patterns,
//! so ranges reload bit-exactly) — the artifact behind
//! `apt calibrate --out <file>` / `apt serve --calib <file>` — and embed
//! into checkpoints as the optional `calib` section
//! (`train::checkpoint::Checkpoint::write_calib`).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::fixedpoint::{Format, FormatFamily, Scheme};

const MAGIC: &str = "aptcalib";
const VERSION: &str = "v1";

/// One calibrated site: a quantizable layer's activation input.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibSite {
    /// Layer name (the serving IR's site key).
    pub name: String,
    /// Calibrated clipping range max |x| the format was derived from.
    pub max_abs: f32,
    /// The activation format this site freezes to.
    pub fmt: Format,
}

/// Site → calibrated format map plus the provenance needed to reproduce it.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibTable {
    /// Observer label (`minmax`, `ema:<a>`, `percentile:<q>`, `kl`).
    pub observer: String,
    /// Format family every site was calibrated into.
    pub family: FormatFamily,
    /// Target bit-width (fixed-point; fixed-width families keep their
    /// storage width).
    pub bits: u8,
    /// Whether `freeze_ptq` should quantize weights per output channel.
    pub per_channel: bool,
    /// Samples (input rows) observed.
    pub samples: usize,
    /// Calibrated sites, in forward (program) order.
    pub sites: Vec<CalibSite>,
}

impl CalibTable {
    /// Look up a site by layer name.
    pub fn get(&self, name: &str) -> Option<&CalibSite> {
        self.sites.iter().find(|s| s.name == name)
    }

    /// Render to the text format (the `--out` artifact).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{MAGIC} {VERSION}");
        let _ = writeln!(out, "observer {}", self.observer);
        let _ = writeln!(out, "family {} {}", self.family.tag(), self.bits);
        let _ = writeln!(out, "per_channel {}", self.per_channel as u8);
        let _ = writeln!(out, "samples {}", self.samples);
        let _ = writeln!(out, "sites {}", self.sites.len());
        for s in &self.sites {
            let _ = writeln!(
                out,
                "site {} {:08x} {} {} {}",
                s.name,
                s.max_abs.to_bits(),
                s.fmt.family().tag(),
                s.fmt.storage_bits(),
                s.fmt.scale_exp()
            );
        }
        out.push_str("end\n");
        out
    }

    /// Parse the text format.
    pub fn parse(text: &str) -> Result<CalibTable> {
        let mut toks = text.split_ascii_whitespace();
        let mut next = || toks.next().ok_or_else(|| anyhow!("truncated calibration table"));
        let expect = |t: &str, want: &str| -> Result<()> {
            if t != want {
                bail!("expected {want:?}, found {t:?}");
            }
            Ok(())
        };
        expect(next()?, MAGIC)?;
        let v = next()?;
        if v != VERSION {
            bail!("unsupported calibration table version {v:?} (this build reads {VERSION})");
        }
        expect(next()?, "observer")?;
        let observer = next()?.to_string();
        expect(next()?, "family")?;
        let ftag = next()?;
        let family = FormatFamily::parse(ftag)
            .ok_or_else(|| anyhow!("unknown format family {ftag:?} in calibration table"))?;
        let bits: u8 = next()?.parse()?;
        expect(next()?, "per_channel")?;
        let per_channel = next()?.parse::<u8>()? != 0;
        expect(next()?, "samples")?;
        let samples: usize = next()?.parse()?;
        expect(next()?, "sites")?;
        let n: usize = next()?.parse()?;
        let mut sites = Vec::with_capacity(n);
        for _ in 0..n {
            expect(next()?, "site")?;
            let name = next()?.to_string();
            let max_abs = f32::from_bits(u32::from_str_radix(next()?, 16)?);
            sites.push(CalibSite { name, max_abs, fmt: parse_fmt(next()?, next()?, next()?)? });
        }
        expect(next()?, "end")?;
        Ok(CalibTable { observer, family, bits, per_channel, samples, sites })
    }

    /// Read a table file (the `apt serve --calib <file>` artifact).
    pub fn read(path: impl AsRef<Path>) -> Result<CalibTable> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration table {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parsing calibration table {path:?}"))
    }

    /// Write the table file.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating directory {dir:?}"))?;
            }
        }
        std::fs::write(path, self.render())
            .with_context(|| format!("writing calibration table {path:?}"))
    }
}

/// Parse one site's `(family, bits, s)` token triple back into a [`Format`]
/// — shared with the checkpoint `calib` section reader.
pub(crate) fn parse_fmt(ftag: &str, bits: &str, s: &str) -> Result<Format> {
    let family = FormatFamily::parse(ftag)
        .ok_or_else(|| anyhow!("unknown format family {ftag:?} in calibration site"))?;
    let bits: u8 = bits.parse()?;
    let s: i32 = s.parse()?;
    Ok(match family {
        FormatFamily::FixedPoint => Format::FixedPoint(Scheme { bits, s }),
        other => Format::from_scheme(other, Scheme { bits, s }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> CalibTable {
        CalibTable {
            observer: "percentile:99.99".into(),
            family: FormatFamily::FixedPoint,
            bits: 8,
            per_channel: false,
            samples: 512,
            sites: vec![
                CalibSite {
                    name: "conv0".into(),
                    max_abs: 1.375,
                    fmt: Format::FixedPoint(Scheme { bits: 8, s: -6 }),
                },
                CalibSite {
                    name: "fc1".into(),
                    max_abs: 0.03125,
                    fmt: Format::FixedPoint(Scheme { bits: 8, s: -12 }),
                },
            ],
        }
    }

    #[test]
    fn render_parse_round_trip() {
        let t = table();
        let back = CalibTable::parse(&t.render()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn minifloat_sites_round_trip() {
        let mut t = table();
        t.family = FormatFamily::E4M3;
        t.sites[0].fmt = Format::for_range(FormatFamily::E4M3, 1e5, 8);
        t.sites[1].fmt = Format::for_range(FormatFamily::E5M2, 0.5, 8);
        let back = CalibTable::parse(&t.render()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn file_round_trip_and_lookup() {
        let t = table();
        let p = std::env::temp_dir().join("apt_calib_table_test.calib");
        t.write(&p).unwrap();
        let back = CalibTable::read(&p).unwrap();
        assert_eq!(back.get("fc1").unwrap().max_abs, 0.03125);
        assert!(back.get("nope").is_none());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(CalibTable::parse("not a table").is_err());
        assert!(CalibTable::parse("aptcalib v9 end").is_err());
        // truncated site list
        let t = table();
        let text = t.render();
        let cut = &text[..text.len() - 20];
        assert!(CalibTable::parse(cut).is_err());
    }
}
