//! Activation-range observers for post-training calibration
//! (DESIGN.md §Calibration).
//!
//! An [`Observer`] watches one site's activations over forward-only passes
//! and reports a calibrated clipping range. Four estimators, matching the
//! tf.contrib.quantize / TensorRT lineage:
//!
//! - [`MinMax`] — running max |x| (exact envelope; outlier-sensitive).
//! - [`MovingAverage`] — EMA of per-batch max |x| (the QAT-style smoothed
//!   envelope, riding the same [`Ema`] the precision controllers use).
//! - [`Percentile`] — the q-th percentile of |x| over *all* observed values,
//!   from a streaming magnitude histogram (clips outliers).
//! - [`Kl`] — entropy calibration: the clipping threshold whose quantized
//!   distribution minimizes KL divergence against the observed one
//!   (TensorRT's int8 calibrator).
//!
//! Percentile and KL share one [`MagnitudeHistogram`] — a fixed-bin linear
//! histogram over |x| whose range grows by exact power-of-two bin merges,
//! so streaming observation never re-reads old data.

use anyhow::{anyhow, bail, Result};

use crate::util::stats::Ema;

/// Histogram bin count. 2048 linear magnitude bins (the TensorRT choice):
/// fine enough that the 99.99th percentile of a 10⁶-sample stream lands
/// within 0.05% of range, coarse enough to stay cache-resident.
const NBINS: usize = 2048;

/// Streaming histogram of |x| with a growable range: when a value exceeds
/// the current range, the bin width doubles and adjacent bin pairs merge
/// (an exact rebin — no sample is misplaced by more than the new width).
#[derive(Clone, Debug)]
pub struct MagnitudeHistogram {
    counts: Vec<u64>,
    /// Bin width; total range is `width · NBINS`.
    width: f32,
    total: u64,
    max_seen: f32,
}

impl MagnitudeHistogram {
    pub fn new() -> Self {
        MagnitudeHistogram { counts: vec![0; NBINS], width: 0.0, total: 0, max_seen: 0.0 }
    }

    /// Total |x| range currently covered.
    pub fn range(&self) -> f32 {
        self.width * NBINS as f32
    }

    /// Largest finite |x| observed.
    pub fn max_abs(&self) -> f32 {
        self.max_seen
    }

    /// Samples observed (non-finite values are skipped).
    pub fn total(&self) -> u64 {
        self.total
    }

    fn grow_to(&mut self, a: f32) {
        if self.width == 0.0 {
            // First nonzero sample seeds the range directly.
            self.width = a / (NBINS as f32 - 0.5);
            return;
        }
        while a >= self.range() {
            for i in 0..NBINS / 2 {
                self.counts[i] = self.counts[2 * i] + self.counts[2 * i + 1];
            }
            for c in self.counts[NBINS / 2..].iter_mut() {
                *c = 0;
            }
            self.width *= 2.0;
        }
    }

    pub fn add(&mut self, x: f32) {
        if !x.is_finite() {
            return;
        }
        let a = x.abs();
        self.total += 1;
        if a == 0.0 {
            self.counts[0] += 1;
            return;
        }
        if a > self.max_seen {
            self.max_seen = a;
        }
        if a >= self.range() {
            self.grow_to(a);
        }
        let idx = ((a / self.width) as usize).min(NBINS - 1);
        self.counts[idx] += 1;
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Magnitude below which fraction `q/100` of observed samples fall
    /// (upper bin edge — never under-covers). `q ≥ 100` returns the exact
    /// max.
    pub fn percentile(&self, q: f64) -> f32 {
        if self.total == 0 {
            return 0.0;
        }
        if q >= 100.0 {
            return self.max_seen;
        }
        let target = (q / 100.0 * self.total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return self.width * (i + 1) as f32;
            }
        }
        self.max_seen
    }

    /// Entropy-calibrated clipping threshold for a symmetric quantizer with
    /// `levels` positive levels (int8: 2⁷ = 128): sweep candidate
    /// thresholds, score each by the KL divergence between the observed
    /// distribution (outliers saturated into the edge bin) and its
    /// `levels`-level quantized reconstruction, return the arg-min.
    pub fn kl_threshold(&self, levels: usize) -> f32 {
        if self.total == 0 {
            return 0.0;
        }
        let first = self.counts.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        if first <= levels {
            // Fewer populated bins than quantized levels: nothing to clip.
            return self.max_seen;
        }
        let mut best = (f64::INFINITY, first);
        let mut i = levels;
        while i <= first {
            let d = self.kl_at(i, levels);
            if d < best.0 {
                best = (d, i);
            }
            // Sweeping every bin is O(bins²); stepping by a handful keeps
            // the sweep ~10⁴ ops with no visible threshold loss.
            i += 4;
        }
        self.width * best.1 as f32
    }

    /// KL(P‖Q) for a clip at bin `m`: P = bins `0..m` with the tail mass
    /// saturated into bin `m−1`; Q = P pooled into `levels` groups and
    /// re-expanded uniformly over each group's non-empty bins.
    fn kl_at(&self, m: usize, levels: usize) -> f64 {
        let tail: u64 = self.counts[m..].iter().sum();
        let mut p: Vec<f64> = self.counts[..m].iter().map(|&c| c as f64).collect();
        *p.last_mut().expect("m >= levels >= 1") += tail as f64;
        let mut div = 0.0f64;
        // Pool P into `levels` contiguous groups (TensorRT's candidate
        // quantization), expand each group's mass uniformly over its
        // non-empty source bins, and accumulate KL in one pass.
        for g in 0..levels {
            let lo = g * m / levels;
            let hi = ((g + 1) * m / levels).max(lo + 1).min(m);
            let grp = &p[lo..hi];
            let mass: f64 = grp.iter().sum();
            let nonzero = grp.iter().filter(|&&v| v > 0.0).count();
            if mass <= 0.0 || nonzero == 0 {
                continue;
            }
            let q = mass / nonzero as f64;
            for &pv in grp {
                if pv > 0.0 {
                    div += pv * (pv / q).ln();
                }
            }
        }
        let total: f64 = p.iter().sum();
        if total > 0.0 {
            div / total
        } else {
            f64::INFINITY
        }
    }
}

impl Default for MagnitudeHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One calibration estimator: feed it tensors, read back a clipping range.
///
/// `bits` reaches [`calibrated_max`](Observer::calibrated_max) because the
/// KL estimator's optimal threshold depends on how many quantized levels
/// the target format has; the other estimators ignore it.
pub trait Observer {
    /// Accumulate one tensor's values into the site statistics.
    fn observe(&mut self, data: &[f32]);
    /// The calibrated clipping range max |x| for a `bits`-wide symmetric
    /// quantizer. 0.0 until something has been observed.
    fn calibrated_max(&self, bits: u8) -> f32;
    /// Parseable estimator label (`minmax`, `ema:0.01`, `percentile:99.99`,
    /// `kl`).
    fn label(&self) -> String;
}

/// Exact running max |x|.
#[derive(Clone, Debug, Default)]
pub struct MinMax {
    max: f32,
}

impl MinMax {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Observer for MinMax {
    fn observe(&mut self, data: &[f32]) {
        for &v in data {
            let a = v.abs();
            if a.is_finite() && a > self.max {
                self.max = a;
            }
        }
    }

    fn calibrated_max(&self, _bits: u8) -> f32 {
        self.max
    }

    fn label(&self) -> String {
        "minmax".into()
    }
}

/// EMA of per-call max |x| — the moving-average range estimator of
/// tf.contrib.quantize, on the same [`Ema`] the precision controllers use.
#[derive(Clone, Debug)]
pub struct MovingAverage {
    ema: Ema,
}

impl MovingAverage {
    pub fn new(alpha: f32) -> Self {
        MovingAverage { ema: Ema::new(alpha) }
    }
}

impl Observer for MovingAverage {
    fn observe(&mut self, data: &[f32]) {
        let m = data.iter().fold(0.0f32, |m, v| if v.is_finite() { m.max(v.abs()) } else { m });
        self.ema.update(m);
    }

    fn calibrated_max(&self, _bits: u8) -> f32 {
        if self.ema.is_initialized() {
            self.ema.value
        } else {
            0.0
        }
    }

    fn label(&self) -> String {
        format!("ema:{}", self.ema.alpha)
    }
}

/// q-th percentile of |x| over everything observed (streaming histogram).
#[derive(Clone, Debug)]
pub struct Percentile {
    q: f64,
    hist: MagnitudeHistogram,
}

impl Percentile {
    pub fn new(q: f64) -> Self {
        Percentile { q, hist: MagnitudeHistogram::new() }
    }
}

impl Observer for Percentile {
    fn observe(&mut self, data: &[f32]) {
        self.hist.add_all(data);
    }

    fn calibrated_max(&self, _bits: u8) -> f32 {
        self.hist.percentile(self.q)
    }

    fn label(&self) -> String {
        format!("percentile:{}", self.q)
    }
}

/// KL/entropy calibration (TensorRT-style) over the shared histogram.
#[derive(Clone, Debug, Default)]
pub struct Kl {
    hist: MagnitudeHistogram,
}

impl Kl {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Observer for Kl {
    fn observe(&mut self, data: &[f32]) {
        self.hist.add_all(data);
    }

    fn calibrated_max(&self, bits: u8) -> f32 {
        let levels = 1usize << (bits.clamp(2, 16) - 1);
        self.hist.kl_threshold(levels)
    }

    fn label(&self) -> String {
        "kl".into()
    }
}

/// Parsed observer selector — what `apt calibrate --observer` takes and
/// what a [`crate::calib::CalibTable`] records.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ObserverKind {
    /// Exact running max |x|.
    MinMax,
    /// EMA of per-batch max |x| with this smoothing factor.
    Ema(f32),
    /// This percentile of |x|.
    Percentile(f64),
    /// KL/entropy calibration.
    Kl,
}

impl ObserverKind {
    /// Parse `minmax`, `ema`, `ema:<alpha>`, `percentile:<q>`, `kl`.
    pub fn parse(s: &str) -> Result<ObserverKind> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        Ok(match (head, arg) {
            ("minmax", None) => ObserverKind::MinMax,
            ("ema", None) => ObserverKind::Ema(0.01),
            ("ema", Some(a)) => {
                let alpha: f32 = a
                    .parse()
                    .map_err(|_| anyhow!("observer {s:?}: cannot parse EMA alpha {a:?}"))?;
                if !(alpha > 0.0 && alpha <= 1.0) {
                    bail!("observer {s:?}: alpha must be in (0, 1]");
                }
                ObserverKind::Ema(alpha)
            }
            ("percentile", Some(q)) => {
                let q: f64 = q
                    .parse()
                    .map_err(|_| anyhow!("observer {s:?}: cannot parse percentile {q:?}"))?;
                if !(q > 0.0 && q <= 100.0) {
                    bail!("observer {s:?}: percentile must be in (0, 100]");
                }
                ObserverKind::Percentile(q)
            }
            ("kl", None) => ObserverKind::Kl,
            _ => bail!(
                "unknown observer {s:?} (expected minmax, ema[:alpha], percentile:<q>, or kl)"
            ),
        })
    }

    /// Instantiate a fresh observer of this kind.
    pub fn build(&self) -> Box<dyn Observer> {
        match self {
            ObserverKind::MinMax => Box::new(MinMax::new()),
            ObserverKind::Ema(a) => Box::new(MovingAverage::new(*a)),
            ObserverKind::Percentile(q) => Box::new(Percentile::new(*q)),
            ObserverKind::Kl => Box::new(Kl::new()),
        }
    }

    /// Round-trips through [`parse`](Self::parse).
    pub fn label(&self) -> String {
        match self {
            ObserverKind::MinMax => "minmax".into(),
            ObserverKind::Ema(a) => format!("ema:{a}"),
            ObserverKind::Percentile(q) => format!("percentile:{q}"),
            ObserverKind::Kl => "kl".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn minmax_tracks_exact_envelope() {
        let mut o = MinMax::new();
        o.observe(&[0.5, -3.0, 1.0, f32::NAN]);
        o.observe(&[2.0]);
        assert_eq!(o.calibrated_max(8), 3.0);
    }

    #[test]
    fn moving_average_smooths_batch_maxes() {
        let mut o = MovingAverage::new(0.5);
        o.observe(&[1.0]); // seeds at 1.0
        o.observe(&[3.0]); // 0.5·1 + 0.5·3 = 2.0
        assert!((o.calibrated_max(8) - 2.0).abs() < 1e-6);
        // smoothed estimate sits strictly below the outlier
        assert!(o.calibrated_max(8) < 3.0);
    }

    #[test]
    fn percentile_clips_outliers_minmax_does_not() {
        let mut rng = Pcg32::seeded(7);
        let mut data: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
        data.push(1000.0); // one gross outlier
        let mut pct = Percentile::new(99.9);
        let mut mm = MinMax::new();
        pct.observe(&data);
        mm.observe(&data);
        assert_eq!(mm.calibrated_max(8), 1000.0);
        let p = pct.calibrated_max(8);
        // 99.9th percentile of |N(0,1)| ≈ 3.29 — allow histogram slack
        assert!(p > 2.5 && p < 5.0, "p = {p}");
    }

    #[test]
    fn percentile_100_is_exact_max() {
        let mut o = Percentile::new(100.0);
        o.observe(&[0.25, -7.5, 3.0]);
        assert_eq!(o.calibrated_max(8), 7.5);
    }

    #[test]
    fn histogram_growth_preserves_counts() {
        let mut h = MagnitudeHistogram::new();
        for i in 1..=1000 {
            h.add(i as f32 * 0.001);
        }
        h.add(1e6); // forces many doublings
        assert_eq!(h.total(), 1001);
        assert_eq!(h.counts.iter().sum::<u64>(), 1001);
        assert_eq!(h.max_abs(), 1e6);
        // median of the bulk is still ~0.5 despite the range explosion
        let med = h.percentile(50.0) as f64;
        assert!(med > 0.2 && med < 1000.0, "median {med}");
    }

    #[test]
    fn kl_threshold_clips_heavy_tail() {
        let mut rng = Pcg32::seeded(3);
        let mut o = Kl::new();
        // bulk gaussian + sparse 100x outliers: entropy calibration should
        // clip far below the outlier envelope
        let data: Vec<f32> = (0..200_000)
            .map(|i| if i % 10_000 == 0 { 100.0 } else { rng.normal() })
            .collect();
        o.observe(&data);
        let t = o.calibrated_max(8);
        assert!(t < 50.0, "kl threshold {t} failed to clip the tail");
        assert!(t > 1.0, "kl threshold {t} clipped the bulk");
    }

    #[test]
    fn kl_without_tail_keeps_full_range() {
        let mut o = Kl::new();
        let data: Vec<f32> = (0..64).map(|i| i as f32 / 64.0).collect();
        o.observe(&data);
        // fewer populated bins than levels: no clipping possible
        let t = o.calibrated_max(8);
        assert!((t - o.hist.max_abs()).abs() < 1e-6);
    }

    #[test]
    fn kind_parse_round_trip() {
        for s in ["minmax", "ema:0.05", "percentile:99.99", "kl"] {
            let k = ObserverKind::parse(s).unwrap();
            assert_eq!(k.label(), s);
            assert_eq!(ObserverKind::parse(&k.label()).unwrap(), k);
        }
        assert_eq!(ObserverKind::parse("ema").unwrap(), ObserverKind::Ema(0.01));
        assert!(ObserverKind::parse("percentile").is_err());
        assert!(ObserverKind::parse("percentile:0").is_err());
        assert!(ObserverKind::parse("percentile:101").is_err());
        assert!(ObserverKind::parse("ema:0").is_err());
        assert!(ObserverKind::parse("entropy").is_err());
        assert!(ObserverKind::parse("minmax:3").is_err());
    }

    #[test]
    fn observers_are_empty_safe() {
        for kind in
            [ObserverKind::MinMax, ObserverKind::Ema(0.1), ObserverKind::Percentile(99.0), ObserverKind::Kl]
        {
            let o = kind.build();
            assert_eq!(o.calibrated_max(8), 0.0, "{}", o.label());
        }
    }
}
