//! Precision schedules: when quantization turns on and at what width
//! (DESIGN.md §Calibration).
//!
//! Generalizes the old `quant_delay` knob into one axis on
//! [`crate::train::SessionBuilder`]: a [`Schedule`] says *from which
//! iteration* quantization is live (`quant_from`, what `--quant-delay`
//! set) and, optionally, a sequence of *phases* that retune every
//! fixed-point controller to a new bit-width at exact step boundaries
//! (AdaPT, arXiv 2107.13490: schedule-driven precision over a run).
//!
//! Degenerate schedules are pinned bit-identical to the pre-schedule
//! behavior: `delay:0` is exactly today's quantize-from-the-start path,
//! and a single phase at the controllers' existing width retunes nothing
//! (`PrecisionController::retune_bits` is a no-op when the width already
//! matches — see `rust/tests/test_calib.rs`).

use anyhow::{anyhow, bail, Result};

use crate::nn::TrainCtx;

/// When quantization is live and at what bit-width.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    /// First iteration at which quantization is on (iterations below train
    /// in plain f32).
    quant_from: u64,
    /// `(start_iter, bits)` phases, strictly increasing in `start_iter`:
    /// at each phase start every fixed-point controller is retuned to
    /// `bits`. Empty = the controllers keep their configured widths.
    phases: Vec<(u64, u8)>,
}

impl Schedule {
    /// Quantize from iteration `n` on (`delay:0` = from the start — the
    /// historical default, bit-identical to pre-schedule sessions).
    pub fn delay(n: u64) -> Schedule {
        Schedule { quant_from: n, phases: Vec::new() }
    }

    /// The `warmup` spelling: float for the first tenth of the run, then
    /// quantize — the same heuristic the adaptive init phase uses.
    pub fn warmup(total_iters: u64) -> Schedule {
        Schedule::delay(total_iters / 10)
    }

    /// A phased width schedule (`progressive:16@0,8@500`): quantization is
    /// live from the first phase's start, and each phase retunes every
    /// fixed-point controller to its width. Phases must be non-empty,
    /// strictly increasing in start iteration, with widths in 2..=32.
    pub fn progressive(phases: Vec<(u64, u8)>) -> Result<Schedule> {
        if phases.is_empty() {
            bail!("progressive schedule needs at least one bits@iter phase");
        }
        for win in phases.windows(2) {
            if win[1].0 <= win[0].0 {
                bail!(
                    "progressive schedule phases must strictly increase: {}@{} after {}@{}",
                    win[1].1,
                    win[1].0,
                    win[0].1,
                    win[0].0
                );
            }
        }
        for &(at, bits) in &phases {
            if !(2..=32).contains(&bits) {
                bail!("progressive schedule: {bits} bits at iter {at} outside 2..=32");
            }
        }
        Ok(Schedule { quant_from: phases[0].0, phases })
    }

    /// Parse a `--schedule` spec: `delay:<n>`, `warmup`, or
    /// `progressive:<bits>@<iter>,…` (e.g. `progressive:16@0,8@500`).
    /// `total_iters` sizes `warmup`.
    pub fn parse(s: &str, total_iters: u64) -> Result<Schedule> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match (head, arg) {
            ("delay", Some(n)) => Ok(Schedule::delay(
                n.parse()
                    .map_err(|_| anyhow!("schedule {s:?}: cannot parse delay {n:?}"))?,
            )),
            ("warmup", None) => Ok(Schedule::warmup(total_iters)),
            ("progressive", Some(spec)) => {
                let mut phases = Vec::new();
                for part in spec.split(',') {
                    let (bits, at) = part.split_once('@').ok_or_else(|| {
                        anyhow!("schedule {s:?}: phase {part:?} is not <bits>@<iter>")
                    })?;
                    phases.push((
                        at.parse().map_err(|_| {
                            anyhow!("schedule {s:?}: cannot parse iter {at:?}")
                        })?,
                        bits.parse().map_err(|_| {
                            anyhow!("schedule {s:?}: cannot parse bits {bits:?}")
                        })?,
                    ));
                }
                Schedule::progressive(phases)
            }
            _ => bail!(
                "unknown schedule {s:?} (expected delay:<n>, warmup, or progressive:<bits>@<iter>,…)"
            ),
        }
    }

    /// First iteration at which quantization is live.
    pub fn quant_from(&self) -> u64 {
        self.quant_from
    }

    /// The width to retune to if `iter` is exactly a phase boundary.
    /// Backends consult this at the top of every step.
    pub fn retune_at(&self, iter: u64) -> Option<u8> {
        self.phases.iter().find(|&&(at, _)| at == iter).map(|&(_, bits)| bits)
    }

    /// The width in force at `iter` (the latest phase whose start is
    /// ≤ `iter`); `None` before the first phase or for phase-less
    /// schedules. Checkpoint restores use this to re-establish the width
    /// floor mid-phase.
    pub fn bits_at(&self, iter: u64) -> Option<u8> {
        self.phases.iter().rev().find(|&&(at, _)| at <= iter).map(|&(_, bits)| bits)
    }

    /// Whether this schedule is the trivial `delay:0` (nothing to install,
    /// nothing to retune — the pre-schedule behavior).
    pub fn is_trivial(&self) -> bool {
        self.quant_from == 0 && self.phases.is_empty()
    }

    /// Install the schedule's quantization-start iteration into a training
    /// context — the single definition behind every backend's
    /// `set_schedule` (the old per-backend `quant_from` plumbing).
    pub fn install(&self, ctx: &mut TrainCtx) {
        ctx.quant_from = self.quant_from;
    }

    /// Round-trips through [`parse`](Self::parse) for `delay`/`progressive`
    /// (`warmup` renders as the delay it resolved to).
    pub fn label(&self) -> String {
        if self.phases.is_empty() {
            format!("delay:{}", self.quant_from)
        } else {
            let parts: Vec<String> =
                self.phases.iter().map(|(at, bits)| format!("{bits}@{at}")).collect();
            format!("progressive:{}", parts.join(","))
        }
    }
}

impl Default for Schedule {
    /// `delay:0` — quantize from the start, retune nothing.
    fn default() -> Self {
        Schedule::delay(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_delay_and_warmup() {
        let s = Schedule::parse("delay:40", 1000).unwrap();
        assert_eq!(s.quant_from(), 40);
        assert_eq!(s.retune_at(40), None);
        assert_eq!(s.label(), "delay:40");
        let w = Schedule::parse("warmup", 1000).unwrap();
        assert_eq!(w.quant_from(), 100);
        assert!(Schedule::parse("delay:0", 10).unwrap().is_trivial());
        assert!(!w.is_trivial());
    }

    #[test]
    fn parse_progressive() {
        let s = Schedule::parse("progressive:16@0,8@500", 1000).unwrap();
        assert_eq!(s.quant_from(), 0);
        assert_eq!(s.retune_at(0), Some(16));
        assert_eq!(s.retune_at(1), None);
        assert_eq!(s.retune_at(500), Some(8));
        assert_eq!(s.bits_at(0), Some(16));
        assert_eq!(s.bits_at(499), Some(16));
        assert_eq!(s.bits_at(9999), Some(8));
        assert_eq!(s.label(), "progressive:16@0,8@500");
        // quantization starts at the first phase
        let late = Schedule::parse("progressive:8@100", 1000).unwrap();
        assert_eq!(late.quant_from(), 100);
        assert_eq!(late.bits_at(99), None);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "nope",
            "delay",
            "delay:x",
            "progressive:",
            "progressive:8",
            "progressive:8@x",
            "progressive:8@0,16@0",
            "progressive:16@100,8@50",
            "progressive:1@0",
            "progressive:64@0",
            "warmup:10",
        ] {
            assert!(Schedule::parse(bad, 100).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn install_sets_quant_from() {
        let mut ctx = TrainCtx::new();
        Schedule::parse("delay:7", 10).unwrap().install(&mut ctx);
        assert_eq!(ctx.quant_from, 7);
        ctx.iter = 6;
        assert!(!ctx.quant_on());
        ctx.iter = 7;
        assert!(ctx.quant_on());
    }
}
