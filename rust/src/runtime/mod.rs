//! PJRT runtime (system S10): loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized `HloModuleProto`s (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md). All artifacts are
//! lowered with `return_tuple=True`, so outputs arrive as one tuple literal
//! that we decompose per the manifest.
//!
//! The PJRT execution path needs the system `xla` (xla_extension) crate and
//! is gated behind the `pjrt` cargo feature (DESIGN.md §6). Without it,
//! [`Runtime::new`] returns an error and every PJRT consumer (tests,
//! benches, fig9b, train_transformer) skips gracefully — the manifest
//! parser and [`HostValue`] marshalling stay available either way.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Element type of an artifact I/O slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One input/output slot.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: Dtype,
    /// Empty = scalar.
    pub dims: Vec<usize>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_io(line: &str) -> Result<IoSpec> {
    // "<name> <dtype> <d0,d1|scalar>"
    let parts: Vec<&str> = line.split_whitespace().collect();
    if parts.len() != 3 {
        bail!("bad io line: {line:?}");
    }
    let dtype = match parts[1] {
        "f32" => Dtype::F32,
        "i32" => Dtype::I32,
        other => bail!("unknown dtype {other:?}"),
    };
    let dims = if parts[2] == "scalar" {
        vec![]
    } else {
        parts[2]
            .split(',')
            .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
            .collect::<Result<Vec<_>>>()?
    };
    Ok(IoSpec { name: parts[0].to_string(), dtype, dims })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt — run `make artifacts`", dir.display()))?;
        let mut artifacts: Vec<ArtifactSpec> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (kind, rest) = line
                .split_once(' ')
                .ok_or_else(|| anyhow!("manifest line {lineno}: {line:?}"))?;
            match kind {
                "artifact" => {
                    let (name, file) = rest
                        .split_once(' ')
                        .ok_or_else(|| anyhow!("artifact line {lineno}"))?;
                    artifacts.push(ArtifactSpec {
                        name: name.to_string(),
                        file: file.to_string(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "in" => artifacts
                    .last_mut()
                    .ok_or_else(|| anyhow!("`in` before `artifact` at line {lineno}"))?
                    .inputs
                    .push(parse_io(rest)?),
                "out" => artifacts
                    .last_mut()
                    .ok_or_else(|| anyhow!("`out` before `artifact` at line {lineno}"))?
                    .outputs
                    .push(parse_io(rest)?),
                other => bail!("unknown manifest entry {other:?} at line {lineno}"),
            }
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostValue {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostValue::F32(v) => v,
            _ => panic!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostValue::I32(v) => v,
            _ => panic!("expected i32 value"),
        }
    }

    pub fn scalar_f32(&self) -> f32 {
        self.as_f32()[0]
    }

    pub fn len(&self) -> usize {
        match self {
            HostValue::F32(v) => v.len(),
            HostValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl LoadedArtifact {
    fn literal_for(spec: &IoSpec, v: &HostValue) -> Result<xla::Literal> {
        if v.len() != spec.elements() {
            bail!(
                "input {}: expected {} elements, got {}",
                spec.name,
                spec.elements(),
                v.len()
            );
        }
        let lit = match (spec.dtype, v) {
            (Dtype::F32, HostValue::F32(data)) => xla::Literal::vec1(data),
            (Dtype::I32, HostValue::I32(data)) => xla::Literal::vec1(data),
            _ => bail!("input {}: dtype mismatch", spec.name),
        };
        if spec.dims.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Execute with inputs in manifest order; returns outputs in manifest
    /// order as host values.
    pub fn exec(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let lits: Vec<xla::Literal> = self
            .spec
            .inputs
            .iter()
            .zip(inputs)
            .map(|(s, v)| Self::literal_for(s, v))
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: artifact returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        self.spec
            .outputs
            .iter()
            .zip(parts)
            .map(|(s, lit)| {
                Ok(match s.dtype {
                    Dtype::F32 => HostValue::F32(lit.to_vec::<f32>()?),
                    Dtype::I32 => HostValue::I32(lit.to_vec::<i32>()?),
                })
            })
            .collect()
    }
}

/// The runtime: one PJRT CPU client + compiled artifacts by name.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    loaded: HashMap<String, LoadedArtifact>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a client over the artifact directory (no compilation yet).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { manifest, client, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return an artifact.
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.loaded.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.manifest.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.loaded.insert(name.to_string(), LoadedArtifact { spec, exe });
        }
        Ok(&self.loaded[name])
    }

    /// Convenience: load + exec.
    pub fn exec(&mut self, name: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        self.load(name)?.exec(inputs)
    }
}

// ------------------------------------------------------------- pjrt stubs
//
// Same API surface as the real runtime, but the constructor fails, so every
// consumer takes its "no artifacts" skip path. Keeps `cargo build` working
// in images without the xla_extension crate.

/// Stub compiled artifact (never constructed — `Runtime::new` fails first).
#[cfg(not(feature = "pjrt"))]
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
}

#[cfg(not(feature = "pjrt"))]
impl LoadedArtifact {
    /// Execute with inputs in manifest order (stub: always fails).
    pub fn exec(&self, _inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        bail!("PJRT runtime not built: rebuild with `--features pjrt` (needs the xla_extension crate; DESIGN.md §6)")
    }
}

/// Stub runtime (see module docs).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub constructor: always fails with an actionable message.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        bail!("PJRT runtime not built: rebuild with `--features pjrt` (needs the xla_extension crate; DESIGN.md §6)")
    }

    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }

    /// Stub load (unreachable in practice — `new` fails first).
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        bail!("PJRT runtime not built (artifact {name:?}): rebuild with `--features pjrt`")
    }

    /// Stub exec (unreachable in practice — `new` fails first).
    pub fn exec(&mut self, name: &str, _inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        bail!("PJRT runtime not built (artifact {name:?}): rebuild with `--features pjrt`")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("apt_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact foo foo.hlo.txt\nin x f32 2,3\nin n i32 scalar\nout y f32 6\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("foo").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![2, 3]);
        assert_eq!(a.inputs[0].elements(), 6);
        assert_eq!(a.inputs[1].dims, Vec::<usize>::new());
        assert_eq!(a.inputs[1].elements(), 1);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.outputs[0].name, "y");
        assert_eq!(a.input_index("n"), Some(1));
        assert_eq!(a.output_index("nope"), None);
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = std::env::temp_dir().join("apt_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "in x f32 2 before artifact\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn host_value_accessors() {
        let v = HostValue::F32(vec![1.0, 2.0]);
        assert_eq!(v.len(), 2);
        assert_eq!(v.scalar_f32(), 1.0);
        let i = HostValue::I32(vec![7]);
        assert_eq!(i.as_i32(), &[7]);
    }

    // PJRT execution round-trips are exercised by rust/tests/test_runtime.rs
    // (integration), which requires `make artifacts` to have run.
}
