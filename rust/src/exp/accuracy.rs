//! Table 1 (classification / detection / segmentation accuracy) and
//! Table 2 (method comparison).

use crate::data::{SynthDetection, SynthSegmentation};
use crate::exp::common::{adaptive_mode, grad_mix_string};
use crate::nn::models::{DetectionNet, SegNet};
use crate::nn::{QuantMode, TrainCtx};
use crate::train::{Seq2SeqBackend, Session, SessionBuilder};
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv};
use crate::util::Pcg32;

/// Table 1: float32 vs adaptive on every task family.
pub fn table1(args: &Args) {
    let iters = args.u64_or("iters", 300);
    println!("== Table 1: accuracy, float32 vs Adaptive Precision (iters {iters}) ==");
    println!("W and X pinned int8; activation gradients adaptive.\n");
    let mut csv = Csv::new(
        results_dir().join("table1.csv"),
        &["task", "network", "float32", "adaptive", "delta", "grad_mix"],
    );

    println!("{:<12} {:<11} {:>8} {:>9} {:>7}   gradient bits", "task", "network", "float32", "adaptive", "Δ");
    for name in crate::nn::models::ZOO {
        let f32_run = SessionBuilder::classifier(name).lr(0.01).noise(1.5).train(iters);
        let q_run = SessionBuilder::classifier(name)
            .lr(0.01)
            .noise(1.5)
            .mode(adaptive_mode(iters))
            .train(iters);
        let mix = grad_mix_string(&q_run.ledger);
        println!(
            "{:<12} {:<11} {:>8.3} {:>9.3} {:>+7.3}   {}",
            "classify", name, f32_run.eval_acc, q_run.eval_acc,
            q_run.eval_acc - f32_run.eval_acc, mix
        );
        csv.row(&[
            "classification".into(),
            name.to_string(),
            format!("{:.4}", f32_run.eval_acc),
            format!("{:.4}", q_run.eval_acc),
            format!("{:.4}", q_run.eval_acc - f32_run.eval_acc),
            mix,
        ]);
    }

    // detection
    for (label, mode) in [("float32", QuantMode::Float32), ("adaptive", adaptive_mode(iters))] {
        let mut rng = Pcg32::seeded(7);
        let mut net = DetectionNet::new(3, mode, &mut rng);
        let mut data = SynthDetection::new(5, 3, 3, 16, 16);
        let mut ctx = TrainCtx::new();
        for it in 0..iters {
            ctx.iter = it;
            let (x, boxes, classes) = data.batch(16);
            net.train_step(&x, &boxes, &classes, 0.05, &mut ctx);
        }
        ctx.ledger.set_total_iters(iters);
        let (x, boxes, classes) = data.batch(128);
        let map = net.map_lite(&x, &boxes, &classes, &mut ctx);
        let mix = grad_mix_string(&ctx.ledger);
        println!("{:<12} {:<11} {:>8} {:>9.3} {:>7}   {}", "detect", format!("ssd-{label}"),
            if label == "float32" { format!("{map:.3}") } else { "-".into() },
            map, "", if label == "adaptive" { mix.clone() } else { String::new() });
        csv.row(&["detection".into(), format!("ssd_lite-{label}"), String::new(), format!("{map:.4}"), String::new(), mix]);
    }

    // segmentation
    for (label, mode) in [("float32", QuantMode::Float32), ("adaptive", adaptive_mode(iters))] {
        let mut rng = Pcg32::seeded(8);
        let mut net = SegNet::new(3, mode, &mut rng);
        let mut data = SynthSegmentation::new(6, 3, 3, 12, 12);
        let mut ctx = TrainCtx::new();
        for it in 0..iters {
            ctx.iter = it;
            let (x, labels) = data.batch(8);
            net.train_step(&x, &labels, &mut ctx);
        }
        ctx.ledger.set_total_iters(iters);
        let (x, labels) = data.batch(64);
        let miou = net.eval_miou(&x, &labels, &mut ctx);
        let mix = grad_mix_string(&ctx.ledger);
        println!("{:<12} {:<11} {:>8} {:>9.3} {:>7}   {}", "segment", format!("seg-{label}"), "", miou, "", if label == "adaptive" { mix.clone() } else { String::new() });
        csv.row(&["segmentation".into(), format!("seg_lite-{label}"), String::new(), format!("{miou:.4}"), String::new(), mix]);
    }
    csv.write().unwrap();
    println!("\npaper shape: adaptive ≈ float32 (|Δ| small); most gradients int16,\nsome int8; W/X always int8");
}

/// Table 2: comparison against the re-implemented baselines.
pub fn table2(args: &Args) {
    let iters = args.u64_or("iters", 300);
    println!("== Table 2: method comparison (CNN = resnet-mini, RNN = seq2seq) ==");
    println!(
        "{:<22} {:<18} {:>9} {:>9}",
        "method", "backward format", "CNN acc", "RNN acc"
    );
    let mut csv = Csv::new(
        results_dir().join("table2.csv"),
        &["method", "backward", "cnn_acc", "rnn_acc"],
    );

    let rnn_eval = |mode: QuantMode| -> f64 {
        let mut s = Session::with_backend(Seq2SeqBackend::new(
            "seq2seq", 12, 32, mode, 3, 16, 4, 0.05, 64,
        ));
        s.run(iters.max(400)).expect("rnn training cannot fail");
        s.record().expect("rnn eval cannot fail").eval_acc
    };

    let methods: Vec<(&str, &str, QuantMode)> = vec![
        ("float32 baseline", "float32", QuantMode::Float32),
        ("WAGE-like [36]", "int8 unified", QuantMode::Static(8)),
        ("int16 unified [7]", "int16 unified", QuantMode::Static(16)),
        ("Adaptive Precision", "int8~24 adaptive", adaptive_mode(iters)),
    ];
    for (name, backward, mode) in methods {
        let cnn = SessionBuilder::classifier("resnet")
            .lr(0.01)
            .noise(1.5)
            .mode(mode)
            .train(iters)
            .eval_acc;
        let rnn = rnn_eval(mode);
        println!("{:<22} {:<18} {:>9.3} {:>9.3}", name, backward, cnn, rnn);
        csv.row(&[name.into(), backward.into(), format!("{cnn:.4}"), format!("{rnn:.4}")]);
    }
    csv.write().unwrap();
    println!("\npaper shape: int8-unified degrades (esp. RNN); int16 close on CNN but\nloses on RNN; adaptive matches float32 on both");
}
