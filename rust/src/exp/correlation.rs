//! Fig 5 / Fig 6 — correlation between the QEM metrics M1..M4 and
//! network accuracy under single-layer deployment quantization.
//!
//! Protocol (paper §5.1): train a model, then for each weight tensor and
//! each bit-width in {6, 8}, quantize only that tensor, run the forward
//! pass on a held-out set, and record (metric value, accuracy). The paper's
//! claim: M1 (mean-change) has the highest Pearson R².
//!
//! Parameter surgery goes through the stable `ParamId` addresses of
//! `train::Session` (DESIGN.md §Session-API) instead of the old raw
//! visit-order indices.

use crate::apt::qem;
use crate::data::SynthImages;
use crate::fixedpoint::quantize::{fake_quant_stats_inplace, max_abs};
use crate::fixedpoint::Scheme;
use crate::nn::loss::accuracy;
use crate::nn::models;
use crate::train::SessionBuilder;
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv};
use crate::util::stats::pearson_r2;

pub fn run(model: &str, figure: &str, args: &Args) {
    let iters = args.u64_or("iters", 250);
    println!("== {figure}: metric↔accuracy correlation on {model}(-mini) ==");
    let mut session = SessionBuilder::classifier(model).lr(0.01).build();
    session.run(iters).expect("host training cannot fail");
    let eval_acc = session.eval().expect("host eval cannot fail").accuracy;
    println!("trained float32 baseline: eval acc {eval_acc:.3}");

    // Probe set: template-identical to the training data (session seed 0 +
    // 1000) but drawn from the held-out stream 999 — the same set
    // `session.eval()` scores, so the sweep's unperturbed point equals the
    // baseline accuracy above. (The pre-Session driver built a seed-1001
    // dataset here, silently probing against different class templates.)
    let data = SynthImages::new(
        1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let (ex, ey) = data.eval_set(999, 256);

    let weights = session.weight_params();
    let mut series: Vec<[f64; 4]> = Vec::new();
    let mut accs: Vec<f64> = Vec::new();
    let mut csv = Csv::new(
        results_dir().join(format!("{}_points.csv", figure.to_lowercase())),
        &["param", "bits", "m1", "m2", "m3", "m4", "acc"],
    );
    for info in &weights {
        let w = session.param_copy(&info.id);
        for bits in [6u8, 8] {
            let sch = Scheme::for_range(max_abs(&w.data), bits);
            let ms = qem::all_metrics(&w.data, sch);
            let acc = session.with_param_replaced(
                &info.id,
                |p| {
                    fake_quant_stats_inplace(&mut p.data, sch);
                },
                |s| {
                    let logits = s.eval_logits(&ex);
                    accuracy(&logits, &ey)
                },
            );
            csv.row(&[
                info.id.to_string(),
                bits.to_string(),
                format!("{:.6}", ms[0]),
                format!("{:.6}", ms[1]),
                format!("{:.6}", ms[2]),
                format!("{:.6}", ms[3]),
                format!("{acc:.4}"),
            ]);
            series.push(ms);
            accs.push(acc);
        }
    }
    csv.write().unwrap();

    println!("\n{:<8} {:>8}   (paper: M1 highest, ~0.84–0.85)", "metric", "R²");
    let mut best = ("", 0.0f64);
    for (i, name) in ["M1", "M2", "M3", "M4"].iter().enumerate() {
        let vals: Vec<f64> = series.iter().map(|m| m[i]).collect();
        let r2 = pearson_r2(&vals, &accs);
        if r2 > best.1 {
            best = (name, r2);
        }
        println!("{:<8} {:>8.3}{}", name, r2, if *name == "M1" { "  ← paper's metric" } else { "" });
    }
    println!("highest: {} ({:.3})", best.0, best.1);
}

pub fn fig5(args: &Args) {
    run("mobilenet", "Fig5", args);
}

pub fn fig6(args: &Args) {
    run("resnet", "Fig6", args);
}
