//! Shared reporting helpers for the experiment drivers. Training itself
//! goes through [`crate::train::SessionBuilder`] (DESIGN.md §Session-API)
//! and convergence summaries through
//! [`crate::train::TrainRecord::tail_loss`]; what remains here is
//! presentation: bit-mix strings and adaptive-config shorthands.

use crate::apt::{AptConfig, Ledger};
use crate::fixedpoint::TensorKind;
use crate::nn::QuantMode;

/// Format a ledger's gradient bit mix like the paper's Table 1 columns.
///
/// Only *compute* gradients count: data-parallel runs merge their
/// gradient-communication controllers into the ledger under `comm:*` keys
/// (DESIGN.md §Data-Parallel) and adaptive activation storage records its
/// decisions under `stash:*` keys (DESIGN.md §Activation-Memory); both are
/// reported separately by the CLI — including either here would skew the
/// Table-1-style number.
pub fn grad_mix_string(ledger: &Ledger) -> String {
    let mix = ledger.timewise_bits_mix_where(TensorKind::Gradient, |name| {
        !name.starts_with("comm:") && !name.starts_with("stash:")
    });
    let pct = |b: u8| mix.get(&b).copied().unwrap_or(0.0) * 100.0;
    format!(
        "int8 {:5.1}% | int16 {:5.1}% | int24 {:5.1}%",
        pct(8),
        pct(16),
        pct(24)
    )
}

/// Format the adaptive activation-*storage* bit mix — the `stash:*`
/// entries only (activation kind), grouped apart from the compute and
/// `comm:*` records so each subsystem's Table-1-style number stays pure.
/// Buckets follow the stash's payload encodings: ≤8 bits are int8 codes,
/// 9–16 are int16 codes, wider widths mean exact f32 fallback storage —
/// so the three columns always sum to 100%.
pub fn stash_mix_string(ledger: &Ledger) -> String {
    let mix = ledger
        .timewise_bits_mix_where(TensorKind::Activation, |name| name.starts_with("stash:"));
    let bucket = |lo: u8, hi: u8| -> f64 {
        mix.iter()
            .filter(|(&b, _)| b >= lo && b <= hi)
            .map(|(_, &w)| w)
            .sum::<f64>()
            * 100.0
    };
    format!(
        "int8 {:5.1}% | int16 {:5.1}% | f32 {:5.1}%",
        bucket(0, 8),
        bucket(9, 16),
        bucket(17, u8::MAX)
    )
}

/// The paper's adaptive mode with the init phase sized to a run length
/// ("one-tenth of the first epoch").
pub fn adaptive_mode(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apt::ledger::Event;

    #[test]
    fn grad_mix_formats_percentages() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        l.record_event(
            "a",
            TensorKind::Gradient,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
        );
        l.record_event(
            "a",
            TensorKind::Gradient,
            Event { iter: 50, bits: 16, interval: 1, error: 0.0 },
        );
        let s = grad_mix_string(&l);
        assert!(s.contains("int8  50.0%"), "{s}");
        assert!(s.contains("int16  50.0%"), "{s}");
    }

    #[test]
    fn mix_strings_group_subsystems_apart() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        l.record_event(
            "conv0",
            TensorKind::Gradient,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
        );
        // comm and stash records must not leak into the compute mix…
        l.record_event(
            "comm:fc0.0",
            TensorKind::Gradient,
            Event { iter: 0, bits: 16, interval: 1, error: 0.0 },
        );
        l.record_event(
            "stash:conv0/patches",
            TensorKind::Activation,
            Event { iter: 0, bits: 16, interval: 1, error: 0.0 },
        );
        let g = grad_mix_string(&l);
        assert!(g.contains("int8 100.0%"), "{g}");
        assert!(g.contains("int16   0.0%"), "{g}");
        // …and the stash mix counts only stash:* activation records
        let s = stash_mix_string(&l);
        assert!(s.contains("int16 100.0%"), "{s}");
        assert!(s.contains("int8   0.0%"), "{s}");
    }

    #[test]
    fn stash_mix_reports_wide_widths_as_f32() {
        let mut l = Ledger::new();
        l.set_total_iters(10);
        l.record_event(
            "stash:fc0/x",
            TensorKind::Activation,
            Event { iter: 0, bits: 24, interval: 1, error: 0.0 },
        );
        let s = stash_mix_string(&l);
        assert!(s.contains("f32 100.0%"), "{s}");
    }

    #[test]
    fn adaptive_mode_sizes_init_phase() {
        match adaptive_mode(500) {
            QuantMode::Adaptive(cfg) => assert_eq!(cfg.init_phase_iters, 50),
            other => panic!("unexpected mode {other:?}"),
        }
    }
}
