//! Shared reporting helpers for the experiment drivers. Training itself
//! goes through [`crate::train::SessionBuilder`] (DESIGN.md §Session-API)
//! and convergence summaries through
//! [`crate::train::TrainRecord::tail_loss`]; what remains here is
//! presentation: bit-mix strings and adaptive-config shorthands.

use crate::apt::{AptConfig, Ledger};
use crate::fixedpoint::TensorKind;
use crate::nn::QuantMode;

/// Format a ledger's gradient bit mix like the paper's Table 1 columns.
///
/// Only *compute* gradients count: data-parallel runs merge their
/// gradient-communication controllers into the ledger under `comm:*` keys
/// (DESIGN.md §Data-Parallel), and those are reported separately by the
/// CLI — including them here would skew the Table-1-style number.
pub fn grad_mix_string(ledger: &Ledger) -> String {
    let mut compute = ledger.clone();
    compute.tensors.retain(|(name, _), _| !name.starts_with("comm:"));
    let mix = compute.timewise_bits_mix(TensorKind::Gradient);
    let pct = |b: u8| mix.get(&b).copied().unwrap_or(0.0) * 100.0;
    format!(
        "int8 {:5.1}% | int16 {:5.1}% | int24 {:5.1}%",
        pct(8),
        pct(16),
        pct(24)
    )
}

/// The paper's adaptive mode with the init phase sized to a run length
/// ("one-tenth of the first epoch").
pub fn adaptive_mode(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apt::ledger::Event;

    #[test]
    fn grad_mix_formats_percentages() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        l.record_event(
            "a",
            TensorKind::Gradient,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
        );
        l.record_event(
            "a",
            TensorKind::Gradient,
            Event { iter: 50, bits: 16, interval: 1, error: 0.0 },
        );
        let s = grad_mix_string(&l);
        assert!(s.contains("int8  50.0%"), "{s}");
        assert!(s.contains("int16  50.0%"), "{s}");
    }

    #[test]
    fn adaptive_mode_sizes_init_phase() {
        match adaptive_mode(500) {
            QuantMode::Adaptive(cfg) => assert_eq!(cfg.init_phase_iters, 50),
            other => panic!("unexpected mode {other:?}"),
        }
    }
}
