//! Shared machinery for the experiment drivers: a classification training
//! loop with loss/accuracy curves, gradient probes, and bit-mix reporting.

use crate::apt::Ledger;
use crate::data::SynthImages;
use crate::fixedpoint::TensorKind;
use crate::nn::loss::{accuracy, softmax_xent};
use crate::nn::models;
use crate::nn::{QuantMode, Sequential, Sgd, TrainCtx};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// One finished training run.
pub struct TrainRun {
    pub label: String,
    pub losses: Vec<f32>,
    pub eval_acc: f64,
    pub ledger: Ledger,
    pub net: Sequential,
}

/// Options for [`train_classifier`].
#[derive(Clone)]
pub struct TrainOpts {
    pub model: String,
    pub mode: QuantMode,
    pub iters: u64,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
    pub noise: f32,
    /// (layer, bits) gradient overrides applied before training.
    pub grad_overrides: Vec<(String, u8)>,
    /// Callback invoked after each backward with (iter, net).
    pub probe_every: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            model: "alexnet".into(),
            mode: QuantMode::Float32,
            iters: 150,
            batch: 16,
            lr: 0.02,
            seed: 0,
            noise: 0.5,
            grad_overrides: vec![],
            probe_every: 0,
        }
    }
}

/// Train a zoo classifier on synthetic images; optionally call `probe`
/// after backward every `probe_every` iterations.
pub fn train_classifier(
    opts: &TrainOpts,
    mut probe: Option<&mut dyn FnMut(u64, &Sequential)>,
) -> TrainRun {
    let mut rng = Pcg32::seeded(opts.seed);
    let mut net = models::by_name(&opts.model, opts.mode, &mut rng)
        .unwrap_or_else(|| panic!("unknown model {:?}", opts.model));
    for (layer, bits) in &opts.grad_overrides {
        assert!(
            net.set_grad_override(layer, Some(*bits)),
            "no layer {layer:?} in {}",
            opts.model
        );
    }
    let mut data = SynthImages::new(
        opts.seed + 1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        opts.noise,
    );
    let mut opt = Sgd::new(opts.lr, 0.9);
    let mut ctx = TrainCtx::new();
    let mut losses = Vec::with_capacity(opts.iters as usize);
    for it in 0..opts.iters {
        ctx.iter = it;
        let (x, y) = data.batch(opts.batch);
        let logits = net.forward(&x, &mut ctx);
        let (l, g) = softmax_xent(&logits, &y);
        net.backward(&g, &mut ctx);
        if opts.probe_every > 0 && it % opts.probe_every == 0 {
            if let Some(p) = probe.as_mut() {
                p(it, &net);
            }
        }
        opt.step(&mut net);
        losses.push(l);
    }
    ctx.ledger.set_total_iters(opts.iters);
    // held-out accuracy (quantized forward — deployment-int8 semantics)
    ctx.training = false;
    let (ex, ey) = data.eval_set(999, 256);
    let logits = net.forward(&ex, &mut ctx);
    let eval_acc = accuracy(&logits, &ey);
    TrainRun {
        label: format!("{}-{}", opts.model, opts.mode.label()),
        losses,
        eval_acc,
        ledger: std::mem::take(&mut ctx.ledger),
        net,
    }
}

/// Format a ledger's gradient bit mix like the paper's Table 1 columns.
pub fn grad_mix_string(ledger: &Ledger) -> String {
    let mix = ledger.timewise_bits_mix(TensorKind::Gradient);
    let pct = |b: u8| mix.get(&b).copied().unwrap_or(0.0) * 100.0;
    format!(
        "int8 {:5.1}% | int16 {:5.1}% | int24 {:5.1}%",
        pct(8),
        pct(16),
        pct(24)
    )
}

/// Mean of the last k losses (convergence summary).
pub fn tail_loss(losses: &[f32], k: usize) -> f64 {
    let k = k.min(losses.len()).max(1);
    losses[losses.len() - k..].iter().map(|&x| x as f64).sum::<f64>() / k as f64
}

/// Quantize one weight tensor of a trained net in place at `bits` and return
/// (undo snapshot, the raw data copy) — used by the Fig 5/6 single-layer
/// deployment-quantization sweep. Weight tensors are the 2-D params in
/// visit order.
pub fn weight_tensors(net: &mut Sequential) -> Vec<usize> {
    let mut idx = Vec::new();
    let mut i = 0usize;
    net.visit_params(&mut |p, _| {
        if p.rank() == 2 {
            idx.push(i);
        }
        i += 1;
    });
    idx
}

/// Run `f` with the i-th parameter (visit order) temporarily replaced by a
/// transformed copy.
pub fn with_param_replaced<R>(
    net: &mut Sequential,
    param_idx: usize,
    transform: impl Fn(&mut Tensor),
    f: impl FnOnce(&mut Sequential) -> R,
) -> R {
    let mut snapshot: Option<Tensor> = None;
    let mut i = 0usize;
    net.visit_params(&mut |p, _| {
        if i == param_idx {
            snapshot = Some(p.clone());
            transform(p);
        }
        i += 1;
    });
    let out = f(net);
    let mut i = 0usize;
    net.visit_params(&mut |p, _| {
        if i == param_idx {
            *p = snapshot.take().unwrap();
        }
        i += 1;
    });
    out
}

/// Read the i-th parameter (visit order).
pub fn param_copy(net: &mut Sequential, param_idx: usize) -> Tensor {
    let mut out = None;
    let mut i = 0usize;
    net.visit_params(&mut |p, _| {
        if i == param_idx {
            out = Some(p.clone());
        }
        i += 1;
    });
    out.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifier_trains_and_reports() {
        let opts = TrainOpts { iters: 30, model: "mlp".into(), ..Default::default() };
        let run = train_classifier(&opts, None);
        assert_eq!(run.losses.len(), 30);
        assert!(run.eval_acc > 0.15, "acc={}", run.eval_acc); // better than chance
    }

    #[test]
    fn probe_fires() {
        let opts = TrainOpts {
            iters: 10,
            model: "mlp".into(),
            probe_every: 2,
            ..Default::default()
        };
        let mut count = 0;
        let mut probe = |_it: u64, _n: &Sequential| count += 1;
        let _ = train_classifier(&opts, Some(&mut probe));
        assert_eq!(count, 5);
    }

    #[test]
    fn with_param_replaced_restores() {
        let mut rng = Pcg32::seeded(0);
        let mut net = models::mlp(QuantMode::Float32, &mut rng, 8, 4);
        let before = param_copy(&mut net, 0);
        with_param_replaced(&mut net, 0, |p| p.data.fill(0.0), |n| {
            assert!(param_copy(n, 0).data.iter().all(|&v| v == 0.0));
        });
        assert_eq!(param_copy(&mut net, 0), before);
    }
}
