//! Shared reporting helpers for the experiment drivers. Training itself
//! goes through [`crate::train::SessionBuilder`] (DESIGN.md §Session-API)
//! and convergence summaries through
//! [`crate::train::TrainRecord::tail_loss`]; what remains here is
//! presentation: bit-mix strings and adaptive-config shorthands.

use std::collections::BTreeMap;

use crate::apt::{AptConfig, Ledger};
use crate::fixedpoint::TensorKind;
use crate::nn::QuantMode;

/// Render a format-label mix (`int16  37.5% | e4m3  62.5%`) in a stable
/// order: fixed-point widths ascending, then the fixed-width families
/// alphabetically. Used by the mix strings once a ledger contains
/// non-fixed-point tensors — the historical three-column layout has no
/// bucket those labels fit in.
fn format_mix_line(mix: &BTreeMap<String, f64>) -> String {
    let sort_key = |label: &str| -> (u8, u32) {
        match label.strip_prefix("int").and_then(|n| n.parse::<u32>().ok()) {
            Some(n) => (0, n),
            None => (1, 0),
        }
    };
    let mut entries: Vec<(&String, f64)> = mix.iter().map(|(l, &w)| (l, w)).collect();
    entries.sort_by(|a, b| sort_key(a.0).cmp(&sort_key(b.0)).then(a.0.cmp(b.0)));
    entries
        .iter()
        .map(|(l, w)| format!("{l} {:5.1}%", w * 100.0))
        .collect::<Vec<_>>()
        .join(" | ")
}

/// Format a ledger's gradient bit mix like the paper's Table 1 columns.
///
/// Only *compute* gradients count: data-parallel runs merge their
/// gradient-communication controllers into the ledger under `comm:*` keys
/// (DESIGN.md §Data-Parallel) and adaptive activation storage records its
/// decisions under `stash:*` keys (DESIGN.md §Activation-Memory); both are
/// reported separately by the CLI — including either here would skew the
/// Table-1-style number.
///
/// Fixed-point-only ledgers keep the pinned historical
/// `int8 | int16 | int24` layout; once any gradient controller runs a
/// minifloat/int4 family the string switches to format labels (a minifloat
/// tensor's `bits` are its storage width, so bucketing it as `int8` would
/// misreport the format).
pub fn grad_mix_string(ledger: &Ledger) -> String {
    let keep = |name: &str| !name.starts_with("comm:") && !name.starts_with("stash:");
    if ledger.has_non_fixed_formats_where(TensorKind::Gradient, keep) {
        return format_mix_line(&ledger.timewise_format_mix_where(TensorKind::Gradient, keep));
    }
    let mix = ledger.timewise_bits_mix_where(TensorKind::Gradient, keep);
    let pct = |b: u8| mix.get(&b).copied().unwrap_or(0.0) * 100.0;
    format!(
        "int8 {:5.1}% | int16 {:5.1}% | int24 {:5.1}%",
        pct(8),
        pct(16),
        pct(24)
    )
}

/// Format the adaptive activation-*storage* bit mix — the `stash:*`
/// entries only (activation kind), grouped apart from the compute and
/// `comm:*` records so each subsystem's Table-1-style number stays pure.
/// Buckets follow the stash's payload encodings: ≤8 bits are int8 codes,
/// 9–16 are int16 codes, wider widths mean exact f32 fallback storage —
/// so the three columns always sum to 100%. As with
/// [`grad_mix_string`], a ledger holding non-fixed-point stash tensors
/// switches to exact format labels instead of the width buckets.
pub fn stash_mix_string(ledger: &Ledger) -> String {
    let keep = |name: &str| name.starts_with("stash:");
    if ledger.has_non_fixed_formats_where(TensorKind::Activation, keep) {
        return format_mix_line(&ledger.timewise_format_mix_where(TensorKind::Activation, keep));
    }
    let mix = ledger.timewise_bits_mix_where(TensorKind::Activation, keep);
    let bucket = |lo: u8, hi: u8| -> f64 {
        mix.iter()
            .filter(|(&b, _)| b >= lo && b <= hi)
            .map(|(_, &w)| w)
            .sum::<f64>()
            * 100.0
    };
    format!(
        "int8 {:5.1}% | int16 {:5.1}% | f32 {:5.1}%",
        bucket(0, 8),
        bucket(9, 16),
        bucket(17, u8::MAX)
    )
}

/// The paper's adaptive mode with the init phase sized to a run length
/// ("one-tenth of the first epoch").
pub fn adaptive_mode(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apt::ledger::Event;

    #[test]
    fn grad_mix_formats_percentages() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        l.record_event(
            "a",
            TensorKind::Gradient,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
        );
        l.record_event(
            "a",
            TensorKind::Gradient,
            Event { iter: 50, bits: 16, interval: 1, error: 0.0 },
        );
        let s = grad_mix_string(&l);
        assert!(s.contains("int8  50.0%"), "{s}");
        assert!(s.contains("int16  50.0%"), "{s}");
    }

    #[test]
    fn mix_strings_group_subsystems_apart() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        l.record_event(
            "conv0",
            TensorKind::Gradient,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
        );
        // comm and stash records must not leak into the compute mix…
        l.record_event(
            "comm:fc0.0",
            TensorKind::Gradient,
            Event { iter: 0, bits: 16, interval: 1, error: 0.0 },
        );
        l.record_event(
            "stash:conv0/patches",
            TensorKind::Activation,
            Event { iter: 0, bits: 16, interval: 1, error: 0.0 },
        );
        let g = grad_mix_string(&l);
        assert!(g.contains("int8 100.0%"), "{g}");
        assert!(g.contains("int16   0.0%"), "{g}");
        // …and the stash mix counts only stash:* activation records
        let s = stash_mix_string(&l);
        assert!(s.contains("int16 100.0%"), "{s}");
        assert!(s.contains("int8   0.0%"), "{s}");
    }

    #[test]
    fn stash_mix_reports_wide_widths_as_f32() {
        let mut l = Ledger::new();
        l.set_total_iters(10);
        l.record_event(
            "stash:fc0/x",
            TensorKind::Activation,
            Event { iter: 0, bits: 24, interval: 1, error: 0.0 },
        );
        let s = stash_mix_string(&l);
        assert!(s.contains("f32 100.0%"), "{s}");
    }

    #[test]
    fn mix_strings_switch_to_format_labels_for_non_fixed_families() {
        use crate::fixedpoint::FormatFamily;
        let mut l = Ledger::new();
        l.set_total_iters(100);
        // one e4m3 gradient controller alongside a fixed-point one: the
        // historical int8/int16/int24 buckets cannot express the mix, so
        // the string must switch to exact labels — and an 8-bit-wide e4m3
        // tensor must NOT be misfiled under "int8".
        l.record_event_fmt(
            "conv0",
            TensorKind::Gradient,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
            FormatFamily::E4M3,
        );
        l.record_event(
            "fc0",
            TensorKind::Gradient,
            Event { iter: 0, bits: 16, interval: 1, error: 0.0 },
        );
        let g = grad_mix_string(&l);
        assert!(g.contains("e4m3  50.0%"), "{g}");
        assert!(g.contains("int16  50.0%"), "{g}");
        assert!(!g.contains("int8"), "8-wide e4m3 misfiled as int8: {g}");
        // fixed-point widths sort ahead of the minifloat families
        assert!(g.find("int16").unwrap() < g.find("e4m3").unwrap(), "{g}");

        // same switch for the stash buckets
        l.record_event_fmt(
            "stash:conv0/patches",
            TensorKind::Activation,
            Event { iter: 0, bits: 8, interval: 1, error: 0.0 },
            FormatFamily::E5M2,
        );
        let s = stash_mix_string(&l);
        assert!(s.contains("e5m2 100.0%"), "{s}");
        assert!(!s.contains("f32"), "{s}");
    }

    #[test]
    fn adaptive_mode_sizes_init_phase() {
        match adaptive_mode(500) {
            QuantMode::Adaptive(cfg) => assert_eq!(cfg.init_phase_iters, 50),
            other => panic!("unexpected mode {other:?}"),
        }
    }
}
