//! Fig 1, Fig 2, Fig 11 — the paper's observations on gradient
//! distributions and per-layer bit-width sensitivity. Probing rides the
//! typed `Phase::AfterBackward` hooks of `train::Session`
//! (DESIGN.md §Session-API).

use crate::calib::{MinMax, Observer};
use crate::exp::common::adaptive_mode;
use crate::fixedpoint::Scheme;
use crate::nn::QuantMode;
use crate::train::{Phase, SessionBuilder, TrainRecord};
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv, Json};
use crate::util::Log2Histogram;

/// Range of one probed tensor, through the calibration [`Observer`] — the
/// same stats path `apt calibrate` runs (DESIGN.md §Calibration), so the
/// figures and the PTQ subsystem share one range estimator.
fn observed_max(data: &[f32]) -> f32 {
    let mut ob = MinMax::new();
    ob.observe(data);
    ob.calibrated_max(32)
}

fn grad_histogram(data: &[f32], bits: Option<u8>) -> Log2Histogram {
    let mut h = Log2Histogram::new(-24, 8);
    match bits {
        None => h.add_all(data),
        Some(b) => {
            let sch = Scheme::for_range(observed_max(data), b);
            for &v in data {
                h.add(sch.fake_quant(v));
            }
        }
    }
    h
}

/// One ablation run: f32 or adaptive with per-layer gradient overrides.
fn ablation_run(iters: u64, model: &str, overrides: Vec<(String, u8)>) -> TrainRecord {
    let mode = if overrides.is_empty() { QuantMode::Float32 } else { adaptive_mode(iters) };
    SessionBuilder::classifier(model)
        .lr(0.01)
        .noise(2.0)
        .mode(mode)
        .grad_overrides(overrides)
        .train(iters)
}

/// Fig 1: last-fc activation-gradient distribution under f32/int8/12/16 and
/// the training-loss consequence of quantizing just that layer.
pub fn fig1(args: &Args) {
    let iters = args.u64_or("iters", 400);
    println!("== Fig 1: AlexNet(-mini) fc1 gradient distribution & convergence ==");
    // Capture the gradient tensor of the last fc during an f32 run.
    let mut captured: Option<Vec<f32>> = None;
    let capture_at = iters / 2;
    {
        let mut s = SessionBuilder::classifier("alexnet").lr(0.01).noise(2.0).build();
        s.on(Phase::AfterBackward, 1, |info| {
            if info.iter == capture_at {
                if let Some(g) = info.net.and_then(|n| n.last_grad_of("fc1")) {
                    captured = Some(g.data.clone());
                }
            }
        });
        s.run(iters).expect("host training cannot fail");
    }
    let grad = captured.expect("no fc1 gradient captured");

    let mut csv = Csv::new(results_dir().join("fig1_hist.csv"), &["variant", "exp", "freq"]);
    for (label, bits) in [("float32", None), ("int8", Some(8)), ("int12", Some(12)), ("int16", Some(16))] {
        let h = grad_histogram(&grad, bits);
        println!("\n-- {label} (log2 |dX| histogram, fc1)");
        print!("{}", h.ascii(40));
        for (i, f) in h.freqs().iter().enumerate() {
            csv.row(&[label.to_string(), (h.min_exp + i as i32).to_string(), format!("{f:.6}")]);
        }
    }
    csv.write().unwrap();

    // Convergence curves with fc1 gradient pinned per variant (Fig 1d).
    let mut curves = Json::obj();
    println!("\n-- convergence (loss, tail mean over last 20 iters)");
    println!("{:<10} {:>10} {:>12}", "variant", "tail loss", "vs float32");
    let mut f32_tail = 0.0;
    for (label, bits) in [("float32", None), ("int8", Some(8u8)), ("int12", Some(12)), ("int16", Some(16))] {
        let overrides = bits.map(|b| vec![("fc1".to_string(), b)]).unwrap_or_default();
        let run = ablation_run(iters, "alexnet", overrides);
        let tail = run.tail_loss(20);
        if bits.is_none() {
            f32_tail = tail;
        }
        println!("{:<10} {:>10.4} {:>11.1}%", label, tail, 100.0 * (tail - f32_tail) / f32_tail.max(1e-9));
        curves.set(label, Json::arr_f32(&run.losses));
    }
    curves.write(results_dir().join("fig1_curves.json")).unwrap();
    println!("\npaper shape: int8 diverges/slow at start, int12 slower, int16 ≈ float32");
}

/// Fig 2: (a) per-layer gradient distributions, (b) max|dX| evolution,
/// (c) single-layer quantization convergence.
pub fn fig2(args: &Args) {
    let iters = args.u64_or("iters", 400);
    println!("== Fig 2: observations on AlexNet(-mini) ==");
    let layers = ["conv0", "conv1", "conv2", "fc0", "fc1"];

    // (a)+(b): probe per-layer gradients during one f32 run
    let mut maxes: Vec<(u64, Vec<f32>)> = Vec::new();
    let mut final_hists: Vec<(String, Log2Histogram)> = Vec::new();
    let capture_at = iters - 1;
    {
        let mut s = SessionBuilder::classifier("alexnet").lr(0.01).noise(2.0).build();
        s.on(Phase::AfterBackward, 1, |info| {
            let net = info.net.expect("host path exposes the net");
            let row: Vec<f32> = layers
                .iter()
                .map(|l| net.last_grad_of(l).map(|g| observed_max(&g.data)).unwrap_or(0.0))
                .collect();
            maxes.push((info.iter, row));
            if info.iter == capture_at {
                for l in layers {
                    if let Some(g) = net.last_grad_of(l) {
                        final_hists.push((l.to_string(), grad_histogram(&g.data, None)));
                    }
                }
            }
        });
        s.run(iters).expect("host training cannot fail");
    }

    println!("\n-- (b) log2 max |dX| during training (first→last sampled rows)");
    println!("{:<8} {}", "iter", layers.map(|l| format!("{l:>8}")).join(""));
    let step = (maxes.len() / 8).max(1);
    let mut csv = Csv::new(results_dir().join("fig2b_maxabs.csv"), &["iter", "layer", "log2max"]);
    for (it, row) in maxes.iter().step_by(step) {
        let cells: String = row.iter().map(|&m| format!("{:>8.1}", m.max(1e-30).log2())).collect();
        println!("{:<8} {}", it, cells);
    }
    for (it, row) in &maxes {
        for (l, &m) in layers.iter().zip(row) {
            csv.row(&[it.to_string(), l.to_string(), format!("{:.3}", m.max(1e-30).log2())]);
        }
    }
    csv.write().unwrap();
    println!("paper shape: fc layers carry larger max |dX| than bottom convs;\nrange moves fast in the first ~1/10 of training then stabilizes");

    println!("\n-- (a) per-layer |dX| distributions at the end of training");
    for (l, h) in &final_hists {
        let fc = l.starts_with("fc");
        println!("{l}: mass at 2^{:.1} (mean |dX|), zeros {:.1}%{}",
            h.coarse_mean_abs().max(1e-30).log2(),
            100.0 * h.zeros as f64 / h.total.max(1) as f64,
            if fc { "  [fc: wider]" } else { "" });
    }

    // (c): single-layer quantization convergence
    println!("\n-- (c) convergence with one layer's dX pinned");
    println!("{:<16} {:>10} {:>10}", "variant", "tail loss", "eval acc");
    let mut csv = Csv::new(results_dir().join("fig2c_convergence.csv"), &["variant", "tail_loss", "acc"]);
    let variants: Vec<(String, Vec<(String, u8)>)> = vec![
        ("float32".into(), vec![]),
        ("conv1-int8".into(), vec![("conv1".into(), 8)]),
        ("fc1-int8".into(), vec![("fc1".into(), 8)]),
        ("fc1-int12".into(), vec![("fc1".into(), 12)]),
        ("fc1-int16".into(), vec![("fc1".into(), 16)]),
    ];
    for (label, ovs) in variants {
        let run = ablation_run(iters, "alexnet", ovs);
        let tail = run.tail_loss(20);
        println!("{:<16} {:>10.4} {:>10.3}", label, tail, run.eval_acc);
        csv.row(&[label, format!("{tail:.4}"), format!("{:.4}", run.eval_acc)]);
    }
    csv.write().unwrap();
    println!("paper shape: conv1-int8 ≈ float32; fc1-int8 hurts; fc1-int16 recovers");
}

/// Fig 11 (Appendix C): same observation on ResNet(-mini).
pub fn fig11(args: &Args) {
    let iters = args.u64_or("iters", 400);
    println!("== Fig 11: observations on ResNet(-mini) ==");
    println!("{:<16} {:>10} {:>10}", "variant", "tail loss", "eval acc");
    let mut csv = Csv::new(results_dir().join("fig11.csv"), &["variant", "tail_loss", "acc"]);
    let variants: Vec<(String, Vec<(String, u8)>)> = vec![
        ("float32".into(), vec![]),
        // inner residual conv (analogue of g3b2c2): int8 is fine
        ("g1b2c2-int8".into(), vec![("g1b2c2".into(), 8)]),
        // stem conv0 and fc have large variance: int8 hurts
        ("conv0-int8".into(), vec![("conv0".into(), 8)]),
        ("fc-int8".into(), vec![("fc".into(), 8)]),
        ("fc-int16".into(), vec![("fc".into(), 16)]),
    ];
    for (label, ovs) in variants {
        let run = ablation_run(iters, "resnet", ovs);
        let tail = run.tail_loss(20);
        println!("{:<16} {:>10.4} {:>10.3}", label, tail, run.eval_acc);
        csv.row(&[label, format!("{tail:.4}"), format!("{:.4}", run.eval_acc)]);
    }
    csv.write().unwrap();
    println!("paper shape: inner-block convs tolerate int8; conv0/fc need ≥int16");
}
