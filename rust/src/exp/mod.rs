//! Experiment drivers (system S13): one entry per table/figure of the paper
//! (see the per-experiment index in DESIGN.md §5). Each prints a
//! paper-vs-measured comparison and writes CSV/JSON under `results/`.

pub mod accuracy;
pub mod common;
pub mod correlation;
pub mod observations;
pub mod overhead;
pub mod speed;
pub mod translation;

use crate::util::cli::Args;

/// All experiment ids, in suggested running order.
pub const ALL: [&str; 14] = [
    "fig1", "fig2", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
    "table1", "table2", "table3", "table5", "appxE",
];

/// Dispatch one experiment by id. Returns false for unknown ids.
pub fn run(id: &str, args: &Args) -> bool {
    match id {
        "fig1" => observations::fig1(args),
        "fig2" => observations::fig2(args),
        "fig5" => correlation::fig5(args),
        "fig6" => correlation::fig6(args),
        "fig7" => overhead::fig7(args),
        "fig8" => overhead::fig8(args),
        "fig9" => translation::fig9(args),
        "fig9a" => translation::fig9a(args),
        "fig9b" => translation::fig9b(args),
        "fig10" => speed::fig10(args),
        "fig11" => observations::fig11(args),
        "table1" => accuracy::table1(args),
        "table2" => accuracy::table2(args),
        "table3" => speed::table3(args),
        "table5" => overhead::table5(args),
        "appxE" | "appendixE" => speed::appendix_e(args),
        _ => return false,
    }
    true
}
