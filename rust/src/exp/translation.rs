//! Fig 9 — machine translation: (a) RNN seq2seq, (b) Transformer (PJRT).
//!
//! Three runs each: float32 baseline, unified int16, adaptive precision.
//! Paper shape: int16 drifts ~2% below float32 on the RNN; adaptive matches
//! float32 by escalating a few gradient tensors above int16.

use crate::coordinator::{tfm_slot_names, tokens_value, ArtifactTrainer};
use crate::data::{lm_batch, translation_batch};
use crate::nn::rnn::Seq2Seq;
use crate::nn::{QuantMode, TrainCtx};
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv, Json};
use crate::util::Pcg32;

fn adaptive(iters: u64) -> QuantMode {
    let mut cfg = crate::apt::AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

/// Fig 9a: RNN seq2seq on the reversal-translation corpus.
pub fn fig9a(args: &Args) {
    let iters = args.u64_or("iters", 600);
    let vocab = args.usize_or("vocab", 12);
    let len = args.usize_or("len", 4);
    println!("== Fig 9a: seq2seq translation (reversal corpus), {iters} iters ==");
    println!("{:<10} {:>10} {:>10}   gradient bits", "run", "word acc", "final loss");
    let mut curves = Json::obj();
    let mut csv = Csv::new(results_dir().join("fig9a.csv"), &["run", "word_acc", "loss"]);
    for (label, mode) in [
        ("float32", QuantMode::Float32),
        ("int16", QuantMode::Static(16)),
        ("adaptive", adaptive(iters)),
    ] {
        let mut rng = Pcg32::seeded(0);
        let mut m = Seq2Seq::new(vocab, 32, mode, &mut rng);
        let mut ctx = TrainCtx::new();
        let mut losses = Vec::new();
        for it in 0..iters {
            ctx.iter = it;
            let (src, tgt) = translation_batch(&mut rng, 16, len, vocab);
            let (l, _) = m.train_step(&src, &tgt, 0.05, &mut ctx);
            losses.push(l);
        }
        let (src, tgt) = translation_batch(&mut rng, 128, len, vocab);
        let (loss, acc) = m.eval(&src, &tgt, &mut ctx);
        let bits: Vec<String> = m.grad_bits().iter().map(|(n, b)| format!("{n}:int{b}")).collect();
        println!("{:<10} {:>10.3} {:>10.3}   {}", label, acc, loss, bits.join(" "));
        curves.set(label, Json::arr_f32(&losses));
        csv.row(&[label.into(), format!("{acc:.4}"), format!("{loss:.4}")]);
    }
    curves.write(results_dir().join("fig9a_curves.json")).unwrap();
    csv.write().unwrap();
    println!("paper shape: int16 below float32; adaptive ≈ float32 with some\ntensors escalated above int16");
}

/// Fig 9b: Transformer LM through the full three-layer stack (PJRT).
pub fn fig9b(args: &Args) {
    let steps = args.u64_or("steps", 40);
    let artifacts = args.str_or("artifacts", "artifacts");
    println!("== Fig 9b: Transformer (PJRT artifact), {steps} steps per run ==");
    let mut rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: {e:#} (run `make artifacts` first)");
            return;
        }
    };
    let spec = match rt.manifest.get("tfm_train_step") {
        Some(s) => s.clone(),
        None => {
            println!("SKIPPED: tfm_train_step not in manifest");
            return;
        }
    };
    // infer layers from slot count: n_q = 6·layers + 1
    let n_q = spec.inputs[spec.input_index("qparams").unwrap()].dims[0];
    let n_layers = (n_q - 1) / 6;
    let toks_spec = &spec.inputs[spec.input_index("tokens").unwrap()];
    let (batch, seq) = (toks_spec.dims[0], toks_spec.dims[1]);
    // vocab from the embed param shape
    let vocab = spec.inputs[spec.input_index("p_embed").unwrap()].dims[0];

    let mut csv = Csv::new(results_dir().join("fig9b.csv"), &["run", "step", "loss"]);
    println!("{:<10} {:>10} {:>10} {:>12}", "run", "first loss", "last loss", "grad bits mix");
    for (label, mode) in [
        ("float32", QuantMode::Float32),
        ("int16", QuantMode::Static(16)),
        ("adaptive", adaptive(steps)),
    ] {
        let mut trainer = match ArtifactTrainer::new(&rt, "tfm_train_step", tfm_slot_names(n_layers), mode, 42) {
            Ok(t) => t,
            Err(e) => {
                println!("SKIPPED {label}: {e:#}");
                continue;
            }
        };
        let mut rng = Pcg32::seeded(1);
        let mut first = 0.0f32;
        let mut last = 0.0f32;
        let mut final_bits = vec![];
        for step in 0..steps {
            let (toks, tgts) = lm_batch(&mut rng, batch, seq, vocab);
            let res = trainer
                .step(&mut rt, vec![tokens_value(&toks), tokens_value(&tgts)], 3e-3)
                .expect("artifact step failed");
            if step == 0 {
                first = res.loss;
            }
            last = res.loss;
            final_bits = res.grad_bits;
            csv.row(&[label.into(), step.to_string(), format!("{:.4}", res.loss)]);
        }
        let mut mix = std::collections::BTreeMap::new();
        for b in &final_bits {
            *mix.entry(*b).or_insert(0usize) += 1;
        }
        let mix_s: Vec<String> = mix.iter().map(|(b, c)| format!("int{b}×{c}")).collect();
        println!("{:<10} {:>10.3} {:>10.3} {:>12}", label, first, last, mix_s.join(" "));
    }
    csv.write().unwrap();
    println!("paper shape: adaptive tracks float32 (slightly better PPL in the paper)");
}

pub fn fig9(args: &Args) {
    fig9a(args);
    println!();
    fig9b(args);
}
