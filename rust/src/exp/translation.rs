//! Fig 9 — machine translation: (a) RNN seq2seq, (b) Transformer (PJRT).
//!
//! Three runs each: float32 baseline, unified int16, adaptive precision.
//! Paper shape: int16 drifts ~2% below float32 on the RNN; adaptive matches
//! float32 by escalating a few gradient tensors above int16.
//!
//! Both halves run through `train::Session` — the RNN on
//! [`Seq2SeqBackend`], the Transformer on [`PjrtBackend`] — one API over
//! the host and device paths (DESIGN.md §Session-API).

use crate::coordinator::{tfm_slot_names, tokens_value};
use crate::data::lm_batch;
use crate::exp::common::adaptive_mode;
use crate::nn::QuantMode;
use crate::runtime::Runtime;
use crate::train::{PjrtBackend, Seq2SeqBackend, Session};
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv, Json};
use crate::util::Pcg32;

/// Fig 9a: RNN seq2seq on the reversal-translation corpus.
pub fn fig9a(args: &Args) {
    let iters = args.u64_or("iters", 600);
    let vocab = args.usize_or("vocab", 12);
    let len = args.usize_or("len", 4);
    println!("== Fig 9a: seq2seq translation (reversal corpus), {iters} iters ==");
    println!("{:<10} {:>10} {:>10}   gradient bits", "run", "word acc", "final loss");
    let mut curves = Json::obj();
    let mut csv = Csv::new(results_dir().join("fig9a.csv"), &["run", "word_acc", "loss"]);
    for (label, mode) in [
        ("float32", QuantMode::Float32),
        ("int16", QuantMode::Static(16)),
        ("adaptive", adaptive_mode(iters)),
    ] {
        let mut s = Session::with_backend(Seq2SeqBackend::new(
            label, vocab, 32, mode, 0, 16, len, 0.05, 128,
        ));
        s.run(iters).expect("rnn training cannot fail");
        let run = s.record().expect("rnn eval cannot fail");
        let bits: Vec<String> =
            run.grad_bits.iter().map(|(n, b)| format!("{n}:int{b}")).collect();
        let loss = run.eval_loss.unwrap_or(f32::NAN);
        println!("{:<10} {:>10.3} {:>10.3}   {}", label, run.eval_acc, loss, bits.join(" "));
        curves.set(label, Json::arr_f32(&run.losses));
        csv.row(&[label.into(), format!("{:.4}", run.eval_acc), format!("{loss:.4}")]);
    }
    curves.write(results_dir().join("fig9a_curves.json")).unwrap();
    csv.write().unwrap();
    println!("paper shape: int16 below float32; adaptive ≈ float32 with some\ntensors escalated above int16");
}

/// Fig 9b: Transformer LM through the full three-layer stack (PJRT).
pub fn fig9b(args: &Args) {
    let steps = args.u64_or("steps", 40);
    let artifacts = args.str_or("artifacts", "artifacts");
    println!("== Fig 9b: Transformer (PJRT artifact), {steps} steps per run ==");
    let mut rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: {e:#} (run `make artifacts` first)");
            return;
        }
    };
    let spec = match rt.manifest.get("tfm_train_step") {
        Some(s) => s.clone(),
        None => {
            println!("SKIPPED: tfm_train_step not in manifest");
            return;
        }
    };
    // infer layers from slot count: n_q = 6·layers + 1
    let n_q = spec.inputs[spec.input_index("qparams").unwrap()].dims[0];
    let n_layers = (n_q - 1) / 6;
    let toks_spec = &spec.inputs[spec.input_index("tokens").unwrap()];
    let (batch, seq) = (toks_spec.dims[0], toks_spec.dims[1]);
    // vocab from the embed param shape
    let vocab = spec.inputs[spec.input_index("p_embed").unwrap()].dims[0];

    let mut csv = Csv::new(results_dir().join("fig9b.csv"), &["run", "step", "loss"]);
    println!("{:<10} {:>10} {:>10} {:>12}", "run", "first loss", "last loss", "grad bits mix");
    for (label, mode) in [
        ("float32", QuantMode::Float32),
        ("int16", QuantMode::Static(16)),
        ("adaptive", adaptive_mode(steps)),
    ] {
        let mut rng = Pcg32::seeded(1);
        let data = Box::new(move |_iter: u64| {
            let (toks, tgts) = lm_batch(&mut rng, batch, seq, vocab);
            vec![tokens_value(&toks), tokens_value(&tgts)]
        });
        let backend = match PjrtBackend::new(
            &mut rt,
            "tfm_train_step",
            tfm_slot_names(n_layers),
            mode,
            42,
            3e-3,
            label,
            data,
        ) {
            Ok(b) => b,
            Err(e) => {
                println!("SKIPPED {label}: {e:#}");
                continue;
            }
        };
        let mut s = Session::with_backend(backend);
        let mut first = 0.0f32;
        let mut last = 0.0f32;
        for step in 0..steps {
            let loss = s.step().expect("artifact step failed");
            if step == 0 {
                first = loss;
            }
            last = loss;
            csv.row(&[label.into(), step.to_string(), format!("{loss:.4}")]);
        }
        let mut mix = std::collections::BTreeMap::new();
        for (_, b) in s.grad_bits() {
            *mix.entry(b).or_insert(0usize) += 1;
        }
        let mix_s: Vec<String> = mix.iter().map(|(b, c)| format!("int{b}×{c}")).collect();
        println!("{:<10} {:>10.3} {:>10.3} {:>12}", label, first, last, mix_s.join(" "));
    }
    csv.write().unwrap();
    println!("paper shape: adaptive tracks float32 (slightly better PPL in the paper)");
}

pub fn fig9(args: &Args) {
    fig9a(args);
    println!();
    fig9b(args);
}
