//! Fig 7 / Table 5 (analytic op counts) and Fig 8 (QPA dynamics).

use crate::fixedpoint::TensorKind;
use crate::nn::QuantMode;
use crate::opcount;
use crate::train::SessionBuilder;
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv};

/// Fig 7: operation share of forward/backward quantification per model.
pub fn fig7(args: &Args) {
    let batch = args.usize_or("batch", 256);
    println!("== Fig 7: quantification operation share (batch {batch}) ==");
    println!(
        "{:<14} {:>10} {:>12} {:>10} {:>12}",
        "network", "fwd-q %", "(of fwd)", "bwd-q %", "(of bwd)"
    );
    let mut csv = Csv::new(results_dir().join("fig7.csv"), &["network", "fwd_q_pct", "bwd_q_pct", "total_share"]);
    for (name, layers) in opcount::paper_networks() {
        let c = opcount::count(&layers, batch);
        println!(
            "{:<14} {:>9.3}% {:>12} {:>9.3}% {:>12}",
            name,
            c.forward_quant_pct(),
            "",
            c.backward_quant_pct(),
            ""
        );
        csv.row(&[
            name.to_string(),
            format!("{:.4}", c.forward_quant_pct()),
            format!("{:.4}", c.backward_quant_pct()),
            format!("{:.5}", c.quant_share()),
        ]);
    }
    csv.write().unwrap();
    println!("paper shape: ≲1% everywhere except MobileNet (several %)");
}

/// Table 5 (Appendix D): absolute op counts vs the paper's numbers.
pub fn table5(args: &Args) {
    let batch = args.usize_or("batch", 256);
    println!("== Table 5: operation counts (batch {batch}) — ours vs paper ==");
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "network", "fwd", "paper", "fwdQ", "paper", "bwd", "paper", "bwdQ", "paper"
    );
    let mut csv = Csv::new(
        results_dir().join("table5.csv"),
        &["network", "fwd", "fwd_paper", "fwdq", "fwdq_paper", "bwd", "bwd_paper", "bwdq", "bwdq_paper"],
    );
    for ((name, layers), (_, paper)) in opcount::paper_networks().iter().zip(opcount::paper_table5()) {
        let c = opcount::count(layers, batch);
        let e = |x: f64| format!("{x:.2e}");
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
            name,
            e(c.forward),
            e(paper[0]),
            e(c.forward_quant),
            e(paper[1]),
            e(c.backward),
            e(paper[2]),
            e(c.backward_quant),
            e(paper[3]),
        );
        csv.row(&[
            name.to_string(),
            e(c.forward),
            e(paper[0]),
            e(c.forward_quant),
            e(paper[1]),
            e(c.backward),
            e(paper[2]),
            e(c.backward_quant),
            e(paper[3]),
        ]);
    }
    csv.write().unwrap();
    println!("note: our backward counts BPROP+WTGRAD = 2×fwd; the paper's ~3× includes\nunitemized bookkeeping (see EXPERIMENTS.md)");
}

/// Fig 8: (a) QPA trigger frequency over training; (b) int8 share of
/// gradient tensors over training, Mode1 vs Mode2.
pub fn fig8(args: &Args) {
    let iters = args.u64_or("iters", 400);
    println!("== Fig 8: QPA dynamics on VGG(-mini), {iters} iters ==");
    let buckets = 10usize;
    let mut csv = Csv::new(
        results_dir().join("fig8.csv"),
        &["mode", "bucket", "adjust_freq", "int8_share"],
    );
    for (label, cfg) in [
        ("Mode1", crate::apt::AptConfig::mode1()),
        ("Mode2", crate::apt::AptConfig::default()),
    ] {
        let mut cfg = cfg;
        cfg.init_phase_iters = iters / 10;
        let run = SessionBuilder::classifier("vgg")
            .mode(QuantMode::Adaptive(cfg))
            .train(iters);
        let freq = run.ledger.adjustment_frequency(TensorKind::Gradient, buckets);
        let share = run.ledger.bits_share_over_time(TensorKind::Gradient, 8, buckets);
        println!("\n-- {label}: acc {:.3}", run.eval_acc);
        println!("{:<8} {:>12} {:>12}", "bucket", "adjust freq", "int8 share");
        for b in 0..buckets {
            println!("{:<8} {:>11.1}% {:>11.1}%", b, freq[b] * 100.0, share[b] * 100.0);
            csv.row(&[
                label.to_string(),
                b.to_string(),
                format!("{:.4}", freq[b]),
                format!("{:.4}", share[b]),
            ]);
        }
        let total_updates = run.ledger.total_updates();
        let slots = run.ledger.tensors.len().max(1) as u64;
        println!(
            "updates: {} over {} tensors × {} iters = {:.2}% of iterations",
            total_updates,
            slots,
            iters,
            100.0 * total_updates as f64 / (slots * iters) as f64
        );
    }
    csv.write().unwrap();
    println!("\npaper shape: adjustment freq ~100% early → ≲1% late;\nMode1 keeps more tensors at int8 than Mode2");
}
