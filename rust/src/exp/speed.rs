//! Table 3 (layer-wise AlexNet speedup), Fig 10 (conv time vs scale) and
//! Appendix E (adaptive mix vs int16-everywhere) — measured on this CPU's
//! `fixedpoint::gemm` kernels. Ratios, not absolute times, are the
//! reproduction target (DESIGN.md §2).

use crate::bench::{gemm_gflops, Bencher, Sample};
use crate::fixedpoint::gemm_simd;
use crate::fixedpoint::quantize::{codes_i16, codes_i8, max_abs};
use crate::fixedpoint::Scheme;
use crate::kernels::Engine;
use crate::util::cli::Args;
use crate::util::out::{results_dir, Csv};
use crate::util::Pcg32;

/// AlexNet layers as GEMM shapes. Convs are the per-image im2col GEMM
/// (m = out_c, k = in_c/groups·k², n = oh·ow); fcs use the batch dimension.
pub fn alexnet_gemm_shapes(batch: usize) -> Vec<(&'static str, usize, usize, usize)> {
    vec![
        ("conv0", 96, 3 * 11 * 11, 55 * 55),
        ("conv1", 256, 48 * 5 * 5, 27 * 27),
        ("conv2", 384, 256 * 3 * 3, 13 * 13),
        ("conv3", 384, 192 * 3 * 3, 13 * 13),
        ("conv4", 256, 192 * 3 * 3, 13 * 13),
        ("fc0", batch, 256 * 6 * 6, 4096),
        ("fc1", batch, 4096, 4096),
        ("fc2", batch, 4096, 1000),
    ]
}

struct GemmBufs {
    a: Vec<f32>,
    b: Vec<f32>,
    a8: Vec<i8>,
    b8: Vec<i8>,
    a16: Vec<i16>,
    b16: Vec<i16>,
    acc: Vec<i32>,
    c: Vec<f32>,
}

fn make_bufs(m: usize, k: usize, n: usize, seed: u64) -> GemmBufs {
    let mut rng = Pcg32::seeded(seed);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 0.2);
    let sa = Scheme::for_range(max_abs(&a), 8);
    let sb = Scheme::for_range(max_abs(&b), 8);
    let mut a8 = vec![0i8; m * k];
    let mut b8 = vec![0i8; k * n];
    codes_i8(&a, &mut a8, sa);
    codes_i8(&b, &mut b8, sb);
    let sa16 = Scheme::for_range(max_abs(&a), 16);
    let sb16 = Scheme::for_range(max_abs(&b), 16);
    let mut a16 = vec![0i16; m * k];
    let mut b16 = vec![0i16; k * n];
    codes_i16(&a, &mut a16, sa16);
    codes_i16(&b, &mut b16, sb16);
    GemmBufs { a, b, a8, b8, a16, b16, acc: vec![0i32; m * n], c: vec![0.0f32; m * n] }
}

/// Measured per-layer speedups on the given kernel engine; returns
/// (name, fwd_speedup_i8, bwd_speedup_i16, f32/i8/i16 samples). Pass
/// `Engine::serial()` for the single-core paper comparison.
pub fn measure_layers(batch: usize, bencher: &Bencher, eng: &Engine) -> Vec<(String, f64, f64, Sample, Sample, Sample)> {
    let mut rows = Vec::new();
    for (name, m, k, n) in alexnet_gemm_shapes(batch) {
        let mut bufs = make_bufs(m, k, n, 7);
        let sf32 = {
            let (a, b) = (bufs.a.clone(), bufs.b.clone());
            let mut c = bufs.c.clone();
            bencher.run(&format!("{name}-f32"), move || {
                eng.gemm_f32(m, k, n, &a, &b, &mut c);
                std::hint::black_box(&c);
            })
        };
        // B (the weight side) is quantized straight into the packed BT
        // layout during the per-iteration quantization pass, so Table 3
        // times the GEMM itself on prepacked codes (see gemm_simd docs).
        let si8 = {
            let a = bufs.a8.clone();
            let mut bt = vec![0i8; k * n];
            let mut colsum = vec![0i32; n];
            gemm_simd::pack_bt_i8(k, n, &bufs.b8, &mut bt, &mut colsum);
            let mut acc = bufs.acc.clone();
            bencher.run(&format!("{name}-i8"), move || {
                eng.gemm_i8_prepacked(m, k, n, &a, &bt, &colsum, &mut acc);
                std::hint::black_box(&acc);
            })
        };
        let si16 = {
            let a = bufs.a16.clone();
            let mut bt = vec![0i16; k * n];
            gemm_simd::pack_bt_i16(k, n, &bufs.b16, &mut bt);
            let mut acc = std::mem::take(&mut bufs.acc);
            bencher.run(&format!("{name}-i16"), move || {
                eng.gemm_i16_prepacked(m, k, n, &a, &bt, &mut acc);
                std::hint::black_box(&acc);
            })
        };
        let fwd = sf32.median() / si8.median().max(1e-12);
        let bwd = sf32.median() / si16.median().max(1e-12);
        rows.push((name.to_string(), fwd, bwd, sf32, si8, si16));
    }
    rows
}

/// Table 3: layer-wise speedup of AlexNet, int8 forward / int16 backward.
pub fn table3(args: &Args) {
    let batch = args.usize_or("batch", 64);
    let quick = args.bool_or("quick", false);
    // threads=1 by default: the paper's Table 3 ratios are single-core;
    // pass --threads N to measure the engine-sharded kernels instead
    // (EXPERIMENTS.md §Perf).
    let eng = Engine::new(args.usize_or("threads", 1));
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    println!(
        "== Table 3: layer-wise AlexNet speedup over f32 (this CPU, {} thread(s)) ==",
        eng.threads()
    );
    println!("paper CPU rows (Xeon Gold 6154 AVX2): fwd 2.0–6.4×, bwd 1.7–5.0×, overall fwd 3.98 / bwd 2.07");
    println!(
        "\n{:<8} {:>14} {:>14} {:>12} {:>12}",
        "layer", "fwd i8 (ours)", "paper fwd", "bwd i16", "paper bwd"
    );
    let paper_fwd = [2.03, 3.89, 6.2, 4.44, 4.28, 4.09, 6.42, 4.41];
    let paper_bwd = [1.91, 1.71, 1.78, 2.21, 2.07, 4.41, 4.97, 2.03];
    let rows = measure_layers(batch, &bencher, &eng);
    let mut csv = Csv::new(
        results_dir().join("table3.csv"),
        &["layer", "fwd_speedup", "paper_fwd", "bwd_speedup", "paper_bwd", "f32_ms", "i8_ms", "i16_ms", "f32_gflops"],
    );
    let (mut f32_tot, mut i8_tot, mut i16_tot) = (0.0, 0.0, 0.0);
    for (i, (name, fwd, bwd, sf, s8, s16)) in rows.iter().enumerate() {
        println!(
            "{:<8} {:>13.2}x {:>13.2}x {:>11.2}x {:>11.2}x",
            name, fwd, paper_fwd[i], bwd, paper_bwd[i]
        );
        let (m, k, n) = {
            let (_, m, k, n) = alexnet_gemm_shapes(batch)[i];
            (m, k, n)
        };
        csv.row(&[
            name.clone(),
            format!("{fwd:.3}"),
            format!("{:.2}", paper_fwd[i]),
            format!("{bwd:.3}"),
            format!("{:.2}", paper_bwd[i]),
            format!("{:.4}", sf.median() * 1e3),
            format!("{:.4}", s8.median() * 1e3),
            format!("{:.4}", s16.median() * 1e3),
            format!("{:.2}", gemm_gflops(m, k, n, sf.median())),
        ]);
        f32_tot += sf.median();
        i8_tot += s8.median();
        i16_tot += s16.median();
    }
    println!(
        "{:<8} {:>13.2}x {:>13} {:>11.2}x {:>11}",
        "Overall",
        f32_tot / i8_tot,
        "3.98x",
        f32_tot / i16_tot,
        "2.07x"
    );
    csv.write().unwrap();
    println!("\npaper shape target: int8 fwd and int16 bwd both beat f32 on every layer;\nabsolute factors depend on SIMD width (AVX-512 there, autovec here)");
}

/// Fig 10: computation time vs operation count for conv-scale GEMMs,
/// fixed-point vs float, with the QEM/QPA overhead shown separately.
pub fn fig10(args: &Args) {
    let quick = args.bool_or("quick", true);
    // Bind a reference: the bench closures are `move`, and a shared `&Engine`
    // is Copy, so every closure can capture it without consuming the engine.
    let eng = &Engine::new(args.usize_or("threads", 1));
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    println!(
        "== Fig 10: conv-scale computation time, fixed vs float ({} thread(s)) ==",
        eng.threads()
    );
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "ops", "f32 ms", "i8 ms", "quant ms", "QEM+QPA ms", "speedup"
    );
    let mut csv = Csv::new(
        results_dir().join("fig10.csv"),
        &["ops", "f32_ms", "i8_ms", "quant_ms", "qemqpa_ms", "speedup"],
    );
    // square-ish GEMMs of growing op count
    for &dim in &[64usize, 96, 128, 192, 256, 384] {
        let (m, k, n) = (dim, dim, dim);
        let bufs = make_bufs(m, k, n, 9);
        let sf32 = {
            let (a, b) = (bufs.a.clone(), bufs.b.clone());
            let mut c = bufs.c.clone();
            bencher.run("f32", move || {
                eng.gemm_f32(m, k, n, &a, &b, &mut c);
                std::hint::black_box(&c);
            })
        };
        let si8 = {
            let (a, b) = (bufs.a8.clone(), bufs.b8.clone());
            let mut acc = bufs.acc.clone();
            bencher.run("i8", move || {
                eng.gemm_i8(m, k, n, &a, &b, &mut acc);
                std::hint::black_box(&acc);
            })
        };
        // quantification cost: f32 → codes for both operands, through the
        // same engine as the GEMMs so the speedup column stays consistent
        // at --threads > 1 (the training path shards these passes too).
        let squant = {
            let (a, b) = (bufs.a.clone(), bufs.b.clone());
            let mut a8 = bufs.a8.clone();
            let mut b8 = bufs.b8.clone();
            bencher.run("quant", move || {
                let sa = Scheme::for_range(max_abs(&a), 8);
                let sb = Scheme::for_range(max_abs(&b), 8);
                eng.codes_i8(&a, &mut a8, sa);
                eng.codes_i8(&b, &mut b8, sb);
                std::hint::black_box((&a8, &b8));
            })
        };
        // QEM+QPA cost: the stats pass + the decision
        let sqem = {
            let a = bufs.a.clone();
            bencher.run("qem", move || {
                let sch = Scheme::for_range(max_abs(&a), 8);
                let st = crate::fixedpoint::quantize::stats_only(&a, sch);
                std::hint::black_box(st.diff());
            })
        };
        let ops = 2 * m * k * n;
        println!(
            "{:<12} {:>10.3} {:>10.3} {:>10.3} {:>12.3} {:>9.2}x",
            format!("{:.1e}", ops as f64),
            sf32.median() * 1e3,
            si8.median() * 1e3,
            squant.median() * 1e3,
            sqem.median() * 1e3,
            sf32.median() / (si8.median() + squant.median())
        );
        csv.row(&[
            ops.to_string(),
            format!("{:.5}", sf32.median() * 1e3),
            format!("{:.5}", si8.median() * 1e3),
            format!("{:.5}", squant.median() * 1e3),
            format!("{:.5}", sqem.median() * 1e3),
            format!("{:.3}", sf32.median() / (si8.median() + squant.median())),
        ]);
    }
    csv.write().unwrap();
    println!("\npaper shape: fixed-point below float at every scale; QEM/QPA extra\ntime small relative to the GEMM, shrinking with scale");
}

/// Appendix E: adaptive mix (int8 fwd + int16 bwd) vs int16-everywhere.
pub fn appendix_e(args: &Args) {
    let batch = args.usize_or("batch", 64);
    let quick = args.bool_or("quick", true);
    let eng = Engine::new(args.usize_or("threads", 1));
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== Appendix E: speedup of the adaptive mix over int16-everywhere ==");
    let rows = measure_layers(batch, &bencher, &eng);
    // forward in int8 vs forward in int16; backward identical (int16): the
    // paper reports 1.7× fwd, 1.13× bwd-inclusive, 1.3× overall.
    let (mut i8f, mut i16f) = (0.0, 0.0);
    for (_, _, _, _, s8, s16) in &rows {
        i8f += s8.median();
        i16f += s16.median();
    }
    let fwd = i16f / i8f;
    // total: fwd(int8) + 2×bwd(int16)  vs  fwd(int16) + 2×bwd(int16)
    let overall = (i16f + 2.0 * i16f) / (i8f + 2.0 * i16f);
    println!("forward: {fwd:.2}x (paper 1.7x)   overall: {overall:.2}x (paper 1.3x)");
    let mut csv = Csv::new(results_dir().join("appendix_e.csv"), &["fwd_speedup", "overall_speedup"]);
    csv.row(&[format!("{fwd:.3}"), format!("{overall:.3}")]);
    csv.write().unwrap();
}
