//! Parallel kernel engine (DESIGN.md §Kernel-Engine): one dispatch layer in
//! front of the `fixedpoint` numeric backends, sharding big kernels across a
//! persistent worker thread pool (`pool.rs`) and falling back to the serial
//! kernels for small problems or `threads = 1`.
//!
//! Backends (all in [`crate::fixedpoint`]):
//! - **serial-portable** — the blocked autovectorized kernels in
//!   `gemm::*_portable` / `gemm_f32`;
//! - **serial-VNNI** — the AVX-512 `vpdpbusd`/`vpmaddwd` kernels in
//!   `gemm_simd` (runtime-detected);
//! - **parallel** — this module: the same kernels on disjoint shards.
//!
//! Sharding strategy (EXPERIMENTS.md §Perf):
//! - GEMM by M-row panels (≤ [`crate::fixedpoint::gemm::MC`] rows each) —
//!   every output row's accumulation order is unchanged, so parallel i8/i16
//!   results are **bit-identical** to serial, and parallel f32 is too
//!   (per-row f32 accumulation order does not depend on the row partition);
//! - conv by output-channel blocks — the im2col GEMM has `m = out_c`, so
//!   row panels *are* channel blocks;
//! - quantize/pack/rescale by contiguous element slices.
//!
//! The process-wide engine ([`global`]) sizes itself from `APT_THREADS` or
//! the machine's available parallelism; `nn::{linear, conv, rnn}`, the
//! coordinator, and the bench drivers all route through it.

mod pool;

use std::sync::{Arc, OnceLock};

use crate::fixedpoint::conv::{self, Conv2dGeom};
use crate::fixedpoint::gemm;
pub use crate::fixedpoint::gemm::Tile;
use crate::fixedpoint::gemm_simd;
use crate::fixedpoint::quantize::{self, QuantStats};
use crate::fixedpoint::{Format, Scheme};
use pool::{SendPtr, ThreadPool};

/// Below this many MACs a GEMM is dispatched serially: pool hand-off costs
/// a few µs, which only pays off once the kernel itself is slower than that.
const PAR_GEMM_MIN_MACS: usize = 1 << 19;

/// Minimum element count before elementwise passes go parallel.
const PAR_ELEMWISE_MIN: usize = 1 << 16;

/// Contiguous-slice shard size for quantize/pack/rescale.
const QUANT_CHUNK: usize = 1 << 15;

/// The kernel engine: thread count + (for `threads > 1`) a persistent pool.
pub struct Engine {
    threads: usize,
    pool: Option<ThreadPool>,
}

impl Engine {
    /// Engine with an explicit thread count (`threads − 1` workers plus the
    /// dispatching thread). `0` is treated as `1`.
    pub fn new(threads: usize) -> Engine {
        let threads = threads.max(1);
        let pool = if threads > 1 { Some(ThreadPool::new(threads - 1)) } else { None };
        Engine { threads, pool }
    }

    /// Serial engine — every dispatch falls through to the serial backends.
    pub fn serial() -> Engine {
        Engine::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..total)` across the pool (and the calling thread), or
    /// inline when the engine is serial or the range is trivial.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, total: usize, f: F) {
        match &self.pool {
            Some(pool) if total > 1 => pool.dispatch(total, &f),
            _ => {
                for i in 0..total {
                    f(i);
                }
            }
        }
    }

    /// Parallel indexed map: `(0..n).map(f)` with the work sharded; result
    /// order matches index order.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.pool.is_none() || n < 2 {
            return (0..n).map(f).collect();
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let out = SendPtr(slots.as_mut_ptr());
        self.parallel_for(n, move |i| {
            // SAFETY: each task writes exactly one distinct slot, and the
            // dispatch barrier ends before `slots` is read.
            unsafe { *out.0.add(i) = Some(f(i)) };
        });
        slots.into_iter().map(|s| s.expect("map_indexed task skipped")).collect()
    }

    fn parallel_gemm(&self, m: usize, k: usize, n: usize) -> bool {
        self.pool.is_some()
            && m >= 2
            && m.saturating_mul(k).saturating_mul(n) >= PAR_GEMM_MIN_MACS
    }

    /// Shard the m×n output of a row-major kernel into row panels and run
    /// `body(r0, r1, rows_slice)` per panel.
    fn shard_rows<T, B>(&self, m: usize, n: usize, c: &mut [T], body: B)
    where
        T: Send,
        B: Fn(usize, usize, &mut [T]) + Sync,
    {
        self.shard_rows_chunk(m, n, 0, c, body)
    }

    /// [`Engine::shard_rows`] with an explicit panel height; `chunk == 0`
    /// keeps the load-balancing default. The partition never changes the
    /// per-row accumulation order, so every chunk choice is bit-identical —
    /// which is what lets the inference compiler autotune it
    /// (DESIGN.md §Inference-Compiler).
    fn shard_rows_chunk<T, B>(&self, m: usize, n: usize, chunk: usize, c: &mut [T], body: B)
    where
        T: Send,
        B: Fn(usize, usize, &mut [T]) + Sync,
    {
        debug_assert_eq!(c.len(), m * n);
        let chunk = if chunk == 0 {
            m.div_ceil(self.threads * 4).clamp(1, gemm::MC)
        } else {
            chunk.min(m.max(1))
        };
        let tasks = m.div_ceil(chunk);
        let out = SendPtr(c.as_mut_ptr());
        self.parallel_for(tasks, move |t| {
            let r0 = t * chunk;
            let r1 = ((t + 1) * chunk).min(m);
            // SAFETY: tasks cover disjoint row ranges of `c` and the
            // dispatch barrier outlives every use of the pointer.
            let rows = unsafe { std::slice::from_raw_parts_mut(out.0.add(r0 * n), (r1 - r0) * n) };
            body(r0, r1, rows);
        });
    }

    /// Shard a flat output buffer into contiguous `chunk`-sized slices and
    /// run `body(start, slice)` per shard.
    fn shard_slices<T, B>(&self, out: &mut [T], chunk: usize, body: B)
    where
        T: Send,
        B: Fn(usize, &mut [T]) + Sync,
    {
        let len = out.len();
        let tasks = len.div_ceil(chunk);
        let p = SendPtr(out.as_mut_ptr());
        self.parallel_for(tasks, move |t| {
            let s = t * chunk;
            let e = ((t + 1) * chunk).min(len);
            // SAFETY: disjoint contiguous ranges; barrier outlives use.
            let slice = unsafe { std::slice::from_raw_parts_mut(p.0.add(s), e - s) };
            body(s, slice);
        });
    }

    // ---------------------------------------------------------------- GEMM

    /// f32 GEMM, row-panel sharded. Bit-identical to the serial kernel for
    /// any thread count (each output row's accumulation order is fixed).
    pub fn gemm_f32(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        if !self.parallel_gemm(m, k, n) {
            gemm::gemm_f32(m, k, n, a, b, c);
            return;
        }
        self.shard_rows(m, n, c, |r0, r1, rows| {
            gemm::gemm_f32(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows);
        });
    }

    /// f32 GEMM with an explicit [`Tile`] (blocking + shard chunk). Every
    /// tile is bit-identical to [`Engine::gemm_f32`]; the compiler's
    /// autotuner picks the fastest one per shape.
    pub fn gemm_f32_tiled(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        t: Tile,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        if !self.parallel_gemm(m, k, n) {
            gemm::gemm_f32_tiled(m, k, n, a, b, c, t.mc, t.kc);
            return;
        }
        self.shard_rows_chunk(m, n, t.shard, c, |r0, r1, rows| {
            gemm::gemm_f32_tiled(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows, t.mc, t.kc);
        });
    }

    /// i8×i8→i32 GEMM. Same backend selection as the serial dispatch
    /// (VNNI when available and `k ≥ 64`, else portable), so results are
    /// bit-identical to [`gemm::gemm_i8`] at every thread count.
    pub fn gemm_i8(&self, m: usize, k: usize, n: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        if !self.parallel_gemm(m, k, n) {
            gemm::gemm_i8(m, k, n, a, b, c);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if gemm_simd::use_vnni_i8(k) {
            let mut bt = vec![0i8; k * n];
            let mut colsum = vec![0i32; n];
            gemm_simd::pack_bt_i8(k, n, b, &mut bt, &mut colsum);
            let (bt, colsum) = (&bt[..], &colsum[..]);
            self.shard_rows(m, n, c, |r0, r1, rows| {
                // SAFETY: VNNI availability checked above.
                unsafe {
                    gemm_simd::gemm_i8_vnni_packed(r1 - r0, k, n, &a[r0 * k..r1 * k], bt, colsum, rows)
                }
            });
            return;
        }
        self.shard_rows(m, n, c, |r0, r1, rows| {
            gemm::gemm_i8_portable(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows);
        });
    }

    /// i16×i16→i32 GEMM (see [`Engine::gemm_i8`] for the dispatch contract).
    pub fn gemm_i16(&self, m: usize, k: usize, n: usize, a: &[i16], b: &[i16], c: &mut [i32]) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        if !self.parallel_gemm(m, k, n) {
            gemm::gemm_i16(m, k, n, a, b, c);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if gemm_simd::use_madd_i16(k) {
            let mut bt = vec![0i16; k * n];
            gemm_simd::pack_bt_i16(k, n, b, &mut bt);
            let bt = &bt[..];
            self.shard_rows(m, n, c, |r0, r1, rows| {
                // SAFETY: AVX-512 BW availability checked above.
                unsafe { gemm_simd::gemm_i16_madd_packed(r1 - r0, k, n, &a[r0 * k..r1 * k], bt, rows) }
            });
            return;
        }
        self.shard_rows(m, n, c, |r0, r1, rows| {
            gemm::gemm_i16_portable(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows);
        });
    }

    /// i8 GEMM over a pre-packed BT + column sums (the training hot path —
    /// quantization emits BT directly, see `gemm_simd::codes_i8_bt`).
    pub fn gemm_i8_prepacked(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[i8],
        bt: &[i8],
        colsum: &[i32],
        c: &mut [i32],
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(bt.len(), k * n);
        assert_eq!(c.len(), m * n);
        if !self.parallel_gemm(m, k, n) {
            gemm_simd::gemm_i8_prepacked(m, k, n, a, bt, colsum, c);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if gemm_simd::has_vnni() {
            self.shard_rows(m, n, c, |r0, r1, rows| {
                // SAFETY: VNNI availability checked above.
                unsafe {
                    gemm_simd::gemm_i8_vnni_packed(r1 - r0, k, n, &a[r0 * k..r1 * k], bt, colsum, rows)
                }
            });
            return;
        }
        // Off-AVX512: unpack once, then shard the portable kernel.
        let b = gemm_simd::unpack_bt_i8(k, n, bt);
        let b = &b[..];
        self.shard_rows(m, n, c, |r0, r1, rows| {
            gemm::gemm_i8_portable(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows);
        });
    }

    /// i16 GEMM over a pre-packed BT (see [`Engine::gemm_i8_prepacked`]).
    pub fn gemm_i16_prepacked(&self, m: usize, k: usize, n: usize, a: &[i16], bt: &[i16], c: &mut [i32]) {
        assert_eq!(a.len(), m * k);
        assert_eq!(bt.len(), k * n);
        assert_eq!(c.len(), m * n);
        if !self.parallel_gemm(m, k, n) {
            gemm_simd::gemm_i16_prepacked(m, k, n, a, bt, c);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if gemm_simd::has_avx512bw() {
            self.shard_rows(m, n, c, |r0, r1, rows| {
                // SAFETY: AVX-512 BW availability checked above.
                unsafe { gemm_simd::gemm_i16_madd_packed(r1 - r0, k, n, &a[r0 * k..r1 * k], bt, rows) }
            });
            return;
        }
        let b = gemm_simd::unpack_bt_i16(k, n, bt);
        let b = &b[..];
        self.shard_rows(m, n, c, |r0, r1, rows| {
            gemm::gemm_i16_portable(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows);
        });
    }

    /// [`Engine::gemm_i8_prepacked`] with an explicit [`Tile`]. On the VNNI
    /// path `mc`/`kc` are moot (the SIMD kernel streams full-`k` dot
    /// products); the shard chunk and the portable-fallback blocking are
    /// what the tile actually steers. Exact integer math → any tile is
    /// bit-identical.
    pub fn gemm_i8_prepacked_tiled(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[i8],
        bt: &[i8],
        colsum: &[i32],
        c: &mut [i32],
        t: Tile,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(bt.len(), k * n);
        assert_eq!(c.len(), m * n);
        #[cfg(target_arch = "x86_64")]
        if gemm_simd::has_vnni() {
            if !self.parallel_gemm(m, k, n) {
                // SAFETY: VNNI availability checked above.
                unsafe { gemm_simd::gemm_i8_vnni_packed(m, k, n, a, bt, colsum, c) };
                return;
            }
            self.shard_rows_chunk(m, n, t.shard, c, |r0, r1, rows| {
                // SAFETY: VNNI availability checked above.
                unsafe {
                    gemm_simd::gemm_i8_vnni_packed(r1 - r0, k, n, &a[r0 * k..r1 * k], bt, colsum, rows)
                }
            });
            return;
        }
        let b = gemm_simd::unpack_bt_i8(k, n, bt);
        let b = &b[..];
        if !self.parallel_gemm(m, k, n) {
            gemm::gemm_i8_portable_tiled(m, k, n, a, b, c, t.mc, t.kc);
            return;
        }
        self.shard_rows_chunk(m, n, t.shard, c, |r0, r1, rows| {
            gemm::gemm_i8_portable_tiled(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows, t.mc, t.kc);
        });
    }

    /// [`Engine::gemm_i16_prepacked`] with an explicit [`Tile`] (see
    /// [`Engine::gemm_i8_prepacked_tiled`] for what the tile steers).
    pub fn gemm_i16_prepacked_tiled(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[i16],
        bt: &[i16],
        c: &mut [i32],
        t: Tile,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(bt.len(), k * n);
        assert_eq!(c.len(), m * n);
        #[cfg(target_arch = "x86_64")]
        if gemm_simd::has_avx512bw() {
            if !self.parallel_gemm(m, k, n) {
                // SAFETY: AVX-512 BW availability checked above.
                unsafe { gemm_simd::gemm_i16_madd_packed(m, k, n, a, bt, c) };
                return;
            }
            self.shard_rows_chunk(m, n, t.shard, c, |r0, r1, rows| {
                // SAFETY: AVX-512 BW availability checked above.
                unsafe { gemm_simd::gemm_i16_madd_packed(r1 - r0, k, n, &a[r0 * k..r1 * k], bt, rows) }
            });
            return;
        }
        let b = gemm_simd::unpack_bt_i16(k, n, bt);
        let b = &b[..];
        if !self.parallel_gemm(m, k, n) {
            gemm::gemm_i16_portable_tiled(m, k, n, a, b, c, t.mc, t.kc);
            return;
        }
        self.shard_rows_chunk(m, n, t.shard, c, |r0, r1, rows| {
            gemm::gemm_i16_portable_tiled(r1 - r0, k, n, &a[r0 * k..r1 * k], b, rows, t.mc, t.kc);
        });
    }

    // ---------------------------------------------------------------- conv

    /// f32 forward convolution of one image via im2col + engine GEMM. The
    /// GEMM's `m` is `out_c`, so row panels shard by output-channel blocks.
    /// `scratch` must hold `rows·cols` f32 (see `Conv2dGeom::im2col_dims`).
    pub fn conv2d_f32(
        &self,
        g: Conv2dGeom,
        h: usize,
        w: usize,
        img: &[f32],
        weight: &[f32],
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        let (rows, cols) = g.im2col_dims(h, w);
        assert_eq!(weight.len(), g.out_c * rows);
        assert_eq!(out.len(), g.out_c * cols);
        conv::im2col(g, h, w, img, scratch);
        self.gemm_f32(g.out_c, rows, cols, weight, scratch, out);
    }

    /// Quantized i8 forward convolution (codes → integer GEMM → rescale),
    /// each stage engine-dispatched.
    pub fn conv2d_i8(
        &self,
        g: Conv2dGeom,
        h: usize,
        w: usize,
        img: &[f32],
        s_img: Scheme,
        weight: &[f32],
        s_w: Scheme,
        out: &mut [f32],
    ) {
        let (rows, cols) = g.im2col_dims(h, w);
        let mut patch = vec![0.0f32; rows * cols];
        conv::im2col(g, h, w, img, &mut patch);
        let mut cw = vec![0i8; weight.len()];
        let mut cp = vec![0i8; patch.len()];
        self.codes_i8(weight, &mut cw, s_w);
        self.codes_i8(&patch, &mut cp, s_img);
        let mut acc = vec![0i32; out.len()];
        self.gemm_i8(g.out_c, rows, cols, &cw, &cp, &mut acc);
        self.rescale_i32(&acc, s_w.resolution() * s_img.resolution(), out);
    }

    // ------------------------------------------------------------ quantize

    /// f32 → i8 codes, sharded by contiguous slices (elementwise, so
    /// bit-identical to the serial pass).
    pub fn codes_i8(&self, xs: &[f32], out: &mut [i8], sch: Scheme) {
        assert_eq!(xs.len(), out.len());
        if self.pool.is_none() || xs.len() < PAR_ELEMWISE_MIN {
            quantize::codes_i8(xs, out, sch);
            return;
        }
        self.shard_slices(out, QUANT_CHUNK, |s, o| {
            quantize::codes_i8(&xs[s..s + o.len()], o, sch);
        });
    }

    /// f32 → i16 codes (see [`Engine::codes_i8`]).
    pub fn codes_i16(&self, xs: &[f32], out: &mut [i16], sch: Scheme) {
        assert_eq!(xs.len(), out.len());
        if self.pool.is_none() || xs.len() < PAR_ELEMWISE_MIN {
            quantize::codes_i16(xs, out, sch);
            return;
        }
        self.shard_slices(out, QUANT_CHUNK, |s, o| {
            quantize::codes_i16(&xs[s..s + o.len()], o, sch);
        });
    }

    /// i32 accumulator → f32 rescale, sharded (elementwise, bit-identical).
    pub fn rescale_i32(&self, acc: &[i32], scale: f32, out: &mut [f32]) {
        assert_eq!(acc.len(), out.len());
        if self.pool.is_none() || out.len() < PAR_ELEMWISE_MIN {
            gemm::rescale_i32(acc, scale, out);
            return;
        }
        self.shard_slices(out, QUANT_CHUNK, |s, o| {
            gemm::rescale_i32(&acc[s..s + o.len()], scale, o);
        });
    }

    /// Fake-quantize in place with fused QEM statistics. Quantized *values*
    /// are bit-identical to the serial pass; the f64 stat sums are merged
    /// per fixed-size chunk in index order, so they are deterministic for
    /// every thread count (but may differ from the serial single-pass sum
    /// in the last few ulps — see EXPERIMENTS.md §Perf).
    pub fn fake_quant_stats(&self, xs: &mut [f32], sch: Scheme) -> QuantStats {
        if self.pool.is_none() || xs.len() < PAR_ELEMWISE_MIN {
            return quantize::fake_quant_stats_inplace(xs, sch);
        }
        let len = xs.len();
        let tasks = len.div_ceil(QUANT_CHUNK);
        let mut parts = vec![QuantStats::default(); tasks];
        let pp = SendPtr(parts.as_mut_ptr());
        let xp = SendPtr(xs.as_mut_ptr());
        self.parallel_for(tasks, move |t| {
            let s = t * QUANT_CHUNK;
            let e = ((t + 1) * QUANT_CHUNK).min(len);
            // SAFETY: disjoint data ranges and one distinct stats slot per
            // task; the dispatch barrier outlives both pointers.
            let slice = unsafe { std::slice::from_raw_parts_mut(xp.0.add(s), e - s) };
            let st = quantize::fake_quant_stats_inplace(slice, sch);
            unsafe { *pp.0.add(t) = st };
        });
        let mut total = QuantStats::default();
        for st in parts {
            total.sum_abs += st.sum_abs;
            total.sum_abs_q += st.sum_abs_q;
            if st.max_abs > total.max_abs {
                total.max_abs = st.max_abs;
            }
        }
        total
    }

    /// Format-generic [`Engine::fake_quant_stats`] (DESIGN.md §Formats):
    /// fixed-point and int4 formats route to the pinned scheme kernel —
    /// bit-identical to the pre-format-axis path — while minifloat formats
    /// run the scaled fp8 codec with the same chunked, index-ordered stat
    /// merge (deterministic at every thread count).
    pub fn fake_quant_fmt(&self, xs: &mut [f32], fmt: Format) -> QuantStats {
        if let Some(sch) = fmt.as_scheme() {
            return self.fake_quant_stats(xs, sch);
        }
        if self.pool.is_none() || xs.len() < PAR_ELEMWISE_MIN {
            return quantize::fake_quant_stats_inplace_fmt(xs, fmt);
        }
        let len = xs.len();
        let tasks = len.div_ceil(QUANT_CHUNK);
        let mut parts = vec![QuantStats::default(); tasks];
        let pp = SendPtr(parts.as_mut_ptr());
        let xp = SendPtr(xs.as_mut_ptr());
        self.parallel_for(tasks, move |t| {
            let s = t * QUANT_CHUNK;
            let e = ((t + 1) * QUANT_CHUNK).min(len);
            // SAFETY: disjoint data ranges and one distinct stats slot per
            // task; the dispatch barrier outlives both pointers.
            let slice = unsafe { std::slice::from_raw_parts_mut(xp.0.add(s), e - s) };
            let st = quantize::fake_quant_stats_inplace_fmt(slice, fmt);
            unsafe { *pp.0.add(t) = st };
        });
        let mut total = QuantStats::default();
        for st in parts {
            total.sum_abs += st.sum_abs;
            total.sum_abs_q += st.sum_abs_q;
            if st.max_abs > total.max_abs {
                total.max_abs = st.max_abs;
            }
        }
        total
    }
}

// ------------------------------------------------------------------ global

static GLOBAL: OnceLock<Arc<Engine>> = OnceLock::new();

fn default_threads() -> usize {
    if let Ok(v) = std::env::var("APT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The process-wide engine, created on first use. Thread count comes from
/// `APT_THREADS` (the CLI's `--threads` sets it before first use) or the
/// machine's available parallelism.
pub fn global() -> &'static Engine {
    GLOBAL.get_or_init(|| Arc::new(Engine::new(default_threads())))
}

/// Shared handle to the global engine, for components that store it
/// (e.g. the coordinator).
pub fn global_arc() -> Arc<Engine> {
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(Engine::new(default_threads()))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::max_abs;
    use crate::util::Pcg32;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn f32_gemm_bit_identical_across_thread_counts() {
        // 160×130×96 ≈ 2M MACs: crosses the parallel threshold.
        let (m, k, n) = (160usize, 130, 96);
        let a = randvec(1, m * k);
        let b = randvec(2, k * n);
        let mut want = vec![0.0f32; m * n];
        gemm::gemm_f32(m, k, n, &a, &b, &mut want);
        for threads in [1usize, 2, 3, 4] {
            let eng = Engine::new(threads);
            let mut got = vec![0.0f32; m * n];
            eng.gemm_f32(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn tiled_gemm_bit_identical_for_any_tile_and_thread_count() {
        let (m, k, n) = (160usize, 130, 96);
        let a = randvec(7, m * k);
        let b = randvec(8, k * n);
        let sa = Scheme::for_range(max_abs(&a), 8);
        let sb = Scheme::for_range(max_abs(&b), 8);
        let mut ca = vec![0i8; a.len()];
        let mut cb = vec![0i8; b.len()];
        quantize::codes_i8(&a, &mut ca, sa);
        quantize::codes_i8(&b, &mut cb, sb);
        let mut bt = vec![0i8; k * n];
        let mut colsum = vec![0i32; n];
        gemm_simd::pack_bt_i8(k, n, &cb, &mut bt, &mut colsum);

        let mut want_f = vec![0.0f32; m * n];
        gemm::gemm_f32(m, k, n, &a, &b, &mut want_f);
        let mut want_i = vec![0i32; m * n];
        gemm::gemm_i8(m, k, n, &ca, &cb, &mut want_i);

        for threads in [1usize, 2, 4] {
            let eng = Engine::new(threads);
            for t in [
                Tile::default(),
                Tile { mc: 16, kc: 64, shard: 8 },
                Tile { mc: 128, kc: 512, shard: 64 },
                Tile { mc: 1, kc: 1, shard: 1 },
            ] {
                let mut cf = vec![0.0f32; m * n];
                eng.gemm_f32_tiled(m, k, n, &a, &b, &mut cf, t);
                assert_eq!(cf, want_f, "f32 threads={threads} tile={t:?}");
                let mut ci = vec![0i32; m * n];
                eng.gemm_i8_prepacked_tiled(m, k, n, &ca, &bt, &colsum, &mut ci, t);
                assert_eq!(ci, want_i, "i8 threads={threads} tile={t:?}");
            }
        }
    }

    #[test]
    fn parallel_for_covers_range_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let eng = Engine::new(4);
        let hits: Vec<AtomicU32> = (0..257).map(|_| AtomicU32::new(0)).collect();
        eng.parallel_for(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_indexed_preserves_order() {
        let eng = Engine::new(3);
        let v = eng.map_indexed(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
        let serial = Engine::serial();
        assert_eq!(serial.map_indexed(5, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_panic_propagates_and_engine_survives() {
        let eng = Engine::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.parallel_for(64, |i| {
                if i == 33 {
                    panic!("task failure");
                }
            });
        }));
        assert!(r.is_err());
        // still usable afterwards
        let v = eng.map_indexed(10, |i| i);
        assert_eq!(v.len(), 10);
    }

    #[test]
    fn quantize_passes_match_serial() {
        let eng = Engine::new(4);
        let xs = randvec(3, PAR_ELEMWISE_MIN + 1234);
        let sch = Scheme::for_range(max_abs(&xs), 8);
        let mut got = vec![0i8; xs.len()];
        let mut want = vec![0i8; xs.len()];
        eng.codes_i8(&xs, &mut got, sch);
        quantize::codes_i8(&xs, &mut want, sch);
        assert_eq!(got, want);

        let mut xq_par = xs.clone();
        let st_par = eng.fake_quant_stats(&mut xq_par, sch);
        let mut xq_ser = xs.clone();
        let st_ser = quantize::fake_quant_stats_inplace(&mut xq_ser, sch);
        assert_eq!(xq_par, xq_ser, "fake-quant values must be bit-identical");
        assert_eq!(st_par.max_abs, st_ser.max_abs);
        assert!((st_par.sum_abs - st_ser.sum_abs).abs() < 1e-6 * st_ser.sum_abs.max(1.0));
        assert!((st_par.sum_abs_q - st_ser.sum_abs_q).abs() < 1e-6 * st_ser.sum_abs_q.max(1.0));

        // stats deterministic across thread counts (chunking is fixed)
        let eng2 = Engine::new(2);
        let mut xq2 = xs.clone();
        let st2 = eng2.fake_quant_stats(&mut xq2, sch);
        assert_eq!(st_par.sum_abs.to_bits(), st2.sum_abs.to_bits());
        assert_eq!(st_par.sum_abs_q.to_bits(), st2.sum_abs_q.to_bits());
    }

    #[test]
    fn global_engine_is_usable() {
        let eng = global();
        assert!(eng.threads() >= 1);
        let mut c = vec![0.0f32; 4];
        eng.gemm_f32(2, 2, 2, &[1.0, 2.0, 3.0, 4.0], &[1.0, 0.0, 0.0, 1.0], &mut c);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 4.0]);
        let arc = global_arc();
        assert_eq!(arc.threads(), eng.threads());
    }
}
