//! Persistent worker thread pool for the kernel engine (DESIGN.md
//! §Kernel-Engine).
//!
//! Plain `std::thread` workers fed over `mpsc` channels — no external
//! dependencies. Work arrives as a [`Job`]: a lifetime-erased task closure
//! plus a shared atomic task counter. Every worker that receives the job
//! claims task indices from the counter until the range is exhausted, then
//! counts down a latch; the dispatching thread participates in the claim
//! loop too, so a pool built for `threads` uses `threads − 1` workers.
//!
//! Soundness of the lifetime erasure: [`ThreadPool::dispatch`] does not
//! return until every worker has counted down the latch, and a worker only
//! counts down after its claim loop stops touching the closure — so the
//! borrow the raw pointer was made from strictly outlives every use.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Raw pointer wrapper asserting cross-thread transferability. Safe to use
/// only under the dispatch protocol documented in the module header (or,
/// for output buffers, when tasks write provably disjoint ranges).
pub(crate) struct SendPtr<T: ?Sized>(pub *mut T);

// Manual Copy/Clone: a derive would demand `T: Copy`, but the pointee type
// is irrelevant — only the pointer is copied.
impl<T: ?Sized> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}

impl<T: ?Sized> Copy for SendPtr<T> {}

// SAFETY: SendPtr is only dereferenced by engine tasks that either (a) read
// shared data that outlives the dispatch, or (b) write disjoint ranges; the
// dispatch barrier guarantees no use-after-return.
unsafe impl<T: ?Sized> Send for SendPtr<T> {}
unsafe impl<T: ?Sized> Sync for SendPtr<T> {}

/// Count-down latch: workers count down, the dispatcher waits for zero.
/// The counter lives in a `Mutex` (not an atomic) because the `Condvar`
/// wakeup requires one.
#[allow(clippy::mutex_atomic)]
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

#[allow(clippy::mutex_atomic)]
impl Latch {
    fn new(count: usize) -> Self {
        Latch { remaining: Mutex::new(count), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// One broadcast unit of sharded work (see module docs).
struct Job {
    /// Lifetime-erased pointer to the caller's `Fn(usize)` closure.
    ctx: SendPtr<()>,
    /// Monomorphized trampoline that reconstitutes and calls the closure.
    ///
    /// Safety contract: `ctx` must point at a live closure of the type the
    /// trampoline was instantiated for.
    call: unsafe fn(*const (), usize),
    /// Next unclaimed task index (shared across all participants).
    next: Arc<AtomicUsize>,
    /// One past the last task index.
    total: usize,
    latch: Arc<Latch>,
    panicked: Arc<AtomicBool>,
}

fn worker_loop(rx: std::sync::mpsc::Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.total {
                break;
            }
            // SAFETY: dispatch() keeps the closure alive until the latch
            // we count down below has been waited on.
            unsafe { (job.call)(job.ctx.0, i) };
        }));
        if result.is_err() {
            job.panicked.store(true, Ordering::SeqCst);
        }
        // Always count down, even after a panic, so dispatch() never hangs.
        job.latch.count_down();
    }
}

/// The persistent pool. Dropping it closes the channels and joins the
/// workers.
pub struct ThreadPool {
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `workers` persistent worker threads (callers pass
    /// `threads − 1`: the dispatching thread is the final participant).
    pub fn new(workers: usize) -> ThreadPool {
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("apt-kernel-{w}"))
                .spawn(move || worker_loop(rx))
                .expect("spawn kernel worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        ThreadPool { senders, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Run `f(0..total)` sharded across the workers *and* the calling
    /// thread. Blocks until every task has run. Panics (after all workers
    /// have quiesced) if any task panicked.
    pub fn dispatch<F: Fn(usize) + Sync>(&self, total: usize, f: &F) {
        /// Reconstitute the erased closure and run one task.
        ///
        /// # Safety
        /// `ctx` must point at a live `F` for the whole dispatch.
        unsafe fn trampoline<F: Fn(usize) + Sync>(ctx: *const (), i: usize) {
            unsafe { (*(ctx as *const F))(i) }
        }

        // Wake only as many workers as there are tasks beyond the one the
        // dispatcher itself will claim — a 2-task dispatch on a wide pool
        // must not pay a full-pool broadcast + latch.
        let participants = self.workers().min(total.saturating_sub(1));
        let next = Arc::new(AtomicUsize::new(0));
        let latch = Arc::new(Latch::new(participants));
        let panicked = Arc::new(AtomicBool::new(false));
        let ctx = SendPtr(f as *const F as *const () as *mut ());
        for tx in self.senders.iter().take(participants) {
            let job = Job {
                ctx,
                call: trampoline::<F>,
                next: Arc::clone(&next),
                total,
                latch: Arc::clone(&latch),
                panicked: Arc::clone(&panicked),
            };
            if let Err(e) = tx.send(job) {
                // Worker gone (cannot normally happen): keep the latch
                // balanced so we do not deadlock below.
                e.0.latch.count_down();
            }
        }
        // The dispatcher participates in the same claim loop.
        let main_result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= total {
                break;
            }
            f(i);
        }));
        latch.wait();
        if main_result.is_err() || panicked.load(Ordering::SeqCst) {
            panic!("parallel kernel task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes the channels → workers exit recv()
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        let f = |i: usize| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        };
        pool.dispatch(hits.len(), &f);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_and_single_task_dispatch() {
        let pool = ThreadPool::new(2);
        let count = AtomicU64::new(0);
        let f = |_i: usize| {
            count.fetch_add(1, Ordering::Relaxed);
        };
        pool.dispatch(0, &f);
        assert_eq!(count.load(Ordering::Relaxed), 0);
        pool.dispatch(1, &f);
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = ThreadPool::new(2);
        let boom = |i: usize| {
            if i == 7 {
                panic!("boom");
            }
        };
        let r = catch_unwind(AssertUnwindSafe(|| pool.dispatch(32, &boom)));
        assert!(r.is_err(), "panic must propagate to the dispatcher");
        // The pool must still work afterwards.
        let count = AtomicU64::new(0);
        let ok = |_i: usize| {
            count.fetch_add(1, Ordering::Relaxed);
        };
        pool.dispatch(16, &ok);
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn many_sequential_dispatches() {
        let pool = ThreadPool::new(1);
        let count = AtomicU64::new(0);
        for _ in 0..100 {
            let f = |_i: usize| {
                count.fetch_add(1, Ordering::Relaxed);
            };
            pool.dispatch(10, &f);
        }
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }
}
