//! # Adaptive Precision Training (APT)
//!
//! Production-grade reproduction of *"Adaptive Precision Training: Quantify
//! Back Propagation in Neural Networks with Fixed-point Numbers"*
//! (Zhang et al., 2019): layer-wise precision-adaptive fixed-point
//! quantization of the forward **and** backward passes, with bit-widths
//! chosen online by the QEM/QPA controller.
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L3** (this crate): coordinator — `apt` controller, `nn` training
//!   substrate, the unified `train::Session` front-end over the host and
//!   PJRT backends (DESIGN.md §Session-API), the `serve` inference
//!   subsystem that deploys frozen int8 checkpoints behind a micro-batching
//!   server (DESIGN.md §Serving), experiment drivers, PJRT `runtime` for
//!   the AOT artifacts, and the parallel `kernels` engine the numeric hot
//!   paths dispatch through (DESIGN.md §Kernel-Engine).
//! - **L2** (`python/compile/model.py`): JAX train-step graphs, AOT-lowered
//!   to HLO text at build time.
//! - **L1** (`python/compile/kernels/`): Pallas quantization/stats/qmatmul
//!   kernels that lower into those graphs.

// Kernel-style math signatures (m, k, n, operands, schemes, outputs) and
// index-heavy blocked loops are the local idiom; these style lints fight it.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::manual_range_contains)]
#![allow(clippy::new_without_default)]
#![allow(clippy::type_complexity)]
// the crate and its core controller module share the paper's name
#![allow(clippy::module_inception)]

pub mod apt;
pub mod bench;
pub mod calib;
pub mod compiler;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod fixedpoint;
pub mod kernels;
pub mod mem;
pub mod nn;
pub mod opcount;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
