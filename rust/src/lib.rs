//! # Adaptive Precision Training (APT)
//!
//! Production-grade reproduction of *"Adaptive Precision Training: Quantify
//! Back Propagation in Neural Networks with Fixed-point Numbers"*
//! (Zhang et al., 2019): layer-wise precision-adaptive fixed-point
//! quantization of the forward **and** backward passes, with bit-widths
//! chosen online by the QEM/QPA controller.
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L3** (this crate): coordinator — `apt` controller, `nn` training
//!   substrate, experiment drivers, PJRT `runtime` for the AOT artifacts.
//! - **L2** (`python/compile/model.py`): JAX train-step graphs, AOT-lowered
//!   to HLO text at build time.
//! - **L1** (`python/compile/kernels/`): Pallas quantization/stats/qmatmul
//!   kernels that lower into those graphs.

pub mod apt;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod fixedpoint;
pub mod nn;
pub mod opcount;
pub mod runtime;
pub mod tensor;
pub mod util;
