//! L3 coordinator for the PJRT path (system S14): drives the AOT train-step
//! artifacts while running QEM/QPA on the host, exactly the split of the
//! paper's Fig 3 — quantified GEMMs on the device, control plane here.
//!
//! Per step the coordinator:
//!   1. renders each tensor's applied [`Scheme`] into the `qparams[n_q, 9]`
//!      runtime input (`(r, qmin, qmax)` for X, W, dY — bit-width changes
//!      never recompile, DESIGN.md §6.1);
//!   2. executes the artifact;
//!   3. reads back the `wstats/xstats/gstats[n_q, 6]` QEM statistics
//!      (sum|x|, max|x|, sum|x̂| applied, sum|x̂| at candidate int8/16/24)
//!      and feeds the controllers that are due for an update.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::apt::{AptConfig, Ledger, PrecisionController};
use crate::fixedpoint::{Scheme, TensorKind};
use crate::kernels::Engine;
use crate::nn::QuantMode;
use crate::runtime::{Dtype, HostValue, Runtime};
use crate::util::Pcg32;

/// Quantized-tensor stats layout produced by kernels/stats.py.
pub const N_STATS: usize = 6;
pub const QP_LEN: usize = 9;

/// Controllers for the three roles of one q-tensor slot.
pub struct SlotControllers {
    pub name: String,
    pub x: PrecisionController,
    pub w: PrecisionController,
    pub g: PrecisionController,
}

/// Scheme → the (r, qmin, qmax) triple the L2 graph consumes.
pub fn scheme_triple(s: Scheme) -> [f32; 3] {
    [s.resolution(), s.qmin() as f32, s.qmax() as f32]
}

/// Feed one stats row (f32[6]) into a controller.
fn feed(ctl: &mut PrecisionController, iter: u64, row: &[f32], ledger: &mut Ledger) {
    let sum_abs = row[0] as f64;
    let max_abs = row[1];
    let cand = [(8u8, row[3] as f64), (16, row[4] as f64), (24, row[5] as f64)];
    if ctl.needs_update(iter) {
        ctl.maybe_update_from_stats(iter, sum_abs, max_abs, &cand, ledger);
    }
}

/// Generic driver over a train-step artifact with the calling convention
/// emitted by `python/compile/aot.py`:
///   inputs:  [params…] ([m…] [v…] if Adam) data… qparams lr (step if Adam)
///   outputs: [new params…] (new m/v…) loss wstats xstats gstats
pub struct ArtifactTrainer {
    pub artifact: String,
    pub n_q: usize,
    pub adam: bool,
    /// Parameter state, in manifest order.
    pub params: Vec<HostValue>,
    opt_m: Vec<HostValue>,
    opt_v: Vec<HostValue>,
    pub slots: Vec<SlotControllers>,
    pub ledger: Ledger,
    pub step_count: u64,
    /// Kernel engine for host-side bulk work (parameter marshalling); the
    /// quantified GEMMs themselves run inside the artifact.
    pub engine: Arc<Engine>,
    n_params: usize,
    data_inputs: usize,
}

/// One step's observable results.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub loss: f32,
    /// Applied gradient bit-widths per q slot.
    pub grad_bits: Vec<u8>,
}

impl ArtifactTrainer {
    /// Build from the manifest: infers parameter count, Adam-ness and n_q
    /// from the artifact's input list; initializes parameters host-side
    /// (He/embedding init by name — see DESIGN.md §6).
    pub fn new(
        rt: &Runtime,
        artifact: &str,
        slot_names: Vec<String>,
        mode: QuantMode,
        seed: u64,
    ) -> Result<Self> {
        let spec = rt
            .manifest
            .get(artifact)
            .ok_or_else(|| anyhow!("artifact {artifact} missing — run `make artifacts`"))?
            .clone();
        let adam = spec.inputs.iter().any(|s| s.name == "step");
        let qp_idx = spec
            .input_index("qparams")
            .ok_or_else(|| anyhow!("{artifact}: no qparams input"))?;
        let n_q = spec.inputs[qp_idx].dims[0];
        if slot_names.len() != n_q {
            anyhow::bail!("{artifact}: {} slot names for n_q={n_q}", slot_names.len());
        }
        // inputs before the first data input are params (+ m,v if adam)
        let first_data = spec
            .inputs
            .iter()
            .position(|s| s.name == "x" || s.name == "tokens")
            .ok_or_else(|| anyhow!("{artifact}: no data input"))?;
        let n_params = if adam { first_data / 3 } else { first_data };
        let data_inputs = qp_idx - first_data;

        let mut rng = Pcg32::seeded(seed);
        let mut init = |iospec: &crate::runtime::IoSpec| -> HostValue {
            let n = iospec.elements();
            let name = iospec.name.trim_start_matches("p_");
            let mut v = vec![0.0f32; n];
            if name.ends_with("_g") || name == "lnf_g" {
                v.fill(1.0); // layernorm gains
            } else if name.ends_with("_b") || name.starts_with('b') && iospec.dims.len() == 1 {
                // biases stay zero
            } else if name.contains("embed") || name.contains("pos") {
                rng.fill_normal(&mut v, 0.02);
            } else if iospec.dims.len() == 2 {
                let fan_in = iospec.dims[0] as f32;
                rng.fill_normal(&mut v, (2.0 / fan_in).sqrt());
            }
            HostValue::F32(v)
        };
        let params: Vec<HostValue> = spec.inputs[..n_params].iter().map(&mut init).collect();
        let zeros = |spec: &crate::runtime::IoSpec| HostValue::F32(vec![0.0; spec.elements()]);
        let (opt_m, opt_v) = if adam {
            (
                spec.inputs[n_params..2 * n_params].iter().map(zeros).collect(),
                spec.inputs[2 * n_params..3 * n_params].iter().map(zeros).collect(),
            )
        } else {
            (vec![], vec![])
        };

        let cfg = mode.config().unwrap_or_else(|| {
            // Float32 runs use a 32-bit static config: quantization grid so
            // fine it is numerically f32 (DESIGN.md §2).
            AptConfig::static_bits(32)
        });
        let slots = slot_names
            .into_iter()
            .map(|n| SlotControllers {
                x: PrecisionController::new(cfg, &n, TensorKind::Activation),
                w: PrecisionController::new(cfg, &n, TensorKind::Weight),
                g: PrecisionController::new(cfg, &n, TensorKind::Gradient),
                name: n,
            })
            .collect();

        Ok(ArtifactTrainer {
            artifact: artifact.to_string(),
            n_q,
            adam,
            params,
            opt_m,
            opt_v,
            slots,
            ledger: Ledger::new(),
            step_count: 0,
            engine: crate::kernels::global_arc(),
            n_params,
            data_inputs,
        })
    }

    /// Clone one parameter bank for the executor, sharding the copies
    /// across the kernel engine only when the bank is big enough to
    /// amortize a pool dispatch (mirrors the engine's elementwise gate).
    fn marshal(&self, bank: &[HostValue]) -> Vec<HostValue> {
        const PAR_MARSHAL_MIN_ELEMS: usize = 1 << 16;
        let total: usize = bank.iter().map(|v| v.len()).sum();
        if total < PAR_MARSHAL_MIN_ELEMS {
            bank.to_vec()
        } else {
            self.engine.map_indexed(bank.len(), |i| bank[i].clone())
        }
    }

    /// Render the current schemes into the qparams input.
    pub fn qparams(&self) -> HostValue {
        let mut out = Vec::with_capacity(self.n_q * QP_LEN);
        for s in &self.slots {
            out.extend_from_slice(&scheme_triple(s.x.scheme()));
            out.extend_from_slice(&scheme_triple(s.w.scheme()));
            out.extend_from_slice(&scheme_triple(s.g.scheme()));
        }
        HostValue::F32(out)
    }

    /// One training step. `data` are the artifact's data inputs in manifest
    /// order (e.g. `[x, labels]` or `[tokens, targets]`).
    pub fn step(&mut self, rt: &mut Runtime, data: Vec<HostValue>, lr: f32) -> Result<StepResult> {
        if data.len() != self.data_inputs {
            anyhow::bail!("expected {} data inputs, got {}", self.data_inputs, data.len());
        }
        let mut inputs = Vec::with_capacity(3 * self.n_params + data.len() + 3);
        // Parameter marshalling copies every tensor each step; shard the
        // clones across the kernel engine (memcpy-bound for big models).
        inputs.extend(self.marshal(&self.params));
        if self.adam {
            inputs.extend(self.marshal(&self.opt_m));
            inputs.extend(self.marshal(&self.opt_v));
        }
        inputs.extend(data);
        inputs.push(self.qparams());
        inputs.push(HostValue::F32(vec![lr]));
        if self.adam {
            inputs.push(HostValue::F32(vec![(self.step_count + 1) as f32]));
        }
        let outputs = rt.exec(&self.artifact, &inputs)?;

        // unpack: params, (m, v), loss, wstats, xstats, gstats
        let mut it = outputs.into_iter();
        for p in self.params.iter_mut() {
            *p = it.next().ok_or_else(|| anyhow!("missing param output"))?;
        }
        if self.adam {
            for m in self.opt_m.iter_mut() {
                *m = it.next().ok_or_else(|| anyhow!("missing m output"))?;
            }
            for v in self.opt_v.iter_mut() {
                *v = it.next().ok_or_else(|| anyhow!("missing v output"))?;
            }
        }
        let loss = it.next().ok_or_else(|| anyhow!("missing loss"))?.scalar_f32();
        let wstats = it.next().ok_or_else(|| anyhow!("missing wstats"))?;
        let xstats = it.next().ok_or_else(|| anyhow!("missing xstats"))?;
        let gstats = it.next().ok_or_else(|| anyhow!("missing gstats"))?;

        let iter = self.step_count;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let row = |hv: &HostValue| hv.as_f32()[i * N_STATS..(i + 1) * N_STATS].to_vec();
            feed(&mut slot.w, iter, &row(&wstats), &mut self.ledger);
            feed(&mut slot.x, iter, &row(&xstats), &mut self.ledger);
            feed(&mut slot.g, iter, &row(&gstats), &mut self.ledger);
            self.ledger
                .trace_bits(&slot.name, TensorKind::Gradient, iter, slot.g.bits());
        }
        self.step_count += 1;
        self.ledger.set_total_iters(self.step_count);

        Ok(StepResult {
            loss,
            grad_bits: self.slots.iter().map(|s| s.g.bits()).collect(),
        })
    }

    /// Current parameter by manifest input name.
    pub fn param(&self, rt: &Runtime, name: &str) -> Option<&HostValue> {
        let spec = rt.manifest.get(&self.artifact)?;
        let idx = spec.input_index(name)?;
        self.params.get(idx)
    }
}

/// Slot names for the transformer artifact (must match the qlinear call
/// order in python/compile/model.py::tfm_forward).
pub fn tfm_slot_names(n_layers: usize) -> Vec<String> {
    let mut v = Vec::new();
    for i in 0..n_layers {
        for p in ["wq", "wk", "wv", "wo", "w1", "w2"] {
            v.push(format!("b{i}_{p}"));
        }
    }
    v.push("head".to_string());
    v
}

/// Slot names for the MLP artifact.
pub fn mlp_slot_names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("fc{i}")).collect()
}

/// Tokens → HostValue helper.
pub fn tokens_value(tokens: &[Vec<i32>]) -> HostValue {
    HostValue::I32(tokens.iter().flatten().copied().collect())
}

/// Marshal a f32 batch.
pub fn f32_value(rows: &[Vec<f32>]) -> HostValue {
    HostValue::F32(rows.iter().flatten().copied().collect())
}

/// Infer n_q for an artifact without instantiating a trainer.
pub fn artifact_n_q(rt: &Runtime, artifact: &str) -> Option<usize> {
    let spec = rt.manifest.get(artifact)?;
    let idx = spec.input_index("qparams")?;
    Some(spec.inputs[idx].dims[0])
}

/// Which Dtype a data input expects.
pub fn data_dtype(rt: &Runtime, artifact: &str, input: &str) -> Option<Dtype> {
    let spec = rt.manifest.get(artifact)?;
    Some(spec.inputs[spec.input_index(input)?].dtype)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_names_match_model_order() {
        let names = tfm_slot_names(2);
        assert_eq!(names.len(), 13);
        assert_eq!(names[0], "b0_wq");
        assert_eq!(names[5], "b0_w2");
        assert_eq!(names[6], "b1_wq");
        assert_eq!(names.last().unwrap(), "head");
        assert_eq!(mlp_slot_names(3), vec!["fc0", "fc1", "fc2"]);
    }

    #[test]
    fn scheme_triple_roundtrip() {
        let s = Scheme::for_range(4.0, 8);
        let t = scheme_triple(s);
        assert_eq!(t[1], -128.0);
        assert_eq!(t[2], 127.0);
        assert!(t[0] > 0.0);
    }

    #[test]
    fn tokens_marshal() {
        let hv = tokens_value(&[vec![1, 2], vec![3, 4]]);
        assert_eq!(hv.as_i32(), &[1, 2, 3, 4]);
    }

    // Full artifact-driving integration lives in rust/tests/test_e2e_pjrt.rs.
}
