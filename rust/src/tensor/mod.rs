//! Minimal dense f32 tensor for the pure-Rust training substrate.
//!
//! Contiguous row-major storage with a shape vector. Heavy math routes to
//! `fixedpoint::gemm` / `fixedpoint::conv`; this type mostly manages shape
//! bookkeeping and elementwise traversal for the `nn` layers.

use crate::fixedpoint::gemm;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Dim i (panics if out of rank).
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Reshape in place (product must match).
    pub fn reshape(&mut self, shape: &[usize]) -> &mut Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D matmul: self (m×k) · other (k×n), dispatched through the global
    /// [`crate::kernels::Engine`] (row-panel parallel for big shapes,
    /// serial otherwise; bit-identical either way).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_with(other, crate::kernels::global())
    }

    /// 2-D matmul on an explicit kernel engine.
    pub fn matmul_with(&self, other: &Tensor, engine: &crate::kernels::Engine) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "inner dims: {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        engine.gemm_f32(m, k, n, &self.data, &other.data, &mut out.data);
        out
    }

    /// Transposed 2-D view materialized.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        gemm::transpose(m, n, &self.data, &mut out.data);
        out
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) -> &mut Self {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
        self
    }

    pub fn add_inplace(&mut self, other: &Tensor) -> &mut Self {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        self
    }

    pub fn scale_inplace(&mut self, s: f32) -> &mut Self {
        for v in self.data.iter_mut() {
            *v *= s;
        }
        self
    }

    /// axpy: self += alpha * other.
    pub fn axpy_inplace(&mut self, alpha: f32, other: &Tensor) -> &mut Self {
        assert_eq!(self.shape, other.shape);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        self
    }

    /// Broadcast-add a bias over the last dim of a 2-D tensor.
    pub fn add_row_bias(&mut self, bias: &[f32]) -> &mut Self {
        assert_eq!(self.rank(), 2);
        let n = self.shape[1];
        assert_eq!(bias.len(), n);
        for row in self.data.chunks_mut(n) {
            for (v, &b) in row.iter_mut().zip(bias) {
                *v += b;
            }
        }
        self
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn max_abs(&self) -> f32 {
        crate::fixedpoint::quantize::max_abs(&self.data)
    }

    /// Row-wise argmax of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        let n = self.shape[1];
        self.data
            .chunks(n)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

/// Numerically-stable row softmax in place (2-D).
pub fn softmax_rows(t: &mut Tensor) {
    assert_eq!(t.rank(), 2);
    let n = t.shape[1];
    for row in t.data.chunks_mut(n) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = a.t();
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1e4]);
        softmax_rows(&mut t);
        for row in t.data.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn bias_and_argmax() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.add_row_bias(&[0.1, 0.5, 0.2]);
        assert_eq!(t.argmax_rows(), vec![1, 1]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
