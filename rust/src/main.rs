//! `apt` — the Adaptive Precision Training coordinator CLI.
//!
//! Subcommands:
//!   exp <id|all> [--iters N ...]   run a paper experiment (fig1..table5)
//!   train [--model M --mode Q]     train one classifier and report
//!   serve [--ckpt F --model M]     serve a checkpoint with micro-batching
//!   opcount [--batch N]            print the Fig7/Table5 analytic counts
//!   list                           list experiments and models
use std::sync::Arc;
use std::time::Instant;

use apt::exp;
use apt::exp::common::grad_mix_string;
use apt::nn::{models, QuantMode};
use apt::serve::{FrozenModel, InferenceServer, ServeConfig};
use apt::train::SessionBuilder;
use apt::util::cli::Args;
use apt::util::stats::percentile;

fn usage() -> ! {
    eprintln!(
        "usage: apt <command> [--threads N]\n\
         \n\
         commands:\n\
         \x20 exp <id|all> [--iters N] [--quick]   run a paper experiment\n\
         \x20 train [--model alexnet|vgg|resnet|mobilenet|inception|mlp]\n\
         \x20       [--mode float32|adaptive|int8|int16] [--iters N] [--lr F]\n\
         \x20 serve [--ckpt file] [--model mlp] [--mode int8] [--train-iters N]\n\
         \x20       [--seed N] [--requests N] [--clients N] [--workers N]\n\
         \x20       [--max-batch N] [--max-wait-us N]\n\
         \x20 opcount [--batch N]\n\
         \x20 list\n\
         \n\
         --threads N sizes the kernel engine (default: all cores;\n\
         env APT_THREADS equivalent)\n\
         \n\
         experiments: {}",
        exp::ALL.join(" ")
    );
    std::process::exit(2);
}

/// Parse a `--mode` string; `iters` sizes the adaptive init phase.
fn parse_mode(s: &str, iters: u64) -> QuantMode {
    match s {
        "float32" | "f32" => QuantMode::Float32,
        "adaptive" => {
            let mut cfg = apt::apt::AptConfig::default();
            cfg.init_phase_iters = iters / 10;
            QuantMode::Adaptive(cfg)
        }
        s if s.starts_with("int") => QuantMode::Static(s[3..].parse().expect("intN")),
        other => {
            eprintln!("unknown mode {other:?}");
            usage();
        }
    }
}

/// `apt serve`: close the train→deploy loop. Loads (or quickly trains) a
/// checkpoint, freezes it to pre-quantized weights, starts the
/// micro-batching [`InferenceServer`], and answers a synthetic concurrent
/// workload, reporting accuracy, QPS and client-side p50/p99 latency
/// (protocol: EXPERIMENTS.md §Serve).
fn cmd_serve(args: &Args) {
    let model = args.str_or("model", "mlp");
    let train_iters = args.u64_or("train-iters", 80);
    let mode = parse_mode(args.str_or("mode", "int8").as_str(), train_iters);
    let seed = args.u64_or("seed", 0);
    let requests = args.usize_or("requests", 512);
    let clients = args.usize_or("clients", 8).max(1);
    let cfg = ServeConfig {
        max_batch: args.usize_or("max-batch", 16),
        max_wait_us: args.u64_or("max-wait-us", 200),
        queue_cap: args.usize_or("queue-cap", 256),
        workers: args.usize_or("workers", 2),
    };

    let ckpt_path = match args.get("ckpt") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            // No checkpoint given: train one briefly and save it, so the
            // serve path below is exactly the deployment path.
            let path = std::env::temp_dir().join(format!(
                "apt_serve_{}_{}.ckpt",
                model,
                std::process::id()
            ));
            println!(
                "no --ckpt given: training {model} ({}) for {train_iters} iters …",
                mode.label()
            );
            let mut s = SessionBuilder::classifier(&model)
                .mode(mode)
                .lr(0.01)
                .seed(seed)
                .build();
            s.run(train_iters).expect("host training cannot fail");
            s.save_checkpoint(&path).expect("writing checkpoint");
            println!("checkpoint saved to {}", path.display());
            path
        }
    };

    let frozen =
        FrozenModel::from_checkpoint(&ckpt_path, &model, mode).expect("freezing checkpoint");
    println!(
        "serving {} ({} weights, input width {})",
        frozen.label(),
        frozen.precision(),
        frozen.input_len()
    );
    let frozen = Arc::new(frozen);
    let server = InferenceServer::start(Arc::clone(&frozen), apt::kernels::global_arc(), cfg);

    // Synthetic eval workload drawn from the same stream Session::eval
    // uses (data seed+1000, eval stream 999 — matches the training run
    // above; pass the training session's --seed when using --ckpt).
    let data = apt::data::SynthImages::new(
        seed + 1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let (ex, ey) = data.eval_set(999, requests);
    let d = frozen.input_len();

    let wall = Instant::now();
    let (correct, latencies) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = &server;
            let ex = &ex;
            let ey = &ey;
            handles.push(scope.spawn(move || {
                // Closed-loop client: submit, wait, repeat over its slice.
                let mut correct = 0usize;
                let mut lat = Vec::new();
                let mut i = c;
                while i < requests {
                    let input = ex.data[i * d..(i + 1) * d].to_vec();
                    let t = Instant::now();
                    let logits = server
                        .submit(input)
                        .expect("submit")
                        .wait()
                        .expect("response");
                    lat.push(t.elapsed().as_secs_f64());
                    let pred = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap();
                    if pred == ey[i] {
                        correct += 1;
                    }
                    i += clients;
                }
                (correct, lat)
            }));
        }
        let mut correct = 0usize;
        let mut lat = Vec::new();
        for h in handles {
            let (c, l) = h.join().expect("client thread");
            correct += c;
            lat.extend(l);
        }
        (correct, lat)
    });
    let secs = wall.elapsed().as_secs_f64();
    let stats = server.shutdown();

    println!(
        "\n{} requests from {clients} clients in {:.3}s — {:.0} QPS",
        requests,
        secs,
        requests as f64 / secs
    );
    println!(
        "latency p50 {:.1}µs  p99 {:.1}µs   (max_batch {}, max_wait {}µs, {} workers)",
        percentile(&latencies, 50.0) * 1e6,
        percentile(&latencies, 99.0) * 1e6,
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.workers
    );
    println!(
        "batches {} (mean size {:.2}), accuracy {:.3}",
        stats.batches,
        stats.mean_batch(),
        correct as f64 / requests as f64
    );
}

fn main() {
    let args = Args::from_env();
    // Size the global kernel engine before anything touches it.
    if let Some(t) = args.get("threads") {
        std::env::set_var("APT_THREADS", t);
    }
    let pos = args.positional().to_vec();
    match pos.first().map(|s| s.as_str()) {
        Some("exp") => {
            let id = pos.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                for e in exp::ALL {
                    exp::run(e, &args);
                    println!();
                }
            } else if !exp::run(id, &args) {
                eprintln!("unknown experiment {id:?}");
                usage();
            }
        }
        Some("train") => {
            let model = args.str_or("model", "alexnet");
            let iters = args.u64_or("iters", 300);
            let mode = parse_mode(args.str_or("mode", "adaptive").as_str(), iters);
            let run = SessionBuilder::classifier(model)
                .mode(mode)
                .lr(args.f32_or("lr", 0.01))
                .batch(args.usize_or("batch", 16))
                .seed(args.u64_or("seed", 0))
                .noise(args.f32_or("noise", 0.5))
                .train(iters);
            println!("{}: eval acc {:.3}", run.label, run.eval_acc);
            println!("gradient bits: {}", grad_mix_string(&run.ledger));
            println!(
                "QPA updates: {} over {} iters",
                run.ledger.total_updates(),
                iters
            );
        }
        Some("serve") => cmd_serve(&args),
        Some("opcount") => {
            exp::run("fig7", &args);
            println!();
            exp::run("table5", &args);
        }
        Some("list") => {
            println!("experiments: {}", exp::ALL.join(" "));
            println!("models: {} mlp", apt::nn::models::ZOO.join(" "));
        }
        _ => usage(),
    }
}
