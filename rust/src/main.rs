//! `apt` — the Adaptive Precision Training coordinator CLI.
//!
//! Subcommands:
//!   exp <id|all> [--iters N ...]   run a paper experiment (fig1..table5)
//!   train [--model M --mode Q]     train one classifier and report
//!   opcount [--batch N]            print the Fig7/Table5 analytic counts
//!   list                           list experiments and models
use apt::exp;
use apt::exp::common::grad_mix_string;
use apt::nn::QuantMode;
use apt::train::SessionBuilder;
use apt::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: apt <command> [--threads N]\n\
         \n\
         commands:\n\
         \x20 exp <id|all> [--iters N] [--quick]   run a paper experiment\n\
         \x20 train [--model alexnet|vgg|resnet|mobilenet|inception|mlp]\n\
         \x20       [--mode float32|adaptive|int8|int16] [--iters N] [--lr F]\n\
         \x20 opcount [--batch N]\n\
         \x20 list\n\
         \n\
         --threads N sizes the kernel engine (default: all cores;\n\
         env APT_THREADS equivalent)\n\
         \n\
         experiments: {}",
        exp::ALL.join(" ")
    );
    std::process::exit(2);
}

fn main() {
    let args = Args::from_env();
    // Size the global kernel engine before anything touches it.
    if let Some(t) = args.get("threads") {
        std::env::set_var("APT_THREADS", t);
    }
    let pos = args.positional().to_vec();
    match pos.first().map(|s| s.as_str()) {
        Some("exp") => {
            let id = pos.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                for e in exp::ALL {
                    exp::run(e, &args);
                    println!();
                }
            } else if !exp::run(id, &args) {
                eprintln!("unknown experiment {id:?}");
                usage();
            }
        }
        Some("train") => {
            let model = args.str_or("model", "alexnet");
            let iters = args.u64_or("iters", 300);
            let mode = match args.str_or("mode", "adaptive").as_str() {
                "float32" | "f32" => QuantMode::Float32,
                "adaptive" => {
                    let mut cfg = apt::apt::AptConfig::default();
                    cfg.init_phase_iters = iters / 10;
                    QuantMode::Adaptive(cfg)
                }
                s if s.starts_with("int") => {
                    QuantMode::Static(s[3..].parse().expect("intN"))
                }
                other => {
                    eprintln!("unknown mode {other:?}");
                    usage();
                }
            };
            let run = SessionBuilder::classifier(model)
                .mode(mode)
                .lr(args.f32_or("lr", 0.01))
                .batch(args.usize_or("batch", 16))
                .seed(args.u64_or("seed", 0))
                .noise(args.f32_or("noise", 0.5))
                .train(iters);
            println!("{}: eval acc {:.3}", run.label, run.eval_acc);
            println!("gradient bits: {}", grad_mix_string(&run.ledger));
            println!(
                "QPA updates: {} over {} iters",
                run.ledger.total_updates(),
                iters
            );
        }
        Some("opcount") => {
            exp::run("fig7", &args);
            println!();
            exp::run("table5", &args);
        }
        Some("list") => {
            println!("experiments: {}", exp::ALL.join(" "));
            println!("models: {} mlp", apt::nn::models::ZOO.join(" "));
        }
        _ => usage(),
    }
}
