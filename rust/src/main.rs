//! `apt` — the Adaptive Precision Training coordinator CLI.
//!
//! Subcommands:
//!   exp <id|all> [--iters N ...]   run a paper experiment (fig1..table5)
//!   train [--model M --mode Q]     train one classifier and report;
//!         [--per-channel]          Q includes the format families
//!         [--quant-delay N]        e4m3|e5m2|int4 (DESIGN.md §Formats),
//!         [--replicas N --comm-bits {8,16,e4m3,e5m2,adaptive,f32}]
//!         [--compress {none,quantize,topk:<r>,topk:<r>+quantize}]
//!         [--node-size N]          gradient compression + hierarchical
//!                                  reduce (DESIGN.md §Data-Parallel)
//!   serve [--ckpt F --model M]     serve through the serving tier: model
//!         [--models A,B --scheduler P --deadline-us N]  registry, pluggable
//!                                  batching policy, SLO-aware shedding
//!         [--no-fuse --tune]       inference-compiler knobs: unfused
//!         [--weight-format F]      interpreter / load-time tile search /
//!                                  weight-only re-quantization (int4 packs
//!                                  two codes per byte)
//!         [--calib F]              PTQ: freeze a *float* checkpoint with a
//!                                  calibration table (file or embedded)
//!   calibrate [--model M]          PTQ calibration pass (DESIGN.md
//!         [--ckpt F --observer K]  §Calibration): observe activations over
//!         [--samples N --bits B]   forward-only passes, derive per-site
//!         [--out F | --embed]      formats, write a table artifact
//!   opcount [--batch N]            print the Fig7/Table5 analytic counts
//!   list                           list experiments and models
//!
//! User-input failure paths (bad flags, malformed checkpoints, unknown
//! models) surface as `error: …` + exit(1) through `anyhow`, not panics.
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use apt::apt::AptConfig;
use apt::calib::{CalibTable, Calibrator, ObserverKind, Schedule};
use apt::compiler::CompileOptions;
use apt::exp;
use apt::fixedpoint::FormatFamily;
use apt::exp::common::{grad_mix_string, stash_mix_string};
use apt::mem::StashPolicy;
use apt::nn::{models, QuantMode};
use apt::serve::{
    FrozenModel, InferenceServer, ModelRegistry, SchedPolicy, ServeConfig, ServeModel,
    ServeOutcome, SubmitOpts,
};
use apt::train::checkpoint::Checkpoint;
use apt::train::{CommPrecision, CompressPolicy, SessionBuilder, TrainRecord};
use apt::util::cli::Args;
use apt::util::stats::percentile;

fn usage() -> ! {
    eprintln!(
        "usage: apt <command> [--threads N]\n\
         \n\
         commands:\n\
         \x20 exp <id|all> [--iters N] [--quick]   run a paper experiment\n\
         \x20 train [--model alexnet|vgg|resnet|mobilenet|inception|mlp]\n\
         \x20       [--mode float32|adaptive|int8|int16|e4m3|e5m2|int4]\n\
         \x20       [--iters N] [--lr F] [--per-channel] [--quant-delay N]\n\
         \x20       [--schedule delay:<n>|warmup|progressive:<bits>@<iter>,…]\n\
         \x20       [--replicas N] [--comm-bits 8|16|e4m3|e5m2|adaptive|f32]\n\
         \x20       [--compress none|quantize|topk:<r>|topk:<r>+quantize]\n\
         \x20       [--node-size N] (power of two; hierarchical all-reduce)\n\
         \x20       [--act-bits 8|16|e4m3|e5m2|adaptive|f32] [--recompute]\n\
         \x20 serve [--ckpt file] [--model mlp] [--models mlp,alexnet,…]\n\
         \x20       [--mode int8] [--train-iters N] [--seed N] [--requests N]\n\
         \x20       [--clients N] [--workers N] [--max-batch N] [--max-wait-us N]\n\
         \x20       [--queue-cap N] [--scheduler flush|continuous]\n\
         \x20       [--deadline-us N] [--lanes N] [--no-fuse] [--tune]\n\
         \x20       [--weight-format int4|e4m3|e5m2] [--calib file]\n\
         \x20 calibrate [--model mlp] [--ckpt file] [--observer minmax|ema[:a]|percentile:<q>|kl]\n\
         \x20       [--samples N] [--bits B] [--family fixed|int4|e4m3|e5m2]\n\
         \x20       [--per-channel] [--out file] [--embed] [--train-iters N]\n\
         \x20       [--ckpt-out file] [--seed N]\n\
         \x20 opcount [--batch N]\n\
         \x20 list\n\
         \n\
         --threads N sizes the kernel engine (default: all cores;\n\
         env APT_THREADS equivalent)\n\
         \n\
         experiments: {}",
        exp::ALL.join(" ")
    );
    std::process::exit(2);
}

/// Checked numeric flag: `Err` (→ `error: …` + exit 1) instead of the
/// panicking `Args::*_or` accessors — bad CLI input must not abort with a
/// backtrace.
fn parsed<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T> {
    match args.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("--{key}: cannot parse {v:?} as a number")),
    }
}

/// Checked boolean flag (`--flag`, `--flag true|1|yes|false|0|no`):
/// errors on junk instead of panicking, same contract as [`parsed`].
fn flag(args: &Args, key: &str) -> Result<bool> {
    match args.get(key) {
        None => Ok(false),
        Some("true") | Some("1") | Some("yes") => Ok(true),
        Some("false") | Some("0") | Some("no") => Ok(false),
        Some(v) => bail!("--{key} expects a bool, got {v:?}"),
    }
}

/// Parse a `--mode` string; `iters` sizes the adaptive init phase.
/// Format-family modes (`e4m3`, `e5m2`, `int4`) run the adaptive
/// controller pinned to that family's storage width: QPA adapts the scale
/// exponent only (DESIGN.md §Formats).
fn parse_mode(s: &str, iters: u64) -> Result<QuantMode> {
    Ok(match s {
        "float32" | "f32" => QuantMode::Float32,
        "adaptive" => apt::exp::common::adaptive_mode(iters),
        "e4m3" | "e5m2" | "int4" => {
            let family = FormatFamily::parse(s)
                .ok_or_else(|| anyhow!("--mode {s:?}: unknown format family"))?;
            let mut cfg = AptConfig::for_family(family);
            cfg.init_phase_iters = iters / 10;
            QuantMode::Adaptive(cfg)
        }
        s if s.starts_with("int") => QuantMode::Static(
            s[3..]
                .parse()
                .map_err(|_| anyhow!("--mode {s:?}: expected intN with numeric N"))?,
        ),
        other => {
            bail!("unknown mode {other:?} (expected float32, adaptive, intN, e4m3, e5m2 or int4)")
        }
    })
}

/// `apt train`: one classifier run, optionally data-parallel
/// (`--replicas N` shards each batch across N replicas with the compressed
/// gradient all-reduce of DESIGN.md §Data-Parallel; `--compress` picks the
/// lossy wire stage, `--node-size` the hierarchical grouping).
fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "alexnet");
    let iters: u64 = parsed(args, "iters", 300)?;
    let mut mode = parse_mode(args.str_or("mode", "adaptive").as_str(), iters)?;
    // --per-channel: per-output-channel weight scales on conv/fc layers.
    // Only the adaptive controller owns weight schemes, so the other modes
    // have nothing to apply it to — error instead of silently ignoring.
    if flag(args, "per-channel")? {
        match &mut mode {
            QuantMode::Adaptive(cfg) => cfg.per_channel_weights = true,
            _ => bail!(
                "--per-channel needs an adaptive or format-family --mode \
                 (float32/static modes have no weight controllers)"
            ),
        }
    }
    let replicas: usize = parsed(args, "replicas", 1)?;
    let compress: Option<CompressPolicy> = match args.get("compress") {
        Some(s) => Some(CompressPolicy::parse(s)?),
        None => None,
    };
    // --comm-bits defaults to f32, except that a quantizing --compress
    // policy with no explicit --comm-bits gets int8 (the natural pairing);
    // contradictory explicit combinations error in the builder.
    let comm = match args.get("comm-bits") {
        Some(s) => CommPrecision::parse(s, iters)?,
        None => match &compress {
            Some(p) if p.wants_codes() => CommPrecision::Static(8),
            _ => CommPrecision::F32,
        },
    };
    let policy = compress.unwrap_or_else(|| comm.default_compress());
    let node: usize = parsed(args, "node-size", 1)?;
    let act = StashPolicy::parse(&args.str_or("act-bits", "f32"), iters)?;
    // checked flag parse: a malformed value must error, not panic (the
    // no-panic CLI contract of the PR-4 hardening pass)
    let recompute = flag(args, "recompute")?;
    // --schedule subsumes --quant-delay (`delay:<n>` is the schedule
    // spelling); both at once is ambiguous, so error instead of picking.
    let schedule = match (args.get("schedule"), args.get("quant-delay")) {
        (Some(_), Some(_)) => {
            bail!("--schedule and --quant-delay conflict (delay:<n> is the --schedule spelling)")
        }
        (Some(s), None) => Schedule::parse(s, iters)?,
        (None, _) => Schedule::delay(parsed(args, "quant-delay", 0)?),
    };
    let mut builder = SessionBuilder::classifier(model)
        .mode(mode)
        .lr(parsed(args, "lr", 0.01)?)
        .batch(parsed(args, "batch", 16)?)
        .seed(parsed(args, "seed", 0)?)
        .noise(parsed(args, "noise", 0.5)?)
        .stash_policy(act)
        .node_size(node)
        .schedule(schedule)
        .recompute(recompute);
    if let Some(p) = compress {
        builder = builder.compress(p);
    }
    // Always build through the Result-based parallel constructor: at
    // --replicas 1 it is bit-identical to the plain host loop (pinned by
    // rust/tests/test_parallel.rs), and a bad --model errors instead of
    // panicking.
    let mut s = builder.build_parallel(replicas.max(1), comm)?;
    s.run(iters)?;
    let peak_stash = s.mem().peak_bytes();
    let wire = s.wire_stats();
    let run: TrainRecord = s.record()?;
    println!("{}: eval acc {:.3}", run.label, run.eval_acc);
    println!("gradient bits: {}", grad_mix_string(&run.ledger));
    println!(
        "activation stash: {} storage{}, peak {:.1} KB/replica/step",
        act.label(),
        if recompute { " + recompute" } else { "" },
        peak_stash as f64 / 1024.0
    );
    if act.config().is_some() {
        println!("stash bits: {}", stash_mix_string(&run.ledger));
    }
    if replicas > 1 {
        // minifloat comm has no adapted bit-width: its reported 8 is the
        // storage width, so label the format, not "int8"
        let comm_bits: Vec<String> = run
            .grad_bits
            .iter()
            .map(|(n, b)| match comm.minifloat_kind() {
                Some(k) => format!("{n}={}", k.label()),
                None => format!("{n}=int{b}"),
            })
            .collect();
        println!(
            "comm ({} replicas, {}): {}",
            replicas,
            comm.label(),
            if comm_bits.is_empty() { "f32 (unquantized)".to_string() } else { comm_bits.join(" ") }
        );
        println!(
            "compression ({}, node {node}): wire {:.1} KB vs dense {:.1} KB — {:.1}x \
             (inter-node {:.1} KB, {:.1}x)",
            policy.label(),
            wire.replica_bytes as f64 / 1024.0,
            wire.dense_bytes as f64 / 1024.0,
            wire.reduction(),
            wire.internode_bytes as f64 / 1024.0,
            wire.internode_reduction()
        );
    }
    println!(
        "QPA updates: {} over {} iters ({} interval clamps)",
        run.ledger.total_updates(),
        iters,
        run.ledger.total_clamps()
    );
    Ok(())
}

/// Train one zoo model briefly and freeze the live net (the `--models`
/// registry path — no checkpoint file round-trip needed for a demo zoo).
fn train_and_freeze(
    name: &str,
    mode: QuantMode,
    iters: u64,
    seed: u64,
    copts: &CompileOptions,
) -> Result<FrozenModel> {
    println!("training {name} ({}) for {iters} iters …", mode.label());
    let mut s = SessionBuilder::classifier(name)
        .mode(mode)
        .lr(0.01)
        .seed(seed)
        .build_parallel(1, CommPrecision::F32)?;
    s.run(iters)?;
    FrozenModel::freeze_with(format!("{name}-{}", mode.label()), s.net(), copts)
        .with_context(|| format!("freezing {name}"))
}

/// `apt serve`: close the train→deploy loop through the serving tier
/// (DESIGN.md §Serving-Tier). Loads (or quickly trains) one checkpoint —
/// or, with `--models a,b,…`, trains a small zoo and publishes every
/// model into a [`ModelRegistry`] — then answers a synthetic concurrent
/// workload through the chosen `--scheduler` policy, with optional
/// `--deadline-us` SLO shedding, reporting accuracy, QPS, client-side
/// p50/p99 latency and the full shed accounting (protocol:
/// EXPERIMENTS.md §Serve and §Serve-SLO).
fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.str_or("model", "mlp");
    let train_iters: u64 = parsed(args, "train-iters", 80)?;
    let mode = parse_mode(args.str_or("mode", "int8").as_str(), train_iters)?;
    let seed: u64 = parsed(args, "seed", 0)?;
    let requests: usize = parsed(args, "requests", 512)?;
    let clients = parsed(args, "clients", 8usize)?.max(1);
    let policy = SchedPolicy::parse(&args.str_or("scheduler", "flush"))?;
    let deadline_us: Option<u64> = match parsed(args, "deadline-us", 0u64)? {
        0 => None,
        d => Some(d),
    };
    let cfg = ServeConfig {
        max_batch: parsed(args, "max-batch", 16)?,
        max_wait_us: parsed(args, "max-wait-us", 200)?,
        queue_cap: parsed(args, "queue-cap", 256)?,
        workers: parsed(args, "workers", 2)?,
        policy,
        lanes: parsed(args, "lanes", 3)?,
    };
    // --weight-format int4|e4m3|e5m2: re-encode frozen weights into that
    // family at freeze time (int4 nibble-packs — half the weight bytes of
    // int8). `fixed` is the no-op spelling of the default int8 path.
    let weight_format = match args.get("weight-format") {
        None => None,
        Some(s) => Some(FormatFamily::parse(s).ok_or_else(|| {
            anyhow!("--weight-format {s:?}: expected fixed, int4, e4m3 or e5m2")
        })?),
    };
    let copts = CompileOptions {
        fuse: !flag(args, "no-fuse")?,
        tune: flag(args, "tune")?,
        weight_format,
    };

    // --models a,b,…: round-robin requests across a registry of briefly
    // trained zoo models instead of serving one checkpoint.
    let model_names: Option<Vec<String>> = args.get("models").map(|s| {
        s.split(',')
            .map(|m| m.trim().to_string())
            .filter(|m| !m.is_empty())
            .collect()
    });

    // --calib: PTQ deployment — freeze a *float* checkpoint statically
    // through a calibration table instead of re-deriving formats from
    // trained controller schemes (DESIGN.md §Calibration).
    let calib_path = args.get("calib");
    if calib_path.is_some() && args.get("mode").is_some() {
        bail!("--calib freezes a float checkpoint via its calibration table; --mode does not apply");
    }
    if calib_path.is_some() && model_names.is_some() {
        bail!("--calib serves one model (use --model/--ckpt, not --models)");
    }

    let server = if let Some(names) = &model_names {
        if names.is_empty() {
            bail!("--models expects a comma-separated list of zoo models");
        }
        let registry = Arc::new(ModelRegistry::new());
        for name in names {
            let frozen = train_and_freeze(name, mode, train_iters, seed, &copts)?;
            print!("{}", frozen.compile_report());
            registry.publish(name.as_str(), 1, Arc::new(frozen) as Arc<dyn ServeModel>)?;
        }
        for info in registry.list() {
            println!("registry: {} v{} active ({} loaded)", info.name, info.active, info.versions.len());
        }
        InferenceServer::start_registry(registry, names[0].clone(), apt::kernels::global_arc(), cfg)?
    } else {
        let ckpt_path = match args.get("ckpt") {
            Some(p) => std::path::PathBuf::from(p),
            None => {
                // No checkpoint given: train one briefly and save it, so the
                // serve path below is exactly the deployment path.
                let path = std::env::temp_dir().join(format!(
                    "apt_serve_{}_{}.ckpt",
                    model,
                    std::process::id()
                ));
                // PTQ freezes from a *float* checkpoint, so the quick
                // bootstrap train runs float when --calib is given.
                let train_mode =
                    if calib_path.is_some() { QuantMode::Float32 } else { mode };
                println!(
                    "no --ckpt given: training {model} ({}) for {train_iters} iters …",
                    train_mode.label()
                );
                // build_parallel(1, F32) == build(), but errors on a bad
                // --model instead of panicking (no-panic CLI contract).
                let mut s = SessionBuilder::classifier(&model)
                    .mode(train_mode)
                    .lr(0.01)
                    .seed(seed)
                    .build_parallel(1, CommPrecision::F32)?;
                s.run(train_iters)?;
                s.save_checkpoint(&path)
                    .with_context(|| format!("writing checkpoint {}", path.display()))?;
                println!("checkpoint saved to {}", path.display());
                path
            }
        };
        let frozen = if let Some(cpath) = calib_path {
            let table = load_calib_table(cpath)?;
            println!(
                "PTQ freeze: {} table ({} sites, {} samples)",
                table.observer,
                table.sites.len(),
                table.samples
            );
            FrozenModel::freeze_ptq(&ckpt_path, &model, &table, &copts)
                .with_context(|| format!("PTQ-freezing checkpoint {}", ckpt_path.display()))?
        } else {
            FrozenModel::from_checkpoint_with(&ckpt_path, &model, mode, &copts)
                .with_context(|| format!("freezing checkpoint {}", ckpt_path.display()))?
        };
        print!("{}", frozen.compile_report());
        if copts.tune && frozen.compile_report().tiles_tuned > 0 {
            // Persist the freshly searched tiles so the next load of this
            // artifact answers every shape from the plan cache.
            Checkpoint::write_tune_cache(&ckpt_path, frozen.tuned_tiles())
                .with_context(|| format!("caching tiles in {}", ckpt_path.display()))?;
            println!(
                "tune cache: wrote {} tile(s) back to {}",
                frozen.tuned_tiles().len(),
                ckpt_path.display()
            );
        }
        println!(
            "serving {} ({} weights, input width {})",
            frozen.label(),
            frozen.precision(),
            frozen.input_len()
        );
        InferenceServer::start(Arc::new(frozen), apt::kernels::global_arc(), cfg)?
    };

    // Synthetic eval workload drawn from the same stream Session::eval
    // uses (data seed+1000, eval stream 999 — matches the training run
    // above; pass the training session's --seed when using --ckpt).
    let data = apt::data::SynthImages::new(
        seed + 1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let (ex, ey) = data.eval_set(999, requests);
    let d = server.input_len();
    let model_names = &model_names;

    let wall = Instant::now();
    let (correct, client_served, client_shed, latencies) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = &server;
            let ex = &ex;
            let ey = &ey;
            handles.push(scope.spawn(move || -> Result<(usize, usize, usize, Vec<f64>)> {
                // Closed-loop client: submit, resolve, repeat over its
                // slice. With --deadline-us, shed replies are an expected
                // outcome and are counted, not failed.
                let (mut correct, mut served, mut shed) = (0usize, 0usize, 0usize);
                let mut lat = Vec::new();
                let mut i = c;
                while i < requests {
                    let input = ex.data[i * d..(i + 1) * d].to_vec();
                    let opts = SubmitOpts {
                        lane: 1,
                        deadline_us,
                        model: model_names.as_ref().map(|ns| ns[i % ns.len()].clone()),
                    };
                    let t = Instant::now();
                    match server.submit_opts(input, opts) {
                        Err(e) if e.to_string().contains("request shed") => shed += 1,
                        Err(e) => return Err(e),
                        Ok(p) => match p.outcome()? {
                            ServeOutcome::Shed(_) => shed += 1,
                            ServeOutcome::Logits(logits) => {
                                lat.push(t.elapsed().as_secs_f64());
                                served += 1;
                                // total_cmp: a NaN logit must not panic the client
                                let pred = logits
                                    .iter()
                                    .enumerate()
                                    .max_by(|a, b| a.1.total_cmp(b.1))
                                    .map(|(j, _)| j)
                                    .unwrap_or(0);
                                if pred == ey[i] {
                                    correct += 1;
                                }
                            }
                        },
                    }
                    i += clients;
                }
                Ok((correct, served, shed, lat))
            }));
        }
        let (mut correct, mut served, mut shed) = (0usize, 0usize, 0usize);
        let mut lat = Vec::new();
        let mut failure = None;
        for h in handles {
            match h.join() {
                Ok(Ok((c, s, x, l))) => {
                    correct += c;
                    served += s;
                    shed += x;
                    lat.extend(l);
                }
                Ok(Err(e)) => failure = Some(e),
                Err(_) => failure = Some(anyhow!("serve client thread panicked")),
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok((correct, served, shed, lat)),
        }
    })?;
    let secs = wall.elapsed().as_secs_f64();
    // Per-step timings accumulate in the models; read them out before the
    // shutdown consumes the server.
    let timing_reports = server.timing_reports();
    let stats = server.shutdown();

    println!(
        "\n{} requests from {clients} clients in {:.3}s — {:.0} QPS ({} scheduler)",
        requests,
        secs,
        requests as f64 / secs,
        policy.label()
    );
    println!(
        "latency p50 {:.1}µs  p99 {:.1}µs   (max_batch {}, max_wait {}µs, {} workers)",
        percentile(&latencies, 50.0) * 1e6,
        percentile(&latencies, 99.0) * 1e6,
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.workers
    );
    println!(
        "batches {} (mean size {:.2}), served {client_served}, shed {client_shed}, accuracy {:.3}",
        stats.batches,
        stats.mean_batch(),
        correct as f64 / client_served.max(1) as f64
    );
    println!(
        "accounting: accepted {} = served {} + shed {} (+{} refused at admission)",
        stats.accepted, stats.served, stats.shed, stats.shed_admission
    );
    for r in &timing_reports {
        print!("\n{r}");
    }
    if !stats.accounted() || stats.submitted() != requests as u64 {
        bail!(
            "serve accounting mismatch: accepted {} served {} shed {} refused {} over {requests} requests",
            stats.accepted,
            stats.served,
            stats.shed,
            stats.shed_admission
        );
    }
    Ok(())
}

/// Load a calibration table: either a standalone table artifact
/// (`apt calibrate --out`) or a checkpoint carrying the embedded `calib`
/// section (`apt calibrate --embed`).
fn load_calib_table(path: &str) -> Result<CalibTable> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading calibration table {path:?}"))?;
    if text.starts_with("aptcalib") {
        CalibTable::parse(&text).with_context(|| format!("parsing calibration table {path:?}"))
    } else {
        Checkpoint::read(std::path::Path::new(path))?
            .calib_table()
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "{path}: neither a calibration table nor a checkpoint with an \
                     embedded calib section"
                )
            })
    }
}

/// `apt calibrate`: the PTQ calibration pass (DESIGN.md §Calibration).
/// Restores a *float* checkpoint (or trains one briefly), streams
/// `--samples` calibration inputs through forward-only passes with an
/// `--observer` watching every quantizable site, and derives a per-site
/// format table — written to `--out` and/or embedded into the checkpoint's
/// `calib` section with `--embed`, ready for `apt serve --calib`.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let model = args.str_or("model", "mlp");
    let observer = ObserverKind::parse(&args.str_or("observer", "percentile:99.99"))?;
    let samples: usize = parsed(args, "samples", 256)?;
    let bits: u8 = parsed(args, "bits", 8)?;
    if !(2..=16).contains(&bits) {
        bail!("--bits {bits}: calibrated activation widths must be in 2..=16");
    }
    let family = match args.get("family") {
        None => FormatFamily::FixedPoint,
        Some(s) => FormatFamily::parse(s).ok_or_else(|| {
            anyhow!("--family {s:?}: expected fixed, int4, e4m3 or e5m2")
        })?,
    };
    let per_channel = flag(args, "per-channel")?;
    let seed: u64 = parsed(args, "seed", 0)?;
    let train_iters: u64 = parsed(args, "train-iters", 80)?;

    // A float session: PTQ calibrates the f32 forward, never a QAT run.
    let mut s = SessionBuilder::classifier(&model)
        .mode(QuantMode::Float32)
        .lr(0.01)
        .seed(seed)
        .build_parallel(1, CommPrecision::F32)?;
    let ckpt_path = match args.get("ckpt") {
        Some(p) => {
            let p = std::path::PathBuf::from(p);
            s.load_checkpoint(&p)
                .with_context(|| format!("restoring float checkpoint {}", p.display()))?;
            p
        }
        None => {
            // No checkpoint given: train float briefly and save it, so the
            // table calibrates exactly the weights `serve --calib` will
            // freeze.
            let path = match args.get("ckpt-out") {
                Some(p) => std::path::PathBuf::from(p),
                None => std::env::temp_dir().join(format!(
                    "apt_calibrate_{}_{}.ckpt",
                    model,
                    std::process::id()
                )),
            };
            println!("no --ckpt given: training {model} (float32) for {train_iters} iters …");
            s.run(train_iters)?;
            s.save_checkpoint(&path)
                .with_context(|| format!("writing checkpoint {}", path.display()))?;
            println!("checkpoint saved to {}", path.display());
            path
        }
    };

    let mut cal = Calibrator::from_net(&model, s.net(), observer)?;
    // Calibration stream: the same synthetic distribution the training and
    // serve paths draw from (data seed+1000).
    let mut data = apt::data::SynthImages::new(
        seed + 1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    while cal.samples() < samples {
        let n = (samples - cal.samples()).min(32);
        let (x, _) = data.batch(n);
        cal.observe(&x);
    }
    let table = cal.finish(family, bits, per_channel);

    println!(
        "calibrated {} sites over {} samples ({}, {} @ {} bits{})",
        table.sites.len(),
        table.samples,
        table.observer,
        table.family.label(),
        table.bits,
        if per_channel { ", per-channel weights" } else { "" }
    );
    for site in &table.sites {
        println!("  {:<12} max|x| {:>10.5} → {}", site.name, site.max_abs, site.fmt.label());
    }
    let mut delivered = false;
    if let Some(out) = args.get("out") {
        table.write(out)?;
        println!("table written to {out}");
        delivered = true;
    }
    if flag(args, "embed")? {
        Checkpoint::write_calib(&ckpt_path, &table)
            .with_context(|| format!("embedding table in {}", ckpt_path.display()))?;
        println!("table embedded in {}", ckpt_path.display());
        delivered = true;
    }
    if !delivered {
        print!("{}", table.render());
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let pos = args.positional().to_vec();
    match pos.first().map(|s| s.as_str()) {
        Some("exp") => {
            let id = pos.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                for e in exp::ALL {
                    exp::run(e, args);
                    println!();
                }
            } else if !exp::run(id, args) {
                eprintln!("unknown experiment {id:?}");
                usage();
            }
            Ok(())
        }
        Some("train") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("opcount") => {
            exp::run("fig7", args);
            println!();
            exp::run("table5", args);
            Ok(())
        }
        Some("list") => {
            println!("experiments: {}", exp::ALL.join(" "));
            println!("models: {} mlp", apt::nn::models::ZOO.join(" "));
            Ok(())
        }
        _ => usage(),
    }
}

fn main() {
    let args = Args::from_env();
    // Size the global kernel engine before anything touches it.
    if let Some(t) = args.get("threads") {
        std::env::set_var("APT_THREADS", t);
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
