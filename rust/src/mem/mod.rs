//! Quantized activation memory (DESIGN.md §Activation-Memory, system S19).
//!
//! Between forward and backward a training step holds every tensor the
//! backward pass will need — for conv nets that is the dominant memory cost
//! of training, and until this module it was all full f32. The
//! [`ActivationStash`] owns those tensors behind a [`StashPolicy`]:
//!
//! - [`StashPolicy::F32`] — store the saved tensors verbatim. Bit-identical
//!   to the pre-stash layer-private caches (pinned by
//!   `rust/tests/test_mem.rs` and the `test_session.rs` reference loops).
//! - [`StashPolicy::Int8`] / [`StashPolicy::Int16`] — encode each stashed
//!   tensor to fixed-point integer codes plus a per-tensor [`Scheme`] at
//!   stash time (scale from the tensor's own max-abs, the paper's Appendix-B
//!   rule), decode at backward time. Per-element error is bounded by half
//!   the scheme resolution.
//! - [`StashPolicy::Minifloat`] — encode to scaled OCP minifloat byte codes
//!   (e4m3 or e5m2): int8's footprint with *relative* error, which degrades
//!   gracefully on long-tailed activations.
//! - [`StashPolicy::Adaptive`] — one [`PrecisionController`] per stash
//!   *site* chooses the storage bit-width via QEM/QPA, exactly as the
//!   compute-side controllers choose GEMM operand widths; decisions are
//!   recorded in the run [`Ledger`] under `stash:<site>` keys
//!   (`TensorKind::Activation`). Widths above 16 fall back to exact f32
//!   storage (there is no packed 24-bit payload).
//!
//! Orthogonally, the **recompute** option (gradient checkpointing) lets the
//! GEMM layers (`nn::linear`, `nn::conv::Conv2d`) stash only their raw
//! *input* and re-derive the quantized operands during backward from the
//! frozen QEM/QPA schemes — dropping the conv patch matrices, the largest
//! stash entries, entirely. Because schemes are frozen between forward and
//! backward of one step and parameters only change after backward,
//! recomputation under F32 storage is bit-identical to stashing
//! (DESIGN.md §Activation-Memory lists the exactness conditions).
//!
//! Boolean masks (ReLU) and pooling argmax indices route through the stash
//! too, as packed bitsets / u32 indices — exact under every policy, but
//! counted by the [`MemLedger`] so reported peaks cover *all* backward
//! state, not just the policy-encoded tensors.

#![warn(missing_docs)]

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::apt::{AptConfig, ControllerState, Ledger, PrecisionController};
use crate::fixedpoint::quantize::{self, codes_i16, codes_i8};
use crate::fixedpoint::{Format, MinifloatKind, Scheme, TensorKind};
use crate::tensor::Tensor;

/// Storage policy for tensors stashed between forward and backward
/// (CLI `--act-bits {8,16,e4m3,e5m2,adaptive,f32}`).
#[derive(Clone, Copy, Debug)]
pub enum StashPolicy {
    /// Store saved tensors verbatim — bit-identical to the historical
    /// layer-private caches. The default.
    F32,
    /// Encode to int8 codes + per-tensor scale at stash time.
    Int8,
    /// Encode to int16 codes + per-tensor scale at stash time.
    Int16,
    /// Encode to scaled OCP minifloat byte codes (e4m3 or e5m2) — same
    /// 1 byte/element as int8, but the error is *relative* (graceful on
    /// long-tailed activations that force fixed-point to coarse scales).
    Minifloat(MinifloatKind),
    /// Per-site QEM/QPA choice of the storage bit-width (int8 → int16 →
    /// exact-f32 fallback above 16 bits), recorded as `stash:*` ledger
    /// entries.
    Adaptive(AptConfig),
}

impl StashPolicy {
    /// Parse an `--act-bits` value. `iters` sizes the adaptive init phase
    /// (one-tenth of the run, mirroring `--mode adaptive` / `--comm-bits`).
    pub fn parse(s: &str, iters: u64) -> Result<StashPolicy> {
        Ok(match s {
            "f32" | "float32" => StashPolicy::F32,
            "8" | "int8" => StashPolicy::Int8,
            "16" | "int16" => StashPolicy::Int16,
            "e4m3" => StashPolicy::Minifloat(MinifloatKind::E4M3),
            "e5m2" => StashPolicy::Minifloat(MinifloatKind::E5M2),
            "adaptive" => {
                let mut cfg = AptConfig::default();
                cfg.init_phase_iters = iters / 10;
                // Stash controllers are Activation-kind; the paper's
                // pin-forward rule must not freeze them at min_bits.
                cfg.pin_forward_bits = false;
                StashPolicy::Adaptive(cfg)
            }
            other => bail!(
                "unknown --act-bits {other:?} (expected 8, 16, e4m3, e5m2, adaptive or f32)"
            ),
        })
    }

    /// Display label (`"f32"`, `"int8"`, `"int16"`, `"e4m3"`, `"e5m2"`,
    /// `"adaptive"`).
    pub fn label(&self) -> String {
        match self {
            StashPolicy::F32 => "f32".into(),
            StashPolicy::Int8 => "int8".into(),
            StashPolicy::Int16 => "int16".into(),
            StashPolicy::Minifloat(kind) => kind.label().into(),
            StashPolicy::Adaptive(_) => "adaptive".into(),
        }
    }

    /// Controller config, if the policy adapts per site.
    pub fn config(&self) -> Option<AptConfig> {
        match self {
            StashPolicy::Adaptive(cfg) => Some(*cfg),
            _ => None,
        }
    }
}

/// Stable address of one stash site: `<layer>/<site>` (e.g. `fc0/x`,
/// `conv1/patches`). Layers create their handles once at construction and
/// route every `put`/`take` through them — the successor of the old
/// layer-private cache fields.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StashHandle {
    key: String,
}

impl StashHandle {
    /// Handle for `site` of `layer` (key `<layer>/<site>`).
    pub fn new(layer: &str, site: &str) -> StashHandle {
        StashHandle { key: format!("{layer}/{site}") }
    }

    /// The `<layer>/<site>` key (also the `stash:<key>` ledger key under
    /// the adaptive policy).
    pub fn key(&self) -> &str {
        &self.key
    }
}

/// Encoded stash payload. Codes store the *quantized* tensor; masks and
/// indices are exact bookkeeping for backward (ReLU masks, pool argmax).
enum Payload {
    /// Verbatim f32 values (the F32 policy, and the adaptive >16-bit
    /// fallback).
    F32(Vec<f32>),
    /// int8 codes + the scheme that decodes them.
    I8 { codes: Vec<i8>, scheme: Scheme },
    /// int16 codes + the scheme that decodes them.
    I16 { codes: Vec<i16>, scheme: Scheme },
    /// Scaled minifloat byte codes + the kind/scale that decode them.
    F8 { codes: Vec<u8>, kind: MinifloatKind, s: i32 },
    /// Packed boolean mask (1 bit per element).
    Mask { bits: Vec<u64>, len: usize },
    /// u32 element indices (pooling argmax).
    Indices(Vec<u32>),
}

impl Payload {
    /// Stored bytes of this payload (codes/values only; the ~8-byte scheme
    /// is counted as scheme overhead per encoded entry).
    fn bytes(&self) -> usize {
        const SCHEME_BYTES: usize = 8; // bits: u8 + s: i32, padded
        match self {
            Payload::F32(v) => 4 * v.len(),
            Payload::I8 { codes, .. } => codes.len() + SCHEME_BYTES,
            Payload::I16 { codes, .. } => 2 * codes.len() + SCHEME_BYTES,
            Payload::F8 { codes, .. } => codes.len() + SCHEME_BYTES,
            Payload::Mask { bits, .. } => 8 * bits.len(),
            Payload::Indices(v) => 4 * v.len(),
        }
    }
}

/// One stashed tensor (shape + encoded payload).
struct Entry {
    shape: Vec<usize>,
    payload: Payload,
}

/// Byte accounting of the stash: live bytes, per-step peak, run peak and
/// put traffic — the measurement behind `bench_act_memory` and the CLI's
/// `stash peak` line.
#[derive(Clone, Debug, Default)]
pub struct MemLedger {
    live_bytes: usize,
    step_peak_bytes: usize,
    peak_bytes: usize,
    total_puts: u64,
    total_put_bytes: u64,
}

impl MemLedger {
    fn on_put(&mut self, bytes: usize) {
        self.live_bytes += bytes;
        self.total_puts += 1;
        self.total_put_bytes += bytes as u64;
        if self.live_bytes > self.step_peak_bytes {
            self.step_peak_bytes = self.live_bytes;
        }
        if self.live_bytes > self.peak_bytes {
            self.peak_bytes = self.live_bytes;
        }
    }

    fn on_take(&mut self, bytes: usize) {
        self.live_bytes = self.live_bytes.saturating_sub(bytes);
    }

    fn begin_step(&mut self) {
        self.step_peak_bytes = self.live_bytes;
    }

    /// Bytes currently held by stash entries.
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    /// Peak stashed bytes within the current step (reset by
    /// `ActivationStash::begin_step`).
    pub fn step_peak_bytes(&self) -> usize {
        self.step_peak_bytes
    }

    /// Peak stashed bytes over the whole run.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Number of `put` operations over the run.
    pub fn total_puts(&self) -> u64 {
        self.total_puts
    }

    /// Total bytes written into the stash over the run.
    pub fn total_put_bytes(&self) -> u64 {
        self.total_put_bytes
    }
}

/// Owns every tensor saved for backward, behind a [`StashPolicy`].
///
/// Lifecycle per step: the session calls [`begin_step`](Self::begin_step),
/// forward `put`s each saved tensor under its layer's [`StashHandle`],
/// backward `take`s (and thereby frees) it. `put` on a live key replaces
/// the entry (repeated forwards without backward, e.g. finite-difference
/// probes, simply overwrite). `take` without a prior `put` is a programmer
/// error and panics with the offending key.
pub struct ActivationStash {
    policy: StashPolicy,
    recompute: bool,
    entries: BTreeMap<String, Entry>,
    /// Per-site storage-width controllers (adaptive policy only), created
    /// lazily on first `put` of each site, in key order.
    ctls: BTreeMap<String, PrecisionController>,
    mem: MemLedger,
}

impl ActivationStash {
    /// A stash with the given storage policy and recompute option.
    pub fn new(policy: StashPolicy, recompute: bool) -> ActivationStash {
        ActivationStash {
            policy,
            recompute,
            entries: BTreeMap::new(),
            ctls: BTreeMap::new(),
            mem: MemLedger::default(),
        }
    }

    /// The default stash of `TrainCtx::new()`: F32 storage, no recompute —
    /// bit-identical to the historical private-field caches.
    pub fn f32_default() -> ActivationStash {
        ActivationStash::new(StashPolicy::F32, false)
    }

    /// The configured storage policy.
    pub fn policy(&self) -> StashPolicy {
        self.policy
    }

    /// Whether the GEMM layers should drop their saved operands and
    /// recompute them from stashed inputs during backward.
    pub fn recompute(&self) -> bool {
        self.recompute
    }

    /// Byte accounting (peaks, live bytes, put traffic).
    pub fn mem(&self) -> &MemLedger {
        &self.mem
    }

    /// Mark a step boundary: the per-step peak restarts from the currently
    /// live bytes (normally zero — backward consumed everything).
    pub fn begin_step(&mut self) {
        self.mem.begin_step();
    }

    /// Drop all live entries and restart the byte accounting (checkpoint
    /// restores land between steps: no in-flight activation survives one,
    /// and the restored run's reported peaks must not include the
    /// pre-restore session's traffic).
    pub fn clear_entries(&mut self) {
        self.entries.clear();
        self.mem = MemLedger::default();
    }

    fn insert(&mut self, h: &StashHandle, shape: Vec<usize>, payload: Payload) {
        if let Some(old) = self.entries.remove(h.key()) {
            self.mem.on_take(old.payload.bytes());
        }
        self.mem.on_put(payload.bytes());
        self.entries.insert(h.key().to_string(), Entry { shape, payload });
    }

    fn remove(&mut self, h: &StashHandle) -> Entry {
        let e = self
            .entries
            .remove(h.key())
            .unwrap_or_else(|| panic!("stash take of {:?} before put", h.key()));
        self.mem.on_take(e.payload.bytes());
        e
    }

    fn encode_codes(data: &[f32], bits: u8) -> Payload {
        let scheme = Scheme::for_range(quantize::max_abs(data), bits);
        if bits <= 8 {
            let mut codes = vec![0i8; data.len()];
            codes_i8(data, &mut codes, scheme);
            Payload::I8 { codes, scheme }
        } else {
            let mut codes = vec![0i16; data.len()];
            codes_i16(data, &mut codes, scheme);
            Payload::I16 { codes, scheme }
        }
    }

    fn encode_f8(data: &[f32], kind: MinifloatKind) -> Payload {
        // Family scale rule: place the codec's max normal at the tensor's
        // max-abs (Format::for_range handles zero/non-finite ranges).
        let s = Format::for_range(kind.family(), quantize::max_abs(data), 8).scale_exp();
        let mut codes = vec![0u8; data.len()];
        quantize::codes_f8(data, &mut codes, kind, s);
        Payload::F8 { codes, kind, s }
    }

    /// Stash a saved tensor under the policy. Takes the tensor by value:
    /// the F32 policy moves the buffer in without a copy (allocation parity
    /// with the historical private-field caches), encoded policies consume
    /// it after the code pass. `iter` drives the adaptive controllers'
    /// QEM/QPA schedule and `ledger` records their decisions
    /// (`stash:<key>`, activation kind).
    pub fn put(&mut self, h: &StashHandle, t: Tensor, iter: u64, ledger: &mut Ledger) {
        let Tensor { shape, data } = t;
        let payload = match self.policy {
            StashPolicy::F32 => Payload::F32(data),
            StashPolicy::Int8 => Self::encode_codes(&data, 8),
            StashPolicy::Int16 => Self::encode_codes(&data, 16),
            StashPolicy::Minifloat(kind) => Self::encode_f8(&data, kind),
            StashPolicy::Adaptive(cfg) => {
                let ctl = self.ctls.entry(h.key().to_string()).or_insert_with(|| {
                    PrecisionController::new(
                        cfg,
                        format!("stash:{}", h.key()),
                        TensorKind::Activation,
                    )
                });
                let bits = if ctl.needs_update(iter) {
                    ctl.maybe_update_from_data(iter, &data, ledger).bits
                } else {
                    ctl.bits()
                };
                if bits <= 16 {
                    Self::encode_codes(&data, bits)
                } else {
                    // no packed storage wider than int16: exact fallback
                    Payload::F32(data)
                }
            }
        };
        self.insert(h, shape, payload);
    }

    /// Take (and free) a stashed tensor, decoding integer codes back to
    /// f32. Panics if the handle was never `put` (backward before forward).
    pub fn take(&mut self, h: &StashHandle) -> Tensor {
        let e = self.remove(h);
        let data = match e.payload {
            Payload::F32(v) => v,
            Payload::I8 { codes, scheme } => {
                let r = scheme.resolution();
                codes.iter().map(|&c| c as f32 * r).collect()
            }
            Payload::I16 { codes, scheme } => {
                let r = scheme.resolution();
                codes.iter().map(|&c| c as f32 * r).collect()
            }
            Payload::F8 { codes, kind, s } => {
                let mut out = vec![0.0f32; codes.len()];
                quantize::decode_f8(&codes, &mut out, kind, s);
                out
            }
            Payload::Mask { .. } | Payload::Indices(_) => {
                panic!("stash entry {:?} is not a tensor (use take_mask/take_indices)", h.key())
            }
        };
        Tensor::from_vec(&e.shape, data)
    }

    /// Stash a boolean mask (1 bit per element, exact under every policy).
    pub fn put_mask(&mut self, h: &StashHandle, mask: &[bool]) {
        let mut bits = vec![0u64; mask.len().div_ceil(64)];
        for (i, &m) in mask.iter().enumerate() {
            if m {
                bits[i / 64] |= 1u64 << (i % 64);
            }
        }
        self.insert(h, vec![mask.len()], Payload::Mask { bits, len: mask.len() });
    }

    /// Take (and free) a stashed mask.
    pub fn take_mask(&mut self, h: &StashHandle) -> Vec<bool> {
        let e = self.remove(h);
        match e.payload {
            Payload::Mask { bits, len } => {
                (0..len).map(|i| (bits[i / 64] >> (i % 64)) & 1 == 1).collect()
            }
            _ => panic!("stash entry {:?} is not a mask", h.key()),
        }
    }

    /// Stash element indices (pooling argmax; stored as u32, exact).
    pub fn put_indices(&mut self, h: &StashHandle, idx: &[usize]) {
        let v: Vec<u32> = idx
            .iter()
            .map(|&i| u32::try_from(i).expect("stash index exceeds u32"))
            .collect();
        self.insert(h, vec![idx.len()], Payload::Indices(v));
    }

    /// Take (and free) stashed indices.
    pub fn take_indices(&mut self, h: &StashHandle) -> Vec<usize> {
        let e = self.remove(h);
        match e.payload {
            Payload::Indices(v) => v.into_iter().map(|i| i as usize).collect(),
            _ => panic!("stash entry {:?} is not an index list", h.key()),
        }
    }

    /// Currently applied storage bit-width per adaptive site, in key order
    /// (empty for non-adaptive policies).
    pub fn stash_bits(&self) -> Vec<(String, u8)> {
        self.ctls
            .iter()
            .map(|(k, c)| (format!("stash:{k}"), c.bits()))
            .collect()
    }

    /// Snapshot every storage controller (checkpointing): site key +
    /// decision state, in key order. Empty for non-adaptive policies.
    pub fn snapshot_controllers(&self) -> Vec<(String, ControllerState)> {
        self.ctls.iter().map(|(k, c)| (k.clone(), c.snapshot())).collect()
    }

    /// Validate a [`snapshot_controllers`](Self::snapshot_controllers)
    /// record against this stash without mutating anything — restores fail
    /// *before* any other session state is overwritten. An empty snapshot
    /// (v1/v2 checkpoints, non-adaptive saves) is compatible with any
    /// policy; a non-empty one requires an adaptive policy here.
    pub fn check_controllers(&self, st: &[(String, ControllerState)]) -> Result<()> {
        if !st.is_empty() && self.policy.config().is_none() {
            bail!(
                "checkpoint carries {} stash controllers but this session's \
                 --act-bits policy is {:?} (expected adaptive)",
                st.len(),
                self.policy.label()
            );
        }
        Ok(())
    }

    /// Restore a controller snapshot: the stash's controller set becomes
    /// exactly the checkpoint's (sites the restored run never stashed are
    /// recreated on their next `put`). Errors — without mutating — on a
    /// policy mismatch; see [`check_controllers`](Self::check_controllers).
    pub fn restore_controllers(&mut self, st: &[(String, ControllerState)]) -> Result<()> {
        self.check_controllers(st)?;
        self.ctls.clear();
        if let Some(cfg) = self.policy.config() {
            for (key, state) in st {
                let mut c = PrecisionController::new(
                    cfg,
                    format!("stash:{key}"),
                    TensorKind::Activation,
                );
                c.restore(state);
                self.ctls.insert(key.clone(), c);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn randt(seed: u64, shape: &[usize], std: f32) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    #[test]
    fn f32_policy_roundtrips_verbatim() {
        let mut s = ActivationStash::f32_default();
        let mut ledger = Ledger::new();
        let h = StashHandle::new("fc0", "x");
        let t = randt(0, &[4, 8], 1.0);
        s.put(&h, t.clone(), 0, &mut ledger);
        assert_eq!(s.mem().live_bytes(), 4 * 32);
        let back = s.take(&h);
        assert_eq!(back, t);
        assert_eq!(s.mem().live_bytes(), 0);
        assert_eq!(s.mem().peak_bytes(), 4 * 32);
    }

    #[test]
    fn int8_int16_error_bounded_by_half_resolution() {
        let t = randt(1, &[16, 32], 2.0);
        let mut ledger = Ledger::new();
        for (policy, bits) in [(StashPolicy::Int8, 8u8), (StashPolicy::Int16, 16u8)] {
            let mut s = ActivationStash::new(policy, false);
            let h = StashHandle::new("l", "x");
            s.put(&h, t.clone(), 0, &mut ledger);
            let back = s.take(&h);
            let sch = Scheme::for_range(t.max_abs(), bits);
            let half = sch.resolution() / 2.0;
            for (&a, &b) in t.data.iter().zip(&back.data) {
                assert!((a - b).abs() <= half + 1e-9, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn minifloat_policy_byte_sized_with_relative_error() {
        let t = randt(7, &[16, 32], 2.0);
        let mut ledger = Ledger::new();
        for kind in [MinifloatKind::E4M3, MinifloatKind::E5M2] {
            let mut s = ActivationStash::new(StashPolicy::Minifloat(kind), false);
            let h = StashHandle::new("l", "x");
            s.put(&h, t.clone(), 0, &mut ledger);
            // 1 byte/element, like int8.
            assert_eq!(s.mem().live_bytes(), 16 * 32 + 8, "{}", kind.label());
            let back = s.take(&h);
            // Half-ulp relative error for normals plus the scaled subnormal
            // step as the absolute floor near zero.
            let fmt = Format::for_range(kind.family(), t.max_abs(), 8);
            for (&a, &b) in t.data.iter().zip(&back.data) {
                let bound = a.abs() * 0.125 + fmt.resolution();
                assert!((a - b).abs() <= bound, "{}: {a} vs {b}", kind.label());
            }
        }
    }

    #[test]
    fn int8_storage_is_quarter_of_f32() {
        let t = randt(2, &[64, 64], 1.0);
        let mut ledger = Ledger::new();
        let mut f = ActivationStash::new(StashPolicy::F32, false);
        let mut q = ActivationStash::new(StashPolicy::Int8, false);
        let h = StashHandle::new("l", "x");
        f.put(&h, t.clone(), 0, &mut ledger);
        q.put(&h, t.clone(), 0, &mut ledger);
        assert_eq!(f.mem().live_bytes(), 4 * 4096);
        assert!(q.mem().live_bytes() < 4096 + 64, "{}", q.mem().live_bytes());
    }

    #[test]
    fn adaptive_policy_records_stash_ledger_keys() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        cfg.pin_forward_bits = false;
        let mut s = ActivationStash::new(StashPolicy::Adaptive(cfg), false);
        let mut ledger = Ledger::new();
        let h = StashHandle::new("conv0", "patches");
        let t = randt(3, &[8, 27], 1.0);
        s.put(&h, t.clone(), 0, &mut ledger);
        let _ = s.take(&h);
        let key = ("stash:conv0/patches".to_string(), TensorKind::Activation);
        assert!(ledger.tensors.contains_key(&key), "{:?}", ledger.tensors.keys());
        assert_eq!(s.stash_bits().len(), 1);
    }

    #[test]
    fn adaptive_escalates_long_tail_to_wider_storage() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        cfg.pin_forward_bits = false;
        let mut s = ActivationStash::new(StashPolicy::Adaptive(cfg), false);
        let mut ledger = Ledger::new();
        let mut t = randt(4, &[4096], 0.05);
        for (i, v) in t.data.iter_mut().enumerate() {
            if i % 64 == 0 {
                *v *= 400.0;
            }
        }
        let h = StashHandle::new("fc2", "x");
        s.put(&h, t.clone(), 0, &mut ledger);
        let bits = s.stash_bits()[0].1;
        assert!(bits >= 16, "long-tail stash must escalate, got int{bits}");
        // and the decode error respects the escalated width
        let back = s.take(&h);
        let sch = Scheme::for_range(t.max_abs(), bits.min(16));
        let half = sch.resolution() / 2.0;
        for (&a, &b) in t.data.iter().zip(&back.data) {
            assert!((a - b).abs() <= half + 1e-9);
        }
    }

    #[test]
    fn masks_and_indices_roundtrip_exactly() {
        let mut s = ActivationStash::new(StashPolicy::Int8, false);
        let hm = StashHandle::new("relu0", "mask");
        let hi = StashHandle::new("pool0", "argmax");
        let mask: Vec<bool> = (0..130).map(|i| i % 3 == 0).collect();
        let idx: Vec<usize> = (0..70).map(|i| i * 13).collect();
        s.put_mask(&hm, &mask);
        s.put_indices(&hi, &idx);
        // 130 bits → 3 u64 words = 24 bytes; 70 u32 = 280 bytes
        assert_eq!(s.mem().live_bytes(), 24 + 280);
        assert_eq!(s.take_mask(&hm), mask);
        assert_eq!(s.take_indices(&hi), idx);
    }

    #[test]
    fn put_replaces_and_step_peak_resets() {
        let mut s = ActivationStash::f32_default();
        let mut ledger = Ledger::new();
        let h = StashHandle::new("l", "x");
        let t = randt(5, &[10], 1.0);
        s.put(&h, t.clone(), 0, &mut ledger);
        s.put(&h, t.clone(), 0, &mut ledger); // replace, not leak
        assert_eq!(s.mem().live_bytes(), 40);
        assert_eq!(s.mem().step_peak_bytes(), 40);
        let _ = s.take(&h);
        s.begin_step();
        assert_eq!(s.mem().step_peak_bytes(), 0);
        assert_eq!(s.mem().peak_bytes(), 40);
        assert_eq!(s.mem().total_puts(), 2);
    }

    #[test]
    #[should_panic(expected = "before put")]
    fn take_before_put_panics_with_key() {
        let mut s = ActivationStash::f32_default();
        let _ = s.take(&StashHandle::new("l", "x"));
    }

    #[test]
    fn controller_snapshot_roundtrip_and_policy_mismatch() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        cfg.pin_forward_bits = false;
        let mut s = ActivationStash::new(StashPolicy::Adaptive(cfg), false);
        let mut ledger = Ledger::new();
        let h = StashHandle::new("fc0", "x");
        s.put(&h, randt(6, &[256], 1.0), 0, &mut ledger);
        let snap = s.snapshot_controllers();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].0, "fc0/x");

        let mut s2 = ActivationStash::new(StashPolicy::Adaptive(cfg), false);
        s2.restore_controllers(&snap).unwrap();
        assert_eq!(s2.snapshot_controllers(), snap);

        // non-adaptive target rejects a controller-carrying snapshot
        let s3 = ActivationStash::new(StashPolicy::Int8, false);
        assert!(s3.check_controllers(&snap).is_err());
        // …but an empty snapshot (v1/v2 checkpoints) is fine everywhere
        assert!(s3.check_controllers(&[]).is_ok());
    }

    #[test]
    fn policy_parse_matches_cli_forms() {
        assert!(matches!(StashPolicy::parse("f32", 100).unwrap(), StashPolicy::F32));
        assert!(matches!(StashPolicy::parse("8", 100).unwrap(), StashPolicy::Int8));
        assert!(matches!(StashPolicy::parse("int16", 100).unwrap(), StashPolicy::Int16));
        assert!(matches!(
            StashPolicy::parse("e4m3", 100).unwrap(),
            StashPolicy::Minifloat(MinifloatKind::E4M3)
        ));
        assert!(matches!(
            StashPolicy::parse("e5m2", 100).unwrap(),
            StashPolicy::Minifloat(MinifloatKind::E5M2)
        ));
        match StashPolicy::parse("adaptive", 100).unwrap() {
            StashPolicy::Adaptive(cfg) => {
                assert_eq!(cfg.init_phase_iters, 10);
                assert!(!cfg.pin_forward_bits);
            }
            other => panic!("unexpected policy {other:?}"),
        }
        assert!(StashPolicy::parse("int7", 100).is_err());
    }
}
