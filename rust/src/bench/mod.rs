//! Micro-benchmark harness (offline substitute for criterion — DESIGN.md §2).
//!
//! Warmup + fixed-duration sampling, trimmed statistics, and a comparison
//! table printer. Used by `rust/benches/*` (cargo bench, harness = false)
//! and by the experiment drivers that need timing (Table 3, Fig 10).

pub mod loadgen;

use crate::util::stats;
use crate::util::Timer;

/// One benchmark's samples.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    /// Seconds per iteration.
    pub secs: Vec<f64>,
}

impl Sample {
    pub fn median(&self) -> f64 {
        stats::median(&self.secs)
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.secs)
    }

    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.secs)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop sampling after this much wall time.
    pub budget_secs: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, min_iters: 5, max_iters: 200, budget_secs: 1.0 }
    }
}

impl Bencher {
    /// Quick preset for sweeps with many points.
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, min_iters: 3, max_iters: 50, budget_secs: 0.25 }
    }

    /// Run a closure repeatedly; the closure must do one full unit of work.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> Sample {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut secs = Vec::new();
        let budget = Timer::start();
        while secs.len() < self.min_iters
            || (secs.len() < self.max_iters && budget.secs() < self.budget_secs)
        {
            let t = Timer::start();
            f();
            secs.push(t.secs());
        }
        Sample { name: name.to_string(), secs }
    }
}

/// Pretty-print a speedup table: rows of (label, baseline, contender),
/// reporting median seconds and the baseline/contender ratio.
pub fn print_speedup_table(title: &str, rows: &[(String, &Sample, &Sample)]) {
    println!("\n== {title}");
    println!(
        "{:<18} {:>12} {:>12} {:>9}",
        "case", "base (ms)", "new (ms)", "speedup"
    );
    for (label, base, new) in rows {
        println!(
            "{:<18} {:>12.4} {:>12.4} {:>8.2}x",
            label,
            base.median() * 1e3,
            new.median() * 1e3,
            base.median() / new.median().max(1e-12)
        );
    }
}

/// GFLOP/s helper for GEMM-shaped work (2·m·k·n flops per run).
pub fn gemm_gflops(m: usize, k: usize, n: usize, secs_per_iter: f64) -> f64 {
    (2.0 * m as f64 * k as f64 * n as f64) / secs_per_iter / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        let b = Bencher { warmup_iters: 1, min_iters: 3, max_iters: 5, budget_secs: 0.01 };
        let mut count = 0usize;
        let s = b.run("noop", || count += 1);
        assert!(s.secs.len() >= 3);
        assert!(count >= 4); // warmup + samples
        assert!(s.median() >= 0.0);
    }

    #[test]
    fn slower_work_measures_slower() {
        let b = Bencher { warmup_iters: 1, min_iters: 5, max_iters: 10, budget_secs: 0.05 };
        // black_box the loop bound so release builds cannot constant-fold
        let fast = b.run("fast", || {
            let n = std::hint::black_box(100u64);
            std::hint::black_box((0..n).map(std::hint::black_box).sum::<u64>());
        });
        let slow = b.run("slow", || {
            let n = std::hint::black_box(1_000_000u64);
            std::hint::black_box((0..n).map(std::hint::black_box).sum::<u64>());
        });
        assert!(slow.median() > fast.median());
    }

    #[test]
    fn gflops_math() {
        let g = gemm_gflops(1000, 1000, 1000, 1.0);
        assert!((g - 2.0).abs() < 1e-9);
    }
}
