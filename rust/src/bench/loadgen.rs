//! Deterministic load generation for the serving tier (DESIGN.md
//! §Serving-Tier; protocol: EXPERIMENTS.md §Serve-SLO).
//!
//! Three pieces:
//!
//! - [`Trace`] — a seeded **open-loop Poisson arrival process**:
//!   exponential inter-arrival times at a given offered QPS, plus a
//!   priority lane per request. Same seed ⇒ byte-identical trace
//!   (pinned by test), so SLO numbers are comparable across PRs.
//! - [`simulate`] — a **virtual-time replay** of a
//!   [`SchedPolicy`](crate::serve::SchedPolicy) under a deterministic
//!   cost model: it drives exactly the scheduler code the live server
//!   runs (admission control, eviction, expiry, batch formation) with a
//!   simulated clock and fixed per-batch cost, so its output —
//!   served/shed counts and latency percentiles — is bit-reproducible.
//!   This is what makes scheduler policies comparable without timing
//!   noise, and it doubles as a conformance harness.
//! - [`drive`] — the same trace played **against a real
//!   [`InferenceServer`]** in wall-clock time: submissions fire at the
//!   trace's arrival offsets without waiting for responses (open loop —
//!   overload is offered, not throttled), latencies are stamped at the
//!   worker's reply instant, and every request is accounted served or
//!   shed.
//!
//! `benches/bench_serve_slo.rs` sweeps offered QPS × policy through
//! both paths into `results/serve_slo.csv`.

use std::time::{Duration, Instant};

use crate::serve::{
    Admit, InferenceServer, Plan, Reply, SchedConfig, SchedCtx, SchedPolicy, SchedEntry,
    SubmitOpts,
};
use crate::util::stats::percentile;
use crate::util::Pcg32;

/// A pre-generated open-loop arrival trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Trace {
    /// Seed the trace was generated from.
    pub seed: u64,
    /// Offered arrival rate in requests/second (λ of the Poisson process).
    pub offered_qps: u64,
    /// Arrival offsets from t₀ in microseconds, non-decreasing.
    pub arrivals_us: Vec<u64>,
    /// Priority lane per request (uniform over `lanes`).
    pub lanes: Vec<usize>,
}

impl Trace {
    /// Generate `n` Poisson arrivals at `offered_qps` requests/second.
    /// Deterministic: the trace is a pure function of the arguments.
    pub fn poisson(seed: u64, offered_qps: u64, n: usize, lanes: usize) -> Trace {
        assert!(offered_qps > 0, "offered_qps must be positive");
        assert!(lanes >= 1, "need at least one lane");
        let mut rng = Pcg32::new(seed, 0x10ad);
        let mut t = 0.0f64;
        let mut arrivals_us = Vec::with_capacity(n);
        let mut lane_v = Vec::with_capacity(n);
        for _ in 0..n {
            // Exponential inter-arrival: −ln(1−u)/λ, u ∈ [0,1).
            let u = rng.uniform() as f64;
            t += -(1.0 - u).ln() / offered_qps as f64;
            arrivals_us.push((t * 1e6).round() as u64);
            lane_v.push(rng.below(lanes));
        }
        Trace { seed, offered_qps, arrivals_us, lanes: lane_v }
    }

    /// Request count.
    pub fn len(&self) -> usize {
        self.arrivals_us.len()
    }

    /// True for the empty trace.
    pub fn is_empty(&self) -> bool {
        self.arrivals_us.is_empty()
    }

    /// FNV-1a checksum over the arrival offsets and lanes — a compact
    /// fingerprint for the CSV, pinning trace identity across PRs.
    pub fn fnv(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1_0000_01b3);
            }
        };
        eat(self.seed);
        eat(self.offered_qps);
        for (&t, &l) in self.arrivals_us.iter().zip(&self.lanes) {
            eat(t);
            eat(l as u64);
        }
        h
    }
}

/// Deterministic cost model for [`simulate`]: a batch of `n` rows takes
/// `batch_overhead_us + n · per_row_us` virtual microseconds.
#[derive(Clone, Copy, Debug)]
pub struct SimCost {
    /// Fixed per-dispatch cost (queue handoff, stacking, rescale setup).
    pub batch_overhead_us: u64,
    /// Marginal cost per batched row.
    pub per_row_us: u64,
}

/// Outcome of one load run (simulated or real).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    /// Requests offered (trace length).
    pub submitted: u64,
    /// Requests answered with logits.
    pub served: u64,
    /// Admitted requests later shed (evicted / expired / shutdown).
    pub shed: u64,
    /// Requests refused at admission (queue full, deadline unmeetable).
    pub shed_admission: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Latency percentiles over *served* requests, microseconds.
    pub p50_us: f64,
    /// 99th percentile latency (µs).
    pub p99_us: f64,
    /// 99.9th percentile latency (µs) — the overload tail.
    pub p999_us: f64,
    /// Served requests per second over the span from first arrival to
    /// last completion.
    pub goodput_qps: f64,
}

impl LoadReport {
    /// Every offered request must be accounted exactly once. The SLO
    /// bench fails on any violation.
    pub fn accounted(&self) -> bool {
        self.submitted == self.served + self.shed + self.shed_admission
    }

    fn finish(&mut self, mut lat_us: Vec<f64>, span_secs: f64) {
        lat_us.sort_by(f64::total_cmp);
        self.p50_us = percentile(&lat_us, 50.0);
        self.p99_us = percentile(&lat_us, 99.0);
        self.p999_us = percentile(&lat_us, 99.9);
        self.goodput_qps = if span_secs > 0.0 { self.served as f64 / span_secs } else { 0.0 };
    }
}

/// Replay `trace` against a scheduler policy in virtual time. Drives the
/// *same* `Scheduler` implementation the live server runs; `deadline_us`
/// (when set) attaches a relative deadline to every request, enabling
/// reject-on-admission and dispatch-time expiry. Fully deterministic:
/// same arguments ⇒ identical report, bit for bit.
pub fn simulate(
    policy: SchedPolicy,
    scfg: SchedConfig,
    workers: usize,
    deadline_us: Option<u64>,
    trace: &Trace,
    cost: SimCost,
) -> LoadReport {
    assert!(workers >= 1);
    let base = Instant::now(); // cancels in every scheduler comparison
    let at = |us: u64| base + Duration::from_micros(us);
    let mut sched = policy.build(scfg);
    // Deterministic service estimate (the live server's EWMA, without
    // the measurement noise).
    let est_req_secs = (cost.per_row_us as f64 + cost.batch_overhead_us as f64 / scfg.max_batch as f64) * 1e-6;
    let ctx = |now_us: u64| SchedCtx { now: at(now_us), est_req_secs, workers };

    let mut report = LoadReport { submitted: trace.len() as u64, ..LoadReport::default() };
    let mut free_at = vec![0u64; workers];
    let mut arrival_of = vec![0u64; trace.len()];
    let mut lat_us: Vec<f64> = Vec::new();
    let mut next_arrival = 0usize;
    let mut now = 0u64;
    let mut last_done = 0u64;
    loop {
        // Admit every arrival due now.
        while next_arrival < trace.len() && trace.arrivals_us[next_arrival] <= now {
            let id = next_arrival as u64;
            arrival_of[next_arrival] = trace.arrivals_us[next_arrival];
            let e = SchedEntry {
                id,
                lane: trace.lanes[next_arrival],
                deadline: deadline_us.map(|d| at(trace.arrivals_us[next_arrival] + d)),
                arrived: at(trace.arrivals_us[next_arrival]),
            };
            match sched.admit(e, &ctx(now)) {
                Admit::Queued => {}
                Admit::Evict { .. } => report.shed += 1,
                Admit::Shed(_) => report.shed_admission += 1,
            }
            next_arrival += 1;
        }
        // Offer the queue to every idle worker.
        let mut hold: Option<u64> = None;
        for w in 0..workers {
            if free_at[w] > now {
                continue;
            }
            loop {
                match sched.plan(&ctx(now)) {
                    Plan::Dispatch { batch, expired } => {
                        report.shed += expired.len() as u64;
                        if batch.is_empty() {
                            continue; // pure expiry made progress; replan
                        }
                        let secs = cost.batch_overhead_us + cost.per_row_us * batch.len() as u64;
                        let done = now + secs;
                        free_at[w] = done;
                        last_done = last_done.max(done);
                        report.batches += 1;
                        report.served += batch.len() as u64;
                        for id in batch {
                            lat_us.push((done - arrival_of[id as usize]) as f64);
                        }
                        break; // this worker is busy now
                    }
                    Plan::Wait(t) => {
                        if let Some(t) = t {
                            let t_us = t.duration_since(base).as_micros() as u64;
                            hold = Some(hold.map_or(t_us, |h: u64| h.min(t_us)));
                        }
                        break;
                    }
                }
            }
        }
        // Advance virtual time to the next event.
        let mut next: Option<u64> = None;
        let mut consider = |t: u64| {
            if t > now {
                next = Some(next.map_or(t, |n: u64| n.min(t)));
            }
        };
        if next_arrival < trace.len() {
            consider(trace.arrivals_us[next_arrival]);
        }
        if sched.len() > 0 || free_at.iter().any(|&f| f > now) {
            for &f in &free_at {
                consider(f);
            }
        }
        if let Some(h) = hold {
            consider(h.max(now + 1));
        }
        match next {
            Some(t) => now = t,
            None => break, // no arrivals, empty queue, idle workers
        }
    }
    report.finish(lat_us, last_done as f64 * 1e-6);
    report
}

/// Play `trace` against a real server, open loop: each request is
/// submitted at its arrival offset via the non-blocking path (overload
/// is *offered* — a full queue sheds instead of throttling the
/// generator), `input(i)` supplies the i-th sample, and latency is
/// measured from submission to the worker's reply stamp. Blocks until
/// every request resolves.
pub fn drive(
    server: &InferenceServer,
    trace: &Trace,
    deadline_us: Option<u64>,
    input: impl Fn(usize) -> Vec<f32>,
) -> LoadReport {
    let mut report = LoadReport { submitted: trace.len() as u64, ..LoadReport::default() };
    let (px, prx) = std::sync::mpsc::channel();
    let collector = std::thread::spawn(move || {
        // Replies are timestamped by the worker, so collecting lazily in
        // submission order does not distort latency.
        let mut lat_us = Vec::new();
        let (mut served, mut shed) = (0u64, 0u64);
        let mut last_done: Option<Instant> = None;
        while let Ok((submitted_at, pending)) = prx.recv() {
            let pending: crate::serve::Pending = pending;
            match pending.recv() {
                Ok(Reply::Logits(_, at)) => {
                    served += 1;
                    lat_us.push(at.duration_since(submitted_at).as_secs_f64() * 1e6);
                    last_done = Some(last_done.map_or(at, |l: Instant| l.max(at)));
                }
                Ok(Reply::Shed(_, _)) | Err(_) => shed += 1,
            }
        }
        (served, shed, lat_us, last_done)
    });

    let t0 = Instant::now();
    for i in 0..trace.len() {
        let due = t0 + Duration::from_micros(trace.arrivals_us[i]);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let opts = SubmitOpts { lane: trace.lanes[i], deadline_us, model: None };
        match server.submit_opts(input(i), opts) {
            Ok(p) => px.send((Instant::now(), p)).expect("collector alive"),
            Err(_) => report.shed_admission += 1,
        }
    }
    drop(px);
    let (served, shed, lat_us, last_done) = collector.join().expect("collector thread");
    report.served = served;
    report.shed = shed;
    let span = last_done.map_or(0.0, |l| l.duration_since(t0).as_secs_f64());
    report.finish(lat_us, span);
    report
}

/// The shared `results/serve_slo.csv` row layout — one formatting path
/// used by both the bench and the determinism test, so "same seed ⇒
/// identical row" is pinned end to end.
pub const SLO_CSV_HEADER: [&str; 13] = [
    "mode", "scheduler", "offered_qps", "requests", "trace_fnv", "workers", "max_batch",
    "deadline_us", "served", "shed", "p50_us", "p99_us", "p999_us",
];

/// Format one CSV row (see [`SLO_CSV_HEADER`]).
pub fn slo_csv_row(
    mode: &str,
    policy: SchedPolicy,
    trace: &Trace,
    workers: usize,
    max_batch: usize,
    deadline_us: Option<u64>,
    r: &LoadReport,
) -> Vec<String> {
    vec![
        mode.to_string(),
        policy.label().to_string(),
        trace.offered_qps.to_string(),
        trace.len().to_string(),
        format!("{:016x}", trace.fnv()),
        workers.to_string(),
        max_batch.to_string(),
        deadline_us.map_or("none".to_string(), |d| d.to_string()),
        r.served.to_string(),
        (r.shed + r.shed_admission).to_string(),
        format!("{:.1}", r.p50_us),
        format!("{:.1}", r.p99_us),
        format!("{:.1}", r.p999_us),
    ]
}
