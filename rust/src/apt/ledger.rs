//! Run ledger: per-tensor records of every QPA decision, powering Fig 8
//! (adjustment frequency, bit-width mix over training) and the Table 1
//! int8/int16/int24 percentage columns.

use std::collections::BTreeMap;

use crate::fixedpoint::{FormatFamily, TensorKind};

/// One QPA event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub iter: u64,
    pub bits: u8,
    pub interval: u64,
    pub error: f64,
}

/// Per-tensor history.
#[derive(Clone, Debug, Default)]
pub struct TensorHistory {
    pub events: Vec<Event>,
    /// (iteration, bits) samples — one per iteration bucket for mix curves.
    pub bits_trace: Vec<(u64, u8)>,
    /// Iterations at which the QPA interval hit the `cfg.max_interval`
    /// ceiling (the fully-converged-tensor clamp; see `qpa::interval`).
    pub clamps: Vec<u64>,
    /// Format family this tensor's controller adapts within — `bits` in
    /// the events are fixed-point widths only under `FixedPoint`; other
    /// families pin them to the storage width (DESIGN.md §Formats).
    pub family: FormatFamily,
}

/// Identifies one quantized tensor: layer name + role.
pub type TensorId = (String, TensorKind);

/// The ledger.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub tensors: BTreeMap<TensorId, TensorHistory>,
    pub total_iters: u64,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_event(&mut self, layer: &str, kind: TensorKind, ev: Event) {
        self.record_event_fmt(layer, kind, ev, FormatFamily::FixedPoint);
    }

    /// [`record_event`](Self::record_event) with the controller's format
    /// family — keeps the mix reporting honest for non-fixed-point tensors
    /// (whose `bits` are storage widths, not precision choices).
    pub fn record_event_fmt(
        &mut self,
        layer: &str,
        kind: TensorKind,
        ev: Event,
        family: FormatFamily,
    ) {
        let hist = self.tensors.entry((layer.to_string(), kind)).or_default();
        hist.family = family;
        hist.events.push(ev);
    }

    /// Record that the QPA update interval was clamped to the configured
    /// `max_interval` ceiling at `iter` — the tensor's error and range delta
    /// were both ≈0, so the unclamped Itv formula would have postponed the
    /// next probe (nearly) forever. Emitted by the controller so converged
    /// tensors stay observable in the run record.
    pub fn record_clamp(&mut self, layer: &str, kind: TensorKind, iter: u64) {
        self.tensors
            .entry((layer.to_string(), kind))
            .or_default()
            .clamps
            .push(iter);
    }

    /// Total interval-clamp events across all tensors.
    pub fn total_clamps(&self) -> u64 {
        self.tensors.values().map(|h| h.clamps.len() as u64).sum()
    }

    /// Sample the applied bit-width at an iteration (call once per iter or
    /// per bucket).
    pub fn trace_bits(&mut self, layer: &str, kind: TensorKind, iter: u64, bits: u8) {
        self.tensors
            .entry((layer.to_string(), kind))
            .or_default()
            .bits_trace
            .push((iter, bits));
    }

    pub fn set_total_iters(&mut self, iters: u64) {
        self.total_iters = iters;
    }

    /// Fraction of iterations that triggered a QPA update, over all tensors
    /// of a kind, bucketed into `buckets` equal spans (Fig 8a).
    pub fn adjustment_frequency(&self, kind: TensorKind, buckets: usize) -> Vec<f64> {
        let mut counts = vec![0u64; buckets];
        let mut tensors = 0u64;
        let total = self.total_iters.max(1);
        for ((_, k), hist) in &self.tensors {
            if *k != kind {
                continue;
            }
            tensors += 1;
            for ev in &hist.events {
                let b = ((ev.iter * buckets as u64) / total).min(buckets as u64 - 1) as usize;
                counts[b] += 1;
            }
        }
        let span = total as f64 / buckets as f64;
        counts
            .iter()
            .map(|&c| c as f64 / (span * tensors.max(1) as f64))
            .collect()
    }

    /// Final bit-width distribution over tensors of a kind (Table 1 columns):
    /// map bits → fraction of tensors.
    pub fn final_bits_mix(&self, kind: TensorKind) -> BTreeMap<u8, f64> {
        let mut counts: BTreeMap<u8, u64> = BTreeMap::new();
        let mut n = 0u64;
        for ((_, k), hist) in &self.tensors {
            if *k != kind {
                continue;
            }
            if let Some(ev) = hist.events.last() {
                *counts.entry(ev.bits).or_default() += 1;
                n += 1;
            }
        }
        counts
            .into_iter()
            .map(|(b, c)| (b, c as f64 / n.max(1) as f64))
            .collect()
    }

    /// Time-weighted bit mix over the whole run (the paper's "12.56% of
    /// activation gradients quantified to int8" style number): for each
    /// tensor, each iteration contributes the bits applied at it.
    pub fn timewise_bits_mix(&self, kind: TensorKind) -> BTreeMap<u8, f64> {
        self.timewise_bits_mix_where(kind, |_| true)
    }

    /// [`timewise_bits_mix`](Self::timewise_bits_mix) restricted to tensors
    /// whose layer name passes `keep` — how the reporting splits compute
    /// tensors from the `comm:*` (data-parallel) and `stash:*`
    /// (activation-storage) subsystems without cloning the ledger.
    pub fn timewise_bits_mix_where(
        &self,
        kind: TensorKind,
        keep: impl Fn(&str) -> bool,
    ) -> BTreeMap<u8, f64> {
        let mut weight: BTreeMap<u8, f64> = BTreeMap::new();
        let mut total = 0.0f64;
        let end = self.total_iters;
        for ((name, k), hist) in &self.tensors {
            if *k != kind || !keep(name) {
                continue;
            }
            for (i, ev) in hist.events.iter().enumerate() {
                let until = hist.events.get(i + 1).map(|e| e.iter).unwrap_or(end);
                let span = until.saturating_sub(ev.iter) as f64;
                *weight.entry(ev.bits).or_default() += span;
                total += span;
            }
        }
        weight
            .into_iter()
            .map(|(b, w)| (b, w / total.max(1.0)))
            .collect()
    }

    /// Format-aware sibling of
    /// [`timewise_bits_mix_where`](Self::timewise_bits_mix_where): keys are
    /// format labels (`int8`/`int16`/… for fixed-point widths, `e4m3` /
    /// `e5m2` / `int4` for the fixed-width families). For ledgers that only
    /// ever saw fixed-point tensors, the label set is exactly the
    /// `int{bits}` image of the bits mix.
    pub fn timewise_format_mix_where(
        &self,
        kind: TensorKind,
        keep: impl Fn(&str) -> bool,
    ) -> BTreeMap<String, f64> {
        let mut weight: BTreeMap<String, f64> = BTreeMap::new();
        let mut total = 0.0f64;
        let end = self.total_iters;
        for ((name, k), hist) in &self.tensors {
            if *k != kind || !keep(name) {
                continue;
            }
            for (i, ev) in hist.events.iter().enumerate() {
                let until = hist.events.get(i + 1).map(|e| e.iter).unwrap_or(end);
                let span = until.saturating_sub(ev.iter) as f64;
                let label = match hist.family {
                    FormatFamily::FixedPoint => format!("int{}", ev.bits),
                    other => other.label().to_string(),
                };
                *weight.entry(label).or_default() += span;
                total += span;
            }
        }
        weight.into_iter().map(|(b, w)| (b, w / total.max(1.0))).collect()
    }

    /// Do any recorded tensors of `kind` passing `keep` use a
    /// non-fixed-point family? (The mix strings switch to format labels
    /// only when this is true, keeping the historical output pinned.)
    pub fn has_non_fixed_formats_where(
        &self,
        kind: TensorKind,
        keep: impl Fn(&str) -> bool,
    ) -> bool {
        self.tensors.iter().any(|((name, k), hist)| {
            *k == kind && keep(name) && hist.family != FormatFamily::FixedPoint
        })
    }

    /// Percentage of *iterations* at each bit-width for one kind, bucketed
    /// over training (Fig 8b's int8-share curve).
    pub fn bits_share_over_time(&self, kind: TensorKind, bits: u8, buckets: usize) -> Vec<f64> {
        let total = self.total_iters.max(1);
        let mut hit = vec![0u64; buckets];
        let mut all = vec![0u64; buckets];
        for ((_, k), hist) in &self.tensors {
            if *k != kind {
                continue;
            }
            for &(it, b) in &hist.bits_trace {
                let bucket = ((it * buckets as u64) / total).min(buckets as u64 - 1) as usize;
                all[bucket] += 1;
                if b == bits {
                    hit[bucket] += 1;
                }
            }
        }
        hit.iter()
            .zip(&all)
            .map(|(&h, &a)| if a == 0 { 0.0 } else { h as f64 / a as f64 })
            .collect()
    }

    /// Total QPA updates across all tensors (numerator of the paper's
    /// "0.01%–2% of iterations activate QEM/QPA").
    pub fn total_updates(&self) -> u64 {
        self.tensors.values().map(|h| h.events.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(iter: u64, bits: u8) -> Event {
        Event { iter, bits, interval: 1, error: 0.0 }
    }

    #[test]
    fn final_mix_counts_last_event() {
        let mut l = Ledger::new();
        l.record_event("a", TensorKind::Gradient, ev(0, 8));
        l.record_event("a", TensorKind::Gradient, ev(10, 16));
        l.record_event("b", TensorKind::Gradient, ev(0, 8));
        l.set_total_iters(100);
        let mix = l.final_bits_mix(TensorKind::Gradient);
        assert_eq!(mix[&16], 0.5);
        assert_eq!(mix[&8], 0.5);
    }

    #[test]
    fn timewise_mix_weights_by_span() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        // 8 bits for iters 0..50, 16 bits for 50..100
        l.record_event("a", TensorKind::Gradient, ev(0, 8));
        l.record_event("a", TensorKind::Gradient, ev(50, 16));
        let mix = l.timewise_bits_mix(TensorKind::Gradient);
        assert!((mix[&8] - 0.5).abs() < 1e-9);
        assert!((mix[&16] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn adjustment_frequency_decays() {
        let mut l = Ledger::new();
        l.set_total_iters(1000);
        // dense updates early, sparse late
        for i in 0..100 {
            l.record_event("a", TensorKind::Gradient, ev(i, 8));
        }
        l.record_event("a", TensorKind::Gradient, ev(900, 8));
        let f = l.adjustment_frequency(TensorKind::Gradient, 10);
        assert!(f[0] > 0.9);
        assert!(f[9] < 0.05);
    }

    #[test]
    fn bits_share_over_time() {
        let mut l = Ledger::new();
        l.set_total_iters(10);
        for it in 0..10u64 {
            l.trace_bits("a", TensorKind::Gradient, it, if it < 5 { 8 } else { 16 });
        }
        let share8 = l.bits_share_over_time(TensorKind::Gradient, 8, 2);
        assert_eq!(share8, vec![1.0, 0.0]);
    }

    #[test]
    fn clamp_events_are_recorded_per_tensor() {
        let mut l = Ledger::new();
        l.record_clamp("a", TensorKind::Gradient, 5);
        l.record_clamp("a", TensorKind::Gradient, 90);
        l.record_clamp("b", TensorKind::Gradient, 7);
        assert_eq!(l.total_clamps(), 3);
        let hist = &l.tensors[&("a".to_string(), TensorKind::Gradient)];
        assert_eq!(hist.clamps, vec![5, 90]);
        // clamps do not count as QPA updates
        assert_eq!(l.total_updates(), 0);
    }

    #[test]
    fn filtered_mix_splits_subsystems_without_cloning() {
        let mut l = Ledger::new();
        l.set_total_iters(100);
        l.record_event("conv0", TensorKind::Gradient, ev(0, 8));
        l.record_event("comm:fc0.0", TensorKind::Gradient, ev(0, 16));
        let compute =
            l.timewise_bits_mix_where(TensorKind::Gradient, |n| !n.starts_with("comm:"));
        assert_eq!(compute[&8], 1.0);
        assert!(!compute.contains_key(&16));
        let comm = l.timewise_bits_mix_where(TensorKind::Gradient, |n| n.starts_with("comm:"));
        assert_eq!(comm[&16], 1.0);
        // the unfiltered method is the keep-everything case
        assert_eq!(l.timewise_bits_mix(TensorKind::Gradient)[&8], 0.5);
    }

    #[test]
    fn kinds_are_separate() {
        let mut l = Ledger::new();
        l.set_total_iters(10);
        l.record_event("a", TensorKind::Weight, ev(0, 8));
        l.record_event("a", TensorKind::Gradient, ev(0, 16));
        assert_eq!(l.final_bits_mix(TensorKind::Weight)[&8], 1.0);
        assert_eq!(l.final_bits_mix(TensorKind::Gradient)[&16], 1.0);
        assert_eq!(l.total_updates(), 2);
    }
}
