//! Adaptive Precision Training core (the paper's contribution, systems
//! S2–S4 in DESIGN.md): QEM error measurement, QPA parameter adjustment,
//! the per-tensor precision controller, and the run ledger.

pub mod config;
pub mod controller;
pub mod ledger;
pub mod qem;
pub mod qpa;

pub use config::{AptConfig, Mode, ThresholdOn};
pub use controller::{ControllerState, LayerControllers, PrecisionController};
pub use ledger::Ledger;
