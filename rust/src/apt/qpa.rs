//! Quantification Parameter Adjustment (paper §4.2).
//!
//! Given the QEM output and the range moving average, QPA decides:
//!   1. the new bit-width `n` (grown in steps of n′=8 until the error is
//!      below threshold — from 8 in Mode1, from the current width in Mode2);
//!   2. the new resolution `r = 2^ceil(log2(Range / (2^(n−1)−1)))`;
//!   3. the next update interval `Itv = β / max(I1, I2) − γ`, with
//!      `I1 = δ·Diff²` and `I2 = |R_i − R_{i−1}|`.

use super::config::{AptConfig, Mode, ThresholdOn};
use crate::fixedpoint::Scheme;

/// A probe of the quantization error at a specific bit-width: callers supply
/// `|bits| -> (error_value)` where the error is the ratio or Diff per
/// `cfg.threshold_on`. The pure-Rust path computes it from the raw tensor;
/// the PJRT path reads it from the artifact's candidate-stat outputs.
pub type ErrorProbe<'a> = dyn Fn(u8) -> f64 + 'a;

/// Outcome of one QPA run.
#[derive(Clone, Copy, Debug)]
pub struct QpaDecision {
    /// New scheme (bits + resolution).
    pub scheme: Scheme,
    /// Next update interval in iterations (≥ 1).
    pub interval: u64,
    /// Error value at the chosen width (for logging).
    pub error: f64,
    /// Whether the bit-width changed.
    pub bits_changed: bool,
    /// Whether `interval` was clamped to `cfg.max_interval` (the
    /// fully-converged-tensor guard: I1 ≈ I2 ≈ 0 makes the raw Itv formula
    /// divide toward +∞, which would otherwise saturate the `u64` cast and
    /// freeze the controller forever). The caller logs a ledger event.
    pub interval_clamped: bool,
}

/// Convert a QEM error into the thresholded quantity.
pub fn error_for_threshold(cfg: &AptConfig, ratio: f64) -> f64 {
    match cfg.threshold_on {
        ThresholdOn::Ratio => ratio,
        ThresholdOn::Diff => (ratio + 1.0).log2(),
    }
}

/// Choose the bit-width per §4.2: grow by `bit_step` until the probed error
/// is below threshold (or `max_bits` is hit).
pub fn choose_bits(cfg: &AptConfig, current_bits: u8, probe: &ErrorProbe) -> (u8, f64) {
    let start = match cfg.mode {
        Mode::Mode1 => cfg.min_bits,
        Mode::Mode2 => current_bits.max(cfg.min_bits),
    };
    let mut bits = start.min(cfg.max_bits);
    let mut err = probe(bits);
    while err > cfg.threshold && bits < cfg.max_bits {
        bits = (bits + cfg.bit_step).min(cfg.max_bits);
        err = probe(bits);
    }
    (bits, err)
}

/// The interval rule. `diff` is the Eq. 2 Diff at the chosen width;
/// `range_delta` is |R_i − R_{i−1}|.
pub fn interval(cfg: &AptConfig, diff: f64, range_delta: f32, in_init_phase: bool) -> u64 {
    interval_with_clamp(cfg, diff, range_delta, in_init_phase).0
}

/// [`interval`] plus whether the `cfg.max_interval` clamp fired.
///
/// `Itv = β / max(I1, I2) − γ` is unbounded above: on a fully converged
/// tensor both `I1 = δ·Diff²` and `I2 = |ΔR|` are ≈0, the division yields
/// `inf`, and an unguarded `as u64` cast saturates — the controller would
/// never re-probe again even if the distribution later shifts. The result
/// is therefore clamped to the documented `cfg.max_interval` ceiling; the
/// boolean reports when that guard (rather than the paper's formula)
/// decided the interval, so callers can emit a ledger event.
pub fn interval_with_clamp(
    cfg: &AptConfig,
    diff: f64,
    range_delta: f32,
    in_init_phase: bool,
) -> (u64, bool) {
    if in_init_phase {
        return (1, false);
    }
    let i1 = cfg.delta as f64 * diff * diff;
    let i2 = range_delta.abs() as f64;
    let denom = i1.max(i2);
    if denom <= 0.0 {
        return (cfg.max_interval, true);
    }
    let itv = cfg.beta as f64 / denom - cfg.gamma as f64;
    if itv >= cfg.max_interval as f64 {
        (cfg.max_interval, true)
    } else {
        (itv.max(1.0) as u64, false)
    }
}

/// Full QPA: choose bits, derive the resolution from the range estimate,
/// compute the next interval.
pub fn adjust(
    cfg: &AptConfig,
    current: Scheme,
    range_estimate: f32,
    range_delta: f32,
    in_init_phase: bool,
    probe: &ErrorProbe,
) -> QpaDecision {
    let (bits, err) = choose_bits(cfg, current.bits, probe);
    let scheme = Scheme::for_range(range_estimate, bits);
    let ratio = match cfg.threshold_on {
        ThresholdOn::Ratio => err,
        ThresholdOn::Diff => err.exp2() - 1.0,
    };
    let diff = (ratio + 1.0).log2();
    let (itv, clamped) = interval_with_clamp(cfg, diff, range_delta, in_init_phase);
    QpaDecision {
        scheme,
        interval: itv,
        error: err,
        bits_changed: bits != current.bits,
        interval_clamped: clamped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AptConfig {
        AptConfig::default()
    }

    /// Probe with fixed errors per width.
    fn table_probe(e8: f64, e16: f64, e24: f64) -> impl Fn(u8) -> f64 {
        move |bits| match bits {
            8 => e8,
            16 => e16,
            24 => e24,
            _ => 0.0,
        }
    }

    #[test]
    fn grows_until_below_threshold() {
        let p = table_probe(0.5, 0.1, 0.01);
        let (bits, err) = choose_bits(&cfg(), 8, &p);
        assert_eq!(bits, 24);
        assert!((err - 0.01).abs() < 1e-12);
    }

    #[test]
    fn stays_at_8_when_good() {
        let p = table_probe(0.001, 0.0, 0.0);
        let (bits, _) = choose_bits(&cfg(), 8, &p);
        assert_eq!(bits, 8);
    }

    #[test]
    fn mode2_starts_from_current() {
        let mut c = cfg();
        c.mode = Mode::Mode2;
        // error at 8 would fail, but we never probe it: current is 16.
        let calls = std::cell::RefCell::new(vec![]);
        let p = |bits: u8| {
            calls.borrow_mut().push(bits);
            0.0
        };
        let (bits, _) = choose_bits(&c, 16, &p);
        assert_eq!(bits, 16);
        assert_eq!(*calls.borrow(), vec![16]);
    }

    #[test]
    fn mode1_restarts_at_8() {
        let mut c = cfg();
        c.mode = Mode::Mode1;
        let p = table_probe(0.001, 0.0, 0.0);
        let (bits, _) = choose_bits(&c, 24, &p); // current is 24 but 8 is fine
        assert_eq!(bits, 8);
    }

    #[test]
    fn max_bits_caps_growth() {
        let mut c = cfg();
        c.max_bits = 16;
        let p = table_probe(1.0, 1.0, 1.0);
        let (bits, _) = choose_bits(&c, 8, &p);
        assert_eq!(bits, 16);
    }

    #[test]
    fn interval_init_phase_is_one() {
        assert_eq!(interval(&cfg(), 10.0, 10.0, true), 1);
    }

    #[test]
    fn interval_grows_as_training_stabilizes() {
        let c = cfg();
        // Early: large Diff and moving range → tiny interval.
        let early = interval(&c, 0.05, 0.5, false);
        // Late: tiny Diff, frozen range → long interval.
        let late = interval(&c, 0.001, 1e-5, false);
        assert!(early <= 2, "early={early}");
        assert!(late > 100, "late={late}");
        assert!(late <= c.max_interval);
    }

    #[test]
    fn interval_formula_matches_paper() {
        let c = cfg();
        // Itv = β/max(δ·Diff², |ΔR|) − γ with β=0.025, δ=25, γ=2.
        let diff = 0.01;
        let i1 = 25.0 * diff * diff; // 0.0025
        let want = (0.025f64 / i1 - 2.0).max(1.0) as u64; // 10 − 2 = 8
        assert_eq!(interval(&c, diff, 0.0, false), want);
    }

    #[test]
    fn zero_error_and_frozen_range_maxes_interval() {
        let c = cfg();
        assert_eq!(interval(&c, 0.0, 0.0, false), c.max_interval);
    }

    #[test]
    fn interval_clamp_fires_only_at_the_ceiling() {
        let c = cfg();
        // fully converged: denom = 0 → inf → clamp
        assert_eq!(interval_with_clamp(&c, 0.0, 0.0, false), (c.max_interval, true));
        // tiny-but-nonzero denom: raw Itv far above the ceiling → clamp
        let (itv, clamped) = interval_with_clamp(&c, 1e-12, 0.0, false);
        assert_eq!(itv, c.max_interval);
        assert!(clamped, "near-zero denom must report the clamp");
        // ordinary mid-training values: no clamp
        let (itv, clamped) = interval_with_clamp(&c, 0.01, 0.0, false);
        assert!(itv < c.max_interval);
        assert!(!clamped);
        // init phase pins Itv = 1 and is never a clamp
        assert_eq!(interval_with_clamp(&c, 0.0, 0.0, true), (1, false));
    }

    #[test]
    fn adjust_reports_interval_clamp() {
        let c = cfg();
        let p = table_probe(0.0, 0.0, 0.0); // zero error → Diff = 0
        let d = adjust(&c, Scheme { bits: 8, s: 0 }, 1.0, 0.0, false, &p);
        assert_eq!(d.interval, c.max_interval);
        assert!(d.interval_clamped);
        let d2 = adjust(&c, Scheme { bits: 8, s: 0 }, 1.0, 0.5, false, &p);
        assert!(!d2.interval_clamped, "moving range keeps the formula in charge");
    }

    #[test]
    fn adjust_sets_resolution_from_range() {
        let c = cfg();
        let p = table_probe(0.0, 0.0, 0.0);
        let d = adjust(&c, Scheme { bits: 8, s: 0 }, 4.0, 0.0, false, &p);
        assert_eq!(d.scheme, Scheme::for_range(4.0, 8));
        assert!(!d.bits_changed);
    }

    #[test]
    fn static_config_never_changes_bits() {
        let c = AptConfig::static_bits(16);
        let p = table_probe(9.9, 9.9, 9.9); // terrible errors everywhere
        let d = adjust(&c, Scheme { bits: 16, s: -3 }, 1.0, 0.0, false, &p);
        assert_eq!(d.scheme.bits, 16);
    }
}
