//! Per-tensor precision controller — Algorithm 1's control plane for one
//! quantized tensor (one `update_iter_*` slot in the paper's pseudocode).
//!
//! The controller owns the applied [`Scheme`], the range moving average
//! `R_i` (Eq. 3), and the next update iteration. At update iterations it
//! runs QEM + QPA and logs the decision to the [`Ledger`]. Between updates
//! quantization parameters are frozen, so no statistics need computing —
//! that is the source of the paper's <1% overhead (Fig 7).

use super::config::AptConfig;
use super::ledger::{Event, Ledger};
use super::qpa;
use crate::fixedpoint::quantize;
use crate::fixedpoint::{Format, FormatFamily, Scheme, TensorKind};
use crate::util::Ema;

/// Serializable decision state of one controller — everything
/// [`PrecisionController`] mutates between updates. Used by
/// `train::checkpoint` for bit-identical save/restore. `family` is the
/// format family the record was written under (checkpoint v4 tag); it is
/// validated against the config on restore, never applied from it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControllerState {
    pub bits: u8,
    pub s: i32,
    pub ema_value: f32,
    pub ema_initialized: bool,
    pub prev_range: f32,
    pub next_update: u64,
    pub updates: u64,
    pub family: FormatFamily,
}

/// Controller state for one tensor.
#[derive(Clone, Debug)]
pub struct PrecisionController {
    pub cfg: AptConfig,
    pub layer: String,
    pub kind: TensorKind,
    scheme: Scheme,
    range_ema: Ema,
    prev_range: f32,
    next_update: u64,
    updates: u64,
    /// Per-channel scale exponents for weight tensors under
    /// `cfg.per_channel_weights` (empty = per-tensor). Refreshed by the
    /// owning layer at update iterations; checkpointed in the v4 `pc`
    /// section.
    pc_scales: Vec<i32>,
}

impl PrecisionController {
    pub fn new(cfg: AptConfig, layer: impl Into<String>, kind: TensorKind) -> Self {
        let mut cfg = cfg;
        // The paper pins weights/activations to the base width; only
        // activation gradients adapt (§5.3).
        if cfg.pin_forward_bits && kind != TensorKind::Gradient {
            cfg.max_bits = cfg.min_bits;
        }
        // Fixed-width families (minifloat/int4) have no bit axis: pin the
        // storage width so QPA only tracks the scale exponent.
        if cfg.family != FormatFamily::FixedPoint {
            let b = cfg.family.storage_bits();
            cfg.min_bits = b;
            cfg.max_bits = b;
        }
        let init_s = Format::for_range(cfg.family, 1.0, cfg.min_bits).scale_exp();
        PrecisionController {
            scheme: Scheme { bits: cfg.min_bits, s: init_s },
            cfg,
            layer: layer.into(),
            kind,
            range_ema: Ema::new(cfg.alpha),
            prev_range: 0.0,
            next_update: 0,
            updates: 0,
            pc_scales: Vec::new(),
        }
    }

    /// Scheme to apply at this iteration. For non-fixed-point families the
    /// `s` slot carries the family's scale exponent; prefer
    /// [`format`](Self::format) which interprets it.
    pub fn scheme(&self) -> Scheme {
        self.scheme
    }

    /// The full format to apply at this iteration (family + adapted
    /// parameters). For `FixedPoint` configs this wraps [`scheme`] exactly.
    pub fn format(&self) -> Format {
        Format::from_scheme(self.cfg.family, self.scheme)
    }

    /// Per-channel scale exponents (empty = per-tensor quantization).
    pub fn pc_scales(&self) -> &[i32] {
        &self.pc_scales
    }

    /// Install per-channel scale exponents (the owning layer computes them
    /// from the weight data at update iterations; checkpoint restore
    /// re-installs the saved vector).
    pub fn set_pc_scales(&mut self, scales: Vec<i32>) {
        self.pc_scales = scales;
    }

    /// Recompute per-channel scale exponents from the weight data when this
    /// controller is configured `per_channel_weights` (no-op otherwise).
    /// Layers call this at update iterations, right after
    /// [`maybe_update_from_data`](Self::maybe_update_from_data), so the
    /// scales freeze together with the per-tensor decision. `by_rows`
    /// selects which axis of the row-major `rows × cols` matrix the
    /// channels index (conv weights: rows = output channels; linear
    /// weights: cols = output features).
    pub fn refresh_pc_scales(&mut self, w: &[f32], rows: usize, cols: usize, by_rows: bool) {
        if !self.cfg.per_channel_weights {
            return;
        }
        self.pc_scales = if by_rows {
            quantize::channel_scales_rows(w, rows, cols, self.cfg.family, self.scheme.bits)
        } else {
            quantize::channel_scales_cols(w, rows, cols, self.cfg.family, self.scheme.bits)
        };
    }

    /// Fake-quantize a weight matrix under this controller's decision:
    /// the per-tensor [`format`](Self::format) normally, the installed
    /// per-channel scales when present. Axis convention as in
    /// [`refresh_pc_scales`](Self::refresh_pc_scales).
    pub fn fake_quant_weights(&self, w: &mut [f32], rows: usize, cols: usize, by_rows: bool) {
        if self.pc_scales.is_empty() {
            crate::kernels::global().fake_quant_fmt(w, self.format());
        } else if by_rows {
            quantize::fake_quant_per_channel_rows(
                w,
                rows,
                cols,
                self.cfg.family,
                self.scheme.bits,
                &self.pc_scales,
            );
        } else {
            quantize::fake_quant_per_channel_cols(
                w,
                rows,
                cols,
                self.cfg.family,
                self.scheme.bits,
                &self.pc_scales,
            );
        }
    }

    pub fn bits(&self) -> u8 {
        self.scheme.bits
    }

    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Does Algorithm 1's `if i == update_iter` fire?
    pub fn needs_update(&self, iter: u64) -> bool {
        iter >= self.next_update
    }

    /// Snapshot the mutable decision state (checkpointing). The config,
    /// layer name and kind are reconstruction-time inputs, not state.
    pub fn snapshot(&self) -> ControllerState {
        ControllerState {
            bits: self.scheme.bits,
            s: self.scheme.s,
            ema_value: self.range_ema.value,
            ema_initialized: self.range_ema.is_initialized(),
            prev_range: self.prev_range,
            next_update: self.next_update,
            updates: self.updates,
            family: self.cfg.family,
        }
    }

    /// Restore a [`snapshot`](Self::snapshot); the controller then continues
    /// the interrupted run bit-identically.
    pub fn restore(&mut self, st: &ControllerState) {
        self.scheme = Scheme { bits: st.bits, s: st.s };
        self.range_ema.set_state(st.ema_value, st.ema_initialized);
        self.prev_range = st.prev_range;
        self.next_update = st.next_update;
        self.updates = st.updates;
    }

    /// Retune to `bits` at a `calib::Schedule` phase boundary. Moves the
    /// configured width floor (see [`set_width_floor`](Self::set_width_floor))
    /// and, when the applied width actually differs, resets the scheme to
    /// `bits` with a scale derived from the tracked range and forces the
    /// next QEM/QPA update to this iteration, so the controller re-probes at
    /// the new width immediately. When the width already matches — every
    /// degenerate schedule, and every checkpoint resume inside a phase —
    /// nothing but the config floor is touched, preserving bit-identity
    /// with the unscheduled path. No-op for fixed-width families
    /// (minifloat/int4 have no bit axis).
    pub fn retune_bits(&mut self, bits: u8, iter: u64) {
        if self.cfg.family != FormatFamily::FixedPoint {
            return;
        }
        self.set_width_floor(bits);
        if self.scheme.bits != bits {
            let r = if self.range_ema.is_initialized() { self.range_ema.value } else { 1.0 };
            let s = Format::for_range(FormatFamily::FixedPoint, r, bits).scale_exp();
            self.scheme = Scheme { bits, s };
            self.next_update = iter;
        }
    }

    /// Move the configured width floor to `bits` without touching the live
    /// scheme or update schedule: `min_bits` becomes `bits`; under the
    /// paper's pinned forward widths non-gradient tensors get `max_bits =
    /// bits` too, while gradient controllers keep their adaptation headroom
    /// (`max_bits` only ever widens). Checkpoint restore re-applies the
    /// in-force schedule phase through this, so a gradient controller that
    /// adapted *above* the phase floor is not forced back down on resume.
    pub fn set_width_floor(&mut self, bits: u8) {
        if self.cfg.family != FormatFamily::FixedPoint {
            return;
        }
        self.cfg.min_bits = bits;
        self.cfg.max_bits = if self.cfg.pin_forward_bits && self.kind != TensorKind::Gradient {
            bits
        } else {
            self.cfg.max_bits.max(bits)
        };
    }

    /// Update from in-hand data (the pure-Rust training path). Call only
    /// when [`needs_update`] is true; returns the applied scheme either way.
    pub fn maybe_update_from_data(
        &mut self,
        iter: u64,
        data: &[f32],
        ledger: &mut Ledger,
    ) -> Scheme {
        if !self.needs_update(iter) {
            return self.scheme;
        }
        let range_now = quantize::max_abs(data);
        let cfg = self.cfg;
        // Family-generic probe: for FixedPoint this is exactly the original
        // `Scheme::for_range` + `stats_only` path (bit-identity pinned).
        let probe = move |bits: u8| {
            let fmt = Format::for_range(cfg.family, range_now.max(1e-30), bits);
            qpa::error_for_threshold(&cfg, quantize::stats_only_fmt(data, fmt).ratio())
        };
        self.apply_decision(iter, range_now, &probe, ledger)
    }

    /// Update from device-computed statistics (the PJRT path): `sum_abs`,
    /// `max_abs` and `sum_abs_q` per candidate width, as produced by
    /// `kernels/stats.py` (candidates int8/16/24; wider widths are assumed
    /// exact).
    pub fn maybe_update_from_stats(
        &mut self,
        iter: u64,
        sum_abs: f64,
        max_abs: f32,
        cand_sum_q: &[(u8, f64)],
        ledger: &mut Ledger,
    ) -> Scheme {
        if !self.needs_update(iter) {
            return self.scheme;
        }
        let cfg = self.cfg;
        let probe = move |bits: u8| {
            let ratio = cand_sum_q
                .iter()
                .find(|(b, _)| *b >= bits)
                .map(|(_, sq)| {
                    if sum_abs <= 0.0 {
                        0.0
                    } else {
                        (sum_abs - sq).abs() / sum_abs
                    }
                })
                .unwrap_or(0.0);
            qpa::error_for_threshold(&cfg, ratio)
        };
        self.apply_decision(iter, max_abs, &probe, ledger)
    }

    fn apply_decision(
        &mut self,
        iter: u64,
        range_now: f32,
        probe: &qpa::ErrorProbe,
        ledger: &mut Ledger,
    ) -> Scheme {
        let prev_r = if self.range_ema.is_initialized() {
            self.range_ema.value
        } else {
            range_now
        };
        let r_i = self.range_ema.update(range_now);
        let range_delta = r_i - prev_r;
        self.prev_range = r_i;

        let in_init = iter < self.cfg.init_phase_iters;
        let decision = qpa::adjust(&self.cfg, self.scheme, r_i.max(range_now), range_delta, in_init, probe);
        self.scheme = if self.cfg.family == FormatFamily::FixedPoint {
            decision.scheme
        } else {
            // Fixed-width family: bits are pinned by the family; the scale
            // exponent follows the family's range rule instead of the
            // fixed-point one.
            let fmt = Format::for_range(self.cfg.family, r_i.max(range_now), decision.scheme.bits);
            Scheme { bits: decision.scheme.bits, s: fmt.scale_exp() }
        };
        self.next_update = iter + decision.interval;
        self.updates += 1;
        if decision.interval_clamped {
            // The Itv formula ran away (converged tensor); the max_interval
            // guard decided the re-probe slot. Keep that visible in the run
            // record — a silent clamp looks like the paper's formula at work.
            ledger.record_clamp(&self.layer, self.kind, iter);
        }
        ledger.record_event_fmt(
            &self.layer,
            self.kind,
            Event {
                iter,
                bits: decision.scheme.bits,
                interval: decision.interval,
                error: decision.error,
            },
            self.cfg.family,
        );
        self.scheme
    }
}

/// Controllers for all three tensors of one linear/conv layer.
#[derive(Clone, Debug)]
pub struct LayerControllers {
    pub w: PrecisionController,
    pub x: PrecisionController,
    pub g: PrecisionController,
}

impl LayerControllers {
    pub fn new(cfg: AptConfig, layer: &str) -> Self {
        LayerControllers {
            w: PrecisionController::new(cfg, layer, TensorKind::Weight),
            x: PrecisionController::new(cfg, layer, TensorKind::Activation),
            g: PrecisionController::new(cfg, layer, TensorKind::Gradient),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn gaussian(seed: u64, n: usize, std: f32) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal() * std).collect()
    }

    #[test]
    fn first_iteration_always_updates() {
        let mut c = PrecisionController::new(AptConfig::default(), "l0", TensorKind::Gradient);
        assert!(c.needs_update(0));
        let mut ledger = Ledger::new();
        let data = gaussian(1, 512, 1.0);
        c.maybe_update_from_data(0, &data, &mut ledger);
        assert_eq!(c.updates(), 1);
        assert!(!c.needs_update(0)); // interval ≥ 1 moved the slot forward
    }

    #[test]
    fn gaussian_data_stays_low_width_tail_escalates() {
        let mut ledger = Ledger::new();
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        // benign data: int8 suffices
        let mut c = PrecisionController::new(cfg, "conv1", TensorKind::Gradient);
        let benign = gaussian(2, 8192, 1.0);
        c.maybe_update_from_data(0, &benign, &mut ledger);
        assert_eq!(c.bits(), 8, "benign gaussian should stay int8");

        // long-tail data: needs escalation (fc2-like — Observation 3)
        let mut tail = gaussian(3, 8192, 0.05);
        for (i, v) in tail.iter_mut().enumerate() {
            if i % 64 == 0 {
                *v *= 400.0;
            }
        }
        let mut c2 = PrecisionController::new(cfg, "fc2", TensorKind::Gradient);
        c2.maybe_update_from_data(0, &tail, &mut ledger);
        assert!(c2.bits() >= 16, "long-tail gradient must escalate, got {}", c2.bits());
    }

    #[test]
    fn pinned_weight_never_escalates() {
        let mut ledger = Ledger::new();
        let cfg = AptConfig::default(); // pin_forward_bits = true
        let mut c = PrecisionController::new(cfg, "fc2", TensorKind::Weight);
        let mut tail = gaussian(4, 4096, 0.05);
        for (i, v) in tail.iter_mut().enumerate() {
            if i % 64 == 0 {
                *v *= 400.0;
            }
        }
        c.maybe_update_from_data(0, &tail, &mut ledger);
        assert_eq!(c.bits(), 8);
    }

    #[test]
    fn interval_one_during_init_phase() {
        let mut ledger = Ledger::new();
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 10;
        let mut c = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        let data = gaussian(5, 256, 1.0);
        for it in 0..10u64 {
            assert!(c.needs_update(it), "iter {it} must update during init");
            c.maybe_update_from_data(it, &data, &mut ledger);
        }
        assert_eq!(c.updates(), 10);
    }

    #[test]
    fn interval_grows_after_init_on_stable_data() {
        let mut ledger = Ledger::new();
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 2;
        let mut c = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        let data = gaussian(6, 4096, 1.0);
        let mut updates = 0;
        for it in 0..200u64 {
            if c.needs_update(it) {
                c.maybe_update_from_data(it, &data, &mut ledger);
                updates += 1;
            }
        }
        // stable distribution → long intervals → few updates
        assert!(updates < 20, "updates={updates}");
    }

    #[test]
    fn converged_tensor_clamps_interval_and_logs_it() {
        let mut ledger = Ledger::new();
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        let mut c = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        // All-zero gradient: QEM error 0 and range EMA frozen at 0 → the
        // raw Itv formula is β/0 = inf. The controller must clamp to
        // max_interval (staying re-probeable) and log the clamp.
        let zeros = vec![0.0f32; 256];
        c.maybe_update_from_data(0, &zeros, &mut ledger);
        assert!(c.needs_update(cfg.max_interval), "controller must re-probe at the ceiling");
        assert!(!c.needs_update(cfg.max_interval - 1));
        assert_eq!(ledger.total_clamps(), 1);
        let hist = &ledger.tensors[&("l".to_string(), TensorKind::Gradient)];
        assert_eq!(hist.clamps, vec![0]);
    }

    #[test]
    fn snapshot_restore_continues_identically() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 3;
        let mut ledger = Ledger::new();
        let mut c = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        let data = gaussian(21, 2048, 1.0);
        for it in 0..5u64 {
            if c.needs_update(it) {
                c.maybe_update_from_data(it, &data, &mut ledger);
            }
        }
        let st = c.snapshot();
        let mut c2 = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        c2.restore(&st);
        assert_eq!(c2.snapshot(), st);
        // both continue with identical decisions
        let tail = gaussian(22, 2048, 0.3);
        let mut l1 = Ledger::new();
        let mut l2 = Ledger::new();
        for it in 5..40u64 {
            assert_eq!(c.needs_update(it), c2.needs_update(it));
            if c.needs_update(it) {
                let s1 = c.maybe_update_from_data(it, &tail, &mut l1);
                let s2 = c2.maybe_update_from_data(it, &tail, &mut l2);
                assert_eq!(s1, s2);
            }
        }
        assert_eq!(c.snapshot(), c2.snapshot());
    }

    #[test]
    fn stats_path_matches_data_path_choice() {
        let mut cfg = AptConfig::default();
        cfg.init_phase_iters = 0;
        let data = gaussian(7, 4096, 1.0);
        let mut l1 = Ledger::new();
        let mut c1 = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        c1.maybe_update_from_data(0, &data, &mut l1);

        // device-style stats with candidate sums at 8/16/24
        let z = quantize::max_abs(&data);
        let sum_abs: f64 = data.iter().map(|&x| x.abs() as f64).sum();
        let cand: Vec<(u8, f64)> = [8u8, 16, 24]
            .iter()
            .map(|&b| {
                let sch = Scheme::for_range(z, b);
                (b, quantize::stats_only(&data, sch).sum_abs_q)
            })
            .collect();
        let mut l2 = Ledger::new();
        let mut c2 = PrecisionController::new(cfg, "l", TensorKind::Gradient);
        c2.maybe_update_from_stats(0, sum_abs, z, &cand, &mut l2);
        assert_eq!(c1.bits(), c2.bits());
    }

    #[test]
    fn mode1_can_decrease_mode2_cannot() {
        let mut ledger = Ledger::new();
        let mut cfg1 = AptConfig::mode1();
        cfg1.init_phase_iters = 0;
        let mut cfg2 = AptConfig::default();
        cfg2.init_phase_iters = 0;

        let mut tail = gaussian(8, 4096, 0.05);
        for (i, v) in tail.iter_mut().enumerate() {
            if i % 64 == 0 {
                *v *= 400.0;
            }
        }
        let benign = gaussian(9, 4096, 1.0);

        for (cfg, expect_final) in [(cfg1, 8u8), (cfg2, 16u8)] {
            let mut c = PrecisionController::new(cfg, "l", TensorKind::Gradient);
            c.maybe_update_from_data(0, &tail, &mut ledger); // escalates to ≥16
            assert!(c.bits() >= 16);
            // data becomes benign; force an update far in the future
            let far = 1_000_000;
            assert!(c.needs_update(far));
            c.maybe_update_from_data(far, &benign, &mut ledger);
            assert_eq!(c.bits(), expect_final, "mode={:?}", cfg.mode);
        }
    }
}
