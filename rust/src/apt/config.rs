//! Hyper-parameters of adaptive precision training (paper §5.3).

use crate::fixedpoint::FormatFamily;

/// QPA bit-width restart policy (paper §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Mode1: restart the search at 8 bits on every update (bit-width can
    /// shrink during training — Fig 8b shows more layers back at int8).
    Mode1,
    /// Mode2: start from the previous bit-width (monotone non-decreasing;
    /// the paper's default — slightly better accuracy).
    Mode2,
}

/// Threshold interpretation for the QEM output (DESIGN.md §6.5): the paper's
/// §1 describes "ratio of quantization error exceeds 3%" while §4.2 applies
/// `T_topdiff` to `Diff = log2(ratio+1)`. Both are supported; they differ by
/// a constant ≈1.44 for small values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThresholdOn {
    /// Compare the pre-log ratio |Σ|x|−Σ|x̂||/Σ|x| against T.
    Ratio,
    /// Compare Diff = log2(ratio+1) against T.
    Diff,
}

/// Full configuration; `Default` reproduces the paper's settings
/// (α=0.01, β=0.025, δ=25, γ=2, T=0.03, Mode2, W/X pinned to int8).
#[derive(Clone, Copy, Debug)]
pub struct AptConfig {
    /// EMA factor for the range moving average (Eq. 3).
    pub alpha: f32,
    /// Interval numerator (Itv = β / max(I1, I2) − γ).
    pub beta: f32,
    /// Diff² weight in I1 = δ·Diff².
    pub delta: f32,
    /// Interval offset γ.
    pub gamma: f32,
    /// QEM threshold T_topdiff.
    pub threshold: f64,
    /// What the threshold compares against.
    pub threshold_on: ThresholdOn,
    /// Bit-width restart policy.
    pub mode: Mode,
    /// Bit-width growth step n′ (8 in the paper).
    pub bit_step: u8,
    /// Initial / minimum bit-width.
    pub min_bits: u8,
    /// Hard ceiling on bit-width (32 = f32-equivalent fallback).
    pub max_bits: u8,
    /// Iterations of the initialization phase (Itv forced to 1) —
    /// "one-tenth of the first epoch" in the paper.
    pub init_phase_iters: u64,
    /// Upper clamp on the update interval (safety valve; the paper reports
    /// intervals growing until ~0.1% of iterations trigger updates).
    pub max_interval: u64,
    /// If true, weights and activations are pinned to `min_bits` (the
    /// paper's experimental setting: only gradients adapt).
    pub pin_forward_bits: bool,
    /// Format family the controller adapts within (DESIGN.md §Formats).
    /// `FixedPoint` (the default) reproduces the paper's bit-width axis
    /// exactly; the fixed-width families (`E4M3`/`E5M2`/`Int4`) pin the
    /// storage width and adapt only the scale exponent.
    pub family: FormatFamily,
    /// Per-channel weight scales (conv/fc): the family/bits decision stays
    /// per-tensor, but each output channel gets its own scale exponent.
    pub per_channel_weights: bool,
}

impl Default for AptConfig {
    fn default() -> Self {
        AptConfig {
            alpha: 0.01,
            beta: 0.025,
            delta: 25.0,
            gamma: 2.0,
            threshold: 0.03,
            threshold_on: ThresholdOn::Ratio,
            mode: Mode::Mode2,
            bit_step: 8,
            min_bits: 8,
            max_bits: 32,
            init_phase_iters: 100,
            max_interval: 10_000,
            pin_forward_bits: true,
            family: FormatFamily::FixedPoint,
            per_channel_weights: false,
        }
    }
}

impl AptConfig {
    /// Unified static bit-width baseline (e.g. the int16 comparator in
    /// Fig 9): adaptation disabled by an infinite threshold.
    pub fn static_bits(bits: u8) -> Self {
        AptConfig {
            min_bits: bits,
            max_bits: bits,
            threshold: f64::INFINITY,
            ..Default::default()
        }
    }

    /// Mode1 variant of the defaults.
    pub fn mode1() -> Self {
        AptConfig { mode: Mode::Mode1, ..Default::default() }
    }

    /// Config for a fixed-width format family (minifloat / int4): storage
    /// width is pinned by the family, QPA adapts only the scale exponent.
    /// `FixedPoint` returns the plain defaults (the paper's axis).
    pub fn for_family(family: FormatFamily) -> Self {
        let mut cfg = AptConfig { family, ..Default::default() };
        if family != FormatFamily::FixedPoint {
            let bits = family.storage_bits();
            cfg.min_bits = bits;
            cfg.max_bits = bits;
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = AptConfig::default();
        assert_eq!(c.alpha, 0.01);
        assert_eq!(c.beta, 0.025);
        assert_eq!(c.delta, 25.0);
        assert_eq!(c.gamma, 2.0);
        assert_eq!(c.threshold, 0.03);
        assert_eq!(c.mode, Mode::Mode2);
        assert_eq!(c.bit_step, 8);
    }

    #[test]
    fn static_config_never_adapts() {
        let c = AptConfig::static_bits(16);
        assert_eq!(c.min_bits, 16);
        assert_eq!(c.max_bits, 16);
        assert!(c.threshold.is_infinite());
    }
}
