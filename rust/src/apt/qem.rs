//! Quantization Error Measurement (paper §4.1 + §5.1).
//!
//! The paper's metric **M1** is the relative change of the mean absolute
//! value under quantization, reported as `Diff = log2(M1 + 1)` (Eq. 2).
//! M2–M4 are the comparison metrics of Fig 5/6; they exist here so the
//! correlation experiment can score all four against network accuracy.

use crate::fixedpoint::{QuantStats, Scheme};

/// M1 — the paper's metric: `|Σ|x| − Σ|x̂|| / Σ|x|`.
pub fn m1(x: &[f32], sch: Scheme) -> f64 {
    crate::fixedpoint::quantize::stats_only(x, sch).ratio()
}

/// Diff (Eq. 2) = log2(M1 + 1), from precomputed stats.
pub fn diff_from_stats(st: &QuantStats) -> f64 {
    st.diff()
}

/// M2 — mean absolute quantization error: `Σ|x − x̂| / Σ|x|`
/// (the metric of [27, 39] in the paper's numbering).
pub fn m2(x: &[f32], sch: Scheme) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for &v in x {
        num += (v - sch.fake_quant(v)).abs() as f64;
        den += v.abs() as f64;
    }
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// M3 — sum of element-wise relative errors: `Σ |x−x̂|/|x|` (zeros skipped),
/// normalized by element count to keep it scale-comparable.
pub fn m3(x: &[f32], sch: Scheme) -> f64 {
    let mut s = 0.0f64;
    let mut n = 0usize;
    for &v in x {
        if v != 0.0 {
            s += ((v - sch.fake_quant(v)).abs() / v.abs()) as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

/// M4 — Kullback–Leibler divergence between the log2-magnitude histograms
/// of the data before and after quantization.
pub fn m4(x: &[f32], sch: Scheme) -> f64 {
    const BINS: usize = 64;
    const MIN_EXP: i32 = -40;
    let hist = |vals: &mut dyn Iterator<Item = f32>| -> Vec<f64> {
        let mut h = vec![0.0f64; BINS + 1]; // +1: zero bucket
        let mut total = 0.0f64;
        for v in vals {
            let a = v.abs();
            let idx = if a == 0.0 {
                BINS
            } else {
                ((a.log2().floor() as i32 - MIN_EXP).clamp(0, BINS as i32 - 1)) as usize
            };
            h[idx] += 1.0;
            total += 1.0;
        }
        for c in h.iter_mut() {
            *c /= total.max(1.0);
        }
        h
    };
    let p = hist(&mut x.iter().copied());
    let q = hist(&mut x.iter().map(|&v| sch.fake_quant(v)));
    let eps = 1e-12;
    p.iter()
        .zip(&q)
        .map(|(&pi, &qi)| {
            if pi <= 0.0 {
                0.0
            } else {
                pi * ((pi + eps) / (qi + eps)).ln()
            }
        })
        .sum()
}

/// All four metrics at once (single pass over the heavy parts is not needed
/// for experiment-time probes; clarity wins).
pub fn all_metrics(x: &[f32], sch: Scheme) -> [f64; 4] {
    [m1(x, sch), m2(x, sch), m3(x, sch), m4(x, sch)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::max_abs;
    use crate::util::proptest::check;
    use crate::util::Pcg32;

    fn gaussian(seed: u64, n: usize, std: f32) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal() * std).collect()
    }

    #[test]
    fn metrics_zero_for_exact_representation() {
        // Data already on the grid of a wide scheme quantizes exactly.
        let sch = Scheme { bits: 16, s: 0 }; // resolution 1, range ±32767
        let x: Vec<f32> = (-100..100).map(|i| i as f32).collect();
        assert_eq!(m1(&x, sch), 0.0);
        assert_eq!(m2(&x, sch), 0.0);
        assert_eq!(m3(&x, sch), 0.0);
        assert!(m4(&x, sch).abs() < 1e-9);
    }

    #[test]
    fn prop_all_metrics_shrink_with_bits() {
        check("metrics-shrink", 20, |g| {
            let _sc = g.f32_log(1e-2, 1e2);
            let x = g.normal_vec(2048, _sc);
            let z = max_abs(&x);
            for f in [m1 as fn(&[f32], Scheme) -> f64, m2, m3] {
                let a = f(&x, Scheme::for_range(z, 8));
                let b = f(&x, Scheme::for_range(z, 16));
                assert!(b <= a + 1e-9, "metric grew: {a} -> {b}");
            }
        });
    }

    #[test]
    fn m2_upper_bounds_m1() {
        // |Σ|x| − Σ|x̂|| <= Σ|x − x̂| by the triangle inequality, so M1 <= M2.
        check("m1-le-m2", 20, |g| {
            let x = g.normal_vec(1024, 1.0);
            let sch = Scheme::for_range(max_abs(&x), 8);
            assert!(m1(&x, sch) <= m2(&x, sch) + 1e-12);
        });
    }

    #[test]
    fn m4_nonnegative() {
        let x = gaussian(5, 4096, 3.0);
        let sch = Scheme::for_range(max_abs(&x), 6);
        assert!(m4(&x, sch) >= 0.0);
    }

    #[test]
    fn m1_detects_variance_growth() {
        // Observation 3: larger σ (relative to the quantization grid set by
        // the max) → larger M1 at int8. Long-tail data has a large max but
        // mass near zero — exactly the hard case.
        let narrow = gaussian(1, 8192, 1.0);
        let mut tail = gaussian(2, 8192, 1.0);
        for (i, v) in tail.iter_mut().enumerate() {
            if i % 50 == 0 {
                *v *= 60.0;
            }
        }
        let mn = m1(&narrow, Scheme::for_range(max_abs(&narrow), 8));
        let mt = m1(&tail, Scheme::for_range(max_abs(&tail), 8));
        assert!(mt > mn, "tail {mt} vs narrow {mn}");
    }
}
