//! Fixed-point numeric substrate (system S1 in DESIGN.md).
//!
//! - [`scheme`]: bit-width + power-of-two-resolution schemes (Appendix B).
//! - [`format`]: the format family generalization (minifloat FP8, int4,
//!   per-channel scales) layered over the scheme math (DESIGN.md §Formats).
//! - [`quantize`]: bulk fake-quant / integer codes fused with QEM stats.
//! - [`gemm`]: i8/i16/f32 GEMM kernels with i32 accumulation — the measured
//!   substrate for Table 3 / Fig 10 / Appendix E speedups.
//! - [`conv`]: im2col-based convolution over those GEMMs.
//!
//! These modules are the *serial backends* of the parallel kernel engine
//! (`crate::kernels`, DESIGN.md §Kernel-Engine): hot paths call
//! `kernels::Engine`, which shards work across a thread pool and falls back
//! to these kernels for small problems or `threads = 1`.

pub mod conv;
pub mod format;
pub mod gemm;
pub mod gemm_simd;
pub mod quantize;
pub mod scheme;

pub use format::{pack_nibbles, unpack_nibbles, Format, FormatFamily, MinifloatKind, QuantAxis};
pub use quantize::QuantStats;
pub use scheme::{Scheme, TensorKind, BIT_STEPS};
