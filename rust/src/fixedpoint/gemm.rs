//! Integer and float GEMM kernels — the measured substrate for the paper's
//! training-speedup claims (Table 3, Fig 10, Appendix E).
//!
//! The paper's Xeon Gold 6154 numbers come from AVX2 int8/int16 vector
//! instructions. Here the same datapath-width argument is exercised through
//! LLVM autovectorization: all kernels share one blocked structure
//! (MC×KC panels, 8-wide accumulator strips) and differ only in element
//! type, so the int8/int16 vs f32 *ratio* reflects lane width, not kernel
//! quality. i8×i8 and i16×i16 products accumulate in i32 (exact — the same
//! contract as the MXU / VNNI path); the caller rescales by `r1·r2`.
//!
//! Row-major everywhere: `a` is m×k, `b` is k×n, `c` is m×n. These are the
//! serial-portable backends of `crate::kernels::Engine`; rows are
//! independent, which is what lets the engine shard by M-row panels with
//! bit-identical results (DESIGN.md §Kernel-Engine).

/// Blocking parameters shared by all kernels (tuned in the perf pass; see
/// EXPERIMENTS.md §Perf).
pub const MC: usize = 64;
pub const KC: usize = 256;

/// A blocking choice for one GEMM shape: panel heights `mc`/`kc` for the
/// blocked loops plus the engine's row-shard chunk (`shard == 0` keeps the
/// engine's load-balancing default). Any `Tile` produces bit-identical
/// results to any other — blocking only reorders *which* panel is visited
/// when, never the per-element accumulation order (the `p` loop always
/// ascends within a row) — so the inference compiler is free to autotune it
/// per shape (DESIGN.md §Inference-Compiler).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    /// Row-panel height for the blocked i-loop (`MC` by default).
    pub mc: usize,
    /// Depth-panel length for the blocked p-loop (`KC` by default).
    pub kc: usize,
    /// Engine row-shard chunk override; 0 = engine default.
    pub shard: usize,
}

impl Default for Tile {
    fn default() -> Self {
        Tile { mc: MC, kc: KC, shard: 0 }
    }
}

/// f32 GEMM baseline: c = a·b (c fully overwritten).
pub fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_f32_tiled(m, k, n, a, b, c, MC, KC)
}

/// f32 GEMM with caller-chosen blocking. Bit-identical to `gemm_f32` for
/// every `(mc, kc)`: within each output row the `p` accumulation order is
/// ascending regardless of panel boundaries, and the `av == 0.0` skip fires
/// on exactly the same elements.
pub fn gemm_f32_tiled(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    mc: usize,
    kc: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let (mc, kc) = (mc.max(1), kc.max(1));
    c.fill(0.0);
    // i-k-j loop order: unit-stride over b and c rows → autovectorizes.
    for ic in (0..m).step_by(mc) {
        let mend = (ic + mc).min(m);
        for pc in (0..k).step_by(kc) {
            let kend = (pc + kc).min(k);
            for i in ic..mend {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for p in pc..kend {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n..(p + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
    }
}

/// int8 GEMM with i32 accumulation: c_i32 = a_i8 · b_i8. Dispatches to the
/// AVX-512 VNNI kernel when available (see `gemm_simd`), else the portable
/// blocked kernel below.
pub fn gemm_i8(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    super::gemm_simd::gemm_i8_fast(m, k, n, a, b, c)
}

/// Portable autovectorized int8 kernel (the pre-perf-pass baseline, kept
/// for dispatch fallback and for the §Perf before/after comparison).
pub fn gemm_i8_portable(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    gemm_i8_portable_tiled(m, k, n, a, b, c, MC, KC)
}

/// Portable int8 kernel with caller-chosen blocking (exact integer math,
/// so any tiling is trivially bit-identical).
pub fn gemm_i8_portable_tiled(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
    mc: usize,
    kc: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let (mc, kc) = (mc.max(1), kc.max(1));
    c.fill(0);
    for ic in (0..m).step_by(mc) {
        let mend = (ic + mc).min(m);
        for pc in (0..k).step_by(kc) {
            let kend = (pc + kc).min(k);
            for i in ic..mend {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for p in pc..kend {
                    let av = arow[p] as i32;
                    if av == 0 {
                        continue;
                    }
                    let brow = &b[p * n..(p + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j] as i32;
                    }
                }
            }
        }
    }
}

/// int16 GEMM with i32 accumulation (the paper's backward-pass precision;
/// footnote 10: int16×int8 executes as int16×int16 on AVX2). Dispatches to
/// the AVX-512 vpmaddwd kernel when available.
pub fn gemm_i16(m: usize, k: usize, n: usize, a: &[i16], b: &[i16], c: &mut [i32]) {
    super::gemm_simd::gemm_i16_fast(m, k, n, a, b, c)
}

/// Portable autovectorized int16 kernel (fallback + §Perf baseline).
pub fn gemm_i16_portable(m: usize, k: usize, n: usize, a: &[i16], b: &[i16], c: &mut [i32]) {
    gemm_i16_portable_tiled(m, k, n, a, b, c, MC, KC)
}

/// Portable int16 kernel with caller-chosen blocking (exact integer math,
/// so any tiling is trivially bit-identical).
pub fn gemm_i16_portable_tiled(
    m: usize,
    k: usize,
    n: usize,
    a: &[i16],
    b: &[i16],
    c: &mut [i32],
    mc: usize,
    kc: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let (mc, kc) = (mc.max(1), kc.max(1));
    c.fill(0);
    for ic in (0..m).step_by(mc) {
        let mend = (ic + mc).min(m);
        for pc in (0..k).step_by(kc) {
            let kend = (pc + kc).min(k);
            for i in ic..mend {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for p in pc..kend {
                    let av = arow[p] as i32;
                    if av == 0 {
                        continue;
                    }
                    let brow = &b[p * n..(p + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j] as i32;
                    }
                }
            }
        }
    }
}

/// Rescale an i32 accumulator into f32 output: `c = acc · scale`.
pub fn rescale_i32(acc: &[i32], scale: f32, out: &mut [f32]) {
    assert_eq!(acc.len(), out.len());
    for (o, &v) in out.iter_mut().zip(acc) {
        *o = v as f32 * scale;
    }
}

/// Transpose a row-major m×n matrix into n×m.
pub fn transpose(m: usize, n: usize, a: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a[i * n + j];
        }
    }
}

/// End-to-end quantized matmul on f32 buffers (quantize → int GEMM →
/// rescale) choosing i8 or i16 kernels from the schemes; falls back to
/// fake-quant + f32 GEMM for wider schemes. Scratch-free convenience used
/// by tests and the speedup benches; the training hot path pre-allocates.
pub fn qgemm(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    sa: super::Scheme,
    b: &[f32],
    sb: super::Scheme,
    c: &mut [f32],
) {
    use super::quantize::{codes_i16, codes_i8};
    let scale = sa.resolution() * sb.resolution();
    if sa.bits <= 8 && sb.bits <= 8 {
        let mut ca = vec![0i8; a.len()];
        let mut cb = vec![0i8; b.len()];
        codes_i8(a, &mut ca, sa);
        codes_i8(b, &mut cb, sb);
        let mut acc = vec![0i32; c.len()];
        gemm_i8(m, k, n, &ca, &cb, &mut acc);
        rescale_i32(&acc, scale, c);
    } else if sa.bits <= 16 && sb.bits <= 16 {
        let mut ca = vec![0i16; a.len()];
        let mut cb = vec![0i16; b.len()];
        codes_i16(a, &mut ca, sa);
        codes_i16(b, &mut cb, sb);
        let mut acc = vec![0i32; c.len()];
        gemm_i16(m, k, n, &ca, &cb, &mut acc);
        rescale_i32(&acc, scale, c);
    } else {
        // int24+ codes exceed i16; emulate with fake-quant + f32 GEMM
        // (exact: codes < 2^24 are representable in f32).
        let mut qa = a.to_vec();
        let mut qb = b.to_vec();
        super::quantize::fake_quant_stats_inplace(&mut qa, sa);
        super::quantize::fake_quant_stats_inplace(&mut qb, sb);
        gemm_f32(m, k, n, &qa, &qb, c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::max_abs;
    use crate::fixedpoint::Scheme;
    use crate::util::proptest::check;
    use crate::util::Pcg32;

    fn naive_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn randvec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal() * scale).collect()
    }

    #[test]
    fn f32_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 130, 33)] {
            let a = randvec(m as u64, m * k, 1.0);
            let b = randvec(n as u64 + 7, k * n, 1.0);
            let mut c = vec![0.0; m * n];
            gemm_f32(m, k, n, &a, &b, &mut c);
            let want = naive_f32(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn i8_exact_vs_naive_int() {
        let mut r = Pcg32::seeded(3);
        let (m, k, n) = (17, 31, 13);
        let a: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let mut c = vec![0i32; m * n];
        gemm_i8(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|p| a[i * k + p] as i32 * b[p * n + j] as i32).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn i16_exact_vs_naive_int() {
        let mut r = Pcg32::seeded(4);
        let (m, k, n) = (9, 65, 21);
        let a: Vec<i16> = (0..m * k).map(|_| (r.below(65535) as i32 - 32767) as i16).collect();
        let b: Vec<i16> = (0..k * n).map(|_| (r.below(200) as i32 - 100) as i16).collect();
        let mut c = vec![0i32; m * n];
        gemm_i16(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|p| a[i * k + p] as i32 * b[p * n + j] as i32).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn prop_qgemm_equals_fakequant_f32gemm() {
        // Paper Eq. 12: r1 r2 (I1·I2) == x̂·ŵ bit-for-bit (both paths
        // compute exact small-integer arithmetic; f32 rounding in the
        // accumulation differs, so compare with a tiny tolerance scaled
        // by k).
        check("qgemm-eq12", 15, |g| {
            let m = g.usize(1, 40);
            let k = g.usize(1, 60);
            let n = g.usize(1, 40);
            let bits = *g.choose(&[8u8, 16]);
            let _sc = g.f32_log(1e-2, 10.0);
            let a = g.normal_vec(m * k, _sc);
            let _sc = g.f32_log(1e-2, 10.0);
            let b = g.normal_vec(k * n, _sc);
            let sa = Scheme::for_range(max_abs(&a), bits);
            let sb = Scheme::for_range(max_abs(&b), bits);
            let mut c = vec![0.0; m * n];
            qgemm(m, k, n, &a, sa, &b, sb, &mut c);

            let mut qa = a.clone();
            let mut qb = b.clone();
            crate::fixedpoint::quantize::fake_quant_stats_inplace(&mut qa, sa);
            crate::fixedpoint::quantize::fake_quant_stats_inplace(&mut qb, sb);
            let want = naive_f32(m, k, n, &qa, &qb);
            for (x, y) in c.iter().zip(&want) {
                let tol = 1e-4 * y.abs().max(1.0);
                assert!((x - y).abs() <= tol, "{x} vs {y} (m={m},k={k},n={n},bits={bits})");
            }
        });
    }

    #[test]
    fn f32_tiled_bit_identical_across_tiles() {
        // The autotuner's legality argument: any (mc, kc) choice is
        // bit-identical, not just numerically close.
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (65, 130, 33), (64, 300, 17)] {
            let a = randvec(m as u64 + 100, m * k, 1.0);
            let b = randvec(n as u64 + 200, k * n, 1.0);
            let mut base = vec![0.0; m * n];
            gemm_f32(m, k, n, &a, &b, &mut base);
            for &(mc, kc) in &[(1, 1), (8, 16), (32, 512), (1024, 1024), (7, 13)] {
                let mut c = vec![0.0; m * n];
                gemm_f32_tiled(m, k, n, &a, &b, &mut c, mc, kc);
                let eq = base.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(eq, "tile ({mc},{kc}) diverged at shape ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn int_tiled_bit_identical_across_tiles() {
        let mut r = Pcg32::seeded(9);
        let (m, k, n) = (13, 77, 19);
        let a8: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let b8: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let a16: Vec<i16> = a8.iter().map(|&v| v as i16 * 3).collect();
        let b16: Vec<i16> = b8.iter().map(|&v| v as i16 * 5).collect();
        let mut base8 = vec![0i32; m * n];
        let mut base16 = vec![0i32; m * n];
        gemm_i8_portable(m, k, n, &a8, &b8, &mut base8);
        gemm_i16_portable(m, k, n, &a16, &b16, &mut base16);
        for &(mc, kc) in &[(1, 1), (5, 9), (256, 256)] {
            let mut c8 = vec![0i32; m * n];
            let mut c16 = vec![0i32; m * n];
            gemm_i8_portable_tiled(m, k, n, &a8, &b8, &mut c8, mc, kc);
            gemm_i16_portable_tiled(m, k, n, &a16, &b16, &mut c16, mc, kc);
            assert_eq!(base8, c8, "i8 tile ({mc},{kc})");
            assert_eq!(base16, c16, "i16 tile ({mc},{kc})");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = randvec(9, 6 * 4, 1.0);
        let mut t = vec![0.0; 24];
        let mut tt = vec![0.0; 24];
        transpose(6, 4, &a, &mut t);
        transpose(4, 6, &t, &mut tt);
        assert_eq!(a, tt);
    }

    #[test]
    fn qgemm_int24_path() {
        let (m, k, n) = (8, 8, 8);
        let a = randvec(11, m * k, 1.0);
        let b = randvec(12, k * n, 1.0);
        let sa = Scheme::for_range(max_abs(&a), 24);
        let sb = Scheme::for_range(max_abs(&b), 24);
        let mut c = vec![0.0; m * n];
        qgemm(m, k, n, &a, sa, &b, sb, &mut c);
        let want = naive_f32(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() <= 2e-3 * y.abs().max(1.0), "{x} vs {y}");
        }
    }
}
